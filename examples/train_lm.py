"""Train a (reduced) assigned-architecture LM for a few hundred steps with
checkpoint/restart — the end-to-end training driver.

    PYTHONPATH=src python examples/train_lm.py [arch] [steps]
"""
import sys
import tempfile

from repro.launch.train import train

arch = sys.argv[1] if len(sys.argv) > 1 else "granite-8b"
steps = int(sys.argv[2]) if len(sys.argv) > 2 else 200

with tempfile.TemporaryDirectory() as ckpt:
    print(f"== training {arch} (reduced config) for {steps} steps ==")
    _, final_loss = train(arch, smoke=True, steps=steps, batch=8, seq=64,
                          lr=3e-3, ckpt_dir=ckpt, ckpt_every=50,
                          n_microbatches=2)
    print(f"final loss {final_loss:.4f}")
    # restart from the checkpoint and keep training (resume path)
    _, resumed_loss = train(arch, smoke=True, steps=steps + 20, batch=8,
                            seq=64, lr=3e-3, ckpt_dir=ckpt, ckpt_every=50,
                            n_microbatches=2)
    print(f"after resume +20 steps: loss {resumed_loss:.4f}")
