"""Quickstart: alpha-seeded 10-fold SVM cross-validation in 20 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core.cv import run_cv
from repro.data.svm_suite import make_dataset

ds = make_dataset("madelon", n_override=600)
print(f"dataset={ds.name} n={ds.n} d={ds.X.shape[1]} C={ds.C} gamma={ds.gamma}")

run_cv(ds, k=10, method="cold"), run_cv(ds, k=10, method="sir")  # jit warmup
cold = run_cv(ds, k=10, method="cold")   # the LibSVM-style baseline
sir = run_cv(ds, k=10, method="sir")     # the paper's best seeder

print("\n          iterations   init(s)  solve(s)  accuracy")
for rep in (cold, sir):
    print(f"{rep.method:>6}    {rep.total_iterations:>10}   "
          f"{rep.total_init_time:7.3f}  {rep.total_solve_time:8.3f}  "
          f"{rep.accuracy:.4f}")
speedup = cold.total_solve_time / max(sir.total_init_time
                                      + sir.total_solve_time, 1e-9)
print(f"\nSIR is {speedup:.1f}x faster than cold-start CV, "
      f"identical accuracy = {sir.accuracy == cold.accuracy}")
