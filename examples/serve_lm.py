"""Serve a (reduced) model with batched one-token decode — prefill, then
cached generation; prints tokens/step timing.

    PYTHONPATH=src python examples/serve_lm.py [arch] [new_tokens]
"""
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.inputs import concrete_batch
from repro.models import init_params, model_params_def
from repro.models import transformer as T
from repro.serving import build_serve_step

arch = sys.argv[1] if len(sys.argv) > 1 else "gemma3-4b"
new_tokens = int(sys.argv[2]) if len(sys.argv) > 2 else 32
B, PROMPT = 4, 16

cfg = get_config(arch, smoke=True)
params = init_params(model_params_def(cfg), jax.random.PRNGKey(0), jnp.float32)
batch = concrete_batch(cfg, B, PROMPT)
batch.pop("patch_embeds", None)

# prefill: teacher-forced through the cache (also validates cache math)
enc_out = None
if cfg.is_encoder_decoder:
    enc_out = T._encode(params, batch["frames"], cfg, None)
cache = T.init_cache(cfg, B, PROMPT + new_tokens, jnp.float32,
                     enc_len=enc_out.shape[1] if enc_out is not None else 0)
serve_step = jax.jit(build_serve_step(cfg), donate_argnums=(1,))

tok = batch["tokens"][:, :1]
times = []
out_tokens = []
for t in range(PROMPT + new_tokens - 1):
    db = {"tokens": tok, "step": jnp.asarray(t, jnp.int32)}
    if cfg.rope_kind == "mrope":
        db["positions"] = jnp.full((B, 3, 1), t, jnp.int32)
    if cfg.is_encoder_decoder:
        db["enc_out"] = enc_out
    t0 = time.perf_counter()
    nxt, cache = serve_step(params, cache, db)
    nxt.block_until_ready()
    times.append(time.perf_counter() - t0)
    if t + 1 < PROMPT:
        tok = batch["tokens"][:, t + 1:t + 2]   # teacher-forced prompt
    else:
        tok = nxt[:, None]                       # free-running generation
        out_tokens.append(int(nxt[0]))

print(f"arch={arch} generated {len(out_tokens)} tokens/seq, batch={B}")
print("first sequence:", out_tokens[:16])
steady = times[2:]
print(f"decode step: {1e3 * sum(steady)/len(steady):.2f} ms "
      f"({B/ (sum(steady)/len(steady)):.1f} tok/s batch throughput)")
