"""End-to-end driver for the paper's full experimental protocol on one
dataset: the SVC estimator facade, all four seeding methods, the pooled
cross-gamma grid, and a fault-tolerant restart demo — every path a thin
plan over the Study API.

    PYTHONPATH=src python examples/svm_cv_seeding.py [dataset]
"""
import shutil
import sys
import tempfile

from repro.checkpoint import CheckpointManager
from repro.core.cv import run_cv
from repro.data.svm_suite import make_dataset
from repro.svm import SVC

name = sys.argv[1] if len(sys.argv) > 1 else "madelon"
ds = make_dataset(name, n_override=600)

# ---- the estimator facade: fit / predict / cross_validate ----
svc = SVC(C=ds.C, gamma=ds.gamma)
svc.fit(ds.X, ds.y)
print(f"== {ds.name}: n={ds.n}, C={ds.C}, gamma={ds.gamma}, k=10 ==")
print(f"SVC fit: {svc.n_iter_} iterations, converged={svc.converged_}, "
      f"train acc={svc.score(ds.X, ds.y):.4f}")

for method in ("cold", "ato", "mir", "sir"):
    rep = svc.cross_validate(ds.X, ds.y, k=10, method=method)
    r = rep.row()
    print(f"{method:>5}: iters={r['iterations']:>7} init={r['init_s']:>8}s "
          f"solve={r['solve_s']:>8}s acc={r['accuracy']}")
    if method == "sir":
        per_fold = [(f.fold, f.seed_from, f.n_iter) for f in rep.folds]
        print("       per-fold (fold, seeded_from, iters):", per_fold)

# ---- lane-scheduled fold execution: independent cold folds submitted to
# the lane pool (repacked/bucketed/width-capped dispatch) ----
from repro.core.cv import run_cv_batched  # noqa: E402

rep_cold = run_cv(ds, k=10, method="cold")
rep_bat = run_cv_batched(ds, k=10)
print(f"\ncold sequential: {rep_cold.row()['total_s']}s; "
      f"cold lane-scheduled: {rep_bat.row()['total_s']}s "
      f"(same per-fold fixed points; occupancy {rep_bat.occupancy})")

# ---- hyper-parameter grid: ONE multi-source pool across gammas — kernel
# reuse per gamma, C-adjacent alpha seeding, no per-row barrier ----
from repro.core.grid import run_grid  # noqa: E402

grid = run_grid(ds, Cs=[ds.C / 4, ds.C, ds.C * 4],
                gammas=[ds.gamma / 2, ds.gamma],
                k=5, method="sir", seed_across_C=True)
best = grid.best()
occ = grid.occupancy or {}
print(f"grid best cell: C={best.C} gamma={best.gamma} "
      f"acc={best.accuracy:.4f} ({grid.total_iterations} total iters; "
      f"per-gamma live widths {occ.get('per_source')})")

# ---- fault tolerance: the alpha chain doubles as the restart seed ----
tmp = tempfile.mkdtemp()
try:
    mgr = CheckpointManager(tmp)
    run_cv(ds, k=10, method="sir", checkpoint_manager=mgr)
    # simulate losing the node after fold 7: drop the last 2 checkpoints
    for s in mgr.all_steps()[-2:]:
        shutil.rmtree(mgr._step_dir(s))
    resumed = run_cv(ds, k=10, method="sir",
                     checkpoint_manager=CheckpointManager(tmp))
    redone = [f.fold for f in resumed.folds if not f.restored]
    kept = [f.fold for f in resumed.folds if f.restored]
    print(f"\nrestart after failure: recomputed folds {redone} only "
          f"(folds {kept} restored from checkpoint; report "
          f"{'partial' if resumed.partial else 'complete'})")
finally:
    shutil.rmtree(tmp, ignore_errors=True)
