"""End-to-end driver for the paper's full experimental protocol on one
dataset: the SVC estimator facade, all four seeding methods, the pooled
cross-gamma grid, and a fault-tolerant restart demo — every path a thin
plan over the Study API.

    PYTHONPATH=src python examples/svm_cv_seeding.py [dataset]

Study-service mode (DESIGN.md §Study service): start a daemon in one
terminal, then point any number of clients at it — each client's study
runs bit-identically to the in-process path, sharing the daemon's pool
(and deduping identical kernels across clients):

    PYTHONPATH=src python examples/svm_cv_seeding.py --serve /tmp/study.sock
    PYTHONPATH=src python examples/svm_cv_seeding.py \\
        --connect /tmp/study.sock [dataset]
"""
import shutil
import sys
import tempfile

from repro.checkpoint import CheckpointManager
from repro.core.cv import run_cv
from repro.data.svm_suite import make_dataset
from repro.svm import SVC


def _serve(sock_path: str) -> None:
    """Run the study daemon until Ctrl-C (drains gracefully)."""
    from repro.service import StudyServer, StudyService
    service = StudyService(chunk_iters=512,
                           checkpoint_root=tempfile.mkdtemp())
    print(f"study daemon on {sock_path} "
          f"(tol={service.pool.tol}, wss={service.pool.wss}) — Ctrl-C drains")
    StudyServer(sock_path, service).serve_forever()


def _connect(sock_path: str, name: str) -> None:
    """Submit this example's fold-chain study to a running daemon and
    compare against the local run — same bits, shared pool."""
    import getpass

    import jax.numpy as jnp

    from repro.core.cv import _fold_masks, _transition_idx
    from repro.core.study import Plan, run_plan
    from repro.data.svm_suite import kfold_chunks
    from repro.service import StudyClient
    from repro.svm.sources import KernelSpec

    ds = make_dataset(name, n_override=600)
    chunks = kfold_chunks(ds.n, 5, seed=0)
    nn = chunks.size
    X = jnp.asarray(ds.X)[:nn]
    y = jnp.asarray(ds.y, jnp.float64)[:nn]
    masks = jnp.asarray(_fold_masks(chunks))
    plan = Plan(sources={"k": KernelSpec(X=X, gamma=ds.gamma, n=nn)}, y=y,
                chunk_iters=512)
    plan.lane(0, train_mask=masks[0], C=ds.C,
              alpha0=jnp.zeros(nn), f0=-y)
    for h in range(1, 5):
        S, R, T = _transition_idx(chunks, h - 1, h)
        plan.lane(h, train_mask=masks[h], C=ds.C, dep=h - 1,
                  transform="fold",
                  params=dict(method="sir", S_idx=S, R_idx=R, T_idx=T))
    for h in range(5):
        plan.evaluate(h, chunks[h])

    with StudyClient(sock_path, tenant=getpass.getuser()) as cli:
        print(f"connected; daemon pool contract: {cli.pool_contract}")
        served = cli.submit(f"cv-{name}", plan,
                            on_result=lambda lid, r: print(
                                f"  fold {lid}: {int(r.n_iter)} iters"))
    local = run_plan(plan)
    same = all(bool((served.results[l].alpha == local.results[l].alpha).all())
               for l in local.results)
    acc = sum(c for c, _ in served.evals.values()) / \
        sum(t for _, t in served.evals.values())
    print(f"served 5-fold CV acc={acc:.4f}; bit-identical to local "
          f"run_plan: {same}; dedup_hits={served.dedup_hits} "
          f"(submit again from another terminal to see kernel dedup)")


if "--serve" in sys.argv:
    _serve(sys.argv[sys.argv.index("--serve") + 1])
    sys.exit(0)
if "--connect" in sys.argv:
    _i = sys.argv.index("--connect")
    _rest = [a for a in sys.argv[_i + 2:] if not a.startswith("-")]
    _connect(sys.argv[_i + 1], _rest[0] if _rest else "madelon")
    sys.exit(0)

name = sys.argv[1] if len(sys.argv) > 1 else "madelon"
ds = make_dataset(name, n_override=600)

# ---- the estimator facade: fit / predict / cross_validate ----
svc = SVC(C=ds.C, gamma=ds.gamma)
svc.fit(ds.X, ds.y)
print(f"== {ds.name}: n={ds.n}, C={ds.C}, gamma={ds.gamma}, k=10 ==")
print(f"SVC fit: {svc.n_iter_} iterations, converged={svc.converged_}, "
      f"train acc={svc.score(ds.X, ds.y):.4f}")

for method in ("cold", "ato", "mir", "sir"):
    rep = svc.cross_validate(ds.X, ds.y, k=10, method=method)
    r = rep.row()
    print(f"{method:>5}: iters={r['iterations']:>7} init={r['init_s']:>8}s "
          f"solve={r['solve_s']:>8}s acc={r['accuracy']}")
    if method == "sir":
        per_fold = [(f.fold, f.seed_from, f.n_iter) for f in rep.folds]
        print("       per-fold (fold, seeded_from, iters):", per_fold)

# ---- lane-scheduled fold execution: independent cold folds submitted to
# the lane pool (repacked/bucketed/width-capped dispatch) ----
from repro.core.cv import run_cv_batched  # noqa: E402

rep_cold = run_cv(ds, k=10, method="cold")
rep_bat = run_cv_batched(ds, k=10)
print(f"\ncold sequential: {rep_cold.row()['total_s']}s; "
      f"cold lane-scheduled: {rep_bat.row()['total_s']}s "
      f"(same per-fold fixed points; occupancy {rep_bat.occupancy})")

# ---- hyper-parameter grid: ONE multi-source pool across gammas — kernel
# reuse per gamma, C-adjacent alpha seeding, no per-row barrier ----
from repro.core.grid import run_grid  # noqa: E402

grid = run_grid(ds, Cs=[ds.C / 4, ds.C, ds.C * 4],
                gammas=[ds.gamma / 2, ds.gamma],
                k=5, method="sir", seed_across_C=True)
best = grid.best()
occ = grid.occupancy or {}
print(f"grid best cell: C={best.C} gamma={best.gamma} "
      f"acc={best.accuracy:.4f} ({grid.total_iterations} total iters; "
      f"per-gamma live widths {occ.get('per_source')})")

# ---- fault tolerance: the alpha chain doubles as the restart seed ----
tmp = tempfile.mkdtemp()
try:
    mgr = CheckpointManager(tmp)
    run_cv(ds, k=10, method="sir", checkpoint_manager=mgr)
    # simulate losing the node after fold 7: drop the last 2 checkpoints
    for s in mgr.all_steps()[-2:]:
        shutil.rmtree(mgr._step_dir(s))
    resumed = run_cv(ds, k=10, method="sir",
                     checkpoint_manager=CheckpointManager(tmp))
    redone = [f.fold for f in resumed.folds if not f.restored]
    kept = [f.fold for f in resumed.folds if f.restored]
    print(f"\nrestart after failure: recomputed folds {redone} only "
          f"(folds {kept} restored from checkpoint; report "
          f"{'partial' if resumed.partial else 'complete'})")
finally:
    shutil.rmtree(tmp, ignore_errors=True)
