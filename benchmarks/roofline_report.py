"""Roofline summary: aggregates results/dryrun/*.json into the per-cell
table used by EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.bench_lib import emit

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load_records(mesh: str | None = "pod16x16"):
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN, "*.json"))):
        with open(path) as fh:
            r = json.load(fh)
        if mesh and r.get("mesh") != mesh and r.get("status") == "ok":
            continue
        recs.append(r)
    return recs


def run(quick: bool = False):
    rows = []
    for r in load_records():
        if r.get("status") != "ok":
            if r.get("status") == "skipped":
                rows.append({"cell": r["cell"], "status": "skipped",
                             "dominant": "-", "compute_s": "-", "memory_s": "-",
                             "collective_s": "-", "roofline_fraction": "-",
                             "hbm_gb": "-", "useful_ratio": "-"})
            continue
        rf = r["roofline"]
        rows.append({
            "cell": r["cell"], "status": "ok", "dominant": rf["dominant"],
            "compute_s": f"{rf['compute_s']:.3e}",
            "memory_s": f"{rf['memory_s']:.3e}",
            "collective_s": f"{rf['collective_s']:.3e}",
            "roofline_fraction": round(rf["roofline_fraction"], 4)
            if rf["roofline_fraction"] else "-",
            "hbm_gb": r.get("hbm_gb_per_device", "-"),
            "useful_ratio": round(r["useful_flops_ratio"], 3)
            if r.get("useful_flops_ratio") else "-",
        })
    emit("roofline", rows)
    return rows


if __name__ == "__main__":
    run()
