"""Benchmark driver — one section per paper table/figure plus the roofline
report. ``python -m benchmarks.run [--quick]`` prints CSV per section and
writes JSON under results/bench/."""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small datasets / fewer k values")
    ap.add_argument("--only", default=None,
                    help="table1|table3|fig2|roofline")
    args = ap.parse_args()

    from benchmarks import fig2_loo, roofline_report, table1_kfold, table3_vary_k
    sections = {
        "table1": lambda: table1_kfold.run(quick=args.quick),
        "table3": lambda: table3_vary_k.run(quick=args.quick),
        "fig2": lambda: fig2_loo.run(quick=args.quick),
        "roofline": lambda: roofline_report.run(quick=args.quick),
    }
    for name, fn in sections.items():
        if args.only and name != args.only:
            continue
        print(f"\n### {name} " + "#" * 50, flush=True)
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            print(f"SECTION FAILED {name}: {type(e).__name__}: {e}",
                  file=sys.stderr)


if __name__ == '__main__':
    main()
