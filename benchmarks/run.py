"""Benchmark driver — one section per paper table/figure plus the roofline
report. ``python -m benchmarks.run [--quick]`` prints CSV per section and
writes JSON under results/bench/.

The table1 section additionally writes ``BENCH_table1.json`` at the repo
root (cold vs cold_batched vs seeded methods) so the perf trajectory is
tracked across PRs — CI runs ``--quick --only table1`` and uploads it.
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import sys

_REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _write_bench_table1(rows: list[dict], quick: bool) -> None:
    import jax
    # per-method aggregates (summed over datasets): the CI artifact diff
    # shows a seeding init-time regression — e.g. the jittable ATO losing
    # its edge over ato_ref — in one line instead of buried across rows
    per_method: dict[str, dict] = {}
    for r in rows:
        agg = per_method.setdefault(
            r["method"], {"init_s": 0.0, "solve_s": 0.0, "iterations": 0})
        agg["init_s"] += r["init_s"]
        agg["solve_s"] += r["solve_s"]
        agg["iterations"] += r["iterations"]
    for agg in per_method.values():
        agg["init_s"] = round(agg["init_s"], 4)
        agg["solve_s"] = round(agg["solve_s"], 4)
    # scheduler occupancy aggregate: mean live width (weighted by chunk
    # count) and peak width across every repacked run — a shrinking
    # mean_live_width is the repack win; mean == peak means retirement
    # never compacted the batch and the scheduler degraded to the old
    # fixed-width schedule
    occ_rows = [r["occupancy"] for r in rows if "occupancy" in r]
    # the historical mean/peak aggregate is the REPACKED-CV signal (a
    # shrinking mean_live_width is the repack win vs cold_batched); the
    # 45-lane grid rows would dominate its chunk counts and shift it for
    # schedule-unrelated reasons, so they are excluded here and tracked by
    # their own rows + the per-source block below
    cv_occ = [r["occupancy"] for r in rows
              if "occupancy" in r and not r["method"].startswith("grid")]
    scheduler = None
    if occ_rows:
        total_chunks = sum(o["chunks"] for o in cv_occ)
        scheduler = {
            "chunks": total_chunks,
            "mean_live_width": round(
                sum(o["mean_live_width"] * o["chunks"] for o in cv_occ)
                / max(total_chunks, 1), 3),
            "peak_width": max((o["peak_width"] for o in cv_occ), default=0),
        }
        # per-source (per-gamma) live widths from multi-source pools,
        # aggregated across datasets by source slot: a straggler gamma row
        # shows up as one slot's mean/peak running away from the others —
        # the cross-gamma pooling win stays visible as an artifact diff
        per_source: dict[str, dict] = {}
        for o in occ_rows:
            for key, s in (o.get("per_source") or {}).items():
                rec = per_source.setdefault(
                    key, {"chunks": 0, "live": 0.0, "peak": 0})
                rec["chunks"] += s["chunks"]
                rec["live"] += s["mean_live_width"] * s["chunks"]
                rec["peak"] = max(rec["peak"], s["peak_live_width"])
        if per_source:
            scheduler["per_source_live_width"] = {
                key: {"chunks": rec["chunks"],
                      "mean": round(rec["live"] / max(rec["chunks"], 1), 3),
                      "peak": rec["peak"]}
                for key, rec in sorted(per_source.items())}
    # kernel-source LRU aggregate (grid_pooled_lru rows): peak resident
    # kernels/bytes across datasets and total materializations — a memory
    # ceiling regression (budget not holding, or eviction thrash showing
    # up as runaway materialization counts) is a one-line artifact diff
    lru = [r["peak_resident"] for r in rows
           if r.get("method") == "grid_pooled_lru" and "peak_resident" in r]
    kernel_cache = None
    if lru:
        kernel_cache = {
            "peak_resident_sources": max(b["sources"] for b in lru),
            "peak_resident_bytes": max(b["bytes"] for b in lru),
            "materializations": sum(b["materializations"] for b in lru),
            "kernel_s": round(sum(b["kernel_s"] for b in lru), 4),
        }
    payload = {
        "bench": "table1_kfold",
        "quick": quick,
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "python": platform.python_version(),
        "per_method": per_method,
        "scheduler": scheduler,
        "kernel_cache": kernel_cache,
        "rows": rows,
    }
    out = os.path.join(_REPO_ROOT, "BENCH_table1.json")
    with open(out, "w") as fh:
        json.dump(payload, fh, indent=1)
    print(f"wrote {out}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small datasets / fewer k values")
    ap.add_argument("--only", default=None,
                    help="table1|table3|fig2|roofline")
    args = ap.parse_args()

    from benchmarks import fig2_loo, roofline_report, table1_kfold, table3_vary_k
    sections = {
        "table1": lambda: table1_kfold.run(quick=args.quick),
        "table3": lambda: table3_vary_k.run(quick=args.quick),
        "fig2": lambda: fig2_loo.run(quick=args.quick),
        "roofline": lambda: roofline_report.run(quick=args.quick),
    }
    failed = []
    for name, fn in sections.items():
        if args.only and name != args.only:
            continue
        print(f"\n### {name} " + "#" * 50, flush=True)
        try:
            rows = fn()
            if name == "table1" and rows:
                _write_bench_table1(rows, args.quick)
        except Exception as e:  # noqa: BLE001
            print(f"SECTION FAILED {name}: {type(e).__name__}: {e}",
                  file=sys.stderr)
            failed.append(name)
    if failed:
        # a green exit on failure would let CI publish the stale checked-in
        # BENCH_table1.json as this commit's perf numbers
        sys.exit(f"benchmark sections failed: {', '.join(failed)}")


if __name__ == '__main__':
    main()
