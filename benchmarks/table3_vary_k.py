"""Paper Table 3: effect of k on total elapsed time, cold vs SIR
(k in {3, 10, 25} — the paper's k=100 regime is run on the two small
datasets where it is CPU-feasible)."""
from __future__ import annotations

from benchmarks.bench_lib import emit
from repro.core.cv import run_cv
from repro.data.svm_suite import make_dataset

SIZES = {"heart": 270, "madelon": 1000}


def run(quick: bool = False):
    rows = []
    ks = (3, 10) if quick else (3, 10, 25, 100)
    for name, n in SIZES.items():
        ds = make_dataset(name, n_override=n)
        for k in ks:
            if k >= ds.n:
                continue
            for method in ("cold", "sir"):
                run_cv(ds, k=k, method=method)        # warm
                rep = run_cv(ds, k=k, method=method)  # measured
                rows.append(rep.row())
    emit("table3_vary_k", rows)
    return rows


if __name__ == "__main__":
    run()
