"""Paper Fig. 2 (suppl.): leave-one-out cross-validation — cold vs the two
prior LOO seeders (AVG, TOP) vs the paper's MIR/SIR chain."""
from __future__ import annotations

from benchmarks.bench_lib import emit
from repro.core.cv import run_loo
from repro.data.svm_suite import make_dataset

METHODS = ("cold", "avg", "top", "mir", "sir")


def run(quick: bool = False):
    rows = []
    cases = [("heart", 270, 100)] if quick else \
        [("heart", 270, 270), ("madelon", 600, 120)]
    for name, n, rounds in cases:
        ds = make_dataset(name, n_override=n)
        for method in METHODS:
            rows.append(run_loo(ds, method=method, rounds=rounds))
    emit("fig2_loo", rows)
    return rows


if __name__ == "__main__":
    run()
