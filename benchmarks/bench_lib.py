"""Shared benchmark helpers."""
from __future__ import annotations

import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "bench")


def emit(name: str, rows: list[dict]) -> None:
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, name + ".json"), "w") as fh:
        json.dump(rows, fh, indent=1)
    if rows:
        # column union in first-appearance order: rows are heterogeneous
        # (occupancy / roofline / residency blocks appear per schedule)
        cols = list(dict.fromkeys(c for r in rows for c in r))
        print(",".join(cols))
        for r in rows:
            print(",".join(str(r.get(c, "")) for c in cols))
