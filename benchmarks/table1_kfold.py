"""Paper Table 1: k=10 cross-validation efficiency — cold (the LibSVM
baseline) vs ATO / MIR / SIR. Columns mirror the paper: init time, solve
("the rest") time, total SMO iterations, accuracy.

Datasets are the synthetic suite at CPU-budget cardinality (DESIGN.md
§Synthetic datasets); each (dataset, method) runs twice and reports the warm
run so jit compile time doesn't pollute the init-time comparison (the
paper's C++ has no JIT).

Beyond the paper's columns, a ``cold_batched`` row runs the same k
independent cold folds CONCURRENTLY through the engine's batched solver
(identical per-fold fixed points; only the schedule differs). Its total_s
against ``cold``'s is the fold-batching speedup/overhead tracked across PRs
in BENCH_table1.json — on few-core CPU hosts the vmapped batch is typically
NOT faster (the (k, n) state busts cache and XLA CPU pays a thread fork/join
per parallel fusion); the batch schedule targets accelerator backends where
per-dispatch overhead dominates (DESIGN.md §Batched folds).

An ``ato_ref`` row runs the eager host-side ATO loop that ``ato`` (now a
fixed-shape jitted ramp, DESIGN.md §Jittable ATO) replaced: the pair makes
the ATO init-time win — and any regression of it — visible directly in
BENCH_table1.json's artifact diff.
"""
from __future__ import annotations

from benchmarks.bench_lib import emit
from repro.core.cv import run_cv, run_cv_batched
from repro.data.svm_suite import make_dataset

SIZES = {"adult": 1000, "heart": 270, "madelon": 1200, "mnist": 1000,
         "webdata": 1000}
METHODS = ("cold", "cold_batched", "ato", "ato_ref", "mir", "sir")


def run(k: int = 10, quick: bool = False, reps: int = 3):
    rows = []
    names = ("heart", "adult") if quick else tuple(SIZES)
    reps = 2 if quick else reps
    for name in names:
        ds = make_dataset(name, n_override=SIZES[name])
        for method in METHODS:
            runner = (lambda: run_cv_batched(ds, k=k)) \
                if method == "cold_batched" \
                else (lambda: run_cv(ds, k=k, method=method))
            runner()                                # warm the jit caches
            # min-of-reps: solver timings on shared CPUs are noisy (and the
            # near-degenerate suites hit denormal-heavy kernels); the min is
            # the standard low-variance estimator for the true cost
            rep = min((runner() for _ in range(reps)),
                      key=lambda r: r.total_solve_time)
            row = rep.row()
            row["us_per_iteration"] = round(
                1e6 * (rep.total_solve_time)
                / max(rep.total_iterations, 1), 2)
            rows.append(row)
    emit(f"table1_k{k}", rows)
    return rows


if __name__ == "__main__":
    run()
