"""Paper Table 1: k=10 cross-validation efficiency — cold (the LibSVM
baseline) vs ATO / MIR / SIR. Columns mirror the paper: init time, solve
("the rest") time, total SMO iterations, accuracy.

Datasets are the synthetic suite at CPU-budget cardinality (DESIGN.md
§Synthetic datasets); each (dataset, method) runs twice and reports the warm
run so jit compile time doesn't pollute the init-time comparison (the
paper's C++ has no JIT).

Beyond the paper's columns:

* ``cold_batched`` — the same k independent cold folds as ONE fixed-width
  batch through ``engine.solve_batched`` (identical per-fold fixed points;
  only the schedule differs). On few-core CPU hosts this was measured
  SLOWER than sequential (the live batch never shrinks — DESIGN.md
  §Batched folds);
* ``cold_batched_repacked`` — the same folds through the LaneScheduler
  (DESIGN.md §Lane scheduler): converged lanes retire between chunks, the
  live batch is repacked to bucketed widths, and the last straggler runs
  the sequential single-lane program. Its row carries an ``occupancy``
  dict (mean/peak live width) that ``benchmarks.run`` aggregates into the
  BENCH_table1.json ``scheduler`` block — the repack win, and any
  regression of it, is a one-line artifact diff against ``cold_batched``;
* ``ato_ref`` — the eager host-side ATO loop that the jitted ramp
  replaced, kept as the jit baseline;
* ``ato_shrink`` — ATO-seeded CV with active-set shrinking on (DESIGN.md
  §Shrinking), carrying the unshrunk baseline, the seeding handoff
  ablation, and an active-fraction-scaled ``hbm_per_iter`` block (see
  ``_shrink_row``);
* ``ato_bucketed`` — the batched ATO ramp across a 3-lane C row for every
  fold transition, with per-lane m_cap buckets (``init_s``) vs the
  historical widest-lane pad (``init_s_padded``); the bucketed ramp must
  be no slower on every dataset;
* ``grid_pooled`` / ``grid_rows`` — a 3x3 (C, gamma) grid through
  ``run_grid`` as ONE cross-gamma lane pool vs the per-gamma-row scheduler
  baseline (identical per-cell results; only the schedule differs). The
  pooled row carries the pool occupancy incl. per-source (per-gamma) live
  widths, so the straggler-row win — and any regression of it — stays
  visible in the BENCH_table1.json artifact diff. Acceptance: pooled is no
  slower in aggregate;
* ``grid_pooled_lru`` — the same cross-gamma pool under a 2-resident-kernel
  LRU budget (``max_resident=GRID_LRU_BUDGET``, DESIGN.md §Kernel-source
  cache): bit-identical cells, a ``peak_resident`` block (resident
  kernels/bytes, materialization count, kernel seconds) tracking the
  memory ceiling, and wall-clock required within ~10% of ``grid_pooled``;
* ``cold_pallas`` / ``grid_pooled_pallas`` — the matrix-free rows
  (DESIGN.md §Pallas sources): cold folds / a cold budgeted grid over
  row-streaming ``PallasRBF`` sources, never materializing an n² kernel.
  Each row carries an ``hbm_per_iter`` block — the analytic per-iteration
  HBM traffic of the dense vs fused-streaming source and the roofline
  service time of the pallas stream (``launch/roofline.py`` bandwidth
  model) — the accelerator-side signal these rows exist to track; on this
  CPU container the interpret-mode kernels make their wall-clock an
  emulation artifact, so they time one rep on a reduced grid
  (``PALLAS_GRID``) and their ``peak_resident.bytes`` (X bytes, not n²)
  is the load-bearing CPU-side number.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.bench_lib import emit
from repro.core import seeding
from repro.core.cv import _fold_masks, _transition_idx, run_cv, run_cv_batched
from repro.data.svm_suite import kfold_chunks, make_dataset
from repro.launch.roofline import roofline_terms
from repro.svm import (bias_from_solution, init_f, kernel_matrix, predict,
                       smo_solve_batched)

SIZES = {"adult": 1000, "heart": 270, "madelon": 1200, "mnist": 1000,
         "webdata": 1000}
METHODS = ("cold", "cold_batched", "cold_batched_repacked", "cold_pallas",
           "ato", "ato_ref", "mir", "sir")
#: C multipliers of the ato_bucketed row — a wide spread (a grid row's
#: realistic range) so lanes land in different free-set cap buckets on
#: every suite dataset (the case bucketing exists for); the middle lane is
#: the paper's C, keeping its accuracy comparable to the ato row
ATO_ROW_C = (0.01, 1.0, 100.0)
#: the grid_pooled/grid_rows comparison grid: multipliers of the paper's
#: (C, gamma), k=5 — 9 cells x 5 folds = 45 lanes per run, enough to give
#: the cross-gamma pool straggler rows to dissolve while keeping the
#: benchmark wall-clock sane
GRID_C = (0.25, 1.0, 4.0)
GRID_GAMMA = (0.5, 1.0, 2.0)
GRID_K = 5
#: the grid_pooled_lru residency budget: 2 of the 3 gamma kernels resident
#: at once — peak kernel bytes must read ~2/3 of the unbounded pool while
#: per-cell results stay bit-identical
GRID_LRU_BUDGET = 2
#: the ato_shrink row's heuristic cadence: at suite cardinality (n ~ 1000,
#: a few hundred iterations per seeded fold) a 512-iteration cadence gives
#: every fold at least one shrink opportunity without thrashing re-gathers
SHRINK_EVERY_BENCH = 512
#: the grid_pooled_pallas sizing: cold WSS-1 folds through interpret-mode
#: pallas cost 5-50x a compiled dense iteration on CPU, so the matrix-free
#: row runs a 2x2 grid corner — enough cells to exercise multi-source
#: residency accounting without dominating the bench wall-clock
PALLAS_GRID = 2


def _hbm_iter_estimate(n: int, d: int, active_frac: float = 1.0) -> dict:
    """Analytic per-SMO-iteration HBM traffic (f64): the dense source
    streams two (n,) kernel rows plus the solver state (f read+write,
    alpha update); the fused pallas step streams X once (n*d) plus the
    same state — one HBM pass per iteration regardless of n². memory_s is
    the roofline service time of the pallas stream at the accelerator
    bandwidth model's HBM_BW; with the MXU cross-term FLOPs alongside it
    shows which side of the ridge a fused iteration sits on.

    ``active_frac`` scales every per-iteration term to the compact working
    set a shrunk lane dispatches over (DESIGN.md §Shrinking): kernel rows,
    the X stream and the f/alpha state are all cap-length buffers, so the
    whole block shrinks with the run's measured mean active fraction. The
    full-set bytes are kept alongside for the artifact diff."""
    m = max(1, int(round(active_frac * n)))
    state = 3 * m * 8
    dense = 2 * m * 8 + state
    pallas = m * d * 8 + state
    flops = 2.0 * m * d + 8.0 * m
    rf = roofline_terms(flops, pallas, 0.0)
    out = {"dense_bytes": dense, "pallas_bytes": pallas,
           "memory_s": rf["memory_s"], "dominant": rf["dominant"]}
    if active_frac != 1.0:
        out["active_frac"] = round(float(active_frac), 4)
        out["dense_bytes_full"] = 2 * n * 8 + 3 * n * 8
        out["pallas_bytes_full"] = n * d * 8 + 3 * n * 8
    return out


def _grid_rows(name: str, reps: int) -> list[dict]:
    """Time the same (C, gamma) grid under the cross-gamma pool (unbounded
    residency), the cross-gamma pool under a 2-kernel LRU budget
    (``grid_pooled_lru``), and the per-gamma-row baseline. Per-cell results
    are bit-identical across all three (asserted in tests/test_study.py and
    tests/test_sources.py); the rows track the schedules' wall-clock,
    occupancy shape and — for the LRU row — the ``peak_resident`` block
    (resident kernels/bytes and materialization count): peak bytes must
    read ~len(gammas)/GRID_LRU_BUDGET x below the unbounded pool, and
    wall-clock must stay within ~10% of ``grid_pooled``."""
    from repro.core.grid import run_grid
    ds = make_dataset(name, n_override=SIZES[name])
    Cs = [m * ds.C for m in GRID_C]
    gammas = [m * ds.gamma for m in GRID_GAMMA]
    rows = []
    for method_name, kw in (
            ("grid_pooled", dict(pool="cross_gamma")),
            ("grid_pooled_lru", dict(pool="cross_gamma",
                                     max_resident=GRID_LRU_BUDGET)),
            ("grid_rows", dict(pool="per_gamma")),
            ("grid_pooled_pallas", dict(
                pool="cross_gamma", method="cold",
                source_backend="pallas_rbf", max_resident=GRID_LRU_BUDGET,
                Cs=Cs[:PALLAS_GRID], gammas=gammas[:PALLAS_GRID]))):
        def runner(kw=kw):
            return run_grid(ds, **{"Cs": Cs, "gammas": gammas, "k": GRID_K,
                                   "method": "sir", **kw})
        runner()                                 # warm the jit caches
        # interpret-mode pallas rows time a single rep (see module doc)
        r_eff = 1 if method_name == "grid_pooled_pallas" else reps
        rep = min((runner() for _ in range(r_eff)),
                  key=lambda r: r.solve_time)
        row = {"dataset": name, "method": method_name, "k": GRID_K,
               "iterations": rep.total_iterations,
               "init_s": round(rep.seed_time, 4),
               "solve_s": round(rep.solve_time, 4),
               "total_s": round(rep.seed_time + rep.solve_time
                                + rep.kernel_time, 4),
               "accuracy": round(rep.best().accuracy, 4),
               "us_per_iteration": round(
                   1e6 * rep.solve_time / max(rep.total_iterations, 1), 2)}
        if rep.occupancy is not None:
            row["occupancy"] = rep.occupancy
        # the memory-ceiling signal belongs to the budgeted rows only — the
        # unbudgeted pools' residency stats are trivial (all resident)
        if (method_name in ("grid_pooled_lru", "grid_pooled_pallas")
                and rep.resident is not None):
            row["peak_resident"] = {
                "sources": rep.resident["peak_resident"],
                "bytes": rep.resident["peak_resident_bytes"],
                "materializations": rep.resident["materializations"],
                "kernel_s": round(rep.kernel_time, 4)}
        if method_name == "grid_pooled_pallas":
            row["hbm_per_iter"] = _hbm_iter_estimate(rep.n, ds.X.shape[1])
        rows.append(row)
    return rows


def _shrink_row(name: str, k: int, reps: int) -> dict:
    """ATO-seeded k-fold CV with active-set shrinking on vs off (DESIGN.md
    §Shrinking): same seeder, same engine, same schedule — the only change
    is the pool compacting bound-locked rows out of each solve at bucketed
    capacities. The row reports the shrink run's timings plus the unshrunk
    baseline (``solve_s_noshrink`` / ``shrink_speedup``) and the
    seeding->shrinking handoff ablation (``solve_s_no_handoff``:
    ``shrink_on_seed=False``, so seeded lanes wait ``shrink_every``
    iterations to rediscover their bound-locked rows instead of starting
    shrunk). Fold accuracies are asserted identical to the unshrunk run —
    shrinking preserves the full-set optimality contract. ``hbm_per_iter``
    is scaled by the run's measured mean active fraction: on accelerators
    the per-iteration bytes (and the roofline service time) shrink with
    the working set, which is the signal this row exists to track on a
    CPU container whose width-1 dispatch cost is overhead-dominated."""
    ds = make_dataset(name, n_override=SIZES[name])

    def runner(**kw):
        return run_cv(ds, k=k, method="ato", **kw)

    on_kw = dict(shrink_every=SHRINK_EVERY_BENCH)
    runner()                                        # warm the jit caches
    off = min((runner() for _ in range(reps)),
              key=lambda r: r.total_solve_time)
    runner(**on_kw)                                 # warm the cap programs
    on = min((runner(**on_kw) for _ in range(reps)),
             key=lambda r: r.total_solve_time)
    accs = lambda r: sorted((f.fold, f.acc_correct) for f in r.folds)
    assert accs(on) == accs(off), \
        f"shrinking changed fold accuracies on {name}"
    handoff_kw = dict(on_kw, shrink_on_seed=False)
    runner(**handoff_kw)
    no_handoff = min((runner(**handoff_kw) for _ in range(reps)),
                     key=lambda r: r.total_solve_time)

    frac = (on.occupancy or {}).get("mean_active_frac", 1.0)
    row = on.row()
    row.update({
        "method": "ato_shrink",
        "us_per_iteration": round(
            1e6 * on.total_solve_time / max(on.total_iterations, 1), 2),
        "solve_s_noshrink": round(off.total_solve_time, 4),
        "shrink_speedup": round(
            off.total_solve_time / max(on.total_solve_time, 1e-9), 3),
        "solve_s_no_handoff": round(no_handoff.total_solve_time, 4),
        "hbm_per_iter": _hbm_iter_estimate(on.n, ds.X.shape[1],
                                           active_frac=frac)})
    if on.occupancy is not None:
        row["occupancy"] = on.occupancy
    return row


def _ato_bucketed_row(name: str, k: int, reps: int) -> dict:
    """Time the batched ATO ramp (one 3-lane C row, every fold transition)
    with per-lane buckets vs the widest-lane pad. The solve chain advances
    on the bucketed seeds; ramp timings are warm min-of-reps."""
    ds = make_dataset(name, n_override=SIZES[name])
    X = jnp.asarray(ds.X)
    y = jnp.asarray(ds.y, jnp.float64)
    chunks = kfold_chunks(ds.n, k, seed=0)
    n = chunks.size
    # slice before the kernel call (same fix as core/cv.py: the full
    # (N, N) kernel wastes O(N^2 - n^2) work for the truncated folds)
    K = kernel_matrix(X[:n], X[:n], kind="rbf", gamma=ds.gamma)
    y = y[:n]
    masks = jnp.asarray(_fold_masks(chunks))
    Cs = jnp.asarray([m * ds.C for m in ATO_ROW_C], jnp.float64)
    m = Cs.shape[0]

    # warm the batched-solver program (each dataset's n forces a fresh
    # trace) so solve_s matches the other rows' warm-run convention —
    # max_iter=1 compiles the same program (it_cap is traced, not static)
    jax.block_until_ready(smo_solve_batched(
        K, y, jnp.tile(masks[0][None], (m, 1)), Cs,
        jnp.zeros((m, n), K.dtype), jnp.tile(-y, (m, 1)), max_iter=1))
    t0 = time.perf_counter()
    prev = smo_solve_batched(K, y, jnp.tile(masks[0][None], (m, 1)), Cs,
                             jnp.zeros((m, n), K.dtype), jnp.tile(-y, (m, 1)))
    jax.block_until_ready(prev)
    solve_s = time.perf_counter() - t0
    iters = int(jnp.sum(prev.n_iter))
    correct = total = 0
    ramp_bucketed = ramp_padded = 0.0

    def eval_paper_lane(res, h):
        # accuracy of the paper-C lane (index 1), comparable to the ato row
        lane = jax.tree.map(lambda a: a[1], res)
        test_idx = jnp.asarray(chunks[h])
        b = bias_from_solution(lane, y, masks[h], float(Cs[1]))
        pred = predict(K[test_idx], y, lane.alpha, b)
        return int(jnp.sum(pred == y[test_idx])), int(test_idx.shape[0])

    c0, t0_ = eval_paper_lane(prev, 0)
    correct += c0
    total += t0_
    for h in range(1, k):
        S, R, T = _transition_idx(chunks, h - 1, h)
        timed = {}
        for key, flag in (("bucketed", True), ("padded", False)):
            def ramp(flag=flag):
                out = seeding.ato_seed_batch(K, y, Cs, prev, S, R, T,
                                             bucket_by_lane=flag)
                jax.block_until_ready(out)
                return out
            ramp()                                   # warm the jit caches
            best = float("inf")
            for _ in range(reps):
                t0 = time.perf_counter()
                out = ramp()
                best = min(best, time.perf_counter() - t0)
            timed[key] = best
            if flag:
                alpha0s = out
        ramp_bucketed += timed["bucketed"]
        ramp_padded += timed["padded"]
        f0s = jnp.stack([init_f(K, y, alpha0s[ci]) for ci in range(m)])
        t0 = time.perf_counter()
        prev = smo_solve_batched(K, y, jnp.tile(masks[h][None], (m, 1)), Cs,
                                 alpha0s, f0s)
        jax.block_until_ready(prev)
        solve_s += time.perf_counter() - t0
        iters += int(jnp.sum(prev.n_iter))
        ch, th = eval_paper_lane(prev, h)
        correct += ch
        total += th
    return {"dataset": name, "method": "ato_bucketed", "k": k,
            "iterations": iters, "init_s": round(ramp_bucketed, 4),
            "solve_s": round(solve_s, 4),
            "total_s": round(ramp_bucketed + solve_s, 4),
            "accuracy": round(correct / max(total, 1), 4),
            "us_per_iteration": round(1e6 * solve_s / max(iters, 1), 2),
            "init_s_padded": round(ramp_padded, 4)}


def run(k: int = 10, quick: bool = False, reps: int = 3):
    rows = []
    names = ("heart", "adult") if quick else tuple(SIZES)
    reps = 2 if quick else reps
    for name in names:
        ds = make_dataset(name, n_override=SIZES[name])
        for method in METHODS:
            if method == "cold_batched":
                runner = lambda: run_cv_batched(ds, k=k, schedule="batched")
            elif method == "cold_batched_repacked":
                runner = lambda: run_cv_batched(ds, k=k, schedule="repacked")
            elif method == "cold_pallas":
                runner = lambda: run_cv_batched(
                    ds, k=k, source_backend="pallas_rbf")
            else:
                runner = lambda m=method: run_cv(ds, k=k, method=m)
            runner()                                # warm the jit caches
            # min-of-reps: solver timings on shared CPUs are noisy (and the
            # near-degenerate suites hit denormal-heavy kernels); the min is
            # the standard low-variance estimator for the true cost — except
            # the interpret-mode pallas row, which times one rep (module doc)
            r_eff = 1 if method == "cold_pallas" else reps
            rep = min((runner() for _ in range(r_eff)),
                      key=lambda r: r.total_solve_time)
            row = rep.row()
            row["us_per_iteration"] = round(
                1e6 * (rep.total_solve_time)
                / max(rep.total_iterations, 1), 2)
            if rep.occupancy is not None:
                row["occupancy"] = rep.occupancy
            if method == "cold_pallas":
                row["hbm_per_iter"] = _hbm_iter_estimate(rep.n,
                                                         ds.X.shape[1])
            rows.append(row)
        rows.append(_shrink_row(name, k, reps))
        rows.append(_ato_bucketed_row(name, k, reps))
        rows.extend(_grid_rows(name, reps))
    emit(f"table1_k{k}", rows)
    return rows


if __name__ == "__main__":
    run()
