"""Paper Table 1: k=10 cross-validation efficiency — cold (the LibSVM
baseline) vs ATO / MIR / SIR. Columns mirror the paper: init time, solve
("the rest") time, total SMO iterations, accuracy.

Datasets are the synthetic suite at CPU-budget cardinality (DESIGN.md §8);
each (dataset, method) runs twice and reports the warm run so jit compile
time doesn't pollute the init-time comparison (the paper's C++ has no JIT).
"""
from __future__ import annotations

from benchmarks.bench_lib import emit
from repro.core.cv import run_cv
from repro.data.svm_suite import make_dataset

SIZES = {"adult": 1000, "heart": 270, "madelon": 1200, "mnist": 1000,
         "webdata": 1000}
METHODS = ("cold", "ato", "mir", "sir")


def run(k: int = 10, quick: bool = False):
    rows = []
    names = ("heart", "madelon") if quick else tuple(SIZES)
    for name in names:
        ds = make_dataset(name, n_override=SIZES[name])
        for method in METHODS:
            run_cv(ds, k=k, method=method)          # warm the jit caches
            rep = run_cv(ds, k=k, method=method)    # measured run
            row = rep.row()
            row["us_per_iteration"] = round(
                1e6 * (rep.total_solve_time)
                / max(rep.total_iterations, 1), 2)
            rows.append(row)
    emit(f"table1_k{k}", rows)
    return rows


if __name__ == "__main__":
    run()
