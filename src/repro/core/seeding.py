"""Alpha-seeding algorithms (the paper's contribution).

All seeders share one contract::

    alpha0 = seeder(K, y, C, prev, S_idx, R_idx, T_idx, ...)

where ``prev`` is the previous fold's ``SMOResult`` (its ``f`` is globally
consistent with its ``alpha`` for ALL instances — the solver maintains f for
masked rows too, see ``repro.svm.smo``), and the index arrays partition the
instance axis for the fold transition h -> h+1:

* ``S_idx`` — shared instances ((k-2) chunks),
* ``R_idx`` — removed (were in fold h's train set, become fold h+1's test),
* ``T_idx`` — added   (fold h's test set, join fold h+1's train set).

Every seeder returns ``alpha0`` that satisfies the box constraint
``0 <= alpha <= C`` and the equality constraint ``sum(y * alpha) = 0`` over
the NEW training set (S + T) — SMO's pairwise updates preserve the equality
constraint, so a violated start would never be repaired by the solver.

The constraint repair (paper §3 "Adjusting alpha'_T") is ``water_fill``:
uniformly shift beta = y*alpha by a scalar c, with box clipping, where c is
found by bisection on the monotone function sum(clip(beta - c)).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.svm.smo import SMOResult

_INF = jnp.inf


# --------------------------------------------------------------------------
# constraint repair
# --------------------------------------------------------------------------

def water_fill(beta: jnp.ndarray, lo: jnp.ndarray, hi: jnp.ndarray,
               target: jnp.ndarray, iters: int = 100) -> jnp.ndarray:
    """Return clip(beta - c, lo, hi) with scalar c s.t. the sum == target.

    ``sum(clip(beta - c, lo, hi))`` is monotone non-increasing in c, so c is
    found by bisection. ``target`` is clamped to the feasible [sum(lo),
    sum(hi)] first; callers handle any residual (see ``repair_equality``).
    """
    target = jnp.clip(target, jnp.sum(lo), jnp.sum(hi))
    c_lo = jnp.min(beta - hi) - 1.0   # => all at hi: sum maximal
    c_hi = jnp.max(beta - lo) + 1.0   # => all at lo: sum minimal

    def body(_, carry):
        c_lo, c_hi = carry
        c = 0.5 * (c_lo + c_hi)
        s = jnp.sum(jnp.clip(beta - c, lo, hi))
        too_big = s > target
        return jnp.where(too_big, c, c_lo), jnp.where(too_big, c_hi, c)

    c_lo, c_hi = jax.lax.fori_loop(0, iters, body, (c_lo, c_hi))
    c = 0.5 * (c_lo + c_hi)
    out = jnp.clip(beta - c, lo, hi)
    # final exact touch-up on the single freest coordinate to kill bisection
    # residue (keeps sum(y*alpha)=0 at fp-exact level for the solver)
    resid = target - jnp.sum(out)
    room = jnp.where(resid >= 0, hi - out, out - lo)
    j = jnp.argmax(room)
    fix = jnp.sign(resid) * jnp.minimum(jnp.abs(resid), room[j])
    return out.at[j].add(fix)


def _box(y: jnp.ndarray, C) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Box for beta = y * alpha: y=+1 -> [0, C]; y=-1 -> [-C, 0]."""
    lo = jnp.where(y > 0, 0.0, -C)
    hi = jnp.where(y > 0, C, 0.0)
    return lo, hi


def repair_equality(alpha0: jnp.ndarray, y: jnp.ndarray, C,
                    S_idx: jnp.ndarray, T_idx: jnp.ndarray) -> jnp.ndarray:
    """Make sum(y*alpha) over S+T exactly 0, touching T first (paper), and
    only spilling into S in the infeasible corner case (label-skewed folds).
    Both stages are no-ops when already satisfied."""
    beta = y * alpha0
    s_S = jnp.sum(beta[S_idx])
    lo_T, hi_T = _box(y[T_idx], C)
    beta_T = water_fill(beta[T_idx], lo_T, hi_T, -s_S)
    alpha0 = alpha0.at[T_idx].set(y[T_idx] * beta_T)
    # residual (only nonzero if -s_S was outside T's box-feasible range)
    resid = s_S + jnp.sum(beta_T)
    lo_S, hi_S = _box(y[S_idx], C)
    beta_S = water_fill(beta[S_idx], lo_S, hi_S, jnp.sum(beta[S_idx]) - resid)
    alpha0 = alpha0.at[S_idx].set(y[S_idx] * beta_S)
    return alpha0


def _bias(prev: SMOResult, y: jnp.ndarray, train_mask: jnp.ndarray, C) -> jnp.ndarray:
    """b with f_i = b on the free set (paper Constraint 5)."""
    free = train_mask & (prev.alpha > 0) & (prev.alpha < C)
    nf = jnp.sum(free)
    mean_f = jnp.sum(jnp.where(free, prev.f, 0.0)) / jnp.maximum(nf, 1)
    return jnp.where(nf > 0, mean_f, 0.5 * (prev.b_up + prev.b_low))


# --------------------------------------------------------------------------
# grid transitions: seed across adjacent C cells (same fold, same gamma)
# --------------------------------------------------------------------------

@jax.jit
def scale_seed_C(alpha: jnp.ndarray, y: jnp.ndarray, C_old, C_new,
                 train_mask: jnp.ndarray) -> jnp.ndarray:
    """Warm-start the (C_new, gamma) grid cell from the (C_old, gamma)
    solution of the SAME fold.

    Bounded SVs sit at alpha = C, and the bound scales linearly with C, so
    ``alpha * C_new / C_old`` is a strong predictor of the neighbour cell's
    solution (free SVs move less; SMO polishes them). Scaling preserves
    ``sum(y * alpha) = 0`` up to fp error; the water-fill repair makes it
    exact again after box clipping. Rows outside ``train_mask`` stay 0.

    This generalizes the paper's fold-chain warm start to the C axis of a
    hyper-parameter grid (see ``repro.core.grid``).
    """
    s = jnp.asarray(C_new, alpha.dtype) / jnp.asarray(C_old, alpha.dtype)
    beta = y * alpha * s
    lo, hi = _box(y, C_new)
    lo = jnp.where(train_mask, lo, 0.0)
    hi = jnp.where(train_mask, hi, 0.0)
    beta = water_fill(jnp.clip(beta, lo, hi), lo, hi, jnp.zeros((), alpha.dtype))
    return y * beta


# --------------------------------------------------------------------------
# cold start (the LibSVM baseline)
# --------------------------------------------------------------------------

def cold_seed(K, y, C, prev, S_idx, R_idx, T_idx, **_):
    return jnp.zeros_like(y, dtype=K.dtype)


# --------------------------------------------------------------------------
# MIR — Multiple Instance Replacement (paper Eq. 13-18, Algorithm 2)
# --------------------------------------------------------------------------

@jax.jit
def mir_seed(K, y, C, prev: SMOResult, S_idx, R_idx, T_idx):
    """Keep alpha_S; solve one least-squares system for alpha'_T.

    Eq. 17, divided through by y_i (Q_ij = y_i y_j K_ij), in terms of
    beta_t = y_t alpha'_t:   K[X,T] @ beta_T  =  df + K[X,R] @ beta_R
    plus the equality row    1^T beta_T       =  1^T beta_R
    with df_i = b - f_i on I_u + I_l and 0 on I_m (rows i over the previous
    training set X = S + R). Solved by lstsq; the box/equality constraints
    are then repaired per the paper's AdjustAlpha.
    """
    X_idx = jnp.concatenate([S_idx, R_idx])
    alpha, f = prev.alpha, prev.f
    mask_prev = jnp.zeros(y.shape, bool).at[X_idx].set(True)
    b = _bias(prev, y, mask_prev, C)
    free = (alpha > 0) & (alpha < C)
    df = jnp.where(free, 0.0, b - f)[X_idx]

    beta_R = (y * alpha)[R_idx]
    rhs = df + K[X_idx][:, R_idx] @ beta_R
    A = K[X_idx][:, T_idx]
    # append the equality constraint as one more row of the LS system
    A_full = jnp.concatenate([A, jnp.ones((1, T_idx.shape[0]), K.dtype)], 0)
    rhs_full = jnp.concatenate([rhs, jnp.sum(beta_R)[None]], 0)
    beta_T, *_ = jnp.linalg.lstsq(A_full, rhs_full)

    lo, hi = _box(y[T_idx], C)
    beta_T = water_fill(jnp.clip(beta_T, lo, hi), lo, hi, jnp.sum(beta_R))
    alpha0 = jnp.zeros_like(alpha).at[S_idx].set(alpha[S_idx])
    alpha0 = alpha0.at[T_idx].set(y[T_idx] * beta_T)
    return repair_equality(alpha0, y, C, S_idx, T_idx)


# --------------------------------------------------------------------------
# SIR — Single Instance Replacement (paper Eq. 19-21, Algorithm 3)
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("fallback",))
def sir_seed(K, y, C, prev: SMOResult, S_idx, R_idx, T_idx,
             rng_key: jax.Array | None = None, fallback: str = "random"):
    """Greedy replacement: each removed x_r inherits its alpha to the most
    similar (max kernel value) unused same-label x_t, followed by constraint
    repair.

    ``fallback`` controls the label-less case (no unused same-label x_t):

    * ``"random"`` — the paper's rule: a random unused pick. A sign-flipped
      beta lands on one coordinate; the repair then shifts every T beta.
    * ``"skip"`` — beyond-paper: drop that alpha and let the (uniform,
      diffuse) repair absorb the mass. Avoids poisoning single coordinates
      with large wrong-sign alphas, which SMO then diffuses over the whole
      free set (iteration counts for both variants come from
      ``benchmarks.table1_kfold``; see DESIGN.md §Benchmarks).
    """
    if rng_key is None:
        rng_key = jax.random.PRNGKey(0)
    m = R_idx.shape[0]
    K_RT = K[R_idx][:, T_idx]
    same = (y[R_idx][:, None] == y[T_idx][None, :])
    alpha_R = prev.alpha[R_idx]
    priority = jax.random.uniform(rng_key, (T_idx.shape[0],), K.dtype)

    def body(r, carry):
        beta_T, used = carry
        scores = jnp.where(same[r] & ~used, K_RT[r], -_INF)
        t_best = jnp.argmax(scores)
        found = scores[t_best] > -_INF
        t_rand = jnp.argmax(jnp.where(~used, priority, -_INF))
        t = jnp.where(found, t_best, t_rand)
        any_free = jnp.any(~used)
        if fallback == "skip":
            write = any_free & found
        else:
            write = any_free
        beta_T = jnp.where(write,
                           beta_T.at[t].set(y[T_idx][t] * alpha_R[r]), beta_T)
        used = jnp.where(write, used.at[t].set(True), used)
        return beta_T, used

    beta_T, _ = jax.lax.fori_loop(
        0, m, body, (jnp.zeros(T_idx.shape[0], K.dtype),
                     jnp.zeros(T_idx.shape[0], bool)))

    lo, hi = _box(y[T_idx], C)
    beta_T = water_fill(jnp.clip(beta_T, lo, hi), lo, hi,
                        jnp.sum((y * prev.alpha)[R_idx]))
    alpha0 = jnp.zeros_like(prev.alpha).at[S_idx].set(prev.alpha[S_idx])
    alpha0 = alpha0.at[T_idx].set(y[T_idx] * beta_T)
    return repair_equality(alpha0, y, C, S_idx, T_idx)


# --------------------------------------------------------------------------
# ATO — Adjusting Alpha Towards Optimum (paper Eq. 7-11, Algorithm 1)
# --------------------------------------------------------------------------
#
# Two implementations share the per-step ramp/retire/graduate semantics:
#
# * ``ato_seed``     — fixed-shape ``lax.while_loop``: the dynamic M/T/R
#   index sets become boolean masks, the per-step least-squares system is a
#   bordered KKT solve over a padded working set, and the whole transition
#   (ramp + constraint repair) runs as ONE jitted device program with zero
#   host syncs inside the loop (see DESIGN.md §Jittable ATO).
# * ``ato_seed_ref`` — the eager host-side loop kept as the executable
#   reference (paper-faithful pinv least squares); the parity contract is
#   covered by tests/test_seeding.py.


def ato_seed_ref(K, y, C, prev: SMOResult, S_idx, R_idx, T_idx,
                 max_steps: int = 30, tol: float = 1e-3):
    """Karasuyama/Takeuchi-style incremental-decremental ramp (reference).

    Host-side loop (the working sets change size every step; the dense
    (1+|M|) x |M| pseudo-inverse dominates — exactly the cost profile the
    paper reports for ATO). Eager jnp ops; terminates when R is drained or
    after ``max_steps`` (then clamps alpha_R to 0, as the remaining mass is
    small) and always ends with the exact constraint repair.
    """
    y = jnp.asarray(y, K.dtype)
    alpha = prev.alpha.copy()
    f = prev.f.copy()
    n = y.shape[0]
    in_S, in_T, in_R = _transition_masks(n, S_idx, R_idx, T_idx)
    T_active = in_T
    R_active = in_R & (alpha > 0)
    alpha = jnp.where(in_T, 0.0, alpha)

    for _ in range(max_steps):
        if not bool(jnp.any(R_active)) and not bool(jnp.any(T_active)):
            break
        train_now = in_S | (in_T & ~T_active)
        free = train_now & (alpha > 0) & (alpha < C)
        b = (jnp.sum(jnp.where(free, f, 0.0)) / jnp.maximum(jnp.sum(free), 1)
             if bool(jnp.any(free)) else 0.5 * (prev.b_up + prev.b_low))

        M = jnp.where(free)[0]
        Tc = jnp.where(T_active)[0]
        Rc = jnp.where(R_active)[0]
        vT = C - alpha[Tc]                     # per-unit ramp-up of alpha_T
        vR = -alpha[Rc]                        # per-unit ramp-down of alpha_R
        # Phi = pinv([y_M; Q_MM]) [y_T y_R; Q_MT Q_MR] [C1-a_T; -a_R] (Eq.10)
        if M.size > 0:
            yM = y[M]
            Q_MM = (yM[:, None] * yM[None, :]) * K[M][:, M]
            Q_MT = (yM[:, None] * y[Tc][None, :]) * K[M][:, Tc]
            Q_MR = (yM[:, None] * y[Rc][None, :]) * K[M][:, Rc]
            A1 = jnp.concatenate([yM[None, :], Q_MM], 0)
            rhs = jnp.concatenate([(y[Tc] @ vT + y[Rc] @ vR)[None],
                                   Q_MT @ vT + Q_MR @ vR], 0)
            Phi = jnp.linalg.pinv(A1) @ rhs
        else:
            Phi = jnp.zeros((0,), K.dtype)
        # per-unit df (Eq. 11 divided by y_i): g_i = -sum_M y_m Phi_m K_im
        #   + sum_T y_t (C-a_t) K_it - sum_R y_r a_r K_ir
        g = (K[:, Tc] @ (y[Tc] * vT) + K[:, Rc] @ (y[Rc] * vR))
        if M.size > 0:
            g = g - K[:, M] @ (y[M] * Phi)
        # step size: smallest eta>0 putting some bound instance's f at b (Eq.5)
        bound = train_now & ~free
        safe_g = jnp.where(jnp.abs(g) > 1e-12, g, 1.0)
        etas = jnp.where(bound & (jnp.abs(g) > 1e-12), (b - f) / safe_g, _INF)
        etas = jnp.where(etas > 1e-12, etas, _INF)
        eta = float(jnp.minimum(jnp.min(etas), 1.0)) if etas.size else 1.0
        if not jnp.isfinite(eta):
            eta = 1.0
        # apply
        if M.size > 0:
            alpha = alpha.at[M].add(-eta * Phi)
        alpha = alpha.at[Tc].add(eta * vT)
        alpha = alpha.at[Rc].add(eta * vR)
        alpha = jnp.clip(alpha, 0.0, C)
        f = f + eta * g
        # retire drained R instances; graduate T instances that meet Eq. 5
        R_active = R_active & (alpha > 1e-12 * max(C, 1.0))
        fT, aT = f[Tc], alpha[Tc]
        ok_m = (aT > 0) & (aT < C) & (jnp.abs(fT - b) <= tol)
        ok_u = ((y[Tc] > 0) & (aT <= 0) | ((y[Tc] < 0) & (aT >= C))) & (fT >= b - tol)
        ok_l = ((y[Tc] > 0) & (aT >= C) | ((y[Tc] < 0) & (aT <= 0))) & (fT <= b + tol)
        T_active = T_active.at[Tc].set(~(ok_m | ok_u | ok_l))
        if eta >= 1.0:
            break

    alpha = jnp.where(in_R, 0.0, alpha)   # R must leave the training set
    return repair_equality(alpha, y, C, S_idx, T_idx)


def _bucket_cap(m: int, n: int) -> int:
    """Smallest multiple of 128 >= m, clamped to [1, n]. Buckets the
    working-set pad so jit retraces are O(n / 128) per problem size instead
    of one per transition, while keeping the padded LU within ~2x of the
    exact-|M| cost (a pow2 bucket can pad 605 -> 1024 and quadruple it)."""
    cap = max(128, -(-m // 128) * 128)
    return max(1, min(cap, n))


def _ato_ramp(K, y, C, alpha, f, b_fallback, in_S, in_T, in_R, tol,
              m_cap: int, max_steps: int):
    """Fixed-shape ATO ramp: ``ato_seed_ref``'s loop with masks for the
    M/T/R sets and a bordered KKT solve for Phi. Pure traced function —
    jit- and vmap-safe (the grid batches it across a C row).

    The free set M is always a subset of (initially-free S rows) + T: a
    bounded row's alpha never moves (only M/T-active/R-active alphas do), so
    it can never become free, while graduated T rows can. Callers therefore
    pad the working set to ``m_cap >= |free S at entry| + |T|``, which is
    exact — overflow is impossible, not just unlikely.
    """
    n = y.shape[0]
    C = jnp.asarray(C, K.dtype)
    thresh = 1e-12 * jnp.maximum(C, 1.0)
    valid = jnp.arange(m_cap)

    def cond(carry):
        _alpha, _f, T_act, R_act, step, stop = carry
        return (step < max_steps) & ~stop & (jnp.any(R_act) | jnp.any(T_act))

    def body(carry):
        alpha, f, T_act, R_act, step, _ = carry
        train_now = in_S | (in_T & ~T_act)
        free = train_now & (alpha > 0) & (alpha < C)
        nf = jnp.sum(free)
        b = jnp.where(nf > 0,
                      jnp.sum(jnp.where(free, f, 0.0)) / jnp.maximum(nf, 1),
                      b_fallback)
        # ramp directions: T ramps up to C, R ramps down to 0 (per unit eta)
        v = jnp.where(T_act, C - alpha, 0.0) - jnp.where(R_act, alpha, 0.0)
        w = y * v
        # fixed-shape working set: indices of M padded to m_cap (padding
        # lanes gather row 0 but are masked out of every product below)
        idx = jnp.nonzero(free, size=m_cap, fill_value=0)[0]
        lane = valid < nf
        yM = jnp.where(lane, y[idx], 0.0)
        Q = (yM[:, None] * yM[None, :]) * K[idx][:, idx]
        # Bordered KKT system replacing the reference's pinv least squares
        # (Eq. 10): unknown (db, Phi) with the equality row enforced exactly
        #     [0    yM^T] [db ]   [sum(w)        ]
        #     [yM   Q_MM] [Phi] = [yM * (K_M: @ w)]
        # Padding lanes carry an identity diagonal and zero rhs (Phi = 0
        # there); a tiny relative ridge keeps the LU finite on duplicate
        # instances, and a non-finite solve falls back to Phi = 0 (pure
        # T/R ramp — the M-empty behaviour).
        lam = 1e-10 * (1.0 + jnp.max(jnp.abs(jnp.diagonal(Q))))
        B = jnp.zeros((m_cap + 1, m_cap + 1), K.dtype)
        B = B.at[0, 0].set(jnp.where(nf > 0, 0.0, 1.0))
        B = B.at[0, 1:].set(yM)
        B = B.at[1:, 0].set(yM)
        B = B.at[1:, 1:].set(Q + jnp.diag(jnp.where(lane, lam, 1.0)))
        r0 = jnp.where(nf > 0, jnp.sum(w), 0.0)
        r = yM * (K[idx] @ w)
        sol = jnp.linalg.solve(B, jnp.concatenate([r0[None], r]))
        Phi = jnp.where(lane & jnp.isfinite(sol[1:]), sol[1:], 0.0)
        Phi_full = jnp.zeros(n, K.dtype).at[idx].add(jnp.where(lane, Phi, 0.0))
        # per-unit df (Eq. 11 divided by y_i), one kernel matvec
        g = K @ (w - y * Phi_full)
        # step size: smallest eta>0 putting some bound instance's f at b
        bound = train_now & ~free
        live = jnp.abs(g) > 1e-12
        safe_g = jnp.where(live, g, 1.0)
        etas = jnp.where(bound & live, (b - f) / safe_g, _INF)
        etas = jnp.where(etas > 1e-12, etas, _INF)
        eta = jnp.minimum(jnp.min(etas), 1.0)
        eta = jnp.where(jnp.isfinite(eta), eta, jnp.ones((), K.dtype))
        # apply (M, T-active, R-active are disjoint: one fused update)
        alpha_new = jnp.clip(alpha + eta * (v - Phi_full), 0.0, C)
        f_new = f + eta * g
        # retire drained R instances; graduate T instances that meet Eq. 5
        R_new = R_act & (alpha_new > thresh)
        ok_m = (alpha_new > 0) & (alpha_new < C) & (jnp.abs(f_new - b) <= tol)
        ok_u = (((y > 0) & (alpha_new <= 0)) | ((y < 0) & (alpha_new >= C))) \
            & (f_new >= b - tol)
        ok_l = (((y > 0) & (alpha_new >= C)) | ((y < 0) & (alpha_new <= 0))) \
            & (f_new <= b + tol)
        T_new = T_act & ~(ok_m | ok_u | ok_l)
        return (alpha_new, f_new, T_new, R_new, step + 1, eta >= 1.0)

    carry = (jnp.where(in_T, 0.0, alpha), f, in_T, in_R & (alpha > 0),
             jnp.zeros((), jnp.int32), jnp.zeros((), bool))
    alpha, *_ = jax.lax.while_loop(cond, body, carry)
    return jnp.where(in_R, 0.0, alpha)   # R must leave the training set


@functools.partial(jax.jit, static_argnames=("m_cap", "max_steps"))
def _ato_seed_jit(K, y, C, alpha, f, b_fallback, in_S, in_T, in_R,
                  S_idx, T_idx, tol, *, m_cap, max_steps):
    out = _ato_ramp(K, y, C, alpha, f, b_fallback, in_S, in_T, in_R, tol,
                    m_cap, max_steps)
    return repair_equality(out, y, jnp.asarray(C, K.dtype), S_idx, T_idx)


@functools.partial(jax.jit, static_argnames=("m_cap", "max_steps"))
def _ato_seed_batch_jit(K, y, Cs, alphas, fs, b_fallbacks, in_S, in_T, in_R,
                        S_idx, T_idx, tol, *, m_cap, max_steps):
    def one(C, alpha, f, b_fb):
        out = _ato_ramp(K, y, C, alpha, f, b_fb, in_S, in_T, in_R, tol,
                        m_cap, max_steps)
        return repair_equality(out, y, jnp.asarray(C, K.dtype), S_idx, T_idx)

    return jax.vmap(one)(Cs, alphas, fs, b_fallbacks)


def _transition_masks(n, S_idx, R_idx, T_idx):
    in_T = jnp.zeros(n, bool).at[T_idx].set(True)
    in_R = jnp.zeros(n, bool).at[R_idx].set(True)
    in_S = jnp.zeros(n, bool).at[S_idx].set(True)
    return in_S, in_T, in_R


def ato_seed(K, y, C, prev: SMOResult, S_idx, R_idx, T_idx,
             max_steps: int = 30, tol: float = 1e-3):
    """Jittable ATO: ``ato_seed_ref``'s ramp as one fixed-shape device
    program (see ``_ato_ramp``). The single host sync below sizes the padded
    working set BEFORE the loop; everything else — including the constraint
    repair — runs on device.
    """
    y = jnp.asarray(y, K.dtype)
    n = y.shape[0]
    in_S, in_T, in_R = _transition_masks(n, S_idx, R_idx, T_idx)
    nf0 = int(jnp.sum(in_S & (prev.alpha > 0) & (prev.alpha < C)))
    m_cap = _bucket_cap(nf0 + int(T_idx.shape[0]), n)
    b_fb = 0.5 * (prev.b_up + prev.b_low)
    return _ato_seed_jit(K, y, C, prev.alpha, prev.f, b_fb, in_S, in_T, in_R,
                         S_idx, T_idx, tol, m_cap=m_cap,
                         max_steps=int(max_steps))


def ato_seed_batch(K, y, Cs, prev: SMOResult, S_idx, R_idx, T_idx,
                   max_steps: int = 30, tol: float = 1e-3,
                   bucket_by_lane: bool = True):
    """Batched ATO over lanes sharing one fold transition — the grid's
    C-row case: ``prev`` is a batched ``SMOResult`` (leading axis = lane,
    one per C value) and ``Cs`` its per-lane C. One vmapped while_loop
    ramps a group of lanes concurrently (lanes that finish freeze via the
    batching rule's select).

    ``bucket_by_lane=True`` (default) applies the scheduler's repacking
    idea to the ramp pad: each lane's working-set cap is computed from ITS
    OWN free set (``_bucket_cap(|free S|_i + |T|, n)`` — the same exact
    bound the solo ``ato_seed`` uses), lanes are grouped by cap, and one
    program is dispatched per bucket. Lanes with a small free set no
    longer pay the widest lane's O(m_cap^3) bordered solve; since caps are
    already bucketed to multiples of 128, the group count (and the jit
    retrace count) stays O(n / 128). ``bucket_by_lane=False`` keeps the
    historical behaviour — every lane padded to the widest cap in one
    program (the baseline the ``ato_bucketed`` benchmark row compares
    against).
    """
    y = jnp.asarray(y, K.dtype)
    n = y.shape[0]
    Cs = jnp.asarray(Cs, K.dtype)
    in_S, in_T, in_R = _transition_masks(n, S_idx, R_idx, T_idx)
    free0 = in_S[None] & (prev.alpha > 0) & (prev.alpha < Cs[:, None])
    nf0s = np.asarray(jnp.sum(free0, axis=1))   # one (lanes,) transfer
    t_sz = int(T_idx.shape[0])
    b_fbs = 0.5 * (prev.b_up + prev.b_low)
    if bucket_by_lane:
        caps = np.asarray([_bucket_cap(int(nf) + t_sz, n) for nf in nf0s])
    else:
        caps = np.full(nf0s.shape[0],
                       _bucket_cap(int(nf0s.max()) + t_sz, n))
    out = jnp.zeros(prev.alpha.shape, K.dtype)
    # the trace key is (m_cap, group size): caps are monotone in C, so
    # bucket membership is a contiguous C-range and the distinct
    # (cap, size) combinations stay small for realistic rows. Padding
    # group sizes would bound the key space further but costs a full
    # O(m_cap^3)-per-step ramp lane per pad — not worth it at C-row scale.
    for cap in sorted(set(caps.tolist())):
        idx = jnp.asarray(np.nonzero(caps == cap)[0])
        sub = _ato_seed_batch_jit(K, y, Cs[idx], prev.alpha[idx],
                                  prev.f[idx], b_fbs[idx], in_S, in_T, in_R,
                                  S_idx, T_idx, tol, m_cap=int(cap),
                                  max_steps=int(max_steps))
        out = out.at[idx].set(sub)
    return out


# --------------------------------------------------------------------------
# LOO baselines: AVG (DeCoste & Wagstaff 2000) and TOP (Lee et al. 2004)
# --------------------------------------------------------------------------

@jax.jit
def avg_seed_loo(K, y, C, alpha, t: jnp.ndarray):
    """Remove instance t; distribute beta_t = y_t alpha_t uniformly over the
    free set, iterating the spill of box-clipped excess (paper suppl.)."""
    beta = y * alpha
    resid = beta[t]
    beta = beta.at[t].set(0.0)
    lo, hi = _box(y, C)
    lo = lo.at[t].set(0.0)
    hi = hi.at[t].set(0.0)
    free0 = (alpha > 0) & (alpha < C)
    free0 = free0.at[t].set(False)

    def body(_, carry):
        beta, resid = carry
        room = jnp.where(resid >= 0, hi - beta, beta - lo)
        can = free0 & (room > 1e-15)
        d = jnp.maximum(jnp.sum(can), 1)
        share = resid / d
        add = jnp.clip(jnp.where(can, share, 0.0),
                       -(beta - lo), hi - beta)
        beta = beta + add
        return beta, resid - jnp.sum(add)

    beta, resid = jax.lax.fori_loop(0, 8, body, (beta, resid))
    alpha0 = y * water_fill(beta, lo, hi, 0.0)
    return alpha0


@jax.jit
def top_seed_loo(K, y, C, alpha, t: jnp.ndarray):
    """Remove instance t; spill beta_t into instances by descending kernel
    similarity K(x_j, x_t) until absorbed (paper suppl., TOP)."""
    beta = y * alpha
    resid = beta[t]
    beta = beta.at[t].set(0.0)
    lo, hi = _box(y, C)
    lo = lo.at[t].set(0.0)
    hi = hi.at[t].set(0.0)
    sim = K[:, t].at[t].set(-_INF)
    order = jnp.argsort(-sim)

    def body(i, carry):
        beta, resid = carry
        j = order[i]
        room = jnp.where(resid >= 0, hi[j] - beta[j], lo[j] - beta[j])
        take = jnp.clip(resid, jnp.minimum(room, 0.0), jnp.maximum(room, 0.0))
        return beta.at[j].add(take), resid - take

    beta, resid = jax.lax.fori_loop(0, y.shape[0] - 1, body, (beta, resid))
    return y * water_fill(beta, lo, hi, 0.0)


SEEDERS = {"cold": cold_seed, "ato": ato_seed, "ato_ref": ato_seed_ref,
           "mir": mir_seed, "sir": sir_seed}

# Seeding -> shrinking handoff (DESIGN.md §Shrinking): a seeded start is
# not just an alpha0 — it implies an initial ACTIVE-SET estimate. Rows the
# seeder left bound-locked against the seeded (b_up, b_low) can start
# shrunk instead of waiting shrink_every iterations to be discovered; the
# pool evaluates this at admission (``shrink_on_seed``) on every transform's
# output through the same heuristic the solver uses mid-run. Re-exported
# here so seeding-layer callers can inspect the mask a transform implies
# without importing the solver-side module.
from repro.svm.shrink import seed_active_mask  # noqa: E402,F401


# --------------------------------------------------------------------------
# named seed transforms — the Study API's admission vocabulary
# --------------------------------------------------------------------------
#
# A transform maps a retired lane's ``SMOResult`` to the next lane's start
# point under one shared contract::
#
#     alpha0 = TRANSFORMS[name](K, y, C, prev, **params)
#
# where (K, y) come from the depending lane's kernel source, C is ITS box
# bound, and ``params`` are the plan-declared keyword arguments (index
# sets, the neighbour C, the held-out instance...). Plans reference
# transforms BY NAME (plus params) instead of closures, so a lane graph is
# data: it can be rebuilt identically on resume, and the same edge
# description works for fold chains, C-adjacent grid warm starts and LOO
# rounds. ``repro.core.study`` finishes the admission by computing
# ``f0 = init_f(K, y, alpha0)``.

TRANSFORMS: dict[str, callable] = {}


def register_transform(name: str):
    """Register a seed transform under ``name`` (see TRANSFORMS above)."""
    def deco(fn):
        TRANSFORMS[name] = fn
        return fn
    return deco


@register_transform("fold")
def fold_transform(K, y, C, prev, *, method, S_idx, R_idx, T_idx):
    """The paper's fold-transition seeders by name: ``method`` picks the
    SEEDERS entry (ato / ato_ref / mir / sir / cold), the index sets
    describe the h-1 -> h transition (module docstring)."""
    return SEEDERS[method](K, y, C, prev, S_idx, R_idx, T_idx)


@register_transform("scale_C")
def scale_C_transform(K, y, C, prev, *, C_old, train_mask):
    """C-adjacent grid warm start: scale the (C_old, gamma) solution of the
    SAME fold to this lane's C (``scale_seed_C``)."""
    return scale_seed_C(prev.alpha, y, C_old, C, train_mask)


#: scale_C never touches K, so the Study API admits it on K-less
#: (row-streaming) sources, deriving f0 from the source's streaming matvec
scale_C_transform.kernel_free = True


@register_transform("loo_avg")
def loo_avg_transform(K, y, C, prev, *, t):
    """LOO round entry (DeCoste & Wagstaff AVG): remove instance ``t`` from
    ``prev``'s solution, spreading its mass over the free set."""
    return avg_seed_loo(K, y, C, prev.alpha, jnp.asarray(t))


@register_transform("loo_top")
def loo_top_transform(K, y, C, prev, *, t):
    """LOO round entry (Lee et al. TOP): spill instance ``t``'s mass by
    descending kernel similarity."""
    return top_seed_loo(K, y, C, prev.alpha, jnp.asarray(t))
