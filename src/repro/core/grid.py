"""(C, gamma) hyper-parameter grid search over alpha-seeded k-fold CV.

The paper warm-starts fold h+1 from fold h. A hyper-parameter grid has two
more warm-start axes, and one big reuse axis, which this driver exploits as
ONE Study plan (``repro.core.study``):

* **kernel reuse** — the RBF kernel matrix depends on gamma only, so every
  C cell (and every fold) of a gamma row shares one kernel; each gamma is
  one *kernel source* of the plan, declared as a compute-on-demand
  ``KernelSpec`` factory and materialized through the pool's LRU cache
  under the ``max_resident``/``cache_bytes`` budget (DESIGN.md
  §Kernel-source cache) — grid memory scales with the budget, not
  ``len(gammas)``;
* **C-adjacent seeding** (``seed_across_C=True``) — fold 0 of cell
  (C_m, gamma) warm-starts from fold 0 of (C_{m-1}, gamma) via the
  ``"scale_C"`` transform (bounded-SV alphas scale ~linearly with C);
* **cross-gamma pooling** (``pool="cross_gamma"``, the default) — every
  (gamma, cell, fold) solve is one lane of a single multi-source
  ``LanePool``: lanes carry their gamma's source key, packing buckets by
  (source, width), and admission is shared across sources. A straggler
  cell no longer bounds its gamma row's wall-clock — cells from OTHER
  gammas fill the schedule while it converges. ``pool="per_gamma"`` keeps
  the PR 3 row-scheduler baseline (one pool per gamma row; the
  ``grid_pooled`` benchmark row compares the two), and per-lane results
  are bit-identical either way — a lane's iterate sequence depends only on
  its own (source, mask, C, state).

The fold chain inside a cell stays sequential — that is the paper's
algorithm — but the grid turns its breadth axes into scheduler lanes:
lane (gi, ci, h) depends on (gi, ci, h-1) through the method's ``"fold"``
transform, so cells advance through their fold chains independently.

Per-lane evaluation is declared as plan ``EvalSpec``s: one jitted vmap per
(gamma, test-size) group computes every lane's held-out correct-count on
device, and a single transfer brings the counts back.

With a checkpoint manager (cross-gamma pool only), the whole grid
checkpoints as one study (plan-keyed ``"study"`` records, lane ids stable
under resume): a killed grid resumes every cell's exact iterate sequence.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.cv import _fold_masks, _transition_idx
from repro.core.study import Plan, StudyCheckpoint, run_plan
from repro.data.svm_suite import SVMDataset, kfold_chunks
from repro.svm import KernelSpec


@dataclasses.dataclass
class GridCell:
    C: float
    gamma: float
    iterations: int
    acc_correct: int
    acc_total: int
    converged: bool

    @property
    def accuracy(self) -> float:
        return self.acc_correct / max(self.acc_total, 1)


@dataclasses.dataclass
class GridReport:
    dataset: str
    method: str
    k: int
    n: int
    kernel_time: float
    seed_time: float
    solve_time: float
    cells: list[GridCell]
    #: LanePool width stats; the cross-gamma pool reports ``per_source``
    #: live widths (one entry per gamma), the per-gamma baseline aggregates
    #: its row pools
    occupancy: dict | None = None
    #: kernel-source cache account (materializations, kernel wall time,
    #: peak resident sources/bytes) summed over the grid's studies — the
    #: memory-ceiling signal the ``grid_pooled_lru`` bench row publishes
    resident: dict | None = None

    @property
    def total_iterations(self) -> int:
        return int(sum(c.iterations for c in self.cells))

    def best(self) -> GridCell:
        return max(self.cells, key=lambda c: c.accuracy)

    def rows(self) -> list[dict]:
        return [{"dataset": self.dataset, "method": self.method,
                 "C": c.C, "gamma": c.gamma, "k": self.k,
                 "iterations": c.iterations,
                 "accuracy": round(c.accuracy, 4),
                 "converged": c.converged} for c in self.cells]


def _merge_occupancy(rows: list[dict]) -> dict | None:
    """Aggregate per-pool occupancy dicts into one report. ``programs`` is
    SUMMED — each pool compiled its own distinct programs, and the stat
    exists to bound total compiled-program count (the old ``max`` silently
    undercounted it). ``per_source`` blocks are merged by source key
    (chunk-weighted mean live width, max peak) instead of being dropped."""
    if not rows:
        return None
    chunks = sum(r["chunks"] for r in rows)
    if chunks == 0:
        return {"chunks": 0, "mean_live_width": 0.0, "peak_width": 0}
    merged = {
        "chunks": chunks,
        "mean_live_width": round(
            sum(r["mean_live_width"] * r["chunks"] for r in rows) / chunks, 3),
        "mean_packed_width": round(
            sum(r["mean_packed_width"] * r["chunks"] for r in rows) / chunks,
            3),
        "peak_width": max(r["peak_width"] for r in rows),
        "programs": sum(r["programs"] for r in rows),
    }
    per_source: dict[str, list] = {}
    for r in rows:
        for key, s in (r.get("per_source") or {}).items():
            rec = per_source.setdefault(key, [0.0, 0, 0])  # [sum, n, peak]
            rec[0] += s["mean_live_width"] * s["chunks"]
            rec[1] += s["chunks"]
            rec[2] = max(rec[2], s["peak_live_width"])
    if per_source:
        merged["per_source"] = {
            key: {"chunks": n,
                  "mean_live_width": round(s / max(n, 1), 3),
                  "peak_live_width": peak}
            for key, (s, n, peak) in per_source.items()}
    return merged


def _row_lanes(plan: Plan, gi: int, Cs, masks, transitions, method: str,
               seed_across_C: bool, max_iter: int, zeros, y, chunks) -> None:
    """Declare one gamma row's lane sub-graph (cells x folds) plus its
    evaluations on ``plan``; lane ids are (gamma index, C index, fold)."""
    k = masks.shape[0]
    for ci, C in enumerate(Cs):
        if method != "cold" and seed_across_C and ci > 0:
            plan.lane((gi, ci, 0), source=gi, train_mask=masks[0], C=C,
                      dep=(gi, ci - 1, 0), transform="scale_C",
                      params=dict(C_old=Cs[ci - 1], train_mask=masks[0]),
                      max_iter=max_iter)
        else:
            plan.lane((gi, ci, 0), source=gi, train_mask=masks[0], C=C,
                      alpha0=zeros, f0=-y, max_iter=max_iter)
        for h in range(1, k):
            if method == "cold":
                plan.lane((gi, ci, h), source=gi, train_mask=masks[h], C=C,
                          alpha0=zeros, f0=-y, max_iter=max_iter)
            else:
                S_idx, R_idx, T_idx = transitions[h]
                plan.lane((gi, ci, h), source=gi, train_mask=masks[h], C=C,
                          dep=(gi, ci, h - 1), transform="fold",
                          params=dict(method=method, S_idx=S_idx,
                                      R_idx=R_idx, T_idx=T_idx),
                          max_iter=max_iter)
        for h in range(k):
            plan.evaluate((gi, ci, h), chunks[h])


def _check_grid_args(pool: str, source_backend: str, method: str) -> None:
    """The grid's own entry contract — checked before any plan is built
    or any kernel spec could resolve, so a typo fails at call time."""
    if pool not in ("cross_gamma", "per_gamma"):
        raise ValueError(f"unknown pool {pool!r}")
    if source_backend not in ("dense", "pallas_rbf"):
        raise ValueError(f"unknown source_backend {source_backend!r} "
                         "(have 'dense', 'pallas_rbf')")
    if source_backend == "pallas_rbf" and method != "cold":
        raise ValueError("source_backend='pallas_rbf' requires "
                         "method='cold': fold-transition seeders "
                         "slab-index a dense kernel matrix")


def grid_plans(ds: SVMDataset, Cs, gammas, k: int = 10,
               method: str = "sir", tol: float = 1e-3,
               max_iter: int = 5_000_000, seed: int = 0,
               seed_across_C: bool = False, chunk_iters: int = 4096,
               kernel_backend: str = "jnp", lane_quantum: int = 4,
               max_width: int | None = None, pool: str = "cross_gamma",
               max_resident: int = 0, cache_bytes: int = 0,
               source_backend: str = "dense", shrink_every: int | str = 0,
               shrink_quantum: int = 128, shrink_caps=None,
               shrink_on_seed: bool = True) -> list:
    """The exact ``Plan``(s) ``run_grid`` executes for these arguments —
    one multi-source plan for ``pool="cross_gamma"``, one single-source
    plan per gamma for ``pool="per_gamma"`` — built but not run. This is
    the static-analysis entry point: feed them to
    ``repro.analysis.plan_check.analyze_plan`` to enumerate compile
    shapes or budget feasibility without solving anything."""
    _check_grid_args(pool, source_backend, method)
    Cs = sorted(float(c) for c in Cs)
    gammas = [float(g) for g in gammas]
    y_all = jnp.asarray(ds.y, jnp.float64)
    X = jnp.asarray(ds.X)
    chunks = kfold_chunks(ds.n, k, seed=seed)
    n = chunks.size
    y = y_all[:n]
    masks = jnp.asarray(_fold_masks(chunks))
    transitions = {} if method == "cold" else \
        {h: _transition_idx(chunks, h - 1, h) for h in range(1, k)}
    # one DECLARED kernel per gamma — nothing is computed here. The spec
    # slices X to the k-fold truncation BEFORE the kernel call; core/cv.py
    # builds its kernel the same way, which keeps grid cells bit-identical
    # to run_cv (the two slice orders differ in final bits at some shapes)
    sources = {gi: KernelSpec(X=X, gamma=gamma, kind="rbf",
                              backend=kernel_backend, n=n)
               for gi, gamma in enumerate(gammas)}
    # cold-start alphas in the KERNEL dtype (KernelSpec answers it without
    # materializing), matching run_cv's jnp.zeros(n, K.dtype)
    zeros = jnp.zeros(n, sources[0].dtype if sources else jnp.float64)

    def make_plan(keys) -> Plan:
        plan = Plan(sources={gi: sources[gi] for gi in keys}, y=y, tol=tol,
                    wss="1" if source_backend == "pallas_rbf" else "2",
                    chunk_iters=chunk_iters, lane_quantum=lane_quantum,
                    max_width=max_width, max_resident=max_resident,
                    cache_bytes=cache_bytes, source_backend=source_backend,
                    shrink_every=shrink_every, shrink_quantum=shrink_quantum,
                    shrink_caps=shrink_caps, shrink_on_seed=shrink_on_seed)
        for gi in keys:
            _row_lanes(plan, gi, Cs, masks, transitions, method,
                       seed_across_C, max_iter, zeros, y, chunks)
        return plan

    if pool == "cross_gamma":
        return [make_plan(range(len(gammas)))]
    return [make_plan([gi]) for gi in range(len(gammas))]


def run_grid(ds: SVMDataset, Cs, gammas, k: int = 10, method: str = "sir",
             tol: float = 1e-3, max_iter: int = 5_000_000, seed: int = 0,
             seed_across_C: bool = False, chunk_iters: int = 4096,
             kernel_backend: str = "jnp", lane_quantum: int = 4,
             max_width: int | None = None, pool: str = "cross_gamma",
             max_resident: int = 0, cache_bytes: int = 0,
             source_backend: str = "dense",
             checkpoint_manager=None,
             checkpoint_every: int = 1, shrink_every: int | str = 0,
             shrink_quantum: int = 128, shrink_caps=None,
             shrink_on_seed: bool = True) -> GridReport:
    """Cross-validate every (C, gamma) cell; returns per-cell accuracy and
    iteration counts (``GridReport.best()`` picks the winner).

    ``method`` is the fold-chain seeder inside each cell ("cold" disables
    chaining; every lane is then independent). ``seed_across_C``
    additionally chains fold 0 along ascending C within a gamma row —
    trades fold-0 concurrency for warm starts, which wins when C values
    are dense (adjacent cells share most of their support vectors).

    ``pool`` picks the schedule: ``"cross_gamma"`` (default) runs the whole
    grid as ONE multi-source lane pool — no per-row barrier, one study
    checkpoint; ``"per_gamma"`` runs one pool per gamma row (the historical
    schedule, kept as the benchmark baseline). Per-cell results match
    ``run_cv`` on the same hyper-parameters under either pool (same
    seeders, same engine, bit-identical solves).

    Kernels are declared as factories (one ``KernelSpec`` per gamma) and
    materialize on demand through the pool's source cache.
    ``max_resident`` / ``cache_bytes`` (0 = unbounded) bound how many
    kernel matrices stay resident at once: under a budget, the scheduler
    drains each resident gamma's lanes before paying for the next kernel,
    evicting by schedule distance — memory scales with the budget instead
    of ``len(gammas) * n^2 * 8`` bytes, and per-cell results stay
    bit-identical under every budget (re-materialization is a pure
    function of (X, gamma)). ``kernel_time`` counts every materialization,
    including re-materializations after eviction or a mid-study resume;
    ``GridReport.resident`` carries the cache account.

    ``source_backend="pallas_rbf"`` resolves every gamma's spec to a
    row-streaming ``PallasRBF`` source instead of a dense matrix: no lane
    ever touches an n² kernel (peak resident bytes track X, not n²), WSS-1
    selection is forced, and evaluations run off row slabs. Requires
    ``method="cold"`` — the fold-transition seeders slab-index a dense K.

    ``shrink_every`` (iterations per heuristic evaluation, or ``"auto"``
    for the cost-model verdict) turns on bucketed active-set shrinking in
    every cell's solve (DESIGN.md §Shrinking): bound-locked variables are
    compacted out and the chunk programs run at bucketed capacities. The
    full-set optimality contract is preserved — per-cell accuracies and
    SV sets match the unshrunk grid; 0 (default) keeps every iterate
    bit-identical to today.
    """
    _check_grid_args(pool, source_backend, method)
    if checkpoint_manager is not None and pool != "cross_gamma":
        raise ValueError("grid checkpointing is plan-keyed and needs the "
                         "cross-gamma pool (one study = one record stream)")
    Cs = sorted(float(c) for c in Cs)
    gammas = [float(g) for g in gammas]
    m = len(Cs)
    chunks = kfold_chunks(ds.n, k, seed=seed)
    n = chunks.size

    # one builder for the declared plans — grid_plans is also the static
    # analyzer's entry point, so what plan_check enumerates is exactly
    # what executes here
    plans = grid_plans(ds, Cs, gammas, k=k, method=method, tol=tol,
                       max_iter=max_iter, seed=seed,
                       seed_across_C=seed_across_C, chunk_iters=chunk_iters,
                       kernel_backend=kernel_backend,
                       lane_quantum=lane_quantum, max_width=max_width,
                       pool=pool, max_resident=max_resident,
                       cache_bytes=cache_bytes,
                       source_backend=source_backend,
                       shrink_every=shrink_every,
                       shrink_quantum=shrink_quantum,
                       shrink_caps=shrink_caps,
                       shrink_on_seed=shrink_on_seed)

    if pool == "cross_gamma":
        checkpoint = None
        if checkpoint_manager is not None:
            checkpoint = StudyCheckpoint(
                manager=checkpoint_manager, every=checkpoint_every,
                meta={"bench": "grid", "dataset": ds.name, "method": method,
                      "k": k, "seed": seed, "tol": tol, "max_iter": max_iter,
                      "Cs": Cs, "gammas": gammas,
                      "seed_across_C": seed_across_C,
                      "shrink_every": shrink_every})
        study_results = [run_plan(plans[0], checkpoint=checkpoint)]
        occupancy = study_results[0].occupancy
    else:
        study_results = [run_plan(p) for p in plans]
        occupancy = _merge_occupancy([s.occupancy for s in study_results])

    seed_time = sum(s.seed_time for s in study_results)
    solve_time = sum(s.solve_time for s in study_results)
    # kernel_time is attributed per MATERIALIZATION: each gamma's first
    # use, plus any re-materialization after eviction or a cold-cache
    # resume — the honest cost of the compute-on-demand schedule
    kernel_time = sum(s.source_stats.get("kernel_time", 0.0)
                      for s in study_results)
    resident = {
        "materializations": sum(s.source_stats.get("materializations", 0)
                                for s in study_results),
        "evictions": sum(s.source_stats.get("evictions", 0)
                         for s in study_results),
        "peak_resident": max(s.source_stats.get("peak_resident", 0)
                             for s in study_results),
        "peak_resident_bytes": max(
            s.source_stats.get("peak_resident_bytes", 0)
            for s in study_results),
    }
    stats = {lid: st for s in study_results for lid, st in s.stats.items()}
    evals = {lid: ev for s in study_results for lid, ev in s.evals.items()}

    t_sz = chunks.shape[1]
    cells: list[GridCell] = []
    for gi, gamma in enumerate(gammas):
        for ci in range(m):
            lids = [(gi, ci, h) for h in range(k)]
            cells.append(GridCell(
                C=Cs[ci], gamma=gamma,
                iterations=int(sum(stats[lid].n_iter for lid in lids)),
                acc_correct=int(sum(evals[lid][0] for lid in lids)),
                acc_total=int(t_sz * k),
                converged=all(stats[lid].converged for lid in lids)))

    return GridReport(dataset=ds.name, method=method, k=k, n=n,
                      kernel_time=kernel_time, seed_time=seed_time,
                      solve_time=solve_time, cells=cells,
                      occupancy=occupancy, resident=resident)
