"""(C, gamma) hyper-parameter grid search over alpha-seeded k-fold CV.

The paper warm-starts fold h+1 from fold h. A hyper-parameter grid has two
more warm-start axes, and one big reuse axis, which this driver exploits on
top of the unified engine:

* **kernel reuse** — the RBF kernel matrix depends on gamma only, so every
  C cell (and every fold) of a gamma row shares one ``kernel_matrix`` call;
* **C-adjacent seeding** (``seed_across_C=True``) — fold 0 of cell
  (C_m, gamma) warm-starts from fold 0 of (C_{m-1}, gamma) via
  ``seeding.scale_seed_C`` (bounded-SV alphas scale ~linearly with C);
* **lane-scheduled concurrency** — every (cell, fold) solve is one lane in
  a ``LaneScheduler`` (DESIGN.md §Lane scheduler). Fold-chain edges are
  lane *dependencies* carrying the seed transform (SIR/MIR via ``SEEDERS``,
  ATO via the jittable ramp, ``scale_seed_C`` along the C axis), so the
  row no longer barriers at each fold: cell A proceeds to fold h+1 the
  moment its own fold h retires, while cell B still iterates on fold h.
  Converged lanes retire between chunks and the live batch is repacked,
  so device work tracks the sum of per-lane iterations. For
  ``method="cold"`` every lane is independent (k * n_C cold lanes).

The fold chain inside a cell stays sequential — that is the paper's
algorithm — but the grid turns its breadth axes into scheduler lanes.

Per-row evaluation is vectorized: one jitted vmap computes every lane's
held-out correct-count (bias + predict) on device, and a single transfer
brings back (correct, n_iter, converged) for the whole row — the old
per-(cell, fold) ``int(...)`` round trips are gone.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import seeding
from repro.core.cv import _fold_masks, _transition_idx
from repro.data.svm_suite import SVMDataset, kfold_chunks
from repro.svm import (DenseKernel, LaneScheduler, bias_from_solution,
                       init_f, kernel_matrix, predict)


@dataclasses.dataclass
class GridCell:
    C: float
    gamma: float
    iterations: int
    acc_correct: int
    acc_total: int
    converged: bool

    @property
    def accuracy(self) -> float:
        return self.acc_correct / max(self.acc_total, 1)


@dataclasses.dataclass
class GridReport:
    dataset: str
    method: str
    k: int
    n: int
    kernel_time: float
    seed_time: float
    solve_time: float
    cells: list[GridCell]
    #: aggregated LaneScheduler width stats across gamma rows
    occupancy: dict | None = None

    @property
    def total_iterations(self) -> int:
        return int(sum(c.iterations for c in self.cells))

    def best(self) -> GridCell:
        return max(self.cells, key=lambda c: c.accuracy)

    def rows(self) -> list[dict]:
        return [{"dataset": self.dataset, "method": self.method,
                 "C": c.C, "gamma": c.gamma, "k": self.k,
                 "iterations": c.iterations,
                 "accuracy": round(c.accuracy, 4),
                 "converged": c.converged} for c in self.cells]


@jax.jit
def _eval_lanes_jit(K, y, test_idx, train_masks, Cs, res):
    """Held-out correct-count for a batch of lanes — the same
    bias_from_solution + predict pipeline as the sequential CV path,
    vmapped so the whole gamma row is ONE device program."""
    def one(ti, mask, C, r):
        b = bias_from_solution(r, y, mask, C)
        pred = predict(K[ti], y, r.alpha, b)
        return jnp.sum(pred == y[ti])

    return jax.vmap(one)(test_idx, train_masks, Cs, res)


def _merge_occupancy(rows: list[dict]) -> dict | None:
    if not rows:
        return None
    chunks = sum(r["chunks"] for r in rows)
    if chunks == 0:
        return {"chunks": 0, "mean_live_width": 0.0, "peak_width": 0}
    return {
        "chunks": chunks,
        "mean_live_width": round(
            sum(r["mean_live_width"] * r["chunks"] for r in rows) / chunks, 3),
        "mean_packed_width": round(
            sum(r["mean_packed_width"] * r["chunks"] for r in rows) / chunks,
            3),
        "peak_width": max(r["peak_width"] for r in rows),
        "programs": max(r["programs"] for r in rows),
    }


def run_grid(ds: SVMDataset, Cs, gammas, k: int = 10, method: str = "sir",
             tol: float = 1e-3, max_iter: int = 5_000_000, seed: int = 0,
             seed_across_C: bool = False, chunk_iters: int = 4096,
             kernel_backend: str = "jnp", lane_quantum: int = 4,
             max_width: int | None = None) -> GridReport:
    """Cross-validate every (C, gamma) cell; returns per-cell accuracy and
    iteration counts (``GridReport.best()`` picks the winner).

    ``method`` is the fold-chain seeder inside each cell ("cold" disables
    chaining; every lane is then independent). ``seed_across_C``
    additionally chains fold 0 along ascending C within a gamma row —
    trades fold-0 concurrency for warm starts, which wins when C values
    are dense (adjacent cells share most of their support vectors).

    Each gamma row is one LaneScheduler run: lane (ci, h) depends on
    (ci, h-1) through the method's seed transform, so cells advance
    through their fold chains independently — no per-fold row barrier —
    and per-cell results match ``run_cv`` on the same hyper-parameters
    (same seeders, same engine, bit-identical solves).
    """
    Cs = sorted(float(c) for c in Cs)
    gammas = [float(g) for g in gammas]
    m = len(Cs)
    y_all = jnp.asarray(ds.y, jnp.float64)
    X = jnp.asarray(ds.X)

    chunks = kfold_chunks(ds.n, k, seed=seed)
    n = chunks.size
    y = y_all[:n]
    masks = jnp.asarray(_fold_masks(chunks))          # (k, n)
    transitions = {} if method == "cold" else \
        {h: _transition_idx(chunks, h - 1, h) for h in range(1, k)}

    kernel_time = seed_time = solve_time = 0.0
    cells: list[GridCell] = []
    occupancies: list[dict] = []

    for gamma in gammas:
        t0 = time.perf_counter()
        K = kernel_matrix(X, X, kind="rbf", gamma=gamma,
                          backend=kernel_backend)[:n][:, :n]
        K.block_until_ready()
        kernel_time += time.perf_counter() - t0

        sched = LaneScheduler(DenseKernel(K), y, tol=tol,
                              chunk_iters=chunk_iters,
                              lane_quantum=lane_quantum,
                              max_width=max_width)
        zeros = jnp.zeros(n, K.dtype)
        seeder = seeding.SEEDERS[method]
        for ci, C in enumerate(Cs):
            if method != "cold" and seed_across_C and ci > 0:
                def c_seed(prev, C_old=Cs[ci - 1], C_new=C):
                    a0 = seeding.scale_seed_C(prev.alpha, y, C_old, C_new,
                                              masks[0])
                    return a0, init_f(K, y, a0)
                sched.add((ci, 0), masks[0], C, dep=(ci - 1, 0),
                          seed_fn=c_seed, max_iter=max_iter)
            else:
                sched.add((ci, 0), masks[0], C, zeros, -y, max_iter=max_iter)
            for h in range(1, k):
                if method == "cold":
                    sched.add((ci, h), masks[h], C, zeros, -y,
                              max_iter=max_iter)
                    continue
                S_idx, R_idx, T_idx = transitions[h]

                def fold_seed(prev, C=C, S=S_idx, R=R_idx, T=T_idx):
                    a0 = seeder(K, y, C, prev, S, R, T)
                    return a0, init_f(K, y, a0)
                sched.add((ci, h), masks[h], C, dep=(ci, h - 1),
                          seed_fn=fold_seed, max_iter=max_iter)

        t0 = time.perf_counter()
        results = sched.run()
        jax.block_until_ready([r.alpha for r in results.values()])
        row_time = time.perf_counter() - t0
        seed_time += sched.seed_time
        solve_time += row_time - sched.seed_time
        occupancies.append(sched.occupancy)

        # ---- one batched on-device evaluation + a single transfer ----
        lane_ids = [(ci, h) for ci in range(m) for h in range(k)]
        res_row = jax.tree.map(lambda *xs: jnp.stack(xs),
                               *[results[lid] for lid in lane_ids])
        hs = np.asarray([h for _, h in lane_ids])
        test_idx = jnp.asarray(chunks[hs])            # (m*k, n//k)
        row_masks = masks[jnp.asarray(hs)]
        row_Cs = jnp.asarray([Cs[ci] for ci, _ in lane_ids], jnp.float64)
        correct_dev = _eval_lanes_jit(K, y, test_idx, row_masks, row_Cs,
                                      res_row)
        correct, iters, conv = jax.device_get(
            (correct_dev, res_row.n_iter, res_row.converged))

        t_sz = chunks.shape[1]
        for ci in range(m):
            sel = slice(ci * k, (ci + 1) * k)
            cells.append(GridCell(
                C=Cs[ci], gamma=gamma,
                iterations=int(iters[sel].sum()),
                acc_correct=int(correct[sel].sum()),
                acc_total=int(t_sz * k),
                converged=bool(conv[sel].all())))

    return GridReport(dataset=ds.name, method=method, k=k, n=n,
                      kernel_time=kernel_time, seed_time=seed_time,
                      solve_time=solve_time, cells=cells,
                      occupancy=_merge_occupancy(occupancies))
