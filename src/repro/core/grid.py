"""(C, gamma) hyper-parameter grid search over alpha-seeded k-fold CV.

The paper warm-starts fold h+1 from fold h. A hyper-parameter grid has two
more warm-start axes, and one big reuse axis, which this driver exploits on
top of the unified engine:

* **kernel reuse** — the RBF kernel matrix depends on gamma only, so every
  C cell (and every fold) of a gamma row shares one ``kernel_matrix`` call;
* **C-adjacent seeding** (``seed_across_C=True``) — fold 0 of cell
  (C_m, gamma) warm-starts from fold 0 of (C_{m-1}, gamma) via
  ``seeding.scale_seed_C`` (bounded-SV alphas scale ~linearly with C);
* **batched concurrency** — solves with no seed dependency run as ONE
  batched engine call instead of a python loop: fold 0 of every cell in a
  gamma row (when not C-chaining), every fold h>0 across cells (each cell
  seeds from its own fold h-1, so cells are mutually independent), and the
  entire row for ``method="cold"`` (k * n_C independent lanes). For
  ``method="ato"`` the seeding itself is batched too: the jittable ATO
  (``seeding.ato_seed_batch``) vmaps one fixed-shape ramp over the whole C
  row, so a transition costs one device program instead of n_C host loops.

The fold chain inside a cell stays sequential — that is the paper's
algorithm — but the grid turns its breadth axes into vmap lanes.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import seeding
from repro.core.cv import _fold_masks, _transition_idx
from repro.data.svm_suite import SVMDataset, kfold_chunks
from repro.svm import (bias_from_solution, init_f, kernel_matrix, predict,
                       smo_solve_batched)


@dataclasses.dataclass
class GridCell:
    C: float
    gamma: float
    iterations: int
    acc_correct: int
    acc_total: int
    converged: bool

    @property
    def accuracy(self) -> float:
        return self.acc_correct / max(self.acc_total, 1)


@dataclasses.dataclass
class GridReport:
    dataset: str
    method: str
    k: int
    n: int
    kernel_time: float
    seed_time: float
    solve_time: float
    cells: list[GridCell]

    @property
    def total_iterations(self) -> int:
        return int(sum(c.iterations for c in self.cells))

    def best(self) -> GridCell:
        return max(self.cells, key=lambda c: c.accuracy)

    def rows(self) -> list[dict]:
        return [{"dataset": self.dataset, "method": self.method,
                 "C": c.C, "gamma": c.gamma, "k": self.k,
                 "iterations": c.iterations,
                 "accuracy": round(c.accuracy, 4),
                 "converged": c.converged} for c in self.cells]


def _lane(tree, idx):
    return jax.tree.map(lambda a: a[idx], tree)


def run_grid(ds: SVMDataset, Cs, gammas, k: int = 10, method: str = "sir",
             tol: float = 1e-3, max_iter: int = 5_000_000, seed: int = 0,
             seed_across_C: bool = False, chunk_iters: int = 4096,
             kernel_backend: str = "jnp") -> GridReport:
    """Cross-validate every (C, gamma) cell; returns per-cell accuracy and
    iteration counts (``GridReport.best()`` picks the winner).

    ``method`` is the fold-chain seeder inside each cell ("cold" disables
    chaining and batches the whole gamma row at once). ``seed_across_C``
    additionally chains fold 0 along ascending C within a gamma row —
    trades fold-0 concurrency for warm starts, which wins when C values
    are dense (adjacent cells share most of their support vectors).
    """
    Cs = sorted(float(c) for c in Cs)
    gammas = [float(g) for g in gammas]
    m = len(Cs)
    y_all = jnp.asarray(ds.y, jnp.float64)
    X = jnp.asarray(ds.X)

    chunks = kfold_chunks(ds.n, k, seed=seed)
    n = chunks.size
    y = y_all[:n]
    masks = jnp.asarray(_fold_masks(chunks))          # (k, n)
    C_vec = jnp.asarray(Cs, jnp.float64)              # (m,)

    kernel_time = seed_time = solve_time = 0.0
    cells: list[GridCell] = []

    for gamma in gammas:
        t0 = time.perf_counter()
        K = kernel_matrix(X, X, kind="rbf", gamma=gamma,
                          backend=kernel_backend)[:n][:, :n]
        K.block_until_ready()
        kernel_time += time.perf_counter() - t0

        iters = np.zeros(m, np.int64)
        correct = np.zeros(m, np.int64)
        total = np.zeros(m, np.int64)
        conv = np.ones(m, bool)

        def eval_fold(res_lane, h, ci, C):
            test_idx = jnp.asarray(chunks[h])
            b = bias_from_solution(res_lane, y, masks[h], C)
            pred = predict(K[test_idx], y, res_lane.alpha, b)
            correct[ci] += int(jnp.sum(pred == y[test_idx]))
            total[ci] += int(test_idx.shape[0])
            iters[ci] += int(res_lane.n_iter)
            conv[ci] &= bool(res_lane.converged)

        if method == "cold":
            # every (cell, fold) is independent: one batch of m*k lanes
            t0 = time.perf_counter()
            bmasks = jnp.tile(masks, (m, 1))                      # (m*k, n)
            bC = jnp.repeat(C_vec, k)
            res = smo_solve_batched(K, y, bmasks, bC,
                                    jnp.zeros((m * k, n), K.dtype),
                                    jnp.tile(-y, (m * k, 1)), tol=tol,
                                    max_iter=max_iter,
                                    chunk_iters=chunk_iters)
            jax.block_until_ready(res)
            solve_time += time.perf_counter() - t0
            for ci in range(m):
                for h in range(k):
                    eval_fold(_lane(res, ci * k + h), h, ci, Cs[ci])
        else:
            seeder = seeding.SEEDERS[method]
            # ---- fold 0 across the C row ----
            if seed_across_C and m > 1:
                # chain along ascending C (scale_seed_C), sequential
                lanes = []
                prev_alpha = None
                for ci, C in enumerate(Cs):
                    t0 = time.perf_counter()
                    if prev_alpha is None:
                        alpha0 = jnp.zeros(n, K.dtype)
                        f0 = -y
                    else:
                        alpha0 = seeding.scale_seed_C(
                            prev_alpha, y, Cs[ci - 1], C, masks[0])
                        f0 = init_f(K, y, alpha0)
                    jax.block_until_ready((alpha0, f0))
                    seed_time += time.perf_counter() - t0
                    t0 = time.perf_counter()
                    r = smo_solve_batched(K, y, masks[0][None], C,
                                          alpha0[None], f0[None], tol=tol,
                                          max_iter=max_iter,
                                          chunk_iters=chunk_iters)
                    jax.block_until_ready(r)
                    solve_time += time.perf_counter() - t0
                    lanes.append(r)
                    prev_alpha = r.alpha[0]
                prev = jax.tree.map(
                    lambda *xs: jnp.concatenate(xs, 0), *lanes)
            else:
                # fold 0 of every cell is cold/independent: one batch
                t0 = time.perf_counter()
                prev = smo_solve_batched(K, y,
                                         jnp.tile(masks[0][None], (m, 1)),
                                         C_vec, jnp.zeros((m, n), K.dtype),
                                         jnp.tile(-y, (m, 1)), tol=tol,
                                         max_iter=max_iter,
                                         chunk_iters=chunk_iters)
                jax.block_until_ready(prev)
                solve_time += time.perf_counter() - t0
            for ci in range(m):
                eval_fold(_lane(prev, ci), 0, ci, Cs[ci])

            # ---- folds 1..k-1: cells are independent given their own
            # fold h-1 result -> seed per cell, solve the row as a batch ----
            for h in range(1, k):
                S_idx, R_idx, T_idx = _transition_idx(chunks, h - 1, h)
                t0 = time.perf_counter()
                if method == "ato":
                    # the jittable ATO vmaps over the C row: one device
                    # program ramps every cell's transition concurrently
                    # (pad sized for the widest lane; see seeding.py)
                    alpha0s = seeding.ato_seed_batch(K, y, C_vec, prev,
                                                     S_idx, R_idx, T_idx)
                else:
                    alpha0s = jnp.stack([
                        seeder(K, y, Cs[ci], _lane(prev, ci),
                               S_idx, R_idx, T_idx)
                        for ci in range(m)])
                # per-cell init_f (not one batched GEMM): same reduction
                # order as run_cv, so grid cells match it bit-exactly
                f0s = jnp.stack([init_f(K, y, alpha0s[ci]) for ci in range(m)])
                jax.block_until_ready((alpha0s, f0s))
                seed_time += time.perf_counter() - t0
                t0 = time.perf_counter()
                prev = smo_solve_batched(K, y,
                                         jnp.tile(masks[h][None], (m, 1)),
                                         C_vec, alpha0s, f0s, tol=tol,
                                         max_iter=max_iter,
                                         chunk_iters=chunk_iters)
                jax.block_until_ready(prev)
                solve_time += time.perf_counter() - t0
                for ci in range(m):
                    eval_fold(_lane(prev, ci), h, ci, Cs[ci])

        for ci in range(m):
            cells.append(GridCell(C=Cs[ci], gamma=gamma,
                                  iterations=int(iters[ci]),
                                  acc_correct=int(correct[ci]),
                                  acc_total=int(total[ci]),
                                  converged=bool(conv[ci])))

    return GridReport(dataset=ds.name, method=method, k=k, n=n,
                      kernel_time=kernel_time, seed_time=seed_time,
                      solve_time=solve_time, cells=cells)
