"""Study API: one declarative lane-graph entry point over a multi-source
scheduler pool.

The paper's speedups come from chaining solves through seed transforms
(alpha seeding across folds); Joulani et al. frame incremental-learning CV
as one dependency structure over reusable partial solutions. This module
says that structure ONCE, declaratively: a ``Plan`` is a graph of
``LaneSpec``s (train mask, C, kernel-source key, seed dependency +
transform name) plus ``EvalSpec``s, and ``run_plan`` executes it on a
multi-source ``LanePool`` (DESIGN.md §Study API). ``run_cv``,
``run_cv_batched``, ``run_loo`` and ``run_grid`` are thin plan builders
over this entry point — bit-identical to their pre-redesign outputs under
every schedule.

Plan grammar (each lane is exactly one of):

* **start lane** — ``alpha0``/``f0`` (+ optional ``n_iter0`` when resuming
  a snapshot): dispatched immediately, or held by an ``after`` ordering
  edge (sequential protocols, e.g. the paper's fold chain, express their
  order without faking a seed dependency);
* **dependent lane** — ``dep`` (another lane id) + ``transform`` (a name
  in ``seeding.TRANSFORMS``) + ``params``: admitted the moment the
  dependency retires, started at ``transform(K, y, C, dep_result,
  **params)``. Dependencies may cross kernel sources;
* **given lane** — ``result``: an already-solved ``SMOResult`` (a restored
  fold) that participates as a seed dependency but never dispatches.

Because transforms are referenced by NAME + params instead of closures,
the lane graph is data: the caller rebuilds the identical plan on resume,
and the checkpoint only has to persist per-lane (alpha, f, n_iter, done)
keyed by lane id (``StudyCheckpoint``; records default to
``retain_class="study"``, lane ids are stable under resume, and a snapshot
written under one schedule shape restores under any other).

``EvalSpec``s declare held-out evaluations; ``run_plan`` batches them into
one jitted program per (source, test-size) group — a whole study's
evaluation is a handful of device calls.

Plan sources may be **factories** (``svm/sources.py:KernelSpec``) instead
of dense matrices: the pool materializes them on demand under the plan's
``max_resident``/``cache_bytes`` budget (schedule-distance eviction —
DESIGN.md §Kernel-source cache). Seed transforms and eval groups resolve
their K through the same cache, so a study's memory scales with the
budget, not the source count. The whole lane graph (edge targets,
transform names, source keys, dep/after acyclicity) is validated at
``run_plan`` entry — a typo'd edge fails by name immediately instead of
surfacing as a drain-time RuntimeError hours into a large study.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import seeding
from repro.svm import shrink as shrink_mod
from repro.svm.engine import EngineState, finalize
from repro.svm.scheduler import LanePool
from repro.svm.sources import KernelSpec, is_factory
from repro.svm.smo import init_f
from repro.svm.svc import bias_from_solution, predict

#: study records live above every run_cv fold step (< _FOLD_STRIDE * k)
#: and every run_cv_batched batch step (_FOLD_STRIDE**2 + chunks), so all
#: three record kinds can share one checkpoint directory without step
#: collisions (``save`` replaces an existing step dir).
STUDY_BASE = 2 * 1_000_000 ** 2


@dataclasses.dataclass
class LaneSpec:
    """One node of the lane graph. See the module docstring for which
    field combinations are legal; ``source`` may be omitted in a
    single-source plan."""
    id: Any
    source: Any = None
    train_mask: Any = None
    C: float | None = None
    alpha0: Any = None
    f0: Any = None
    n_iter0: int = 0
    max_iter: int = 10_000_000
    dep: Any = None
    transform: str | None = None
    params: dict = dataclasses.field(default_factory=dict)
    after: Any = None
    result: Any = None


@dataclasses.dataclass
class EvalSpec:
    """Held-out evaluation of one lane: correct-count of ``predict`` over
    ``test_idx`` rows of the lane's kernel source."""
    lane: Any
    test_idx: Any


@dataclasses.dataclass
class Plan:
    """A declarative study: kernel sources, the lane graph, evaluations,
    and the schedule knobs forwarded to the ``LanePool``."""
    sources: dict
    y: Any                                # shared labels, or {source_key: y}
    lanes: list = dataclasses.field(default_factory=list)
    evals: list = dataclasses.field(default_factory=list)
    tol: float = 1e-3
    wss: str = "2"
    chunk_iters: int = 4096
    lane_quantum: int = 4
    max_width: int | None = None
    #: kernel-source residency budget (0 = unbounded): sources declared as
    #: factories (svm/sources.py:KernelSpec) materialize on demand and at
    #: most ``max_resident`` kernels / ``cache_bytes`` bytes stay resident
    #: (schedule-distance eviction — DESIGN.md §Kernel-source cache)
    max_resident: int = 0
    cache_bytes: int = 0
    #: kernel-source backend for the plan's declared ``KernelSpec``s:
    #: ``"dense"`` leaves them as declared; ``"pallas_rbf"`` rewrites every
    #: dense-RBF spec to the row-streaming kind (``svm/engine.py:PallasRBF``
    #: — nbytes = X bytes, fused, requires ``wss="1"``), so one knob flips
    #: a whole plan between n²-resident and row-streaming execution
    source_backend: str = "dense"
    #: active-set shrinking (``svm/shrink.py``): 0 = off (bit-identical to
    #: the pre-shrinking pool), an int = heuristic period in iterations,
    #: ``"auto"`` = backend-gated by the measured cost model
    #: (``cost_model.pick_shrink``). ``shrink_quantum`` buckets compact
    #: capacities (``shrink_caps`` declares an explicit ladder instead —
    #: what exact-program-count CI cells use); ``shrink_on_seed`` applies
    #: the seeding->shrinking handoff at admission
    shrink_every: int | str = 0
    shrink_quantum: int = 128
    shrink_caps: Any = None
    shrink_on_seed: bool = True
    #: support-vector-only evaluation: gather ``alpha > 0`` rows (the
    #: fixed-shape nonzero idiom at a ``shrink.bucket_cap`` capacity)
    #: before the eval matvec instead of multiplying through zero rows;
    #: dense-K groups only, falls back to the full path otherwise
    sv_eval: bool = False

    def lane(self, id, **kwargs) -> LaneSpec:
        spec = LaneSpec(id=id, **kwargs)
        self.lanes.append(spec)
        return spec

    def evaluate(self, lane, test_idx) -> None:
        self.evals.append(EvalSpec(lane, test_idx))

    def source_key_of(self, spec: LaneSpec) -> Any:
        if spec.source is not None:
            return spec.source
        if len(self.sources) == 1:
            return next(iter(self.sources))
        raise ValueError(f"lane {spec.id!r} needs a source key in a "
                         "multi-source plan")

    def y_of(self, key) -> jnp.ndarray:
        return self.y[key] if isinstance(self.y, dict) else self.y


@dataclasses.dataclass
class StudyCheckpoint:
    """Checkpoint wiring for ``run_plan``: every ``every``-th chunk, all
    admitted lanes' (alpha, f, n_iter, done) are saved stacked in lane-id
    order under ``retain_class`` at steps counting up from ``base_step``.
    ``meta`` is the plan identity — verified on resume, so a snapshot from
    a different study (or different solver parameters, which are part of
    the run identity: retired lanes carry fixed points at the snapshot's
    tolerance/budget) is rejected instead of silently mixed in."""
    manager: Any
    every: int = 1
    retain_class: str = "study"
    phase: str = "study_mid"
    base_step: int = STUDY_BASE
    meta: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class LaneStat:
    """Per-lane execution account: iterations, convergence, the admission
    transform's wall time (the paper's "init."), the lane's share of its
    dispatch chunks, and whether it was restored pre-solved."""
    n_iter: int
    converged: bool
    seed_s: float
    solve_s: float
    restored: bool = False


@dataclasses.dataclass
class StudyResult:
    results: dict                         # lane id -> SMOResult
    stats: dict                           # lane id -> LaneStat
    evals: dict                           # lane id -> (correct, total)
    occupancy: dict
    seed_time: float
    solve_time: float                     # pool wall time minus seed_time
    restored: frozenset                   # lanes already done at pool start
    #: kernel-source cache account: materialization count/wall-time and
    #: peak residency (sources, bytes) — all zeros for all-dense plans
    source_stats: dict = dataclasses.field(default_factory=dict)
    #: pre-execution static analysis (``repro.analysis.plan_check``):
    #: compile-shape enumeration, budget feasibility, advisory findings;
    #: None when ``run_plan(..., analysis="off")``
    analysis: Any = None


@jax.jit
def _eval_lanes_jit(K, y, test_idx, train_masks, Cs, res):
    """Held-out correct-count for a batch of lanes — the same
    bias_from_solution + predict pipeline as the sequential CV path,
    vmapped so a whole eval group is ONE device program."""
    def one(ti, mask, C, r):
        b = bias_from_solution(r, y, mask, C)
        pred = predict(K[ti], y, r.alpha, b)
        return jnp.sum(pred == y[ti])

    return jax.vmap(one)(test_idx, train_masks, Cs, res)


@functools.partial(jax.jit, static_argnames=("cap",))
def _eval_lanes_sv_jit(K, y, test_idx, train_masks, Cs, res, cap):
    """Support-vector-only variant of ``_eval_lanes_jit``: each lane
    gathers its ``alpha > 0`` rows (the same fixed-shape
    ``nonzero(size=cap, fill_value=n)`` compact-gather idiom the
    shrinking scheduler uses — pad columns clamp to the last row and are
    zero-weighted) and the decision matvec contracts over ``cap`` support
    vectors instead of all n training rows. Same ``>= 0`` prediction
    convention as ``svc.predict``; summation order over the support set
    differs from the full matvec, so this path carries the usual allclose
    guarantee, not bit parity — which is why it is opt-in
    (``Plan.sv_eval``)."""
    def one(ti, mask, C, r):
        b = bias_from_solution(r, y, mask, C)
        sv = r.alpha > 0
        svi = jnp.nonzero(sv, size=cap, fill_value=y.shape[0])[0]
        coef = jnp.where(jnp.arange(cap) < jnp.sum(sv),
                         r.alpha[svi] * y[svi], 0.0)
        dec = K[ti][:, svi] @ coef + b
        pred = jnp.where(dec >= 0, 1, -1)
        return jnp.sum(pred == y[ti])

    return jax.vmap(one)(test_idx, train_masks, Cs, res)


@jax.jit
def _eval_lanes_rows_jit(K_rows, y, test_idx, train_masks, Cs, res):
    """Row-slab variant for K-less (row-streaming) sources: ``K_rows``
    (b, t, n) holds each lane's test rows, computed by ``rows_at`` —
    O(t*n) transient per group, never n² resident."""
    def one(Kr, ti, mask, C, r):
        b = bias_from_solution(r, y, mask, C)
        pred = predict(Kr, y, r.alpha, b)
        return jnp.sum(pred == y[ti])

    return jax.vmap(one)(K_rows, test_idx, train_masks, Cs, res)


def _freeze(x):
    """JSON round-trips tuples as lists; lane ids are hashable keys, so
    freeze them back on restore."""
    return tuple(_freeze(v) for v in x) if isinstance(x, list) else x


def _make_seed_fn(plan: Plan, spec: LaneSpec, resolve):
    """Build the pool-facing seed closure for a dependent lane. ``resolve``
    maps a source key to a USABLE source at call time (the pool's residency
    cache) — K is looked up lazily, at admission, so factory sources only
    materialize when a lane of theirs actually seeds."""
    fn = seeding.TRANSFORMS[spec.transform]
    key = plan.source_key_of(spec)
    y, C, params = plan.y_of(key), spec.C, dict(spec.params)

    def seed(prev):
        source = resolve(key)
        K = getattr(source, "K", None)
        if K is None:
            # kernel-free transforms (seeding.py marks them) never touch
            # K; f0 comes from the source's streaming matvec instead of
            # the dense init_f
            if getattr(fn, "kernel_free", False) and \
                    callable(getattr(source, "matvec", None)):
                alpha0 = fn(None, y, C, prev, **params)
                return alpha0, source.matvec(alpha0 * y) - y
            raise ValueError(f"lane {spec.id!r}: transform "
                             f"{spec.transform!r} needs a dense kernel "
                             f"source (source {key!r} has no K)")
        alpha0 = fn(K, y, C, prev, **params)
        return alpha0, init_f(K, y, alpha0)

    return seed


def _check_dense(plan: Plan, lane_id, key, what: str,
                 transform: str | None = None) -> None:
    """Seed transforms and evaluations need a dense K — unless the
    source supports the K-less alternative: kernel-free transforms run
    off a streaming ``matvec``, evaluations off a ``rows_at`` row slab.
    For an already-materialized (pinned) source that is checkable AT
    ENTRY — an incompatible source must not fail only after its
    dependency solved for hours. Factory entries stay deferred for the
    capabilities a spec cannot declare; the lazy resolution re-checks."""
    entry = plan.sources[key]
    if is_factory(entry) or getattr(entry, "K", None) is not None:
        return
    if transform is not None:
        fn = seeding.TRANSFORMS[transform]
        if getattr(fn, "kernel_free", False) and \
                callable(getattr(entry, "matvec", None)):
            return
    elif callable(getattr(entry, "rows_at", None)):
        return
    raise ValueError(f"lane {lane_id!r}: {what} a dense kernel "
                     f"source (source {key!r} has no K)")


def _validate_plan(plan: Plan, specs: dict) -> None:
    """Fail fast, by name, on a malformed lane graph. A typo'd ``dep`` /
    ``after`` edge or an unknown source key used to surface only at drain
    time, as ``LanePool.run``'s "missing or cyclic dep" RuntimeError
    listing EVERY pending lane — after hours of solving on a large study.
    Here every edge target, transform name and source key is checked at
    ``run_plan`` entry, and dep/after cycles are reported as the cycle."""
    for spec in plan.lanes:
        if spec.source is not None and spec.source not in plan.sources:
            raise ValueError(f"lane {spec.id!r}: unknown source key "
                             f"{spec.source!r} (plan has "
                             f"{sorted(map(repr, plan.sources))})")
        for edge, target in (("dep", spec.dep), ("after", spec.after)):
            if target is not None and target not in specs:
                raise ValueError(
                    f"lane {spec.id!r}: {edge} edge targets undeclared "
                    f"lane {target!r}")
        if spec.dep is not None:
            if spec.transform not in seeding.TRANSFORMS:
                raise ValueError(f"lane {spec.id!r}: unknown transform "
                                 f"{spec.transform!r} (have "
                                 f"{sorted(seeding.TRANSFORMS)})")
            _check_dense(plan, spec.id, plan.source_key_of(spec),
                         f"transform {spec.transform!r} needs",
                         transform=spec.transform)
    for ev in plan.evals:
        if ev.lane not in specs:
            raise ValueError(f"EvalSpec targets undeclared lane {ev.lane!r}")
        _check_dense(plan, ev.lane, plan.source_key_of(specs[ev.lane]),
                     "evaluation needs")
    # cycle check over the admission edges (given lanes are pre-resolved
    # and cannot be part of a cycle): iterative three-color DFS
    edges = {spec.id: [t for t in (spec.dep, spec.after)
                       if t is not None and specs[t].result is None]
             for spec in plan.lanes if spec.result is None}
    state: dict = {}                       # id -> "on_path" | "done"
    for root in edges:
        if root in state:
            continue
        stack = [(root, iter(edges.get(root, ())))]
        state[root] = "on_path"
        while stack:
            node, it = stack[-1]
            for target in it:
                if state.get(target) == "on_path":
                    path = [n for n, _ in stack]
                    cycle = path[path.index(target):] + [target]
                    raise ValueError(
                        "lane graph has a dep/after cycle: "
                        + " -> ".join(repr(n) for n in cycle))
                if target not in state:
                    state[target] = "on_path"
                    stack.append((target, iter(edges.get(target, ()))))
                    break
            else:
                state[node] = "done"
                stack.pop()


def resolve_source_backend(plan: Plan) -> Plan:
    """Validate ``plan.source_backend`` and apply it: ``"pallas_rbf"``
    rewrites every dense-RBF spec to the row-streaming kind (and requires
    WSS-1). This runs at entry — both ``run_plan`` and the static
    analyzer (``repro.analysis.plan_check``) resolve through here, so a
    typo'd backend fails before any kernel could materialize."""
    if plan.source_backend not in ("dense", "pallas_rbf"):
        raise ValueError(f"unknown source_backend {plan.source_backend!r} "
                         "(have 'dense', 'pallas_rbf')")
    if plan.source_backend == "pallas_rbf":
        if plan.wss != "1":
            raise ValueError("source_backend='pallas_rbf' streams both "
                             "kernel rows through the fused step kernel "
                             "and requires WSS-1 (wss='1')")
        plan = dataclasses.replace(plan, sources={
            k: (dataclasses.replace(s, kind="pallas_rbf")
                if isinstance(s, KernelSpec) and s.kind == "rbf" else s)
            for k, s in plan.sources.items()})
    return plan


def run_plan(plan: Plan, *, checkpoint: StudyCheckpoint | None = None,
             on_result=None, on_lane_chunk=None,
             analysis: str = "advisory") -> StudyResult:
    """Execute a ``Plan`` on one multi-source ``LanePool``.

    ``on_result(lane_id, result)`` streams each lane's ``SMOResult`` the
    moment it retires (long studies consume results without waiting for
    the pool to drain); ``on_lane_chunk(lane_id, state)`` observes every
    live lane between its chunks (the per-lane checkpoint hook legacy
    drivers use for their own record formats).

    With ``checkpoint``, the newest committed study record is restored
    first (identity verified against ``checkpoint.meta``): lanes found
    ``done`` re-enter as results, live lanes resume their exact iterate
    sequence, and pending lanes re-derive their seeds from the restored
    results — bit-identical to the uninterrupted run, under ANY schedule
    shape on either side of the crash.

    ``analysis`` wires the static plan analyzer
    (``repro.analysis.plan_check``): ``"advisory"`` (default) attaches
    the pre-execution report to ``StudyResult.analysis``; ``"strict"``
    raises on error-severity findings (budget-infeasible sources,
    checkpoint key collisions) BEFORE anything dispatches — the same
    gate a plan-admitting daemon calls; ``"off"`` skips it.
    """
    if analysis not in ("advisory", "strict", "off"):
        raise ValueError(f"unknown analysis mode {analysis!r} "
                         "(have 'advisory', 'strict', 'off')")
    plan = resolve_source_backend(plan)

    specs: dict[Any, LaneSpec] = {}
    for spec in plan.lanes:
        if spec.id in specs:
            raise ValueError(f"duplicate lane id {spec.id!r}")
        specs[spec.id] = spec
    _validate_plan(plan, specs)

    plan_analysis = None
    if analysis != "off":
        # deferred import: plan_check imports this module for the
        # validation surface and STUDY_BASE
        from repro.analysis import plan_check
        if analysis == "strict":
            plan_analysis = plan_check.check_plan(plan,
                                                  checkpoint=checkpoint)
        else:
            plan_analysis = plan_check.analyze_plan(plan,
                                                    checkpoint=checkpoint)

    restored: dict[Any, tuple] = {}
    step0 = 0
    if checkpoint is not None:
        snap = checkpoint.manager.restore_latest_of_class(
            checkpoint.retain_class)
        if snap is not None:
            step0, tree, extra = snap
            want = {"phase": checkpoint.phase, **checkpoint.meta}
            got = {key: extra.get(key) for key in want}
            if got != want:
                raise ValueError(
                    f"checkpoint at step {step0} belongs to run {got}, "
                    f"cannot resume it as {want}; point the manager at a "
                    "fresh directory or delete the stale checkpoints")
            for i, lid in enumerate(extra["lane_ids"]):
                # the shrink ledger rides along when the snapshotting pool
                # had shrinking on (absent in legacy/shrink-off snapshots):
                # a mid-shrink lane re-enters its exact compact bucket
                shrink0 = None
                if "active" in tree:
                    shrink0 = (
                        jnp.asarray(tree["active"][i])
                        if bool(tree["shrunk"][i]) else None,
                        bool(tree["no_shrink"][i]),
                        int(tree["unshrinks"][i]))
                restored[_freeze(lid)] = (
                    jnp.asarray(tree["alpha"][i]), jnp.asarray(tree["f"][i]),
                    int(tree["n_iter"][i]), bool(tree["done"][i]), shrink0)

    on_snapshot = None
    if checkpoint is not None:
        counter = {"c": max(step0, checkpoint.base_step)}

        def on_snapshot(pool):
            counter["c"] += 1
            lane_ids, tree = pool.snapshot_lanes()
            checkpoint.manager.save(
                counter["c"], tree,
                extra_meta={"phase": checkpoint.phase, "lane_ids": lane_ids,
                            **checkpoint.meta},
                blocking=False, retain_class=checkpoint.retain_class)

    pool = LanePool(plan.sources, plan.y, tol=plan.tol, wss=plan.wss,
                    chunk_iters=plan.chunk_iters,
                    lane_quantum=plan.lane_quantum, max_width=plan.max_width,
                    max_resident=plan.max_resident,
                    cache_bytes=plan.cache_bytes,
                    on_snapshot=on_snapshot,
                    snapshot_every=checkpoint.every if checkpoint else 1,
                    on_result=on_result, on_lane_chunk=on_lane_chunk,
                    shrink_every=plan.shrink_every,
                    shrink_quantum=plan.shrink_quantum,
                    shrink_caps=plan.shrink_caps,
                    shrink_on_seed=plan.shrink_on_seed)

    pre_done: set = set()
    for spec in plan.lanes:
        key = plan.source_key_of(spec) if spec.result is None else None
        if spec.result is not None:
            pool.add_result(spec.id, spec.result)
            pre_done.add(spec.id)
        elif spec.id in restored:
            alpha, f, n_it, done, shrink0 = restored[spec.id]
            if done:
                # a retired lane: re-finalize its snapshot state (optimality
                # is a pure function of alpha/f, so converged/b_up/b_low
                # come back identical to the pre-crash result)
                state = EngineState(alpha, f, jnp.asarray(n_it, jnp.int64),
                                    jnp.ones((), bool))
                pool.add_result(spec.id, finalize(
                    state, plan.y_of(key), spec.train_mask, spec.C, plan.tol))
                pre_done.add(spec.id)
            else:
                # mid-flight at the crash: it was already admitted, so its
                # plan-declared edges are history — resume the state as-is
                pool.add(spec.id, spec.train_mask, spec.C, alpha, f,
                         source=key, n_iter0=n_it, max_iter=spec.max_iter,
                         shrink0=shrink0)
        elif spec.dep is not None:
            pool.add(spec.id, spec.train_mask, spec.C, source=key,
                     dep=spec.dep,
                     seed_fn=_make_seed_fn(plan, spec, pool.resolve_source),
                     max_iter=spec.max_iter, after=spec.after)
        else:
            pool.add(spec.id, spec.train_mask, spec.C, spec.alpha0, spec.f0,
                     source=key, n_iter0=spec.n_iter0,
                     max_iter=spec.max_iter, after=spec.after)

    t0 = time.perf_counter()
    kt0 = pool.cache.kernel_time
    results = pool.run()
    jax.block_until_ready([results[s.id].alpha for s in plan.lanes])
    # kernel materializations during the run are attributed to the cache's
    # kernel_time (source_stats), not to seed or solve time
    wall = (time.perf_counter() - t0) - (pool.cache.kernel_time - kt0)
    if checkpoint is not None:
        checkpoint.manager.wait()

    stats = {}
    for spec in plan.lanes:
        res = results[spec.id]
        seed_s, solve_s = pool.lane_times(spec.id)
        stats[spec.id] = LaneStat(
            n_iter=int(res.n_iter), converged=bool(res.converged),
            seed_s=seed_s, solve_s=solve_s, restored=spec.id in pre_done)

    # ---- evaluations: one jitted program per (source, test-size) group ----
    evals: dict[Any, tuple[int, int]] = {}
    groups: dict[tuple, list[EvalSpec]] = {}
    for ev in plan.evals:
        spec = specs[ev.lane]
        t_sz = int(np.asarray(ev.test_idx).shape[0])
        groups.setdefault((plan.source_key_of(spec), t_sz), []).append(ev)
    # same-source groups run back-to-back, resident sources first, so a
    # budgeted cache re-materializes each remaining source at most once
    # here (the residency snapshot is taken before any eval materializes)
    order0 = {}
    for key, _ in groups:
        order0.setdefault(key, len(order0))
    key_rank = {key: (not pool.cache.resident(key), order0[key])
                for key in order0}
    for (key, t_sz), evs in sorted(groups.items(),
                                   key=lambda kv: key_rank[kv[0][0]]):
        source, y = pool.resolve_source(key), plan.y_of(key)
        K = getattr(source, "K", None)
        if K is None and not callable(getattr(source, "rows_at", None)):
            raise ValueError(f"EvalSpec on lane {evs[0].lane!r}: evaluation "
                             f"needs a dense kernel source (source {key!r} "
                             "has no K)")
        res = jax.tree.map(lambda *xs: jnp.stack(xs),
                           *[results[ev.lane] for ev in evs])
        test_idx = jnp.asarray(np.stack([np.asarray(ev.test_idx)
                                         for ev in evs]))
        masks = jnp.stack([specs[ev.lane].train_mask for ev in evs])
        Cs = jnp.asarray([specs[ev.lane].C for ev in evs], jnp.float64)
        if K is None:
            # K-less source: one O(b*t*n) row slab per group instead of K
            K_rows = source.rows_at(test_idx.reshape(-1)).reshape(
                test_idx.shape[0], t_sz, -1)
            correct = jax.device_get(
                _eval_lanes_rows_jit(K_rows, y, test_idx, masks, Cs, res))
        else:
            cap_sv = 0
            if plan.sv_eval:
                # shared compact-gather bucketing: one cap per group (the
                # widest lane's SV count, rounded up) keeps this at one
                # compiled program per (source, t_sz, cap) instead of one
                # per lane; a cap that wouldn't shrink the contraction
                # falls back to the full path
                n_rows = int(np.shape(y)[0])
                cap_sv = shrink_mod.bucket_cap(
                    int(np.max(jax.device_get(
                        jnp.sum(res.alpha > 0, axis=1)))), 128)
                if cap_sv >= n_rows:
                    cap_sv = 0
            if cap_sv:
                correct = jax.device_get(_eval_lanes_sv_jit(
                    K, y, test_idx, masks, Cs, res, cap_sv))
            else:
                correct = jax.device_get(
                    _eval_lanes_jit(K, y, test_idx, masks, Cs, res))
        for ev, c in zip(evs, correct):
            evals[ev.lane] = (int(c), t_sz)

    return StudyResult(results=results, stats=stats, evals=evals,
                       occupancy=pool.occupancy, seed_time=pool.seed_time,
                       solve_time=wall - pool.seed_time,
                       restored=frozenset(pre_done),
                       source_stats=pool.cache.stats,
                       analysis=plan_analysis)
