"""Study API: one declarative lane-graph entry point over a multi-source
scheduler pool.

The paper's speedups come from chaining solves through seed transforms
(alpha seeding across folds); Joulani et al. frame incremental-learning CV
as one dependency structure over reusable partial solutions. This module
says that structure ONCE, declaratively: a ``Plan`` is a graph of
``LaneSpec``s (train mask, C, kernel-source key, seed dependency +
transform name) plus ``EvalSpec``s, and ``run_plan`` executes it on a
multi-source ``LanePool`` (DESIGN.md §Study API). ``run_cv``,
``run_cv_batched``, ``run_loo`` and ``run_grid`` are thin plan builders
over this entry point — bit-identical to their pre-redesign outputs under
every schedule.

Plan grammar (each lane is exactly one of):

* **start lane** — ``alpha0``/``f0`` (+ optional ``n_iter0`` when resuming
  a snapshot): dispatched immediately, or held by an ``after`` ordering
  edge (sequential protocols, e.g. the paper's fold chain, express their
  order without faking a seed dependency);
* **dependent lane** — ``dep`` (another lane id) + ``transform`` (a name
  in ``seeding.TRANSFORMS``) + ``params``: admitted the moment the
  dependency retires, started at ``transform(K, y, C, dep_result,
  **params)``. Dependencies may cross kernel sources;
* **given lane** — ``result``: an already-solved ``SMOResult`` (a restored
  fold) that participates as a seed dependency but never dispatches.

Because transforms are referenced by NAME + params instead of closures,
the lane graph is data: the caller rebuilds the identical plan on resume,
and the checkpoint only has to persist per-lane (alpha, f, n_iter, done)
keyed by lane id (``StudyCheckpoint``; records default to
``retain_class="study"``, lane ids are stable under resume, and a snapshot
written under one schedule shape restores under any other).

``EvalSpec``s declare held-out evaluations; ``run_plan`` batches them into
one jitted program per (source, test-size) group — a whole study's
evaluation is a handful of device calls.

Plan sources may be **factories** (``svm/sources.py:KernelSpec``) instead
of dense matrices: the pool materializes them on demand under the plan's
``max_resident``/``cache_bytes`` budget (schedule-distance eviction —
DESIGN.md §Kernel-source cache). Seed transforms and eval groups resolve
their K through the same cache, so a study's memory scales with the
budget, not the source count. The whole lane graph (edge targets,
transform names, source keys, dep/after acyclicity) is validated at
``run_plan`` entry — a typo'd edge fails by name immediately instead of
surfacing as a drain-time RuntimeError hours into a large study.
"""
from __future__ import annotations

import base64
import dataclasses
import functools
import math
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import seeding
from repro.svm import shrink as shrink_mod
from repro.svm.engine import (DenseKernel, EngineState, SMOResult,
                              finalize)
from repro.svm.scheduler import LanePool
from repro.svm.sources import KernelSpec, is_factory
from repro.svm.smo import init_f
from repro.svm.svc import bias_from_solution, predict

#: study records live above every run_cv fold step (< _FOLD_STRIDE * k)
#: and every run_cv_batched batch step (_FOLD_STRIDE**2 + chunks), so all
#: three record kinds can share one checkpoint directory without step
#: collisions (``save`` replaces an existing step dir).
STUDY_BASE = 2 * 1_000_000 ** 2


@dataclasses.dataclass
class LaneSpec:
    """One node of the lane graph. See the module docstring for which
    field combinations are legal; ``source`` may be omitted in a
    single-source plan."""
    id: Any
    source: Any = None
    train_mask: Any = None
    C: float | None = None
    alpha0: Any = None
    f0: Any = None
    n_iter0: int = 0
    max_iter: int = 10_000_000
    dep: Any = None
    transform: str | None = None
    params: dict = dataclasses.field(default_factory=dict)
    after: Any = None
    result: Any = None


@dataclasses.dataclass
class EvalSpec:
    """Held-out evaluation of one lane: correct-count of ``predict`` over
    ``test_idx`` rows of the lane's kernel source."""
    lane: Any
    test_idx: Any


@dataclasses.dataclass
class Plan:
    """A declarative study: kernel sources, the lane graph, evaluations,
    and the schedule knobs forwarded to the ``LanePool``."""
    sources: dict
    y: Any                                # shared labels, or {source_key: y}
    lanes: list = dataclasses.field(default_factory=list)
    evals: list = dataclasses.field(default_factory=list)
    tol: float = 1e-3
    wss: str = "2"
    chunk_iters: int = 4096
    lane_quantum: int = 4
    max_width: int | None = None
    #: kernel-source residency budget (0 = unbounded): sources declared as
    #: factories (svm/sources.py:KernelSpec) materialize on demand and at
    #: most ``max_resident`` kernels / ``cache_bytes`` bytes stay resident
    #: (schedule-distance eviction — DESIGN.md §Kernel-source cache)
    max_resident: int = 0
    cache_bytes: int = 0
    #: kernel-source backend for the plan's declared ``KernelSpec``s:
    #: ``"dense"`` leaves them as declared; ``"pallas_rbf"`` rewrites every
    #: dense-RBF spec to the row-streaming kind (``svm/engine.py:PallasRBF``
    #: — nbytes = X bytes, fused, requires ``wss="1"``), so one knob flips
    #: a whole plan between n²-resident and row-streaming execution
    source_backend: str = "dense"
    #: active-set shrinking (``svm/shrink.py``): 0 = off (bit-identical to
    #: the pre-shrinking pool), an int = heuristic period in iterations,
    #: ``"auto"`` = backend-gated by the measured cost model
    #: (``cost_model.pick_shrink``). ``shrink_quantum`` buckets compact
    #: capacities (``shrink_caps`` declares an explicit ladder instead —
    #: what exact-program-count CI cells use); ``shrink_on_seed`` applies
    #: the seeding->shrinking handoff at admission
    shrink_every: int | str = 0
    shrink_quantum: int = 128
    shrink_caps: Any = None
    shrink_on_seed: bool = True
    #: support-vector-only evaluation: gather ``alpha > 0`` rows (the
    #: fixed-shape nonzero idiom at a ``shrink.bucket_cap`` capacity)
    #: before the eval matvec instead of multiplying through zero rows;
    #: dense-K groups only, falls back to the full path otherwise
    sv_eval: bool = False

    def lane(self, id, **kwargs) -> LaneSpec:
        spec = LaneSpec(id=id, **kwargs)
        self.lanes.append(spec)
        return spec

    def evaluate(self, lane, test_idx) -> None:
        self.evals.append(EvalSpec(lane, test_idx))

    def source_key_of(self, spec: LaneSpec) -> Any:
        if spec.source is not None:
            return spec.source
        if len(self.sources) == 1:
            return next(iter(self.sources))
        raise ValueError(f"lane {spec.id!r} needs a source key in a "
                         "multi-source plan")

    def y_of(self, key) -> jnp.ndarray:
        return self.y[key] if isinstance(self.y, dict) else self.y


@dataclasses.dataclass
class StudyCheckpoint:
    """Checkpoint wiring for ``run_plan``: every ``every``-th chunk, all
    admitted lanes' (alpha, f, n_iter, done) are saved stacked in lane-id
    order under ``retain_class`` at steps counting up from ``base_step``.
    ``meta`` is the plan identity — verified on resume, so a snapshot from
    a different study (or different solver parameters, which are part of
    the run identity: retired lanes carry fixed points at the snapshot's
    tolerance/budget) is rejected instead of silently mixed in."""
    manager: Any
    every: int = 1
    retain_class: str = "study"
    phase: str = "study_mid"
    base_step: int = STUDY_BASE
    meta: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class LaneStat:
    """Per-lane execution account: iterations, convergence, the admission
    transform's wall time (the paper's "init."), the lane's share of its
    dispatch chunks, and whether it was restored pre-solved."""
    n_iter: int
    converged: bool
    seed_s: float
    solve_s: float
    restored: bool = False


@dataclasses.dataclass
class StudyResult:
    results: dict                         # lane id -> SMOResult
    stats: dict                           # lane id -> LaneStat
    evals: dict                           # lane id -> (correct, total)
    occupancy: dict
    seed_time: float
    solve_time: float                     # pool wall time minus seed_time
    restored: frozenset                   # lanes already done at pool start
    #: kernel-source cache account: materialization count/wall-time and
    #: peak residency (sources, bytes) — all zeros for all-dense plans
    source_stats: dict = dataclasses.field(default_factory=dict)
    #: pre-execution static analysis (``repro.analysis.plan_check``):
    #: compile-shape enumeration, budget feasibility, advisory findings;
    #: None when ``run_plan(..., analysis="off")``
    analysis: Any = None
    #: fair-share accounting tag the lanes ran under (the daemon sets it
    #: to the submitting client's tenant id; None for in-process runs)
    tenant: Any = None


@jax.jit
def _eval_lanes_jit(K, y, test_idx, train_masks, Cs, res):
    """Held-out correct-count for a batch of lanes — the same
    bias_from_solution + predict pipeline as the sequential CV path,
    vmapped so a whole eval group is ONE device program."""
    def one(ti, mask, C, r):
        b = bias_from_solution(r, y, mask, C)
        pred = predict(K[ti], y, r.alpha, b)
        return jnp.sum(pred == y[ti])

    return jax.vmap(one)(test_idx, train_masks, Cs, res)


@functools.partial(jax.jit, static_argnames=("cap",))
def _eval_lanes_sv_jit(K, y, test_idx, train_masks, Cs, res, cap):
    """Support-vector-only variant of ``_eval_lanes_jit``: each lane
    gathers its ``alpha > 0`` rows (the same fixed-shape
    ``nonzero(size=cap, fill_value=n)`` compact-gather idiom the
    shrinking scheduler uses — pad columns clamp to the last row and are
    zero-weighted) and the decision matvec contracts over ``cap`` support
    vectors instead of all n training rows. Same ``>= 0`` prediction
    convention as ``svc.predict``; summation order over the support set
    differs from the full matvec, so this path carries the usual allclose
    guarantee, not bit parity — which is why it is opt-in
    (``Plan.sv_eval``)."""
    def one(ti, mask, C, r):
        b = bias_from_solution(r, y, mask, C)
        sv = r.alpha > 0
        svi = jnp.nonzero(sv, size=cap, fill_value=y.shape[0])[0]
        coef = jnp.where(jnp.arange(cap) < jnp.sum(sv),
                         r.alpha[svi] * y[svi], 0.0)
        dec = K[ti][:, svi] @ coef + b
        pred = jnp.where(dec >= 0, 1, -1)
        return jnp.sum(pred == y[ti])

    return jax.vmap(one)(test_idx, train_masks, Cs, res)


@jax.jit
def _eval_lanes_rows_jit(K_rows, y, test_idx, train_masks, Cs, res):
    """Row-slab variant for K-less (row-streaming) sources: ``K_rows``
    (b, t, n) holds each lane's test rows, computed by ``rows_at`` —
    O(t*n) transient per group, never n² resident."""
    def one(Kr, ti, mask, C, r):
        b = bias_from_solution(r, y, mask, C)
        pred = predict(Kr, y, r.alpha, b)
        return jnp.sum(pred == y[ti])

    return jax.vmap(one)(K_rows, test_idx, train_masks, Cs, res)


def _freeze(x):
    """JSON round-trips tuples as lists; lane ids are hashable keys, so
    freeze them back on restore."""
    return tuple(_freeze(v) for v in x) if isinstance(x, list) else x


# --------------------------------------------------------------------------
# Wire serialization: the study-service plan/result format. A Plan is
# already data (transforms by NAME, checkpoints by lane id), so the wire
# format is a direct JSON image of the dataclasses, with arrays carried as
# ``{"__nd__": 1, dtype, shape, data: base64(raw bytes)}`` — an EXACT bit
# round-trip, which is what lets a served study stay bit-identical to the
# in-process ``run_plan`` of the same plan. ``plan_from_dict`` is the
# hostile-input half: it re-freezes ids, and rejects unknown transform
# names, unknown source kinds and non-finite hyperparameters AT PARSE TIME
# with the same by-name errors as ``_validate_plan`` — a daemon never
# holds an unparseable plan object in memory waiting for admission to
# notice.
# --------------------------------------------------------------------------

#: the source kinds a wire plan may declare (svm/kernels.py dense kinds
#: plus the row-streaming Pallas source)
WIRE_SOURCE_KINDS = ("rbf", "linear", "pallas_rbf")


def _nd_to_wire(a) -> dict:
    a = np.ascontiguousarray(np.asarray(a))
    return {"__nd__": 1, "dtype": str(a.dtype), "shape": list(a.shape),
            "data": base64.b64encode(a.tobytes()).decode("ascii")}


def _nd_from_wire(d) -> np.ndarray:
    a = np.frombuffer(base64.b64decode(d["data"]), dtype=np.dtype(d["dtype"]))
    return a.reshape([int(s) for s in d["shape"]]).copy()


def _to_wire(v):
    """JSON-encodable image of a plan field value: arrays via the nd
    codec, tuples as lists (re-frozen on parse), numpy scalars unboxed.
    Python floats survive JSON exactly (shortest-round-trip repr)."""
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    if isinstance(v, (np.bool_, np.integer, np.floating)):
        return v.item()
    if isinstance(v, (np.ndarray, jax.Array)):
        return _nd_to_wire(v)
    if isinstance(v, (list, tuple)):
        return [_to_wire(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _to_wire(val) for k, val in v.items()}
    raise TypeError(f"cannot serialize {type(v).__name__!r} value {v!r}")


def _from_wire(v):
    """Inverse of ``_to_wire``; lists come back as TUPLES (wire lists only
    occur where hashability matters: ids, params, shrink_caps)."""
    if isinstance(v, dict):
        if v.get("__nd__") == 1:
            return _nd_from_wire(v)
        return {k: _from_wire(val) for k, val in v.items()}
    if isinstance(v, list):
        return tuple(_from_wire(x) for x in v)
    return v


def _check_finite(value, what: str):
    """Parse-time hyperparameter gate: a NaN/inf C, gamma or tol would
    pass every structural check and then poison a shared pool's solves."""
    if value is None:
        return None
    value = float(value)
    if not math.isfinite(value):
        raise ValueError(f"{what}: non-finite value {value!r}")
    return value


def result_to_dict(r: SMOResult) -> dict:
    """Wire image of an ``SMOResult`` (bit-exact: arrays via the nd
    codec, scalars as JSON numbers)."""
    return {"alpha": _nd_to_wire(r.alpha), "f": _nd_to_wire(r.f),
            "n_iter": int(r.n_iter), "converged": bool(r.converged),
            "b_up": float(r.b_up), "b_low": float(r.b_low)}


def result_from_dict(d: dict) -> SMOResult:
    return SMOResult(
        alpha=jnp.asarray(_nd_from_wire(d["alpha"])),
        f=jnp.asarray(_nd_from_wire(d["f"])),
        n_iter=jnp.asarray(int(d["n_iter"]), jnp.int64),
        converged=jnp.asarray(bool(d["converged"])),
        b_up=jnp.asarray(float(d["b_up"])),
        b_low=jnp.asarray(float(d["b_low"])))


def _source_to_wire(key, entry) -> dict:
    if isinstance(entry, KernelSpec):
        return {"kind_tag": "spec", "X": _nd_to_wire(entry.X),
                "gamma": float(entry.gamma), "kind": entry.kind,
                "backend": entry.backend,
                "n": None if entry.n is None else int(entry.n)}
    K = getattr(entry, "K", None)
    if K is not None and not is_factory(entry):
        return {"kind_tag": "dense", "K": _nd_to_wire(K)}
    raise TypeError(
        f"source {key!r}: only KernelSpec and dense-K sources serialize "
        f"(got {type(entry).__name__!r}) — opaque sources cannot cross "
        "the wire")


def _source_from_wire(key, d: dict):
    tag = d.get("kind_tag")
    if tag == "dense":
        K = jnp.asarray(_nd_from_wire(d["K"]))
        return DenseKernel(K)
    if tag != "spec":
        raise ValueError(f"source {key!r}: unknown source entry tag "
                         f"{tag!r} (have 'spec', 'dense')")
    kind = d.get("kind")
    if kind not in WIRE_SOURCE_KINDS:
        raise ValueError(f"source {key!r}: unknown source kind {kind!r} "
                         f"(have {sorted(WIRE_SOURCE_KINDS)})")
    gamma = _check_finite(d.get("gamma", 1.0), f"source {key!r}: gamma")
    return KernelSpec(jnp.asarray(_nd_from_wire(d["X"])), gamma=gamma,
                      kind=kind, backend=d.get("backend", "jnp"),
                      n=None if d.get("n") is None else int(d["n"]))


def plan_to_dict(plan: Plan) -> dict:
    """JSON-encodable image of a ``Plan``. Source/y keys ride as
    ``[key, value]`` pairs (JSON objects cannot key by tuple/float);
    ``plan_from_dict`` re-freezes them."""
    y = plan.y
    y_wire = {"__ymap__": 1,
              "items": [[_to_wire(k), _nd_to_wire(v)]
                        for k, v in y.items()]} \
        if isinstance(y, dict) else _nd_to_wire(y)
    lanes = []
    for spec in plan.lanes:
        lanes.append({
            "id": _to_wire(spec.id), "source": _to_wire(spec.source),
            "train_mask": None if spec.train_mask is None
            else _nd_to_wire(spec.train_mask),
            "C": None if spec.C is None else float(spec.C),
            "alpha0": None if spec.alpha0 is None
            else _nd_to_wire(spec.alpha0),
            "f0": None if spec.f0 is None else _nd_to_wire(spec.f0),
            "n_iter0": int(spec.n_iter0), "max_iter": int(spec.max_iter),
            "dep": _to_wire(spec.dep), "transform": spec.transform,
            "params": _to_wire(dict(spec.params)),
            "after": _to_wire(spec.after),
            "result": None if spec.result is None
            else result_to_dict(spec.result)})
    return {"__plan__": 1,
            "sources": [[_to_wire(k), _source_to_wire(k, v)]
                        for k, v in plan.sources.items()],
            "y": y_wire,
            "lanes": lanes,
            "evals": [[_to_wire(ev.lane), _nd_to_wire(ev.test_idx)]
                      for ev in plan.evals],
            "tol": float(plan.tol), "wss": plan.wss,
            "chunk_iters": int(plan.chunk_iters),
            "lane_quantum": int(plan.lane_quantum),
            "max_width": None if plan.max_width is None
            else int(plan.max_width),
            "max_resident": int(plan.max_resident),
            "cache_bytes": int(plan.cache_bytes),
            "source_backend": plan.source_backend,
            "shrink_every": plan.shrink_every,
            "shrink_quantum": int(plan.shrink_quantum),
            "shrink_caps": _to_wire(plan.shrink_caps),
            "shrink_on_seed": bool(plan.shrink_on_seed),
            "sv_eval": bool(plan.sv_eval)}


def plan_from_dict(d: dict) -> Plan:
    """Parse a wire plan, rejecting hostile content at PARSE time: unknown
    transform names and source kinds, and non-finite hyperparameters (C,
    gamma, tol) raise the same by-name errors ``_validate_plan`` uses —
    before any object that could reach a pool exists. Structural rules
    (edge targets, cycles, duplicate ids) remain ``_validate_plan``'s
    job; admission calls it via ``check_plan``."""
    if not isinstance(d, dict) or d.get("__plan__") != 1:
        raise ValueError("not a wire plan (missing '__plan__': 1)")
    sources = {}
    for key_w, entry_w in d.get("sources", ()):
        key = _from_wire(key_w)
        if key in sources:
            raise ValueError(f"duplicate source key {key!r}")
        sources[key] = _source_from_wire(key, entry_w)
    y_w = d.get("y")
    if isinstance(y_w, dict) and y_w.get("__ymap__") == 1:
        y = {_from_wire(k): jnp.asarray(_nd_from_wire(v))
             for k, v in y_w["items"]}
    else:
        y = jnp.asarray(_nd_from_wire(y_w))
    tol = _check_finite(d.get("tol", 1e-3), "tol")
    if tol <= 0:
        raise ValueError(f"tol: non-positive value {tol!r}")
    lanes = []
    for lw in d.get("lanes", ()):
        lid = _from_wire(lw.get("id"))
        transform = lw.get("transform")
        if transform is not None and transform not in seeding.TRANSFORMS:
            raise ValueError(f"lane {lid!r}: unknown transform "
                             f"{transform!r} (have "
                             f"{sorted(seeding.TRANSFORMS)})")
        C = _check_finite(lw.get("C"), f"lane {lid!r}: C")
        params = _from_wire(lw.get("params") or {})
        for pk, pv in params.items():
            if isinstance(pv, float):
                _check_finite(pv, f"lane {lid!r}: params[{pk!r}]")
        lanes.append(LaneSpec(
            id=lid, source=_from_wire(lw.get("source")),
            train_mask=None if lw.get("train_mask") is None
            else jnp.asarray(_nd_from_wire(lw["train_mask"])),
            C=C,
            alpha0=None if lw.get("alpha0") is None
            else jnp.asarray(_nd_from_wire(lw["alpha0"])),
            f0=None if lw.get("f0") is None
            else jnp.asarray(_nd_from_wire(lw["f0"])),
            n_iter0=int(lw.get("n_iter0", 0)),
            max_iter=int(lw.get("max_iter", 10_000_000)),
            dep=_from_wire(lw.get("dep")), transform=transform,
            params=params, after=_from_wire(lw.get("after")),
            result=None if lw.get("result") is None
            else result_from_dict(lw["result"])))
    evals = [EvalSpec(_from_wire(lane_w),
                      jnp.asarray(_nd_from_wire(idx_w)))
             for lane_w, idx_w in d.get("evals", ())]
    shrink_every = d.get("shrink_every", 0)
    if shrink_every != "auto":
        shrink_every = int(shrink_every)
    caps = _from_wire(d.get("shrink_caps"))
    return Plan(sources=sources, y=y, lanes=lanes, evals=evals,
                tol=tol, wss=str(d.get("wss", "2")),
                chunk_iters=int(d.get("chunk_iters", 4096)),
                lane_quantum=int(d.get("lane_quantum", 4)),
                max_width=None if d.get("max_width") is None
                else int(d["max_width"]),
                max_resident=int(d.get("max_resident", 0)),
                cache_bytes=int(d.get("cache_bytes", 0)),
                source_backend=str(d.get("source_backend", "dense")),
                shrink_every=shrink_every,
                shrink_quantum=int(d.get("shrink_quantum", 128)),
                shrink_caps=caps,
                shrink_on_seed=bool(d.get("shrink_on_seed", True)),
                sv_eval=bool(d.get("sv_eval", False)))


def _make_seed_fn(plan: Plan, spec: LaneSpec, resolve):
    """Build the pool-facing seed closure for a dependent lane. ``resolve``
    maps a source key to a USABLE source at call time (the pool's residency
    cache) — K is looked up lazily, at admission, so factory sources only
    materialize when a lane of theirs actually seeds."""
    fn = seeding.TRANSFORMS[spec.transform]
    key = plan.source_key_of(spec)
    y, C, params = plan.y_of(key), spec.C, dict(spec.params)

    def seed(prev):
        source = resolve(key)
        K = getattr(source, "K", None)
        if K is None:
            # kernel-free transforms (seeding.py marks them) never touch
            # K; f0 comes from the source's streaming matvec instead of
            # the dense init_f
            if getattr(fn, "kernel_free", False) and \
                    callable(getattr(source, "matvec", None)):
                alpha0 = fn(None, y, C, prev, **params)
                return alpha0, source.matvec(alpha0 * y) - y
            raise ValueError(f"lane {spec.id!r}: transform "
                             f"{spec.transform!r} needs a dense kernel "
                             f"source (source {key!r} has no K)")
        alpha0 = fn(K, y, C, prev, **params)
        return alpha0, init_f(K, y, alpha0)

    return seed


def _check_dense(plan: Plan, lane_id, key, what: str,
                 transform: str | None = None) -> None:
    """Seed transforms and evaluations need a dense K — unless the
    source supports the K-less alternative: kernel-free transforms run
    off a streaming ``matvec``, evaluations off a ``rows_at`` row slab.
    For an already-materialized (pinned) source that is checkable AT
    ENTRY — an incompatible source must not fail only after its
    dependency solved for hours. Factory entries stay deferred for the
    capabilities a spec cannot declare; the lazy resolution re-checks."""
    entry = plan.sources[key]
    if is_factory(entry) or getattr(entry, "K", None) is not None:
        return
    if transform is not None:
        fn = seeding.TRANSFORMS[transform]
        if getattr(fn, "kernel_free", False) and \
                callable(getattr(entry, "matvec", None)):
            return
    elif callable(getattr(entry, "rows_at", None)):
        return
    raise ValueError(f"lane {lane_id!r}: {what} a dense kernel "
                     f"source (source {key!r} has no K)")


def _validate_plan(plan: Plan, specs: dict) -> None:
    """Fail fast, by name, on a malformed lane graph. A typo'd ``dep`` /
    ``after`` edge or an unknown source key used to surface only at drain
    time, as ``LanePool.run``'s "missing or cyclic dep" RuntimeError
    listing EVERY pending lane — after hours of solving on a large study.
    Here every edge target, transform name and source key is checked at
    ``run_plan`` entry, and dep/after cycles are reported as the cycle."""
    for spec in plan.lanes:
        if spec.source is not None and spec.source not in plan.sources:
            raise ValueError(f"lane {spec.id!r}: unknown source key "
                             f"{spec.source!r} (plan has "
                             f"{sorted(map(repr, plan.sources))})")
        for edge, target in (("dep", spec.dep), ("after", spec.after)):
            if target is not None and target not in specs:
                raise ValueError(
                    f"lane {spec.id!r}: {edge} edge targets undeclared "
                    f"lane {target!r}")
        if spec.dep is not None:
            if spec.transform not in seeding.TRANSFORMS:
                raise ValueError(f"lane {spec.id!r}: unknown transform "
                                 f"{spec.transform!r} (have "
                                 f"{sorted(seeding.TRANSFORMS)})")
            _check_dense(plan, spec.id, plan.source_key_of(spec),
                         f"transform {spec.transform!r} needs",
                         transform=spec.transform)
    for ev in plan.evals:
        if ev.lane not in specs:
            raise ValueError(f"EvalSpec targets undeclared lane {ev.lane!r}")
        _check_dense(plan, ev.lane, plan.source_key_of(specs[ev.lane]),
                     "evaluation needs")
    # cycle check over the admission edges (given lanes are pre-resolved
    # and cannot be part of a cycle): iterative three-color DFS
    edges = {spec.id: [t for t in (spec.dep, spec.after)
                       if t is not None and specs[t].result is None]
             for spec in plan.lanes if spec.result is None}
    state: dict = {}                       # id -> "on_path" | "done"
    for root in edges:
        if root in state:
            continue
        stack = [(root, iter(edges.get(root, ())))]
        state[root] = "on_path"
        while stack:
            node, it = stack[-1]
            for target in it:
                if state.get(target) == "on_path":
                    path = [n for n, _ in stack]
                    cycle = path[path.index(target):] + [target]
                    raise ValueError(
                        "lane graph has a dep/after cycle: "
                        + " -> ".join(repr(n) for n in cycle))
                if target not in state:
                    state[target] = "on_path"
                    stack.append((target, iter(edges.get(target, ()))))
                    break
            else:
                state[node] = "done"
                stack.pop()


def resolve_source_backend(plan: Plan) -> Plan:
    """Validate ``plan.source_backend`` and apply it: ``"pallas_rbf"``
    rewrites every dense-RBF spec to the row-streaming kind (and requires
    WSS-1). This runs at entry — both ``run_plan`` and the static
    analyzer (``repro.analysis.plan_check``) resolve through here, so a
    typo'd backend fails before any kernel could materialize."""
    if plan.source_backend not in ("dense", "pallas_rbf"):
        raise ValueError(f"unknown source_backend {plan.source_backend!r} "
                         "(have 'dense', 'pallas_rbf')")
    if plan.source_backend == "pallas_rbf":
        if plan.wss != "1":
            raise ValueError("source_backend='pallas_rbf' streams both "
                             "kernel rows through the fused step kernel "
                             "and requires WSS-1 (wss='1')")
        plan = dataclasses.replace(plan, sources={
            k: (dataclasses.replace(s, kind="pallas_rbf")
                if isinstance(s, KernelSpec) and s.kind == "rbf" else s)
            for k, s in plan.sources.items()})
    return plan


def plan_specs(plan: Plan) -> dict:
    """``{lane_id: LaneSpec}`` with the duplicate-id check — the one
    spec index ``run_plan`` and the study daemon both build."""
    specs: dict[Any, LaneSpec] = {}
    for spec in plan.lanes:
        if spec.id in specs:
            raise ValueError(f"duplicate lane id {spec.id!r}")
        specs[spec.id] = spec
    return specs


def restore_study_lanes(checkpoint: StudyCheckpoint | None):
    """Load the newest committed study record (identity verified against
    ``checkpoint.meta``): returns ``(step0, {lane_id: (alpha, f, n_iter,
    done, shrink0)})`` — empty when there is nothing to resume. Factored
    out of ``run_plan`` so the daemon resumes a killed study through the
    exact code path the in-process API uses."""
    restored: dict[Any, tuple] = {}
    step0 = 0
    if checkpoint is None:
        return step0, restored
    snap = checkpoint.manager.restore_latest_of_class(
        checkpoint.retain_class)
    if snap is None:
        return step0, restored
    step0, tree, extra = snap
    want = {"phase": checkpoint.phase, **checkpoint.meta}
    got = {key: extra.get(key) for key in want}
    if got != want:
        raise ValueError(
            f"checkpoint at step {step0} belongs to run {got}, "
            f"cannot resume it as {want}; point the manager at a "
            "fresh directory or delete the stale checkpoints")
    for i, lid in enumerate(extra["lane_ids"]):
        # the shrink ledger rides along when the snapshotting pool
        # had shrinking on (absent in legacy/shrink-off snapshots):
        # a mid-shrink lane re-enters its exact compact bucket
        shrink0 = None
        if "active" in tree:
            shrink0 = (
                jnp.asarray(tree["active"][i])
                if bool(tree["shrunk"][i]) else None,
                bool(tree["no_shrink"][i]),
                int(tree["unshrinks"][i]))
        restored[_freeze(lid)] = (
            jnp.asarray(tree["alpha"][i]), jnp.asarray(tree["f"][i]),
            int(tree["n_iter"][i]), bool(tree["done"][i]), shrink0)
    return step0, restored


def enroll_plan_lanes(pool: LanePool, plan: Plan, specs: dict,
                      restored: dict, *, tenant=None) -> set:
    """Register every plan lane with ``pool`` — given results directly,
    restored lanes from their snapshot state, dependent lanes with their
    lazy seed closure. Returns the ids that entered pre-solved. The plan
    must already be validated and its sources present in the pool (the
    daemon admits sources separately, under dedup)."""
    pre_done: set = set()
    for spec in plan.lanes:
        key = plan.source_key_of(spec) if spec.result is None else None
        if spec.result is not None:
            pool.add_result(spec.id, spec.result, tenant=tenant)
            pre_done.add(spec.id)
        elif spec.id in restored:
            alpha, f, n_it, done, shrink0 = restored[spec.id]
            if done:
                # a retired lane: re-finalize its snapshot state (optimality
                # is a pure function of alpha/f, so converged/b_up/b_low
                # come back identical to the pre-crash result)
                state = EngineState(alpha, f, jnp.asarray(n_it, jnp.int64),
                                    jnp.ones((), bool))
                pool.add_result(spec.id, finalize(
                    state, plan.y_of(key), spec.train_mask, spec.C,
                    plan.tol), tenant=tenant)
                pre_done.add(spec.id)
            else:
                # mid-flight at the crash: it was already admitted, so its
                # plan-declared edges are history — resume the state as-is
                pool.add(spec.id, spec.train_mask, spec.C, alpha, f,
                         source=key, n_iter0=n_it, max_iter=spec.max_iter,
                         shrink0=shrink0, tenant=tenant)
        elif spec.dep is not None:
            pool.add(spec.id, spec.train_mask, spec.C, source=key,
                     dep=spec.dep,
                     seed_fn=_make_seed_fn(plan, spec, pool.resolve_source),
                     max_iter=spec.max_iter, after=spec.after, tenant=tenant)
        else:
            pool.add(spec.id, spec.train_mask, spec.C, spec.alpha0, spec.f0,
                     source=key, n_iter0=spec.n_iter0,
                     max_iter=spec.max_iter, after=spec.after, tenant=tenant)
    return pre_done


def run_plan_evals(pool: LanePool, plan: Plan, specs: dict,
                   results: dict) -> dict:
    """The plan's held-out evaluations: one jitted program per
    (source, test-size) group. Same-source groups run back-to-back,
    resident sources first, so a budgeted cache re-materializes each
    remaining source at most once (the residency snapshot is taken
    before any eval materializes)."""
    evals: dict[Any, tuple[int, int]] = {}
    groups: dict[tuple, list[EvalSpec]] = {}
    for ev in plan.evals:
        spec = specs[ev.lane]
        t_sz = int(np.asarray(ev.test_idx).shape[0])
        groups.setdefault((plan.source_key_of(spec), t_sz), []).append(ev)
    order0 = {}
    for key, _ in groups:
        order0.setdefault(key, len(order0))
    key_rank = {key: (not pool.cache.resident(key), order0[key])
                for key in order0}
    for (key, t_sz), evs in sorted(groups.items(),
                                   key=lambda kv: key_rank[kv[0][0]]):
        source, y = pool.resolve_source(key), plan.y_of(key)
        K = getattr(source, "K", None)
        if K is None and not callable(getattr(source, "rows_at", None)):
            raise ValueError(f"EvalSpec on lane {evs[0].lane!r}: evaluation "
                             f"needs a dense kernel source (source {key!r} "
                             "has no K)")
        res = jax.tree.map(lambda *xs: jnp.stack(xs),
                           *[results[ev.lane] for ev in evs])
        test_idx = jnp.asarray(np.stack([np.asarray(ev.test_idx)
                                         for ev in evs]))
        masks = jnp.stack([specs[ev.lane].train_mask for ev in evs])
        Cs = jnp.asarray([specs[ev.lane].C for ev in evs], jnp.float64)
        if K is None:
            # K-less source: one O(b*t*n) row slab per group instead of K
            K_rows = source.rows_at(test_idx.reshape(-1)).reshape(
                test_idx.shape[0], t_sz, -1)
            correct = jax.device_get(
                _eval_lanes_rows_jit(K_rows, y, test_idx, masks, Cs, res))
        else:
            cap_sv = 0
            if plan.sv_eval:
                # shared compact-gather bucketing: one cap per group (the
                # widest lane's SV count, rounded up) keeps this at one
                # compiled program per (source, t_sz, cap) instead of one
                # per lane; a cap that wouldn't shrink the contraction
                # falls back to the full path
                n_rows = int(np.shape(y)[0])
                cap_sv = shrink_mod.bucket_cap(
                    int(np.max(jax.device_get(
                        jnp.sum(res.alpha > 0, axis=1)))), 128)
                if cap_sv >= n_rows:
                    cap_sv = 0
            if cap_sv:
                correct = jax.device_get(_eval_lanes_sv_jit(
                    K, y, test_idx, masks, Cs, res, cap_sv))
            else:
                correct = jax.device_get(
                    _eval_lanes_jit(K, y, test_idx, masks, Cs, res))
        for ev, c in zip(evs, correct):
            evals[ev.lane] = (int(c), t_sz)
    return evals


def run_plan(plan: Plan, *, checkpoint: StudyCheckpoint | None = None,
             on_result=None, on_lane_chunk=None,
             analysis: str = "advisory", tenant=None) -> StudyResult:
    """Execute a ``Plan`` on one multi-source ``LanePool``.

    ``on_result(lane_id, result)`` streams each lane's ``SMOResult`` the
    moment it retires (long studies consume results without waiting for
    the pool to drain); ``on_lane_chunk(lane_id, state)`` observes every
    live lane between its chunks (the per-lane checkpoint hook legacy
    drivers use for their own record formats).

    With ``checkpoint``, the newest committed study record is restored
    first (identity verified against ``checkpoint.meta``): lanes found
    ``done`` re-enter as results, live lanes resume their exact iterate
    sequence, and pending lanes re-derive their seeds from the restored
    results — bit-identical to the uninterrupted run, under ANY schedule
    shape on either side of the crash.

    ``analysis`` wires the static plan analyzer
    (``repro.analysis.plan_check``): ``"advisory"`` (default) attaches
    the pre-execution report to ``StudyResult.analysis``; ``"strict"``
    raises on error-severity findings (budget-infeasible sources,
    checkpoint key collisions) BEFORE anything dispatches — the same
    gate a plan-admitting daemon calls; ``"off"`` skips it.
    """
    if analysis not in ("advisory", "strict", "off"):
        raise ValueError(f"unknown analysis mode {analysis!r} "
                         "(have 'advisory', 'strict', 'off')")
    plan = resolve_source_backend(plan)

    specs = plan_specs(plan)
    _validate_plan(plan, specs)

    plan_analysis = None
    if analysis != "off":
        # deferred import: plan_check imports this module for the
        # validation surface and STUDY_BASE
        from repro.analysis import plan_check
        if analysis == "strict":
            plan_analysis = plan_check.check_plan(plan,
                                                  checkpoint=checkpoint)
        else:
            plan_analysis = plan_check.analyze_plan(plan,
                                                    checkpoint=checkpoint)

    step0, restored = restore_study_lanes(checkpoint)

    on_snapshot = None
    if checkpoint is not None:
        counter = {"c": max(step0, checkpoint.base_step)}

        def on_snapshot(pool):
            counter["c"] += 1
            lane_ids, tree = pool.snapshot_lanes()
            checkpoint.manager.save(
                counter["c"], tree,
                extra_meta={"phase": checkpoint.phase, "lane_ids": lane_ids,
                            **checkpoint.meta},
                blocking=False, retain_class=checkpoint.retain_class)

    pool = LanePool(plan.sources, plan.y, tol=plan.tol, wss=plan.wss,
                    chunk_iters=plan.chunk_iters,
                    lane_quantum=plan.lane_quantum, max_width=plan.max_width,
                    max_resident=plan.max_resident,
                    cache_bytes=plan.cache_bytes,
                    on_snapshot=on_snapshot,
                    snapshot_every=checkpoint.every if checkpoint else 1,
                    on_result=on_result, on_lane_chunk=on_lane_chunk,
                    shrink_every=plan.shrink_every,
                    shrink_quantum=plan.shrink_quantum,
                    shrink_caps=plan.shrink_caps,
                    shrink_on_seed=plan.shrink_on_seed)

    pre_done = enroll_plan_lanes(pool, plan, specs, restored, tenant=tenant)

    t0 = time.perf_counter()
    kt0 = pool.cache.kernel_time
    results = pool.run()
    jax.block_until_ready([results[s.id].alpha for s in plan.lanes])
    # kernel materializations during the run are attributed to the cache's
    # kernel_time (source_stats), not to seed or solve time
    wall = (time.perf_counter() - t0) - (pool.cache.kernel_time - kt0)
    if checkpoint is not None:
        checkpoint.manager.wait()

    stats = {}
    for spec in plan.lanes:
        res = results[spec.id]
        seed_s, solve_s = pool.lane_times(spec.id)
        stats[spec.id] = LaneStat(
            n_iter=int(res.n_iter), converged=bool(res.converged),
            seed_s=seed_s, solve_s=solve_s, restored=spec.id in pre_done)

    evals = run_plan_evals(pool, plan, specs, results)

    return StudyResult(results=results, stats=stats, evals=evals,
                       occupancy=pool.occupancy, seed_time=pool.seed_time,
                       solve_time=wall - pool.seed_time,
                       restored=frozenset(pre_done),
                       source_stats=pool.cache.stats,
                       analysis=plan_analysis, tenant=tenant)
