"""k-fold cross-validation driver with alpha-seed chaining.

Reproduces the paper's experimental protocol: fold 0 starts cold; fold h>0
warm-starts from the most recent completed fold via the chosen seeder. The
driver is also the fault-tolerance unit: each completed fold is checkpointed
(fold index + alpha + f), so a restarted job re-seeds from the last
completed fold — the paper's own mechanism doubles as the recovery path.

Straggler policy: ``strict`` (paper semantics — always seed from fold h-1)
or ``best_available`` (seed from the nearest *completed* fold; lets the
scheduler keep going when a fold is slow/lost; still bit-compatible results
because seeding never changes the fixed point).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import seeding
from repro.data.svm_suite import SVMDataset, kfold_chunks
from repro.svm import (accuracy, bias_from_solution, init_f, kernel_matrix,
                       predict, smo_solve, dual_objective)


@dataclasses.dataclass
class FoldStat:
    fold: int
    seed_from: int          # which fold's solution seeded this one (-1 = cold)
    n_iter: int
    init_time: float        # seeding + f-recompute (the paper's "init.")
    solve_time: float       # SMO time (the paper's "the rest": train part)
    acc_correct: int
    acc_total: int
    objective: float
    converged: bool


@dataclasses.dataclass
class CVReport:
    dataset: str
    method: str
    k: int
    n: int
    kernel_time: float
    folds: list[FoldStat]

    @property
    def total_iterations(self) -> int:
        return int(sum(f.n_iter for f in self.folds))

    @property
    def total_init_time(self) -> float:
        return float(sum(f.init_time for f in self.folds))

    @property
    def total_solve_time(self) -> float:
        return float(sum(f.solve_time for f in self.folds))

    @property
    def accuracy(self) -> float:
        c = sum(f.acc_correct for f in self.folds)
        t = sum(f.acc_total for f in self.folds)
        return c / max(t, 1)

    def row(self) -> dict:
        return {"dataset": self.dataset, "method": self.method, "k": self.k,
                "iterations": self.total_iterations,
                "init_s": round(self.total_init_time, 4),
                "solve_s": round(self.total_solve_time, 4),
                "total_s": round(self.total_init_time + self.total_solve_time
                                 + self.kernel_time, 4),
                "accuracy": round(self.accuracy, 4)}


def _transition_idx(chunks: np.ndarray, g: int, h: int):
    """Index sets for seeding fold h from fold g's solution.

    Previous train set = all \\ chunk[g]; new train set = all \\ chunk[h]:
    T (added) = chunk[g], R (removed) = chunk[h], S = the rest.
    """
    k = chunks.shape[0]
    S = np.concatenate([chunks[j] for j in range(k) if j not in (g, h)])
    return jnp.asarray(S), jnp.asarray(chunks[h]), jnp.asarray(chunks[g])


def run_cv(ds: SVMDataset, k: int = 10, method: str = "sir",
           tol: float = 1e-3, max_iter: int = 5_000_000, seed: int = 0,
           checkpoint_manager=None, straggler_policy: str = "strict",
           unavailable_folds: frozenset[int] = frozenset(),
           kernel_backend: str = "jnp") -> CVReport:
    """Run alpha-seeded k-fold CV. ``unavailable_folds`` simulates stragglers/
    failures: those folds' results are not used as seeds (best_available
    policy then falls back to the nearest earlier completed fold)."""
    seeder = seeding.SEEDERS[method]
    X = jnp.asarray(ds.X)
    y = jnp.asarray(ds.y, jnp.float64)

    t0 = time.perf_counter()
    K = kernel_matrix(X, X, kind="rbf", gamma=ds.gamma,
                      backend=kernel_backend)
    K.block_until_ready()
    kernel_time = time.perf_counter() - t0

    chunks = kfold_chunks(ds.n, k, seed=seed)
    n = chunks.size  # padded n (multiple of k)
    K = K[:n][:, :n]
    y = y[:n]

    results: dict[int, object] = {}
    folds: list[FoldStat] = []
    start_fold = 0

    if checkpoint_manager is not None and checkpoint_manager.latest_step() is not None:
        step, tree, extra = checkpoint_manager.restore()
        results[extra["fold"]] = _result_from_tree(tree)
        start_fold = extra["fold"] + 1

    for h in range(start_fold, k):
        test_idx = jnp.asarray(chunks[h])
        train_mask = jnp.ones(n, bool).at[test_idx].set(False)

        # ---- choose the seed fold (straggler policy) ----
        completed = [g for g in sorted(results) if g not in unavailable_folds]
        if h == 0 or method == "cold" or not completed:
            seed_from = -1
        elif straggler_policy == "strict":
            seed_from = h - 1 if (h - 1) in completed else -1
        else:  # best_available: nearest completed fold
            seed_from = min(completed, key=lambda g: abs(h - g))

        # ---- init (the paper's "init." column) ----
        t0 = time.perf_counter()
        if seed_from < 0:
            alpha0 = jnp.zeros(n, K.dtype)
            f0 = -y
        else:
            S_idx, R_idx, T_idx = _transition_idx(chunks, seed_from, h)
            alpha0 = seeder(K, y, ds.C, results[seed_from], S_idx, R_idx, T_idx)
            f0 = init_f(K, y, alpha0)
        jax.block_until_ready((alpha0, f0))
        init_time = time.perf_counter() - t0

        # ---- solve ----
        t0 = time.perf_counter()
        res = smo_solve(K, y, train_mask, ds.C, alpha0, f0, tol=tol,
                        max_iter=max_iter)
        jax.block_until_ready(res)
        solve_time = time.perf_counter() - t0

        b = bias_from_solution(res, y, train_mask, ds.C)
        pred = predict(K[test_idx], y, res.alpha, b)
        correct = int(jnp.sum(pred == y[test_idx]))
        obj = float(dual_objective(K, y, res.alpha))

        folds.append(FoldStat(
            fold=h, seed_from=seed_from, n_iter=int(res.n_iter),
            init_time=init_time, solve_time=solve_time,
            acc_correct=correct, acc_total=int(test_idx.shape[0]),
            objective=obj, converged=bool(res.converged)))
        results[h] = res

        if checkpoint_manager is not None:
            checkpoint_manager.save(
                h, {"alpha": res.alpha, "f": res.f, "n_iter": res.n_iter,
                    "converged": res.converged, "b_up": res.b_up,
                    "b_low": res.b_low},
                extra_meta={"fold": h, "method": method, "k": k,
                            "dataset": ds.name}, blocking=False)

    if checkpoint_manager is not None:
        checkpoint_manager.wait()
    return CVReport(dataset=ds.name, method=method, k=k, n=n,
                    kernel_time=kernel_time, folds=folds)


def _result_from_tree(tree):
    from repro.svm.smo import SMOResult
    return SMOResult(alpha=jnp.asarray(tree["alpha"]), f=jnp.asarray(tree["f"]),
                     n_iter=jnp.asarray(tree["n_iter"]),
                     converged=jnp.asarray(tree["converged"]),
                     b_up=jnp.asarray(tree["b_up"]),
                     b_low=jnp.asarray(tree["b_low"]))


def run_loo(ds: SVMDataset, method: str = "sir", rounds: int | None = None,
            tol: float = 1e-3, max_iter: int = 2_000_000,
            seed: int = 0) -> dict:
    """Leave-one-out CV (paper suppl. Fig. 2). AVG/TOP seed every round from
    the full-data SVM; ATO/MIR/SIR chain round h from round h-1 (T = the
    instance returned, R = the instance removed); cold starts from zero."""
    X = jnp.asarray(ds.X)
    y = jnp.asarray(ds.y, jnp.float64)
    n = ds.n
    rounds = n if rounds is None else min(rounds, n)

    t_start = time.perf_counter()
    K = kernel_matrix(X, X, kind="rbf", gamma=ds.gamma)
    # full-data SVM (shared by AVG/TOP; also round -1 for the chain methods)
    full = smo_solve(K, y, jnp.ones(n, bool), ds.C, jnp.zeros(n, K.dtype),
                     -y, tol=tol, max_iter=max_iter)
    base_iters = int(full.n_iter)

    total_iters, correct = 0, 0
    prev = full
    prev_t = None  # index held out in the previous round (chain methods)
    order = np.arange(rounds)
    for t in order:
        t_j = jnp.asarray(t)
        mask = jnp.ones(n, bool).at[t_j].set(False)
        if method == "cold":
            alpha0, f0 = jnp.zeros(n, K.dtype), -y
        elif method in ("avg", "top"):
            fn = seeding.avg_seed_loo if method == "avg" else seeding.top_seed_loo
            alpha0 = fn(K, y, ds.C, full.alpha, t_j)
            f0 = init_f(K, y, alpha0)
        else:  # chain: ato / mir / sir
            if prev_t is None:
                # first round: remove t from the full SVM (AVG-style entry)
                alpha0 = seeding.avg_seed_loo(K, y, ds.C, full.alpha, t_j)
            else:
                S = jnp.asarray(np.delete(np.arange(n), [prev_t, t]))
                alpha0 = seeding.SEEDERS[method](
                    K, y, ds.C, prev, S, jnp.asarray([t]),
                    jnp.asarray([prev_t]))
            f0 = init_f(K, y, alpha0)
        res = smo_solve(K, y, mask, ds.C, alpha0, f0, tol=tol,
                        max_iter=max_iter)
        total_iters += int(res.n_iter)
        b = bias_from_solution(res, y, mask, ds.C)
        pred = predict(K[t_j][None, :], y, res.alpha, b)
        correct += int(pred[0] == y[t_j])
        prev, prev_t = res, t
    elapsed = time.perf_counter() - t_start
    return {"dataset": ds.name, "method": method, "rounds": rounds,
            "base_iterations": base_iters, "iterations": total_iters,
            "elapsed_s": round(elapsed, 4),
            "accuracy": round(correct / rounds, 4)}
