"""k-fold cross-validation drivers — thin plan builders over the Study API.

Reproduces the paper's experimental protocol: fold 0 starts cold; fold h>0
warm-starts from the most recent completed fold via the chosen seeder. Each
driver DECLARES that structure as a ``repro.core.study.Plan`` — lanes with
seed dependencies carrying named transforms — and ``run_plan`` executes it
on the lane pool; the drivers keep their historical signatures, record
formats and (bit-identical) outputs.

``run_cv`` is also the fault-tolerance unit, at two granularities:

* fold-level (always on with a checkpoint manager): each completed fold is
  checkpointed (fold index + alpha + f) from the pool's per-lane
  retirement callback, so a restarted job re-seeds from the last completed
  fold — the paper's own mechanism doubles as the recovery path. On
  restore, EVERY retained done record is loaded: the resumed report covers
  the pre-crash folds (``FoldStat.restored``) so its totals match an
  uninterrupted run, or ``CVReport.partial`` flags the gap when retention
  GC dropped some;
* chunk-level (opt-in via ``chunk_iters``): the pool's per-lane chunk hook
  snapshots (alpha, f, n_iter) every ``checkpoint_every`` chunks *inside*
  a fold, so recovery no longer loses an in-flight fold — the restarted
  solve resumes the exact iterate sequence (bit-identical fixed point).

Straggler policy: ``strict`` (paper semantics — always seed from fold h-1)
or ``best_available`` (seed from the nearest *completed* fold; lets the
scheduler keep going when a fold is slow/lost; still bit-compatible results
because seeding never changes the fixed point).

``run_cv_batched`` executes independent (cold) folds concurrently through
the pool's repacked schedule; ``run_loo`` chains (or fans out) the
leave-one-out rounds through the same plan machinery — both get repacked
dispatch and mid-study checkpoint/resume from the shared entry point.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import seeding
from repro.core.study import Plan, StudyCheckpoint, run_plan
from repro.data.svm_suite import SVMDataset, kfold_chunks
from repro.svm import (DenseKernel, PallasRBF, bias_from_solution,
                       dual_objective, kernel_matrix, predict,
                       smo_solve_batched)

# step numbering inside a checkpoint directory: fold h's mid-fold chunk
# snapshots live at h*_FOLD_STRIDE + 1 + chunk, its completion record at
# (h+1)*_FOLD_STRIDE — monotone in (fold, chunk), so ``latest_step`` always
# points at the furthest progress.
_FOLD_STRIDE = 1_000_000
# run_cv_batched's mid-batch snapshots live at _BATCH_BASE + chunk: far
# above any run_cv step (k*_FOLD_STRIDE), so the two record kinds can share
# a directory without step collisions (save() replaces an existing step
# dir, so a collision would silently clobber the other run's checkpoint).
# Study records (retain_class "study") start at study.STUDY_BASE, above
# both — see DESIGN.md §Study API for the full key scheme.
_BATCH_BASE = _FOLD_STRIDE ** 2


@dataclasses.dataclass
class FoldStat:
    fold: int
    seed_from: int          # which fold's solution seeded this one (-1 = cold)
    n_iter: int
    init_time: float        # seeding + f-recompute (the paper's "init.")
    solve_time: float       # SMO time (the paper's "the rest": train part)
    acc_correct: int
    acc_total: int
    objective: float
    converged: bool
    restored: bool = False  # rebuilt from a checkpoint (times then read 0.0)


@dataclasses.dataclass
class CVReport:
    dataset: str
    method: str
    k: int
    n: int
    kernel_time: float
    folds: list[FoldStat]
    #: lane-pool width stats (mean/peak live width, program count; with
    #: shrinking, the shrink-chunk count and mean active fraction) from the
    #: run's pool; None for the plain-batched schedule, which bypasses it
    occupancy: dict | None = None

    @property
    def total_iterations(self) -> int:
        return int(sum(f.n_iter for f in self.folds))

    @property
    def total_init_time(self) -> float:
        return float(sum(f.init_time for f in self.folds))

    @property
    def total_solve_time(self) -> float:
        return float(sum(f.solve_time for f in self.folds))

    @property
    def accuracy(self) -> float:
        c = sum(f.acc_correct for f in self.folds)
        t = sum(f.acc_total for f in self.folds)
        return c / max(t, 1)

    @property
    def partial(self) -> bool:
        """True when the report does not cover every fold — a resumed run
        whose earlier done-records were retention-GC'd. Totals/accuracy then
        aggregate fewer than k folds and are NOT comparable to a full run."""
        return sorted(f.fold for f in self.folds) != list(range(self.k))

    def row(self) -> dict:
        return {"dataset": self.dataset, "method": self.method, "k": self.k,
                "iterations": self.total_iterations,
                "init_s": round(self.total_init_time, 4),
                "solve_s": round(self.total_solve_time, 4),
                "total_s": round(self.total_init_time + self.total_solve_time
                                 + self.kernel_time, 4),
                "accuracy": round(self.accuracy, 4)}


def _transition_idx(chunks: np.ndarray, g: int, h: int):
    """Index sets for seeding fold h from fold g's solution.

    Previous train set = all \\ chunk[g]; new train set = all \\ chunk[h]:
    T (added) = chunk[g], R (removed) = chunk[h], S = the rest.
    """
    k = chunks.shape[0]
    S = np.concatenate([chunks[j] for j in range(k) if j not in (g, h)])
    return jnp.asarray(S), jnp.asarray(chunks[h]), jnp.asarray(chunks[g])


def _eval_fold(K, y, chunks, h, res, C) -> tuple[int, int, float]:
    """(acc_correct, acc_total, objective) of fold h's held-out chunk —
    the one evaluation path shared by the live CV loop, the batched driver
    and the checkpoint-restore rebuild, so they cannot drift apart."""
    test_idx = jnp.asarray(chunks[h])
    train_mask = jnp.ones(chunks.size, bool).at[test_idx].set(False)
    b = bias_from_solution(res, y, train_mask, C)
    pred = predict(K[test_idx], y, res.alpha, b)
    return (int(jnp.sum(pred == y[test_idx])), int(test_idx.shape[0]),
            float(dual_objective(K, y, res.alpha)))


def _eval_fold_rows(source, y, chunks, h, res, C) -> tuple[int, int, float]:
    """``_eval_fold`` for row-streaming sources: the test-chunk kernel rows
    come from ``rows_at`` and the dual objective's quadratic term from the
    streaming ``matvec`` — no (n, n) matrix is ever resident."""
    test_idx = jnp.asarray(chunks[h])
    train_mask = jnp.ones(chunks.size, bool).at[test_idx].set(False)
    b = bias_from_solution(res, y, train_mask, C)
    pred = predict(source.rows_at(test_idx), y, res.alpha, b)
    v = res.alpha * y
    obj = jnp.sum(res.alpha) - 0.5 * jnp.dot(v, source.matvec(v))
    return (int(jnp.sum(pred == y[test_idx])), int(test_idx.shape[0]),
            float(obj))


def _fold_masks(chunks: np.ndarray) -> np.ndarray:
    """(k, n) boolean train masks; row h is True off fold h's test chunk."""
    k, n = chunks.shape[0], chunks.size
    masks = np.ones((k, n), bool)
    for h in range(k):
        masks[h, chunks[h]] = False
    return masks


def run_cv(ds: SVMDataset, k: int = 10, method: str = "sir",
           tol: float = 1e-3, max_iter: int = 5_000_000, seed: int = 0,
           checkpoint_manager=None, straggler_policy: str = "strict",
           unavailable_folds: frozenset[int] = frozenset(),
           kernel_backend: str = "jnp", chunk_iters: int | None = None,
           checkpoint_every: int = 1, shrink_every: int | str = 0,
           shrink_quantum: int = 128, shrink_caps=None,
           shrink_on_seed: bool = True) -> CVReport:
    """Run alpha-seeded k-fold CV. ``unavailable_folds`` simulates stragglers/
    failures: those folds' results are not used as seeds (best_available
    policy then falls back to the nearest earlier completed fold).

    ``chunk_iters`` switches the solver to chunked dispatch; with a
    checkpoint manager attached, every ``checkpoint_every``-th chunk is
    snapshotted so a crash mid-fold resumes inside the fold instead of
    replaying it from its seed.

    The fold chain is one Study plan: restored folds enter as given
    results, live fold h is a lane whose seed dependency carries the
    ``"fold"`` transform (and an ``after`` ordering edge on fold h-1, so
    the paper's sequential protocol — and the mid-fold checkpoint cadence
    that assumes one in-flight fold — is preserved even for independent
    cold folds; the concurrent schedules live in ``run_cv_batched`` and
    ``run_grid``).

    ``shrink_every`` enables active-set shrinking inside each fold's solve
    (DESIGN.md §Shrinking); 0 (default) keeps every iterate bit-identical
    to today. Incompatible with mid-fold chunk checkpointing: run_cv's
    legacy mid records carry only (alpha, f, n_iter), not the shrink
    ledger, so a resume could not re-enter the compact subproblem —
    study-keyed drivers (``run_cv_batched``, ``run_grid``) checkpoint the
    ledger and support both together."""
    seeding.SEEDERS[method]   # validate the method name up front
    if shrink_every and checkpoint_manager is not None \
            and chunk_iters is not None:
        raise ValueError(
            "run_cv mid-fold checkpoints do not record the shrink ledger; "
            "use shrink_every=0 here, drop chunk_iters, or switch to a "
            "study-keyed driver (run_cv_batched / run_grid)")
    X = jnp.asarray(ds.X)
    y = jnp.asarray(ds.y, jnp.float64)

    chunks = kfold_chunks(ds.n, k, seed=seed)
    n = chunks.size  # padded n (multiple of k)
    # slice X to the k-fold truncation BEFORE the kernel call — computing
    # the full (N, N) matrix and slicing after wastes O(N^2 - n^2) work,
    # and run_grid's KernelSpec sources build their kernels this way (the
    # two slice orders differ in final bits at some shapes, and grid cells
    # must stay bit-identical to run_cv)
    t0 = time.perf_counter()
    K = kernel_matrix(X[:n], X[:n], kind="rbf", gamma=ds.gamma,
                      backend=kernel_backend)
    K.block_until_ready()
    kernel_time = time.perf_counter() - t0
    y = y[:n]
    masks = jnp.asarray(_fold_masks(chunks))

    results: dict[int, object] = {}
    restored_meta: dict[int, dict] = {}
    folds: list[FoldStat] = []
    start_fold = 0
    resume = None   # (alpha, f, n_iter, seed_from) of an in-flight fold

    if checkpoint_manager is not None:
        # run_cv's records all live below _BATCH_BASE; batch/study records
        # (keyed by lane id, resumable only through run_plan) are excluded
        # from BOTH the loop and the "latest" computation — a shared
        # directory must not make run_cv treat its own newest mid snapshot
        # as stale just because a batch record outranks it numerically.
        cv_steps = [s for s in checkpoint_manager.all_steps()
                    if s < _BATCH_BASE]
        latest = cv_steps[-1] if cv_steps else None
        # restore EVERY retained done record, not just the latest: the
        # returned report must account for pre-crash folds (else its
        # total_iterations/accuracy silently disagree with an uninterrupted
        # run), and the strict straggler policy needs fold h-1 in
        # ``results`` to seed fold h. Done records live at
        # (fold+1)*_FOLD_STRIDE unconditionally — chunked and unchunked runs
        # share the numbering, so either kind can resume the other. Mid
        # snapshots (step % _FOLD_STRIDE != 0) are stale unless latest.
        for s in cv_steps:
            if s % _FOLD_STRIDE != 0 and s != latest:
                continue
            step, tree, extra = checkpoint_manager.restore(step=s)
            # a checkpoint is only resumable into the SAME run: a different
            # partition (k/dataset/seed) misaligns the fold masks, and
            # resuming a mid-fold snapshot under a different
            # method/partition would silently converge to a wrong but
            # "converged" fixed point. A done record tolerates a method
            # change (seeding never moves the fixed point); a mid snapshot
            # IS the method's trajectory, so it doesn't.
            want = {"k": k, "dataset": ds.name, "seed": seed}
            if extra.get("phase") == "mid":
                want["method"] = method
            got = {key: extra.get(key) for key in want}
            if got != want:
                raise ValueError(
                    f"checkpoint at step {step} belongs to run {got}, cannot "
                    f"resume it as {want}; point the manager at a fresh "
                    "directory or delete the stale checkpoints")
            if extra.get("phase") == "mid":   # only possible for the latest
                start_fold = extra["fold"]
                resume = (jnp.asarray(tree["alpha"]), jnp.asarray(tree["f"]),
                          int(tree["n_iter"]), extra["seed_from"])
            else:
                results[extra["fold"]] = _result_from_tree(tree)
                restored_meta[extra["fold"]] = extra
                start_fold = max(start_fold, extra["fold"] + 1)

    # rebuild FoldStats for the restored folds so the report covers them
    # (per-fold timings are not checkpointed and read 0.0; ``restored``
    # marks them) — but ONLY for records written under the SAME method:
    # a done record from another method is a valid seed (the fixed point is
    # method-independent) yet its n_iter is that method's trajectory, and
    # republishing it under this report's label would fabricate a
    # per-method iteration count (the paper's headline metric). Skipped
    # folds leave a gap that ``report.partial`` flags.
    for h in sorted(results):
        if restored_meta[h].get("method") != method:
            continue
        res = results[h]
        correct, total, obj = _eval_fold(K, y, chunks, h, res, ds.C)
        folds.append(FoldStat(
            fold=h, seed_from=restored_meta[h].get("seed_from", -1),
            n_iter=int(res.n_iter), init_time=0.0, solve_time=0.0,
            acc_correct=correct, acc_total=total, objective=obj,
            converged=bool(res.converged), restored=True))

    # ---- declare the fold chain as a plan ----
    plan = Plan(sources={"cv": DenseKernel(K)}, y=y, tol=tol,
                chunk_iters=chunk_iters if chunk_iters is not None
                else max_iter,
                shrink_every=shrink_every, shrink_quantum=shrink_quantum,
                shrink_caps=shrink_caps, shrink_on_seed=shrink_on_seed)
    for g in sorted(results):
        plan.lane(g, result=results[g])

    # the seed-fold choice (straggler policy) is deterministic: live folds
    # execute in order (the ``after`` chain), so fold h sees exactly the
    # restored folds plus every earlier live fold as completed
    seed_froms: dict[int, int] = {}
    base_counts: dict[int, int] = {}
    done_folds = sorted(results)
    prev_lane = None
    zeros = jnp.zeros(n, K.dtype)
    for h in range(start_fold, k):
        avail = [g for g in done_folds if g not in unavailable_folds]
        if resume is not None and h == start_fold:
            seed_from = resume[3]
        elif h == 0 or method == "cold" or not avail:
            seed_from = -1
        elif straggler_policy == "strict":
            seed_from = h - 1 if (h - 1) in avail else -1
        else:  # best_available: nearest completed fold
            seed_from = min(avail, key=lambda g: abs(h - g))
        seed_froms[h] = seed_from
        base_counts[h] = 0

        common = dict(train_mask=masks[h], C=ds.C, max_iter=max_iter,
                      after=prev_lane)
        if resume is not None and h == start_fold:
            alpha0, f0, n_iter0, _ = resume
            base_counts[h] = (n_iter0 // chunk_iters
                              if chunk_iters is not None else 0)
            plan.lane(h, alpha0=alpha0, f0=f0, n_iter0=n_iter0, **common)
        elif seed_from < 0:
            plan.lane(h, alpha0=zeros, f0=-y, **common)
        else:
            S_idx, R_idx, T_idx = _transition_idx(chunks, seed_from, h)
            plan.lane(h, dep=seed_from, transform="fold",
                      params=dict(method=method, S_idx=S_idx, R_idx=R_idx,
                                  T_idx=T_idx), **common)
        done_folds.append(h)
        prev_lane = h

    # ---- checkpoint hooks: run_cv keeps its own record formats ----
    on_lane_chunk = None
    if checkpoint_manager is not None and chunk_iters is not None:
        # seed the chunk counter from the restored n_iter so step numbers
        # reflect ABSOLUTE fold progress: a resumed run's snapshots must
        # outnumber the pre-crash ones, or latest_step()/retention-GC
        # would keep resurrecting the stale pre-crash snapshot forever
        counters = dict(base_counts)

        def on_lane_chunk(h, state):
            counters[h] += 1
            if counters[h] % checkpoint_every:
                return
            step = h * _FOLD_STRIDE + min(counters[h], _FOLD_STRIDE - 2) + 1
            # mid snapshots GC separately from done records: they are
            # frequent and superseded by the next one, and must never
            # evict the done records the resume path depends on
            checkpoint_manager.save(
                step, {"alpha": state.alpha, "f": state.f,
                       "n_iter": state.n_iter},
                extra_meta={"phase": "mid", "fold": h,
                            "seed_from": seed_froms[h], "method": method,
                            "k": k, "dataset": ds.name, "seed": seed},
                blocking=False, retain_class="mid")

    on_result = None
    if checkpoint_manager is not None:
        def on_result(h, res):
            # strided numbering UNCONDITIONALLY: unchunked runs used to save
            # fold h at step h while every reader assumed (h+1)*_FOLD_STRIDE,
            # so a later resume with chunk_iters set pointed at nonexistent
            # steps and silently degraded strict seeding to cold
            checkpoint_manager.save(
                (h + 1) * _FOLD_STRIDE,
                {"alpha": res.alpha, "f": res.f, "n_iter": res.n_iter,
                 "converged": res.converged, "b_up": res.b_up,
                 "b_low": res.b_low},
                extra_meta={"phase": "done", "fold": h,
                            "seed_from": seed_froms[h], "method": method,
                            "k": k, "dataset": ds.name, "seed": seed},
                blocking=False, retain_class="done")

    sres = run_plan(plan, on_result=on_result, on_lane_chunk=on_lane_chunk)

    for h in range(start_fold, k):
        res = sres.results[h]
        stat = sres.stats[h]
        correct, total, obj = _eval_fold(K, y, chunks, h, res, ds.C)
        folds.append(FoldStat(
            fold=h, seed_from=seed_froms[h], n_iter=stat.n_iter,
            init_time=stat.seed_s, solve_time=stat.solve_s,
            acc_correct=correct, acc_total=total,
            objective=obj, converged=stat.converged))

    if checkpoint_manager is not None:
        checkpoint_manager.wait()
    return CVReport(dataset=ds.name, method=method, k=k, n=n,
                    kernel_time=kernel_time, folds=folds,
                    occupancy=sres.occupancy)


def run_cv_batched(ds: SVMDataset, k: int = 10, tol: float = 1e-3,
                   max_iter: int = 5_000_000, seed: int = 0,
                   kernel_backend: str = "jnp", chunk_iters: int = 4096,
                   schedule: str = "repacked", lane_quantum: int = 4,
                   max_width: int | None = None,
                   source_backend: str = "dense", checkpoint_manager=None,
                   checkpoint_every: int = 1, shrink_every: int | str = 0,
                   shrink_quantum: int = 128, shrink_caps=None,
                   shrink_on_seed: bool = True) -> CVReport:
    """Cold k-fold CV with all folds solved concurrently: independent
    solves are a batch, not a loop.

    ``schedule`` picks the dispatch strategy:

    * ``"repacked"`` (default, method "cold_batched_repacked") — the folds
      are a k-lane plan executed by ``run_plan`` on the lane pool:
      converged folds retire between chunks, the live batch is compacted
      (bucketed widths) and the dispatch width is capped by the backend
      cost model (``max_width``; on CPU the default is a width-1
      round-robin through the sequential program), so device work tracks
      ``sum_h n_iter_h`` (DESIGN.md §Lane scheduler / §Study API);
    * ``"batched"`` (method "cold_batched") — the fixed-width
      ``engine.solve_batched`` batch kept as the repack baseline.

    ``source_backend="pallas_rbf"`` (repacked schedule only, method
    "cold_pallas") swaps the dense precomputed matrix for the
    row-streaming ``PallasRBF`` source: no (n, n) kernel is ever built
    (``kernel_time`` then covers only the O(n·d) row-norm precompute),
    the folds solve under WSS-1 with the fused kernel-row + f-update
    Pallas step, and held-out evaluation streams test-chunk rows via
    ``rows_at`` / the dual objective via ``matvec``. Alphas match the
    dense WSS-1 solve bit-for-bit in interpret mode (DESIGN.md §Pallas
    sources); they differ from the default WSS-2 methods' iterate
    sequence, as any WSS choice does.

    Both produce the same per-fold fixed points as ``run_cv(method="cold")``
    (bit-identical alphas — the engine body is shared); only the schedule
    differs. Seeded chains stay sequential by nature — their concurrency
    axis is the hyper-parameter grid (see ``repro.core.grid``).

    With a checkpoint manager (repacked schedule only), every
    ``checkpoint_every``-th chunk snapshots ALL lanes' (alpha, f, n_iter,
    done) keyed by **original fold id** — not packed position — as one
    ``phase: "batch_mid"`` record (retain_class "batch"), so a crashed
    mid-batch run resumes each fold's exact iterate sequence regardless of
    how lanes were packed at the crash."""
    if schedule not in ("repacked", "batched"):
        raise ValueError(f"unknown schedule {schedule!r}")
    if checkpoint_manager is not None and schedule != "repacked":
        raise ValueError("mid-batch checkpointing requires the repacked "
                         "schedule (snapshots are keyed by scheduler lane)")
    if source_backend not in ("dense", "pallas_rbf"):
        raise ValueError(f"unknown source_backend {source_backend!r}")
    if source_backend == "pallas_rbf" and schedule != "repacked":
        raise ValueError("source_backend='pallas_rbf' requires the repacked "
                         "schedule: the streaming source runs through the "
                         "lane pool, not engine.solve_batched on a matrix")
    if shrink_every and schedule != "repacked":
        raise ValueError("shrink_every requires the repacked schedule: "
                         "shrinking is a lane-pool transformation, not an "
                         "engine.solve_batched feature")
    X = jnp.asarray(ds.X)
    y = jnp.asarray(ds.y, jnp.float64)

    chunks = kfold_chunks(ds.n, k, seed=seed)
    n = chunks.size
    # slice before the kernel call (see run_cv): no wasted (N, N) compute,
    # bit-aligned with run_grid's KernelSpec sources
    t0 = time.perf_counter()
    if source_backend == "pallas_rbf":
        K = None
        source = PallasRBF(X[:n], ds.gamma)
        source.sq_norms.block_until_ready()
    else:
        K = kernel_matrix(X[:n], X[:n], kind="rbf", gamma=ds.gamma,
                          backend=kernel_backend)
        K.block_until_ready()
        source = DenseKernel(K)
    kernel_time = time.perf_counter() - t0
    y = y[:n]
    masks = jnp.asarray(_fold_masks(chunks))

    if schedule == "batched":
        t0 = time.perf_counter()
        res = smo_solve_batched(K, y, masks, ds.C, jnp.zeros((k, n), K.dtype),
                                jnp.tile(-y, (k, 1)), tol=tol,
                                max_iter=max_iter, chunk_iters=chunk_iters)
        jax.block_until_ready(res)
        solve_time = time.perf_counter() - t0

        folds = []
        for h in range(k):
            fold_res = jax.tree.map(lambda a: a[h], res)
            correct, total, obj = _eval_fold(K, y, chunks, h, fold_res, ds.C)
            folds.append(FoldStat(
                fold=h, seed_from=-1, n_iter=int(fold_res.n_iter),
                init_time=0.0, solve_time=solve_time / k,
                acc_correct=correct, acc_total=total, objective=obj,
                converged=bool(fold_res.converged)))
        return CVReport(dataset=ds.name, method="cold_batched", k=k, n=n,
                        kernel_time=kernel_time, folds=folds)

    # ---- repacked schedule: a k-lane cold plan ----
    method = ("cold_pallas" if source_backend == "pallas_rbf"
              else "cold_batched_repacked")
    plan = Plan(sources={"cv": source}, y=y, tol=tol,
                wss="1" if source_backend == "pallas_rbf" else "2",
                chunk_iters=chunk_iters, lane_quantum=lane_quantum,
                max_width=max_width,
                shrink_every=shrink_every, shrink_quantum=shrink_quantum,
                shrink_caps=shrink_caps, shrink_on_seed=shrink_on_seed)
    zeros = jnp.zeros(n, source.dtype)
    for h in range(k):
        plan.lane(h, train_mask=masks[h], C=ds.C, alpha0=zeros, f0=-y,
                  max_iter=max_iter)

    checkpoint = None
    if checkpoint_manager is not None:
        # tol and max_iter are part of the run identity: retired lanes
        # carry fixed points at the snapshot's tolerance/budget, so
        # resuming under different solver parameters would mix convergence
        # criteria across lanes
        checkpoint = StudyCheckpoint(
            manager=checkpoint_manager, every=checkpoint_every,
            retain_class="batch", phase="batch_mid", base_step=_BATCH_BASE,
            meta={"k": k, "dataset": ds.name, "seed": seed, "tol": tol,
                  "max_iter": max_iter, "method": method})

    t0 = time.perf_counter()
    sres = run_plan(plan, checkpoint=checkpoint)
    solve_time = time.perf_counter() - t0

    done_at_start = sres.restored
    live = max(k - len(done_at_start), 1)
    folds = []
    for h in range(k):
        res = sres.results[h]
        correct, total, obj = (
            _eval_fold(K, y, chunks, h, res, ds.C) if K is not None
            else _eval_fold_rows(source, y, chunks, h, res, ds.C))
        folds.append(FoldStat(
            fold=h, seed_from=-1, n_iter=int(res.n_iter),
            init_time=0.0,
            solve_time=0.0 if h in done_at_start else solve_time / live,
            acc_correct=correct, acc_total=total, objective=obj,
            converged=bool(res.converged), restored=h in done_at_start))
    return CVReport(dataset=ds.name, method=method, k=k,
                    n=n, kernel_time=kernel_time, folds=folds,
                    occupancy=sres.occupancy)


def _result_from_tree(tree):
    from repro.svm.smo import SMOResult
    return SMOResult(alpha=jnp.asarray(tree["alpha"]), f=jnp.asarray(tree["f"]),
                     n_iter=jnp.asarray(tree["n_iter"]),
                     converged=jnp.asarray(tree["converged"]),
                     b_up=jnp.asarray(tree["b_up"]),
                     b_low=jnp.asarray(tree["b_low"]))


def run_loo(ds: SVMDataset, method: str = "sir", rounds: int | None = None,
            tol: float = 1e-3, max_iter: int = 2_000_000, seed: int = 0,
            chunk_iters: int = 4096, max_width: int | None = None,
            checkpoint_manager=None, checkpoint_every: int = 1) -> dict:
    """Leave-one-out CV (paper suppl. Fig. 2). AVG/TOP seed every round from
    the full-data SVM; ATO/MIR/SIR chain round h from round h-1 (T = the
    instance returned, R = the instance removed); cold starts from zero.

    The protocol is one plan: the full-data solve is a lane, chain rounds
    are dependency edges carrying the ``"fold"`` transform, and AVG/TOP
    rounds all depend on the full lane only — so those fan out through the
    pool's repacked dispatch instead of the old sequential-only loop, and
    a checkpoint manager gives mid-study resume (plan-keyed ``"study"``
    records) for free."""
    if method not in ("cold", "avg", "top", "ato", "mir", "sir"):
        raise ValueError(f"unknown LOO method {method!r}")
    X = jnp.asarray(ds.X)
    y = jnp.asarray(ds.y, jnp.float64)
    n = ds.n
    rounds = n if rounds is None else min(rounds, n)

    t_start = time.perf_counter()
    K = kernel_matrix(X, X, kind="rbf", gamma=ds.gamma)

    plan = Plan(sources={"loo": DenseKernel(K)}, y=y, tol=tol,
                chunk_iters=chunk_iters, max_width=max_width)
    zeros = jnp.zeros(n, K.dtype)
    # full-data SVM (shared by AVG/TOP; also round -1 for the chain methods)
    plan.lane("full", train_mask=jnp.ones(n, bool), C=ds.C, alpha0=zeros,
              f0=-y, max_iter=max_iter)
    for t in range(rounds):
        mask = jnp.ones(n, bool).at[t].set(False)
        common = dict(train_mask=mask, C=ds.C, max_iter=max_iter)
        if method == "cold":
            plan.lane(t, alpha0=zeros, f0=-y, **common)
        elif method in ("avg", "top"):
            plan.lane(t, dep="full", transform=f"loo_{method}",
                      params={"t": t}, **common)
        elif t == 0:
            # first round: remove t from the full SVM (AVG-style entry)
            plan.lane(0, dep="full", transform="loo_avg", params={"t": 0},
                      **common)
        else:
            S = np.delete(np.arange(n), [t - 1, t])
            plan.lane(t, dep=t - 1, transform="fold",
                      params=dict(method=method, S_idx=jnp.asarray(S),
                                  R_idx=jnp.asarray([t]),
                                  T_idx=jnp.asarray([t - 1])), **common)
        plan.evaluate(t, np.asarray([t]))

    checkpoint = None
    if checkpoint_manager is not None:
        checkpoint = StudyCheckpoint(
            manager=checkpoint_manager, every=checkpoint_every,
            meta={"bench": "loo", "dataset": ds.name, "method": method,
                  "rounds": rounds, "seed": seed, "tol": tol,
                  "max_iter": max_iter})

    sres = run_plan(plan, checkpoint=checkpoint)
    total_iters = sum(sres.stats[t].n_iter for t in range(rounds))
    correct = sum(sres.evals[t][0] for t in range(rounds))
    elapsed = time.perf_counter() - t_start
    return {"dataset": ds.name, "method": method, "rounds": rounds,
            "base_iterations": sres.stats["full"].n_iter,
            "iterations": total_iters,
            "elapsed_s": round(elapsed, 4),
            "accuracy": round(correct / rounds, 4)}
