"""The paper's primary contribution: alpha-seeded SVM k-fold cross-validation.

Wen et al., AAAI 2017 — three seeding algorithms (ATO, MIR, SIR) that reuse
fold h's dual solution to warm-start fold h+1, plus the two prior
leave-one-out baselines (AVG, TOP) and the cold-start reference.
"""
from repro.core.seeding import (  # noqa: F401
    cold_seed, mir_seed, sir_seed, ato_seed, ato_seed_ref, ato_seed_batch,
    avg_seed_loo, top_seed_loo, water_fill, repair_equality, SEEDERS,
)
from repro.core.study import (  # noqa: F401
    EvalSpec, LaneSpec, LaneStat, Plan, StudyCheckpoint, StudyResult,
    run_plan)
from repro.core.cv import run_cv, run_cv_batched, run_loo, CVReport, FoldStat  # noqa: F401
from repro.core.grid import run_grid, GridCell, GridReport  # noqa: F401
