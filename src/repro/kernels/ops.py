"""Jit'd public wrappers for the Pallas kernels (interpret-mode default on
CPU; pass interpret=False on real TPU)."""
from repro.kernels.flash_attention import flash_attention  # noqa: F401
from repro.kernels.rbf import rbf_kernel_matrix  # noqa: F401
from repro.kernels.smo_step import fused_smo_step  # noqa: F401
from repro.kernels.smo_update import smo_f_update  # noqa: F401
