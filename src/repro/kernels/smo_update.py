"""Pallas TPU kernel: fused SMO rank-2 indicator update.

Each SMO iteration updates every optimality indicator:
f += delta * (K_i - K_j). At scale this is THE per-iteration memory-bound
loop (two kernel-row streams + one read-modify-write stream). The fusion
keeps a single pass over HBM; blocks are (8, 1024)-aligned VPU tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.rbf import auto_interpret


def _fupdate_kernel(f_ref, ki_ref, kj_ref, delta_ref, o_ref):
    o_ref[...] = f_ref[...] + delta_ref[0, 0] * (ki_ref[...] - kj_ref[...])


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def smo_f_update(f, K_i, K_j, delta, *, block: int = 8192,
                 interpret: bool | None = None):
    """f, K_i, K_j: (n,); delta scalar -> updated f.

    ``interpret=None`` auto-detects (Python kernel body on CPU, compiled
    elsewhere) — see :func:`repro.kernels.rbf.auto_interpret`.
    """
    interpret = auto_interpret(interpret)
    n = f.shape[0]
    pad = (-n) % block
    fp = jnp.pad(f, (0, pad))[None, :]
    kip = jnp.pad(K_i, (0, pad))[None, :]
    kjp = jnp.pad(K_j, (0, pad))[None, :]
    d = jnp.asarray(delta, f.dtype).reshape(1, 1)
    out = pl.pallas_call(
        _fupdate_kernel,
        grid=((n + pad) // block,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (0, i)),
            pl.BlockSpec((1, block), lambda i: (0, i)),
            pl.BlockSpec((1, block), lambda i: (0, i)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n + pad), f.dtype),
        interpret=interpret,
    )(fp, kip, kjp, d)
    return out[0, :n]
