"""Pallas TPU kernel: fused WSS-1 kernel-row pair + SMO rank-2 update.

The paper's cost profile (LibSVM spends its time evaluating Gaussian
kernel rows) says the per-iteration hot loop is the pair of rows K_i, K_j
for the maximal-violating pair plus the indicator update
``f += delta * (K_i - K_j)``. A dense source streams three n-vectors from
HBM per iteration (two kernel rows + the f read-modify-write) *after*
having paid n^2 bytes to materialize K. This kernel never forms K at all:
one blocked pass over X computes both rows — the cross-term
``X @ [x_i; x_j]^T`` runs on the MXU over (BM, 2) output tiles with a
BK-chunked contraction accumulated in VMEM scratch, row norms stream in
as (BM, 1) tiles, and the ``exp`` plus the rank-2 f-update fuse on the
VPU at the final contraction step. One HBM stream (X plus two n-vectors)
per iteration, O(n*d) resident bytes instead of O(n^2): the TPU-native
version of ``FusedRBF.rows2``.

Bit-parity contract (the acceptance bar for ``PallasRBF``): with
full-array blocks (``bm=n``, ``bk=d`` — the interpret-mode default) there
is no padding and a single grid step, so the kernel body is exactly the
jnp expression ``f + delta * (exp(-g*d2)[:, 0] - exp(-g*d2)[:, 1])`` that
``FusedRBF`` evaluates — same ops, same shapes, same accumulation order —
and the output is bit-identical, solo and under vmap. Blocked launches
(the compiled TPU configuration) change the contraction split and carry
only the usual allclose guarantee, covered by tests/test_kernels.py.

VMEM per launch at the compiled defaults (bm=512, bk=512, f32):
bm*bk (X tile) + 2*bk (xij) + 4*bm (norms/f/out) + bm*2 acc ~ 1.1 MB,
well under the 16 MB budget; f64 interpret mode doubles it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.rbf import auto_interpret


def _smo_step_kernel(xn_ref, sn2_ref, f_ref, delta_ref, x_ref, xij_ref,
                     o_ref, acc_ref, *, gamma, n_k_steps):
    k_step = pl.program_id(1)
    prod = jnp.dot(x_ref[...], xij_ref[...].T,
                   preferred_element_type=acc_ref.dtype)

    @pl.when(k_step == 0)
    def _init():
        acc_ref[...] = prod

    @pl.when(k_step > 0)
    def _accumulate():
        acc_ref[...] += prod

    @pl.when(k_step == n_k_steps - 1)
    def _finalize():
        d2 = jnp.maximum(xn_ref[...] + sn2_ref[...] - 2.0 * acc_ref[...],
                         0.0)
        K2 = jnp.exp(-gamma * d2)
        o_ref[...] = (f_ref[...] + delta_ref[0, 0]
                      * (K2[:, :1] - K2[:, 1:])).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("gamma", "bm", "bk", "interpret"))
def fused_smo_step(f, X, xij, sq_norms, delta, *, gamma: float,
                   bm: int | None = None, bk: int | None = None,
                   interpret: bool | None = None):
    """One fused SMO step: ``f + delta * (K_i - K_j)`` without rows in HBM.

    ``f`` (n,) indicator vector; ``X`` (n, d) training matrix; ``xij``
    (2, d) the WSS-1 pair's feature rows (gathered by the caller — the
    engine's onehot idiom keeps this sharding-friendly); ``sq_norms`` (n,)
    precomputed row norms of X; ``delta`` the clipped 2-variable step.

    ``bm``/``bk`` default to full-array blocks (n, d): no padding, single
    contraction step, bit-identical to the unblocked jnp expression (the
    interpret-mode parity contract). Pass MXU-aligned blocks on TPU.
    ``interpret=None`` auto-detects the CPU validation path.
    """
    interpret = auto_interpret(interpret)
    n, d = X.shape
    bm = n if bm is None else bm
    bk = d if bk is None else bk
    # norms of the pair rows, computed before any padding so the reduction
    # matches FusedRBF.rows2 verbatim
    acc_dtype = jnp.float64 if X.dtype == jnp.float64 else jnp.float32
    sn2 = jnp.sum(xij * xij, 1)[None].astype(acc_dtype)          # (1, 2)
    pad_n, pad_d = (-n) % bm, (-d) % bk
    # zero feature columns leave cross-terms and norms unchanged; padded
    # rows are sliced off the output
    Xp = jnp.pad(X, ((0, pad_n), (0, pad_d)))
    xijp = jnp.pad(xij, ((0, 0), (0, pad_d)))
    fp = jnp.pad(f, (0, pad_n))[:, None]
    xn = jnp.pad(sq_norms, (0, pad_n))[:, None].astype(acc_dtype)
    N, D = n + pad_n, d + pad_d
    n_k_steps = D // bk

    out = pl.pallas_call(
        functools.partial(_smo_step_kernel, gamma=gamma,
                          n_k_steps=n_k_steps),
        grid=(N // bm, n_k_steps),
        in_specs=[
            pl.BlockSpec((bm, 1), lambda i, k: (i, 0)),    # row norms
            pl.BlockSpec((1, 2), lambda i, k: (0, 0)),     # pair norms
            pl.BlockSpec((bm, 1), lambda i, k: (i, 0)),    # f
            pl.BlockSpec((1, 1), lambda i, k: (0, 0)),     # delta
            pl.BlockSpec((bm, bk), lambda i, k: (i, k)),   # X
            pl.BlockSpec((2, bk), lambda i, k: (0, k)),    # pair rows
        ],
        out_specs=pl.BlockSpec((bm, 1), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, 1), f.dtype),
        scratch_shapes=[pltpu.VMEM((bm, 2), acc_dtype)],
        interpret=interpret,
    )(xn, sn2, fp, jnp.asarray(delta, f.dtype).reshape(1, 1), Xp, xijp)
    return out[:n, 0]
