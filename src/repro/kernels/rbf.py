"""Pallas TPU kernel: tiled RBF kernel-matrix computation.

THE compute hot-spot of the paper's pipeline: LibSVM spends its time
evaluating Gaussian kernel rows; on TPU we compute K = exp(-g*d2(X,Z)) as a
blocked matmul — the cross-term X @ Z^T runs on the MXU over (BM, BN)
output tiles with a BK-chunked contraction accumulated in an f32 VMEM
scratch; row norms stream in as (BM,1)/(1,BN) tiles and the exp() fuses on
the VPU at the final contraction step. This is the TPU-native adaptation of
the paper's kernel-cache design (recompute beats irregular caches on MXU).

Block sizes default to MXU-aligned (128, 128, 512): VMEM footprint
= BM*BK + BK*BN (bf16/f32 inputs) + BM*BN*4 (acc) ~ 0.6 MB << 16 MB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def auto_interpret(interpret: bool | None) -> bool:
    """Resolve an ``interpret=None`` default: interpret mode (kernel body
    run in Python) only when the backend has no Mosaic compiler — i.e. the
    CPU validation path. TPU callers get compiled kernels without passing
    a flag."""
    if interpret is None:
        return jax.default_backend() == "cpu"
    return bool(interpret)


def _rbf_kernel(xn_ref, zn_ref, x_ref, z_ref, o_ref, acc_ref, *, gamma,
                n_k_steps):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], z_ref[...].T,
                            preferred_element_type=acc_ref.dtype)

    @pl.when(k_step == n_k_steps - 1)
    def _finalize():
        d2 = xn_ref[...] + zn_ref[...] - 2.0 * acc_ref[...]
        d2 = jnp.maximum(d2, 0.0)
        o_ref[...] = jnp.exp(-gamma * d2).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("gamma", "bm", "bn", "bk", "interpret"))
def rbf_kernel_matrix(X, Z, gamma: float, *, bm: int = 128, bn: int = 128,
                      bk: int = 512, interpret: bool | None = None):
    """K[i,j] = exp(-gamma * ||X_i - Z_j||^2); X (n,d), Z (m,d) -> (n,m).

    ``interpret=None`` auto-detects: the kernel body runs in Python on
    CPU (validation mode for this container) and compiles elsewhere.
    """
    interpret = auto_interpret(interpret)
    n, d = X.shape
    m = Z.shape[0]
    pad_n = (-n) % bm
    pad_m = (-m) % bn
    pad_d = (-d) % bk
    Xp = jnp.pad(X, ((0, pad_n), (0, pad_d)))
    Zp = jnp.pad(Z, ((0, pad_m), (0, pad_d)))
    # accumulate in f64 only for f64 inputs (TPU path is f32; interpret
    # mode validates the f64 LibSVM-parity path bit-accurately)
    acc_dtype = jnp.float64 if X.dtype == jnp.float64 else jnp.float32
    xn = jnp.sum(Xp * Xp, -1, keepdims=True).astype(acc_dtype)    # (N,1)
    zn = jnp.sum(Zp * Zp, -1, keepdims=True).T.astype(acc_dtype)  # (1,M)
    N, M, D = n + pad_n, m + pad_m, d + pad_d
    n_k_steps = D // bk

    out = pl.pallas_call(
        functools.partial(_rbf_kernel, gamma=gamma, n_k_steps=n_k_steps),
        grid=(N // bm, M // bn, n_k_steps),
        in_specs=[
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((N, M), X.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), acc_dtype)],
        interpret=interpret,
    )(xn, zn, Xp, Zp)
    return out[:n, :m]
