"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rbf_kernel_matrix_ref(X, Z, gamma):
    xn = jnp.sum(X * X, -1)[:, None]
    zn = jnp.sum(Z * Z, -1)[None, :]
    d2 = jnp.maximum(xn + zn - 2.0 * (X @ Z.T), 0.0)
    return jnp.exp(-gamma * d2)


def flash_attention_ref(q, k, v, *, causal=True, window=None):
    """q,k,v: (B, H, S, D) -> (B, H, S, D); plain softmax attention."""
    S, T = q.shape[2], k.shape[2]
    scores = jnp.einsum("bhsd,bhtd->bhst", q, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(q.shape[-1]).astype(jnp.float32)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", probs.astype(q.dtype), v)


def smo_f_update_ref(f, K_i, K_j, delta):
    """The SMO inner-loop rank-2 indicator update (paper Eq. 2 delta)."""
    return f + delta * (K_i - K_j)


def fused_smo_step_ref(f, X, xij, sq_norms, delta, gamma):
    """Fused pair-rows + rank-2 update: the FusedRBF.rows2 expression."""
    cross = X @ xij.T
    d2 = jnp.maximum(sq_norms[:, None] + jnp.sum(xij * xij, 1)[None]
                     - 2.0 * cross, 0.0)
    K2 = jnp.exp(-gamma * d2)
    return f + delta * (K2[:, 0] - K2[:, 1])
