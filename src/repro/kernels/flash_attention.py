"""Pallas TPU kernel: causal / sliding-window flash attention (forward).

Blockwise online-softmax: the grid is (batch*heads, Sq/BQ, Skv/BK) with the
kv axis innermost; running max m, normalizer l, and the output accumulator
live in VMEM scratch across kv steps. Causal and sliding-window masks are
applied per tile, and fully-masked tiles are skipped by the index map domain
(upper-triangular tiles never run for causal=True).

This is the TPU fast path for every full-attention arch in the zoo; the XLA
einsum path in repro.models.attention is the oracle it is tested against
(interpret mode, shape/dtype sweep in tests/test_kernels.py).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.rbf import auto_interpret

_NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale, bq, bk, n_kv_steps, causal, window, kv_len):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                  # (BQ, D)
    k = k_ref[0]                                  # (BK, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = kpos < kv_len            # padded kv rows never contribute
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, _NEG)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == n_kv_steps - 1)
    def _finalize():
        o_ref[0, ...] = (acc_ref[...]
                         / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention(q, k, v, *, causal=True, window=None, bq=128, bk=128,
                    interpret=None):
    """q,k,v: (B, H, S, D) -> (B, H, S, D). GQA callers broadcast kv heads
    before the call (or pass H=KV groups).

    ``interpret=None`` auto-detects (Python kernel body on CPU, compiled
    elsewhere) — see :func:`repro.kernels.rbf.auto_interpret`.
    """
    interpret = auto_interpret(interpret)
    B, H, S, D = q.shape
    T = k.shape[2]
    pad_q = (-S) % bq
    pad_k = (-T) % bk
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    Sq, Sk = S + pad_q, T + pad_k
    qp = qp.reshape(B * H, Sq, D)
    kp = kp.reshape(B * H, Sk, D)
    vp = vp.reshape(B * H, Sk, D)
    n_kv = Sk // bk
    scale = float(1.0 / math.sqrt(D))

    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, bq=bq, bk=bk,
                          n_kv_steps=n_kv, causal=causal, window=window,
                          kv_len=T),
        grid=(B * H, Sq // bq, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out.reshape(B, H, Sq, D)[:, :, :S]
