"""Mamba-1 selective SSM block (Jamba's mixer).

Training/prefill runs the selective scan as a chunked linear recurrence:
within a chunk the recurrence h_t = a_t * h_{t-1} + b_t is composed with an
associative scan (log-depth, TPU-friendly); chunks are chained with a
lax.scan carry — O(S) work, O(S/chunk) sequential depth, and the hidden
(d_inner x d_state) state tensor is only materialized per chunk (VMEM-sized,
the same blocking a Pallas scan kernel would use).

Decode is the O(1) recurrent step on a (conv_state, ssm_state) cache —
this is why Jamba runs the long_500k cell that full-attention archs skip.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import ParamDef
from repro.sharding import constrain

_CHUNK = 256


def mamba_def(cfg):
    D = cfg.d_model
    Din = cfg.mamba_expand * D
    St, Cv = cfg.mamba_d_state, cfg.mamba_d_conv
    dt_rank = max(D // 16, 1)
    return {
        "in_proj": ParamDef((D, 2 * Din), ("embed", "mlp")),
        "conv_w": ParamDef((Cv, Din), ("conv", "heads_act"), scale=0.5),
        "conv_b": ParamDef((Din,), ("heads_act",), init="zeros"),
        "x_db": ParamDef((Din, dt_rank + 2 * St), ("mlp", None)),
        "dt_proj_w": ParamDef((dt_rank, Din), (None, "mlp"), scale=0.1),
        "dt_proj_b": ParamDef((Din,), ("heads_act",), init="ones", ),
        "A_log": ParamDef((Din, St), ("heads_act", "state"), init="ones"),
        "D": ParamDef((Din,), ("heads_act",), init="ones"),
        "out_proj": ParamDef((Din, D), ("mlp", "embed_tp")),
    }


def _ssm_chunk(carry, xs):
    """Compose the linear recurrence h_t = a_t h_{t-1} + b_t over one chunk
    via associative scan, seeded with the carried state."""
    h0 = carry
    a, b = xs                       # (T, B, Din, St)

    def comb(l, r):
        return (l[0] * r[0], r[0] * l[1] + r[1])

    a_c, b_c = jax.lax.associative_scan(comb, (a, b), axis=0)
    h = a_c * h0[None] + b_c        # (T, B, Din, St)
    return h[-1], h


def mamba_apply(params, x, cfg, *, rules=None, cache=None):
    """x: (B,S,D) -> (y, new_cache). cache = {conv: (B,Cv-1,Din),
    ssm: (B,Din,St)} for decode (S==1)."""
    B, S, D = x.shape
    Din = cfg.mamba_expand * D
    St, Cv = cfg.mamba_d_state, cfg.mamba_d_conv
    dt_rank = max(D // 16, 1)

    xz = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = constrain(xin, ("batch", "seq", "heads_act"), rules)

    # -- causal depthwise conv (width Cv) --
    if cache is None:
        pad = jnp.zeros((B, Cv - 1, Din), x.dtype)
        xpad = jnp.concatenate([pad, xin], 1)
        new_conv = None
    else:
        xpad = jnp.concatenate([cache["conv"], xin], 1)
        new_conv = xpad[:, -(Cv - 1):]
    xc = sum(xpad[:, i:i + S] * params["conv_w"][i] for i in range(Cv))
    xc = jax.nn.silu(xc + params["conv_b"])

    # -- selective parameters --
    dbc = jnp.einsum("bse,ef->bsf", xc, params["x_db"])
    dt = dbc[..., :dt_rank]
    Bp = dbc[..., dt_rank:dt_rank + St]              # (B,S,St)
    Cp = dbc[..., dt_rank + St:]
    dt = jax.nn.softplus(jnp.einsum("bsr,re->bse", dt, params["dt_proj_w"])
                         + params["dt_proj_b"])      # (B,S,Din)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))  # (Din,St)
    dA = jnp.exp(dt.astype(jnp.float32)[..., None] * A)          # (B,S,Din,St)
    dBx = (dt * xc).astype(jnp.float32)[..., None] * Bp.astype(jnp.float32)[:, :, None, :]

    if cache is None:
        # chunked scan over sequence
        Sp = S
        if S % _CHUNK:
            padlen = _CHUNK - S % _CHUNK
            dA = jnp.pad(dA, ((0, 0), (0, padlen), (0, 0), (0, 0)),
                         constant_values=1.0)
            dBx = jnp.pad(dBx, ((0, 0), (0, padlen), (0, 0), (0, 0)))
            Sp = S + padlen
        nch = Sp // _CHUNK
        dA_c = dA.reshape(B, nch, _CHUNK, Din, St).transpose(1, 2, 0, 3, 4)
        dBx_c = dBx.reshape(B, nch, _CHUNK, Din, St).transpose(1, 2, 0, 3, 4)
        h0 = jnp.zeros((B, Din, St), jnp.float32)
        hlast, hs = jax.lax.scan(_ssm_chunk, h0, (dA_c, dBx_c))
        h = hs.transpose(2, 0, 1, 3, 4).reshape(B, Sp, Din, St)[:, :S]
        new_ssm = hlast if cache is not None else None
    else:
        h = cache["ssm"][:, None].astype(jnp.float32) * dA + dBx  # (B,1,Din,St)
        new_ssm = h[:, 0]
    y = jnp.einsum("bsen,bsn->bse", h, Cp.astype(jnp.float32)).astype(x.dtype)
    y = y + xc * params["D"]
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"])
    out = constrain(out, ("batch", "seq", "embed_act"), rules)
    new_cache = None if cache is None else {"conv": new_conv, "ssm": new_ssm}
    return out, new_cache


def mamba_cache_def(cfg, batch):
    Din = cfg.mamba_expand * cfg.d_model
    return {"conv": ParamDef((batch, cfg.mamba_d_conv - 1, Din),
                             ("batch", None, "heads_act"), init="zeros"),
            "ssm": ParamDef((batch, Din, cfg.mamba_d_state),
                            ("batch", "heads_act", "state"), init="zeros",
                            dtype="float32")}
