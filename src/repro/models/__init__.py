from repro.models.params import ParamDef, init_params, abstract_params, param_specs  # noqa: F401
from repro.models.transformer import (  # noqa: F401
    build_model, model_params_def, init_cache, count_params, active_params,
)
