"""Core layers: norms, embeddings, MLPs, rotary embeddings (incl. M-RoPE)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import ParamDef
from repro.sharding import constrain


# ---------------------------------------------------------------- norms ----

def rmsnorm_def(dim):
    return {"scale": ParamDef((dim,), ("embed_act",), init="ones")}


def rmsnorm(params, x, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), -1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


# ----------------------------------------------------------- embeddings ----

def embedding_def(vocab, dim):
    return {"table": ParamDef((vocab, dim), ("vocab", "embed"), scale=1.0)}


def embed(params, tokens, rules=None):
    out = jnp.take(params["table"], tokens, axis=0)
    return constrain(out, ("batch", "seq", "embed_act"), rules)


def unembed(params, x, rules=None):
    """Logits, kept vocab-sharded — the loss is computed WITHOUT gathering
    the full vocab axis (see training.loss.sharded_xent)."""
    logits = jnp.einsum("bsd,vd->bsv", x, params["table"])
    return constrain(logits, ("batch", "seq", "vocab_act"), rules)


# ------------------------------------------------------------------ MLP ----

def mlp_def(dim, hidden):
    return {
        "wi_gate": ParamDef((dim, hidden), ("embed", "mlp")),
        "wi_up": ParamDef((dim, hidden), ("embed", "mlp")),
        "wo": ParamDef((hidden, dim), ("mlp", "embed_tp")),
    }


def mlp(params, x, act="silu", rules=None):
    a = jnp.einsum("bsd,df->bsf", x, params["wi_gate"])
    b = jnp.einsum("bsd,df->bsf", x, params["wi_up"])
    a = constrain(a, ("batch", "seq", "heads_act"), rules)
    h = (jax.nn.silu(a) if act == "silu" else jax.nn.gelu(a)) * b
    out = jnp.einsum("bsf,fd->bsd", h, params["wo"])
    return constrain(out, ("batch", "seq", "embed_act"), rules)


# ----------------------------------------------------------------- RoPE ----

def _rot(x, cos, sin):
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)


def rope(x, positions, theta=10_000.0):
    """x: (B, S, H, D); positions: (B, S) int."""
    d = x.shape[-1]
    half = d // 2
    inv = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    freqs = positions.astype(jnp.float32)[..., None] * inv      # (B,S,half)
    cos = jnp.cos(freqs)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(freqs)[:, :, None, :].astype(x.dtype)
    return _rot(x, cos, sin)


def mrope(x, positions, sections=(16, 24, 24), theta=10_000.0):
    """Qwen2-VL multimodal RoPE. positions: (B, 3, S) for (t, h, w) axes;
    the frequency bands are split across the three position streams."""
    d = x.shape[-1]
    half = d // 2
    assert sum(sections) == half, (sections, half)
    inv = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    freqs = positions.astype(jnp.float32)[..., None] * inv      # (B,3,S,half)
    parts, start = [], 0
    for i, sec in enumerate(sections):
        parts.append(freqs[:, i, :, start:start + sec])
        start += sec
    freqs = jnp.concatenate(parts, -1)                          # (B,S,half)
    cos = jnp.cos(freqs)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(freqs)[:, :, None, :].astype(x.dtype)
    return _rot(x, cos, sin)


def apply_rope(x, positions, cfg):
    if cfg.rope_kind == "none" or positions is None:  # e.g. Jamba: NoPE attn
        return x
    if cfg.rope_kind == "mrope":
        return mrope(x, positions, cfg.mrope_sections, cfg.rope_theta)
    if positions.ndim == 3:       # mrope-shaped positions on a standard arch
        positions = positions[:, 0]
    return rope(x, positions, cfg.rope_theta)
