"""Model assembly: pattern-aware decoder LMs and encoder-decoder models.

A config is compiled to a LAYER PLAN: a list of stages, each stage a
(pattern, repeat) pair where ``pattern`` is a short tuple of heterogeneous
layer specs and ``repeat`` stacks it. Stages with repeat>1 run as a
``lax.scan`` over stacked parameters — essential for compile time at 60+
layers (the HLO contains each distinct layer body once).

The planner reproduces each assigned arch's published structure:
  deepseek-v2/v3   [dense]*k then [MLA+MoE]*(L-k)
  gemma3           ([local]*5 + [global])*5 + [local]*4   (5:1, window 1024)
  jamba            ([mamba+dense, mamba+moe]*2, attn@4, ...) period-8 blocks
  xlstm            [mlstm, slstm]*6
  llama-family     [GQA+dense]*L
  seamless         encoder [bidir attn]*24 + decoder [self+cross]*24
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.layers import (embed, embedding_def, mlp, mlp_def, rmsnorm,
                                 rmsnorm_def, unembed)
from repro.models.params import ParamDef, count_from_defs
from repro.sharding import constrain


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str                # attn | mla | mamba | mlstm | slstm
    mlp: str                  # dense | moe | none
    window: int | None = None
    cross: bool = False       # enc-dec decoder layers


# ------------------------------------------------------------- planning ----

def _layer_specs(cfg) -> list[LayerSpec]:
    specs = []
    for i in range(cfg.n_layers):
        if cfg.block_kinds is not None:
            mixer = cfg.block_kinds[i % len(cfg.block_kinds)]
        elif cfg.attn_every > 1:
            mixer = ("mla" if cfg.attn_kind == "mla" else "attn") \
                if i % cfg.attn_every == cfg.attn_offset else "mamba"
        else:
            mixer = "mla" if cfg.attn_kind == "mla" else "attn"
        if cfg.d_ff == 0 and cfg.n_experts == 0:
            m = "none"
        elif cfg.n_experts and i >= cfg.first_dense_layers \
                and i % cfg.moe_every == cfg.moe_offset:
            m = "moe"
        else:
            m = "dense"
        w = None
        if cfg.window_pattern is not None:
            w = cfg.window_pattern[i % len(cfg.window_pattern)]
        specs.append(LayerSpec(mixer=mixer, mlp=m, window=w,
                               cross=cfg.is_encoder_decoder))
    return specs


def layer_plan(cfg) -> list[tuple[tuple[LayerSpec, ...], int]]:
    specs = _layer_specs(cfg)
    L = len(specs)
    stages, i = [], 0
    while i < L:
        best = (1, 1)
        for p in (1, 2, 3, 4, 6, 8):
            if i + p > L:
                break
            pat = specs[i:i + p]
            r = 1
            while i + (r + 1) * p <= L and specs[i + r * p: i + (r + 1) * p] == pat:
                r += 1
            # a longer pattern only wins if it actually REPEATS (r >= 2);
            # otherwise prefer homogeneous runs (smaller scanned HLO)
            if (p == 1 or r >= 2) and p * r > best[0] * best[1]:
                best = (p, r)
        p, r = best
        stages.append((tuple(specs[i:i + p]), r))
        i += p * r
    return stages


# ---------------------------------------------------------- param trees ----

def _mixer_def(spec: LayerSpec, cfg):
    if spec.mixer == "attn":
        return attn_mod.gqa_def(cfg)
    if spec.mixer == "mla":
        return attn_mod.mla_def(cfg)
    if spec.mixer == "mamba":
        return ssm_mod.mamba_def(cfg)
    if spec.mixer == "mlstm":
        return xlstm_mod.mlstm_def(cfg)
    if spec.mixer == "slstm":
        return xlstm_mod.slstm_def(cfg)
    raise ValueError(spec.mixer)


def _layer_def(spec: LayerSpec, cfg):
    d = {"ln1": rmsnorm_def(cfg.d_model), "mixer": _mixer_def(spec, cfg)}
    if spec.cross:
        d["ln_x"] = rmsnorm_def(cfg.d_model)
        d["xattn"] = attn_mod.gqa_def(cfg)
    if spec.mlp == "dense":
        d["ln2"] = rmsnorm_def(cfg.d_model)
        d["mlp"] = mlp_def(cfg.d_model, cfg.d_ff)
    elif spec.mlp == "moe":
        d["ln2"] = rmsnorm_def(cfg.d_model)
        d["moe"] = moe_mod.experts_def(cfg)
    return d


def _stack_defs(tree, repeat):
    return jax.tree.map(
        lambda d: ParamDef((repeat, *d.shape), ("stack", *d.axes),
                           init=d.init, scale=d.scale, dtype=d.dtype),
        tree, is_leaf=lambda x: isinstance(x, ParamDef))


def _stage_def(pattern, repeat, cfg):
    tree = [_layer_def(s, cfg) for s in pattern]
    return _stack_defs(tree, repeat) if repeat > 1 else tree


def model_params_def(cfg):
    plan = layer_plan(cfg)
    defs = {
        "embed": embedding_def(cfg.vocab_size, cfg.d_model),
        "final_norm": rmsnorm_def(cfg.d_model),
        "stages": [_stage_def(p, r, cfg) for p, r in plan],
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = {"table": ParamDef((cfg.vocab_size, cfg.d_model),
                                             ("vocab", "embed"), scale=0.02)}
    if cfg.is_encoder_decoder:
        enc_cfg = cfg.replace(is_encoder_decoder=False, n_layers=cfg.n_enc_layers,
                              n_experts=0, attn_every=1, block_kinds=None)
        enc_plan = layer_plan(enc_cfg)
        defs["enc_in"] = {"w": ParamDef((cfg.d_model, cfg.d_model),
                                        ("embed", "embed_tp"))}
        defs["enc_stages"] = [_stage_def(p, r, enc_cfg) for p, r in enc_plan]
        defs["enc_norm"] = rmsnorm_def(cfg.d_model)
    if cfg.mtp_depth:
        mcfg = cfg.replace(n_experts=0)
        defs["mtp"] = {
            "proj": ParamDef((2 * cfg.d_model, cfg.d_model), ("embed", "embed_tp")),
            "block": _layer_def(LayerSpec("mla" if cfg.attn_kind == "mla"
                                          else "attn", "dense"), mcfg),
            "norm": rmsnorm_def(cfg.d_model),
        }
    if cfg.frontend == "vision_patches":
        defs["patch_proj"] = {"w": ParamDef((cfg.d_model, cfg.d_model),
                                            ("embed", "embed_tp"))}
    return defs


# ------------------------------------------------------------ cache defs ---

def _layer_cache_def(spec: LayerSpec, cfg, batch, max_len, enc_len=0):
    if spec.mixer in ("attn", "mla"):
        win = spec.window
        eff = max_len if win is None else min(max_len, int(win))
        if spec.mixer == "attn":
            d = attn_mod.gqa_cache_def(cfg, batch, max_len)
        else:
            d = attn_mod.mla_cache_def(cfg, batch, max_len)
        del eff  # windowed layers still cache full length (simple + correct)
    elif spec.mixer == "mamba":
        d = ssm_mod.mamba_cache_def(cfg, batch)
    elif spec.mixer == "mlstm":
        d = xlstm_mod.mlstm_cache_def(cfg, batch)
    else:
        d = xlstm_mod.slstm_cache_def(cfg, batch)
    if spec.cross:
        KV, Dh = cfg.n_kv_heads, cfg.head_dim_
        d["xk"] = ParamDef((batch, enc_len, KV, Dh),
                           ("batch", None, "kv_heads", None), init="zeros")
        d["xv"] = ParamDef((batch, enc_len, KV, Dh),
                           ("batch", None, "kv_heads", None), init="zeros")
    return d


def cache_def(cfg, batch, max_len, enc_len=0):
    plan = layer_plan(cfg)
    stages = []
    for pattern, repeat in plan:
        tree = [_layer_cache_def(s, cfg, batch, max_len, enc_len)
                for s in pattern]
        stages.append(_stack_defs(tree, repeat) if repeat > 1 else tree)
    return {"stages": stages}


def init_cache(cfg, batch, max_len, dtype=jnp.bfloat16, enc_len=0):
    from repro.models.params import init_params
    return init_params(cache_def(cfg, batch, max_len, enc_len),
                       jax.random.PRNGKey(0), dtype)


# -------------------------------------------------------------- forward ----

def _apply_layer(spec: LayerSpec, params, x, ctx, cache=None):
    cfg, rules = ctx["cfg"], ctx["rules"]
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(params["ln1"], x, cfg.norm_eps)
    if spec.mixer == "attn":
        mix, new_kv = attn_mod.gqa_apply(
            params["mixer"], h, ctx["positions"], cfg, window=spec.window,
            rules=rules, cache=cache, step=ctx.get("step"),
            causal=ctx.get("causal", True))
    elif spec.mixer == "mla":
        mix, new_kv = attn_mod.mla_apply(
            params["mixer"], h, ctx["positions"], cfg, rules=rules,
            cache=cache, step=ctx.get("step"), window=spec.window,
            causal=ctx.get("causal", True))
    elif spec.mixer == "mamba":
        mix, new_kv = ssm_mod.mamba_apply(params["mixer"], h, cfg, rules=rules,
                                          cache=cache)
    elif spec.mixer == "mlstm":
        mix, new_kv = xlstm_mod.mlstm_apply(params["mixer"], h, cfg,
                                            rules=rules, cache=cache)
    else:
        mix, new_kv = xlstm_mod.slstm_apply(params["mixer"], h, cfg,
                                            rules=rules, cache=cache)
    x = x + mix
    if spec.cross:
        h = rmsnorm(params["ln_x"], x, cfg.norm_eps)
        if ctx.get("enc_out") is not None:   # fresh encoder output available
            e = ctx["enc_out"]
            ck = jnp.einsum("bsd,dhk->bshk", e, params["xattn"]["wk"])
            cv = jnp.einsum("bsd,dhk->bshk", e, params["xattn"]["wv"])
        else:                                # decode from the primed cache
            ck, cv = cache["xk"], cache["xv"]
        xo, _ = attn_mod.gqa_apply(params["xattn"], h, None, cfg, rules=rules,
                                   cross_kv=(ck, cv))
        x = x + xo
        if new_kv is not None:
            new_kv = {**new_kv, "xk": ck, "xv": cv}
    if spec.mlp == "dense":
        h = rmsnorm(params["ln2"], x, cfg.norm_eps)
        x = x + mlp(params["mlp"], h, act=cfg.act, rules=rules)
    elif spec.mlp == "moe":
        h = rmsnorm(params["ln2"], x, cfg.norm_eps)
        y, aux_l = moe_mod.moe_apply(params["moe"], h, cfg, rules=rules,
                                     act=cfg.act)
        x = x + y
        aux = aux + aux_l
    return x, aux, new_kv


def _remat(fn, cfg):
    if cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return jax.checkpoint(fn)


# Analysis mode: XLA's HloCostAnalysis counts while-loop bodies ONCE, not
# x trip-count, so the roofline dry-run lowers a second, UNROLLED variant of
# each cell to read true per-step flops/bytes/collectives. Runtime lowerings
# keep the scans (compile time, remat). See launch/dryrun.py.
ANALYSIS_UNROLL = False


def _apply_stage(pattern, repeat, params, x, ctx, cache=None, use_remat=True):
    """Returns (x, aux, new_cache)."""
    cfg = ctx["cfg"]

    def run_pattern(params_list, x, cache_list):
        aux = jnp.zeros((), jnp.float32)
        new_caches = []
        for spec, p, c in zip(pattern, params_list,
                              cache_list if cache_list is not None
                              else [None] * len(pattern)):
            x, a, nc = _apply_layer(spec, p, x, ctx, cache=c)
            aux += a
            new_caches.append(nc)
        return x, aux, new_caches

    if repeat == 1:
        return run_pattern(params, x, cache)

    if ANALYSIS_UNROLL:
        aux = jnp.zeros((), jnp.float32)
        new_layers = []
        for i in range(repeat):
            pl = jax.tree.map(lambda p: p[i], params)
            cl = jax.tree.map(lambda c: c[i], cache) if cache is not None else None
            x, a, nc = run_pattern(pl, x, cl)
            aux += a
            new_layers.append(nc)
        if cache is None:
            return x, aux, None
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *new_layers)
        return x, aux, stacked

    if cache is None:
        def body(carry, layer_params):
            x, aux = carry
            x, a, _ = run_pattern(layer_params, x, None)
            return (x, aux + a), None
        body_fn = _remat(body, cfg) if use_remat else body
        (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)),
                                   params)
        return x, aux, None

    def body(carry, xs):
        x, aux = carry
        layer_params, layer_cache = xs
        x, a, ncs = run_pattern(layer_params, x, layer_cache)
        return (x, aux + a), ncs

    (x, aux), new_cache = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                       (params, cache))
    return x, aux, new_cache


def _encode(params, frames, cfg, rules):
    enc_cfg = cfg.replace(is_encoder_decoder=False, n_layers=cfg.n_enc_layers,
                          n_experts=0, attn_every=1, block_kinds=None)
    x = jnp.einsum("bsd,de->bse", frames, params["enc_in"]["w"])
    x = constrain(x, ("batch", "seq", "embed_act"), rules)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    ctx = {"cfg": enc_cfg, "rules": rules, "positions": positions,
           "causal": False}
    for (pattern, repeat), sp in zip(layer_plan(enc_cfg), params["enc_stages"]):
        x, _, _ = _apply_stage(pattern, repeat, sp, x, ctx)
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def forward(params, batch, cfg, rules=None, mode="train"):
    """batch: tokens (B,S) [+ positions, frames, patch_embeds].
    Returns (logits, aux)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed(params["embed"], tokens, rules)
    if cfg.frontend == "vision_patches" and "patch_embeds" in batch:
        pe = jnp.einsum("bsd,de->bse", batch["patch_embeds"],
                        params["patch_proj"]["w"]).astype(x.dtype)
        n_p = pe.shape[1]
        x = jnp.concatenate([pe, x[:, n_p:]], axis=1)
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = _encode(params, batch["frames"], cfg, rules)
    ctx = {"cfg": cfg, "rules": rules, "positions": positions,
           "enc_out": enc_out, "causal": True}
    aux = jnp.zeros((), jnp.float32)
    for (pattern, repeat), sp in zip(layer_plan(cfg), params["stages"]):
        x, a, _ = _apply_stage(pattern, repeat, sp, x, ctx,
                               use_remat=(mode == "train"))
        aux += a
    h = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if mode == "prefill":      # serving prefill: last-position logits only
        h = h[:, -1:]
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = unembed(table, h, rules)
    extras = {"aux_loss": aux}
    if cfg.mtp_depth and mode == "train":
        emb_next = embed(params["embed"], batch["mtp_tokens"], rules) \
            if "mtp_tokens" in batch else jnp.roll(x, -1, axis=1)
        hm = jnp.concatenate([rmsnorm(params["mtp"]["norm"], x, cfg.norm_eps),
                              emb_next.astype(x.dtype)], -1)
        hm = jnp.einsum("bse,ed->bsd", hm, params["mtp"]["proj"])
        mcfg = cfg.replace(n_experts=0)
        mctx = {"cfg": mcfg, "rules": rules, "positions": positions,
                "causal": True}
        hm, _, _ = _apply_layer(LayerSpec("mla" if cfg.attn_kind == "mla"
                                          else "attn", "dense"),
                                params["mtp"]["block"], hm, mctx)
        extras["mtp_logits"] = unembed(table, rmsnorm(params["final_norm"], hm,
                                                      cfg.norm_eps), rules)
    return logits, extras


def decode_step(params, cache, batch, cfg, rules=None):
    """One-token decode. batch: tokens (B,1), step scalar int32,
    [positions (B,[3,]1), enc_out for enc-dec]. Returns (logits, new_cache)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    step = batch["step"]
    x = embed(params["embed"], tokens, rules)
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(step[None, None] if step.ndim == 0
                                     else step[:, None], (B, S)).astype(jnp.int32)
    ctx = {"cfg": cfg, "rules": rules, "positions": positions, "step": step,
           "enc_out": batch.get("enc_out"), "causal": True}
    new_stages = []
    for (pattern, repeat), sp, sc in zip(layer_plan(cfg), params["stages"],
                                         cache["stages"]):
        x, _, nc = _apply_stage(pattern, repeat, sp, x, ctx, cache=sc,
                                use_remat=False)
        new_stages.append(nc)
    h = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = unembed(table, h, rules)
    return logits, {"stages": new_stages}


# ------------------------------------------------------------- counting ----

def count_params(cfg) -> int:
    return count_from_defs(model_params_def(cfg))


def active_params(cfg) -> int:
    """Active parameters per token (MoE: top_k + shared experts only) —
    the N in MODEL_FLOPS = 6*N*D."""
    total = count_params(cfg)
    if not cfg.n_experts:
        return total
    D, F, E = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    specs = _layer_specs(cfg)
    n_moe = sum(1 for s in specs if s.mlp == "moe")
    per_expert = 3 * D * F
    total -= n_moe * E * per_expert              # remove all routed experts
    total += n_moe * cfg.top_k * per_expert      # add back the active ones
    return total


def build_model(cfg):
    return {
        "cfg": cfg,
        "params_def": model_params_def(cfg),
        "forward": partial(forward, cfg=cfg),
        "decode_step": partial(decode_step, cfg=cfg),
    }
