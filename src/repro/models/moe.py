"""Mixture-of-Experts with expert parallelism.

Dispatch is sort-based (TPU adaptation of the paper-era GShard einsum
dispatch, whose (tokens, experts, capacity) one-hot would be ~1e11 elements
at DeepSeek scale): token->expert assignments are argsorted, positions within
each expert computed from the sorted stream, and tokens scattered into a
dense (experts, capacity, d) buffer that feeds a batched expert GEMM. FLOPs
are the true active-parameter FLOPs times the capacity factor.

Experts are sharded over the "model" mesh axis (EP); the scatter/gather
across the data->expert sharding boundary lowers to all-to-all-class
collectives under SPMD (measured in the roofline; the shard_map variant with
explicit jax.lax.all_to_all is the §Perf alternative, cfg.moe_impl).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import ParamDef
from repro.sharding import constrain


def router_def(cfg):
    return {"w": ParamDef((cfg.d_model, cfg.n_experts), ("embed", "experts"),
                          scale=0.02)}


def experts_def(cfg):
    D, F, E = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    p = {
        "router": router_def(cfg),
        "wi_gate": ParamDef((E, D, F), ("experts", "embed", "exp_mlp")),
        "wi_up": ParamDef((E, D, F), ("experts", "embed", "exp_mlp")),
        "wo": ParamDef((E, F, D), ("experts", "exp_mlp", "embed")),
    }
    if cfg.n_shared_experts:
        Fs = F * cfg.n_shared_experts
        p["shared"] = {
            "wi_gate": ParamDef((D, Fs), ("embed", "mlp")),
            "wi_up": ParamDef((D, Fs), ("embed", "mlp")),
            "wo": ParamDef((Fs, D), ("mlp", "embed_tp")),
        }
    return p


def _route(params, x2, cfg):
    """x2: (N, D) -> (weights (N,k), experts (N,k)). softmax (v2/jamba) or
    sigmoid+renorm (v3-style) router, fp32 for stability."""
    logits = jnp.einsum("nd,de->ne", x2, params["router"]["w"]).astype(jnp.float32)
    if cfg.router_kind == "sigmoid":
        scores = jax.nn.sigmoid(logits)
        w, e = jax.lax.top_k(scores, cfg.top_k)
        w = w / (jnp.sum(w, -1, keepdims=True) + 1e-9)
    else:
        probs = jax.nn.softmax(logits, -1)
        w, e = jax.lax.top_k(probs, cfg.top_k)
        w = w / (jnp.sum(w, -1, keepdims=True) + 1e-9)
    return w, e, logits


def _aux_loss(logits, experts, cfg):
    """Switch-style load-balancing loss (fraction-dispatched x mean-prob)."""
    probs = jax.nn.softmax(logits, -1)
    me = jnp.mean(probs, 0)
    ce = jnp.mean(jax.nn.one_hot(experts[:, 0], cfg.n_experts,
                                 dtype=jnp.float32), 0)
    return cfg.n_experts * jnp.sum(me * ce)


def moe_apply(params, x, cfg, rules=None, act="silu"):
    """x: (B,S,D) -> (y, aux_loss). Dispatches on cfg.moe_impl; shard_map
    needs a mesh whose batch axes divide B (falls back to scatter)."""
    if cfg.moe_impl == "shard_map":
        from repro.sharding import current_abstract_mesh
        mesh = current_abstract_mesh()
        if mesh is not None:
            batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
            n_b = 1
            for a in batch_axes:
                n_b *= dict(mesh.shape)[a]
            if ("model" in mesh.axis_names and batch_axes
                    and x.shape[0] % n_b == 0
                    and cfg.n_experts % dict(mesh.shape)["model"] == 0):
                return _moe_shard_map(params, x, cfg, mesh, batch_axes, act)
    return _moe_scatter(params, x, cfg, rules, act)


def _moe_scatter(params, x, cfg, rules=None, act="silu"):
    """Baseline pjit implementation: sort-based capacity packing into a
    model-sharded (E, C, D) buffer. XLA's SPMD partitioner reshards the
    data-sharded tokens into the expert-sharded buffer with global
    all-gathers — measured collective-bound at DeepSeek scale (see
    EXPERIMENTS.md §Perf), which motivates the shard_map variant below."""
    B, S, D = x.shape
    N = B * S
    E, k = cfg.n_experts, cfg.top_k
    cap = int(cfg.capacity_factor * N * k / E + 1)
    x2 = x.reshape(N, D)

    w, e, logits = _route(params, x2, cfg)          # (N,k)
    aux = _aux_loss(logits, e, cfg)

    e_flat = e.reshape(-1)                           # (N*k,)
    order = jnp.argsort(e_flat)
    sorted_e = e_flat[order]
    tok = order // k                                 # source token per slot
    # position of each routed slot within its expert
    start = jnp.searchsorted(sorted_e, jnp.arange(E))
    pos = jnp.arange(N * k) - start[sorted_e]
    keep = pos < cap
    pos_c = jnp.where(keep, pos, cap)                # dropped -> OOB row

    # dense (E, cap(+1 dump row), D) buffer for the batched expert GEMM
    buf = jnp.zeros((E, cap + 1, D), x.dtype)
    buf = buf.at[sorted_e, pos_c].set(x2[tok], mode="drop")
    buf = constrain(buf, ("exp_act", None, None), rules)

    h_g = jnp.einsum("ecd,edf->ecf", buf, params["wi_gate"])
    h_u = jnp.einsum("ecd,edf->ecf", buf, params["wi_up"])
    h = (jax.nn.silu(h_g) if act == "silu" else jax.nn.gelu(h_g)) * h_u
    out = jnp.einsum("ecf,efd->ecd", h, params["wo"])
    out = constrain(out, ("exp_act", None, None), rules)

    gathered = out[sorted_e, pos_c]                  # (N*k, D)
    w_flat = w.reshape(-1)[order].astype(x.dtype)
    contrib = gathered * jnp.where(keep, w_flat, 0.0)[:, None]
    y2 = jnp.zeros((N, D), x.dtype).at[tok].add(contrib)
    y2 = constrain(y2.reshape(B, S, D), ("batch", "seq", "embed_act"), rules)

    if cfg.n_shared_experts:
        from repro.models.layers import mlp
        y2 = y2 + mlp(params["shared"], x, act=act, rules=rules)
    return y2, aux


# ---------------------------------------------------------------------------
# shard_map expert parallelism (beyond-paper optimization, cfg.moe_impl)
# ---------------------------------------------------------------------------
# Key observation: activations are replicated along the "model" mesh axis
# (they are sharded over batch only), so every model-rank already HOLDS every
# token of its batch shard. Expert parallelism therefore needs NO token
# all-to-all at all: each model-rank routes identically (same tokens, same
# router), selects the assignments owned by its E/model_size local experts,
# runs the local grouped GEMM, and ONE psum over "model" combines the
# per-rank partial outputs. Collective cost per MoE layer drops from
# O(tokens*D * world) (SPMD scatter resharding) to one 2*N_loc*D all-reduce.

def _moe_shard_map(params, x, cfg, mesh, batch_axes, act="silu"):
    from jax.sharding import PartitionSpec as P
    shard_map = jax.shard_map

    E, k = cfg.n_experts, cfg.top_k
    model_size = dict(mesh.shape)["model"]
    E_loc = E // model_size
    B, S, D = x.shape
    n_b = 1
    for a in batch_axes:
        n_b *= dict(mesh.shape)[a]
    n_loc = (B // n_b) * S
    cap = int(cfg.capacity_factor * n_loc * k / E + 1)

    def local_moe(xb, rw, wg, wu, wo):
        x2 = xb.reshape(n_loc, D)
        m_rank = jax.lax.axis_index("model")
        logits = jnp.einsum("nd,de->ne", x2, rw).astype(jnp.float32)
        if cfg.router_kind == "sigmoid":
            scores = jax.nn.sigmoid(logits)
            w, e = jax.lax.top_k(scores, k)
        else:
            w, e = jax.lax.top_k(jax.nn.softmax(logits, -1), k)
        w = w / (jnp.sum(w, -1, keepdims=True) + 1e-9)
        aux = _aux_loss(logits, e, cfg)
        aux = jax.lax.pmean(aux, batch_axes if len(batch_axes) > 1
                            else batch_axes[0])

        e_loc = e - m_rank * E_loc
        own = (e_loc >= 0) & (e_loc < E_loc)
        e_flat = jnp.where(own.reshape(-1), e_loc.reshape(-1), E_loc)
        order = jnp.argsort(e_flat)
        sorted_e = e_flat[order]
        tok = order // k
        start = jnp.searchsorted(sorted_e, jnp.arange(E_loc))
        pos = jnp.arange(n_loc * k) - start[sorted_e]
        keep = (sorted_e < E_loc) & (pos < cap)
        pos_c = jnp.where(keep, pos, cap)
        e_c = jnp.where(keep, sorted_e, E_loc - 1)

        buf = jnp.zeros((E_loc, cap + 1, D), x2.dtype)
        buf = buf.at[e_c, pos_c].set(
            jnp.where(keep[:, None], x2[tok], 0.0), mode="drop")
        h_g = jnp.einsum("ecd,edf->ecf", buf, wg)
        h_u = jnp.einsum("ecd,edf->ecf", buf, wu)
        h = (jax.nn.silu(h_g) if act == "silu" else jax.nn.gelu(h_g)) * h_u
        out = jnp.einsum("ecf,efd->ecd", h, wo)

        gathered = out[e_c, pos_c]
        w_flat = w.reshape(-1)[order].astype(x2.dtype)
        contrib = gathered * jnp.where(keep, w_flat, 0.0)[:, None]
        y = jnp.zeros((n_loc, D), x2.dtype).at[tok].add(contrib)
        y = jax.lax.psum(y, "model")
        return y.reshape(xb.shape), aux

    bspec = tuple(batch_axes) if len(batch_axes) > 1 else batch_axes[0]
    y, aux = shard_map(
        local_moe, mesh=mesh,
        in_specs=(P(bspec, None, None), P(None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=(P(bspec, None, None), P()),
        check_vma=False,
    )(x, params["router"]["w"], params["wi_gate"], params["wi_up"],
      params["wo"])

    if cfg.n_shared_experts:
        from repro.models.layers import mlp
        y = y + mlp(params["shared"], x, act=act, rules=None)
    return y, aux
