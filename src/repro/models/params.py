"""Single-source-of-truth parameter definitions.

A model is declared as a pytree of ``ParamDef`` (shape + logical axes +
initializer). From that one tree we derive:

* ``init_params``      — real arrays (smoke tests, examples)
* ``abstract_params``  — ShapeDtypeStructs (the dry-run lowers 671B-param
                         models without allocating a byte)
* ``param_specs``      — logical-axes tree -> PartitionSpecs via the rules
                         table (sharding is never hand-written per tensor)
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding import logical_to_pspec


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple
    axes: tuple              # logical axis names, len == len(shape)
    init: str = "normal"     # normal | zeros | ones
    scale: float | None = None  # stddev; default fan-in scaling
    dtype: str | None = None    # per-leaf override (e.g. f32 SSM states)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_def(x):
    return isinstance(x, ParamDef)


def init_params(defs, key, dtype=jnp.float32):
    leaves, treedef = jax.tree.flatten(defs, is_leaf=_is_def)
    keys = jax.random.split(key, max(len(leaves), 2))
    out = []
    for k, d in zip(keys, leaves):
        dt = jnp.dtype(d.dtype) if d.dtype else dtype
        if d.init == "zeros":
            out.append(jnp.zeros(d.shape, dt))
        elif d.init == "ones":
            out.append(jnp.ones(d.shape, dt))
        else:
            scale = d.scale
            if scale is None:
                fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
                scale = 1.0 / np.sqrt(max(fan_in, 1))
            out.append((jax.random.normal(k, d.shape) * scale).astype(dt))
    return jax.tree.unflatten(treedef, out)


def abstract_params(defs, dtype=jnp.bfloat16, mesh=None, rules=None):
    """ShapeDtypeStructs (optionally with NamedShardings) for .lower()."""
    def mk(d: ParamDef):
        sharding = None
        if mesh is not None and rules is not None:
            sharding = jax.sharding.NamedSharding(
                mesh, logical_to_pspec(d.axes, rules, mesh, shape=d.shape))
        dt = jnp.dtype(d.dtype) if d.dtype else dtype
        return jax.ShapeDtypeStruct(d.shape, dt, sharding=sharding)
    return jax.tree.map(mk, defs, is_leaf=_is_def)


def param_specs(defs):
    """Pytree of logical-axes tuples (feed to sharding.spec_tree_to_pspecs)."""
    return jax.tree.map(lambda d: d.axes, defs, is_leaf=_is_def)


def count_from_defs(defs) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=_is_def)
    return int(sum(int(np.prod(d.shape)) for d in leaves))
