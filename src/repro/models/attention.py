"""Attention: GQA / MHA, sliding-window (chunked, sub-quadratic), MLA
(DeepSeek multi-head latent attention with the absorbed decode path), and
single-token decode against a KV cache (head- or sequence-sharded).

The XLA einsum path here is the dry-run/roofline path; the Pallas flash
kernel (repro.kernels.flash_attention) is the TPU fast path and is validated
against this module's math in tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, rmsnorm, rmsnorm_def
from repro.models.params import ParamDef
from repro.sharding import constrain

_NEG = -1e30


def _softcap(x, cap):
    return jnp.tanh(x / cap) * cap if cap else x


# =========================================================== core maths ====

def sdpa(q, k, v, *, causal=True, q_offset=0, window=None, softcap=None,
         kv_len=None, rules=None):
    """Grouped-query attention. q: (B,S,H,D); k, v: (B,T,KV,D).

    ``q_offset``: absolute position of q[0] (decode: the current step).
    ``kv_len``: number of valid cache rows (decode masking).
    """
    B, S, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, D)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(D).astype(jnp.float32)
    scores = _softcap(scores, softcap)
    T = k.shape[1]
    qpos = jnp.arange(S)[:, None] + q_offset
    kpos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    if kv_len is not None:
        mask &= kpos < kv_len
    scores = jnp.where(mask[None, None, None], scores, _NEG)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    out = out.reshape(B, S, H, v.shape[-1])   # v head-dim may differ (MLA)
    return constrain(out, ("batch", "seq", "heads_act", None), rules)


def sdpa_q_chunked(q, k, v, *, causal=True, window=None, softcap=None,
                   q_chunk=2048, rules=None):
    """Flash-style memory bound on the XLA path: queries are processed in
    chunks of ``q_chunk`` sequentially (lax.map), so only one chunk's
    (B,KV,G,C,T) score block is ever live — prefill memory drops from
    O(S^2) to O(S*C) per layer. FLOPs unchanged. The Pallas flash kernel is
    the TPU fast path; this is its XLA twin for the dry-run/roofline."""
    from repro.models import transformer as _T
    B, S, H, D = q.shape
    pad = (-S) % q_chunk
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = qp.shape[1] // q_chunk
    qc = jnp.moveaxis(qp.reshape(B, nc, q_chunk, H, D), 1, 0)
    offsets = jnp.arange(nc) * q_chunk

    def one(args):
        qi, off = args
        return sdpa(qi, k, v, causal=causal, q_offset=off, window=window,
                    softcap=softcap, kv_len=S, rules=rules)

    if _T.ANALYSIS_UNROLL:   # exact per-step flops (see dryrun analysis mode)
        outs = [one((qc[i], offsets[i])) for i in range(nc)]
        out = jnp.stack(outs, 0)
    else:
        out = jax.lax.map(one, (qc, offsets))
    out = jnp.moveaxis(out, 0, 1).reshape(B, nc * q_chunk, H, -1)[:, :S]
    return out


def sdpa_local_chunked(q, k, v, *, window, softcap=None, rules=None):
    """Sliding-window attention computed block-band-wise: each width-W chunk
    of queries attends to its own and the previous chunk only — O(S*W)
    compute instead of the O(S^2) naive masked form (honest roofline FLOPs
    for gemma3's 5:1 local layers at 32k/500k context)."""
    B, S, H, D = q.shape
    KV = k.shape[2]
    W = window
    if S % W:
        pad = W - S % W
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = q.shape[1]
    nc = Sp // W
    G = H // KV
    qc = q.reshape(B, nc, W, KV, G, D)
    kc = k.reshape(B, nc, W, KV, D)
    vc = v.reshape(B, nc, W, KV, D)
    zeros = jnp.zeros_like(kc[:, :1])
    k2 = jnp.concatenate([jnp.concatenate([zeros, kc[:, :-1]], 1), kc], 2)
    v2 = jnp.concatenate([jnp.concatenate([jnp.zeros_like(vc[:, :1]),
                                           vc[:, :-1]], 1), vc], 2)
    scores = jnp.einsum("bcskgd,bctkd->bckgst", qc, k2).astype(jnp.float32)
    scores = _softcap(scores / jnp.sqrt(D).astype(jnp.float32), softcap)
    qpos = jnp.arange(W)[:, None] + W            # within the 2W k-window
    kpos = jnp.arange(2 * W)[None, :]
    first = jnp.arange(nc) == 0                  # chunk 0 has no predecessor
    mask = (kpos <= qpos) & (kpos > qpos - W)    # causal, width-W band
    valid0 = kpos >= W
    mask = jnp.where(first[:, None, None], mask & valid0, mask)  # (nc,W,2W)
    scores = jnp.where(mask[None, :, None, None], scores, _NEG)
    probs = jax.nn.softmax(scores, -1).astype(q.dtype)
    out = jnp.einsum("bckgst,bctkd->bcskgd", probs, v2)
    out = out.reshape(B, Sp, H, D)[:, :S]
    return constrain(out, ("batch", "seq", "heads_act", None), rules)


# ======================================================== GQA attention ====

def gqa_def(cfg):
    D, H, KV, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    return {
        "wq": ParamDef((D, H, Dh), ("embed", "heads", None)),
        "wk": ParamDef((D, KV, Dh), ("embed", "kv_heads", None)),
        "wv": ParamDef((D, KV, Dh), ("embed", "kv_heads", None)),
        "wo": ParamDef((H, Dh, D), ("heads", None, "embed_tp")),
    }


def gqa_apply(params, x, positions, cfg, *, window=None, rules=None,
              cache=None, step=None, cross_kv=None, causal=True):
    """Returns (out, new_cache). Modes:
    * train/prefill: cache=None — full (or chunked-local) attention;
    * decode: cache={'k','v'} (B,Smax,KV,Dh), step = current length;
    * cross-attention: cross_kv = (k, v) precomputed from the encoder.
    """
    B, S, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    q = constrain(q, ("batch", "seq", "heads_act", None), rules)
    if cross_kv is not None:
        k, v = cross_kv
        out = sdpa(q, k, v, causal=False, rules=rules)
        return jnp.einsum("bshk,hkd->bsd", out, params["wo"]), cache
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if positions is not None:
        q = apply_rope(q, positions, cfg)
        k = apply_rope(k, positions, cfg)
    if cache is None:
        if window is not None and S > 2 * window:
            out = sdpa_local_chunked(q, k, v, window=window,
                                     softcap=cfg.attn_logit_softcap,
                                     rules=rules)
        elif cfg.attn_q_chunk and S > 2 * cfg.attn_q_chunk:
            out = sdpa_q_chunked(q, k, v, causal=causal, window=window,
                                 softcap=cfg.attn_logit_softcap,
                                 q_chunk=cfg.attn_q_chunk, rules=rules)
        else:
            out = sdpa(q, k, v, causal=causal, window=window,
                       softcap=cfg.attn_logit_softcap, rules=rules)
        new_cache = None
    else:
        kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, step, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, step, axis=1)
        kv_axes = ("batch", "seq_model" if cfg.decode_kv_shard == "seq"
                   else "seq", "kv_heads", None)
        kc = constrain(kc, kv_axes, rules)
        vc = constrain(vc, kv_axes, rules)
        out = sdpa(q, kc, vc, causal=True, q_offset=step, window=window,
                   softcap=cfg.attn_logit_softcap, kv_len=step + S,
                   rules=rules)
        new_cache = {"k": kc, "v": vc}
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return constrain(y, ("batch", "seq", "embed_act"), rules), new_cache


def gqa_cache_def(cfg, batch, max_len):
    KV, Dh = cfg.n_kv_heads, cfg.head_dim_
    kv_axes = ("batch", "seq_model" if cfg.decode_kv_shard == "seq" else "seq",
               "kv_heads", None)
    return {"k": ParamDef((batch, max_len, KV, Dh), kv_axes, init="zeros"),
            "v": ParamDef((batch, max_len, KV, Dh), kv_axes, init="zeros")}


# ======================================================== MLA attention ====

def mla_def(cfg):
    D, H = cfg.d_model, cfg.n_heads
    nope, rope_d, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    p = {
        "w_dkv": ParamDef((D, cfg.kv_lora_rank), ("embed", "kv_lora")),
        "kv_norm": rmsnorm_def(cfg.kv_lora_rank),
        "w_kr": ParamDef((D, rope_d), ("embed", None)),
        "w_uk": ParamDef((cfg.kv_lora_rank, H, nope), ("kv_lora", "heads", None)),
        "w_uv": ParamDef((cfg.kv_lora_rank, H, vd), ("kv_lora", "heads", None)),
        "w_o": ParamDef((H, vd, D), ("heads", None, "embed_tp")),
    }
    if cfg.q_lora_rank:
        p["w_dq"] = ParamDef((D, cfg.q_lora_rank), ("embed", "q_lora"))
        p["q_norm"] = rmsnorm_def(cfg.q_lora_rank)
        p["w_uq"] = ParamDef((cfg.q_lora_rank, H, nope + rope_d),
                             ("q_lora", "heads", None))
    else:
        p["w_q"] = ParamDef((D, H, nope + rope_d), ("embed", "heads", None))
    return p


def _mla_q(params, x, positions, cfg, rules):
    nope = cfg.qk_nope_dim
    if cfg.q_lora_rank:
        qa = rmsnorm(params["q_norm"], jnp.einsum("bsd,dr->bsr", x, params["w_dq"]),
                     cfg.norm_eps)
        q = jnp.einsum("bsr,rhk->bshk", qa, params["w_uq"])
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, params["w_q"])
    q = constrain(q, ("batch", "seq", "heads_act", None), rules)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg)
    return q_nope, q_rope


def mla_apply(params, x, positions, cfg, *, rules=None, cache=None, step=None,
              window=None, causal=True):
    """MLA. Prefill caches/computes the full per-head K/V; decode runs the
    ABSORBED path: only the rank-512 latent + rope-key are cached (the
    paper-exact serving trick: 576 floats/token instead of 2*H*128),
    and W_UK/W_UV are folded into the score/value einsums."""
    B, S, _ = x.shape
    nope = cfg.qk_nope_dim
    q_nope, q_rope = _mla_q(params, x, positions, cfg, rules)
    c = rmsnorm(params["kv_norm"], jnp.einsum("bsd,dr->bsr", x, params["w_dkv"]),
                cfg.norm_eps)
    kr = apply_rope(jnp.einsum("bsd,dr->bsr", x, params["w_kr"])[:, :, None, :],
                    positions, cfg)[:, :, 0, :]
    if cache is None:
        k_nope = jnp.einsum("bsr,rhk->bshk", c, params["w_uk"])
        v = jnp.einsum("bsr,rhv->bshv", c, params["w_uv"])
        q = jnp.concatenate([q_nope, q_rope], -1)
        k = jnp.concatenate([k_nope,
                             jnp.broadcast_to(kr[:, :, None, :],
                                              (*k_nope.shape[:3], kr.shape[-1]))],
                            -1)
        if cfg.attn_q_chunk and S > 2 * cfg.attn_q_chunk:
            out = sdpa_q_chunked(q, k, v, causal=causal, window=window,
                                 q_chunk=cfg.attn_q_chunk, rules=rules)
        else:
            out = sdpa(q, k, v, causal=causal, window=window, rules=rules)
        y = jnp.einsum("bshv,hvd->bsd", out, params["w_o"])
        return constrain(y, ("batch", "seq", "embed_act"), rules), None
    # ---------------- absorbed decode ----------------
    cc = jax.lax.dynamic_update_slice_in_dim(cache["c"], c, step, axis=1)
    krc = jax.lax.dynamic_update_slice_in_dim(cache["kr"], kr, step, axis=1)
    seq_ax = "seq_model" if cfg.decode_kv_shard == "seq" else "seq"
    cc = constrain(cc, ("batch", seq_ax, "kv_lora"), rules)
    krc = constrain(krc, ("batch", seq_ax, None), rules)
    q_abs = jnp.einsum("bshk,rhk->bshr", q_nope, params["w_uk"])
    scores = (jnp.einsum("bshr,btr->bhst", q_abs, cc)
              + jnp.einsum("bshk,btk->bhst", q_rope, krc)).astype(jnp.float32)
    scores = scores / jnp.sqrt(nope + cfg.qk_rope_dim).astype(jnp.float32)
    T = cc.shape[1]
    kpos = jnp.arange(T)[None, :]
    qpos = jnp.arange(S)[:, None] + step
    mask = (kpos <= qpos) & (kpos < step + S)
    scores = jnp.where(mask[None, None], scores, _NEG)
    probs = jax.nn.softmax(scores, -1).astype(x.dtype)
    ctx = jnp.einsum("bhst,btr->bshr", probs, cc)
    out = jnp.einsum("bshr,rhv->bshv", ctx, params["w_uv"])
    y = jnp.einsum("bshv,hvd->bsd", out, params["w_o"])
    y = constrain(y, ("batch", "seq", "embed_act"), rules)
    return y, {"c": cc, "kr": krc}


def mla_cache_def(cfg, batch, max_len):
    seq_ax = "seq_model" if cfg.decode_kv_shard == "seq" else "seq"
    return {"c": ParamDef((batch, max_len, cfg.kv_lora_rank),
                          ("batch", seq_ax, "kv_lora"), init="zeros"),
            "kr": ParamDef((batch, max_len, cfg.qk_rope_dim),
                           ("batch", seq_ax, None), init="zeros")}
