"""xLSTM blocks: mLSTM (matrix memory, exp gating) and sLSTM (scalar memory).

mLSTM trains with the parallel (attention-like, stabilized) formulation and
decodes with the O(1) recurrent (C, n, m) state update — the property that
qualifies xlstm-125m for the long_500k cell. sLSTM has a true hidden-to-
hidden recurrence, so it always runs as a lax.scan.

Per the assignment (d_ff=0) blocks are mixer-only residual blocks; mLSTM
carries its own 2x up-projection as in the xLSTM paper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import ParamDef
from repro.sharding import constrain


# ------------------------------------------------------------- mLSTM -------

def mlstm_def(cfg):
    D, H = cfg.d_model, cfg.n_heads
    Din = 2 * D
    dh = Din // H
    return {
        "up": ParamDef((D, 2 * Din), ("embed", "mlp")),
        "wq": ParamDef((Din, H, dh), ("mlp", "heads", None)),
        "wk": ParamDef((Din, H, dh), ("mlp", "heads", None)),
        "wv": ParamDef((Din, H, dh), ("mlp", "heads", None)),
        "wi": ParamDef((Din, H), ("mlp", "heads"), scale=0.02),
        "wf": ParamDef((Din, H), ("mlp", "heads"), scale=0.02),
        "bf": ParamDef((H,), ("heads",), init="ones"),
        "bi": ParamDef((H,), ("heads",), init="zeros"),
        "down": ParamDef((Din, D), ("mlp", "embed_tp")),
    }


def mlstm_apply(params, x, cfg, *, rules=None, cache=None):
    B, S, D = x.shape
    H = cfg.n_heads
    up = jnp.einsum("bsd,de->bse", x, params["up"])
    xin, z = jnp.split(up, 2, axis=-1)
    q = jnp.einsum("bse,ehk->bshk", xin, params["wq"])
    k = jnp.einsum("bse,ehk->bshk", xin, params["wk"])
    v = jnp.einsum("bse,ehk->bshk", xin, params["wv"])
    dh = q.shape[-1]
    scale = 1.0 / jnp.sqrt(dh)
    logi = (jnp.einsum("bse,eh->bsh", xin, params["wi"]) + params["bi"]).astype(jnp.float32)
    logf = jax.nn.log_sigmoid(
        (jnp.einsum("bse,eh->bsh", xin, params["wf"]) + params["bf"]).astype(jnp.float32))

    if cache is None:
        # parallel stabilized form: D_ij = F_i - F_j + i_j (j <= i)
        F = jnp.cumsum(logf, axis=1)                       # (B,S,H)
        Dm = F[:, :, None, :] - F[:, None, :, :] + logi[:, None, :, :]
        causal = jnp.tril(jnp.ones((S, S), bool))
        Dm = jnp.where(causal[None, :, :, None], Dm, -jnp.inf)
        m = jnp.max(Dm, axis=2, keepdims=True)             # (B,S,1,H)
        w = jnp.exp(Dm - m)                                # (B,S,S,H)
        scores = jnp.einsum("bshk,bthk->bsth", q, k) * scale
        sw = scores.astype(jnp.float32) * w
        num = jnp.einsum("bsth,bthk->bshk", sw.astype(x.dtype), v)
        den = jnp.abs(jnp.sum(sw, axis=2))                 # (B,S,H)
        den = jnp.maximum(den, jnp.exp(-m[:, :, 0, :]))
        h = num / den[..., None].astype(x.dtype)
        new_cache = None
    else:
        # recurrent update (S == 1)
        C, n, m0 = cache["C"], cache["n"], cache["m"]      # (B,H,dk,dv),(B,H,dk),(B,H)
        li, lf = logi[:, 0], logf[:, 0]                    # (B,H)
        m1 = jnp.maximum(lf + m0, li)
        a = jnp.exp(lf + m0 - m1)[..., None, None]
        b = jnp.exp(li - m1)[..., None, None]
        kv = jnp.einsum("bhk,bhv->bhkv", k[:, 0].astype(jnp.float32),
                        v[:, 0].astype(jnp.float32))
        C1 = a * C + b * kv
        n1 = a[..., 0] * n + b[..., 0] * k[:, 0].astype(jnp.float32)
        qs = q[:, 0].astype(jnp.float32) * scale
        num = jnp.einsum("bhkv,bhk->bhv", C1, qs)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n1, qs)),
                          jnp.exp(-m1))
        h = (num / den[..., None]).astype(x.dtype)[:, None]  # (B,1,H,dv)
        new_cache = {"C": C1, "n": n1, "m": m1}
    h = h.reshape(B, S, -1) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", h, params["down"])
    return constrain(out, ("batch", "seq", "embed_act"), rules), new_cache


def mlstm_cache_def(cfg, batch):
    H = cfg.n_heads
    dh = 2 * cfg.d_model // H
    return {"C": ParamDef((batch, H, dh, dh), ("batch", "heads", None, None),
                          init="zeros", dtype="float32"),
            "n": ParamDef((batch, H, dh), ("batch", "heads", None),
                          init="zeros", dtype="float32"),
            "m": ParamDef((batch, H), ("batch", "heads"), init="zeros",
                          dtype="float32")}


# ------------------------------------------------------------- sLSTM -------

def slstm_def(cfg):
    D = cfg.d_model
    return {
        "wz": ParamDef((D, D), ("embed", "mlp")),
        "wi": ParamDef((D, D), ("embed", "mlp"), scale=0.02),
        "wf": ParamDef((D, D), ("embed", "mlp"), scale=0.02),
        "wo": ParamDef((D, D), ("embed", "mlp")),
        "rz": ParamDef((D, D), ("mlp", "mlp"), scale=0.02),
        "bf": ParamDef((D,), ("heads_act",), init="ones"),
        "out": ParamDef((D, D), ("mlp", "embed_tp")),
    }


def _slstm_step(params, carry, xt):
    """One sLSTM step. carry = (c, n, h, m) each (B, D)."""
    c, n, h, m = carry
    zt = jnp.tanh(xt @ params["wz"] + h @ params["rz"])
    it = (xt @ params["wi"]).astype(jnp.float32)
    ft = jax.nn.log_sigmoid((xt @ params["wf"]).astype(jnp.float32)
                            + params["bf"])
    ot = jax.nn.sigmoid(xt @ params["wo"])
    m1 = jnp.maximum(ft + m, it)
    ip = jnp.exp(it - m1)
    fp = jnp.exp(ft + m - m1)
    c1 = fp * c + ip * zt.astype(jnp.float32)
    n1 = fp * n + ip
    h1 = (ot * (c1 / jnp.maximum(n1, 1e-6)).astype(xt.dtype))
    return (c1, n1, h1, m1), h1


def slstm_apply(params, x, cfg, *, rules=None, cache=None):
    B, S, D = x.shape
    if cache is None:
        carry = tuple(jnp.zeros((B, D), jnp.float32) for _ in range(2)) + (
            jnp.zeros((B, D), x.dtype), jnp.zeros((B, D), jnp.float32))
        carry, hs = jax.lax.scan(lambda c, xt: _slstm_step(params, c, xt),
                                 carry, x.transpose(1, 0, 2))
        h = hs.transpose(1, 0, 2)
        new_cache = None
    else:
        carry = (cache["c"], cache["n"], cache["h"], cache["m"])
        carry, h1 = _slstm_step(params, carry, x[:, 0])
        h = h1[:, None]
        new_cache = {"c": carry[0], "n": carry[1], "h": carry[2], "m": carry[3]}
    out = jnp.einsum("bsd,de->bse", h, params["out"])
    return constrain(out, ("batch", "seq", "embed_act"), rules), new_cache


def slstm_cache_def(cfg, batch):
    D = cfg.d_model
    return {"c": ParamDef((batch, D), ("batch", "mlp"), init="zeros",
                          dtype="float32"),
            "n": ParamDef((batch, D), ("batch", "mlp"), init="zeros",
                          dtype="float32"),
            "h": ParamDef((batch, D), ("batch", "mlp"), init="zeros"),
            "m": ParamDef((batch, D), ("batch", "mlp"), init="zeros",
                          dtype="float32")}
