"""Production training entrypoint.

    PYTHONPATH=src python -m repro.launch.train --arch granite-8b --smoke \
        --steps 200 --batch 8 --seq 64 --ckpt-dir /tmp/run1

Features exercised end-to-end (CPU-scale with --smoke; the same loop lowers
on the production mesh via launch/dryrun.py):
  * deterministic resumable data stream (step-indexed, no shuffle state)
  * async checkpointing every --ckpt-every steps + resume on restart
  * straggler watchdog: logs any step slower than 3x the trailing median
  * mesh-aware: uses all local devices as a (data, model) host mesh
"""
from __future__ import annotations

import argparse
import time
import warnings

warnings.filterwarnings("ignore")

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data.tokens import synthetic_token_batch
from repro.launch.mesh import make_host_mesh
from repro.models import init_params, model_params_def
from repro.sharding import DEFAULT_RULES
from repro.training import build_train_step, get_optimizer


def train(arch: str, smoke: bool = True, steps: int = 100, batch: int = 8,
          seq: int = 64, lr: float = 1e-3, ckpt_dir: str | None = None,
          ckpt_every: int = 20, n_microbatches: int = 1, seed: int = 0,
          optimizer: str = "adamw", log_every: int = 10):
    cfg = get_config(arch, smoke=smoke)
    mesh = make_host_mesh(model=1)
    rules = DEFAULT_RULES
    opt = get_optimizer(optimizer)

    start_step = 0
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    with jax.sharding.set_mesh(mesh):
        params = init_params(model_params_def(cfg), jax.random.PRNGKey(seed),
                             jnp.float32)
        opt_state = opt.init(params)
        if mgr is not None and mgr.latest_step() is not None:
            start_step, tree, extra = mgr.restore(
                target={"params": params, "opt": opt_state})
            params, opt_state = tree["params"], tree["opt"]
            start_step += 1
            print(f"[resume] from step {start_step} ({extra})", flush=True)

        step_fn = jax.jit(build_train_step(cfg, rules, opt, lr=lr,
                                           n_microbatches=n_microbatches),
                          donate_argnums=(0, 1))
        durations: list[float] = []
        for step in range(start_step, steps):
            b = synthetic_token_batch(cfg.vocab_size, batch, seq, seed=seed,
                                      step=step)
            b = {k: jnp.asarray(v) for k, v in b.items()}
            t0 = time.perf_counter()
            params, opt_state, metrics = step_fn(params, opt_state, b)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            durations.append(dt)
            med = float(np.median(durations[-50:]))
            if len(durations) > 5 and dt > 3.0 * med:
                print(f"[straggler] step {step}: {dt:.3f}s vs median "
                      f"{med:.3f}s", flush=True)
            if step % log_every == 0:
                print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"{dt*1e3:.0f}ms", flush=True)
            if mgr is not None and (step + 1) % ckpt_every == 0:
                mgr.save(step, {"params": params, "opt": opt_state},
                         extra_meta={"arch": arch, "loss": float(metrics["loss"])},
                         blocking=False)
        if mgr is not None:
            mgr.save(steps - 1, {"params": params, "opt": opt_state},
                     extra_meta={"arch": arch}, blocking=True)
    return params, float(metrics["loss"])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--optimizer", default="adamw")
    args = ap.parse_args()
    train(args.arch, smoke=args.smoke, steps=args.steps, batch=args.batch,
          seq=args.seq, lr=args.lr, ckpt_dir=args.ckpt_dir,
          ckpt_every=args.ckpt_every, n_microbatches=args.microbatches,
          optimizer=args.optimizer)


if __name__ == "__main__":
    main()
