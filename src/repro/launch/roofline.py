"""Roofline accounting from compiled dry-run artifacts.

Hardware model (TPU v5e-class target):
  peak_flops = 197e12 bf16 FLOP/s per chip
  hbm_bw     = 819e9  B/s per chip
  link_bw    = 50e9   B/s per ICI link

Terms (per step, seconds):
  compute    = FLOPs_global / (chips * peak)
  memory     = HBM bytes_global / (chips * hbm_bw)
  collective = collective bytes (per-device, ring-equivalent) / link_bw

cost_analysis() reports PER-DEVICE flops/bytes of the post-SPMD module, so
the chips factor cancels: compute = flops_per_device / peak, etc.

Collective bytes are parsed from the compiled HLO: every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute result shape,
with ring-algorithm multipliers (all-reduce 2x, others 1x, (n-1)/n ~ 1).
"""
from __future__ import annotations

import re

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?P<type>\([^)]*\)|\S+)\s+"
    r"(?P<kind>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<suffix>-start|-done)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_MULT = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
         "all-to-all": 1.0, "collective-permute": 1.0}


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    by_kind: dict[str, float] = {}
    count: dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        if m.group("suffix") == "-done":
            continue  # the -start op already carries the transfer
        kind = m.group("kind")
        b = _type_bytes(m.group("type")) * _MULT[kind]
        by_kind[kind] = by_kind.get(kind, 0.0) + b
        count[kind] = count.get(kind, 0) + 1
    return {"total_bytes": sum(by_kind.values()),
            "by_kind": {k: {"bytes": v, "count": count[k]}
                        for k, v in by_kind.items()}}


def scan_correction_flops(cfg, shape, n_devices: int) -> float:
    """Per-device FLOPs hidden inside sequence-level scans that even the
    unrolled analysis lowering keeps rolled (sLSTM's recurrent matmuls,
    mamba's chunked associative scan). Analytic, train/prefill only."""
    if shape.kind == "decode":
        return 0.0  # single-step: no seq scan
    from repro.models.transformer import _layer_specs
    specs = _layer_specs(cfg)
    tokens = shape.global_batch * shape.seq_len
    mult = 3.0 if shape.kind == "train" else 1.0  # fwd + ~2x bwd
    total = 0.0
    n_slstm = sum(1 for s in specs if s.mixer == "slstm")
    if n_slstm:
        # 5 DxD matmuls per token per layer (wz, wi, wf, wo, rz)
        total += n_slstm * 2.0 * tokens * cfg.d_model ** 2 * 5
    n_mamba = sum(1 for s in specs if s.mixer == "mamba")
    if n_mamba:
        din = cfg.mamba_expand * cfg.d_model
        # associative scan: ~3 flops/elem/level, log2(chunk)+chain levels
        levels = 10
        total += n_mamba * 3.0 * tokens * din * cfg.mamba_d_state * levels
    return total * mult / n_devices


def roofline_terms(flops_per_device: float, bytes_per_device: float,
                   collective_bytes_per_device: float) -> dict:
    compute = flops_per_device / PEAK_FLOPS
    memory = bytes_per_device / HBM_BW
    collective = collective_bytes_per_device / LINK_BW
    terms = {"compute_s": compute, "memory_s": memory,
             "collective_s": collective}
    dominant = max(terms, key=terms.get)
    total = max(compute, memory, collective)
    return {**terms, "dominant": dominant.replace("_s", ""),
            "bound_step_s": total,
            "roofline_fraction": (compute / total) if total > 0 else None}
