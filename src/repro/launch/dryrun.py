import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and capture memory/cost/collective evidence.

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only]

Results are cached as JSON under results/dryrun/<cell>.json; the roofline
report (launch/roofline.py, EXPERIMENTS.md) reads from there.
"""  # noqa: E402
import argparse
import json
import time
import traceback
import warnings

warnings.filterwarnings("ignore")

import jax                                    # noqa: E402
import jax.numpy as jnp                       # noqa: E402

from repro.configs import SHAPES, get_config  # noqa: E402
from repro.configs.base import ARCH_IDS       # noqa: E402
from repro.launch import inputs as inp        # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.roofline import (collective_bytes_from_hlo, roofline_terms,  # noqa: E402
                                   scan_correction_flops)
from repro.models import abstract_params, model_params_def  # noqa: E402
from repro.models.transformer import active_params, cache_def, count_params  # noqa: E402
from repro.serving.decode import build_serve_step, prefill_logits  # noqa: E402
from repro.sharding import DEFAULT_RULES, logical_to_pspec  # noqa: E402
from repro.models.params import param_specs  # noqa: E402
from repro.training import build_train_step, get_optimizer  # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

# ------------------------------------------------------------------------
# cell policy
# ------------------------------------------------------------------------

SUBQUADRATIC = {"gemma3-4b", "jamba-v0.1-52b", "xlstm-125m"}

# Named sharding-rule presets (hillclimb levers; scripts/hillclimb_cell.py
# selects with rules=<name>).
RULES_PRESETS = {
    "default": DEFAULT_RULES,
    # pure data parallelism over every mesh axis — the right layout for
    # sub-1B models where TP all-reduces dwarf compute (xlstm hillclimb)
    "dp_only": {**DEFAULT_RULES,
                "batch": ("pod", "data", "model"),
                "heads_act": None, "vocab_act": None, "exp_act": None,
                "embed": None, "embed_tp": ("pod", "data"),
                "heads": None, "kv_heads": None, "mlp": ("pod", "data"),
                "vocab": ("pod", "data"), "experts": None},
    # DP for the transformer body, vocab/logits stay model-sharded (the
    # HC-3 iteration-2 layout: avoids both TP activation all-reduces AND
    # replicated-logits blowup)
    "dp_body": {**DEFAULT_RULES,
                "heads_act": None, "exp_act": None,
                "embed": None, "embed_tp": None,
                "heads": None, "kv_heads": None, "mlp": None,
                "experts": None},
}

# arch -> optimizer (HBM-fit choice, see DESIGN.md / EXPERIMENTS.md)
OPTIMIZER = {
    "deepseek-v2-236b": "adafactor",
    "deepseek-v3-671b": "adafactor",
    "jamba-v0.1-52b": "adafactor",
    "yi-34b": "adafactor",
}
ACCUM_DTYPE = {"deepseek-v3-671b": jnp.bfloat16, "deepseek-v2-236b": jnp.bfloat16}


def applicable(arch: str, shape_name: str) -> tuple[bool, str]:
    if shape_name == "long_500k" and arch not in SUBQUADRATIC:
        return False, ("full-attention arch: 500k-token cell requires "
                       "sub-quadratic attention (DESIGN.md SArch-applicability)")
    return True, ""


def runtime_choices(arch, shape, multi_pod):
    data_shards = 32 if multi_pod else 16
    per_shard = max(shape.global_batch // data_shards, 1)
    n_micro = per_shard  # 1 sample per shard per microbatch
    return {"optimizer": OPTIMIZER.get(arch, "adamw"),
            "n_microbatches": n_micro,
            "accum_dtype": ACCUM_DTYPE.get(arch, jnp.float32)}


# ------------------------------------------------------------------------
# lowering
# ------------------------------------------------------------------------

def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               rules=None, overrides=None, analysis: bool = False):
    """Lower one cell. ``analysis=True`` unrolls every layer scan and uses
    n_microbatches=1 so cost_analysis/collective counts are per-step exact
    (XLA counts while bodies once); the default rolled lowering is the
    runtime artifact whose memory_analysis/compile success is the deliverable."""
    from repro.models import transformer as T
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    if shape_name == "long_500k":
        cfg = cfg.replace(decode_kv_shard="seq")
    shape = SHAPES[shape_name]
    rules = rules or DEFAULT_RULES
    mesh = make_production_mesh(multi_pod=multi_pod)
    T.ANALYSIS_UNROLL = analysis

    try:
        with jax.sharding.set_mesh(mesh):
            params_abs = abstract_params(model_params_def(cfg),
                                         jnp.bfloat16, mesh, rules)
            if shape.kind == "train":
                rc = runtime_choices(arch, shape, multi_pod)
                opt = get_optimizer(rc["optimizer"])
                opt_abs = jax.eval_shape(opt.init, params_abs)
                axes = opt.state_axes(param_specs(model_params_def(cfg)))
                opt_abs = jax.tree.map(
                    lambda s, a: jax.ShapeDtypeStruct(
                        s.shape, s.dtype,
                        sharding=jax.sharding.NamedSharding(
                            mesh, logical_to_pspec(a, rules, mesh,
                                                   shape=s.shape))),
                    opt_abs, axes,
                    is_leaf=lambda x: isinstance(x, tuple) and not any(
                        hasattr(e, "shape") for e in x))
                batch = inp.batch_specs(cfg, shape, mesh, rules)
                n_micro = 1 if analysis else rc["n_microbatches"]
                step = build_train_step(cfg, rules, opt,
                                        n_microbatches=n_micro,
                                        accum_dtype=rc["accum_dtype"])
                jitted = jax.jit(step, donate_argnums=(0, 1))
                lowered = jitted.lower(params_abs, opt_abs, batch)
            elif shape.kind == "prefill":
                batch = inp.batch_specs(cfg, shape, mesh, rules)
                jitted = jax.jit(lambda p, b: prefill_logits(p, b, cfg, rules))
                lowered = jitted.lower(params_abs, batch)
            else:  # decode
                cache_abs = abstract_params(
                    cache_def(cfg, shape.global_batch, shape.seq_len,
                              enc_len=inp.ENC_LEN),
                    jnp.bfloat16, mesh, rules)
                batch = inp.decode_batch_specs(cfg, shape, mesh, rules)
                step = build_serve_step(cfg, rules)
                jitted = jax.jit(step, donate_argnums=(1,))
                lowered = jitted.lower(params_abs, cache_abs, batch)
    finally:
        T.ANALYSIS_UNROLL = False
    return cfg, shape, mesh, lowered


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             rules=None, overrides=None, tag="", skip_analysis=False) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell_id = f"{arch}__{shape_name}__{mesh_name}" + (f"__{tag}" if tag else "")
    out_path = os.path.join(out_dir, cell_id + ".json")
    ok, reason = applicable(arch, shape_name)
    if not ok:
        rec = {"cell": cell_id, "status": "skipped", "reason": reason}
        _save(out_path, rec)
        return rec

    try:
        # ---- runtime artifact: rolled scans, microbatched, donated ----
        t0 = time.perf_counter()
        cfg, shape, mesh, lowered = lower_cell(arch, shape_name, multi_pod,
                                               rules, overrides)
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0
        mem = compiled.memory_analysis()
        mem_rec = {k: getattr(mem, k, None)
                   for k in ("argument_size_in_bytes", "output_size_in_bytes",
                             "temp_size_in_bytes", "alias_size_in_bytes")}
        hbm_gb = ((mem_rec.get("argument_size_in_bytes") or 0)
                  + (mem_rec.get("temp_size_in_bytes") or 0)
                  - (mem_rec.get("alias_size_in_bytes") or 0)
                  + (mem_rec.get("output_size_in_bytes") or 0)) / 1e9
        del compiled, lowered

        # ---- analysis artifact: unrolled, exact per-step cost ----
        n_dev = mesh.size
        if skip_analysis:
            cost, coll, t_acompile = {}, {"total_bytes": 0.0, "by_kind": {}}, None
        else:
            t0 = time.perf_counter()
            _, _, _, alow = lower_cell(arch, shape_name, multi_pod, rules,
                                       overrides, analysis=True)
            acomp = alow.compile()
            t_acompile = time.perf_counter() - t0
            cost = acomp.cost_analysis() or {}
            coll = collective_bytes_from_hlo(acomp.as_text())
            del acomp, alow

        flops_per_dev = float(cost.get("flops", 0.0))
        flops_per_dev += scan_correction_flops(cfg, shape, n_dev)
        bytes_per_dev = float(cost.get("bytes accessed", 0.0))
        tokens = shape.global_batch * (shape.seq_len
                                       if shape.kind in ("train", "prefill")
                                       else 1)
        n_active = active_params(cfg)
        model_flops = (6.0 if shape.kind == "train" else 2.0) * n_active * tokens
        rec = {
            "cell": cell_id, "status": "ok", "arch": arch,
            "shape": shape_name, "mesh": mesh_name, "n_devices": n_dev,
            "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
            "analysis_compile_s": round(t_acompile, 1) if t_acompile else None,
            "params_total": count_params(cfg), "params_active": n_active,
            "tokens_per_step": tokens,
            "flops_per_device": flops_per_dev,
            "flops_global": flops_per_dev * n_dev,
            "bytes_per_device": bytes_per_dev,
            "collective_bytes_per_device": coll["total_bytes"],
            "collectives": coll["by_kind"],
            "memory": mem_rec, "hbm_gb_per_device": round(hbm_gb, 3),
            "model_flops": model_flops,
            "useful_flops_ratio": (model_flops / (flops_per_dev * n_dev))
            if flops_per_dev else None,
            "roofline": roofline_terms(flops_per_dev, bytes_per_dev,
                                       coll["total_bytes"]),
        }
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec = {"cell": cell_id, "status": "error",
               "error": f"{type(e).__name__}: {e}",
               "trace": traceback.format_exc()[-2000:]}
    _save(out_path, rec)
    return rec


def _save(path, rec):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as fh:
        json.dump(rec, fh, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=os.path.abspath(RESULTS_DIR))
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--skip-analysis", action="store_true",
                    help="runtime lowering only (compile + memory evidence); "
                         "roofline terms come from depth-extrapolated runs")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "pod2x16x16" if mp else "pod16x16"
                cell = f"{arch}__{shape}__{mesh_name}"
                path = os.path.join(args.out, cell + ".json")
                if os.path.exists(path) and not args.force:
                    with open(path) as fh:
                        rec = json.load(fh)
                    if rec.get("status") in ("ok", "skipped"):
                        print(f"[cached] {cell}: {rec['status']}")
                        continue
                rec = run_cell(arch, shape, mp, args.out,
                               skip_analysis=args.skip_analysis)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (f" dominant={r['dominant']}"
                             f" compile={rec['compile_s']}s")
                elif status == "error":
                    extra = " " + rec["error"][:120]
                print(f"[{status}] {cell}{extra}", flush=True)


if __name__ == "__main__":
    main()
