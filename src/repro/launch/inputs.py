"""ShapeDtypeStruct input stand-ins for every (arch x shape) dry-run cell.

Weak-type-correct, shardable, zero allocation. Modality frontends are stubs
per the assignment: seamless gets precomputed audio-frame embeddings,
qwen2-vl gets precomputed vision-patch embeddings.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs.base import ModelConfig, ShapeConfig
from repro.sharding import logical_to_pspec

ENC_LEN = 4096       # stubbed audio-frame count (seamless)
N_PATCHES = 256      # stubbed vision patches (qwen2-vl)


def _sds(shape, dtype, axes, mesh, rules):
    sharding = None
    if mesh is not None:
        sharding = NamedSharding(mesh, logical_to_pspec(axes, rules, mesh,
                                                        shape=shape))
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh=None, rules=None,
                dtype=jnp.bfloat16):
    """Abstract batch for train/prefill (full sequence)."""
    B, S = shape.global_batch, shape.seq_len
    specs = {
        "tokens": _sds((B, S), jnp.int32, ("batch", "seq"), mesh, rules),
    }
    if shape.kind == "train":
        specs["targets"] = _sds((B, S), jnp.int32, ("batch", "seq"), mesh, rules)
        specs["mask"] = _sds((B, S), jnp.float32, ("batch", "seq"), mesh, rules)
    if cfg.is_encoder_decoder:
        specs["frames"] = _sds((B, min(S, ENC_LEN), cfg.d_model), dtype,
                               ("batch", "seq", "embed_act"), mesh, rules)
    if cfg.frontend == "vision_patches":
        specs["patch_embeds"] = _sds((B, N_PATCHES, cfg.d_model), dtype,
                                     ("batch", None, "embed_act"), mesh, rules)
    if cfg.rope_kind == "mrope":
        specs["positions"] = _sds((B, 3, S), jnp.int32,
                                  ("batch", None, "seq"), mesh, rules)
    return specs


def decode_batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh=None,
                       rules=None, dtype=jnp.bfloat16):
    """Abstract one-token decode batch: the KV cache holds shape.seq_len."""
    B = shape.global_batch
    specs = {
        "tokens": _sds((B, 1), jnp.int32, ("batch", None), mesh, rules),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }
    if cfg.is_encoder_decoder:
        specs["enc_out"] = _sds((B, ENC_LEN, cfg.d_model), dtype,
                                ("batch", None, "embed_act"), mesh, rules)
    if cfg.rope_kind == "mrope":
        specs["positions"] = _sds((B, 3, 1), jnp.int32,
                                  ("batch", None, None), mesh, rules)
    return specs


def concrete_batch(cfg: ModelConfig, batch: int, seq: int, seed=0,
                   dtype=jnp.float32):
    """Small REAL batch for smoke tests / examples (reduced configs)."""
    from repro.data.tokens import synthetic_token_batch
    import numpy as np
    b = synthetic_token_batch(cfg.vocab_size, batch, seq, seed=seed)
    out = {k: jnp.asarray(v) for k, v in b.items()}
    if cfg.is_encoder_decoder:
        rng = np.random.default_rng(seed)
        out["frames"] = jnp.asarray(
            rng.normal(size=(batch, max(seq // 2, 4), cfg.d_model)) * 0.02, dtype)
    if cfg.frontend == "vision_patches":
        rng = np.random.default_rng(seed + 1)
        n_p = min(8, seq // 2)
        out["patch_embeds"] = jnp.asarray(
            rng.normal(size=(batch, n_p, cfg.d_model)) * 0.02, dtype)
    if cfg.rope_kind == "mrope":
        pos = jnp.broadcast_to(jnp.arange(seq)[None, None], (batch, 3, seq))
        out["positions"] = pos.astype(jnp.int32)
    return out
