"""Production meshes. Functions (not module constants) so importing never
touches jax device state — the dry-run must set XLA_FLAGS first."""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(data: int | None = None, model: int = 1):
    """Small mesh over whatever devices exist (tests, examples)."""
    n = len(jax.devices())
    data = data or (n // model)
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(AxisType.Auto, AxisType.Auto))
