"""Classifier-facing API: the ``SVC`` estimator facade plus the bias /
decision-function / accuracy helpers it is built from.

``SVC`` is the intended public entry point for single-model use — fit /
predict / cross_validate over the Study API — so the low-level
``bias_from_solution``/``predict`` pair stops being the de-facto public
interface (they remain exported for the drivers and for power users)."""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.svm.smo import SMOResult


def bias_from_solution(res: SMOResult, y: jnp.ndarray, train_mask: jnp.ndarray,
                       C: float) -> jnp.ndarray:
    """b such that decision(x) = sum_i alpha_i y_i K(x_i, x) + b.

    KKT: for 0 < alpha_i < C, f_i = w.x_i - y_i = -b, so b = -mean(f | I_m);
    if the free set is empty fall back to -(b_up + b_low)/2 (LibSVM rule).
    """
    free = train_mask & (res.alpha > 0) & (res.alpha < C)
    n_free = jnp.sum(free)
    mean_f = jnp.sum(jnp.where(free, res.f, 0.0)) / jnp.maximum(n_free, 1)
    fallback = (res.b_up + res.b_low) / 2.0
    return -jnp.where(n_free > 0, mean_f, fallback)


@jax.jit
def decision_function(K_test_train: jnp.ndarray, y_train: jnp.ndarray,
                      alpha: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return K_test_train @ (alpha * y_train) + b


def predict(K_test_train, y_train, alpha, b):
    return jnp.where(decision_function(K_test_train, y_train, alpha, b) >= 0, 1, -1)


def accuracy(pred: jnp.ndarray, y_true: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean((pred == y_true).astype(jnp.float64))


class SVC:
    """Small estimator facade over the Study API (scikit-learn-flavoured).

    ``fit`` declares the single training solve as a one-lane plan and runs
    it through ``repro.core.study.run_plan`` — the same engine, pool and
    evaluation machinery the CV/grid drivers use — then stores the dual
    solution and recovered bias. ``cross_validate`` forwards to the
    ``run_cv`` plan builder on the fitted hyper-parameters.

    Labels may be any two values; they are mapped to {-1, +1} by sorted
    order and mapped back in ``predict``.
    """

    def __init__(self, C: float = 1.0, gamma: float | str = "scale",
                 kind: str = "rbf", tol: float = 1e-3,
                 max_iter: int = 10_000_000, kernel_backend: str = "jnp",
                 shrink_every: int | str = 0, shrink_quantum: int = 128):
        self.C = float(C)
        self.gamma = gamma
        self.kind = kind
        self.tol = float(tol)
        self.max_iter = int(max_iter)
        self.kernel_backend = kernel_backend
        # active-set shrinking knobs (DESIGN.md §Shrinking): 0 = off
        # (bit-identical solve), "auto" = cost-model verdict
        self.shrink_every = shrink_every
        self.shrink_quantum = int(shrink_quantum)

    def _resolve_gamma(self, X) -> float:
        if self.gamma == "scale":   # sklearn convention: 1 / (d * Var[X])
            return float(1.0 / (X.shape[1] * max(float(jnp.var(X)), 1e-12)))
        return float(self.gamma)

    def _encode(self, y) -> jnp.ndarray:
        y = np.asarray(y)
        self.classes_ = np.unique(y)
        if self.classes_.shape[0] != 2:
            raise ValueError(f"SVC is binary; got classes {self.classes_}")
        return jnp.asarray(np.where(y == self.classes_[1], 1.0, -1.0),
                           jnp.float64)

    def fit(self, X, y) -> "SVC":
        from repro.core.study import Plan, run_plan
        from repro.svm.kernels import kernel_matrix

        X = jnp.asarray(X, jnp.float64)
        y_pm = self._encode(y)
        n = X.shape[0]
        self.gamma_ = self._resolve_gamma(X)
        K = kernel_matrix(X, X, kind=self.kind, gamma=self.gamma_,
                          backend=self.kernel_backend)
        from repro.svm.engine import DenseKernel
        plan = Plan(sources={"fit": DenseKernel(K)}, y=y_pm, tol=self.tol,
                    shrink_every=self.shrink_every,
                    shrink_quantum=self.shrink_quantum)
        plan.lane("fit", train_mask=jnp.ones(n, bool), C=self.C,
                  alpha0=jnp.zeros(n, K.dtype), f0=-y_pm,
                  max_iter=self.max_iter)
        sres = run_plan(plan)
        res = sres.results["fit"]
        self.X_ = X
        self.y_ = y_pm
        self.result_ = res
        self.b_ = bias_from_solution(res, y_pm, jnp.ones(n, bool), self.C)
        self.n_iter_ = int(res.n_iter)
        self.converged_ = bool(res.converged)
        return self

    def decision_function(self, X) -> jnp.ndarray:
        from repro.svm.kernels import kernel_matrix
        Kt = kernel_matrix(jnp.asarray(X, jnp.float64), self.X_,
                           kind=self.kind, gamma=self.gamma_,
                           backend=self.kernel_backend)
        return decision_function(Kt, self.y_, self.result_.alpha, self.b_)

    def predict(self, X) -> np.ndarray:
        pm = np.asarray(self.decision_function(X)) >= 0
        return np.where(pm, self.classes_[1], self.classes_[0])

    def score(self, X, y) -> float:
        return float(np.mean(self.predict(X) == np.asarray(y)))

    def cross_validate(self, X, y, k: int = 10, method: str = "sir", **kw):
        """Alpha-seeded k-fold CV of THIS estimator's hyper-parameters on
        (X, y): builds the dataset record and forwards to the ``run_cv``
        plan builder (all its knobs — checkpointing, chunking, straggler
        policy — pass through ``**kw``). Returns the ``CVReport``."""
        from repro.core.cv import run_cv
        from repro.data.svm_suite import SVMDataset

        if self.kind != "rbf":
            # run_cv computes an RBF kernel; silently cross-validating a
            # different kernel than fit() trains would score the wrong model
            raise ValueError(
                f"cross_validate supports kind='rbf' only (estimator has "
                f"kind={self.kind!r}); run_cv's kernel is RBF")
        X = np.asarray(X, np.float64)
        y_pm = np.asarray(self._encode(y), np.int64)
        ds = SVMDataset(name="svc", X=X, y=y_pm, C=self.C,
                        gamma=self._resolve_gamma(jnp.asarray(X)))
        kw.setdefault("kernel_backend", self.kernel_backend)
        kw.setdefault("shrink_every", self.shrink_every)
        kw.setdefault("shrink_quantum", self.shrink_quantum)
        return run_cv(ds, k=k, method=method, tol=self.tol,
                      max_iter=self.max_iter, **kw)
