"""Classifier-facing helpers: bias recovery, decision function, accuracy."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.svm.smo import SMOResult


def bias_from_solution(res: SMOResult, y: jnp.ndarray, train_mask: jnp.ndarray,
                       C: float) -> jnp.ndarray:
    """b such that decision(x) = sum_i alpha_i y_i K(x_i, x) + b.

    KKT: for 0 < alpha_i < C, f_i = w.x_i - y_i = -b, so b = -mean(f | I_m);
    if the free set is empty fall back to -(b_up + b_low)/2 (LibSVM rule).
    """
    free = train_mask & (res.alpha > 0) & (res.alpha < C)
    n_free = jnp.sum(free)
    mean_f = jnp.sum(jnp.where(free, res.f, 0.0)) / jnp.maximum(n_free, 1)
    fallback = (res.b_up + res.b_low) / 2.0
    return -jnp.where(n_free > 0, mean_f, fallback)


@jax.jit
def decision_function(K_test_train: jnp.ndarray, y_train: jnp.ndarray,
                      alpha: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return K_test_train @ (alpha * y_train) + b


def predict(K_test_train, y_train, alpha, b):
    return jnp.where(decision_function(K_test_train, y_train, alpha, b) >= 0, 1, -1)


def accuracy(pred: jnp.ndarray, y_true: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean((pred == y_true).astype(jnp.float64))
