"""The one SMO engine: a single iteration core behind pluggable kernel-row
providers, chunked resumable dispatch, and batched fold execution.

Why one engine
--------------
The repo used to carry two divergent copies of the SMO iteration: the dense
LibSVM-parity solver (``smo.py``) and the sharded on-demand-kernel solver
(``distributed.py``). Every working-set-selection or update fix had to land
twice, and the CV driver could only use the dense copy, strictly one fold at
a time. This module hosts the WSS-1/WSS-2 selection, the box-clipped rank-2
update, and the duality-gap logic exactly once; ``smo.smo_solve`` and
``distributed.smo_iterations`` are thin wrappers over it (see DESIGN.md).

KernelSource protocol
---------------------
A kernel source answers "give me kernel row i" for the engine, plus the
scalar read / scatter-update idioms that match how the row is produced.
Sources also answer the *residency* half of the protocol — ``dtype``,
``fused`` and ``nbytes`` — which must stay cheap (no kernel compute): the
lane pool's source cache (``svm/sources.py``) types and sizes lanes from
those alone, and a ``KernelSpec`` factory answers them for a kernel that
has not been materialized yet:

* ``DenseKernel``  — precomputed K; direct indexing (the LibSVM-parity path).
* ``OnDemandRBF``  — recompute K[:, i] from X each iteration
  (``impl="gather"`` dynamic-slices x_i; ``impl="onehot"`` reads x_i and all
  scalars via one-hot contractions so the instance axis can stay sharded).
* ``FusedRBF``     — WSS-1 pair selection from f alone, then BOTH kernel
  rows in one pass over X (halves the dominant HBM stream).
* ``PallasRBF``    — FusedRBF's math as ONE fused Pallas launch per
  iteration (``kernels/smo_step.py``): kernel-row pair + rank-2 f-update
  in a single blocked pass over X, never materializing rows in HBM.
  ``streams_rows = True`` — the engine routes the f-update through
  ``update_f(f, i, j, delta)`` instead of asking for rows.
* ``ShardedRBF``   — OnDemandRBF/FusedRBF plus logical-axis sharding
  constraints for the production mesh (the old ``distributed.py`` path).

All sources are jax pytrees: array state (K or X) is traced, configuration
(gamma, impl) is static, so jit caches one executable per source kind.

Chunked dispatch
----------------
Instead of one monolithic ``lax.while_loop`` running to convergence, the
host dispatches jit'd chunks of ``chunk_iters`` iterations and inspects the
``done`` flag between chunks. The chunk is:

* the mid-fold checkpoint unit — ``solve(..., on_chunk=...)`` lets the CV
  driver snapshot (alpha, f, n_iter) between chunks, so recovery no longer
  loses an entire in-flight fold;
* the retry unit the distributed scheduler assumes (``smo_iterations`` is
  exactly one chunk).

Convergence is detected *inside* the chunk body (a converged state passes
through untouched), which makes the same body ``vmap``-safe for batched
execution: converged folds freeze while the rest keep iterating.

Bit-parity contract
-------------------
For a given source the engine replays the seed solvers' floating-point ops
in the same order, so ``smo_solve`` (DenseKernel) and ``smo_iterations``
(ShardedRBF) produce bit-identical alpha/f to the pre-engine implementations
(covered by tests/test_engine.py).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.rbf import auto_interpret
from repro.kernels.smo_step import fused_smo_step
from repro.sharding import constrain

_INF = jnp.inf
_TAU = 1e-12

#: logical-axis rules for the sharded sources (instances over pod x data,
#: features over model) — re-exported by ``repro.svm.distributed``.
RULES = {
    "inst": ("pod", "data"),
    "feat": "model",
    None: None,
}


class SMOResult(NamedTuple):
    alpha: jnp.ndarray      # (n,) dual variables (0 outside train_mask)
    f: jnp.ndarray          # (n,) optimality indicators, globally consistent
    n_iter: jnp.ndarray     # () int64 — SMO iterations executed
    converged: jnp.ndarray  # () bool
    b_up: jnp.ndarray       # () min f over I_up at exit
    b_low: jnp.ndarray      # () max f over I_low at exit


class EngineState(NamedTuple):
    """Resumable solver state — the unit chunks pass between themselves,
    checkpoints serialize, and the batched driver stacks along axis 0.

    The lane helpers below are the batched-state vocabulary: the scheduler
    ``stack``s single-lane states into a packed batch and ``lane``-extracts
    them back at retirement or for a single-lane (sequential-program)
    dispatch; ``gather``/``scatter`` compact a batched state to a lane
    subset and write it back — for callers that edit a batch in place
    (e.g. reseeding a subset of grid lanes) rather than round-tripping
    through per-lane states.
    """
    alpha: jnp.ndarray
    f: jnp.ndarray
    n_iter: jnp.ndarray   # () int — updates applied so far
    done: jnp.ndarray     # () bool — converged or iteration-capped

    @staticmethod
    def stack(states: "list[EngineState]") -> "EngineState":
        """Pack single-lane states into a batched state (axis 0 = lane)."""
        return jax.tree.map(lambda *xs: jnp.stack(xs), *states)

    def lane(self, i) -> "EngineState":
        """Extract lane ``i`` of a batched state as a single-lane state."""
        return jax.tree.map(lambda a: a[i], self)

    def gather(self, idx) -> "EngineState":
        """Compact a batched state to the lanes in ``idx`` (repacking)."""
        return jax.tree.map(lambda a: a[jnp.asarray(idx)], self)

    def scatter(self, idx, sub: "EngineState") -> "EngineState":
        """Write the lanes of ``sub`` back into positions ``idx``."""
        return jax.tree.map(lambda a, b: a.at[jnp.asarray(idx)].set(b),
                            self, sub)


def _sets(alpha, y, mask, C):
    """I_up / I_low membership (paper Eq. 4): I_up = I_u + I_m, I_low = I_l + I_m."""
    pos, neg = y > 0, y < 0
    at_lo, at_hi = alpha <= 0.0, alpha >= C
    i_up = mask & ~((pos & at_hi) | (neg & at_lo))
    i_low = mask & ~((pos & at_lo) | (neg & at_hi))
    return i_up, i_low


def _guarded_first(v, m, nan):
    """First index where ``v == m`` — or the first NaN index if any (NaN
    wins, as in ``jnp.argmin``/``argmax``) — always in range."""
    idx = jnp.arange(v.shape[0])
    first = jnp.min(jnp.where(v == m, idx, v.shape[0]))
    first_nan = jnp.min(jnp.where(nan, idx, v.shape[0]))
    out = jnp.where(jnp.any(nan), first_nan, first)
    return jnp.minimum(out, v.shape[0] - 1)


def _argmin(v):
    """First index of the minimum. Same selection (and tie-breaking: first
    occurrence) as ``jnp.argmin``, but built from plain min reduces — XLA's
    variadic argmin reduce is an order of magnitude slower on CPU, and
    catastrophically so when vmapped over a fold batch.

    NaN-guarded: the naive ``v == jnp.min(v)`` is all-False when v contains
    a NaN (min propagates it), which used to return ``v.shape[0]`` — an
    out-of-range index that jax's clamped gather silently turned into
    "always pick the last row", so the solver spun on a bogus pair instead
    of surfacing the bad state.
    """
    nan = jnp.isnan(v)
    return _guarded_first(v, jnp.min(jnp.where(nan, _INF, v)), nan)


def _argmax(v):
    """First index of the maximum; NaN-guarded like ``_argmin``."""
    nan = jnp.isnan(v)
    return _guarded_first(v, jnp.max(jnp.where(nan, -_INF, v)), nan)


def optimality(alpha, f, y, train_mask, C):
    """(b_up, b_low, gap) of a state; gap = -inf when a working pair cannot
    be formed (empty I_up or I_low)."""
    i_up, i_low = _sets(alpha, y, train_mask, C)
    has = jnp.any(i_up) & jnp.any(i_low)
    b_up = jnp.min(jnp.where(i_up, f, _INF))
    b_low = jnp.max(jnp.where(i_low, f, -_INF))
    gap = jnp.where(has, b_low - b_up, -_INF)
    return b_up, b_low, gap


# --------------------------------------------------------------------------
# kernel-row providers
# --------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
class DenseKernel:
    """Precomputed kernel matrix — today's LibSVM-parity hot path.

    Direct indexing (``v[i]`` / ``.at[i].add``) is the right idiom both
    solo and under ``vmap``: the one-hot contraction alternative (which the
    sharded sources use to keep the instance axis distributed) was measured
    ~1.8x slower per batched iteration on CPU — the extra (b, n) masked
    passes cost more than the batched gathers they replace.

    ``fupdate`` selects the rank-2 indicator-update implementation:
    ``"jnp"`` is the plain expression, ``"pallas"`` routes through the
    fused ``kernels/smo_update.py`` tile kernel (elementwise, so the two
    are bit-identical), ``"auto"`` picks pallas off-CPU — the same
    backend auto-detect the kernels themselves use.
    """

    fused = False

    def __init__(self, K, fupdate: str = "auto"):
        self.K = K
        if fupdate == "auto":
            fupdate = "jnp" if jax.default_backend() == "cpu" else "pallas"
        self.fupdate = fupdate

    @property
    def dtype(self):
        return self.K.dtype

    @property
    def nbytes(self) -> int:
        """Bytes held resident by this source — what the kernel-source
        cache (svm/sources.py) accounts against its byte budget."""
        return int(self.K.nbytes)

    def diag(self):
        return jnp.diagonal(self.K)

    def row(self, i):
        return self.K[i]

    def rows2(self, i, j):
        return self.K[i], self.K[j]

    def read(self, v, i):
        return v[i]

    def update_alpha(self, alpha, i, j, y_i, y_j, delta):
        alpha = alpha.at[i].add(y_i * delta)
        return alpha.at[j].add(-y_j * delta)

    def update_f(self, f, K_i, K_j, delta):
        if self.fupdate == "pallas":
            from repro.kernels.smo_update import smo_f_update
            return smo_f_update(f, K_i, K_j, delta)
        return f + delta * (K_i - K_j)

    def rows_at(self, idx):
        """Kernel row slab K[idx, :] — same eval/reconstruction surface as
        the row-streaming sources, directly indexed."""
        return self.K[jnp.asarray(idx)]

    def matvec(self, v):
        """``K @ v`` — the unshrink reconstruction path (`shrink.py`)."""
        return self.K @ v

    def compact(self, idx):
        """Active-set gather for the shrinking scheduler: the kernel
        restricted to rows/columns ``idx`` (pads — index n — clamp to the
        last row, inert under the compact validity mask)."""
        idx = jnp.asarray(idx)
        return DenseKernel(self.K[idx][:, idx], fupdate=self.fupdate)

    def constrain(self, v):
        return v

    def tree_flatten(self):
        return (self.K,), (self.fupdate,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], fupdate=aux[0])


@jax.tree_util.register_pytree_node_class
class OnDemandRBF:
    """RBF kernel rows recomputed from X each iteration (K_ii = 1).

    ``impl="gather"``: x_i = X[i] — a dynamic-slice; on a 2D-sharded X the
    SPMD partitioner lowers this to large all-gathers.

    ``impl="onehot"``: x_i = onehot(i) @ X — a skinny matvec reducing over
    the *sharded instance axis*; scalar reads and the alpha scatter use the
    same trick, dropping collective bytes per iteration ~1000x.
    """

    def __init__(self, X, gamma: float, sq_norms=None, impl: str = "gather"):
        self.X = X
        self.gamma = gamma
        self.sq_norms = jnp.sum(X * X, axis=-1) if sq_norms is None else sq_norms
        self.impl = impl

    @property
    def dtype(self):
        return self.X.dtype

    @property
    def fused(self):
        return self.impl == "onehot_fused"

    def diag(self):
        return jnp.ones(self.X.shape[0], self.X.dtype)

    def row(self, i):
        X = self.X
        if self.impl.startswith("onehot"):
            oh = (jnp.arange(X.shape[0]) == i).astype(X.dtype)
            xi = oh @ X                                 # (d,) psum over inst
        else:
            xi = X[i]                                   # (d,) gathered row
        cross = X @ xi                                  # (n,) feature-axis psum
        d2 = jnp.maximum(self.sq_norms + jnp.sum(xi * xi) - 2.0 * cross, 0.0)
        return self.constrain(jnp.exp(-self.gamma * d2))

    def rows2(self, i, j):
        """Both kernel rows in ONE pass over X (the fused-WSS-1 trick:
        halves the dominant per-iteration HBM stream; WSS-1 needs ~10-30%
        more iterations than WSS-2 — net win when memory-bound)."""
        X = self.X
        oh2 = jnp.stack([(jnp.arange(X.shape[0]) == i).astype(X.dtype),
                         (jnp.arange(X.shape[0]) == j).astype(X.dtype)])
        xij = oh2 @ X                                   # (2, d) psum over inst
        cross = X @ xij.T                               # (n, 2): one X stream
        d2 = jnp.maximum(self.sq_norms[:, None] + jnp.sum(xij * xij, 1)[None]
                         - 2.0 * cross, 0.0)
        K2 = jnp.exp(-self.gamma * d2)
        return self.constrain(K2[:, 0]), self.constrain(K2[:, 1])

    def read(self, v, i):
        if self.impl.startswith("onehot"):
            return jnp.sum(jnp.where(jnp.arange(v.shape[0]) == i, v, 0))
        return v[i]

    def update_alpha(self, alpha, i, j, y_i, y_j, delta):
        if self.impl.startswith("onehot"):
            idx = jnp.arange(alpha.shape[0])
            return alpha + jnp.where(idx == i, y_i * delta, 0.0) \
                - jnp.where(idx == j, y_j * delta, 0.0)
        alpha = alpha.at[i].add(y_i * delta)
        return alpha.at[j].add(-y_j * delta)

    def update_f(self, f, K_i, K_j, delta):
        return f + delta * (K_i - K_j)

    def rows_at(self, idx):
        """Kernel row slab K[idx, :] -> (t, n) — the evaluation path for
        K-less sources: O(t*n) transient, never n^2 resident."""
        Xi = self.X[jnp.asarray(idx)]
        d2 = jnp.maximum(jnp.sum(Xi * Xi, -1)[:, None] + self.sq_norms[None]
                         - 2.0 * (Xi @ self.X.T), 0.0)
        return jnp.exp(-self.gamma * d2)

    def matvec(self, v, *, block: int = 2048):
        """Streaming ``K @ v`` (``init_f`` on seeded lanes, unshrink
        reconstruction): kernel row blocks are formed and reduced
        immediately, O(block*n) transient memory."""
        n, d = self.X.shape
        pad = (-n) % block
        Xb = jnp.pad(self.X, ((0, pad), (0, 0))).reshape(-1, block, d)
        sqb = jnp.pad(self.sq_norms, (0, pad)).reshape(-1, block)

        def one(args):
            xb, sb = args
            d2 = jnp.maximum(sb[:, None] + self.sq_norms[None]
                             - 2.0 * (xb @ self.X.T), 0.0)
            return jnp.exp(-self.gamma * d2) @ v

        return jax.lax.map(one, (Xb, sqb)).reshape(-1)[:n]

    def compact(self, idx):
        """Active-set gather for the shrinking scheduler: the same source
        kind over ``X[idx]`` (so a compact ``PallasRBF`` streams only the
        active bytes). Pads — index n — clamp to the last row, inert under
        the compact validity mask. Goes through the pytree so every
        subclass compacts with its own aux config intact."""
        children, aux = self.tree_flatten()
        idx = jnp.asarray(idx)
        return type(self).tree_unflatten(aux,
                                         tuple(c[idx] for c in children))

    def constrain(self, v):
        return v

    def tree_flatten(self):
        return (self.X, self.sq_norms), (self.gamma, self.impl)

    @classmethod
    def tree_unflatten(cls, aux, children):
        X, sq_norms = children
        gamma, impl = aux
        return cls(X, gamma, sq_norms, impl)


@jax.tree_util.register_pytree_node_class
class FusedRBF(OnDemandRBF):
    """One-pass two-row RBF evaluation; forces WSS-1 pair selection (the
    second index must come from f alone so both rows stream together)."""

    def __init__(self, X, gamma: float, sq_norms=None, impl: str = "onehot_fused"):
        super().__init__(X, gamma, sq_norms, impl="onehot_fused")


@jax.tree_util.register_pytree_node_class
class PallasRBF(OnDemandRBF):
    """Row-streaming RBF source over the fused Pallas step kernel.

    Holds only X (``nbytes`` = X bytes, not n² kernel bytes): each SMO
    iteration is one blocked pass over X that computes the WSS-1 pair's
    kernel rows on the MXU and applies ``f += delta * (K_i - K_j)`` on the
    VPU in the same launch (``kernels/smo_step.py``) — the rows never hit
    HBM. ``streams_rows = True`` tells the engine to route the update
    through ``update_f(f, i, j, delta)`` / ``kij(i, j)`` instead of
    materializing rows; selection must therefore be WSS-1 (``fused``).

    Interpret-mode contract: on CPU (``interpret=None`` auto) the kernel
    runs with full-array blocks — no padding, one contraction step — so
    every op matches ``FusedRBF``'s jnp expression and alpha/f are
    bit-identical to ``FusedRBF``, solo and vmapped under the lane pool
    (tests/test_engine.py). Compiled launches use MXU-aligned blocks and
    carry the usual allclose guarantee only.
    """

    streams_rows = True

    def __init__(self, X, gamma: float, sq_norms=None,
                 impl: str = "onehot_fused", *, bm: int | None = None,
                 bk: int | None = None, interpret: bool | None = None):
        super().__init__(X, gamma, sq_norms, impl="onehot_fused")
        self.bm = bm
        self.bk = bk
        self.interpret = auto_interpret(interpret)

    @property
    def nbytes(self) -> int:
        """Resident bytes are X's — the whole point: the cache budget
        bounds rows-from-X sources by O(n*d), not O(n^2)."""
        return int(self.X.nbytes)

    def _pair(self, i, j):
        """The WSS pair's feature rows (2, d) via the onehot contraction
        (sharding-friendly, and exactly how ``rows2`` gathers them)."""
        X = self.X
        oh2 = jnp.stack([(jnp.arange(X.shape[0]) == i).astype(X.dtype),
                         (jnp.arange(X.shape[0]) == j).astype(X.dtype)])
        return oh2 @ X

    def kij(self, i, j):
        """K[i, j] for the eta denominator without keeping a row around.

        Interpret mode reuses the inherited one-pass ``rows2`` expression
        so the scalar is bit-identical to FusedRBF's (the parity
        contract); compiled mode uses the O(d) pair-only evaluation.
        """
        if self.interpret:
            K_i, _ = self.rows2(i, j)
            return self.read(K_i, j)
        xij = self._pair(i, j)
        d2 = jnp.maximum(jnp.sum((xij[0] - xij[1]) ** 2), 0.0)
        return jnp.exp(-self.gamma * d2)

    def update_f(self, f, i, j, delta):
        xij = self._pair(i, j)
        return fused_smo_step(f, self.X, xij, self.sq_norms, delta,
                              gamma=self.gamma, bm=self.bm, bk=self.bk,
                              interpret=self.interpret)

    # rows_at / matvec (the eval-slab and streaming-matvec paths) are
    # inherited from OnDemandRBF — the expressions are row-streaming
    # already, and sharing one definition keeps the reconstruction path
    # bit-identical across the RBF source family.

    def tree_flatten(self):
        return (self.X, self.sq_norms), \
            (self.gamma, self.impl, self.bm, self.bk, self.interpret)

    @classmethod
    def tree_unflatten(cls, aux, children):
        X, sq_norms = children
        gamma, impl, bm, bk, interpret = aux
        return cls(X, gamma, sq_norms, impl, bm=bm, bk=bk,
                   interpret=interpret)


@jax.tree_util.register_pytree_node_class
class ShardedRBF(OnDemandRBF):
    """OnDemandRBF plus logical-axis sharding constraints — the production
    mesh path (instances over ("pod","data"), features over "model"). Off
    a mesh scope the constraints are no-ops, so the same source serves
    single-device tests and the 512-chip dry-run."""

    def constrain(self, v):
        return constrain(v, ("inst",), RULES)


# --------------------------------------------------------------------------
# the single iteration core
# --------------------------------------------------------------------------

def _step(source, y, train_mask, C, diag, tol, it_cap, wss, state):
    """One SMO iteration: WSS pair selection + box-clipped rank-2 update.

    A state that is already optimal (or iteration-capped) passes through
    bit-unchanged with ``done`` set — this is what makes the same body safe
    under ``vmap`` (converged folds freeze) and lets chunks over-dispatch
    without overshooting.
    """
    alpha, f, it, done = state
    i_up, i_low = _sets(alpha, y, train_mask, C)
    has = jnp.any(i_up) & jnp.any(i_low)
    b_up = jnp.min(jnp.where(i_up, f, _INF))
    b_low = jnp.max(jnp.where(i_low, f, -_INF))
    gap = jnp.where(has, b_low - b_up, -_INF)
    # a NaN gap (NaN in f on an active row) can never satisfy gap <= tol, so
    # the solver would burn max_iter on a poisoned state; halt instead and
    # let _finalize report converged=False (the bad state surfaces)
    done = done | (gap <= tol) | (it >= it_cap) | jnp.isnan(gap)

    # --- select i: minimal f over I_up ---
    i = _argmin(jnp.where(i_up, f, _INF))
    f_i = source.read(f, i)
    streams = getattr(source, "streams_rows", False)
    if wss == "2":
        # LibSVM WSS-2: among j in I_low with f_j > f_i, maximise
        # (f_j - f_i)^2 / eta_j.
        K_i = source.row(i)
        diff = f - f_i
        eta = jnp.maximum(source.read(diag, i) + diag - 2.0 * K_i, _TAU)
        gain = jnp.where(i_low & (diff > 0), diff * diff / eta, -_INF)
        j = _argmax(gain)
        K_j = source.row(j)
    else:
        # WSS-1 (maximal violating pair): j from f alone, so fused sources
        # can evaluate both kernel rows in a single pass — and streaming
        # sources can defer them to the fused update launch entirely.
        j = _argmax(jnp.where(i_low, f, -_INF))
        if not streams:
            K_i, K_j = source.rows2(i, j)

    # --- analytic 2-variable update, delta >= 0 along (+y_i, -y_j) ---
    f_j = source.read(f, j)
    a_i, a_j = source.read(alpha, i), source.read(alpha, j)
    y_i, y_j = source.read(y, i), source.read(y, j)
    # K[i,j] for the eta denominator: a scalar hook for streaming sources
    # (no row in scope), the hoisted row read otherwise (pure dataflow —
    # bit-identical to reading it inline below)
    K_ij = source.kij(i, j) if streams else source.read(K_i, j)
    eta_ij = jnp.maximum(source.read(diag, i) + source.read(diag, j)
                         - 2.0 * K_ij, _TAU)
    delta = (f_j - f_i) / eta_ij
    hi_i = jnp.where(y_i > 0, C - a_i, a_i)
    hi_j = jnp.where(y_j > 0, a_j, C - a_j)
    delta = jnp.maximum(jnp.minimum(jnp.minimum(delta, hi_i), hi_j), 0.0)
    alpha_new = source.update_alpha(alpha, i, j, y_i, y_j, delta)
    alpha_new = jnp.clip(alpha_new, 0.0, C)  # kill fp dust at the box boundary
    # rank-2 update keeps f consistent for ALL rows (incl. masked);
    # streaming sources fuse row computation into the update launch
    if streams:
        f_new = source.constrain(source.update_f(f, i, j, delta))
    else:
        f_new = source.constrain(source.update_f(f, K_i, K_j, delta))

    alpha = jnp.where(done, alpha, alpha_new)
    f = jnp.where(done, f, f_new)
    it = jnp.where(done, it, it + 1)
    return EngineState(alpha, f, it, done)


def smo_chunk(source, y, train_mask, C, state: EngineState, *,
              n_iters: int, wss: str = "2", tol: float = 1e-3,
              it_cap=None) -> EngineState:
    """Run up to ``n_iters`` SMO iterations from ``state``.

    Pure function of its inputs with static shapes — safe to jit, to chain
    (chunk N+1 continues chunk N's iterate sequence bit-exactly), and to
    ``vmap`` over a batch of states/masks. ``it_cap`` (traced) bounds total
    ``n_iter`` across chunks, so a tail chunk never needs a retrace.
    """
    if source.fused and wss == "2":
        raise ValueError("fused kernel sources evaluate both rows in one "
                         "pass and require WSS-1 (wss='1')")
    C = jnp.asarray(C, source.dtype)
    if it_cap is None:
        it_cap = jnp.iinfo(jnp.int32).max
    it_cap = jnp.asarray(it_cap, state.n_iter.dtype)
    diag = source.diag()
    step = functools.partial(_step, source, y, train_mask, C, diag, tol,
                             it_cap, wss)

    def cond(carry):
        s, t = carry
        return (~s.done) & (t < n_iters)

    def body(carry):
        s, t = carry
        return step(s), t + 1

    state, _ = jax.lax.while_loop(cond, body, (state, jnp.zeros((), jnp.int32)))
    return state


@functools.partial(jax.jit, static_argnames=("n_iters", "wss"))
def chunk_jit(source, y, train_mask, C, tol, it_cap, state, n_iters, wss):
    """Jitted single-lane chunk — the dispatch unit of ``solve`` and the
    lane pool's width-1 (sequential-program) path."""
    return smo_chunk(source, y, train_mask, C, state, n_iters=n_iters,
                     wss=wss, tol=tol, it_cap=it_cap)


@functools.partial(jax.jit, static_argnames=("n_iters", "wss"))
def chunk_batched_jit(source, y, train_masks, Cs, tol, it_caps, states,
                      n_iters, wss):
    """One chunk over a batch of folds: a single top-level while_loop whose
    body vmaps ``_step`` over (train_mask, C, it_cap, state); source and y
    are shared across the batch. Per-fold convergence masking comes from the
    ``done`` freeze inside ``_step`` — a converged fold's state passes
    through bit-unchanged while stragglers keep iterating. (vmapping the
    body, not the while_loop, avoids the batching rule's second layer of
    full-state selects per iteration.) ``it_caps`` is per-lane — scheduler
    lanes carry their own iteration budgets — a scalar broadcasts."""
    it_caps = jnp.broadcast_to(jnp.asarray(it_caps, states.n_iter.dtype),
                               states.done.shape)
    diag = source.diag()

    def one(mask, C, cap, state):
        return _step(source, y, mask, jnp.asarray(C, source.dtype), diag,
                     tol, cap, wss, state)

    def cond(carry):
        s, t = carry
        return jnp.any(~s.done) & (t < n_iters)

    def body(carry):
        s, t = carry
        return jax.vmap(one)(train_masks, Cs, it_caps, s), t + 1

    states, _ = jax.lax.while_loop(cond, body,
                                   (states, jnp.zeros((), jnp.int32)))
    return states


def stack_sources(sources):
    """Stack same-kind, same-shape kernel sources along a new leading lane
    axis (array leaves stack, static aux must agree) — the operand for
    ``chunk_batched_sources_jit``."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *sources)


@functools.partial(jax.jit, static_argnames=("n_iters", "wss"))
def chunk_batched_sources_jit(sources, ys, train_masks, Cs, tol, it_caps,
                              states, n_iters, wss):
    """One chunk over a batch of lanes that each carry their OWN kernel
    operands: ``sources`` is a stacked source pytree (``stack_sources``,
    leading axis = lane) and ``ys`` is (b, n). This is the shrinking
    scheduler's compact-group program — every shrunk lane gathered its own
    active rows, so even lanes bucketed to the same ``(source, width,
    cap)`` program differ in operand *values*. vmap maps the source's
    array leaves (K or X) per lane and closes over the shared static
    config, so one program serves the whole bucket."""
    it_caps = jnp.broadcast_to(jnp.asarray(it_caps, states.n_iter.dtype),
                               states.done.shape)

    def one(src, y, mask, C, cap, state):
        return _step(src, y, mask, jnp.asarray(C, src.dtype), src.diag(),
                     tol, cap, wss, state)

    def cond(carry):
        s, t = carry
        return jnp.any(~s.done) & (t < n_iters)

    def body(carry):
        s, t = carry
        return jax.vmap(one)(sources, ys, train_masks, Cs, it_caps, s), t + 1

    states, _ = jax.lax.while_loop(cond, body,
                                   (states, jnp.zeros((), jnp.int32)))
    return states


# --------------------------------------------------------------------------
# drivers: single solve / batched solve
# --------------------------------------------------------------------------

def init_state(source, y, train_mask, alpha0, f0,
               n_iter0=0) -> EngineState:
    """Entry transform shared by every wrapper: zero alphas outside the
    training mask, cast to the source dtype, reset the done flag."""
    alpha0 = jnp.where(train_mask, alpha0, 0.0)
    return EngineState(alpha0.astype(source.dtype), f0.astype(source.dtype),
                       jnp.asarray(n_iter0, jnp.int64), jnp.zeros((), bool))


def finalize(state: EngineState, y, train_mask, C, tol) -> SMOResult:
    """Close an ``EngineState`` into an ``SMOResult``: optimality is a pure
    function of (alpha, f), so finalizing a restored snapshot reproduces the
    pre-crash result exactly (the lane pool and the Study resume rely on
    this)."""
    b_up, b_low, gap = optimality(state.alpha, state.f, y, train_mask, C)
    return SMOResult(alpha=state.alpha, f=state.f, n_iter=state.n_iter,
                     converged=gap <= tol, b_up=b_up, b_low=b_low)


# historical private names, kept for callers/tests written before the lane
# pool made these part of the public dispatch vocabulary
_chunk_jit = chunk_jit
_chunk_batched_jit = chunk_batched_jit
_finalize = finalize


def solve(source, y, train_mask, C, alpha0, f0, *, tol: float = 1e-3,
          max_iter: int = 10_000_000, wss: str = "2",
          chunk_iters: int | None = None, on_chunk=None,
          n_iter0: int = 0) -> SMOResult:
    """Solve the masked dual SVM to convergence over any kernel source.

    ``chunk_iters=None`` dispatches one chunk sized ``max_iter`` (a single
    device program, like the old monolithic solver). With ``chunk_iters=m``
    the host inspects ``done`` every m iterations and calls
    ``on_chunk(state)`` between chunks — the mid-fold checkpoint hook.
    ``n_iter0`` pre-loads the iteration counter when resuming a checkpointed
    partial solve, so ``n_iter`` accounting survives a restart.
    """
    state = init_state(source, y, train_mask, alpha0, f0, n_iter0=n_iter0)
    n = chunk_iters if chunk_iters is not None else max_iter
    # cap counts TOTAL updates incl. the pre-loaded n_iter0, so a resumed
    # solve stops exactly where the uninterrupted one would have
    it_cap = jnp.asarray(max_iter, jnp.int64)
    while True:
        state = _chunk_jit(source, y, train_mask, C, tol, it_cap, state,
                           n_iters=n, wss=wss)
        if chunk_iters is None or bool(state.done):
            break
        if on_chunk is not None:
            on_chunk(state)
    return _finalize(state, y, train_mask, C, tol)


def solve_batched(source, y, train_masks, Cs, alpha0s, f0s, *,
                  tol: float = 1e-3, max_iter: int = 10_000_000,
                  wss: str = "2", chunk_iters: int = 4096,
                  on_chunk=None, n_iter0s=None) -> SMOResult:
    """Solve a batch of folds concurrently over one shared kernel source.

    ``train_masks`` (b, n), ``Cs`` () or (b,), ``alpha0s``/``f0s`` (b, n).
    One vmapped chunk advances every unconverged fold ~chunk_iters
    iterations; folds that converge freeze (their state passes through the
    body untouched) while stragglers keep iterating, so total device work
    is b * max(n_iter_b), not b * sum. Returns a batched ``SMOResult``
    (leading axis = fold).

    ``n_iter0s`` (() or (b,)) pre-loads per-lane iteration counters when
    resuming a checkpointed batched run, mirroring the single-lane
    ``solve(..., n_iter0=...)`` path: ``max_iter`` caps TOTAL updates
    including the preload, so a resumed batch stops exactly where the
    uninterrupted one would have.
    """
    if source.fused and wss == "2":
        raise ValueError("fused kernel sources require WSS-1 (wss='1')")
    b, n = train_masks.shape
    Cs = jnp.broadcast_to(jnp.asarray(Cs, source.dtype), (b,))
    alpha0s = jnp.where(train_masks, alpha0s, 0.0).astype(source.dtype)
    n_iter0s = jnp.broadcast_to(
        jnp.asarray(0 if n_iter0s is None else n_iter0s, jnp.int64), (b,))
    states = EngineState(alpha0s, f0s.astype(source.dtype),
                         n_iter0s, jnp.zeros(b, bool))
    it_cap = jnp.asarray(max_iter, jnp.int64)
    while True:
        states = _chunk_batched_jit(source, y, train_masks, Cs, tol, it_cap,
                                    states, n_iters=chunk_iters, wss=wss)
        if bool(jnp.all(states.done)):
            break
        if on_chunk is not None:
            on_chunk(states)
    b_up, b_low, gap = jax.vmap(
        lambda a, f, m, c: optimality(a, f, y, m, c))(
            states.alpha, states.f, train_masks, Cs)
    return SMOResult(alpha=states.alpha, f=states.f, n_iter=states.n_iter,
                     converged=gap <= tol, b_up=b_up, b_low=b_low)
