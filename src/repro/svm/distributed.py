"""Distributed SMO: the paper's solver on the production mesh.

Scale-out layout for n instances (millions) across (pod, data, model):
  * X (n, d)   — instances sharded over ("pod","data"), features over "model"
  * f, alpha   — sharded over ("pod","data")
  * kernel rows K_i, K_j — computed on demand: a sharded matvec
    x_i @ X^T (the Pallas RBF kernel computes the same tiles on TPU)

One SMO iteration lowers to: two masked argmin/argmax reductions over the
sharded f (all-reduce), two kernel-row matvecs (feature-axis psum), and a
rank-2 f update (purely local). ``smo_iterations`` runs a chunk of
iterations inside one jit — the chunk is the dispatch unit a cluster
scheduler retries on failure (alpha, f checkpoint between chunks, exactly
like the CV fold chain).

This module is the SVM-side multi-pod dry-run artifact: lower+compile on
the 512-chip mesh is exercised by scripts/dryrun_svm.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.sharding import constrain

_INF = jnp.inf
_TAU = 1e-12

RULES = {
    "inst": ("pod", "data"),    # instance axis
    "feat": "model",            # feature axis
    None: None,
}


def rbf_row(X, i, gamma, sq_norms, *, impl: str = "gather"):
    """K[:, i] for RBF, from sharded X.

    impl="gather": xi = X[i] — a dynamic-slice on the 2D-sharded X, which
    the SPMD partitioner lowers to large all-gathers (measured: ~6 MB/iter,
    collective-dominant — EXPERIMENTS.md §Perf svm-smo baseline).

    impl="onehot": xi = onehot(i) @ X — a skinny matvec that reduces over
    the *sharded instance axis* with a (d,)-sized psum instead of gathering
    rows; scalar reads (f[i], alpha updates) use the same trick. Collective
    bytes per iteration drop ~1000x (the §Perf iteration).
    """
    if impl == "onehot":
        oh = (jnp.arange(X.shape[0]) == i).astype(X.dtype)
        xi = oh @ X                                 # (d,) psum over inst
    else:
        xi = X[i]                                   # (d,) gathered row
    cross = X @ xi                                  # (n,) feature-axis psum
    d2 = jnp.maximum(sq_norms + jnp.sum(xi * xi) - 2.0 * cross, 0.0)
    return jnp.exp(-gamma * d2)


@functools.partial(jax.jit, static_argnames=("n_iters", "gamma", "impl"))
def smo_iterations(X, y, train_mask, alpha, f, sq_norms, C,
                   gamma: float = 0.5, n_iters: int = 100, tol: float = 1e-3,
                   impl: str = "gather"):
    """Run ``n_iters`` SMO iterations with on-demand kernel rows.

    All state tensors are instance-sharded; working-set selection reduces
    globally. Returns (alpha, f, iterations_done, gap).
    """
    C = jnp.asarray(C, X.dtype)

    def read(v, i):
        if impl.startswith("onehot"):
            return jnp.sum(jnp.where(jnp.arange(v.shape[0]) == i, v, 0))
        return v[i]

    def sets(alpha):
        pos, neg = y > 0, y < 0
        at_lo, at_hi = alpha <= 0.0, alpha >= C
        i_up = train_mask & ~((pos & at_hi) | (neg & at_lo))
        i_low = train_mask & ~((pos & at_lo) | (neg & at_hi))
        return i_up, i_low

    def body(state):
        alpha, f, it, _ = state
        i_up, i_low = sets(alpha)
        i = jnp.argmin(jnp.where(i_up, f, _INF))
        f_i = read(f, i)
        if impl == "onehot_fused":
            # WSS-1: j from f alone -> both kernel rows in ONE pass over X
            # (halves the dominant per-iteration HBM stream; WSS-1 needs
            # ~10-30% more iterations than WSS-2 — net win when memory-bound)
            j = jnp.argmax(jnp.where(i_low, f, -_INF))
            oh2 = jnp.stack([(jnp.arange(X.shape[0]) == i).astype(X.dtype),
                             (jnp.arange(X.shape[0]) == j).astype(X.dtype)])
            xij = oh2 @ X                            # (2, d) psum over inst
            cross = X @ xij.T                        # (n, 2): one X stream
            d2 = jnp.maximum(sq_norms[:, None] + jnp.sum(xij * xij, 1)[None]
                             - 2.0 * cross, 0.0)
            K2 = jnp.exp(-gamma * d2)
            K_i = constrain(K2[:, 0], ("inst",), RULES)
            K_j = constrain(K2[:, 1], ("inst",), RULES)
        else:
            K_i = rbf_row(X, i, gamma, sq_norms, impl=impl)
            K_i = constrain(K_i, ("inst",), RULES)
            diff = f - f_i
            eta = jnp.maximum(2.0 - 2.0 * K_i, _TAU)  # K_ii = 1 for RBF
            gain = jnp.where(i_low & (diff > 0), diff * diff / eta, -_INF)
            j = jnp.argmax(gain)
            K_j = rbf_row(X, j, gamma, sq_norms, impl=impl)
            K_j = constrain(K_j, ("inst",), RULES)
        f_j, a_i, a_j = read(f, j), read(alpha, i), read(alpha, j)
        y_i, y_j = read(y, i), read(y, j)
        eta_ij = jnp.maximum(2.0 - 2.0 * read(K_i, j), _TAU)
        delta = (f_j - f_i) / eta_ij
        hi_i = jnp.where(y_i > 0, C - a_i, a_i)
        hi_j = jnp.where(y_j > 0, a_j, C - a_j)
        delta = jnp.maximum(jnp.minimum(jnp.minimum(delta, hi_i), hi_j), 0.0)
        if impl.startswith("onehot"):
            idx = jnp.arange(alpha.shape[0])
            alpha = alpha + jnp.where(idx == i, y_i * delta, 0.0) \
                - jnp.where(idx == j, y_j * delta, 0.0)
        else:
            alpha = alpha.at[i].add(y_i * delta)
            alpha = alpha.at[j].add(-y_j * delta)
        alpha = jnp.clip(alpha, 0.0, C)
        f = f + delta * (K_i - K_j)
        f = constrain(f, ("inst",), RULES)
        i_up, i_low = sets(alpha)
        gap = jnp.max(jnp.where(i_low, f, -_INF)) - \
            jnp.min(jnp.where(i_up, f, _INF))
        return alpha, f, it + 1, gap

    def cond(state):
        _, _, it, gap = state
        return (it < n_iters) & (gap > tol)

    state = (alpha, f, jnp.zeros((), jnp.int32), jnp.asarray(_INF, X.dtype))
    alpha, f, it, gap = jax.lax.while_loop(cond, body, state)
    return alpha, f, it, gap
