"""Distributed SMO: the paper's solver on the production mesh — a thin
wrapper over the unified engine's ``ShardedRBF`` kernel source.

Scale-out layout for n instances (millions) across (pod, data, model):
  * X (n, d)   — instances sharded over ("pod","data"), features over "model"
  * f, alpha   — sharded over ("pod","data")
  * kernel rows K_i, K_j — computed on demand: a sharded matvec
    x_i @ X^T (the Pallas RBF kernel computes the same tiles on TPU)

One SMO iteration lowers to: two masked argmin/argmax reductions over the
sharded f (all-reduce), two kernel-row matvecs (feature-axis psum), and a
rank-2 f update (purely local). ``smo_iterations`` runs a chunk of
iterations inside one jit — the chunk is the dispatch unit a cluster
scheduler retries on failure (alpha, f checkpoint between chunks, exactly
like the CV fold chain). The iteration core itself lives in
``repro.svm.engine`` — one body serves this path and the dense solver.

This module is the SVM-side multi-pod dry-run artifact: lower+compile on
the 512-chip mesh is exercised by scripts/dryrun_svm.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.svm.engine import (EngineState, RULES, ShardedRBF,  # noqa: F401
                              optimality, smo_chunk)


def rbf_row(X, i, gamma, sq_norms, *, impl: str = "gather"):
    """K[:, i] for RBF, from sharded X.

    impl="gather": xi = X[i] — a dynamic-slice on the 2D-sharded X, which
    the SPMD partitioner lowers to large all-gathers (measured: ~6 MB/iter,
    collective-dominant — DESIGN.md §Distributed SMO, results/dryrun/).

    impl="onehot": xi = onehot(i) @ X — a skinny matvec that reduces over
    the *sharded instance axis* with a (d,)-sized psum instead of gathering
    rows; scalar reads (f[i], alpha updates) use the same trick. Collective
    bytes per iteration drop ~1000x (DESIGN.md §Distributed SMO).
    """
    return ShardedRBF(X, gamma, sq_norms, impl=impl).row(i)


@functools.partial(jax.jit, static_argnames=("n_iters", "gamma", "impl"))
def smo_iterations(X, y, train_mask, alpha, f, sq_norms, C,
                   gamma: float = 0.5, n_iters: int = 100, tol: float = 1e-3,
                   impl: str = "gather"):
    """Run up to ``n_iters`` SMO iterations with on-demand kernel rows.

    All state tensors are instance-sharded; working-set selection reduces
    globally. Returns (alpha, f, iterations_done, gap). ``impl`` picks the
    kernel-row strategy: "gather", "onehot", or "onehot_fused" (WSS-1 with
    both rows in one pass over X — see ``engine.OnDemandRBF``).

    This is exactly one engine chunk: an already-converged input returns
    unchanged with iterations_done = 0.
    """
    source = ShardedRBF(X, gamma, sq_norms, impl=impl)
    state = EngineState(alpha, f, jnp.zeros((), jnp.int32),
                        jnp.zeros((), bool))
    state = smo_chunk(source, y, train_mask, C, state, n_iters=n_iters,
                      wss="1" if source.fused else "2", tol=tol)
    _, _, gap = optimality(state.alpha, state.f, y, train_mask,
                           jnp.asarray(C, X.dtype))
    return state.alpha, state.f, state.n_iter, gap
