"""Dense LibSVM-parity SMO solver — a thin wrapper over the unified engine.

The iteration core (Keerthi-style working-set selection, box-clipped rank-2
update, duality-gap termination) lives in ``repro.svm.engine`` exactly once;
this module binds it to a precomputed kernel matrix (``DenseKernel`` source)
and keeps the historical call signature.

Design notes (unchanged semantics)
----------------------------------
* One compiled solver serves every fold of k-fold CV: fold membership is a
  boolean ``train_mask`` over the padded instance axis, so shapes are static
  and the k-fold loop never retraces.
* The optimality-indicator vector ``f`` (paper Eq. 2, f_i = w.phi(x_i) - y_i)
  is maintained for ALL instances — masked (held-out) entries receive the
  same rank-2 updates, so after a solve ``f`` is globally consistent with
  ``alpha``. The seeding algorithms (MIR in particular) rely on this.
* Working-set selection: WSS-2 (LibSVM's second-order pair selection) by
  default; WSS-1 (maximal violating pair) available for ablation.
* The pairwise update preserves sum(y * alpha) exactly (up to fp error) —
  seeded initial alphas MUST satisfy the equality constraint; the seeding
  module repairs them before calling the solver.

New in the engine era: ``chunk_iters``/``on_chunk`` expose the engine's
chunked dispatch for mid-fold checkpointing, and ``n_iter0`` resumes the
iteration count of a restored partial solve (see DESIGN.md §Chunked
dispatch). Defaults replay the old monolithic behaviour bit-exactly.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.svm.engine import (DenseKernel, SMOResult, _sets,  # noqa: F401
                              solve, solve_batched)


def init_f(K: jnp.ndarray, y: jnp.ndarray, alpha: jnp.ndarray) -> jnp.ndarray:
    """f_i = sum_j alpha_j y_j K_ij - y_i, for all i (masked or not)."""
    return K @ (alpha * y) - y


def dual_objective(K: jnp.ndarray, y: jnp.ndarray, alpha: jnp.ndarray) -> jnp.ndarray:
    """Paper Problem (1): sum(alpha) - 0.5 aT Q a with Q_ij = y_i y_j K_ij."""
    v = alpha * y
    return jnp.sum(alpha) - 0.5 * (v @ (K @ v))


def smo_solve(K: jnp.ndarray, y: jnp.ndarray, train_mask: jnp.ndarray,
              C: float, alpha0: jnp.ndarray, f0: jnp.ndarray,
              tol: float = 1e-3, max_iter: int = 10_000_000,
              wss: str = "2", chunk_iters: int | None = None,
              on_chunk=None, n_iter0: int = 0) -> SMOResult:
    """Solve the masked dual SVM with SMO, warm-started at (alpha0, f0).

    ``f0`` must equal ``init_f(K, y, alpha0)`` (callers use ``init_f`` or the
    incrementally-maintained ``f`` of a previous solve). For a cold start,
    ``alpha0 = 0`` gives ``f0 = -y`` with no matvec.
    """
    return solve(DenseKernel(K), y, train_mask, C, alpha0, f0, tol=tol,
                 max_iter=max_iter, wss=wss, chunk_iters=chunk_iters,
                 on_chunk=on_chunk, n_iter0=n_iter0)


def smo_solve_batched(K: jnp.ndarray, y: jnp.ndarray, train_masks: jnp.ndarray,
                      Cs, alpha0s: jnp.ndarray, f0s: jnp.ndarray,
                      tol: float = 1e-3, max_iter: int = 10_000_000,
                      wss: str = "2", chunk_iters: int = 4096,
                      n_iter0s=None) -> SMOResult:
    """Solve a batch of folds over one shared kernel matrix concurrently.

    ``train_masks``/``alpha0s``/``f0s`` carry a leading fold axis; ``Cs`` is
    a scalar or (b,) vector (per-cell C for hyper-parameter grids). Returns
    a fold-batched ``SMOResult``. Converged folds freeze while stragglers
    keep iterating — see ``engine.solve_batched``. ``n_iter0s`` pre-loads
    per-lane iteration counters when resuming a checkpointed batched run
    (mirrors the single-lane ``n_iter0``).
    """
    return solve_batched(DenseKernel(K), y, train_masks, Cs, alpha0s, f0s,
                         tol=tol, max_iter=max_iter, wss=wss,
                         chunk_iters=chunk_iters, n_iter0s=n_iter0s)
