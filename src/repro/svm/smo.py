"""Jittable SMO solver (Keerthi-style working-set selection, LibSVM parity).

Design notes
------------
* One compiled solver serves every fold of k-fold CV: fold membership is a
  boolean ``train_mask`` over the padded instance axis, so shapes are static
  and the k-fold loop never retraces.
* The optimality-indicator vector ``f`` (paper Eq. 2, f_i = w.phi(x_i) - y_i)
  is maintained for ALL instances — masked (held-out) entries receive the
  same rank-2 updates, so after a solve ``f`` is globally consistent with
  ``alpha``. The seeding algorithms (MIR in particular) rely on this.
* Working-set selection: WSS-2 (LibSVM's second-order pair selection) by
  default; WSS-1 (maximal violating pair) available for ablation.
* The pairwise update preserves sum(y * alpha) exactly (up to fp error) —
  seeded initial alphas MUST satisfy the equality constraint; the seeding
  module repairs them before calling the solver.

The solver is pure ``lax.while_loop`` — it lowers and shards (f, K rows are
sharded over the data axis; the argmin/argmax reductions become all-reduces).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

_INF = jnp.inf
_TAU = 1e-12


class SMOResult(NamedTuple):
    alpha: jnp.ndarray      # (n,) dual variables (0 outside train_mask)
    f: jnp.ndarray          # (n,) optimality indicators, globally consistent
    n_iter: jnp.ndarray     # () int64 — SMO iterations executed
    converged: jnp.ndarray  # () bool
    b_up: jnp.ndarray       # () min f over I_up at exit
    b_low: jnp.ndarray      # () max f over I_low at exit


def init_f(K: jnp.ndarray, y: jnp.ndarray, alpha: jnp.ndarray) -> jnp.ndarray:
    """f_i = sum_j alpha_j y_j K_ij - y_i, for all i (masked or not)."""
    return K @ (alpha * y) - y


def dual_objective(K: jnp.ndarray, y: jnp.ndarray, alpha: jnp.ndarray) -> jnp.ndarray:
    """Paper Problem (1): sum(alpha) - 0.5 aT Q a with Q_ij = y_i y_j K_ij."""
    v = alpha * y
    return jnp.sum(alpha) - 0.5 * (v @ (K @ v))


def _sets(alpha, y, mask, C):
    """I_up / I_low membership (paper Eq. 4): I_up = I_u + I_m, I_low = I_l + I_m."""
    pos, neg = y > 0, y < 0
    at_lo, at_hi = alpha <= 0.0, alpha >= C
    i_up = mask & ~((pos & at_hi) | (neg & at_lo))
    i_low = mask & ~((pos & at_lo) | (neg & at_hi))
    return i_up, i_low


@functools.partial(jax.jit, static_argnames=("max_iter", "wss"))
def smo_solve(K: jnp.ndarray, y: jnp.ndarray, train_mask: jnp.ndarray,
              C: float, alpha0: jnp.ndarray, f0: jnp.ndarray,
              tol: float = 1e-3, max_iter: int = 10_000_000,
              wss: str = "2") -> SMOResult:
    """Solve the masked dual SVM with SMO, warm-started at (alpha0, f0).

    ``f0`` must equal ``init_f(K, y, alpha0)`` (callers use ``init_f`` or the
    incrementally-maintained ``f`` of a previous solve). For a cold start,
    ``alpha0 = 0`` gives ``f0 = -y`` with no matvec.
    """
    diagK = jnp.diagonal(K)
    C = jnp.asarray(C, K.dtype)

    def cond(state):
        alpha, f, it = state
        i_up, i_low = _sets(alpha, y, train_mask, C)
        has = jnp.any(i_up) & jnp.any(i_low)
        b_up = jnp.min(jnp.where(i_up, f, _INF))
        b_low = jnp.max(jnp.where(i_low, f, -_INF))
        gap = jnp.where(has, b_low - b_up, -_INF)
        return (gap > tol) & (it < max_iter)

    def body(state):
        alpha, f, it = state
        i_up, i_low = _sets(alpha, y, train_mask, C)
        # --- select i: minimal f over I_up ---
        i = jnp.argmin(jnp.where(i_up, f, _INF))
        f_i = f[i]
        K_i = K[i]
        if wss == "2":
            # LibSVM WSS-2: among j in I_low with f_j > f_i, maximise
            # (f_j - f_i)^2 / eta_j.
            diff = f - f_i
            eta = jnp.maximum(diagK[i] + diagK - 2.0 * K_i, _TAU)
            gain = jnp.where(i_low & (diff > 0), diff * diff / eta, -_INF)
            j = jnp.argmax(gain)
        else:
            j = jnp.argmax(jnp.where(i_low, f, -_INF))
        K_j = K[j]
        # --- analytic 2-variable update, delta >= 0 along (+y_i, -y_j) ---
        eta_ij = jnp.maximum(diagK[i] + diagK[j] - 2.0 * K_i[j], _TAU)
        delta = (f[j] - f_i) / eta_ij
        hi_i = jnp.where(y[i] > 0, C - alpha[i], alpha[i])
        hi_j = jnp.where(y[j] > 0, alpha[j], C - alpha[j])
        delta = jnp.maximum(jnp.minimum(jnp.minimum(delta, hi_i), hi_j), 0.0)
        alpha = alpha.at[i].add(y[i] * delta)
        alpha = alpha.at[j].add(-y[j] * delta)
        alpha = jnp.clip(alpha, 0.0, C)  # kill fp dust at the box boundary
        # rank-2 update keeps f consistent for ALL rows (incl. masked)
        f = f + delta * (K_i - K_j)
        return alpha, f, it + 1

    alpha0 = jnp.where(train_mask, alpha0, 0.0)
    state = (alpha0.astype(K.dtype), f0.astype(K.dtype), jnp.zeros((), jnp.int64))
    alpha, f, it = jax.lax.while_loop(cond, body, state)

    i_up, i_low = _sets(alpha, y, train_mask, C)
    has = jnp.any(i_up) & jnp.any(i_low)
    b_up = jnp.min(jnp.where(i_up, f, _INF))
    b_low = jnp.max(jnp.where(i_low, f, -_INF))
    gap = jnp.where(has, b_low - b_up, -_INF)
    return SMOResult(alpha=alpha, f=f, n_iter=it, converged=gap <= tol,
                     b_up=b_up, b_low=b_low)
