"""Measured scheduler cost model: per-lane chunk cost vs dispatch width.

The lane pool's ``max_width`` default used to be a hard-coded verdict
("CPU loses at any vmapped width, accelerators want full width") baked
into ``scheduler.py``. This module replaces the constant with a
*measurement*: ``scripts/measure_cost_model.py`` micro-benchmarks the
batched chunk program at several dispatch widths per (backend, source
kind) and writes the verdict to ``results/cost_model.json``; the pool
loads it here at construction.

File schema (``results/cost_model.json``)::

    {
      "schema": 1,
      "meta": {...},                       # harness provenance
      "entries": {
        "<jax backend>": {                 # "cpu", "tpu", ...
          "<source kind>": {               # "dense", "pallas_rbf"
            "max_width": 0 | int,          # 0 = unbounded (full width)
            "us_per_lane_iter": {"<width>": float, ...},
            "shrink": bool,                # smaller-cap programs pay off?
            "us_per_iter_by_n": {"<n>": float, ...}   # per-cap sweep
          }
        }
      }
    }

``max_width`` combines across a pool's source kinds conservatively (the
smallest nonzero cap wins; 0 only when every kind says unbounded). When
the file, backend, or kind is missing, the pool falls back to the
pre-measurement default: width-1 round-robin on CPU, unbounded elsewhere.

The path resolves relative to the repo checkout (this file lives in
``src/repro/svm/``); ``REPRO_COST_MODEL`` overrides it, and the loaded
file is cached per path for the process lifetime.
"""
from __future__ import annotations

import json
import os
import pathlib

import jax

#: repo-relative location the measurement script writes to
DEFAULT_PATH = pathlib.Path(__file__).resolve().parents[3] \
    / "results" / "cost_model.json"

_CACHE: dict[str, dict | None] = {}


def clear_cache() -> None:
    """Drop every cached parse. Tests that point ``REPRO_COST_MODEL`` at a
    temp file must call this around the swap — the cache is keyed by path,
    but a test rewriting the same path would otherwise read the stale
    parse."""
    _CACHE.clear()


def model_path() -> pathlib.Path:
    return pathlib.Path(os.environ.get("REPRO_COST_MODEL", DEFAULT_PATH))


def load(path=None) -> dict | None:
    """Parse the cost-model file; None when absent or unreadable (the
    caller falls back to the pre-measurement default)."""
    p = pathlib.Path(path) if path is not None else model_path()
    key = str(p)
    if key not in _CACHE:
        try:
            with open(p) as fh:
                model = json.load(fh)
            _CACHE[key] = model if isinstance(model.get("entries"), dict) \
                else None
        except (OSError, ValueError):
            _CACHE[key] = None
    return _CACHE[key]


def source_kind(entry) -> str:
    """Cost-model kind of a pool sources-dict entry (source or spec):
    row-streaming sources dispatch a fused pallas launch per iteration,
    everything else indexes a dense matrix."""
    return "pallas_rbf" if getattr(entry, "streams_rows", False) else "dense"


def fallback_max_width(backend: str | None = None) -> int:
    """The pre-measurement default (scheduler.py's historical verdict):
    CPU's vmapped batch loses at every width > 1, accelerators want full
    width."""
    backend = backend or jax.default_backend()
    return 1 if backend == "cpu" else 0


def pick_max_width(backend: str | None = None, kinds=("dense",),
                   model=None, path=None) -> int:
    """``max_width`` for a pool dispatching the given source kinds.

    Reads the measured entry per kind and combines conservatively: the
    smallest nonzero cap across kinds, 0 (unbounded) only when every kind
    measured unbounded. Any missing entry degrades to the fallback
    default for this backend.
    """
    backend = backend or jax.default_backend()
    if model is None:
        model = load(path)
    caps = []
    per_backend = (model or {}).get("entries", {}).get(backend, {})
    for kind in set(kinds) or {"dense"}:
        entry = per_backend.get(kind)
        if not isinstance(entry, dict) or "max_width" not in entry:
            caps.append(fallback_max_width(backend))
        else:
            caps.append(int(entry["max_width"]))
    finite = [c for c in caps if c > 0]
    return min(finite) if finite else 0


def fallback_shrink(backend: str | None = None) -> bool:
    """Pre-measurement shrink verdict: on CPU the engine runs width-1
    interpret-mode programs whose per-iteration cost is dominated by
    dispatch overhead, not operand bytes — shrink-induced recompiles (one
    program per cap bucket) can cost more than the smaller operands save,
    so CPU defaults off; bandwidth-bound accelerators default on."""
    backend = backend or jax.default_backend()
    return backend != "cpu"


def pick_shrink(backend: str | None = None, kinds=("dense",),
                model=None, path=None) -> bool:
    """Shrink verdict for a pool dispatching the given source kinds
    (drives ``shrink_every="auto"``).

    Reads the measured ``shrink`` entry per kind (written by the per-cap
    throughput sweep in ``scripts/measure_cost_model.py``) and combines
    conservatively: shrinking is enabled only when EVERY kind measured
    True; a missing file/backend/kind degrades that kind to the fallback
    verdict — mirroring ``pick_max_width``'s smallest-cap-wins caution.
    """
    backend = backend or jax.default_backend()
    if model is None:
        model = load(path)
    per_backend = (model or {}).get("entries", {}).get(backend, {})
    verdicts = []
    for kind in set(kinds) or {"dense"}:
        entry = per_backend.get(kind)
        if not isinstance(entry, dict) or "shrink" not in entry:
            verdicts.append(fallback_shrink(backend))
        else:
            verdicts.append(bool(entry["shrink"]))
    return all(verdicts)
