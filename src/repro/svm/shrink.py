"""Bucketed active-set shrinking for the SMO engine (LIBSVM heuristic).

The paper's premise is that a seeded solve starts *near* optimal: most
alphas sit at their bounds from iteration zero and the solver polishes a
small free set — yet every iteration still pays a full O(n*d) (fused
Pallas / row-streaming RBF) or O(n) (dense row) pass. Shrinking removes
bound-locked variables from the working problem so per-iteration cost
scales with the ACTIVE fraction, which is exactly the quantity alpha
seeding makes small.

Design: shrinking is a **problem transformation at chunk granularity**,
not engine-core surgery. The engine's ``EngineState``/``_step``/chunk
programs are untouched (``shrink_every=0`` is bit-identical to today by
construction); a shrunk lane instead runs the *same* chunk programs on a
gathered compact subproblem:

* **heuristic** — every ``shrink_every`` iterations (a boundary enforced
  via the traced ``it_cap``, so cadence adds NO new program shapes), a
  variable is shrunk when it is bound-locked against the current
  ``(b_up, b_low)`` estimates: in I_up only with ``f > b_low``, or in
  I_low only with ``f < b_up`` (LIBSVM's rule in this repo's sign
  convention). Free variables never shrink, and the maximal violating
  pair is provably retained (the argmin of f over I_up has
  ``f = b_up < b_low`` whenever the gap is positive), so the compact
  problem's gap equals the full gap at the moment of shrinking.
* **bucketed compaction** — active indices are extracted with the
  fixed-shape ``jnp.nonzero(size=cap, fill_value=n)`` idiom (the
  ``ato_seed`` pattern); ``cap`` is the smallest ``shrink_quantum``
  multiple >= the active count (or the smallest declared ``shrink_caps``
  entry), so compile shapes stay O(n / quantum) per source. Pads point
  at row ``n``: gathers clamp (they replicate the last row, inert under
  the validity mask), scatters drop them — compaction round-trips are
  bit-exact with no duplicate-index hazards.
* **reconstruction contract** — when the active gap closes within
  ``10*tol`` (the compact dispatch runs at that relaxed tolerance), the
  full ``f`` is reconstructed as ``K @ (alpha*y) - y`` via the source's
  dense ``K`` or streaming ``matvec`` slab path, the lane unshrinks, and
  the solver continues on the full set to the true tolerance — so
  ``SMOResult`` keeps the full-set optimality contract (``f`` globally
  consistent, ``converged`` judged on the full gap). A lane re-shrinks
  only while its full gap stays above ``10*tol``; ``UNSHRINK_LIMIT``
  bounds the cycle count.
* **bit-determinism** — the compact iterate sequence is a pure function
  of the active VALUES: pad rows can never win the masked reductions and
  their rank-2 garbage is dropped at scatter, so re-bucketing the same
  mask at a different cap (a resume under a different ``shrink_quantum``)
  replays bit-identical alphas. Heuristic evaluations happen at exact
  ``n_iter`` boundaries (pure functions of ``n_iter``, not of the chunk
  schedule), so a mid-shrink snapshot restored under a different schedule
  shape resumes the identical trajectory — provided both bucketing rules
  take the same shrink/no-shrink decisions (guaranteed when the active
  count stays below the coarser quantum's last bucket, the practical
  case; covered by tests/test_shrink.py).

The scheduler (``svm/scheduler.py``) drives this per lane through
:class:`LaneShrink` + :func:`advance`; :func:`solve_shrunk` is the solo
reference driver (bit-identical to a width-1 pool, same contract as
``engine.solve``).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.svm.engine import (EngineState, SMOResult, _INF, _sets, chunk_jit,
                              finalize, init_state, optimality)

#: heuristic cadence when shrinking is enabled without an explicit period
#: (``shrink_every="auto"`` resolves here when the cost model approves)
DEFAULT_SHRINK_EVERY = 1024

#: shrink/unshrink cycles per lane before the endgame pins to the full set
UNSHRINK_LIMIT = 4


# --------------------------------------------------------------- bucketing

def bucket_cap(m: int, quantum: int = 128) -> int:
    """Smallest ``quantum`` multiple >= ``m`` (>= one quantum) — the
    compact buffer capacity for an active count of ``m``. Mirrors
    ``seeding._bucket_cap``'s shape-bucketing so compile shapes stay
    O(n / quantum)."""
    q = max(int(quantum), 1)
    return -(-max(int(m), 1) // q) * q


def pick_cap(m: int, n: int, quantum: int = 128, caps=None) -> int | None:
    """Capacity bucket for ``m`` active of ``n`` rows, or ``None`` when
    compaction would not reduce the shape (bucket >= n, or no declared
    cap fits). ``caps`` restricts to a declared ladder — the smallest
    declared cap that fits wins (plans that must be exactly predictable
    by the static analyzer declare their ladder)."""
    m, n = int(m), int(n)
    if caps:
        fit = [int(c) for c in caps if m <= int(c) < n]
        return min(fit) if fit else None
    cap = bucket_cap(m, quantum)
    return cap if cap < n else None


def possible_caps(n: int, quantum: int = 128, caps=None) -> tuple[int, ...]:
    """Every compact capacity :func:`pick_cap` can produce for an
    ``n``-row source — the cap enumeration ``analysis/plan_check.py``
    maps onto jitted programs (the ``possible_widths`` pattern: kept next
    to the bucketing rule so prediction and execution cannot drift)."""
    n = int(n)
    if caps:
        return tuple(sorted({int(c) for c in caps if 0 < int(c) < n}))
    q = max(int(quantum), 1)
    return tuple(range(q, n, q))


# --------------------------------------------------------------- heuristic

@jax.jit
def active_set(alpha, f, y, train_mask, C):
    """(active, gap): the LIBSVM shrink heuristic against the current
    ``(b_up, b_low)`` estimates. A variable is bound-locked (inactive)
    when it can only move the objective away from the violating pair:
    in I_up only with ``f > b_low``, or in I_low only with ``f < b_up``.
    Free variables (in both sets) and the maximal violating pair always
    stay active; rows outside ``train_mask`` never are."""
    i_up, i_low = _sets(alpha, y, train_mask, C)
    has = jnp.any(i_up) & jnp.any(i_low)
    b_up = jnp.min(jnp.where(i_up, f, _INF))
    b_low = jnp.max(jnp.where(i_low, f, -_INF))
    gap = jnp.where(has, b_low - b_up, -_INF)
    locked = (i_up & ~i_low & (f > b_low)) | (i_low & ~i_up & (f < b_up))
    return train_mask & ~locked, gap


def seed_active_mask(alpha0, f0, y, train_mask, C):
    """Initial active-candidate mask for a seeded lane (the seeding ->
    shrinking handoff): bound-locked seeded alphas start shrunk, so an
    ATO/MIR/SIR-seeded lane begins compact instead of re-deriving the set
    after its first ``shrink_every`` iterations. Re-exported by
    ``core/seeding.py``; the pool applies it at admission when
    ``shrink_on_seed`` is set."""
    active, _ = active_set(alpha0, f0, y, train_mask, C)
    return active


@jax.jit
def _gap_of(alpha, f, y, mask, C):
    return optimality(alpha, f, y, mask, C)[2]


# ----------------------------------------------------------- reconstruction

@jax.jit
def _dense_f(K, y, alpha):
    return K @ (alpha * y) - y


def reconstruct_f(source, y, alpha):
    """Full-set ``f = K @ (alpha*y) - y`` for unshrinking: the dense ``K``
    when the source holds one, else the streaming ``matvec`` slab path
    (``PallasRBF``/``OnDemandRBF`` — O(block*n) transient, never n^2)."""
    K = getattr(source, "K", None)
    if K is not None:
        return _dense_f(K, y, alpha)
    mv = getattr(source, "matvec", None)
    if callable(mv):
        return mv(alpha * y) - y
    raise ValueError("source has neither K nor matvec; cannot reconstruct "
                     "f to unshrink")


# ------------------------------------------------------------- lane ledger

class LaneShrink:
    """Host-side shrink ledger for ONE lane: the active mask, the bucketed
    compact buffer (indices, operands, state), and the lifecycle flags.
    The full-shape ``EngineState`` mirror stays with the caller (the
    pool's ``lane.state``); :func:`advance` keeps it fresh by scattering
    the compact state back after every chunk — alpha and the *active*
    rows of f are always current, inactive f goes stale until
    reconstruction (exactly LIBSVM's contract)."""

    def __init__(self, n: int, *, every: int, quantum: int = 128,
                 caps=None, unshrink_limit: int = UNSHRINK_LIMIT):
        self.n = int(n)
        self.every = max(int(every), 1)
        self.quantum = int(quantum)
        self.caps = tuple(int(c) for c in caps) if caps else None
        self.unshrink_limit = int(unshrink_limit)
        self.active = None            # (n,) bool — None until first shrink
        self.cap = 0                  # compact capacity; 0 = unshrunk
        self.m = 0                    # live active count (<= cap)
        self.idx = None               # (cap,) int; pads = n (dropped)
        self.cmask = None             # (cap,) bool validity mask
        self.cy = None                # (cap,) compact labels
        self.csrc = None              # compact kernel source
        self.cstate = None            # compact EngineState
        self.no_shrink = False        # endgame: full-set polish only
        self.unshrinks = 0

    @property
    def shrunk(self) -> bool:
        return self.cap > 0

    def it_cap(self, n_iter: int, max_iter: int) -> int:
        """Iteration cap for the next dispatch: stop exactly at the next
        heuristic boundary — a pure function of ``n_iter``, NOT of the
        chunk schedule, so heuristic decisions land at identical
        iteration counts under any schedule shape (the resume
        contract)."""
        if self.no_shrink and not self.shrunk:
            return int(max_iter)
        boundary = (int(n_iter) // self.every + 1) * self.every
        return min(int(max_iter), boundary)

    def mark(self, active, m: int) -> bool:
        """Adopt an active mask from a full-set heuristic evaluation (or
        a restored snapshot); returns True when a (re)compaction is now
        pending — the gather itself is lazy (:meth:`enter` runs at the
        next dispatch, where a resolved source is in scope, so intake
        never forces a kernel into residency)."""
        cap = pick_cap(m, self.n, self.quantum, self.caps)
        if cap is None:
            return False
        self.active = jnp.asarray(active, bool)
        self.m = int(m)
        if self.shrunk and cap >= self.cap:
            return False
        self.cap = cap
        self.idx = None
        self.cstate = None
        return True

    def enter(self, source, y, full: EngineState) -> None:
        """Gather the compact subproblem from the full-state mirror:
        indices via the fixed-shape nonzero idiom (pads = n: gathers
        clamp to the last row — inert under ``cmask`` — and scatters
        drop them), operands via the source's ``compact`` gather."""
        idx = jnp.nonzero(self.active, size=self.cap,
                          fill_value=self.n)[0]
        self.idx = idx
        self.cmask = jnp.arange(self.cap) < self.m
        self.cy = y[idx]
        self.csrc = source.compact(idx)
        self.cstate = EngineState(full.alpha[idx], full.f[idx],
                                  full.n_iter, jnp.zeros((), bool))

    def scatter(self, full: EngineState) -> EngineState:
        """Write the compact state back into the full mirror (pads are
        out-of-range and dropped; valid indices are unique, so the
        scatter is deterministic and bit-exact)."""
        st = self.cstate
        return EngineState(
            full.alpha.at[self.idx].set(st.alpha, mode="drop"),
            full.f.at[self.idx].set(st.f, mode="drop"),
            st.n_iter, full.done)

    def tighten(self, active_c, m_new: int) -> None:
        """Apply a boundary re-evaluation INSIDE compact mode: the mask
        tightens in place (cmask &= heuristic — value-identical whether
        or not the buffer re-buckets, the cross-quantum determinism
        contract), and the buffer re-gathers only when the bucket
        actually drops (a pure perf move)."""
        self.cmask = self.cmask & active_c
        self.m = int(m_new)
        self.active = jnp.zeros(self.n, bool).at[self.idx].set(
            self.cmask, mode="drop")
        cap = pick_cap(self.m, self.n, self.quantum, self.caps)
        if cap is not None and cap < self.cap:
            self.cap = cap
            self.idx = None
            self.cstate = None

    def unshrink(self) -> None:
        self.cap = 0
        self.m = 0
        self.idx = self.cmask = self.cy = self.csrc = self.cstate = None
        self.active = None
        self.unshrinks += 1
        if self.unshrinks >= self.unshrink_limit:
            self.no_shrink = True


def seed_shrink(ls: LaneShrink, y, train_mask, C, state: EngineState, *,
                tol: float) -> None:
    """The admission-time handoff: evaluate the heuristic on the seeded
    (alpha0, f0). A lane already inside the ``10*tol`` endgame never
    shrinks (it would unshrink immediately); otherwise bound-locked
    seeded alphas start shrunk."""
    gap = float(_gap_of(state.alpha, state.f, y, train_mask,
                        jnp.asarray(C, state.alpha.dtype)))
    if math.isnan(gap) or gap <= 10.0 * tol:
        ls.no_shrink = True
        return
    active, _ = active_set(state.alpha, state.f, y, train_mask,
                           jnp.asarray(C, state.alpha.dtype))
    ls.mark(active, int(jnp.sum(active)))


def advance(ls: LaneShrink, source, y, train_mask, C, full: EngineState, *,
            tol: float, max_iter: int):
    """Post-chunk lifecycle for one shrink-enabled lane. Returns
    ``(full_state, verdict)`` with verdict ``"run"`` (keep dispatching)
    or ``"retire"`` (full-set converged, NaN-poisoned, or
    iteration-capped — the state is reconstructed and finalizable).

    Shrunk lane, chunk done: the compact dispatch ran at ``10*tol``, so
    ``done`` means the active gap closed (reconstruct + unshrink), the
    budget ran out (reconstruct + retire), or the next heuristic
    boundary was hit (tighten the mask against the compact
    ``(b_up, b_low)``). Unshrunk lane, chunk done: true convergence
    retires; a heuristic boundary evaluates the full-set mask and may
    enter compaction.
    """
    stol = 10.0 * tol
    if ls.shrunk:
        st = ls.cstate
        full = ls.scatter(full)
        if not bool(st.done):
            return full, "run"
        n_it = int(st.n_iter)
        Cd = jnp.asarray(C, st.alpha.dtype)
        gap_c = float(_gap_of(st.alpha, st.f, ls.cy, ls.cmask, Cd))
        if gap_c <= stol or math.isnan(gap_c) or n_it >= max_iter:
            # the active gap closed within 10*tol (or the budget ran
            # out): reconstruct f over the FULL set and unshrink — the
            # SMOResult contract is full-set optimality
            f_full = reconstruct_f(source, y, full.alpha)
            full = EngineState(full.alpha, f_full, st.n_iter,
                               jnp.zeros((), bool))
            ls.unshrink()
            gap = float(_gap_of(full.alpha, full.f, y, train_mask,
                                jnp.asarray(C, full.alpha.dtype)))
            if gap <= tol or math.isnan(gap) or n_it >= max_iter:
                return full._replace(done=jnp.ones((), bool)), "retire"
            if gap <= stol:
                ls.no_shrink = True    # endgame: polish the full set
            return full, "run"
        # heuristic boundary inside compact mode
        act_c, _ = active_set(st.alpha, st.f, ls.cy, ls.cmask, Cd)
        ls.cstate = st._replace(done=jnp.zeros((), bool))
        m_new = int(jnp.sum(act_c))
        if m_new < ls.m:
            ls.tighten(act_c, m_new)
        return full, "run"

    if not bool(full.done):
        return full, "run"
    n_it = int(full.n_iter)
    gap = float(_gap_of(full.alpha, full.f, y, train_mask,
                        jnp.asarray(C, full.alpha.dtype)))
    if gap <= tol or math.isnan(gap) or n_it >= max_iter:
        return full, "retire"
    full = full._replace(done=jnp.zeros((), bool))
    if ls.no_shrink:
        return full, "run"
    if gap <= stol:
        ls.no_shrink = True            # already in the endgame
        return full, "run"
    active, _ = active_set(full.alpha, full.f, y, train_mask,
                           jnp.asarray(C, full.alpha.dtype))
    ls.mark(active, int(jnp.sum(active)))
    return full, "run"


# ------------------------------------------------------------- solo driver

def solve_shrunk(source, y, train_mask, C, alpha0, f0, *, tol: float = 1e-3,
                 max_iter: int = 10_000_000, wss: str = "2",
                 chunk_iters: int = 4096,
                 shrink_every: int = DEFAULT_SHRINK_EVERY,
                 shrink_quantum: int = 128, shrink_caps=None,
                 shrink_on_seed: bool = True,
                 n_iter0: int = 0) -> SMOResult:
    """``engine.solve`` with active-set shrinking — the reference driver
    the pool's shrink path is bit-identical to (tests/test_shrink.py).
    ``shrink_every=0`` falls back to ``engine.solve`` verbatim. The
    result satisfies the same full-set contract as ``solve``: ``f`` is
    globally consistent (reconstructed at unshrink) and ``converged``
    reflects the full-set gap at ``tol``."""
    from repro.svm import engine
    if not shrink_every:
        return engine.solve(source, y, train_mask, C, alpha0, f0, tol=tol,
                            max_iter=max_iter, wss=wss,
                            chunk_iters=chunk_iters, n_iter0=n_iter0)
    state = init_state(source, y, train_mask, alpha0, f0, n_iter0=n_iter0)
    ls = LaneShrink(int(state.alpha.shape[0]), every=shrink_every,
                    quantum=shrink_quantum, caps=shrink_caps)
    if shrink_on_seed:
        seed_shrink(ls, y, train_mask, C, state, tol=tol)
    while True:
        if ls.cap and ls.idx is None:
            ls.enter(source, y, state)
        if ls.shrunk:
            it = ls.it_cap(int(ls.cstate.n_iter), max_iter)
            ls.cstate = chunk_jit(ls.csrc, ls.cy, ls.cmask, C, 10.0 * tol,
                                  jnp.asarray(it, jnp.int64), ls.cstate,
                                  n_iters=chunk_iters, wss=wss)
        else:
            it = ls.it_cap(int(state.n_iter), max_iter)
            state = chunk_jit(source, y, train_mask, C, tol,
                              jnp.asarray(it, jnp.int64), state,
                              n_iters=chunk_iters, wss=wss)
        state, verdict = advance(ls, source, y, train_mask, C, state,
                                 tol=tol, max_iter=max_iter)
        if verdict == "retire":
            return finalize(state, y, train_mask, C, tol)
