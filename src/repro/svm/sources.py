"""Kernel-source factories and the compute-on-demand LRU cache.

The grid's reuse axis (one RBF matrix per gamma, shared by every C cell
and fold) used to force the cross-gamma pool to materialize ALL
``len(gammas) * n^2 * 8`` bytes up front. Joulani et al. frame CV as a
dependency structure over reusable partial solutions — our lane graph IS
that structure, so the schedule itself knows which kernel a chunk needs
next and which resident kernel is furthest from being needed. This module
makes kernel matrices **residency-managed operands**:

* a :class:`KernelSpec` *declares* a kernel source — ``(kind, gamma, X,
  backend)`` plus an optional row truncation — without computing it. A
  spec satisfies the cheap half of the engine's kernel-source protocol
  (``dtype``, ``fused``, ``nbytes``) so schedulers can type/size lanes
  without materializing, and ``materialize()`` produces the dense source
  on demand;
* a :class:`SourceCache` fronts a ``{key: source-or-spec}`` dict:
  already-dense entries are *pinned* (always resident, exactly the
  pre-cache behaviour), spec entries materialize through the cache under
  a ``max_resident`` / ``cache_bytes`` budget and are **evicted by
  schedule distance** — the resident source with the fewest remaining
  unretired lanes goes first (it is the one the schedule needs least),
  the *sticky* (currently serving) source only as a last resort, ties
  broken least-recently-used.

Eviction drops only the materialized array. Because a spec is a pure
function of ``(X, kind, gamma, backend, n)``, re-materialization rebuilds
the bit-identical matrix, and a lane's iterate sequence depends only on
its own (source, mask, C, state) — so any eviction/re-materialization
schedule preserves the pool's bit-parity invariant (covered by
tests/test_sources.py). The scheduler's packed-batch cache for an evicted
source is written back to the lanes *before* the kernel is dropped
(``on_evict``), so no solver progress is ever lost to eviction.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.svm.engine import DenseKernel, PallasRBF
from repro.svm.kernels import kernel_matrix


def is_factory(entry) -> bool:
    """True when a sources-dict entry is a factory (declares a kernel and
    materializes on demand) rather than an already-usable kernel source —
    factories expose ``materialize()``, sources expose ``row()``."""
    return callable(getattr(entry, "materialize", None)) and \
        not callable(getattr(entry, "row", None))


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """A declared-but-not-computed kernel source.

    ``n`` truncates to the first ``n`` instances (the k-fold padding
    truncation). The slice is applied to ``X`` *before* the kernel call —
    computing the full ``(N, N)`` matrix and slicing after wastes
    O(N² − n²) compute and memory per materialization (and the two are
    not bit-identical at every shape, so callers that need parity with a
    truncated kernel must build it this way too, see ``core/cv.py``).

    ``kind="pallas_rbf"`` declares a *row-streaming* source: materialize
    returns a :class:`~repro.svm.engine.PallasRBF` holding only ``X[:n]``
    — ``nbytes`` is X's bytes, not n² kernel bytes, so the cache budget
    bounds such sources by data size, and ``fused`` is answered True
    without compute (WSS-1 is checked at pool construction, not deferred
    to first dispatch).
    """
    X: Any
    gamma: float = 1.0
    kind: str = "rbf"
    backend: str = "jnp"
    n: int | None = None

    @property
    def fused(self) -> bool:
        """Dense kinds materialize a plain dense source (the fused/WSS
        check re-runs against the product anyway — deferred check);
        pallas_rbf is fused by declaration."""
        return self.kind == "pallas_rbf"

    @property
    def streams_rows(self) -> bool:
        return self.kind == "pallas_rbf"

    @property
    def dtype(self):
        return self.X.dtype

    @property
    def n_rows(self) -> int:
        return int(self.X.shape[0] if self.n is None else self.n)

    @property
    def nbytes(self) -> int:
        """Resident bytes of the materialized source — what the cache
        budget accounts, known without computing anything: n² kernel
        bytes for dense kinds, X's bytes for row-streaming kinds."""
        if self.kind == "pallas_rbf":
            d = int(self.X.shape[1])
            return self.n_rows * d * self.X.dtype.itemsize
        return self.n_rows * self.n_rows * self.X.dtype.itemsize

    def materialize(self):
        X = self.X if self.n is None else self.X[: self.n]
        if self.kind == "pallas_rbf":
            return PallasRBF(X, self.gamma)
        K = kernel_matrix(X, X, kind=self.kind, gamma=self.gamma,
                          backend=self.backend)
        K.block_until_ready()
        return DenseKernel(K)


def source_identity(entry, y=None) -> tuple | None:
    """Content identity of a sources-dict entry: equal identities declare
    the SAME kernel values, so a multi-tenant pool may serve both tenants
    from one resident kernel. ``None`` means "not identifiable — never
    dedup" (opaque custom sources).

    Labels are part of the identity when given: the pool stores one ``y``
    per source key, so two tenants may share a kernel only when they also
    share the label vector that kernel's lanes train against.

    Arrays enter as sha1 digests of their raw bytes (after the spec's own
    ``[:n]`` truncation — a truncated and an untruncated view of the same
    ``X`` are different kernels), keeping the identity hashable and cheap
    to compare without holding the data."""
    import hashlib

    import numpy as np

    def digest(a) -> str:
        a = np.ascontiguousarray(np.asarray(a))
        return hashlib.sha1(a.tobytes()).hexdigest()

    if isinstance(entry, KernelSpec):
        ident = ("spec", entry.kind, float(entry.gamma), entry.backend,
                 entry.n_rows, str(entry.dtype),
                 digest(entry.X[: entry.n_rows]))
    elif isinstance(entry, DenseKernel):
        K = entry.K
        ident = ("dense", str(K.dtype), int(K.shape[0]), digest(K))
    else:
        return None
    if y is not None:
        ident = ident + (digest(y),)
    return ident


def _source_nbytes(src) -> int:
    nb = getattr(src, "nbytes", None)
    if nb is not None:
        return int(nb)
    K = getattr(src, "K", None)
    return int(K.nbytes) if K is not None else 0


def source_nbytes(src) -> int:
    """Resident bytes a source (or spec) will occupy — the figure the
    cache budget accounts. Public alias the schedule simulator prices
    plans with."""
    return _source_nbytes(src)


def budget_fits(count: int, nbytes: int, *, max_resident: int = 0,
                cache_bytes: int = 0) -> bool:
    """THE residency budget rule (0 = unbounded), in pure form: eviction,
    the scheduler's per-chunk source selection and the schedule simulator
    (``repro.analysis.plan_sim``) all defer here, so they cannot
    desynchronize."""
    if max_resident and count > max_resident:
        return False
    return not (cache_bytes and nbytes > cache_bytes)


def pick_victim(resident, *, sticky, distance):
    """THE eviction victim rule, in pure form: ``resident`` is the
    managed keys in recency order (least-recently-used first). Non-sticky
    before sticky, then ascending schedule distance (fewest remaining
    lanes = needed least), then LRU. Shared by the live cache and the
    schedule simulator."""
    keys = list(resident)
    return min(keys, key=lambda k: (k == sticky, distance(k),
                                    keys.index(k)))


class SourceCache:
    """Residency manager for a pool's ``{key: source-or-spec}`` dict.

    * ``get(key)`` returns a usable kernel source, materializing a spec
      entry on demand. Before a materialization that would exceed the
      budget (``max_resident`` managed sources and/or ``cache_bytes``
      managed bytes; 0 = unbounded), resident managed sources are evicted
      in *schedule-distance* order: fewest remaining lanes first
      (``distance(key)``, supplied by the scheduler), the sticky source
      (``sticky()``) only if nothing else can be evicted, ties broken
      least-recently-used. ``on_evict(key)`` fires before the array is
      dropped — the scheduler writes its packed batch back there.
    * ``meta(key)`` answers the cheap protocol questions (``dtype``,
      ``fused``) without materializing: the resident source when there is
      one, else the entry itself (specs carry ``dtype``/``fused``).
    * pinned entries (already-materialized sources) are always resident,
      never evicted, and not counted against the budget — a pool built
      from dense matrices behaves exactly as before the cache existed.

    The fused/WSS-1 compatibility check runs at materialization time
    (``wss`` is the pool's selection mode): a factory's product cannot be
    inspected at pool construction, so the check is *deferred* — it fires
    on the first dispatch that would actually mis-drive the source.
    """

    def __init__(self, entries: dict, *, max_resident: int = 0,
                 cache_bytes: int = 0, wss: str = "2",
                 distance: Callable[[Any], int] | None = None,
                 sticky: Callable[[], Any] | None = None,
                 on_evict: Callable[[Any], None] | None = None,
                 on_trace: Callable | None = None):
        self._entries = dict(entries)
        self.max_resident = int(max_resident)
        self.cache_bytes = int(cache_bytes)
        self.wss = wss
        self._distance = distance or (lambda key: 0)
        self._sticky = sticky or (lambda: None)
        self.on_evict = on_evict
        # varargs event sink (the pool's ``_trace``): materialize/evict
        # events join the scheduler's trace grammar through here
        self.on_trace = on_trace
        self._resident: dict[Any, Any] = {}     # managed key -> source (LRU)
        self._pinned: dict[Any, Any] = {
            k: v for k, v in entries.items() if not is_factory(v)}
        # accounting (the grid's kernel_time and the bench peak_resident
        # block read these)
        self.kernel_time = 0.0
        self.materializations = 0
        self.evictions = 0
        self.peak_resident = len(self._pinned)
        self.peak_resident_bytes = sum(
            _source_nbytes(s) for s in self._pinned.values())

    # ------------------------------------------------------------- queries

    def resident(self, key) -> bool:
        return key in self._pinned or key in self._resident

    def pinned(self, key) -> bool:
        return key in self._pinned

    def nbytes_of(self, key) -> int:
        """Resident footprint of ``key`` — from the materialized source if
        resident, else the spec's estimate; never materializes."""
        return _source_nbytes(self.meta(key))

    @property
    def budgeted(self) -> bool:
        return bool(self.max_resident or self.cache_bytes)

    def fits(self, count: int, nbytes: int) -> bool:
        """True when ``count`` managed sources totalling ``nbytes`` bytes
        fit the budget (0 = unbounded). Defers to the pure
        :func:`budget_fits`: eviction (``_evict_for``), the scheduler's
        per-chunk source selection (``LanePool._budget_sources``) and the
        schedule simulator all share the one rule."""
        return budget_fits(count, nbytes, max_resident=self.max_resident,
                           cache_bytes=self.cache_bytes)

    def meta(self, key):
        """The entry for protocol questions that must not materialize
        (``dtype``, ``fused``): the resident source if there is one, else
        the spec itself."""
        if key in self._pinned:
            return self._pinned[key]
        return self._resident.get(key, self._entries[key])

    @property
    def resident_bytes(self) -> int:
        return sum(_source_nbytes(s) for s in self._resident.values())

    @property
    def pinned_bytes(self) -> int:
        return sum(_source_nbytes(s) for s in self._pinned.values())

    @property
    def stats(self) -> dict:
        return {"materializations": self.materializations,
                "evictions": self.evictions,
                "kernel_time": round(self.kernel_time, 4),
                "peak_resident": self.peak_resident,
                "peak_resident_bytes": self.peak_resident_bytes}

    # ----------------------------------------------------- entry lifecycle

    def add_entry(self, key, entry) -> None:
        """Admit a new entry after construction (the daemon admits plans
        into a live pool). Same pinning rule as the constructor: an
        already-usable source is pinned, a factory is managed."""
        if key in self._entries:
            raise ValueError(f"source {key!r} already present")
        self._entries[key] = entry
        if not is_factory(entry):
            self._pinned[key] = entry
            self.peak_resident = max(
                self.peak_resident, len(self._pinned) + len(self._resident))

    def remove_entry(self, key) -> None:
        """Drop an entry and any residency it holds (a drained study's
        sources leave the pool). Not an eviction: no ``on_evict`` — the
        caller has already retired every lane reading ``key``."""
        self._entries.pop(key, None)
        self._pinned.pop(key, None)
        self._resident.pop(key, None)

    # ------------------------------------------------------ materialization

    def check_fused(self, key, src) -> None:
        """The one fused/WSS-1 compatibility rule: the pool applies it to
        pinned entries at construction, the cache to factory products at
        materialization."""
        if getattr(src, "fused", False) and self.wss == "2":
            raise ValueError(
                f"source {key!r} is fused and requires WSS-1 (wss='1')")

    def _evict_for(self, incoming_bytes: int) -> None:
        """Evict managed residents until the budget admits ``incoming_bytes``
        more. Victim order: non-sticky before sticky, then ascending
        schedule distance (fewest remaining lanes = needed least), then
        least-recently-used (dict order = recency)."""
        # the `self._resident` guard keeps a single over-budget kernel
        # admissible when there is nothing left to evict
        while self._resident and not self.fits(
                len(self._resident) + 1,
                self.resident_bytes + incoming_bytes):
            # dict order = recency (LRU first); the pure rule is shared
            # with the schedule simulator
            victim = pick_victim(self._resident, sticky=self._sticky(),
                                 distance=self._distance)
            if self.on_evict is not None:
                self.on_evict(victim)
            if self.on_trace is not None:
                self.on_trace("evict", victim,
                              _source_nbytes(self._resident[victim]))
            del self._resident[victim]
            self.evictions += 1

    def get(self, key):
        """Return a usable kernel source for ``key``, materializing (and
        evicting per the budget) on demand."""
        if key in self._pinned:
            return self._pinned[key]
        src = self._resident.pop(key, None)
        if src is not None:                    # hit: refresh recency
            self._resident[key] = src
            return src
        spec = self._entries[key]
        self._evict_for(_source_nbytes(spec))
        t0 = time.perf_counter()
        # sources are pytrees: block on the product so kernel_time measures
        # the materialization, not its dispatch (the dense path blocks
        # inside materialize; the row-streaming path holds only X)
        src = jax.block_until_ready(spec.materialize())
        self.kernel_time += time.perf_counter() - t0
        self.materializations += 1
        self.check_fused(key, src)
        self._resident[key] = src
        if self.on_trace is not None:
            self.on_trace("materialize", key, _source_nbytes(src))
        self.peak_resident = max(
            self.peak_resident, len(self._pinned) + len(self._resident))
        self.peak_resident_bytes = max(
            self.peak_resident_bytes,
            self.resident_bytes
            + sum(_source_nbytes(s) for s in self._pinned.values()))
        return src
