"""Lane scheduler: repacked batched dispatch with incremental admission.

The engine's batched driver (``engine.solve_batched``) advances every lane
of a fixed-width batch until the LAST lane converges — converged lanes
freeze but still flow through the vmapped body, so on CPU the batch was
measured slower than the sequential fold loop (DESIGN.md §Batched folds).
This module replaces the fixed batch with a **schedule**:

* **repacking** — between chunks, converged lanes are *retired* (their
  state finalized into an ``SMOResult`` and scattered back to the caller's
  slot by original lane id) and the live lanes gathered into a compact
  batch, so device work tracks ``sum_h n_iter_h`` instead of
  ``width * max_h n_iter_h``;
* **bucketing** — the packed width is rounded up to a multiple of
  ``lane_quantum`` (widths 1 and 2 stay exact), padding with inert
  ``done`` lanes, so distinct jit programs stay O(peak_width / quantum)
  instead of one retrace per live-width;
* **degradation** — a dispatch width of 1 uses the *single-lane*
  sequential program (the same ``_chunk_jit`` the scalar ``solve`` path
  uses), so a straggler tail costs sequential-solver time, not a vmapped
  batch of one;
* **width capping** (``max_width``) — the dispatch width is bounded by a
  backend cost model: XLA CPU pays a ~1.5-2x per-lane-iteration penalty
  for ANY vmapped width (a thread-pool fork/join per parallel fusion, the
  (w, n) state leaving L2) — measured flat from width 2 up — so on CPU the
  only schedule at parity with the sequential fold loop is width 1: the
  scheduler round-robins lanes through the sequential program at chunk
  granularity (total device work still tracks ``sum_h n_iter_h``; lanes
  beyond the cap park for one chunk, least-served first). Accelerator
  backends amortize dispatch overhead across lanes and default to
  unbounded width;
* **admission** — a lane may be added with a *dependency* on another
  lane's result plus a seed transform (``seed_fn(prev_result) ->
  (alpha0, f0)``, e.g. a ``SEEDERS`` entry + ``init_f``). It is admitted
  into the live batch the moment its dependency retires — so the CV grid's
  per-cell fold chains interleave instead of barriering a whole row at
  each fold (cell A solves fold h+1 while cell B still iterates fold h).

Because each lane's iterate sequence depends only on its own
(mask, C, state) — the engine body freezes ``done`` lanes and ``vmap``
keeps lanes independent — per-lane results are **bit-identical** to
sequential ``engine.solve`` runs regardless of the packing schedule
(covered by tests/test_scheduler.py).

Checkpointing: ``snapshot_lanes()`` serializes every admitted lane's
(alpha, f, n_iter, done) stacked **in lane-id order**, not packed
position, so a mid-batch snapshot survives any repack/resume boundary;
``core/cv.py:run_cv_batched`` wires it to the checkpoint manager.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.svm.engine import (EngineState, SMOResult, _chunk_batched_jit,
                              _chunk_jit, _finalize, init_state)


def bucket_width(w: int, quantum: int = 4) -> int:
    """Packed width for ``w`` live lanes: 1 and 2 are exact (the straggler
    tail, where padding would be pure overhead), wider batches round up to
    the next multiple of ``quantum`` so the number of distinct compiled
    programs stays bounded by ``peak_width / quantum + 2``."""
    if w <= 2:
        return max(w, 1)
    q = max(int(quantum), 1)
    return -(-w // q) * q


@dataclasses.dataclass
class _Lane:
    id: Any
    train_mask: jnp.ndarray
    C: float
    max_iter: int
    state: EngineState | None = None      # admitted, not yet retired
    dep: Any = None                       # lane id this lane seeds from
    seed_fn: Callable | None = None       # SMOResult -> (alpha0, f0)
    result: SMOResult | None = None       # set at retirement
    served: int = 0                       # chunks dispatched (park fairness)


class LaneScheduler:
    """Queue of independent solve lanes driven to convergence by repacked,
    bucketed, incrementally-admitted chunk dispatch over one shared kernel
    source. See the module docstring for the scheduling policy; per-lane
    results are bit-identical to sequential solves."""

    def __init__(self, source, y, *, tol: float = 1e-3, wss: str = "2",
                 chunk_iters: int = 2048, lane_quantum: int = 4,
                 max_width: int | None = None,
                 on_snapshot=None, snapshot_every: int = 1):
        if source.fused and wss == "2":
            raise ValueError("fused kernel sources require WSS-1 (wss='1')")
        if max_width is None:
            # backend cost model (see module docstring): CPU's vmapped
            # batch loses at every width > 1, accelerators want full width
            max_width = 1 if jax.default_backend() == "cpu" else 0
        self.max_width = int(max_width)   # 0 = unbounded
        self.source = source
        self.y = y
        self.tol = tol
        self.wss = wss
        self.chunk_iters = int(chunk_iters)
        self.lane_quantum = int(lane_quantum)
        self.on_snapshot = on_snapshot
        self.snapshot_every = max(int(snapshot_every), 1)
        self._lanes: dict[Any, _Lane] = {}
        self._order: list[Any] = []       # insertion order = packing order
        self.results: dict[Any, SMOResult] = {}
        self.seed_time = 0.0              # admission transforms (paper "init.")
        self.chunk_count = 0
        self._width_log: list[tuple[int, int]] = []   # (live, packed)/chunk
        # packed-batch cache: rebuilt only when the live set changes
        self._packed_ids: tuple | None = None
        self._packed: tuple | None = None  # (masks, Cs, it_caps, states)

    # ---------------------------------------------------------- lane intake

    def add(self, lane_id, train_mask, C, alpha0=None, f0=None, *,
            n_iter0: int = 0, max_iter: int = 10_000_000,
            dep=None, seed_fn=None) -> None:
        """Register a lane. Either give its start point (``alpha0``/``f0``,
        optionally ``n_iter0`` when resuming a snapshot) or a dependency
        (``dep`` = another lane id, ``seed_fn`` mapping that lane's
        ``SMOResult`` to this lane's (alpha0, f0)) — the lane is then
        admitted when the dependency retires."""
        if lane_id in self._lanes:
            raise ValueError(f"duplicate lane id {lane_id!r}")
        if (dep is None) == (alpha0 is None):
            raise ValueError("give exactly one of alpha0/f0 or dep/seed_fn")
        if (alpha0 is None) != (f0 is None):
            raise ValueError("alpha0 and f0 must be given together "
                             "(f0 = init_f(K, y, alpha0))")
        if dep is not None and seed_fn is None:
            raise ValueError("a dependent lane needs a seed_fn")
        lane = _Lane(id=lane_id, train_mask=train_mask, C=C,
                     max_iter=int(max_iter), dep=dep, seed_fn=seed_fn)
        if alpha0 is not None:
            lane.state = init_state(self.source, self.y, train_mask,
                                    alpha0, f0, n_iter0=n_iter0)
        self._lanes[lane_id] = lane
        self._order.append(lane_id)

    def add_result(self, lane_id, result: SMOResult) -> None:
        """Register an already-solved lane (a restored ``done`` snapshot):
        it participates as a seed dependency but is never dispatched."""
        if lane_id in self._lanes:
            raise ValueError(f"duplicate lane id {lane_id!r}")
        lane = _Lane(id=lane_id, train_mask=None, C=None, max_iter=0,
                     result=result)
        self._lanes[lane_id] = lane
        self._order.append(lane_id)
        self.results[lane_id] = result

    # ------------------------------------------------------------ scheduling

    def _admit(self) -> None:
        """Admit every pending lane whose dependency has retired: run its
        seed transform (timed as init/seed work) and build its state."""
        for lane_id in self._order:
            lane = self._lanes[lane_id]
            if lane.state is not None or lane.result is not None:
                continue
            if lane.dep not in self.results:
                continue
            t0 = time.perf_counter()
            alpha0, f0 = lane.seed_fn(self.results[lane.dep])
            jax.block_until_ready((alpha0, f0))
            self.seed_time += time.perf_counter() - t0
            lane.state = init_state(self.source, self.y, lane.train_mask,
                                    alpha0, f0)

    def _live(self) -> list[_Lane]:
        return [self._lanes[i] for i in self._order
                if self._lanes[i].state is not None
                and self._lanes[i].result is None]

    def _retire(self, lane: _Lane) -> None:
        lane.result = _finalize(lane.state, self.y, lane.train_mask,
                                lane.C, self.tol)
        self.results[lane.id] = lane.result

    def _pack(self, live: list[_Lane]) -> None:
        """Gather the live lanes into a compact batch of bucketed width;
        pad positions replicate lane 0 with ``done`` set (inert: the engine
        body passes done lanes through untouched, and the while_loop's
        ``any(~done)`` ignores them)."""
        width = bucket_width(len(live), self.lane_quantum)
        states = [ln.state for ln in live]
        masks = [ln.train_mask for ln in live]
        Cs = [ln.C for ln in live]
        caps = [ln.max_iter for ln in live]
        for _ in range(width - len(live)):
            pad = live[0].state
            states.append(pad._replace(done=jnp.ones((), bool)))
            masks.append(live[0].train_mask)
            Cs.append(live[0].C)
            caps.append(0)
        self._packed_ids = tuple(ln.id for ln in live)
        self._packed = (jnp.stack(masks),
                        jnp.asarray(Cs, self.source.dtype),
                        jnp.asarray(caps, jnp.int64),
                        EngineState.stack(states))

    def _unpack(self, live: list[_Lane]) -> None:
        states = self._packed[3]
        for i, lane in enumerate(live):
            lane.state = states.lane(i)
        self._packed_ids = None
        self._packed = None

    def run(self) -> dict[Any, SMOResult]:
        """Drive every lane to retirement; returns {lane_id: SMOResult}."""
        while True:
            self._admit()
            live = self._live()
            if not live:
                pending = [i for i in self._order
                           if self._lanes[i].result is None]
                if pending:
                    raise RuntimeError(
                        f"lanes {pending} wait on dependencies that never "
                        "retire (missing or cyclic dep)")
                break
            selected, parked = live, False
            if self.max_width and len(live) > self.max_width:
                # park the overflow for one chunk, least-served lanes first
                # (stable sort: insertion order breaks ties), so every lane
                # keeps advancing at chunk granularity
                selected = sorted(live, key=lambda ln: ln.served)
                selected = selected[:self.max_width]
                parked = True
            for lane in selected:
                lane.served += 1
            width = (1 if len(selected) == 1
                     else bucket_width(len(selected), self.lane_quantum))
            self._width_log.append((len(live), width))
            if len(selected) == 1:
                self._step_single(selected[0])
            else:
                self._step_batched(selected, flush=parked)
            self.chunk_count += 1
            if self.on_snapshot is not None and \
                    self.chunk_count % self.snapshot_every == 0:
                self.on_snapshot(self)
        return dict(self.results)

    def _step_single(self, lane: _Lane) -> None:
        """Dispatch width 1: the sequential single-lane program
        (bit-identical to ``engine.solve``'s chunks) — no vmap overhead on
        a straggler or a width-capped round-robin schedule."""
        lane.state = _chunk_jit(self.source, self.y, lane.train_mask, lane.C,
                                self.tol, jnp.asarray(lane.max_iter, jnp.int64),
                                lane.state, n_iters=self.chunk_iters,
                                wss=self.wss)
        if bool(lane.state.done):
            self._retire(lane)

    def _step_batched(self, live: list[_Lane], flush: bool = False) -> None:
        """One chunk over the selected lanes. ``flush`` forces the packed
        states back into the lanes afterwards — required whenever the next
        chunk may select a different lane set (parking rotation), or the
        stale ``lane.state`` would be repacked and progress lost."""
        if self._packed_ids != tuple(ln.id for ln in live):
            self._pack(live)
        masks, Cs, caps, states = self._packed
        states = _chunk_batched_jit(self.source, self.y, masks, Cs, self.tol,
                                    caps, states, n_iters=self.chunk_iters,
                                    wss=self.wss)
        self._packed = (masks, Cs, caps, states)
        done = np.asarray(states.done[:len(live)])   # one (w,) transfer
        if done.any() or flush:
            self._unpack(live)
            for flag, lane in zip(done, live):
                if flag:
                    self._retire(lane)

    # ---------------------------------------------------------- observability

    def _lane_state(self, lane: _Lane) -> EngineState:
        """Current state of a live lane, reading through the packed cache."""
        if self._packed_ids is not None and lane.id in self._packed_ids:
            return self._packed[3].lane(self._packed_ids.index(lane.id))
        return lane.state

    def snapshot_lanes(self):
        """(lane_ids, tree) of every admitted-or-retired lane, stacked in
        lane-id (insertion) order — NOT packed position — so a mid-batch
        checkpoint restores by original lane id across any repack/resume
        boundary. ``tree`` = {alpha (L, n), f (L, n), n_iter (L,),
        done (L,)}; pending (unadmitted) lanes are omitted — their seeds
        re-derive from the retired results in the snapshot."""
        ids, alphas, fs, iters, dones = [], [], [], [], []
        for lane_id in self._order:
            lane = self._lanes[lane_id]
            if lane.result is not None:
                src, done = lane.result, True
            elif lane.state is not None:
                src, done = self._lane_state(lane), False
            else:
                continue
            ids.append(lane_id)
            alphas.append(src.alpha)
            fs.append(src.f)
            iters.append(src.n_iter)
            dones.append(done)
        tree = {"alpha": jnp.stack(alphas), "f": jnp.stack(fs),
                "n_iter": jnp.stack(iters), "done": jnp.asarray(dones)}
        return ids, tree

    @property
    def occupancy(self) -> dict:
        """Schedule shape over the run. ``mean_live_width`` counts
        *runnable* lanes per chunk (the demand); ``mean_packed_width`` /
        ``peak_width`` count the *dispatched* program width (after width
        capping and pad bucketing). live >> packed is the width-capped
        round-robin regime (CPU); live == packed == peak means retirement
        never compacted the batch (lanes converged together)."""
        if not self._width_log:
            return {"chunks": 0, "mean_live_width": 0.0,
                    "mean_packed_width": 0.0, "peak_width": 0,
                    "programs": 0}
        lives = [w for w, _ in self._width_log]
        packed = [p for _, p in self._width_log]
        return {"chunks": len(self._width_log),
                "mean_live_width": round(sum(lives) / len(lives), 3),
                "mean_packed_width": round(sum(packed) / len(packed), 3),
                "peak_width": max(packed),
                "programs": len(set(packed))}
