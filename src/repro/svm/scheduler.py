"""Lane pool: multi-source repacked batched dispatch with incremental
admission.

The engine's batched driver (``engine.solve_batched``) advances every lane
of a fixed-width batch until the LAST lane converges — converged lanes
freeze but still flow through the vmapped body, so on CPU the batch was
measured slower than the sequential fold loop (DESIGN.md §Batched folds).
This module replaces the fixed batch with a **schedule** over a pool of
lanes that may span SEVERAL kernel sources (e.g. one RBF matrix per gamma
of a hyper-parameter grid):

* **repacking** — between chunks, converged lanes are *retired* (their
  state finalized into an ``SMOResult`` keyed by original lane id) and the
  live lanes gathered into a compact batch, so device work tracks
  ``sum_h n_iter_h`` instead of ``width * max_h n_iter_h``;
* **source bucketing** — every lane carries a *source key*; the selected
  lanes are grouped by source and ONE batched program is dispatched per
  (source, width) bucket. Lanes of different sources never share a
  program (their kernel operands differ), but they share the pool's
  admission, width budget and fairness accounting — this is what
  dissolves the per-gamma row barrier in ``run_grid``;
* **width bucketing** — a group's packed width is rounded up to a multiple
  of ``lane_quantum`` (widths 1 and 2 stay exact), padding with inert
  ``done`` lanes, so distinct jit programs stay O(peak_width / quantum)
  per source shape instead of one retrace per live-width;
* **degradation** — a group of 1 uses the *single-lane* sequential
  program (the same ``chunk_jit`` the scalar ``solve`` path uses), so a
  straggler tail costs sequential-solver time, not a vmapped batch of one;
* **width capping** (``max_width``) — the TOTAL dispatch width per chunk
  is bounded by a *measured* cost model (``svm/cost_model.py`` loads
  ``results/cost_model.json``, written per (backend, source kind) by
  ``scripts/measure_cost_model.py``; absent entries fall back to the
  historical verdict): XLA CPU pays a ~1.5-2x per-lane-iteration penalty
  for ANY vmapped width (measured flat from width 2 up), so on CPU the
  measured default is width-1 round-robin through the sequential program
  (total device work still tracks
  ``sum_h n_iter_h``). The capped rotation is **source-sticky**: the most
  recently dispatched source keeps the width budget while it has live
  lanes (its kernel matrix stays cache-hot; a per-chunk rotation across
  sources restreams a cold ~n^2 operand every chunk — measured ~5%
  slower), least-served lanes first within it. Accelerator backends
  amortize dispatch overhead across lanes and default to unbounded width;
* **admission** — a lane may be added with a *dependency* on another
  lane's result plus a seed transform (``seed_fn(prev_result) ->
  (alpha0, f0)``), and/or a pure *ordering* edge (``after``) that holds an
  explicitly-started lane until another lane retires. Dependencies may
  cross sources (a gamma-row cell seeding from its C-neighbour in another
  bucket is legal); a lane is admitted the moment its edges retire;
* **kernel residency** — a source may be declared as a *factory*
  (``svm/sources.py:KernelSpec``) instead of a dense matrix: the pool's
  ``SourceCache`` materializes it on the first dispatch that needs it,
  under a ``max_resident``/``cache_bytes`` budget, evicting the resident
  source with the fewest remaining unretired lanes (schedule distance;
  the sticky source only as a last resort). Eviction writes the source's
  packed batch back to its lanes first. Selection is budget-aware at
  every width: a chunk dispatches at most budget-many managed sources
  (sticky/resident preferred) even when ``max_width=0`` selects all live
  lanes, and width-capped selection prefers lanes whose source is already
  resident — so a budgeted pool drains each kernel before paying for the
  next one instead of thrashing. Re-materialization is bit-identical (a
  spec is a pure function of its inputs), preserving the bit-parity
  invariant below.

Because each lane's iterate sequence depends only on its own
(source, mask, C, state) — the engine body freezes ``done`` lanes, lanes of
one program share one source, and ``vmap`` keeps lanes independent —
per-lane results are **bit-identical** to sequential ``engine.solve`` runs
regardless of the packing schedule and of which sources share the pool
(covered by tests/test_scheduler.py and tests/test_study.py).

Checkpointing: ``snapshot_lanes()`` serializes every admitted lane's
(alpha, f, n_iter, done) stacked **in lane-id order**, not packed
position, so a mid-batch snapshot survives any repack/resume boundary;
``core/study.py:run_plan`` wires it to the checkpoint manager.

``LaneScheduler`` remains as the single-source facade (one source, one
label vector) used by callers predating the pool.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.svm import cost_model
from repro.svm import shrink as shrink_mod
from repro.svm.engine import (EngineState, SMOResult, chunk_batched_jit,
                              chunk_batched_sources_jit, chunk_jit, finalize,
                              init_state, stack_sources)
from repro.svm.sources import SourceCache, is_factory


def bucket_width(w: int, quantum: int = 4) -> int:
    """Packed width for ``w`` live lanes: 1 and 2 are exact (the straggler
    tail, where padding would be pure overhead), wider batches round up to
    the next multiple of ``quantum`` so the number of distinct compiled
    programs stays bounded by ``peak_width / quantum + 2``."""
    if w <= 2:
        return max(w, 1)
    q = max(int(quantum), 1)
    return -(-w // q) * q


def possible_widths(peak: int, quantum: int = 4,
                    max_width: int = 0) -> tuple[int, ...]:
    """Every distinct packed width the pool can dispatch for a source
    whose live-lane count ranges over 1..``peak`` under a ``max_width``
    cap (0 = unbounded): the compile-shape enumeration the plan analyzer
    (``repro.analysis.plan_check``) maps onto jitted programs — width 1
    is the single-lane program, each bucketed width >= 2 one batched
    program. Kept next to :func:`bucket_width` so prediction and
    execution cannot drift apart."""
    cap = int(peak) if not max_width else min(int(peak), int(max_width))
    return tuple(sorted({bucket_width(w, quantum)
                         for w in range(1, max(cap, 1) + 1)}))


def order_capped(lanes, *, sticky, resident, served, source) -> list:
    """Width-capped dispatch priority — the PURE form of the pool's
    sticky > resident > cold ordering, shared with the schedule simulator
    (``repro.analysis.plan_sim``) so prediction and execution cannot
    drift. ``lanes`` is any sequence; ``source(lane)`` names its source
    key, ``resident(key)``/``served(lane)`` supply the pool-or-simulated
    residency and fairness state. Each tier is stable-sorted by
    ``served`` (ties keep input order — the pool's insertion order)."""
    stick = [ln for ln in lanes if source(ln) == sticky]
    near = [ln for ln in lanes
            if source(ln) != sticky and resident(source(ln))]
    far = [ln for ln in lanes
           if source(ln) != sticky and not resident(source(ln))]
    return (sorted(stick, key=served) + sorted(near, key=served)
            + sorted(far, key=served))


def select_capped(lanes, *, max_width, sticky, resident, served, source,
                  tenant, tenant_served) -> list:
    """Pure form of ``LanePool._cap_select``: single-tenant inputs take
    the historical sticky/resident/served order truncated to the width
    budget; multi-tenant inputs fair-share it — per-tenant ordering by
    the same policy, tenants interleaved round-robin least-served first.
    ``tenant(lane)`` tags a lane, ``tenant_served`` maps tag -> lane-chunk
    count. Shared with the schedule simulator."""
    tenants = list(dict.fromkeys(tenant(ln) for ln in lanes))
    order = dict(sticky=sticky, resident=resident, served=served,
                 source=source)
    if len(tenants) <= 1:
        return order_capped(lanes, **order)[:max_width]
    per = {t: order_capped([ln for ln in lanes
                            if tenant(ln) is t or tenant(ln) == t], **order)
           for t in tenants}
    tenants.sort(key=lambda t: tenant_served.get(t, 0))
    out: list = []
    while len(out) < max_width and any(per.values()):
        for t in tenants:
            if per[t] and len(out) < max_width:
                out.append(per[t].pop(0))
    return out


def budget_sources(srcs, *, budgeted, pinned, resident, sticky, nbytes,
                   fits) -> set:
    """Pure form of ``LanePool._budget_sources``: which of the candidate
    source keys may dispatch this chunk under the residency budget.
    Pinned sources always; managed sources greedily in sticky > resident
    > cold priority (stable: input order breaks ties) while the budget
    rule (``fits(count, bytes)``) admits the next one. Shared with the
    schedule simulator."""
    srcs = list(dict.fromkeys(srcs))
    if not budgeted or len(srcs) <= 1:
        return set(srcs)
    allowed = {s for s in srcs if pinned(s)}
    managed = sorted((s for s in srcs if s not in allowed),
                     key=lambda s: (s != sticky, not resident(s)))
    taken: list = []
    used = 0
    for s in managed:
        nb = nbytes(s)
        if taken and not fits(len(taken) + 1, used + nb):
            break
        taken.append(s)
        used += nb
    return allowed | set(taken)


def snapshot_nbytes(n: int, itemsize: int, lane_count: int,
                    shrink: bool = False) -> int:
    """Estimated serialized bytes of one pool snapshot record
    (``snapshot_lanes``): per lane, stacked ``alpha`` + ``f`` rows
    (``2 * n * itemsize``), an ``n_iter`` scalar (8) and a ``done`` flag
    (1); shrink-enabled pools add the ``active`` mask (n), the
    ``shrunk``/``no_shrink`` flags and the int32 ``unshrinks`` counter.
    The simulator prices checkpoint write volume with this — an estimate
    of array payload, not serialization framing."""
    per = 2 * int(n) * int(itemsize) + 8 + 1
    if shrink:
        per += int(n) + 1 + 1 + 4
    return int(lane_count) * per


@dataclasses.dataclass
class _Lane:
    id: Any
    source: Any                           # key into the pool's sources
    train_mask: jnp.ndarray
    C: float
    max_iter: int
    state: EngineState | None = None      # admitted, not yet retired
    dep: Any = None                       # lane id this lane seeds from
    seed_fn: Callable | None = None       # SMOResult -> (alpha0, f0)
    after: Any = None                     # ordering-only admission edge
    alpha0: Any = None                    # deferred start (held by ``after``)
    f0: Any = None
    n_iter0: int = 0
    result: SMOResult | None = None       # set at retirement
    served: int = 0                       # chunks dispatched (park fairness)
    tenant: Any = None                    # fair-share accounting group
    seed_s: float = 0.0                   # admission-transform wall time
    solve_s: float = 0.0                  # dispatch wall time attributed here
    shrink: Any = None                    # shrink.LaneShrink when enabled
    shrink0: Any = None                   # restored ledger (active, flags)


class LanePool:
    """Queue of independent solve lanes over MULTIPLE kernel sources,
    driven to convergence by repacked, source-bucketed, incrementally-
    admitted chunk dispatch. See the module docstring for the scheduling
    policy; per-lane results are bit-identical to sequential solves.

    ``sources`` maps a source key to a kernel source, or to a *factory*
    (e.g. ``sources.KernelSpec``) that declares one without computing it:
    factory entries materialize on demand through the pool's
    :class:`~repro.svm.sources.SourceCache` under the
    ``max_resident``/``cache_bytes`` budget and are evicted by schedule
    distance (DESIGN.md §Kernel-source cache), so pool memory scales with
    the budget instead of the source count. ``y`` is the label vector
    shared by every source, or a dict keyed like ``sources`` when sources
    carry different instance sets. ``on_result(lane_id, result)`` streams
    retirements (long studies consume results as they land);
    ``on_lane_chunk(lane_id, state)`` observes every still-live lane after
    each of its chunks (the per-lane mid-checkpoint hook).
    """

    def __init__(self, sources, y, *, tol: float = 1e-3, wss: str = "2",
                 chunk_iters: int = 2048, lane_quantum: int = 4,
                 max_width: int | None = None,
                 max_resident: int = 0, cache_bytes: int = 0,
                 on_snapshot=None, snapshot_every: int = 1,
                 on_result=None, on_lane_chunk=None,
                 shrink_every: int | str = 0, shrink_quantum: int = 128,
                 shrink_caps=None, shrink_on_seed: bool = True,
                 on_trace=None):
        if not isinstance(sources, dict):
            raise ValueError("sources must be a {key: source} dict")
        # an EMPTY pool is legal: a long-lived daemon constructs the pool
        # once and admits sources/lanes as plans arrive (add_source)
        kinds = {cost_model.source_kind(s) for s in sources.values()}
        if max_width is None:
            # measured cost model (results/cost_model.json, written by
            # scripts/measure_cost_model.py): per-(backend, source-kind)
            # width verdict, combined conservatively across this pool's
            # kinds. Falls back to the historical default when unmeasured:
            # CPU's vmapped batch loses at every width > 1, accelerators
            # want full width.
            max_width = cost_model.pick_max_width(kinds=kinds)
        self.max_width = int(max_width)   # 0 = unbounded
        if shrink_every == "auto":
            # backend-gated default: shrinking trades smaller per-iteration
            # operands for extra compiled programs (one per cap bucket) and
            # host-side lifecycle sync — the cost-model sweep decides
            # whether smaller-cap programs are actually faster here
            shrink_every = shrink_mod.DEFAULT_SHRINK_EVERY \
                if cost_model.pick_shrink(kinds=kinds) else 0
        self.shrink_every = int(shrink_every)
        self.shrink_quantum = int(shrink_quantum)
        self.shrink_caps = tuple(int(c) for c in shrink_caps) \
            if shrink_caps else None
        self.shrink_on_seed = bool(shrink_on_seed)
        self._frac_log: list[float] = []  # (cap or n)/n per lane-dispatch
        self.sources = dict(sources)
        self._ys = {k: (y[k] if isinstance(y, dict) else y)
                    for k in self.sources}
        if on_snapshot is not None and \
                len({np.shape(yv) for yv in self._ys.values()}) > 1:
            # snapshot_lanes stacks every lane's (alpha, f) into one (L, n)
            # tree — fail at construction, not at the first checkpoint
            raise ValueError(
                "snapshotting requires every source to share one instance "
                "set (homogeneous y shapes); got "
                f"{sorted({np.shape(yv) for yv in self._ys.values()})}")
        self.tol = tol
        self.wss = wss
        self.chunk_iters = int(chunk_iters)
        self.lane_quantum = int(lane_quantum)
        self.on_snapshot = on_snapshot
        self.snapshot_every = max(int(snapshot_every), 1)
        self.on_result = on_result
        self.on_lane_chunk = on_lane_chunk
        # schedule trace hook: when set, the pool (and its cache) emit the
        # typed event grammar of DESIGN.md §Schedule simulator — the
        # instrumented dry-run the simulator's output is asserted against.
        # Assignable after construction (the daemon's pool outlives any
        # one trace consumer).
        self.on_trace = on_trace
        self._lanes: dict[Any, _Lane] = {}
        self._order: list[Any] = []       # insertion order = packing order
        self.results: dict[Any, SMOResult] = {}
        self._tenant_served: dict[Any, int] = {}   # fair-share accounting
        self.seed_time = 0.0              # admission transforms (paper "init.")
        self.chunk_count = 0
        self._width_log: list[tuple[int, int]] = []   # (live, dispatched)
        self._programs: set[tuple] = set()            # (source, width) seen
        self._src_live: dict[Any, list] = {}          # key -> [sum, n, peak]
        self._sticky: Any = None          # last dispatched source (affinity)
        # packed-batch cache per source: rebuilt when a group's membership
        # changes (the previous pack is evicted — states written back — so
        # no progress is ever lost to a stale ``lane.state``)
        self._packed: dict[Any, tuple] = {}  # key -> (ids, payload)
        # kernel residency: factory entries materialize on demand under the
        # cache budget and are evicted by schedule distance (fewest
        # remaining lanes first, the sticky source last); dense entries are
        # pinned — see svm/sources.py and DESIGN.md §Kernel-source cache.
        # Evicting a source also drops its packed-batch cache (states are
        # written back to the lanes first, so no progress is lost).
        self.cache = SourceCache(
            self.sources, max_resident=max_resident, cache_bytes=cache_bytes,
            wss=wss, distance=self._source_distance,
            sticky=lambda: self._sticky, on_evict=self._on_source_evict,
            on_trace=self._trace)
        for key, entry in self.sources.items():
            # every entry answers ``fused`` cheaply now (pinned sources
            # directly, specs by declaration — a pallas_rbf spec is fused
            # without compute), so the check runs at construction for
            # all of them; factory *products* are re-checked at
            # materialization anyway (the same rule, deferred)
            self.cache.check_fused(key, entry)

    def _trace(self, *event) -> None:
        """Emit one schedule trace event (a plain tuple) to ``on_trace``.
        The cache funnels its materialize/evict events through here too,
        so assigning ``pool.on_trace`` after construction captures the
        full grammar."""
        if self.on_trace is not None:
            self.on_trace(tuple(event))

    def y_of(self, source_key) -> jnp.ndarray:
        return self._ys[source_key]

    def resolve_source(self, source_key):
        """The usable kernel source for ``source_key``, materialized through
        the residency cache (the pool's own dispatch, the study's seed
        transforms and its eval groups all read kernels through here)."""
        return self.cache.get(source_key)

    def _source_distance(self, source_key) -> int:
        """Schedule distance of a resident source = how many of its lanes
        are still unretired (live or pending admission). The source with
        the FEWEST remaining lanes is the one the schedule needs least —
        it is evicted first."""
        return sum(1 for lane in self._lanes.values()
                   if lane.source == source_key and lane.result is None)

    def _on_source_evict(self, source_key) -> None:
        """A source's kernel is about to be dropped: flush its packed-batch
        cache back into the lanes so no solver progress rides on the
        evicted operand."""
        if source_key in self._packed:
            self._writeback(source_key)

    def _budget_sources(self, lanes) -> set:
        """The sources allowed to dispatch this chunk under the residency
        budget: pinned sources always, managed sources in sticky >
        resident > cold priority (stable: insertion order breaks ties),
        truncated to the budget. Without this, an unbounded-width schedule
        would dispatch EVERY live source's group each chunk and a budget
        below the live source count would re-materialize kernels every
        chunk — with it, the pool drains resident kernels first and
        materialization count tracks the source count, not the chunk
        count, under every width policy. Defers to the pure
        :func:`budget_sources` the simulator replays."""
        return budget_sources(
            [ln.source for ln in lanes], budgeted=self.cache.budgeted,
            pinned=self.cache.pinned, resident=self.cache.resident,
            sticky=self._sticky, nbytes=self.cache.nbytes_of,
            fits=self.cache.fits)

    def _source_key(self, source) -> Any:
        if source is not None:
            if source not in self.sources:
                raise ValueError(f"unknown source key {source!r}")
            return source
        if len(self.sources) == 1:
            return next(iter(self.sources))
        raise ValueError("a multi-source pool needs an explicit source key "
                         "per lane")

    # ------------------------------------------------------- source lifecycle

    def add_source(self, key, entry, y) -> None:
        """Admit a source into a LIVE pool (the daemon's per-plan intake —
        the constructor path for pools whose workload arrives over time).
        Same rules as construction: the fused/WSS check runs now, factory
        entries stay unmaterialized until a dispatch needs them."""
        if key in self.sources:
            raise ValueError(f"duplicate source key {key!r}")
        self.cache.check_fused(key, entry)
        self.sources[key] = entry
        self._ys[key] = y
        self.cache.add_entry(key, entry)

    def remove_source(self, key) -> None:
        """Drop a source whose lanes have all retired (a drained study's
        kernels leave residency so other tenants' budgets recover the
        bytes). Refuses while any unretired lane still reads it."""
        live = [ln.id for ln in self._lanes.values()
                if ln.source == key and ln.result is None]
        if live:
            raise ValueError(
                f"source {key!r} still has unretired lanes {live!r}")
        self._packed.pop(key, None)
        if self._sticky == key:
            self._sticky = None
        self.sources.pop(key, None)
        self._ys.pop(key, None)
        self._src_live.pop(key, None)
        self.cache.remove_entry(key)

    def remove_lanes(self, lane_ids) -> None:
        """Forget RETIRED lanes (a drained study leaves the pool so its
        ids never collide with a later admission). Live/pending lanes
        refuse — cancellation is not yet a pool primitive (ROADMAP)."""
        ids = set(lane_ids)
        for lane_id in ids:
            lane = self._lanes.get(lane_id)
            if lane is not None and lane.result is None:
                raise ValueError(f"lane {lane_id!r} is not retired")
        for lane_id in ids:
            self._lanes.pop(lane_id, None)
            self.results.pop(lane_id, None)
        self._order = [i for i in self._order if i not in ids]

    # ---------------------------------------------------------- lane intake

    def add(self, lane_id, train_mask, C, alpha0=None, f0=None, *,
            source=None, n_iter0: int = 0, max_iter: int = 10_000_000,
            dep=None, seed_fn=None, after=None, shrink0=None,
            tenant=None) -> None:
        """Register a lane. Either give its start point (``alpha0``/``f0``,
        optionally ``n_iter0`` when resuming a snapshot) or a dependency
        (``dep`` = another lane id, ``seed_fn`` mapping that lane's
        ``SMOResult`` to this lane's (alpha0, f0)) — the lane is then
        admitted when the dependency retires. ``after`` adds a pure
        ordering edge: the lane (even an explicitly-started one) is held
        until that lane retires — sequential protocols (the paper's fold
        chain) express their ordering without faking a seed dependency.

        ``shrink0`` restores a snapshotted shrink ledger:
        ``(active_mask_or_None, no_shrink, unshrinks)`` — a restored lane
        re-enters its compact bucket (or its endgame flags) instead of
        re-running the admission handoff, which is what makes a mid-shrink
        resume replay the uninterrupted trajectory bit-exactly."""
        if lane_id in self._lanes:
            raise ValueError(f"duplicate lane id {lane_id!r}")
        if (dep is None) == (alpha0 is None):
            raise ValueError("give exactly one of alpha0/f0 or dep/seed_fn")
        if (alpha0 is None) != (f0 is None):
            raise ValueError("alpha0 and f0 must be given together "
                             "(f0 = init_f(K, y, alpha0))")
        if dep is not None and seed_fn is None:
            raise ValueError("a dependent lane needs a seed_fn")
        key = self._source_key(source)
        lane = _Lane(id=lane_id, source=key, train_mask=train_mask, C=C,
                     max_iter=int(max_iter), dep=dep, seed_fn=seed_fn,
                     after=after, shrink0=shrink0, tenant=tenant)
        if alpha0 is not None:
            if after is None:
                # cache.meta answers dtype without materializing a factory
                # source — intake must not force kernels into residency
                lane.state = init_state(self.cache.meta(key), self._ys[key],
                                        train_mask, alpha0, f0,
                                        n_iter0=n_iter0)
                self._attach_shrink(lane)
            else:   # held: built at admission, when ``after`` retires
                lane.alpha0, lane.f0, lane.n_iter0 = alpha0, f0, int(n_iter0)
        self._lanes[lane_id] = lane
        self._order.append(lane_id)
        if lane.state is not None:
            self._trace("admit", lane_id, key)

    def _attach_shrink(self, lane: _Lane) -> None:
        """Build a lane's shrink ledger the moment its state exists (the
        handoff, like intake, never materializes a kernel). A restored
        ledger (``shrink0``) takes precedence; otherwise the seeding ->
        shrinking handoff evaluates the heuristic on the seeded (alpha0,
        f0) so bound-locked seeded alphas start shrunk."""
        if not self.shrink_every:
            return
        y = self._ys[lane.source]
        ls = shrink_mod.LaneShrink(int(np.shape(y)[0]),
                                   every=self.shrink_every,
                                   quantum=self.shrink_quantum,
                                   caps=self.shrink_caps)
        lane.shrink = ls
        if lane.shrink0 is not None:
            active, no_shrink, unshrinks = lane.shrink0
            ls.no_shrink = bool(no_shrink)
            ls.unshrinks = int(unshrinks)
            lane.shrink0 = None
            if active is not None:
                active = jnp.asarray(active, bool) & \
                    jnp.asarray(lane.train_mask, bool)
                ls.mark(active, int(jnp.sum(active)))
            return
        if self.shrink_on_seed:
            shrink_mod.seed_shrink(ls, y, lane.train_mask, lane.C,
                                   lane.state, tol=self.tol)

    def add_result(self, lane_id, result: SMOResult, *,
                   tenant=None) -> None:
        """Register an already-solved lane (a restored ``done`` snapshot):
        it participates as a seed dependency but is never dispatched."""
        if lane_id in self._lanes:
            raise ValueError(f"duplicate lane id {lane_id!r}")
        lane = _Lane(id=lane_id, source=None, train_mask=None, C=None,
                     max_iter=0, result=result, tenant=tenant)
        self._lanes[lane_id] = lane
        self._order.append(lane_id)
        self.results[lane_id] = result
        self._trace("given", lane_id)

    def lane_times(self, lane_id) -> tuple[float, float]:
        """(seed_s, solve_s) wall time attributed to one lane: its admission
        transform, and its share of every chunk it was dispatched in."""
        lane = self._lanes[lane_id]
        return lane.seed_s, lane.solve_s

    # ------------------------------------------------------------ scheduling

    def _admit(self) -> None:
        """Admit every pending lane whose edges have retired: run its seed
        transform (timed as init/seed work) and build its state."""
        for lane_id in self._order:
            lane = self._lanes[lane_id]
            if lane.state is not None or lane.result is not None:
                continue
            if lane.after is not None and lane.after not in self.results:
                continue
            meta, y = self.cache.meta(lane.source), self._ys[lane.source]
            if lane.dep is None:          # explicit start held by ``after``
                lane.state = init_state(meta, y, lane.train_mask, lane.alpha0,
                                        lane.f0, n_iter0=lane.n_iter0)
                lane.alpha0 = lane.f0 = None
                self._attach_shrink(lane)
                self._trace("admit", lane_id, lane.source)
                continue
            if lane.dep not in self.results:
                continue
            # a seed transform may materialize its kernel through the cache
            # (lazy K resolution, core/study.py); that wall time is KERNEL
            # time, not seed time — subtract the cache's delta so the
            # paper's "init." column stays a seeding measurement
            t0 = time.perf_counter()
            k0 = self.cache.kernel_time
            alpha0, f0 = lane.seed_fn(self.results[lane.dep])
            jax.block_until_ready((alpha0, f0))
            dt = (time.perf_counter() - t0) - (self.cache.kernel_time - k0)
            lane.seed_s += dt
            self.seed_time += dt
            lane.state = init_state(meta, y, lane.train_mask, alpha0, f0)
            self._attach_shrink(lane)
            self._trace("admit", lane_id, lane.source)

    def _live(self) -> list[_Lane]:
        return [self._lanes[i] for i in self._order
                if self._lanes[i].state is not None
                and self._lanes[i].result is None]

    def _retire(self, lane: _Lane) -> None:
        lane.result = finalize(lane.state, self._ys[lane.source],
                               lane.train_mask, lane.C, self.tol)
        self.results[lane.id] = lane.result
        if self.on_trace is not None:     # int() syncs — only when tracing
            self._trace("retire", lane.id, int(lane.result.n_iter))
        if self.on_result is not None:
            self.on_result(lane.id, lane.result)

    def _pack(self, key, live: list[_Lane]) -> None:
        """Gather a source group's live lanes into a compact batch of
        bucketed width; pad positions replicate lane 0 with ``done`` set
        (inert: the engine body passes done lanes through untouched, and
        the while_loop's ``any(~done)`` ignores them)."""
        width = bucket_width(len(live), self.lane_quantum)
        states = [ln.state for ln in live]
        masks = [ln.train_mask for ln in live]
        Cs = [ln.C for ln in live]
        caps = [ln.max_iter for ln in live]
        for _ in range(width - len(live)):
            pad = live[0].state
            states.append(pad._replace(done=jnp.ones((), bool)))
            masks.append(live[0].train_mask)
            Cs.append(live[0].C)
            caps.append(0)
        payload = (jnp.stack(masks),
                   jnp.asarray(Cs, self.cache.meta(key).dtype),
                   jnp.asarray(caps, jnp.int64),
                   EngineState.stack(states))
        self._packed[key] = (tuple(ln.id for ln in live), payload)
        self._trace("pack", key, tuple(ln.id for ln in live))

    def _writeback(self, key) -> None:
        """Write a source's packed states back into its lanes and drop the
        packed cache — required before the group's membership changes
        (retire, park rotation, admission), a member dispatches solo, or
        the source's kernel is evicted from residency."""
        ids, payload = self._packed.pop(key)
        states = payload[3]
        for i, lane_id in enumerate(ids):
            self._lanes[lane_id].state = states.lane(i)

    def _cap_order(self, selected: list[_Lane]) -> list[_Lane]:
        """Width-capped dispatch priority within one fair-share group.
        Selection is SOURCE-STICKY: the most recently dispatched source
        keeps the width budget while it has live lanes — its kernel
        operands stay cache-hot, where a per-chunk rotation across
        sources was measured ~5% slower on CPU (each chunk restreamed a
        cold ~n^2 kernel matrix). Within the sticky source (and for any
        leftover width), least-served lanes go first (stable sort:
        insertion order breaks ties), so every lane of the serving source
        keeps advancing at chunk granularity; other sources advance when
        the sticky one drains or leaves width to spare. Leftover width is
        RESIDENCY-AWARE: lanes whose kernel is already materialized beat
        lanes that would force a materialization (and, under a budget, an
        eviction) — a budgeted pool drains each resident source before
        paying for the next kernel, so materialization count tracks the
        source count, not the chunk count. Dense (pinned) sources are
        always resident, so single-matrix pools keep the exact pre-cache
        ordering. Defers to the pure :func:`order_capped` the simulator
        replays."""
        return order_capped(selected, sticky=self._sticky,
                            resident=self.cache.resident,
                            served=lambda ln: ln.served,
                            source=lambda ln: ln.source)

    def _cap_select(self, selected: list[_Lane]) -> list[_Lane]:
        """Park the overflow for one chunk. Single-tenant pools (every
        lane untagged, or one tag — all pre-daemon callers) take the
        historical path verbatim. Multi-tenant pools FAIR-SHARE the width
        budget: each tenant's lanes are ordered by the same sticky/
        resident/served policy, then tenants are interleaved round-robin
        — least-served tenant first — so one tenant's wide grid cannot
        starve another's two folds, while each tenant's own lanes still
        drain source-sticky. Defers to the pure :func:`select_capped` the
        simulator replays."""
        return select_capped(selected, max_width=self.max_width,
                             sticky=self._sticky,
                             resident=self.cache.resident,
                             served=lambda ln: ln.served,
                             source=lambda ln: ln.source,
                             tenant=lambda ln: ln.tenant,
                             tenant_served=self._tenant_served)

    def run(self) -> dict[Any, SMOResult]:
        """Drive every lane to retirement; returns {lane_id: SMOResult}."""
        while self.step():
            pass
        pending = [i for i in self._order
                   if self._lanes[i].result is None]
        if pending:
            raise RuntimeError(
                f"lanes {pending} wait on dependencies that never "
                "retire (missing or cyclic dep)")
        return dict(self.results)

    def step(self) -> bool:
        """One scheduling round: admit ready lanes, select under the
        budget/width policy, dispatch one chunk per (source, width)
        group. Returns False when nothing is runnable — every lane
        retired, or the rest wait on edges that have not retired (the
        daemon's idle condition; ``run`` turns pending-forever into the
        missing/cyclic-dep error)."""
        self._admit()
        live = self._live()
        if not live:
            return False
        selected = live
        if len(self.sources) > 1 and self.cache.budgeted:
            # residency budget first: only budget-many managed sources
            # dispatch per chunk (sticky/resident preferred), so even
            # an unbounded-width schedule drains kernels instead of
            # thrashing the cache
            allowed = self._budget_sources(live)
            if len(allowed) < len({ln.source for ln in live}):
                selected = [ln for ln in live if ln.source in allowed]
        if self.max_width and len(selected) > self.max_width:
            selected = self._cap_select(selected)
        for lane in selected:
            lane.served += 1
            self._tenant_served[lane.tenant] = \
                self._tenant_served.get(lane.tenant, 0) + 1
        groups: dict[Any, list[_Lane]] = {}
        for lane in selected:
            # under shrinking, lanes bucket by (source, cap): a shrunk
            # lane migrates to the smaller-shape compact program of its
            # cap bucket, and only same-cap lanes can share a stacked
            # dispatch (their operand shapes match)
            gkey = (lane.source, lane.shrink.cap) if self.shrink_every \
                else lane.source
            groups.setdefault(gkey, []).append(lane)
        if len(self.sources) > 1:
            counts: dict[Any, int] = {}
            for lane in live:
                counts[lane.source] = counts.get(lane.source, 0) + 1
            for key, c in counts.items():
                rec = self._src_live.setdefault(key, [0, 0, 0])
                rec[0] += c
                rec[1] += 1
                rec[2] = max(rec[2], c)
        # affinity follows the chunk's PRIMARY group (selected[0]'s
        # source) — not the last group dispatched, which under a split
        # selection would hand stickiness to the overflow source
        self._sticky = selected[0].source
        chunk = self.chunk_count
        dispatched = 0
        for gkey, lanes in groups.items():
            width = (1 if len(lanes) == 1
                     else bucket_width(len(lanes), self.lane_quantum))
            dispatched += width
            if self.shrink_every:
                key, cap = gkey
                n = int(np.shape(self._ys[key])[0])
                self._programs.add((key, width, cap or n))
                for lane in lanes:
                    self._frac_log.append((cap or n) / n)
            else:
                key, cap = gkey, 0
                self._programs.add((key, width))
            self._trace("dispatch", chunk, key, cap, width,
                        tuple(ln.id for ln in lanes))
            # dispatch may materialize the group's kernel through the
            # cache; that delta is kernel time, not solve time
            t0 = time.perf_counter()
            k0 = self.cache.kernel_time
            if self.shrink_every:
                self._step_shrink(key, cap, lanes)
            elif len(lanes) == 1:
                self._step_single(lanes[0])
            else:
                self._step_batched(key, lanes)
            dt = (time.perf_counter() - t0) \
                - (self.cache.kernel_time - k0)
            for lane in lanes:
                lane.solve_s += dt / len(lanes)
        self._width_log.append((len(live), dispatched))
        if self.on_trace is not None:
            if any(ln.tenant is not None for ln in selected):
                shares: dict[Any, int] = {}
                for lane in selected:
                    shares[lane.tenant] = shares.get(lane.tenant, 0) + 1
                self._trace("shares", chunk, tuple(sorted(
                    (repr(t), c) for t, c in shares.items())))
            self._trace("resident", chunk,
                        self.cache.pinned_bytes + self.cache.resident_bytes)
        self.chunk_count += 1
        if self.on_lane_chunk is not None:
            for lane in selected:
                if lane.result is None:
                    self.on_lane_chunk(lane.id, self._lane_state(lane))
        if self.on_snapshot is not None and \
                self.chunk_count % self.snapshot_every == 0:
            if self.on_trace is not None:
                ids = [i for i in self._order
                       if self._lanes[i].state is not None
                       or self._lanes[i].result is not None]
                first = self._lanes[ids[0]]
                ref = (first.result.alpha if first.result is not None
                       else first.state.alpha)
                self._trace("checkpoint", chunk, tuple(ids),
                            snapshot_nbytes(int(ref.shape[0]),
                                            ref.dtype.itemsize, len(ids),
                                            bool(self.shrink_every)))
            self.on_snapshot(self)
        return True

    def _step_single(self, lane: _Lane) -> None:
        """Dispatch width 1: the sequential single-lane program
        (bit-identical to ``engine.solve``'s chunks) — no vmap overhead on
        a straggler or a width-capped round-robin schedule."""
        cached = self._packed.get(lane.source)
        if cached is not None and lane.id in cached[0]:
            self._writeback(lane.source)
        src, y = self.resolve_source(lane.source), self._ys[lane.source]
        lane.state = chunk_jit(src, y, lane.train_mask, lane.C,
                               self.tol, jnp.asarray(lane.max_iter, jnp.int64),
                               lane.state, n_iters=self.chunk_iters,
                               wss=self.wss)
        if bool(lane.state.done):
            self._retire(lane)

    def _step_batched(self, key, lanes: list[_Lane]) -> None:
        """One chunk over one source's selected lanes. A membership change
        (vs the cached pack) first evicts the cache — packed states flow
        back into the lanes — so repacking always starts from the freshest
        state."""
        ids = tuple(ln.id for ln in lanes)
        cached = self._packed.get(key)
        if cached is None or cached[0] != ids:
            if cached is not None:
                self._writeback(key)
            self._pack(key, lanes)
        # resolve BEFORE reading the pack: materializing this source may
        # evict another source (flushing ITS pack), never this group's
        src = self.resolve_source(key)
        masks, Cs, caps, states = self._packed[key][1]
        states = chunk_batched_jit(src, self._ys[key], masks,
                                   Cs, self.tol, caps, states,
                                   n_iters=self.chunk_iters, wss=self.wss)
        self._packed[key] = (ids, (masks, Cs, caps, states))
        done = np.asarray(states.done[:len(lanes)])   # one (w,) transfer
        if done.any():
            self._writeback(key)
            for flag, lane in zip(done, lanes):
                if flag:
                    self._retire(lane)

    def _step_shrink(self, key, cap: int, lanes: list[_Lane]) -> None:
        """One chunk over a shrink-enabled ``(source, cap)`` group, then
        the per-lane shrink lifecycle. ``cap == 0`` lanes run the normal
        full-set programs with their iteration cap pinned to the next
        heuristic boundary; shrunk lanes run the SAME chunk programs over
        their gathered compact operands at the relaxed ``10*tol`` (lanes
        of one bucket each carry their own gathered rows, so width > 1
        dispatches through ``chunk_batched_sources_jit``). States are
        packed fresh and written back every chunk — shrunk groups change
        membership as lanes migrate between cap buckets, so a packed-batch
        cache would thrash; the full-state mirror (``lane.state``) is kept
        fresh by ``shrink.advance``'s scatter, which is what snapshots and
        ``on_lane_chunk`` observe."""
        src, y = self.resolve_source(key), self._ys[key]
        for lane in lanes:
            if lane.shrink.cap and lane.shrink.idx is None:
                lane.shrink.enter(src, y, lane.state)
        if cap == 0:
            it_caps = [ln.shrink.it_cap(int(ln.state.n_iter), ln.max_iter)
                       for ln in lanes]
            if len(lanes) == 1:
                ln = lanes[0]
                ln.state = chunk_jit(src, y, ln.train_mask, ln.C, self.tol,
                                     jnp.asarray(it_caps[0], jnp.int64),
                                     ln.state, n_iters=self.chunk_iters,
                                     wss=self.wss)
            else:
                width = bucket_width(len(lanes), self.lane_quantum)
                states = [ln.state for ln in lanes]
                masks = [ln.train_mask for ln in lanes]
                Cs = [ln.C for ln in lanes]
                for _ in range(width - len(lanes)):
                    states.append(lanes[0].state._replace(
                        done=jnp.ones((), bool)))
                    masks.append(lanes[0].train_mask)
                    Cs.append(lanes[0].C)
                    it_caps.append(0)
                out = chunk_batched_jit(
                    src, y, jnp.stack(masks), jnp.asarray(Cs, src.dtype),
                    self.tol, jnp.asarray(it_caps, jnp.int64),
                    EngineState.stack(states), n_iters=self.chunk_iters,
                    wss=self.wss)
                for i, ln in enumerate(lanes):
                    ln.state = out.lane(i)
        else:
            stol = 10.0 * self.tol
            it_caps = [ln.shrink.it_cap(int(ln.shrink.cstate.n_iter),
                                        ln.max_iter) for ln in lanes]
            if len(lanes) == 1:
                ls = lanes[0].shrink
                ls.cstate = chunk_jit(ls.csrc, ls.cy, ls.cmask, lanes[0].C,
                                      stol, jnp.asarray(it_caps[0], jnp.int64),
                                      ls.cstate, n_iters=self.chunk_iters,
                                      wss=self.wss)
            else:
                width = bucket_width(len(lanes), self.lane_quantum)
                srcs = [ln.shrink.csrc for ln in lanes]
                cys = [ln.shrink.cy for ln in lanes]
                cmasks = [ln.shrink.cmask for ln in lanes]
                cstates = [ln.shrink.cstate for ln in lanes]
                Cs = [ln.C for ln in lanes]
                for _ in range(width - len(lanes)):
                    pad = lanes[0].shrink
                    srcs.append(pad.csrc)
                    cys.append(pad.cy)
                    cmasks.append(pad.cmask)
                    cstates.append(pad.cstate._replace(
                        done=jnp.ones((), bool)))
                    Cs.append(lanes[0].C)
                    it_caps.append(0)
                out = chunk_batched_sources_jit(
                    stack_sources(srcs), jnp.stack(cys), jnp.stack(cmasks),
                    jnp.asarray(Cs, src.dtype), stol,
                    jnp.asarray(it_caps, jnp.int64),
                    EngineState.stack(cstates), n_iters=self.chunk_iters,
                    wss=self.wss)
                for i, ln in enumerate(lanes):
                    ln.shrink.cstate = out.lane(i)
        for ln in lanes:
            ln.state, verdict = shrink_mod.advance(
                ln.shrink, src, y, ln.train_mask, ln.C, ln.state,
                tol=self.tol, max_iter=ln.max_iter)
            if verdict == "retire":
                self._retire(ln)

    # ---------------------------------------------------------- observability

    def _lane_state(self, lane: _Lane) -> EngineState:
        """Current state of a live lane, reading through the packed cache."""
        cached = self._packed.get(lane.source)
        if cached is not None and lane.id in cached[0]:
            return cached[1][3].lane(cached[0].index(lane.id))
        return lane.state

    def tenant_stats(self) -> dict:
        """Per-tenant accounting: lane counts by lifecycle stage plus the
        fair-share ``served`` counter (lane-chunks dispatched). The
        daemon's ``status`` answer and the fairness tests read this."""
        stats: dict[Any, dict] = {}

        def rec(t):
            return stats.setdefault(
                t, {"lanes": 0, "live": 0, "pending": 0, "retired": 0,
                    "served": 0})

        for lane in self._lanes.values():
            r = rec(lane.tenant)
            r["lanes"] += 1
            if lane.result is not None:
                r["retired"] += 1
            elif lane.state is not None:
                r["live"] += 1
            else:
                r["pending"] += 1
        for t, n in self._tenant_served.items():
            rec(t)["served"] = n
        return stats

    def snapshot_lanes(self, *, only=None):
        """(lane_ids, tree) of every admitted-or-retired lane, stacked in
        lane-id (insertion) order — NOT packed position — so a mid-batch
        checkpoint restores by original lane id across any repack/resume
        boundary. ``tree`` = {alpha (L, n), f (L, n), n_iter (L,),
        done (L,)}; pending (unadmitted) lanes are omitted — their seeds
        re-derive from the retired results in the snapshot. ``only``
        restricts the snapshot to a membership test over lane ids (the
        daemon checkpoints each study's lanes separately: one tenant's
        instance set need not be shape-homogeneous with another's).

        Shrink-enabled pools additionally persist the per-lane shrink
        ledger — ``active`` (L, n) masks, ``shrunk``/``no_shrink`` (L,)
        flags and the ``unshrinks`` (L,) counter — so a mid-shrink resume
        re-enters the exact compact bucket (under ANY schedule shape or
        cap quantum) instead of re-deriving decisions; a live shrunk
        lane's mirror has fresh alpha everywhere and fresh f on active
        rows, which is exactly what re-gathering needs. ``shrink_every=0``
        pools emit the historical four-key tree byte-identically."""
        ids, alphas, fs, iters, dones = [], [], [], [], []
        actives, shrunks, noshrinks, unshrinks = [], [], [], []
        for lane_id in self._order:
            if only is not None and lane_id not in only:
                continue
            lane = self._lanes[lane_id]
            if lane.result is not None:
                src, done = lane.result, True
            elif lane.state is not None:
                src, done = self._lane_state(lane), False
            else:
                continue
            ids.append(lane_id)
            alphas.append(src.alpha)
            fs.append(src.f)
            iters.append(src.n_iter)
            dones.append(done)
            if self.shrink_every:
                ls = lane.shrink if lane.result is None else None
                if ls is not None and ls.shrunk:
                    actives.append(ls.active)
                else:
                    actives.append(jnp.ones(src.alpha.shape[0], bool))
                shrunks.append(bool(ls is not None and ls.shrunk))
                noshrinks.append(bool(ls is not None and ls.no_shrink))
                unshrinks.append(0 if ls is None else int(ls.unshrinks))
        if not ids:       # nothing admitted yet (daemon pre-first-chunk)
            return [], {}
        tree = {"alpha": jnp.stack(alphas), "f": jnp.stack(fs),
                "n_iter": jnp.stack(iters), "done": jnp.asarray(dones)}
        if self.shrink_every:
            tree["active"] = jnp.stack(actives)
            tree["shrunk"] = jnp.asarray(shrunks)
            tree["no_shrink"] = jnp.asarray(noshrinks)
            tree["unshrinks"] = jnp.asarray(unshrinks, jnp.int32)
        return ids, tree

    @property
    def occupancy(self) -> dict:
        """Schedule shape over the run. ``mean_live_width`` counts
        *runnable* lanes per chunk (the demand); ``mean_packed_width`` /
        ``peak_width`` count the *dispatched* program width summed over the
        chunk's source groups (after width capping and pad bucketing).
        live >> packed is the width-capped round-robin regime (CPU);
        live == packed == peak means retirement never compacted the batch
        (lanes converged together). Multi-source pools additionally report
        ``per_source`` live-width stats — the per-gamma demand profile that
        makes a straggler row visible in artifact diffs."""
        if not self._width_log:
            return {"chunks": 0, "mean_live_width": 0.0,
                    "mean_packed_width": 0.0, "peak_width": 0,
                    "programs": 0}
        lives = [w for w, _ in self._width_log]
        packed = [p for _, p in self._width_log]
        occ = {"chunks": len(self._width_log),
               "mean_live_width": round(sum(lives) / len(lives), 3),
               "mean_packed_width": round(sum(packed) / len(packed), 3),
               "peak_width": max(packed),
               "programs": len(self._programs)}
        if self.shrink_every:
            # HBM-roofline hook: a lane-dispatch at cap streams cap/n of
            # the full operand bytes, so this mean scales ``hbm_per_iter``
            # (benchmarks/table1_kfold.py reads it)
            occ["shrink_lane_chunks"] = len(self._frac_log)
            occ["mean_active_frac"] = round(
                sum(self._frac_log) / max(len(self._frac_log), 1), 4)
        if len(self.sources) > 1:
            occ["per_source"] = {
                str(key): {"chunks": n,
                           "mean_live_width": round(s / max(n, 1), 3),
                           "peak_live_width": peak}
                for key, (s, n, peak) in self._src_live.items()}
        return occ


class LaneScheduler(LanePool):
    """Single-source facade over ``LanePool`` — the historical interface
    (one kernel source, one label vector); lanes omit the source key."""

    _SOLO = "_solo"

    def __init__(self, source, y, **kwargs):
        super().__init__({self._SOLO: source}, y, **kwargs)

    @property
    def source(self):
        return self.resolve_source(self._SOLO)

    @property
    def y(self):
        return self._ys[self._SOLO]
