"""SVM substrate: SMO solver, kernel functions, classifier API.

The SVM stack runs in float64 (LibSVM parity — the paper's "identical
results" claim depends on a well-converged dual). We enable x64 here;
the LM model zoo is dtype-explicit everywhere, so it is unaffected.
"""
import jax

jax.config.update("jax_enable_x64", True)

from repro.svm.kernels import rbf_kernel, linear_kernel, kernel_matrix  # noqa: E402,F401
from repro.svm.engine import (  # noqa: E402,F401
    DenseKernel, EngineState, FusedRBF, OnDemandRBF, PallasRBF, ShardedRBF)
from repro.svm.sources import KernelSpec, SourceCache  # noqa: E402,F401
from repro.svm.shrink import (  # noqa: E402,F401
    LaneShrink, bucket_cap, possible_caps, seed_active_mask, solve_shrunk)
from repro.svm.scheduler import LanePool, LaneScheduler  # noqa: E402,F401
from repro.svm.smo import (  # noqa: E402,F401
    SMOResult, smo_solve, smo_solve_batched, init_f, dual_objective)
from repro.svm.svc import (  # noqa: E402,F401
    SVC, decision_function, predict, accuracy, bias_from_solution)
