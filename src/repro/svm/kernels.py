"""Kernel functions.

``kernel_matrix`` is the compute hot-spot of the whole paper pipeline —
LibSVM's time is dominated by kernel-row evaluation. On TPU the Pallas
kernel in ``repro.kernels.rbf`` computes the same tiled quantity on the
MXU; this module is the pure-jnp reference path (and the CPU path).
"""
from __future__ import annotations

import jax.numpy as jnp


def rbf_kernel(X: jnp.ndarray, Z: jnp.ndarray, gamma: float) -> jnp.ndarray:
    """K[i,j] = exp(-gamma * ||x_i - z_j||^2), shapes (n,d),(m,d) -> (n,m)."""
    xn = jnp.sum(X * X, axis=-1)[:, None]
    zn = jnp.sum(Z * Z, axis=-1)[None, :]
    d2 = jnp.maximum(xn + zn - 2.0 * (X @ Z.T), 0.0)
    return jnp.exp(-gamma * d2)


def linear_kernel(X: jnp.ndarray, Z: jnp.ndarray, gamma: float = 0.0) -> jnp.ndarray:
    del gamma
    return X @ Z.T


_KERNELS = {"rbf": rbf_kernel, "linear": linear_kernel}


def kernel_matrix(X: jnp.ndarray, Z: jnp.ndarray, *, kind: str = "rbf",
                  gamma: float = 1.0, backend: str = "jnp") -> jnp.ndarray:
    """Full kernel matrix. ``backend='pallas'`` uses the TPU Pallas tile
    kernel (validated in interpret mode on CPU)."""
    if backend == "pallas" and kind == "rbf":
        from repro.kernels.ops import rbf_kernel_matrix  # lazy: optional path
        return rbf_kernel_matrix(X, Z, gamma)
    return _KERNELS[kind](X, Z, gamma)
