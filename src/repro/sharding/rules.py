"""Logical-axis sharding rules (MaxText-style).

Model code annotates every parameter / activation dimension with a LOGICAL
axis name ("embed", "heads", "experts", "batch", ...). A rules table maps
logical names to physical mesh axes. Changing the parallelism layout (the
main §Perf hillclimb lever) means changing ONE table — model code never
hard-codes mesh axes.

Physical mesh axes (launch/mesh.py):
  single-pod: ("data", "model")          = (16, 16)
  multi-pod:  ("pod", "data", "model")   = (2, 16, 16)

Default layout = 2D sharding: FSDP over ("pod","data") for the non-TP
dimension of every weight, tensor/expert parallelism over "model".
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> mesh axis (str), tuple of mesh axes, or None (replicated)
LogicalAxisRules = dict

DEFAULT_RULES: LogicalAxisRules = {
    # activations
    "batch": ("pod", "data"),    # data parallel over pod x data
    "seq": None,                 # sequence replicated by default (SP opt-in)
    "seq_model": "model",        # sequence-sharded decode KV (flash-decode)
    "embed_act": None,
    "heads_act": "model",
    "vocab_act": "model",
    "exp_act": "model",
    # parameters: TP dim -> "model", FSDP dim -> ("pod","data")
    "embed": ("pod", "data"),    # FSDP axis of most weights
    "embed_tp": "model",         # rows of attn-out / mlp-out (TP dim)
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "vocab": "model",
    "experts": "model",
    "exp_mlp": None,
    "kv_lora": None,
    "q_lora": None,
    "conv": None,
    "state": None,
    "stack": None,               # scanned-layer leading axis: never sharded
    None: None,
}


def logical_to_pspec(axes: tuple, rules: LogicalAxisRules,
                     mesh: Mesh | None = None, shape: tuple | None = None) -> P:
    """Map a tuple of logical axis names to a PartitionSpec.

    Robustness rules (so ONE rules table serves every arch and both meshes):
    * mesh axes absent from ``mesh`` are dropped (pod axis on single-pod);
    * a mesh axis may shard at most one dim — first occurrence wins;
    * with ``shape`` given, mesh axes are applied greedily only while their
      product divides the dim (4 kv-heads never shard over a 16-way axis;
      batch=1 decode stays replicated).
    """
    have = set(mesh.axis_names) if mesh is not None else None
    sizes = dict(mesh.shape) if mesh is not None else {}
    out = []
    used: set[str] = set()
    for i, ax in enumerate(axes):
        phys = rules.get(ax, None)
        if phys is None:
            out.append(None)
            continue
        if isinstance(phys, str):
            phys = (phys,)
        phys = tuple(p for p in phys
                     if (have is None or p in have) and p not in used)
        if shape is not None and sizes:
            picked, prod = [], 1
            for p in phys:
                if shape[i] % (prod * sizes.get(p, 1)) == 0:
                    picked.append(p)
                    prod *= sizes.get(p, 1)
            phys = tuple(picked)
        used.update(phys)
        out.append(phys if len(phys) > 1 else (phys[0] if phys else None))
    return P(*out)


def spec_tree_to_pspecs(spec_tree, rules: LogicalAxisRules, mesh=None):
    """Map a pytree of logical-axes tuples to a pytree of PartitionSpecs."""
    return jax.tree.map(lambda axes: logical_to_pspec(axes, rules, mesh),
                        spec_tree, is_leaf=lambda x: isinstance(x, tuple))


def named_sharding(mesh: Mesh, axes: tuple, rules: LogicalAxisRules):
    return NamedSharding(mesh, logical_to_pspec(axes, rules, mesh))


def current_abstract_mesh():
    """The ambient abstract mesh, or ``None``.

    ``jax.sharding.get_abstract_mesh`` / ``set_mesh`` only exist on newer jax;
    on older versions there is no ambient-mesh scope, so constraints degrade
    to no-ops (the caller's code still runs, unsharded)."""
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is None:
        return None
    mesh = get()
    if mesh is None or mesh.empty:
        return None
    return mesh


def constrain(x, axes: tuple, rules: LogicalAxisRules | None = None):
    """with_sharding_constraint by logical axes. No-op outside a mesh scope
    (``jax.sharding.set_mesh``) and on jax versions without ambient-mesh
    support, so the same model code runs in single-device smoke tests and in
    the 512-device dry-run unchanged."""
    if rules is None:
        return x
    mesh = current_abstract_mesh()
    if mesh is None:
        return x
    spec = logical_to_pspec(axes, rules, mesh, shape=x.shape)
    return jax.lax.with_sharding_constraint(x, spec)
