from repro.sharding.rules import (  # noqa: F401
    LogicalAxisRules, DEFAULT_RULES, logical_to_pspec, spec_tree_to_pspecs,
    constrain, current_abstract_mesh, named_sharding,
)
