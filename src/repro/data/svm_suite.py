"""Synthetic stand-ins for the paper's five LibSVM datasets.

The container is offline, so Adult/Heart/Madelon/MNIST/Webdata cannot be
downloaded. We generate binary tasks with the paper's dimensionalities and
hyper-parameters (Table 2); the three large sets are cardinality-scaled to a
CPU budget (paper claims are about iteration counts / identical fixed points,
which are scale-invariant — see DESIGN.md §Synthetic datasets).

Generator: two anisotropic Gaussian clusters over ``n_informative`` dims,
remaining dims pure noise (Madelon-style), plus label noise ``flip``.
Deterministic per (name, seed) so any worker can regenerate any shard
(straggler/fault-tolerance property — no data state to lose).
"""
from __future__ import annotations

import dataclasses
import zlib

import numpy as np


@dataclasses.dataclass(frozen=True)
class SVMDataset:
    name: str
    X: np.ndarray          # (n, d) float64
    y: np.ndarray          # (n,) {-1, +1}
    C: float
    gamma: float

    @property
    def n(self):
        return self.X.shape[0]


# name -> (cardinality, dim, C, gamma, n_informative, separation, flip,
#          balanced)
# C/gamma are the paper's Table 2 values. ``cardinality`` for the three large
# sets is scaled (paper sizes in comments). separation/flip are tuned so each
# synthetic task lands in the same SVM *regime* as its namesake — the paper's
# reported CV accuracies expose those regimes: Madelon 50.0% and MNIST 50.85%
# are chance level (K ~= I, alphas ~ all bounded at C), Heart 55.6% is near
# chance (huge C=2182), Adult 82.4% mixed, Webdata 97.7% near separable.
# ``balanced`` gives an exact 50/50 label split (real Madelon is 1000/1000),
# which determines the equality-multiplier nu and hence the bounded/free SV
# split that alpha seeding is sensitive to.
SPECS = {
    "adult":   (2000, 123, 100.0, 0.5, 40, 1.3, 0.10, False),   # paper: 32,561
    "heart":   (270, 13, 2182.0, 0.2, 10, 0.35, 0.30, False),   # paper size
    "madelon": (2000, 500, 1.0, 0.7071, 0, 0.0, 0.0, True),     # paper size
    "mnist":   (2000, 780, 10.0, 0.125, 60, 0.15, 0.40, True),  # paper: 60,000
    "webdata": (2000, 300, 64.0, 7.8125, 30, 2.2, 0.015, False),  # paper: 49,749
}
DATASETS = tuple(SPECS)


def make_dataset(name: str, *, seed: int = 0, n_override: int | None = None) -> SVMDataset:
    n, d, C, gamma, n_inf, sep, flip, balanced = SPECS[name]
    if n_override is not None:
        n = n_override
    # crc32, NOT hash(): str hashing is salted per process (PYTHONHASHSEED),
    # which silently broke the "any worker can regenerate any shard" property
    # and made cross-process results (tests, benchmarks) non-reproducible
    rng = np.random.default_rng(zlib.crc32(f"{name}:{seed}".encode()))
    if balanced:
        y = np.repeat([1, -1], [n - n // 2, n // 2])
        y = y[rng.permutation(n)]
    else:
        y = np.where(rng.random(n) < 0.5, 1, -1)
    X = rng.normal(size=(n, d))
    if n_inf > 0:
        # class-dependent mean shift on informative dims, anisotropic scale
        centers = rng.normal(size=(2, n_inf)) * sep
        scales = 0.5 + rng.random(n_inf)
        X[:, :n_inf] = X[:, :n_inf] * scales + np.where(y[:, None] > 0,
                                                        centers[0], centers[1])
    # label noise makes the task non-separable (drives bounded SVs, like Adult)
    flip_mask = rng.random(n) < flip
    y = np.where(flip_mask, -y, y)
    # feature scaling to [-1, 1] (LibSVM convention; keeps gamma meaningful)
    X = X / (np.abs(X).max(axis=0, keepdims=True) + 1e-12)
    return SVMDataset(name=name, X=X.astype(np.float64), y=y.astype(np.int64),
                      C=C, gamma=gamma)


def kfold_chunks(n: int, k: int, *, seed: int = 0) -> np.ndarray:
    """Shuffled indices split into k equal chunks, shape (k, n//k).

    Instances beyond k*(n//k) are dropped (static shapes: one compiled solver
    serves all folds). Chunk h is fold h's test set.

    Indices are a permutation of range(k*(n//k)) — the same range callers
    slice their arrays to. (Permuting range(n) and truncating, as this used
    to do, leaves indices >= k*(n//k) in the chunks whenever k does not
    divide n; jax's clamping scatter then silently corrupted that fold's
    train mask. For k | n the draw is unchanged.)
    """
    rng = np.random.default_rng(seed)
    m = n // k
    return rng.permutation(k * m).reshape(k, m)
