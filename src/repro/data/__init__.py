from repro.data.svm_suite import SVMDataset, make_dataset, kfold_chunks, DATASETS  # noqa: F401
from repro.data.tokens import synthetic_token_batch, token_stream  # noqa: F401
