"""Deterministic synthetic LM token pipeline.

Resumability by construction: batch ``step`` is a pure function of
(seed, step), so a restarted (or re-scheduled, or elastically re-sharded)
trainer regenerates the exact stream from the checkpointed step index —
there is no shuffle-buffer state to lose on node failure.
"""
from __future__ import annotations

import numpy as np


def synthetic_token_batch(vocab_size: int, batch: int, seq_len: int,
                          *, seed: int = 0, step: int = 0) -> dict:
    """Returns {tokens, targets, mask}: a Zipf-ish token stream with a simple
    learnable bigram structure (so loss decreases measurably in examples)."""
    rng = np.random.default_rng((seed * 1_000_003 + step) % (2**63))
    # Zipf-distributed unigrams, clipped to vocab
    base = rng.zipf(1.3, size=(batch, seq_len)).astype(np.int64)
    tokens = base % vocab_size
    # inject bigram structure: even positions predict (t*7+3) % V at odd ones
    tokens[:, 1::2] = (tokens[:, 0::2] * 7 + 3) % vocab_size
    targets = np.roll(tokens, -1, axis=1)
    mask = np.ones((batch, seq_len), np.float32)
    mask[:, -1] = 0.0
    return {"tokens": tokens, "targets": targets, "mask": mask}


def token_stream(vocab_size: int, batch: int, seq_len: int, *, seed: int = 0,
                 start_step: int = 0):
    step = start_step
    while True:
        yield step, synthetic_token_batch(vocab_size, batch, seq_len,
                                          seed=seed, step=step)
        step += 1
