"""Client side of the study service: submit a ``Plan``, get a
``StudyResult``-shaped answer back, bit-identical to running it locally.

:class:`StudyClient` hides the wire entirely: ``submit(plan_id, plan)``
serializes with ``plan_to_dict``, streams the daemon's events, and
returns a :class:`ServedStudy` whose ``results``/``evals`` carry real
``SMOResult`` objects and real (correct, total) counts — what
``run_plan`` would have produced, byte for byte. A plan the daemon's
admission gate refuses raises :class:`PlanRejectedByServer` carrying the
structured ``check_plan`` findings; nothing ran.
"""
from __future__ import annotations

import dataclasses
from typing import Any

from repro.core import study as study_mod
from repro.service import protocol


class PlanRejectedByServer(ValueError):
    """The daemon's admission gate refused the plan; ``findings`` is the
    structured ``check_plan`` payload (rule/severity/message dicts) —
    empty for parse/contract rejections, whose story is in ``str(e)``.
    ``analysis`` is the full ``PlanAnalysis.to_json()`` dict (programs,
    budgets, min/max schedule-simulation summaries) when the analyzer
    ran, else None."""

    def __init__(self, message: str, findings: list,
                 analysis: dict | None = None):
        super().__init__(message)
        self.findings = findings
        self.analysis = analysis


@dataclasses.dataclass
class ServedStudy:
    """One completed served study: the same shape of answer ``run_plan``
    gives, minus in-process-only accounting (per-lane wall times live on
    the daemon's side of the socket)."""
    plan_id: str
    results: dict                   # lane id -> SMOResult (bit-exact)
    evals: dict                     # lane id -> (correct, total)
    restored: frozenset             # lanes that entered pre-solved
    dedup_hits: int                 # this study's sources already resident
    sources_admitted: int           # sources this study brought into the pool
    source_stats: dict              # pool-wide kernel-source cache account
    tenant_stats: dict              # this tenant's fair-share account


class StudyClient:
    """One tenant's connection to a running study daemon."""

    def __init__(self, socket_path: str, tenant: str):
        self.tenant = tenant
        self._sock = protocol.connect(socket_path)
        self._rfile = self._sock.makefile("rb")
        self._wfile = self._sock.makefile("wb")
        protocol.send_msg(self._wfile, {"op": "hello", "tenant": tenant})
        reply = self._recv()
        if reply.get("type") != "hello":
            raise RuntimeError(f"bad handshake reply: {reply!r}")
        #: the daemon pool's result-affecting contract (tol, wss, shrink
        #: settings) — build plans against this or be rejected
        self.pool_contract = reply["pool"]

    def _recv(self) -> dict:
        msg = protocol.recv_msg(self._rfile)
        if msg is None:
            raise ConnectionError("study daemon closed the connection")
        return msg

    def submit(self, plan_id: str, plan, *,
               on_result=None) -> ServedStudy:
        """Run ``plan`` on the daemon; blocks until ``done``. Streams each
        lane's retirement to ``on_result(lane_id, SMOResult)`` the moment
        it crosses the wire (long studies consume results as they land)."""
        protocol.send_msg(self._wfile, {
            "op": "submit", "plan_id": plan_id,
            "plan": study_mod.plan_to_dict(plan)})
        results: dict[Any, Any] = {}
        admitted: dict = {}
        while True:
            msg = self._recv()
            kind = msg.get("type")
            if kind == "admitted":
                admitted = msg
            elif kind == "result":
                lane = study_mod._from_wire(msg["lane"])
                res = study_mod.result_from_dict(msg["result"])
                results[lane] = res
                if on_result is not None:
                    on_result(lane, res)
            elif kind == "done":
                return ServedStudy(
                    plan_id=plan_id, results=results,
                    evals={study_mod._from_wire(lane): (c, t)
                           for lane, (c, t) in msg["evals"]},
                    restored=frozenset(study_mod._from_wire(lid)
                                       for lid in msg["restored"]),
                    dedup_hits=msg["study_source_stats"]["dedup_hits"],
                    sources_admitted=msg["study_source_stats"]
                    ["sources_admitted"],
                    source_stats=msg["source_stats"],
                    tenant_stats=msg["tenant_stats"])
            elif kind == "rejected":
                raise PlanRejectedByServer(msg["error"],
                                           msg.get("findings", []),
                                           msg.get("analysis"))
            elif kind == "error":
                raise RuntimeError(f"study {plan_id!r} failed on the "
                                   f"daemon: {msg['error']}")
            else:
                raise RuntimeError(f"unexpected message {msg!r}")

    def status(self) -> dict:
        protocol.send_msg(self._wfile, {"op": "status"})
        return self._recv()

    def shutdown(self) -> None:
        """Ask the daemon to drain (in-flight studies flush snapshots)
        and exit."""
        protocol.send_msg(self._wfile, {"op": "shutdown"})
        msg = self._recv()
        if msg.get("type") != "bye":
            raise RuntimeError(f"unexpected shutdown reply: {msg!r}")

    def close(self) -> None:
        try:
            self._rfile.close()
            self._wfile.close()
        finally:
            self._sock.close()

    def __enter__(self) -> "StudyClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
