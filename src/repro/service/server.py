"""The study daemon: one shared ``LanePool`` serving many tenants.

Two layers, so the scheduling core is testable without sockets:

* :class:`StudyService` — the transport-agnostic daemon core. It owns ONE
  ``LanePool`` + ``SourceCache`` for its whole lifetime and a single
  **service thread** that does ALL jax work: plan parsing, admission,
  lane enrollment, chunk dispatch (``pool.step()``), evaluations,
  snapshots. Callers hand it closures via :meth:`enqueue`; transport
  threads never touch the pool. Per submission the service:

  1. parses the wire plan (``plan_from_dict`` — hostile content dies at
     parse), holds it to the pool's **result-affecting contract** (tol,
     wss, shrink settings must match; schedule-only knobs — chunk size,
     quantum, width, budgets — are normalized to the pool's, which the
     bit-parity invariant makes safe), and runs
     ``repro.analysis.plan_check.check_plan`` VERBATIM — budget
     feasibility against the pool's declared budget (TIME-RESOLVED: the
     static schedule simulator replays the plan under bounding oracles,
     so a plan whose schedule co-holds sources beyond ``cache_bytes`` is
     refused even when each source fits alone), checkpoint-range audit,
     compile-shape enumeration — before any kernel materializes.
     Daemon policy additionally hardens the ``recompile-storm`` warning
     into a rejection: one tenant must not inject an unbounded program
     set into the shared jit cache. Per-plan tenant budgets
     (``plan_chunk_budget`` lane-chunks, ``plan_bytes_budget`` peak
     resident bytes — advertised in the ``hello`` contract) are held
     against the max-bound simulated schedule. Rejections carry the
     full structured analysis on the wire (``PlanRejected.analysis``).
  2. **namespaces** the admitted plan: lane ids become
     ``("tenant/plan_id", original_id)`` and source keys are replaced by
     content-identity keys (below), so many tenants' graphs coexist in
     one pool without collisions and the whole in-process enrollment
     path (``enroll_plan_lanes``) is reused unchanged.
  3. **dedups kernel sources across tenants**: ``sources.source_identity``
     digests (kind, gamma, backend, n, dtype, X bytes, y bytes) — equal
     identity means the same kernel values AND the same labels, so both
     tenants' lanes read one resident kernel. The pool key IS a digest of
     the identity, so it is deterministic across daemon restarts.
     Refcounted per study; a drained study's sources leave the pool when
     the last reference drops.
  4. streams ``result`` events as lanes retire (the pool's ``on_result``
     routed by namespace), snapshots each study's lanes every
     ``snapshot_every`` chunks into a per-(tenant, plan) namespaced
     checkpoint directory (``CheckpointManager.namespaced``), and on
     completion runs the plan's evals, emits ``done``, and removes the
     study's lanes/sources from the pool.

  Fairness is the pool's: lanes are tagged with their tenant and the
  width-capped selection round-robins tenants (``LanePool._cap_select``),
  least-served first.

* :class:`StudyServer` — the AF_UNIX JSON-lines front end
  (``protocol.py``). One handler thread per connection does framing only;
  every reply and event a submission produces is emitted from the service
  thread through the connection's write lock. ``shutdown`` drains
  gracefully: in-flight studies flush a final snapshot and the daemon
  exits — a client resubmitting the same (tenant, plan_id) against a
  restarted daemon resumes bit-identically, under ANY schedule shape
  (test_service.py's kill/restart test changes the width).

A submission that dies mid-flight on a daemon KILLED without drain is
covered by the periodic snapshots: restart + resubmit restores every
retired lane and resumes live ones from their last chunk boundary.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import queue
import socket
import threading
import traceback
from typing import Any

from repro.analysis import plan_check
from repro.service import protocol
from repro.checkpoint import CheckpointManager
from repro.core import study as study_mod
from repro.svm.scheduler import LanePool
from repro.svm.sources import source_identity

#: result-affecting plan fields that must MATCH the pool (a lane's
#: iterate sequence depends on them — serving a mismatched plan would
#: silently return different bits than the client's own run_plan)
CONTRACT_FIELDS = ("tol", "wss", "shrink_every", "shrink_quantum",
                   "shrink_caps", "shrink_on_seed")


@dataclasses.dataclass
class _Study:
    """One admitted submission: the namespaced plan plus routing state."""
    tenant: str
    plan_id: str
    ns: str
    plan: Any                       # namespaced Plan
    specs: dict                     # namespaced {lane_id: LaneSpec}
    emit: Any                       # callable(dict) -> None (wire events)
    lane_ids: set                   # namespaced ids, all lanes
    remaining: set                  # not yet retired
    source_keys: tuple              # distinct pool keys this study refs
    checkpoint: Any                 # StudyCheckpoint | None
    step: int                       # next snapshot step number
    dedup_hits: int
    restored: frozenset = frozenset()


class StudyService:
    """Transport-agnostic daemon core; see the module docstring."""

    def __init__(self, *, tol: float = 1e-3, wss: str = "2",
                 chunk_iters: int = 4096, lane_quantum: int = 4,
                 max_width: int | None = None, max_resident: int = 0,
                 cache_bytes: int = 0, shrink_every: int = 0,
                 shrink_quantum: int = 128, shrink_caps=None,
                 shrink_on_seed: bool = True,
                 checkpoint_root: str | None = None,
                 snapshot_every: int = 1, max_to_keep: int = 3,
                 plan_chunk_budget: int = 0, plan_bytes_budget: int = 0):
        self.pool = LanePool(
            {}, {}, tol=tol, wss=wss, chunk_iters=chunk_iters,
            lane_quantum=lane_quantum, max_width=max_width,
            max_resident=max_resident, cache_bytes=cache_bytes,
            shrink_every=shrink_every, shrink_quantum=shrink_quantum,
            shrink_caps=shrink_caps, shrink_on_seed=shrink_on_seed,
            on_result=self._route_result)
        self.checkpoint_root = checkpoint_root
        self.snapshot_every = max(int(snapshot_every), 1)
        self.max_to_keep = int(max_to_keep)
        #: per-plan admission budgets, 0 = unbounded: held against the
        #: MAX-BOUND simulated schedule at submit time
        self.plan_chunk_budget = int(plan_chunk_budget)
        self.plan_bytes_budget = int(plan_bytes_budget)
        self._studies: dict[str, _Study] = {}
        self._ident_to_key: dict = {}     # source identity -> pool key
        self._key_ident: dict = {}        # pool key -> identity
        self._key_refs: dict = {}         # pool key -> study refcount
        self._cmds: queue.Queue = queue.Queue()
        self._wake = threading.Event()
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------ lifecycle

    def start(self) -> None:
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    def enqueue(self, fn) -> None:
        """Hand a closure to the service thread (the ONLY thread that may
        touch the pool)."""
        self._cmds.put(fn)
        self._wake.set()

    def request_stop(self) -> None:
        self._stop.set()
        self._wake.set()

    def join(self, timeout: float | None = None) -> None:
        if self._thread is not None:
            self._thread.join(timeout)

    def _loop(self) -> None:
        while True:
            while True:
                try:
                    fn = self._cmds.get_nowait()
                except queue.Empty:
                    break
                try:
                    fn()
                except Exception:       # a command must not kill the daemon
                    traceback.print_exc()
            if self._stop.is_set():
                break
            try:
                progressed = self.pool.step()
            except Exception:
                # a dispatch failure poisons the shared pool — fail the
                # in-flight studies on the wire and stop (their periodic
                # snapshots resume them on the next daemon start)
                traceback.print_exc()
                self._fail_active("pool dispatch error:\n"
                                  + traceback.format_exc(limit=3))
                self._stop.set()
                progressed = False
            if progressed:
                self._snapshot_tick()
            self._finish_ready()
            if not progressed and self._cmds.empty():
                self._wake.wait(0.02)
                self._wake.clear()
        # graceful drain: every in-flight study flushes a snapshot so a
        # restarted daemon resumes it bit-identically
        for st in list(self._studies.values()):
            if st.checkpoint is not None:
                self._snapshot(st)
                st.checkpoint.manager.wait()

    # ------------------------------------------------------------ admission

    def pool_contract(self) -> dict:
        """The result-affecting contract + schedule shape, for ``hello``."""
        return {"tol": float(self.pool.tol), "wss": self.pool.wss,
                "shrink_every": self.pool.shrink_every,
                "shrink_quantum": self.pool.shrink_quantum,
                "shrink_caps": list(self.pool.shrink_caps or ()) or None,
                "shrink_on_seed": self.pool.shrink_on_seed,
                "chunk_iters": self.pool.chunk_iters,
                "lane_quantum": self.pool.lane_quantum,
                "max_width": self.pool.max_width,
                "max_resident": self.pool.cache.max_resident,
                "cache_bytes": self.pool.cache.cache_bytes,
                "plan_chunk_budget": self.plan_chunk_budget,
                "plan_bytes_budget": self.plan_bytes_budget}

    def _check_contract(self, plan) -> None:
        if plan.shrink_every == "auto":
            raise ValueError(
                "shrink_every='auto' resolves against the CLIENT's cost "
                "model; a served plan must pin the pool's value "
                f"(shrink_every={self.pool.shrink_every})")
        pool_vals = {"tol": float(self.pool.tol), "wss": self.pool.wss,
                     "shrink_every": self.pool.shrink_every,
                     "shrink_quantum": self.pool.shrink_quantum,
                     "shrink_caps": self.pool.shrink_caps,
                     "shrink_on_seed": self.pool.shrink_on_seed}
        plan_vals = {"tol": float(plan.tol), "wss": plan.wss,
                     "shrink_every": int(plan.shrink_every),
                     "shrink_quantum": int(plan.shrink_quantum),
                     "shrink_caps": tuple(int(c) for c in plan.shrink_caps)
                     if plan.shrink_caps else None,
                     "shrink_on_seed": bool(plan.shrink_on_seed)}
        if not pool_vals["shrink_every"] and not plan_vals["shrink_every"]:
            # shrink sub-knobs are inert when shrinking is off on both
            for k in ("shrink_quantum", "shrink_caps", "shrink_on_seed"):
                plan_vals[k] = pool_vals[k]
        bad = [f"{k}: plan {plan_vals[k]!r} != pool {pool_vals[k]!r}"
               for k in CONTRACT_FIELDS if plan_vals[k] != pool_vals[k]]
        if bad:
            raise ValueError(
                "plan/pool contract mismatch (these change the iterate "
                "sequence — a served run must be bit-identical to the "
                "client's own): " + "; ".join(bad))

    def _check_tenant_budget(self, pa, context: str) -> None:
        """Hold the daemon's per-plan budgets against the MAX-BOUND
        simulated schedule (``pa.sim["max"]``): worst-case lane-chunk
        and peak-resident-byte cost, known before any kernel
        materializes. Budget breaches become ``tenant-budget`` error
        findings and a structured :class:`PlanRejected`."""
        if not (self.plan_chunk_budget or self.plan_bytes_budget):
            return
        hi = (pa.sim or {}).get("max")
        if hi is None:
            # the simulator degraded (a sim-error warning is already on
            # the report) — a budget that cannot be checked cannot be
            # held, so the plan is refused
            pa.report.add(
                "tenant-budget", "<plan>", "schedule",
                "daemon enforces per-plan budgets but the schedule "
                "simulation produced no max bound", context=context)
        else:
            if self.plan_chunk_budget and \
                    hi["lane_chunks"] > self.plan_chunk_budget:
                pa.report.add(
                    "tenant-budget", "<plan>", "lane_chunks",
                    f"max-bound schedule costs {hi['lane_chunks']} "
                    f"lane-chunks, over the daemon's per-plan budget of "
                    f"{self.plan_chunk_budget}", context=context)
            if self.plan_bytes_budget and \
                    hi["peak_resident_bytes"] > self.plan_bytes_budget:
                pa.report.add(
                    "tenant-budget", "<plan>", "resident_bytes",
                    f"max-bound schedule co-holds "
                    f"{hi['peak_resident_bytes']} resident bytes, over "
                    f"the daemon's per-plan budget of "
                    f"{self.plan_bytes_budget}", context=context)
        bad = [f for f in pa.report.errors if f.rule == "tenant-budget"]
        if bad:
            raise plan_check.PlanRejected(
                "daemon per-plan budget exceeded:\n"
                + "\n".join(f.render() for f in bad), pa)

    def _checkpoint_for(self, tenant: str, plan_id: str, plan):
        if not self.checkpoint_root:
            return None
        mgr = CheckpointManager.namespaced(
            self.checkpoint_root, tenant, plan_id,
            max_to_keep=self.max_to_keep)
        return study_mod.StudyCheckpoint(
            manager=mgr, every=self.snapshot_every,
            meta={"study": f"{tenant}/{plan_id}", "tol": float(plan.tol),
                  "wss": plan.wss})

    def submit(self, tenant: str, plan_id: str, plan_dict, emit) -> None:
        """Admission gate + enrollment; SERVICE THREAD ONLY. Emits exactly
        one of: ``rejected`` (nothing entered the pool), or ``admitted``
        followed by the study's event stream."""
        ns = f"{tenant}/{plan_id}"
        try:
            if ns in self._studies:
                raise ValueError(f"study {ns!r} is already in flight")
            plan = study_mod.plan_from_dict(plan_dict)
            plan = study_mod.resolve_source_backend(plan)
            self._check_contract(plan)
            # schedule-only knobs are the POOL's (bit-parity makes the
            # schedule shape free); the budget the analyzer audits is the
            # pool's real budget, not the client's wish
            plan = dataclasses.replace(
                plan, chunk_iters=self.pool.chunk_iters,
                lane_quantum=self.pool.lane_quantum,
                max_width=self.pool.max_width,
                max_resident=self.pool.cache.max_resident,
                cache_bytes=self.pool.cache.cache_bytes)
            ckpt = self._checkpoint_for(tenant, plan_id, plan)
            # THE admission gate (ROADMAP: "call it verbatim"): rejects
            # invalid graphs, budget-infeasible sources, colliding
            # checkpoint ranges — before any kernel materializes
            pa = plan_check.check_plan(plan, checkpoint=ckpt, context=ns)
            storms = [f for f in pa.report if f.rule == "recompile-storm"]
            if storms:
                # daemon policy: the warning becomes a rejection — the jit
                # cache is shared, a storm taxes every tenant
                raise plan_check.PlanRejected(
                    "daemon policy rejects compile-storm plans:\n"
                    + "\n".join(f.render() for f in storms), pa)
            self._check_tenant_budget(pa, ns)
        except plan_check.PlanRejected as e:
            emit({"type": "rejected", "plan_id": plan_id, "error": str(e),
                  "findings": e.analysis.report.to_json()["findings"],
                  "analysis": e.analysis.to_json()})
            return
        except (ValueError, TypeError, KeyError) as e:
            emit({"type": "rejected", "plan_id": plan_id, "error": str(e),
                  "findings": []})
            return

        ns_plan, key_map, dedup_hits, new_keys = self._namespace(ns, plan)
        specs = study_mod.plan_specs(ns_plan)
        step0, restored = study_mod.restore_study_lanes(ckpt)
        pre_done = study_mod.enroll_plan_lanes(
            self.pool, ns_plan, specs, restored, tenant=tenant)
        lane_ids = set(specs)
        st = _Study(
            tenant=tenant, plan_id=plan_id, ns=ns, plan=ns_plan,
            specs=specs, emit=emit, lane_ids=lane_ids,
            remaining=lane_ids - pre_done,
            source_keys=tuple(dict.fromkeys(key_map.values())),
            checkpoint=ckpt,
            step=max(step0, study_mod.STUDY_BASE),
            dedup_hits=dedup_hits, restored=frozenset(pre_done))
        self._studies[ns] = st
        emit({"type": "admitted", "plan_id": plan_id,
              "lanes": len(lane_ids), "restored": len(pre_done),
              "dedup_hits": dedup_hits,
              "sources_admitted": len(new_keys),
              "analysis": {"program_count": pa.program_count,
                           "max_width": pa.max_width}})
        for spec in ns_plan.lanes:       # restored-done results, in order
            if spec.id in pre_done:
                self._emit_result(st, spec.id, self.pool.results[spec.id])
        self._wake.set()

    def _namespace(self, ns: str, plan):
        """Rewrite a validated plan for the shared pool: lane ids become
        ``(ns, orig)``, source keys become content-identity digests
        (dedup'd against every resident study), y becomes per-key."""
        key_map: dict = {}
        ys: dict = {}
        sources: dict = {}
        dedup_hits, new_keys = 0, []
        for okey, entry in plan.sources.items():
            y = plan.y_of(okey)
            ident = source_identity(entry, y)
            pkey = self._ident_to_key.get(ident) if ident is not None \
                else None
            if pkey is not None:
                if pkey not in key_map.values():
                    dedup_hits += 1
            else:
                digest = hashlib.sha1(repr(ident).encode()).hexdigest() \
                    if ident is not None else hashlib.sha1(
                        f"{ns}:{okey!r}".encode()).hexdigest()
                pkey = ("src", digest[:16])
                self.pool.add_source(pkey, entry, y)
                if ident is not None:
                    self._ident_to_key[ident] = pkey
                    self._key_ident[pkey] = ident
                new_keys.append(pkey)
            key_map[okey] = pkey
            sources[pkey] = self.pool.sources[pkey]
            ys[pkey] = self.pool.y_of(pkey)
        for pkey in dict.fromkeys(key_map.values()):
            self._key_refs[pkey] = self._key_refs.get(pkey, 0) + 1
        lanes = [dataclasses.replace(
            spec, id=(ns, spec.id),
            source=None if spec.result is not None
            else key_map[plan.source_key_of(spec)],
            dep=None if spec.dep is None else (ns, spec.dep),
            after=None if spec.after is None else (ns, spec.after))
            for spec in plan.lanes]
        evals = [study_mod.EvalSpec((ns, ev.lane), ev.test_idx)
                 for ev in plan.evals]
        ns_plan = dataclasses.replace(plan, sources=sources, y=ys,
                                      lanes=lanes, evals=evals)
        return ns_plan, key_map, dedup_hits, new_keys

    # ------------------------------------------------------------- events

    def _emit_result(self, st: _Study, lane_id, result) -> None:
        st.remaining.discard(lane_id)
        _, orig = lane_id
        st.emit({"type": "result", "plan_id": st.plan_id,
                 "lane": study_mod._to_wire(orig),
                 "result": study_mod.result_to_dict(result)})

    def _route_result(self, lane_id, result) -> None:
        """Pool ``on_result`` hook: fan a retirement out to its study."""
        st = self._studies.get(lane_id[0] if isinstance(lane_id, tuple)
                               else None)
        if st is not None and lane_id in st.lane_ids:
            self._emit_result(st, lane_id, result)

    def _finish_ready(self) -> None:
        for ns in list(self._studies):
            st = self._studies[ns]
            if st.remaining:
                continue
            results = {lid: self.pool.results[lid] for lid in st.lane_ids}
            try:
                evals = study_mod.run_plan_evals(
                    self.pool, st.plan, st.specs, results)
            except Exception as e:
                st.emit({"type": "error", "plan_id": st.plan_id,
                         "error": f"evaluation failed: {e}"})
                evals = {}
            if st.checkpoint is not None:
                # final flush: resubmitting this (tenant, plan_id) later
                # restores every lane pre-solved
                self._snapshot(st)
                st.checkpoint.manager.wait()
            tstats = self.pool.tenant_stats().get(st.tenant, {})
            st.emit({"type": "done", "plan_id": st.plan_id,
                     "evals": [[study_mod._to_wire(lid[1]),
                                [int(c), int(t)]]
                               for lid, (c, t) in evals.items()],
                     "restored": [study_mod._to_wire(lid[1])
                                  for lid in sorted_wire(st.restored)],
                     "study_source_stats": {
                         "dedup_hits": st.dedup_hits,
                         "sources_admitted": len(st.source_keys)
                         - st.dedup_hits},
                     "source_stats": dict(self.pool.cache.stats),
                     "tenant_stats": tstats})
            self._cleanup(st)

    def _cleanup(self, st: _Study) -> None:
        self.pool.remove_lanes(st.lane_ids)
        for pkey in st.source_keys:
            self._key_refs[pkey] -= 1
            if self._key_refs[pkey] <= 0:
                del self._key_refs[pkey]
                ident = self._key_ident.pop(pkey, None)
                if ident is not None:
                    self._ident_to_key.pop(ident, None)
                self.pool.remove_source(pkey)
        del self._studies[st.ns]

    def _fail_active(self, message: str) -> None:
        for st in list(self._studies.values()):
            st.emit({"type": "error", "plan_id": st.plan_id,
                     "error": message})

    # ----------------------------------------------------------- snapshots

    def _snapshot_tick(self) -> None:
        if self.pool.chunk_count % self.snapshot_every:
            return
        for st in self._studies.values():
            if st.checkpoint is not None and st.remaining:
                self._snapshot(st)

    def _snapshot(self, st: _Study) -> None:
        ids, tree = self.pool.snapshot_lanes(only=st.lane_ids)
        if not ids:
            return
        st.step += 1
        st.checkpoint.manager.save(
            st.step, tree,
            extra_meta={"phase": st.checkpoint.phase, "lane_ids": ids,
                        **st.checkpoint.meta},
            blocking=True, retain_class=st.checkpoint.retain_class)

    # -------------------------------------------------------------- status

    def status(self) -> dict:
        """SERVICE THREAD ONLY (route through ``enqueue``)."""
        return {"type": "status",
                "studies": [{"study": ns, "lanes": len(st.lane_ids),
                             "remaining": len(st.remaining)}
                            for ns, st in self._studies.items()],
                "tenants": {str(t): dict(rec) for t, rec in
                            self.pool.tenant_stats().items()},
                "occupancy": self.pool.occupancy,
                "source_stats": dict(self.pool.cache.stats),
                "resident_sources": len(self._key_refs)}


def sorted_wire(ids):
    """Deterministic ordering for mixed-type lane ids on the wire."""
    return sorted(ids, key=repr)


class StudyServer:
    """AF_UNIX front end: accept loop + one framing-only handler thread
    per connection. NO jax work happens on these threads — every op is
    forwarded to the service thread via ``enqueue``, and every event the
    service emits for a connection goes through that connection's write
    lock (the service thread and the handler thread share the socket)."""

    def __init__(self, socket_path: str, service: StudyService):
        self.socket_path = socket_path
        self.service = service
        self._listener: socket.socket | None = None
        self._accepting = threading.Event()

    def serve_forever(self) -> None:
        """Bind, start the service thread, accept until ``shutdown``.
        Returns after the graceful drain completes."""
        if os.path.exists(self.socket_path):
            os.unlink(self.socket_path)        # stale socket from a kill
        self._listener = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        self._listener.bind(self.socket_path)
        self._listener.listen()
        self.service.start()
        self._accepting.set()
        try:
            while self._accepting.is_set():
                try:
                    conn, _ = self._listener.accept()
                except OSError:                # listener closed by shutdown
                    break
                threading.Thread(target=self._handle, args=(conn,),
                                 daemon=True).start()
        finally:
            self.service.request_stop()
            self.service.join()
            self._listener.close()
            if os.path.exists(self.socket_path):
                os.unlink(self.socket_path)

    def stop_accepting(self) -> None:
        self._accepting.clear()
        if self._listener is not None:
            try:
                self._listener.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            self._listener.close()

    @staticmethod
    def _make_emit(wfile, lock):
        """An emit closure that survives a vanished client: once a write
        fails, further events are dropped — the study itself keeps
        running (results land in the pool, snapshots flush), it just has
        no listener."""
        dead = [False]

        def emit(msg) -> None:
            if dead[0]:
                return
            try:
                protocol.send_msg(wfile, msg, lock)
            except (OSError, ValueError):
                dead[0] = True
        return emit

    def _handle(self, conn: socket.socket) -> None:
        rfile = conn.makefile("rb")
        wfile = conn.makefile("wb")
        lock = threading.Lock()
        emit = self._make_emit(wfile, lock)
        tenant = None
        try:
            while True:
                try:
                    msg = protocol.recv_msg(rfile)
                except ValueError as e:        # framing error: drop conn
                    emit({"type": "error", "error": str(e)})
                    return
                if msg is None:
                    return
                op = msg.get("op") if isinstance(msg, dict) else None
                if op == "hello":
                    tenant = str(msg.get("tenant", ""))
                    if not tenant:
                        emit({"type": "error",
                              "error": "hello needs a tenant name"})
                        continue
                    emit({"type": "hello",
                          "pool": self.service.pool_contract()})
                elif op == "submit":
                    if tenant is None:
                        emit({"type": "error",
                              "error": "submit before hello"})
                        continue
                    plan_id = str(msg.get("plan_id", ""))
                    if not plan_id:
                        emit({"type": "error",
                              "error": "submit needs a plan_id"})
                        continue
                    plan_dict = msg.get("plan")
                    self.service.enqueue(
                        lambda t=tenant, p=plan_id, d=plan_dict:
                        self.service.submit(t, p, d, emit))
                elif op == "status":
                    self.service.enqueue(
                        lambda: emit(self.service.status()))
                elif op == "shutdown":
                    self.stop_accepting()
                    self.service.request_stop()
                    self.service.join()
                    emit({"type": "bye"})
                    return
                else:
                    emit({"type": "error",
                          "error": f"unknown op {op!r}"})
        finally:
            try:
                rfile.close()
                wfile.close()
            except OSError:
                pass
            conn.close()
