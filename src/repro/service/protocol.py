"""Wire protocol of the study service: JSON lines over a local socket.

One message per line, UTF-8 JSON, ``\\n``-terminated — the simplest
framing that composes with the study wire format (``core/study.py``'s
``plan_to_dict``: arrays ride as base64 blobs inside the JSON, so a line
IS a complete message regardless of payload size). Requests carry an
``op``; every reply and streamed event carries a ``type``.

Client -> server ops:

* ``{"op": "hello", "tenant": <str>}`` — names the connection's tenant
  (the fair-share accounting group). Reply: ``{"type": "hello",
  "pool": {...}}`` with the daemon's result-affecting pool contract
  (tol, wss, shrink settings) — what ``submit`` will hold plans to —
  plus the per-plan admission budgets ``plan_chunk_budget`` /
  ``plan_bytes_budget`` (0 = unbounded), enforced against the max-bound
  simulated schedule.
* ``{"op": "submit", "plan_id": <str>, "plan": <plan_to_dict image>}`` —
  admission + execution. Streamed replies, in order: ``admitted`` (with
  per-source dedup accounting), zero or more ``result`` events (one per
  lane, the moment it retires, bit-exact ``SMOResult`` image), then
  ``done`` (evals, per-lane stats, tenant/source accounting). A plan
  that fails admission gets a single ``rejected`` reply carrying the
  ``check_plan`` findings as structured payload AND the full
  ``PlanAnalysis.to_json()`` image under ``analysis`` (programs,
  budgets, min/max schedule-simulation summaries) — nothing
  materialized.
* ``{"op": "status"}`` — pool occupancy + per-tenant accounting.
* ``{"op": "shutdown"}`` — graceful drain: in-flight studies flush their
  checkpoint snapshots (they resume on the next daemon start), the
  daemon stops. Reply: ``{"type": "bye"}``.

Unknown ops answer ``{"type": "error", "error": ...}`` and keep the
connection; framing errors (non-JSON line) drop the connection.
"""
from __future__ import annotations

import json
import socket

#: bound on one message line (256 MiB): a runaway/hostile client cannot
#: make the daemon buffer an unbounded line
MAX_LINE = 256 * 1024 * 1024


def send_msg(wfile, obj, lock=None) -> None:
    """Write one message line. ``lock`` serializes writers when the
    service thread (events) and a handler thread (replies) share the
    socket."""
    data = (json.dumps(obj, separators=(",", ":")) + "\n").encode()
    if lock is not None:
        with lock:
            wfile.write(data)
            wfile.flush()
    else:
        wfile.write(data)
        wfile.flush()


def recv_msg(rfile):
    """Read one message line; None on EOF. Raises ``ValueError`` on a
    non-JSON or oversized line (the caller drops the connection)."""
    line = rfile.readline(MAX_LINE + 1)
    if not line:
        return None
    if len(line) > MAX_LINE:
        raise ValueError("message line exceeds MAX_LINE")
    return json.loads(line)


def connect(path: str) -> socket.socket:
    """Client-side AF_UNIX connect."""
    sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    sock.connect(path)
    return sock
