"""The study service: a long-lived multi-tenant ``LanePool`` daemon.

``server`` is the daemon (``StudyService`` core + ``StudyServer`` socket
front end), ``client`` the tenant-side API, ``protocol`` the JSON-lines
wire format. See DESIGN.md §Study service.
"""
from repro.service.client import (PlanRejectedByServer,  # noqa: F401
                                  ServedStudy, StudyClient)
from repro.service.server import StudyServer, StudyService  # noqa: F401
