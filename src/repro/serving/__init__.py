from repro.serving.decode import build_serve_step, prefill_logits  # noqa: F401
