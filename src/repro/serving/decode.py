"""Serving runtime: batched one-token decode against sharded caches.

serve_step = embed -> stacked-layer scan (each layer updates its cache
in-place via dynamic_update_slice) -> logits -> greedy/temperature sample.
Cache sharding is a config lever: "heads" (TP over kv heads) or "seq"
(sequence-sharded cache — flash-decode style; the partial softmax reductions
over the sharded seq axis lower to all-reduces; required for long_500k)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models.transformer import decode_step, forward


def build_serve_step(cfg, rules=None, sample: str = "greedy"):
    def serve_step(params, cache, batch):
        logits, new_cache = decode_step(params, cache, batch, cfg, rules=rules)
        last = logits[:, -1].astype(jnp.float32)
        if sample == "greedy":
            nxt = jnp.argmax(last, axis=-1).astype(jnp.int32)
        else:
            key = jax.random.PRNGKey(0)
            key = jax.random.fold_in(key, batch["step"])
            nxt = jax.random.categorical(key, last).astype(jnp.int32)
        return nxt, new_cache
    return serve_step


def prefill_logits(params, batch, cfg, rules=None):
    """Inference prefill: full-context forward, logits for the LAST position
    only (vLLM semantics — the prompt's logits are never materialized)."""
    logits, _ = forward(params, batch, cfg, rules=rules, mode="prefill")
    return logits
