"""Train-step builder: microbatched gradient accumulation, remat, sharded
loss, optional error-feedback int8 gradient compression.

Gradient accumulation is a lax.scan over microbatches — each microbatch's
backward produces FSDP-sharded (reduce-scattered) grads that accumulate into
a params-shaped buffer, so peak activation memory is one microbatch deep and
the per-microbatch grad reduce-scatter overlaps the next microbatch's
compute under XLA's latency-hiding scheduler (documented §Perf).

Error-feedback compression (``compress_grads="int8_ef"``): each microbatch
gradient is absmax-int8 quantized before accumulation; the quantization
residual is carried and re-injected into the next microbatch (EF-SGD
semantics). This bounds the accumulator wire/width at 1 B/param; the
residual buffer lives sharded like the grads.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.models.transformer import forward
from repro.sharding import constrain
from repro.training.loss import sharded_xent
from repro.training.optimizer import Optimizer


@dataclasses.dataclass
class TrainState:
    params: dict
    opt_state: dict
    step: jnp.ndarray


def _loss_fn(params, mb, cfg, rules):
    logits, extras = forward(params, mb, cfg, rules=rules, mode="train")
    loss = sharded_xent(logits, mb["targets"], mb.get("mask"))
    if "aux_loss" in extras:
        loss = loss + 0.001 * extras["aux_loss"]
    if "mtp_logits" in extras:  # deepseek-v3 MTP: predict t+2 (weight 0.3)
        t2 = jnp.roll(mb["targets"], -1, axis=1)
        m2 = mb.get("mask")
        loss = loss + 0.3 * sharded_xent(extras["mtp_logits"], t2, m2)
    return loss


def _q8_ef(g, carry_err):
    g32 = g.astype(jnp.float32) + carry_err
    scale = jnp.max(jnp.abs(g32)) / 127.0 + 1e-12
    q = jnp.round(g32 / scale)
    deq = q * scale
    return deq, g32 - deq


def build_train_step(cfg, rules, optimizer: Optimizer, *,
                     n_microbatches: int = 1, lr: float = 3e-4,
                     accum_dtype=jnp.float32, compress_grads: str | None = None):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics). ``batch``: tokens/targets/mask (global_batch, seq) [+ extras]."""

    loss_fn = partial(_loss_fn, cfg=cfg, rules=rules)

    def train_step(params, opt_state, batch):
        if n_microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def split(x):
                y = x.reshape(n_microbatches, x.shape[0] // n_microbatches,
                              *x.shape[1:])
                # keep the REAL batch axis data-sharded (not the micro axis)
                return constrain(y, (None, "batch") + (None,) * (y.ndim - 2),
                                 rules)
            mbs = jax.tree.map(split, batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params)

            if compress_grads == "int8_ef":
                errs = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)

                def body(carry, mb):
                    acc, err, lsum = carry
                    l, g = jax.value_and_grad(loss_fn)(params, mb)
                    qe = jax.tree.map(_q8_ef, g, err)
                    deq = jax.tree.map(lambda t: t[0], qe,
                                       is_leaf=lambda x: isinstance(x, tuple))
                    nerr = jax.tree.map(lambda t: t[1], qe,
                                        is_leaf=lambda x: isinstance(x, tuple))
                    acc = jax.tree.map(lambda a, d: a + d.astype(accum_dtype),
                                       acc, deq)
                    return (acc, nerr, lsum + l), None

                (grads, _, lsum), _ = jax.lax.scan(
                    body, (zeros, errs, jnp.zeros((), jnp.float32)), mbs)
            else:
                def body(carry, mb):
                    acc, lsum = carry
                    l, g = jax.value_and_grad(loss_fn)(params, mb)
                    acc = jax.tree.map(lambda a, gg: a + gg.astype(accum_dtype),
                                       acc, g)
                    return (acc, lsum + l), None

                (grads, lsum), _ = jax.lax.scan(
                    body, (zeros, jnp.zeros((), jnp.float32)), mbs)
            loss = lsum / n_microbatches
            grads = jax.tree.map(lambda g: (g / n_microbatches), grads)

        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        new_params, new_opt = optimizer.update(grads, opt_state, params, lr)
        return new_params, new_opt, {"loss": loss, "grad_norm": gnorm}

    return train_step
