from repro.training.optimizer import adamw, adafactor, adam8bit, get_optimizer  # noqa: F401
from repro.training.loss import sharded_xent  # noqa: F401
from repro.training.train_step import build_train_step, TrainState  # noqa: F401
