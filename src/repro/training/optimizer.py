"""Optimizers (no optax dependency): AdamW, Adafactor, 8-bit-state Adam.

All are pure pytree transforms; optimizer state inherits each parameter's
sharding (states are elementwise/factored images of the param tree), so FSDP
shards the optimizer exactly as it shards the weights.

Memory per param (the §Roofline memory-term lever, chosen per arch config):
  adamw       bf16 param + fp32 m + fp32 v            = 10 B
  adam8bit    bf16 param + int8 m + int8 v + scales   = ~4 B
  adafactor   bf16 param + factored row/col stats     = ~2 B
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable
    update: Callable     # (grads, state, params, lr) -> (new_params, new_state)
    state_axes: Callable = None  # param-logical-axes tree -> state axes tree


def _tmap(f, *trees, **kw):
    return jax.tree.map(f, *trees, **kw)


# ------------------------------------------------------------------ AdamW --

def adamw(b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.01) -> Optimizer:
    def init(params):
        return {"m": _tmap(lambda p: jnp.zeros(p.shape, jnp.float32), params),
                "v": _tmap(lambda p: jnp.zeros(p.shape, jnp.float32), params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        step = state["step"] + 1
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)
        m = _tmap(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                  state["m"], grads)
        v = _tmap(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                  state["v"], grads)

        def upd(p, m, v):
            u = (m / c1) / (jnp.sqrt(v / c2) + eps) + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        return _tmap(upd, params, m, v), {"m": m, "v": v, "step": step}

    def state_axes(param_axes):
        t = lambda: jax.tree.map(lambda a: a, param_axes,
                                 is_leaf=lambda x: isinstance(x, tuple))
        return {"m": t(), "v": t(), "step": ()}

    return Optimizer("adamw", init, update, state_axes)


# -------------------------------------------------------------- Adafactor --

def adafactor(eps=1e-30, clip_threshold=1.0, decay=0.8) -> Optimizer:
    """Factored second moments over the last two axes for ndim>=2 params —
    the HBM-fit choice for the 236B/671B MoE configs."""
    def init(params):
        def mk(p):
            if p.ndim >= 2:
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"v": _tmap(mk, params,), "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        step = state["step"] + 1
        beta = 1.0 - step.astype(jnp.float32) ** -decay

        def upd(p, g, s):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if p.ndim >= 2:
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.sqrt(vr[..., None] * vc[..., None, :]
                                 / (jnp.mean(vr, axis=-1, keepdims=True)[..., None] + eps))
                u = g / (denom + eps)
                ns = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g / (jnp.sqrt(v) + eps)
                ns = {"v": v}
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), ns

        flat = _tmap(lambda p, g, s: upd(p, g, s), params, grads, state["v"],)
        new_params = _tmap(lambda x: x[0], flat,
                           is_leaf=lambda x: isinstance(x, tuple))
        new_v = _tmap(lambda x: x[1], flat,
                      is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"v": new_v, "step": step}

    def state_axes(param_axes):
        def mk(a):
            if len(a) >= 2:
                return {"vr": a[:-1], "vc": a[:-2] + a[-1:]}
            return {"v": a}
        return {"v": jax.tree.map(mk, param_axes,
                                  is_leaf=lambda x: isinstance(x, tuple)),
                "step": ()}

    return Optimizer("adafactor", init, update, state_axes)


# --------------------------------------------------------- 8-bit-state Adam

def _q8(x):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    return jnp.round(x / scale).astype(jnp.int8), scale


def _dq8(q, scale):
    return q.astype(jnp.float32) * scale


def adam8bit(b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.01) -> Optimizer:
    """Adam with int8-quantized moments (per-tensor absmax scaling) — a
    distributed-optimization memory trick: 4 B/param of optimizer state
    instead of 8 B, sharded like the params."""
    def init(params):
        def mk(p):
            return {"mq": jnp.zeros(p.shape, jnp.int8),
                    "ms": jnp.ones((), jnp.float32),
                    "vq": jnp.zeros(p.shape, jnp.int8),
                    "vs": jnp.ones((), jnp.float32)}
        return {"s": _tmap(mk, params), "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        step = state["step"] + 1
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(p, g, s):
            g = g.astype(jnp.float32)
            m = b1 * _dq8(s["mq"], s["ms"]) + (1 - b1) * g
            v = b2 * _dq8(s["vq"], s["vs"]) + (1 - b2) * jnp.square(g)
            u = (m / c1) / (jnp.sqrt(v / c2) + eps) + weight_decay * p.astype(jnp.float32)
            mq, ms = _q8(m)
            vq, vs = _q8(v)
            return ((p.astype(jnp.float32) - lr * u).astype(p.dtype),
                    {"mq": mq, "ms": ms, "vq": vq, "vs": vs})

        flat = _tmap(upd, params, grads, state["s"])
        new_params = _tmap(lambda x: x[0], flat,
                           is_leaf=lambda x: isinstance(x, tuple))
        new_s = _tmap(lambda x: x[1], flat,
                      is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"s": new_s, "step": step}

    def state_axes(param_axes):
        def mk(a):
            return {"mq": a, "ms": (), "vq": a, "vs": ()}
        return {"s": jax.tree.map(mk, param_axes,
                                  is_leaf=lambda x: isinstance(x, tuple)),
                "step": ()}

    return Optimizer("adam8bit", init, update, state_axes)


def get_optimizer(name: str, **kw) -> Optimizer:
    return {"adamw": adamw, "adafactor": adafactor, "adam8bit": adam8bit}[name](**kw)
