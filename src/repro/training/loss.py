"""Losses. The cross-entropy keeps logits vocab-sharded: the logsumexp and
the target-logit gather reduce over the sharded vocab axis (an all-reduce of
(B,S) scalars under SPMD), never materializing a replicated (B,S,V) tensor —
at gemma3's 262k vocab that is the difference between fitting and not."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sharded_xent(logits, targets, mask=None):
    """logits (B,S,V) [sharded over V], targets (B,S) int, mask (B,S)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = lse - tgt
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
