"""Gemma3-4B [hf:google/gemma-3-4b-pt; unverified]. 34L, d=2560, 8H, kv=4,
head_dim=256, GeGLU ffn 10240, vocab 262144, 5:1 local(window 1024):global."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b", family="dense", n_layers=34, d_model=2560, n_heads=8,
    n_kv_heads=4, d_ff=10240, vocab_size=262_144, head_dim=256, act="gelu",
    tie_embeddings=True, rope_theta=1_000_000.0,
    window_pattern=(1024, 1024, 1024, 1024, 1024, None),
)

SMOKE = CONFIG.replace(n_layers=6, d_model=64, n_heads=4, n_kv_heads=2,
                       d_ff=128, vocab_size=512, head_dim=16,
                       window_pattern=(16, 16, 16, 16, 16, None))
