"""Yi-34B [arXiv:2403.04652; hf]. Llama-arch GQA: 60L, d=7168, 56H, kv=8,
ffn 20480, vocab 64000."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b", family="dense", n_layers=60, d_model=7168, n_heads=56,
    n_kv_heads=8, d_ff=20480, vocab_size=64_000, head_dim=128,
    rope_theta=5_000_000.0,
)

SMOKE = CONFIG.replace(n_layers=3, d_model=64, n_heads=8, n_kv_heads=2,
                       d_ff=128, vocab_size=512, head_dim=16)
