"""DeepSeek-V2 236B [arXiv:2405.04434; hf]. MLA (kv_lora=512, q_lora=1536),
60L, 128H, MoE: 2 shared + 160 routed top-6 (moe_ffn=1536), first layer dense
(ffn 12288), vocab 102400."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe", n_layers=60, d_model=5120,
    n_heads=128, n_kv_heads=128, d_ff=12288, vocab_size=102_400,
    attn_kind="mla", q_lora_rank=1536, kv_lora_rank=512, qk_nope_dim=128,
    qk_rope_dim=64, v_head_dim=128, head_dim=192,
    n_experts=160, n_shared_experts=2, top_k=6, moe_d_ff=1536,
    first_dense_layers=1, router_kind="softmax",
)

SMOKE = CONFIG.replace(
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=512, q_lora_rank=48, kv_lora_rank=32, qk_nope_dim=16,
    qk_rope_dim=8, v_head_dim=16, head_dim=24, n_experts=8,
    n_shared_experts=1, top_k=2, moe_d_ff=32, first_dense_layers=1,
)
