"""Qwen2-VL-2B [arXiv:2409.12191; hf]. 28L, d=1536, 12H, kv=2, ffn 8960,
vocab 151936, M-RoPE (sections 16/24/24). The vision frontend is a STUB:
input_specs() provides precomputed patch embeddings per the assignment."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b", family="vlm", n_layers=28, d_model=1536, n_heads=12,
    n_kv_heads=2, d_ff=8960, vocab_size=151_936, head_dim=128,
    rope_kind="mrope", mrope_sections=(16, 24, 24), tie_embeddings=True,
    rope_theta=1_000_000.0, frontend="vision_patches",
)

SMOKE = CONFIG.replace(n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
                       d_ff=128, vocab_size=512, head_dim=32,
                       mrope_sections=(4, 6, 6))
