"""Gemma-7B [arXiv:2403.08295; hf]. 28L, d=3072, 16H MHA (kv=16),
head_dim=256, GeGLU ffn 24576, vocab 256000, tied embeddings."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b", family="dense", n_layers=28, d_model=3072, n_heads=16,
    n_kv_heads=16, d_ff=24576, vocab_size=256_000, head_dim=256, act="gelu",
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
                       d_ff=128, vocab_size=512, head_dim=16)
