"""SeamlessM4T-large-v2 [arXiv:2308.11596; hf]. Enc-dec transformer backbone:
24 encoder + 24 decoder layers, d=1024, 16H, ffn 8192, vocab 256206. The
audio frontend is a STUB: input_specs() provides precomputed frame
embeddings (B, frames, d) per the assignment."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="audio", n_layers=24, d_model=1024,
    n_heads=16, n_kv_heads=16, d_ff=8192, vocab_size=256_206, head_dim=64,
    is_encoder_decoder=True, n_enc_layers=24, frontend="audio_frames",
)

SMOKE = CONFIG.replace(n_layers=3, n_enc_layers=3, d_model=64, n_heads=4,
                       n_kv_heads=4, d_ff=128, vocab_size=512, head_dim=16)
