from repro.configs.base import ModelConfig, ShapeConfig, SHAPES, get_config, list_configs  # noqa: F401
