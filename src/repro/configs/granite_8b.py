"""Granite-8B-code [arXiv:2405.04324; hf]. Llama-arch: 36L, d=4096, 32H,
kv=8, ffn 14336, vocab 49152."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b", family="dense", n_layers=36, d_model=4096, n_heads=32,
    n_kv_heads=8, d_ff=14336, vocab_size=49_152, head_dim=128,
    rope_theta=10_000_000.0,
)

SMOKE = CONFIG.replace(n_layers=3, d_model=64, n_heads=8, n_kv_heads=2,
                       d_ff=128, vocab_size=512, head_dim=16)
