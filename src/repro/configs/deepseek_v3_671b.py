"""DeepSeek-V3 671B [arXiv:2412.19437; hf]. MLA, 61L, 128H, 1 shared + 256
routed top-8 (sigmoid router), first 3 layers dense (ffn 18432), MTP depth 1,
vocab 129280."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe", n_layers=61, d_model=7168,
    n_heads=128, n_kv_heads=128, d_ff=18432, vocab_size=129_280,
    attn_kind="mla", q_lora_rank=1536, kv_lora_rank=512, qk_nope_dim=128,
    qk_rope_dim=64, v_head_dim=128, head_dim=192,
    n_experts=256, n_shared_experts=1, top_k=8, moe_d_ff=2048,
    first_dense_layers=3, router_kind="sigmoid", mtp_depth=1,
)

SMOKE = CONFIG.replace(
    n_layers=5, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=512, q_lora_rank=48, kv_lora_rank=32, qk_nope_dim=16,
    qk_rope_dim=8, v_head_dim=16, head_dim=24, n_experts=8,
    n_shared_experts=1, top_k=2, moe_d_ff=32, first_dense_layers=2,
)
