"""xLSTM-125M [arXiv:2405.04517; unverified]. 12 blocks alternating
mLSTM/sLSTM, d=768, 4H, no separate FFN (d_ff=0), vocab 50304."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm", n_layers=12, d_model=768, n_heads=4,
    n_kv_heads=4, d_ff=0, vocab_size=50_304,
    block_kinds=("mlstm", "slstm"), tie_embeddings=True,
)

SMOKE = CONFIG.replace(n_layers=4, d_model=64, n_heads=2, n_kv_heads=2,
                       vocab_size=512)
