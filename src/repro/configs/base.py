"""Config system: one ModelConfig per assigned architecture (+ reduced smoke
variants), plus the assigned input-shape suite."""
from __future__ import annotations

import dataclasses
import importlib


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None    # default d_model // n_heads
    act: str = "silu"              # silu (SwiGLU) | gelu (GeGLU)
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # attention
    attn_kind: str = "gqa"         # gqa | mla
    rope_theta: float = 10_000.0
    rope_kind: str = "standard"    # standard | mrope
    mrope_sections: tuple = (16, 24, 24)
    window_pattern: tuple | None = None  # e.g. gemma3: (1024,)*5 + (None,)
    attn_every: int = 1            # jamba: attention layer every Nth...
    attn_offset: int = 0           # ...at this offset (others are mamba)
    attn_logit_softcap: float | None = None
    attn_q_chunk: int | None = None  # flash-style q-chunked XLA attention

    # MLA (deepseek)
    q_lora_rank: int | None = None
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 2
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    first_dense_layers: int = 0    # deepseek: first k layers use dense MLP
    moe_every: int = 1             # jamba: MoE replaces MLP every Nth layer
    moe_offset: int = 0
    router_kind: str = "softmax"   # softmax (v2/jamba) | sigmoid (v3)

    # SSM (mamba)
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2

    # xLSTM
    block_kinds: tuple | None = None   # explicit per-layer kinds override

    # enc-dec (seamless)
    is_encoder_decoder: bool = False
    n_enc_layers: int = 0

    # multi-token prediction (deepseek-v3)
    mtp_depth: int = 0

    # modality frontend stubs ([audio]/[vlm]): input_specs provides embeddings
    frontend: str | None = None    # None | "audio_frames" | "vision_patches"

    # numerics / runtime
    dtype: str = "bfloat16"
    remat_policy: str = "full"     # full | dots | none   (hillclimb lever)
    decode_kv_shard: str = "heads"  # heads | seq  (seq-sharded flash-decode)
    moe_impl: str = "scatter"      # scatter | shard_map  (hillclimb lever)

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


# The assigned input-shape suite (identical for all 10 LM archs).
SHAPES = {
    "train_4k":    ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k":  ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k":   ShapeConfig("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS = (
    "deepseek-v2-236b", "deepseek-v3-671b", "yi-34b", "gemma3-4b",
    "granite-8b", "gemma-7b", "jamba-v0.1-52b", "seamless-m4t-large-v2",
    "xlstm-125m", "qwen2-vl-2b",
)


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(
        "repro.configs." + arch.replace("-", "_").replace(".", "_"))
    return mod.SMOKE if smoke else mod.CONFIG


def list_configs():
    return ARCH_IDS
