"""Jamba-v0.1 52B [arXiv:2403.19887; hf]. 32L hybrid: attention every 8th
layer (offset 4, 1:7 attn:mamba), MoE (16 experts top-2) every 2nd layer
(offset 1), d=4096, 32H, kv=8, ffn 14336, vocab 65536. NoPE attention."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab_size=65_536, head_dim=128,
    rope_kind="none", attn_every=8, attn_offset=4,
    n_experts=16, top_k=2, moe_d_ff=14336, moe_every=2, moe_offset=1,
    mamba_d_state=16, mamba_d_conv=4, mamba_expand=2,
)

SMOKE = CONFIG.replace(n_layers=8, d_model=64, n_heads=4, n_kv_heads=2,
                       d_ff=128, vocab_size=512, head_dim=16, n_experts=4,
                       top_k=2, moe_d_ff=64)
