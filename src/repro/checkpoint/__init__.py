from repro.checkpoint.manager import (CheckpointManager, load_pytree,  # noqa: F401
                                      namespace_path, save_pytree)
