"""Sharding-agnostic checkpointing with crash-consistent commits.

Design (scaled-down Orbax):

* Arrays are saved with their GLOBAL shape (device_get assembles shards), so
  a checkpoint written on one mesh restores onto ANY other mesh — this is the
  elastic-scaling path: change the pod count, restart, restore, continue.
* Writes are crash-consistent: payload goes to ``<step>.tmp/``, then an
  atomic rename to ``<step>/`` publishes it; readers only trust directories
  with a ``COMMIT`` marker. A killed writer never corrupts the latest
  checkpoint (fault-tolerance requirement).
* ``save(..., blocking=False)`` runs the serialization on a background
  thread so the training loop overlaps checkpoint I/O with compute
  (async checkpointing). ``wait()`` joins before exit.
* Retention: ``max_to_keep`` newest steps are kept PER ``retain_class``
  (default: one shared class), so high-frequency snapshots cannot evict
  the rare records a resume depends on.

The same manager checkpoints LM training state (params/opt/step) and the CV
fold chain (fold index, alpha, f) — the paper's alpha seeding doubles as the
restart mechanism for cross-validation.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import threading

import jax
import numpy as np

_SEP = "/"

_SAFE_PART = re.compile(r"[^A-Za-z0-9._-]")


def namespace_path(root: str, *parts: str) -> str:
    """A filesystem-safe subdirectory of ``root`` for the given namespace
    parts (the daemon keys checkpoints by ``tenant / plan_id``). Each part
    is sanitized to ``[A-Za-z0-9._-]``; when sanitization changed the
    part, a short content hash of the ORIGINAL is appended so two
    distinct raw names that sanitize alike ("a/b" vs "a:b") cannot share
    a directory — and the mapping is deterministic, so a restarted daemon
    finds the same directory for the same tenant/plan names."""
    safe = []
    for part in parts:
        part = str(part)
        if not part or set(part) <= {"."}:
            raise ValueError(f"namespace part {part!r} is empty or dots-only")
        clean = _SAFE_PART.sub("_", part)
        if clean != part:
            clean += "-" + hashlib.sha1(part.encode()).hexdigest()[:8]
        safe.append(clean)
    return os.path.join(root, *safe)


def _flatten(tree):
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in leaves_with_paths:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = np.asarray(jax.device_get(leaf))
    return out


def save_pytree(path: str, tree, extra_meta: dict | None = None,
                retain_class: str = "default") -> None:
    """Atomic commit: write to <path>.tmp, fsync, rename, marker."""
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    treedef = jax.tree_util.tree_structure(tree)
    meta = {"treedef": str(treedef), "keys": sorted(flat),
            "extra": extra_meta or {}, "retain_class": retain_class}
    with open(os.path.join(tmp, "meta.json"), "w") as fh:
        json.dump(meta, fh)
    with open(os.path.join(tmp, "COMMIT"), "w") as fh:
        fh.write("ok")
    if os.path.exists(path):
        shutil.rmtree(path)
    os.replace(tmp, path)


def load_pytree(path: str, target=None):
    """Load a checkpoint. With ``target`` (a pytree prototype), leaves are
    restored in target's tree structure (and device_put with the leaf's
    sharding if the prototype leaf is a jax.Array — elastic re-shard)."""
    if not os.path.exists(os.path.join(path, "COMMIT")):
        raise FileNotFoundError(f"no committed checkpoint at {path}")
    with np.load(os.path.join(path, "arrays.npz")) as data:
        flat = {k: data[k] for k in data.files}
    with open(os.path.join(path, "meta.json")) as fh:
        meta = json.load(fh)
    if target is None:
        return flat, meta["extra"]
    paths_and_leaves = jax.tree_util.tree_flatten_with_path(target)
    restored = []
    for path_elems, proto in paths_and_leaves[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path_elems)
        arr = flat[key]
        if isinstance(proto, jax.Array) and hasattr(proto, "sharding"):
            arr = jax.device_put(arr.astype(proto.dtype), proto.sharding)
        restored.append(arr)
    return jax.tree_util.tree_unflatten(paths_and_leaves[1], restored), meta["extra"]


class CheckpointManager:
    @classmethod
    def namespaced(cls, root: str, *parts: str,
                   max_to_keep: int = 3) -> "CheckpointManager":
        """Manager over ``namespace_path(root, *parts)`` — one isolated
        step-number space and retention budget per (tenant, plan): two
        tenants' studies can both write ``STUDY_BASE + k`` records into
        one checkpoint root without colliding, and one tenant's snapshot
        frequency cannot evict another's records."""
        return cls(namespace_path(root, *parts), max_to_keep=max_to_keep)

    def __init__(self, directory: str, max_to_keep: int = 3):
        self.directory = directory
        self.max_to_keep = max_to_keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._retain_classes: dict[int, str] = {}

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:010d}")

    def all_steps(self) -> list[int]:
        steps = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp") and \
                    os.path.exists(os.path.join(self.directory, name, "COMMIT")):
                steps.append(int(name[len("step_"):]))
        return sorted(steps)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def steps_of_class(self, retain_class: str) -> list[int]:
        """Committed steps written under one ``retain_class``. Record kinds
        GC independently, so resume paths that only understand one kind
        (e.g. the batched CV driver's ``"batch"`` mid-batch snapshots,
        keyed by lane id) must also *select* by class rather than trusting
        ``latest_step`` across the whole directory."""
        return [s for s in self.all_steps() if self._step_class(s) == retain_class]

    def latest_step_of_class(self, retain_class: str) -> int | None:
        steps = self.steps_of_class(retain_class)
        return steps[-1] if steps else None

    def restore_latest_of_class(self, retain_class: str):
        """(step, tree, extra) of the newest committed record in one
        ``retain_class``, or None when the class has no records — the
        one-call resume entry the Study API uses (class-scoped ``latest``:
        a directory shared with other record kinds must not shadow it)."""
        step = self.latest_step_of_class(retain_class)
        if step is None:
            return None
        return self.restore(step=step)

    def save(self, step: int, tree, extra_meta: dict | None = None,
             blocking: bool = True, retain_class: str = "default") -> None:
        """``retain_class`` partitions the retention budget: ``max_to_keep``
        newest steps are kept PER class, so frequent low-value snapshots
        (e.g. the CV driver's mid-fold chunk states) cannot evict the rare
        records that resume correctness depends on (completed folds)."""
        self.wait()
        self._retain_classes[step] = retain_class
        # materialize on host BEFORE backgrounding (donated buffers may die)
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def _work():
            save_pytree(self._step_dir(step), host_tree, extra_meta,
                        retain_class)
            self._gc()

        if blocking:
            _work()
        else:
            self._thread = threading.Thread(target=_work, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore(self, step: int | None = None, target=None):
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        tree, extra = load_pytree(self._step_dir(step), target)
        return step, tree, extra

    def _step_class(self, step: int) -> str:
        """Retention class of a step; read from meta.json when this manager
        instance didn't write it (resume after a restart)."""
        cls = self._retain_classes.get(step)
        if cls is None:
            try:
                with open(os.path.join(self._step_dir(step),
                                       "meta.json")) as fh:
                    cls = json.load(fh).get("retain_class", "default")
            except (OSError, json.JSONDecodeError):
                cls = "default"
            self._retain_classes[step] = cls
        return cls

    def _gc(self) -> None:
        by_class: dict[str, list[int]] = {}
        for s in self.all_steps():   # sorted -> per-class lists sorted too
            by_class.setdefault(self._step_class(s), []).append(s)
        for steps in by_class.values():
            for s in steps[: -self.max_to_keep]:
                shutil.rmtree(self._step_dir(s), ignore_errors=True)
                self._retain_classes.pop(s, None)
