"""Static schedule simulator: abstract interpretation of the LanePool.

``analyze_plan`` (plan_check.py) bounds a plan's *shape* — program set,
worst single source vs the cache budget. What it cannot see is the
schedule over TIME: which kernels are co-resident when, how often the LRU
re-materializes under churn, how many chunks each tenant consumes, how
much checkpoint volume a study writes. This module answers those by
*executing the schedule symbolically*: a deterministic replay of the
``LanePool`` scheduling loop over a ``Plan`` — no kernel materializes, no
program compiles, no solve runs — emitting the same typed event trace the
instrumented live pool emits (``LanePool(on_trace=...)``), so the two can
be asserted equal event-for-event.

**Pure-function contract.** Every decision the live scheduler makes per
chunk is a pure function this module replays verbatim:
``scheduler.order_capped`` / ``select_capped`` (width-capped sticky >
resident > cold priority, multi-tenant round-robin), ``budget_sources``
(per-chunk managed-source budget), ``bucket_width`` (pad bucketing),
``sources.budget_fits`` (THE residency budget rule) and
``sources.pick_victim`` (THE eviction rule). The simulator holds no
policy of its own — drift between prediction and execution is a failed
CI trace assertion (``scripts/ci_plan_sim_smoke.py``), not a silent bug.

**Event grammar** (tuples; ``chunk`` = 0-based scheduling round):

* ``("given", lane_id)`` — pre-solved result registered
* ``("admit", lane_id, source_key)`` — lane state built (edges retired)
* ``("materialize", source_key, nbytes)`` / ``("evict", source_key,
  nbytes)`` — managed residency transitions, in schedule order
* ``("pack", source_key, lane_ids)`` — batched group (re)packed
* ``("dispatch", chunk, source_key, cap, width, lane_ids)`` — one chunk
  program over one (source, cap) group at its bucketed width (cap 0 =
  unshrunk / shrink off)
* ``("retire", lane_id, n_iter)`` — lane done, at its final iteration
  count
* ``("shares", chunk, ((tenant_repr, lanes), ...))`` — per-tenant width
  split of the chunk's selection (multi-tenant pools only)
* ``("resident", chunk, nbytes)`` — end-of-chunk resident watermark
  (pinned + managed)
* ``("checkpoint", chunk, lane_ids, est_bytes)`` — snapshot record
  (``scheduler.snapshot_nbytes`` estimate)

**Iteration oracle.** Convergence is the ONE dynamic input: when each
lane's ``done`` flag first trips. :class:`ExactOracle` replays recorded
per-lane ``n_iter`` (and, for shrink-enabled pools, the recorded
per-dispatch cap sequence — shrink lifecycle decisions are
data-dependent); :func:`oracle_from_trace` derives one from an
instrumented run. :class:`BoundOracle` brackets an unknown schedule:
``"min"`` assumes every lane converges in its first chunk (fewest
dispatches; materialization floor), ``"max"`` runs every lane to a
horizon (dispatch/eviction ceiling). A shrink-enabled plan under a
``BoundOracle`` is approximate — lanes are assumed never to shrink, but
``it_cap`` boundary arithmetic still paces dispatches.

The per-dispatch arithmetic mirrors ``engine._step`` exactly: ``done``
is computed BEFORE the iterate, so a lane whose remaining room is an
exact multiple of ``chunk_iters`` costs one extra zero-advance dispatch
before it retires.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

from repro.svm import cost_model
from repro.svm import shrink as shrink_mod
from repro.svm.scheduler import (LanePool, budget_sources, bucket_width,
                                 order_capped, select_capped,
                                 snapshot_nbytes)
from repro.svm.sources import (budget_fits, is_factory, pick_victim,
                               source_nbytes)

#: safety valve on simulated scheduling rounds: a max-bound sim of an
#: adversarial plan must not spin the admission gate; truncation only
#: under-reports (``ScheduleAnalysis.truncated`` flags it)
DEFAULT_MAX_CHUNKS = 100_000


class ExactOracle:
    """Exact replay: ``n_iters`` maps lane id -> recorded final
    ``n_iter``. For shrink-enabled pools, ``caps`` maps lane id -> the
    recorded per-dispatch cap sequence (the shrink lifecycle is
    data-dependent, so exact shrink replay needs the recorded caps; the
    lane retires when its sequence is exhausted)."""

    label = "exact"

    def __init__(self, n_iters: dict, caps: dict | None = None):
        self.n_iters = dict(n_iters)
        self.caps = {k: list(v) for k, v in caps.items()} \
            if caps is not None else None

    def target(self, lane_id, max_iter: int) -> int:
        if lane_id not in self.n_iters:
            raise KeyError(f"oracle has no n_iter for lane {lane_id!r}")
        return min(int(self.n_iters[lane_id]), int(max_iter))

    def cap_seq(self, lane_id):
        if self.caps is None:
            return None
        return self.caps.get(lane_id)


class BoundOracle:
    """Bounding oracle: ``"min"`` = every lane converges within its first
    chunk (1 iteration), ``"max"`` = no lane converges before ``horizon``
    iterations (capped by each lane's ``max_iter``)."""

    def __init__(self, mode: str, horizon: int | None = None):
        if mode not in ("min", "max"):
            raise ValueError(f"unknown bound mode {mode!r}")
        if mode == "max" and not horizon:
            raise ValueError("a max-bound oracle needs a horizon")
        self.mode = mode
        self.horizon = int(horizon) if horizon else None
        self.label = f"bound:{mode}"

    def target(self, lane_id, max_iter: int) -> int:
        if self.mode == "min":
            return min(1, int(max_iter))
        return min(self.horizon, int(max_iter))

    def cap_seq(self, lane_id):
        return None


def oracle_from_trace(events, *, shrink: bool = False) -> ExactOracle:
    """Derive the exact oracle from an instrumented trace (``dry_run`` or
    a live ``on_trace`` capture): retire events carry final ``n_iter``;
    with ``shrink``, dispatch events carry each lane's cap sequence."""
    n_iters: dict = {}
    caps: dict = {}
    for ev in events:
        if ev[0] == "dispatch":
            for lid in ev[5]:
                caps.setdefault(lid, []).append(int(ev[3]))
        elif ev[0] == "retire":
            n_iters[ev[1]] = int(ev[2])
    return ExactOracle(n_iters, caps=caps if shrink else None)


def dry_run(plan, *, tenant=None, snapshot_every: int = 0):
    """Instrumented LIVE run of ``plan``'s schedule: a real ``LanePool``
    (kernels materialize, lanes solve) with the trace hook on, enrolled
    and stepped exactly as ``run_plan`` would — but WITHOUT the eval
    phase, which is outside the schedule trace. Returns ``(events,
    pool)``; the trace validates the simulator (and feeds
    :func:`oracle_from_trace`). ``snapshot_every`` > 0 wires a no-op
    snapshot consumer so checkpoint events fire without a checkpoint
    directory."""
    from repro.core import study

    plan = study.resolve_source_backend(plan)
    specs = study.plan_specs(plan)
    study._validate_plan(plan, specs)
    events: list = []
    pool = LanePool(plan.sources, plan.y, tol=plan.tol, wss=plan.wss,
                    chunk_iters=plan.chunk_iters,
                    lane_quantum=plan.lane_quantum, max_width=plan.max_width,
                    max_resident=plan.max_resident,
                    cache_bytes=plan.cache_bytes,
                    on_snapshot=(lambda p: None) if snapshot_every else None,
                    snapshot_every=max(int(snapshot_every), 1),
                    shrink_every=plan.shrink_every,
                    shrink_quantum=plan.shrink_quantum,
                    shrink_caps=plan.shrink_caps,
                    shrink_on_seed=plan.shrink_on_seed,
                    on_trace=events.append)
    study.enroll_plan_lanes(pool, plan, specs, {}, tenant=tenant)
    pool.run()
    return events, pool


@dataclasses.dataclass
class ScheduleAnalysis:
    """The simulator's answer: the full event trace plus time-resolved
    accounting no shape analysis can produce."""
    oracle: str                    # oracle label the replay used
    chunks: int                    # scheduling rounds
    lane_chunks: int               # lane-dispatches (fairness currency)
    dispatches: dict               # (program, kind, width, cap) -> count
    materializations: int
    evictions: int
    pinned_bytes: int
    peak_resident_bytes: int       # pinned + managed, max over time
    resident_watermarks: list      # per-chunk pinned + managed bytes
    checkpoints: int
    checkpoint_bytes: int          # summed snapshot_nbytes estimates
    tenant_lane_chunks: dict       # tenant repr -> lane-chunks
    n_iters: dict                  # lane id -> simulated final n_iter
    est_dispatch_s: float | None   # cost-model-weighted dispatch estimate
    truncated: bool                # hit max_chunks (under-reports only)
    events: list

    def summary_json(self) -> dict:
        """JSON-able summary WITHOUT the trace (findings and wire
        payloads carry this; the event list can be large)."""
        return {
            "oracle": self.oracle, "chunks": self.chunks,
            "lane_chunks": self.lane_chunks,
            "dispatches": sorted(
                [list(k) + [v] for k, v in self.dispatches.items()]),
            "materializations": self.materializations,
            "evictions": self.evictions,
            "pinned_bytes": self.pinned_bytes,
            "peak_resident_bytes": self.peak_resident_bytes,
            "checkpoints": self.checkpoints,
            "checkpoint_bytes": self.checkpoint_bytes,
            "tenant_lane_chunks": {str(k): v for k, v in
                                   self.tenant_lane_chunks.items()},
            "est_dispatch_s": self.est_dispatch_s,
            "truncated": self.truncated,
            "events": len(self.events)}


class _SimLane:
    """Abstract lane: lifecycle flags plus the iteration counter the
    oracle drives. ``(n, itemsize)`` sizes checkpoint estimates."""

    def __init__(self, id, source, *, tenant=None, dep=None, after=None,
                 held=False, max_iter: int = 10_000_000, n_iter0: int = 0,
                 n: int = 0, itemsize: int = 8):
        self.id = id
        self.source = source
        self.tenant = tenant
        self.dep = dep
        self.after = after
        self.held = held            # explicit start held by ``after``
        self.max_iter = int(max_iter)
        self.m = int(n_iter0)       # iterations so far
        self.n = int(n)
        self.itemsize = int(itemsize)
        self.served = 0
        self.admitted = False
        self.retired = False
        self.given = False
        self.target = None          # min(oracle target, max_iter)
        self.caps = None            # recorded per-dispatch cap sequence
        self.di = 0                 # dispatches so far (caps replay)


class _SimCache:
    """Abstract ``SourceCache``: pinned/managed split, LRU recency as
    list order, residency transitions through the SAME pure rules
    (``budget_fits`` / ``pick_victim``) as the live cache."""

    def __init__(self, sources: dict, nbytes: dict, *, max_resident: int,
                 cache_bytes: int, distance, sticky, on_evict, trace):
        self.pinned = {k for k, e in sources.items() if not is_factory(e)}
        self.nbytes = dict(nbytes)
        self.max_resident = int(max_resident)
        self.cache_bytes = int(cache_bytes)
        self._distance = distance
        self._sticky = sticky
        self._on_evict = on_evict
        self._trace = trace
        self.lru: list = []         # managed resident keys, LRU first
        self.materializations = 0
        self.evictions = 0
        self.pinned_bytes = sum(self.nbytes[k] for k in self.pinned)
        self.peak_bytes = self.pinned_bytes

    @property
    def budgeted(self) -> bool:
        return bool(self.max_resident or self.cache_bytes)

    def fits(self, count: int, nbytes: int) -> bool:
        return budget_fits(count, nbytes, max_resident=self.max_resident,
                           cache_bytes=self.cache_bytes)

    def resident(self, key) -> bool:
        return key in self.pinned or key in self.lru

    def is_pinned(self, key) -> bool:
        return key in self.pinned

    @property
    def managed_bytes(self) -> int:
        return sum(self.nbytes[k] for k in self.lru)

    def get(self, key) -> None:
        """Replay of ``SourceCache.get``: pinned short-circuits, a hit
        refreshes recency, a miss evicts per the budget then
        materializes."""
        if key in self.pinned:
            return
        if key in self.lru:
            self.lru.remove(key)
            self.lru.append(key)
            return
        incoming = self.nbytes[key]
        # the lru guard keeps a single over-budget kernel admissible —
        # the live cache's last-resort rule
        while self.lru and not self.fits(len(self.lru) + 1,
                                         self.managed_bytes + incoming):
            victim = pick_victim(self.lru, sticky=self._sticky(),
                                 distance=self._distance)
            self._on_evict(victim)
            self._trace("evict", victim, self.nbytes[victim])
            self.lru.remove(victim)
            self.evictions += 1
        self.lru.append(key)
        self.materializations += 1
        self._trace("materialize", key, incoming)
        self.peak_bytes = max(self.peak_bytes,
                              self.pinned_bytes + self.managed_bytes)


class _SimPool:
    """The abstract interpreter: ``LanePool.step()``'s control flow with
    every decision routed through the shared pure functions and every
    solve replaced by the oracle's iteration arithmetic."""

    def __init__(self, sources: dict, nbytes: dict, ys: dict,
                 lanes: list, *, chunk_iters: int, lane_quantum: int,
                 max_width: int, max_resident: int, cache_bytes: int,
                 shrink_every: int, oracle, snapshot_every: int = 0,
                 snapshots: bool = False):
        self.sources = dict(sources)
        self.kinds = {k: cost_model.source_kind(e)
                      for k, e in sources.items()}
        self.ys = dict(ys)
        self.lanes = {ln.id: ln for ln in lanes}
        self.order = [ln.id for ln in lanes]
        self.chunk_iters = int(chunk_iters)
        self.lane_quantum = int(lane_quantum)
        self.max_width = int(max_width)
        self.shrink_every = int(shrink_every)
        self.oracle = oracle
        self.snapshot_every = max(int(snapshot_every), 1)
        self.snapshots = bool(snapshots)
        self.events: list = []
        self.sticky = None
        self.chunk_count = 0
        self.tenant_served: dict = {}
        self.packed: dict = {}      # source key -> lane-id tuple
        self.dispatches: dict = {}
        self.lane_chunks = 0
        self.tenant_lane_chunks: dict = {}
        self.iter_weight: dict = {}   # (kind, width) -> lane-iterations
        self.checkpoints = 0
        self.checkpoint_bytes = 0
        self.watermarks: list = []
        self.cache = _SimCache(
            sources, nbytes, max_resident=max_resident,
            cache_bytes=cache_bytes, distance=self._distance,
            sticky=lambda: self.sticky, on_evict=self._on_evict,
            trace=self._trace)
        # registration-order events, exactly as enroll_plan_lanes emits
        for ln in lanes:
            if ln.given:
                self._trace("given", ln.id)
            elif ln.dep is None and not ln.held:
                ln.admitted = True
                self._trace("admit", ln.id, ln.source)

    def _trace(self, *event) -> None:
        self.events.append(tuple(event))

    def _distance(self, key) -> int:
        return sum(1 for ln in self.lanes.values()
                   if ln.source == key and not ln.retired)

    def _on_evict(self, key) -> None:
        self.packed.pop(key, None)

    # ---------------------------------------------------------- lifecycle

    def _admit(self) -> None:
        for lane_id in self.order:
            ln = self.lanes[lane_id]
            if ln.admitted or ln.retired:
                continue
            if ln.after is not None and not self.lanes[ln.after].retired:
                continue
            if ln.dep is None:          # explicit start held by ``after``
                ln.admitted = True
                self._trace("admit", ln.id, ln.source)
                continue
            if not self.lanes[ln.dep].retired:
                continue
            # the study's seed closure resolves the lane's own source at
            # admission (lazy K) — a cache transition in schedule order
            self.cache.get(ln.source)
            ln.admitted = True
            self._trace("admit", ln.id, ln.source)

    def _prepare(self, ln: _SimLane) -> None:
        if ln.target is None:
            ln.caps = self.oracle.cap_seq(ln.id)
            if ln.caps is not None:
                ln.caps = list(ln.caps)
            ln.target = self.oracle.target(ln.id, ln.max_iter)

    def _lane_cap(self, ln: _SimLane) -> int:
        """The lane's current shrink cap for grouping: the recorded
        sequence under exact replay, 0 (never shrunk) under bounds."""
        self._prepare(ln)
        if ln.caps is not None and ln.di < len(ln.caps):
            return ln.caps[ln.di]
        return 0

    def _retire(self, ln: _SimLane, n_iter: int) -> None:
        ln.retired = True
        ln.m = int(n_iter)
        self._trace("retire", ln.id, int(n_iter))

    def _advance(self, ln: _SimLane) -> bool:
        """One dispatch of one lane: ``engine._step`` arithmetic (done
        checked before the iterate). Returns True when the lane retires
        this chunk."""
        self._prepare(ln)
        if ln.caps is not None:
            # exact shrink replay: the recorded cap sequence IS the
            # dispatch schedule; attribute iterations uniformly across it
            # (the per-dispatch split is not recorded)
            if ln.di == 0 and len(ln.caps):
                ln._per = max(self.oracle.n_iters[ln.id], 0) / len(ln.caps)
            self._weigh(ln, getattr(ln, "_per", 0.0))
            ln.di += 1
            if ln.di >= len(ln.caps):
                self._retire(ln, self.oracle.n_iters[ln.id])
                return True
            return False
        if self.shrink_every:
            boundary = (ln.m // self.shrink_every + 1) * self.shrink_every
            tgt = min(ln.target, boundary, ln.max_iter)
        else:
            tgt = ln.target
        room = tgt - ln.m
        done = room < self.chunk_iters
        adv = min(self.chunk_iters, max(room, 0))
        ln.m += adv
        self._weigh(ln, adv)
        if done and ln.m >= ln.target:
            self._retire(ln, ln.m)
            return True
        return False

    def _weigh(self, ln: _SimLane, iters: float) -> None:
        key = (self.kinds[ln.source], self._width)
        self.iter_weight[key] = self.iter_weight.get(key, 0.0) + iters

    # ---------------------------------------------------------- scheduling

    def run(self, max_chunks: int) -> bool:
        """Drive to drain; returns True if truncated at ``max_chunks``."""
        while self.step():
            if self.chunk_count >= max_chunks:
                return True
        pending = [i for i in self.order if not self.lanes[i].retired]
        if pending:
            raise ValueError(
                f"simulated lanes {pending} wait on dependencies that "
                "never retire (missing or cyclic dep)")
        return False

    def step(self) -> bool:
        self._admit()
        live = [self.lanes[i] for i in self.order
                if self.lanes[i].admitted and not self.lanes[i].retired]
        if not live:
            return False
        selected = live
        if len(self.sources) > 1 and self.cache.budgeted:
            allowed = budget_sources(
                [ln.source for ln in live], budgeted=self.cache.budgeted,
                pinned=self.cache.is_pinned, resident=self.cache.resident,
                sticky=self.sticky, nbytes=self.cache.nbytes.__getitem__,
                fits=self.cache.fits)
            if len(allowed) < len({ln.source for ln in live}):
                selected = [ln for ln in live if ln.source in allowed]
        if self.max_width and len(selected) > self.max_width:
            selected = select_capped(
                selected, max_width=self.max_width, sticky=self.sticky,
                resident=self.cache.resident,
                served=lambda ln: ln.served,
                source=lambda ln: ln.source,
                tenant=lambda ln: ln.tenant,
                tenant_served=self.tenant_served)
        for ln in selected:
            ln.served += 1
            self.tenant_served[ln.tenant] = \
                self.tenant_served.get(ln.tenant, 0) + 1
        groups: dict = {}
        for ln in selected:
            gkey = (ln.source, self._lane_cap(ln)) if self.shrink_every \
                else ln.source
            groups.setdefault(gkey, []).append(ln)
        self.sticky = selected[0].source
        chunk = self.chunk_count
        for gkey, lanes in groups.items():
            width = (1 if len(lanes) == 1
                     else bucket_width(len(lanes), self.lane_quantum))
            if self.shrink_every:
                key, cap = gkey
            else:
                key, cap = gkey, 0
            self._trace("dispatch", chunk, key, cap, width,
                        tuple(ln.id for ln in lanes))
            program = "single" if width == 1 else "batched"
            bucket = (program, self.kinds[key], width, cap)
            self.dispatches[bucket] = self.dispatches.get(bucket, 0) + 1
            self.lane_chunks += len(lanes)
            for ln in lanes:
                t = repr(ln.tenant)
                self.tenant_lane_chunks[t] = \
                    self.tenant_lane_chunks.get(t, 0) + 1
            self._width = width
            if self.shrink_every:
                # _step_shrink: resolve FIRST, then the lifecycle
                self.cache.get(key)
                for ln in lanes:
                    self._advance(ln)
            elif len(lanes) == 1:
                ln = lanes[0]
                if ln.id in self.packed.get(key, ()):
                    self.packed.pop(key)            # writeback, no event
                self.cache.get(key)
                self._advance(ln)
            else:
                ids = tuple(ln.id for ln in lanes)
                if self.packed.get(key) != ids:
                    self.packed[key] = ids
                    self._trace("pack", key, ids)
                self.cache.get(key)
                done = [self._advance(ln) for ln in lanes]
                if any(done):
                    self.packed.pop(key, None)      # writeback, no event
        if any(ln.tenant is not None for ln in selected):
            shares: dict = {}
            for ln in selected:
                shares[ln.tenant] = shares.get(ln.tenant, 0) + 1
            self._trace("shares", chunk, tuple(sorted(
                (repr(t), c) for t, c in shares.items())))
        watermark = self.cache.pinned_bytes + self.cache.managed_bytes
        self.watermarks.append(watermark)
        self._trace("resident", chunk, watermark)
        self.chunk_count += 1
        if self.snapshots and self.chunk_count % self.snapshot_every == 0:
            ids = [i for i in self.order
                   if self.lanes[i].admitted or self.lanes[i].given]
            first = self.lanes[ids[0]]
            est = snapshot_nbytes(first.n, first.itemsize, len(ids),
                                  bool(self.shrink_every))
            self.checkpoints += 1
            self.checkpoint_bytes += est
            self._trace("checkpoint", chunk, tuple(ids), est)
        return True


def _estimate_dispatch_s(iter_weight: dict, backend: str | None) -> \
        float | None:
    """Cost-model-weighted dispatch estimate: sum over (kind, width) of
    lane-iterations x the measured ``us_per_lane_iter`` (nearest measured
    width when the exact one is absent). None when the model (or any
    needed kind) is unmeasured."""
    model = cost_model.load()
    if model is None:
        return None
    import jax
    per_backend = model.get("entries", {}).get(
        backend or jax.default_backend(), {})
    total_us = 0.0
    for (kind, width), iters in iter_weight.items():
        entry = per_backend.get(kind)
        upli = entry.get("us_per_lane_iter") if isinstance(entry, dict) \
            else None
        if not isinstance(upli, dict) or not upli:
            return None
        wkey = min(upli, key=lambda k: (abs(int(k) - width), int(k)))
        total_us += float(upli[wkey]) * iters
    return round(total_us / 1e6, 6)


def _merged_schedule(plans: list, backend: str | None):
    """One set of pool knobs for a multi-plan pool (the daemon normalizes
    every admitted plan to ITS schedule) — mismatches are an error, and
    ``max_width`` / ``shrink_every`` resolve exactly as the pool does."""
    knobs = [(p.wss, p.chunk_iters, p.lane_quantum, p.max_width,
              p.max_resident, p.cache_bytes, p.shrink_every,
              p.shrink_quantum, p.shrink_caps) for p in plans]
    if len(set(knobs)) > 1:
        raise ValueError("simulate_plans needs every plan to share the "
                         f"pool schedule knobs; got {sorted(set(knobs))}")
    return knobs[0]


def simulate_plans(entries: list, *, oracle, backend=None,
                   snapshot_every: int = 0,
                   max_chunks: int = DEFAULT_MAX_CHUNKS) -> ScheduleAnalysis:
    """Simulate ONE pool serving several (tenant, plan) submissions —
    the daemon's shape: sources merged (shared keys = the daemon's
    dedup), lanes enrolled per plan in submission order, the width
    budget fair-shared across tenants. ``entries`` is a list of
    ``(tenant, plan)``; all plans must share the pool schedule knobs.
    For a solo study, use :func:`simulate_plan`."""
    from repro.core import study

    plans = []
    for tenant, plan in entries:
        plan = study.resolve_source_backend(plan)
        study._validate_plan(plan, study.plan_specs(plan))
        plans.append((tenant, plan))
    (wss, chunk_iters, lane_quantum, max_width, max_resident, cache_bytes,
     shrink_every, shrink_quantum, shrink_caps) = \
        _merged_schedule([p for _, p in plans], backend)
    del wss, shrink_quantum, shrink_caps   # shape-only knobs: no events
    sources: dict = {}
    ys: dict = {}
    for _, plan in plans:
        for key, entry in plan.sources.items():
            if key not in sources:
                sources[key] = entry
                ys[key] = plan.y_of(key)
    kinds = {cost_model.source_kind(e) for e in sources.values()}
    if max_width is None:
        max_width = cost_model.pick_max_width(backend, kinds=kinds)
    if shrink_every == "auto":
        shrink_every = shrink_mod.DEFAULT_SHRINK_EVERY \
            if cost_model.pick_shrink(backend, kinds=kinds) else 0
    nbytes = {k: source_nbytes(e) for k, e in sources.items()}
    lanes: list = []
    for tenant, plan in plans:
        for spec in plan.lanes:
            if spec.result is not None:
                ln = _SimLane(spec.id, None, tenant=tenant)
                ln.given = ln.retired = True
                alpha = np.asarray(spec.result.alpha)
                ln.n, ln.itemsize = int(alpha.shape[0]), alpha.dtype.itemsize
                lanes.append(ln)
                continue
            key = plan.source_key_of(spec)
            lanes.append(_SimLane(
                spec.id, key, tenant=tenant, dep=spec.dep, after=spec.after,
                held=spec.alpha0 is not None and spec.after is not None,
                max_iter=spec.max_iter, n_iter0=spec.n_iter0,
                n=int(np.shape(ys[key])[0]),
                itemsize=np.dtype(sources[key].dtype).itemsize))
    pool = _SimPool(sources, nbytes, ys, lanes, chunk_iters=chunk_iters,
                    lane_quantum=lane_quantum, max_width=int(max_width),
                    max_resident=max_resident, cache_bytes=cache_bytes,
                    shrink_every=int(shrink_every), oracle=oracle,
                    snapshot_every=snapshot_every,
                    snapshots=snapshot_every > 0)
    truncated = pool.run(max_chunks)
    return ScheduleAnalysis(
        oracle=oracle.label, chunks=pool.chunk_count,
        lane_chunks=pool.lane_chunks, dispatches=dict(pool.dispatches),
        materializations=pool.cache.materializations,
        evictions=pool.cache.evictions,
        pinned_bytes=pool.cache.pinned_bytes,
        peak_resident_bytes=pool.cache.peak_bytes,
        resident_watermarks=pool.watermarks,
        checkpoints=pool.checkpoints,
        checkpoint_bytes=pool.checkpoint_bytes,
        tenant_lane_chunks=dict(pool.tenant_lane_chunks),
        n_iters={ln.id: ln.m for ln in lanes if ln.retired and not ln.given},
        est_dispatch_s=_estimate_dispatch_s(pool.iter_weight, backend),
        truncated=truncated, events=pool.events)


def simulate_plan(plan, *, oracle, backend=None, tenant=None,
                  snapshot_every: int = 0,
                  max_chunks: int = DEFAULT_MAX_CHUNKS) -> ScheduleAnalysis:
    """Simulate one ``Plan``'s schedule under ``oracle``. The trace is
    event-for-event what ``dry_run(plan)`` records when the oracle is
    exact (CI asserts this); bounding oracles bracket the unknown
    schedule instead."""
    return simulate_plans([(tenant, plan)], oracle=oracle, backend=backend,
                          snapshot_every=snapshot_every,
                          max_chunks=max_chunks)


def render_events(events, limit: int = 0) -> str:
    """Human-readable trace (``scripts/plan_explain.py`` and the CI
    smoke's diff artifact)."""
    lines = [repr(ev) for ev in events]
    if limit and len(lines) > limit:
        lines = lines[:limit] + [f"... ({len(events) - limit} more)"]
    return "\n".join(lines)
