"""Pre-execution analysis of a Study ``Plan``: what will this plan make
the machine do, and can it do it within the declared budget?

Grown out of ``study._validate_plan`` (which stays the hard entry gate —
malformed graphs raise there regardless of analysis mode), this module
answers the *feasibility and shape* questions validation doesn't:

* **compile-shape enumeration** — the distinct jitted programs the
  schedule can produce. Per source, the peak concurrent lane count is the
  maximum antichain of the dep/after DAG (Dilworth via bipartite matching
  on the reachability relation — any antichain can be simultaneously
  live under some retirement schedule, and no comparable pair can);
  ``scheduler.possible_widths`` maps that peak through the pool's width
  buckets and ``max_width`` cap, and each (program kind, width, cap, n,
  dtype, wss) tuple is one jit cache entry — deduplicated globally,
  because the jit cache is global (same-shaped sources share compiles;
  this is why ``occupancy["programs"]`` overcounts). Shrink-enabled plans
  (``plan.shrink_every``) additionally enumerate ``shrink.possible_caps``
  compact capacities per width — a shrunk lane runs the same chunk
  programs at its cap's shape, so every (width, cap) pair is one more
  potential compile; ``cap == n`` marks the unshrunk program. CAN-PRODUCE
  semantics as for widths: a run realizes a cap program only if some lane
  actually shrinks into that bucket (plans needing exact counts declare
  ``shrink_caps``). Known aliasing limit: a compact program at cap c and
  an unshrunk program over a DIFFERENT source with n == c share one jit
  entry — the enumeration counts them separately, mirroring the
  same-shape overcount already documented for widths.
  ``recompile-storm`` warns when the count exceeds the threshold.
* **SourceCache feasibility** — the budget contract: pinned (dense)
  sources are always resident and every managed source must fit on top
  of them (``cache_bytes``); a plan whose largest declared source cannot
  be admitted within the declared budget is rejected (the runtime cache
  would run it anyway via the last-resort guard, but a daemon admitting
  third-party plans must hold the declared budget to its word).
  Row-streaming (pallas) sources cost X bytes, dense kinds n² bytes —
  both read from the spec without materializing.
* **checkpoint step-key audit** — study records must live at
  ``base_step >= STUDY_BASE`` (2e12): the mid-fold range is < 1e12 and
  the batch range is [1e12, 2e12), so a lower base silently interleaves
  record kinds in a shared checkpoint directory.
* **dead lanes** — lanes whose result nothing consumes (no eval, no
  dependent lane): advisory, they often indicate a mis-keyed EvalSpec.

``analyze_plan`` returns a :class:`PlanAnalysis` (advisory);
``check_plan`` is the strict entry — it raises on any error-severity
finding and is what the ROADMAP's study-service daemon should call at
admission time.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.analysis.findings import Report
from repro.svm import cost_model
from repro.svm import shrink as shrink_mod
from repro.svm.scheduler import possible_widths
from repro.svm.sources import _source_nbytes, is_factory

#: distinct-program warning threshold: beyond this, first-chunk latency is
#: dominated by retraces (each program is one XLA compile)
STORM_THRESHOLD = 8

#: antichain computation cap: above this many lanes per source the peak
#: falls back to the lane count (an upper bound) — noted in the analysis
ANTICHAIN_LIMIT = 512

#: max-bound simulation horizon, in chunks per lane: admission must stay
#: cheap, and residency/eviction behaviour is periodic well before this
SIM_HORIZON_CHUNKS = 8

#: eviction-thrash warning: more than this many evictions per managed
#: source over the max-bound schedule means kernels re-materialize
#: repeatedly instead of draining
THRASH_FACTOR = 2


class PlanRejected(ValueError):
    """``check_plan``'s strict rejection. A ``ValueError`` (existing
    callers' except clauses keep working) that carries the full
    :class:`PlanAnalysis`, so an admission gate — the study daemon — can
    put the structured findings on the wire instead of re-parsing the
    rendered message."""

    def __init__(self, message: str, analysis: "PlanAnalysis"):
        super().__init__(message)
        self.analysis = analysis


@dataclasses.dataclass
class PlanAnalysis:
    """The analyzer's answer: distinct program shapes, per-source width
    profile, budget accounting, schedule-simulation summaries (when the
    simulator ran), and the findings report."""
    programs: list[tuple]      # sorted distinct (program, kind, w, cap, n, dtype, wss)
    program_count: int
    per_source: dict           # key -> {kind, n, dtype, peak_width, widths, caps}
    max_width: int             # effective cap the enumeration used
    pinned_bytes: int
    peak_managed_bytes: int    # largest single managed source
    report: Report
    #: ``{"min": ..., "max": ...}`` ScheduleAnalysis.summary_json() dicts
    #: from the bounding simulations (None when ``simulate="off"``)
    sim: dict | None = None

    @property
    def ok(self) -> bool:
        return not self.report.errors

    def to_json(self) -> dict:
        return {"programs": [list(p) for p in self.programs],
                "program_count": self.program_count,
                "per_source": {str(k): v for k, v in
                               self.per_source.items()},
                "max_width": self.max_width,
                "pinned_bytes": self.pinned_bytes,
                "peak_managed_bytes": self.peak_managed_bytes,
                "sim": self.sim,
                "findings": self.report.to_json()["findings"]}


def _max_antichain(nodes: list, prereqs: dict) -> int:
    """Maximum antichain of the DAG over ``nodes`` (``prereqs[v]`` = ids
    v waits on), restricted to ``nodes`` but ordered through the full
    graph: Dilworth — |S| minus a maximum matching on the reachability
    relation, reachability as bitmasks over a topological order."""
    order = _topo(prereqs)
    idx = {v: i for i, v in enumerate(order)}
    reach = [0] * len(order)            # bitmask of ancestors (prereqs*)
    for v in order:
        m = 0
        for p in prereqs.get(v, ()):
            if p in idx:
                m |= reach[idx[p]] | (1 << idx[p])
        reach[idx[v]] = m
    sel = [v for v in nodes if v in idx]
    sel_bit = {v: 1 << idx[v] for v in sel}
    # comparable pairs within the selection: u < v iff u in ancestors(v)
    adj = {v: [u for u in sel
               if u is not v and reach[idx[v]] & sel_bit[u]]
           for v in sel}
    match_l: dict = {}
    match_r: dict = {}
    for v in sel:                        # greedy init (chains match fast)
        for u in adj[v]:
            if u not in match_r:
                match_l[v], match_r[u] = u, v
                break

    def augment(v, seen):
        for u in adj[v]:
            if u in seen:
                continue
            seen.add(u)
            if u not in match_r or augment(match_r[u], seen):
                match_l[v], match_r[u] = u, v
                return True
        return False

    for v in sel:
        if v not in match_l:
            augment(v, set())
    return len(sel) - len(match_l)


def _topo(prereqs: dict) -> list:
    seen: dict = {}
    out: list = []
    for root in prereqs:
        stack = [(root, iter(prereqs.get(root, ())))]
        if root in seen:
            continue
        seen[root] = True
        while stack:
            node, it = stack[-1]
            advanced = False
            for p in it:
                if p in prereqs and p not in seen:
                    seen[p] = True
                    stack.append((p, iter(prereqs.get(p, ()))))
                    advanced = True
                    break
            if not advanced:
                out.append(node)
                stack.pop()
    return out


def analyze_plan(plan, *, checkpoint=None, backend=None,
                 storm_threshold: int = STORM_THRESHOLD,
                 context: str = "", simulate: str = "off",
                 sim_horizon: int | None = None) -> PlanAnalysis:
    """Build the pre-execution report for ``plan``. Never raises on plan
    content — structural problems (the ``_validate_plan`` surface) come
    back as ``invalid-plan`` error findings, so a daemon can report them
    instead of crashing on them. Pure inspection: no kernel materializes,
    no program compiles.

    ``context`` names the submission the findings belong to (the daemon
    threads ``tenant/plan_id`` here), so multi-tenant rejection logs name
    the offending plan; it never enters finding identity.

    ``simulate="bounds"`` additionally replays the schedule through the
    static simulator (``repro.analysis.plan_sim``) under the min/max
    bounding oracles — ``sim_horizon`` iterations per lane for the max
    bound (default ``SIM_HORIZON_CHUNKS * chunk_iters``) — attaching the
    summaries as ``PlanAnalysis.sim`` and the TIME-RESOLVED findings:
    ``cache-infeasible-time`` when the peak co-resident bytes (pinned +
    managed, over the simulated schedule) exceed ``cache_bytes`` (an
    error when even the min schedule exceeds — no convergence pattern
    stays within the declared budget — a warning when only the max
    does), and ``eviction-thrash`` when the max schedule re-materializes
    kernels far beyond the source count. This is what catches the plan
    the worst-single-source rule admits: each source fits alone, but the
    schedule holds several at once."""
    from repro.core import study   # deferred: study imports this lazily

    report = Report()
    try:
        plan = study.resolve_source_backend(plan)
        specs = {}
        for spec in plan.lanes:
            if spec.id in specs:
                raise ValueError(f"duplicate lane id {spec.id!r}")
            specs[spec.id] = spec
        study._validate_plan(plan, specs)
    except ValueError as e:
        report.add("invalid-plan", "<plan>", "plan", str(e),
                   context=context)
        return PlanAnalysis(programs=[], program_count=0, per_source={},
                            max_width=0, pinned_bytes=0,
                            peak_managed_bytes=0, report=report)

    kinds = {cost_model.source_kind(s) for s in plan.sources.values()}
    max_width = plan.max_width if plan.max_width is not None \
        else cost_model.pick_max_width(backend, kinds=kinds)
    # resolve the shrink knob EXACTLY as the pool does ("auto" goes through
    # the same cost-model verdict), so prediction tracks execution
    shrink_every = getattr(plan, "shrink_every", 0)
    if shrink_every == "auto":
        shrink_every = shrink_mod.DEFAULT_SHRINK_EVERY \
            if cost_model.pick_shrink(backend, kinds=kinds) else 0
    shrink_every = int(shrink_every)

    # ---- compile-shape enumeration --------------------------------------
    solved = [s for s in plan.lanes if s.result is None]
    prereqs = {s.id: [t for t in (s.dep, s.after)
                      if t is not None and specs[t].result is None]
               for s in solved}
    per_source: dict = {}
    programs: set = set()
    for key, entry in plan.sources.items():
        lanes = [s.id for s in solved if plan.source_key_of(s) == key]
        if not lanes:
            continue
        n = int(np.shape(plan.y_of(key))[0])
        dtype = str(getattr(entry, "dtype", "?"))
        kind = cost_model.source_kind(entry)
        if len(lanes) > ANTICHAIN_LIMIT:
            peak, exact = len(lanes), False
        else:
            peak, exact = _max_antichain(lanes, prereqs), True
        widths = possible_widths(peak, plan.lane_quantum, max_width)
        caps = shrink_mod.possible_caps(
            n, getattr(plan, "shrink_quantum", 128),
            getattr(plan, "shrink_caps", None)) if shrink_every else ()
        for w in widths:
            program = "single" if w == 1 else "batched"
            # cap == n marks the unshrunk program; each smaller cap is the
            # same chunk program traced at the compact shape
            programs.add((program, kind, w, n, n, dtype, plan.wss))
            for c in caps:
                programs.add((program, kind, w, int(c), n, dtype, plan.wss))
        per_source[key] = {"kind": kind, "n": n, "dtype": dtype,
                           "lanes": len(lanes), "peak_width": peak,
                           "peak_exact": exact, "widths": list(widths),
                           "caps": [int(c) for c in caps]}

    if len(programs) > storm_threshold:
        report.add("recompile-storm", "<plan>", "programs",
                   f"schedule can produce {len(programs)} distinct jitted "
                   f"programs (> {storm_threshold}): raise lane_quantum "
                   "or cap max_width to bound first-chunk retraces",
                   severity="warn", context=context)

    # ---- SourceCache budget feasibility ---------------------------------
    pinned_bytes = sum(_source_nbytes(s) for s in plan.sources.values()
                      if not is_factory(s))
    managed = {k: _source_nbytes(s) for k, s in plan.sources.items()
               if is_factory(s)}
    peak_managed = max(managed.values(), default=0)
    if plan.cache_bytes and managed:
        worst = max(managed, key=managed.get)
        if pinned_bytes + managed[worst] > plan.cache_bytes:
            report.add(
                "cache-infeasible", "<plan>", repr(worst),
                f"source {worst!r} needs {managed[worst]} bytes on top of "
                f"{pinned_bytes} pinned bytes, exceeding the declared "
                f"cache_bytes={plan.cache_bytes} budget — no eviction "
                "schedule can admit it within the plan's own contract",
                context=context)
    if plan.max_resident < 0 or plan.cache_bytes < 0:
        report.add("cache-infeasible", "<plan>", "budget",
                   "negative residency budget", context=context)

    # ---- schedule simulation (time-resolved budget findings) -------------
    sim = None
    if simulate not in ("off", "bounds"):
        raise ValueError(f"unknown simulate mode {simulate!r} "
                         "(have 'off', 'bounds')")
    if simulate == "bounds" and not report.errors:
        from repro.analysis import plan_sim
        horizon = int(sim_horizon) if sim_horizon \
            else SIM_HORIZON_CHUNKS * int(plan.chunk_iters)
        try:
            lo = plan_sim.simulate_plan(
                plan, oracle=plan_sim.BoundOracle("min"), backend=backend)
            hi = plan_sim.simulate_plan(
                plan, oracle=plan_sim.BoundOracle("max", horizon=horizon),
                backend=backend)
        except Exception as e:   # admission must degrade, not crash
            report.add("sim-error", "<plan>", "schedule",
                       f"schedule simulation failed: {e}", severity="warn",
                       context=context)
        else:
            sim = {"min": lo.summary_json(), "max": hi.summary_json()}
            if plan.cache_bytes:
                for sa, severity in ((lo, "error"), (hi, "warn")):
                    if sa.peak_resident_bytes > plan.cache_bytes:
                        report.add(
                            "cache-infeasible-time", "<plan>", "schedule",
                            f"simulated schedule ({sa.oracle} oracle) "
                            f"co-holds {sa.peak_resident_bytes} resident "
                            f"bytes (pinned + managed), exceeding the "
                            f"declared cache_bytes={plan.cache_bytes} "
                            "budget — every source fits alone, but the "
                            "schedule the pool will execute does not",
                            severity=severity, context=context)
                        break
            if managed and hi.evictions > THRASH_FACTOR * len(managed):
                report.add(
                    "eviction-thrash", "<plan>", "schedule",
                    f"max-bound schedule evicts {hi.evictions} times for "
                    f"{len(managed)} managed sources — kernels "
                    "re-materialize instead of draining; raise the "
                    "residency budget or narrow max_width",
                    severity="warn", context=context)

    # ---- checkpoint step-key ranges -------------------------------------
    if checkpoint is not None:
        base = int(getattr(checkpoint, "base_step", study.STUDY_BASE))
        if base < study.STUDY_BASE:
            zone = "mid-fold (< 1e12)" if base < 1_000_000 ** 2 \
                else "batch ([1e12, 2e12))"
            report.add(
                "checkpoint-key-collision", "<plan>", "base_step",
                f"study base_step {base} lands in the {zone} record range; "
                f"study records must start at STUDY_BASE "
                f"({study.STUDY_BASE}) to share a checkpoint directory "
                "with fold and batch records", context=context)

    # ---- dead lanes ------------------------------------------------------
    consumed = {ev.lane for ev in plan.evals}
    consumed |= {t for s in plan.lanes for t in (s.dep, s.after)
                 if t is not None}
    for spec in plan.lanes:
        if spec.id not in consumed:
            what = "given result" if spec.result is not None else "result"
            report.add("lane-unobserved", "<plan>", repr(spec.id),
                       f"lane {spec.id!r}: {what} is never evaluated and "
                       "no lane depends on it (mis-keyed EvalSpec, or "
                       "consumed only via on_result/StudyResult)",
                       severity="warn", context=context)

    return PlanAnalysis(programs=sorted(programs),
                        program_count=len(programs),
                        per_source=per_source, max_width=max_width,
                        pinned_bytes=int(pinned_bytes),
                        peak_managed_bytes=int(peak_managed),
                        report=report, sim=sim)


def check_plan(plan, *, checkpoint=None, backend=None,
               context: str = "", simulate: str = "bounds",
               sim_horizon: int | None = None) -> PlanAnalysis:
    """Strict-mode analysis: raise :class:`PlanRejected` (a
    ``ValueError`` carrying the analysis) on any error-severity finding —
    the admission gate the study daemon calls verbatim; returns the
    analysis otherwise. Strict mode runs the schedule simulator by
    default (``simulate="bounds"``): admission holds the plan to the
    TIME-RESOLVED budget, not just the worst single source."""
    pa = analyze_plan(plan, checkpoint=checkpoint, backend=backend,
                      context=context, simulate=simulate,
                      sim_horizon=sim_horizon)
    if pa.report.errors:
        raise PlanRejected(
            "plan rejected by static analysis:\n"
            + "\n".join(f.render() for f in pa.report.errors), pa)
    return pa
