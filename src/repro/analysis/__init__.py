"""Static analysis: pre-execution plan reports and JAX/Pallas lint passes.

The paper's premise is that CV work can be *planned* — the Study API makes
the reuse graph explicit data, and this package analyzes that data (plus
the source tree that executes it) before anything runs:

* :mod:`repro.analysis.plan_check` — pre-execution report on a ``Plan``:
  distinct jitted program shapes the schedule can produce (recompile-storm
  warning), SourceCache budget feasibility, checkpoint step-key ranges,
  dead lanes. ``run_plan`` runs it in advisory mode by default; the study
  daemon's admission path is the strict-mode consumer, which also replays
  the schedule through the simulator (time-resolved budget findings).
* :mod:`repro.analysis.plan_sim` — the static schedule simulator: an
  abstract interpreter of the ``LanePool`` loop that replays a plan (or a
  merged multi-tenant pool) without kernels or solves, emitting the same
  typed event trace as the instrumented live pool — trace-validated in CI
  (``scripts/ci_plan_sim_smoke.py``; DESIGN.md §Schedule simulator).
* :mod:`repro.analysis.jit_lint` — AST lint for trace-purity and timer
  hazards over ``src/repro/{svm,core,kernels}``.
* :mod:`repro.analysis.kernel_lint` — static checks on Pallas launch
  configs in ``kernels/``.
* :mod:`repro.analysis.findings` — the shared ``Finding``/``Report``
  structure all three emit, with the committed-baseline workflow
  (``results/lint_baseline.json``) that lets CI gate on NEW findings only.
* :mod:`repro.analysis.imports` — the intra-package import graph the lint
  scope is derived from (unimported seed scaffolding is excluded; see
  DESIGN.md §Static analysis).

``scripts/repro_lint.py`` is the CLI entry point; DESIGN.md §Static
analysis documents the finding taxonomy and baseline workflow.
"""
from repro.analysis.findings import Finding, Report  # noqa: F401
