"""Static checks on Pallas launch configurations in ``kernels/``.

A *launcher* is any top-level function whose body calls
``pl.pallas_call``; a *kernel body* is any function whose parameters are
``*_ref`` names. Four rules (DESIGN.md §Static analysis):

* ``auto-interpret-contract`` — launchers must default ``interpret=None``
  and resolve it through :func:`repro.kernels.rbf.auto_interpret`
  (interpret on CPU only). A hard-coded ``interpret=True`` default runs
  the Python body on every backend; ``False`` breaks the CPU validation
  path.
* ``block-divisibility`` — every block size used as a grid divisor
  (``N // bm`` inside the ``grid=`` expression) needs a matching ragged-
  tail pad ``(-n) % bm`` in the launcher body; without it a non-multiple
  shape either fails to launch or silently drops the tail.
* ``vmem-footprint`` — the per-block VMEM estimate (block shapes of
  in/out specs + scratch shapes, at the launcher's literal block-size
  defaults, 8 bytes/element worst case, symbolic dims assumed
  ``SYMBOLIC_DIM``) must stay under ``VMEM_LIMIT_BYTES``. Launchers whose
  defaults are full-array (``None``) are skipped — their footprint is
  input-dependent by design.
* ``acc-dtype-promotion`` — a VMEM scratch accumulator must either use
  the f64-conditional idiom (``jnp.float64 if <input is f64> else
  jnp.float32`` — the §Pallas sources rule: accumulate in f64 iff the
  input is f64, else f32) or be baselined with a justification (e.g.
  flash attention's by-design f32 online softmax). Kernel-body dots must
  pass ``preferred_element_type`` so the MXU accumulates in the scratch
  dtype rather than the input dtype.
"""
from __future__ import annotations

import ast
import math
import pathlib

from repro.analysis.findings import Report

VMEM_LIMIT_BYTES = 16 * 1024 * 1024
#: assumed extent of a block dim the lint cannot resolve to a literal
#: (e.g. a model dim ``D`` flowing through a BlockSpec): generous enough
#: to catch real blowups, small enough not to cry wolf
SYMBOLIC_DIM = 512
WORST_CASE_ITEMSIZE = 8   # f64 interpret mode


def _call_name(node: ast.Call):
    fn = node.func
    if isinstance(fn, ast.Name):
        return fn.id
    if isinstance(fn, ast.Attribute):
        return fn.attr
    return None


def _find_calls(node: ast.AST, name: str):
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call) and _call_name(sub) == name:
            yield sub


def _literal_defaults(fn: ast.FunctionDef) -> dict[str, int | None]:
    """{param: literal int default} over positional + kw-only params
    (None default recorded as None)."""
    out: dict[str, int | None] = {}
    args = fn.args
    pos = args.posonlyargs + args.args
    for arg, default in zip(pos[len(pos) - len(args.defaults):],
                            args.defaults):
        if isinstance(default, ast.Constant):
            out[arg.arg] = default.value
    for arg, default in zip(args.kwonlyargs, args.kw_defaults):
        if isinstance(default, ast.Constant):
            out[arg.arg] = default.value
    return out


def _dim_extent(node: ast.expr, env: dict) -> int:
    """Best-effort extent of one block-shape dim: literal ints, names
    bound to literal defaults, else ``SYMBOLIC_DIM``."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return node.value
    if isinstance(node, ast.Name):
        v = env.get(node.id)
        if isinstance(v, int):
            return v
    return SYMBOLIC_DIM


def _block_shapes(call: ast.Call, env: dict):
    """Extents of every BlockSpec block shape and scratch shape of one
    ``pallas_call``; yields (what, [dims]) with unresolved dims at
    ``SYMBOLIC_DIM``. A None entry means full-array blocks (skipped)."""
    kwargs = {kw.arg: kw.value for kw in call.keywords}
    for field in ("in_specs", "out_specs"):
        spec = kwargs.get(field)
        if spec is None:
            continue
        specs = spec.elts if isinstance(spec, (ast.Tuple, ast.List)) \
            else [spec]
        for s in specs:
            if not isinstance(s, ast.Call):
                continue
            shape = s.args[0] if s.args else None
            for kw in s.keywords:
                if kw.arg == "block_shape":
                    shape = kw.value
            if isinstance(shape, (ast.Tuple, ast.List)):
                yield field, [_dim_extent(d, env) for d in shape.elts]
    scratch = kwargs.get("scratch_shapes")
    if scratch is not None and isinstance(scratch, (ast.Tuple, ast.List)):
        for s in scratch.elts:
            if isinstance(s, ast.Call) and s.args and \
                    isinstance(s.args[0], (ast.Tuple, ast.List)):
                yield "scratch", [_dim_extent(d, env)
                                  for d in s.args[0].elts]


def _grid_divisors(call: ast.Call) -> set[str]:
    """Names used as ``X // name`` divisors in the grid expression, plus
    names bound earlier like ``n_k_steps = D // bk`` are resolved by the
    caller."""
    kwargs = {kw.arg: kw.value for kw in call.keywords}
    grid = kwargs.get("grid")
    names: set[str] = set()
    if grid is None:
        return names
    for node in ast.walk(grid):
        if isinstance(node, ast.BinOp) and \
                isinstance(node.op, ast.FloorDiv) and \
                isinstance(node.right, ast.Name):
            names.add(node.right.id)
    return names


def _floordiv_bindings(fn: ast.FunctionDef) -> dict[str, set[str]]:
    """{assigned name: divisor names} for ``x = <expr> // name``
    assignments — grid entries are often precomputed this way."""
    out: dict[str, set[str]] = {}
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 and \
                isinstance(node.targets[0], ast.Name):
            divisors = {sub.right.id for sub in ast.walk(node.value)
                        if isinstance(sub, ast.BinOp)
                        and isinstance(sub.op, ast.FloorDiv)
                        and isinstance(sub.right, ast.Name)}
            if divisors:
                out[node.targets[0].id] = divisors
    return out


def _pad_guards(fn: ast.FunctionDef) -> set[str]:
    """Block-size names appearing in a ragged-tail pad ``(-x) % b``."""
    guards: set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Mod) \
                and isinstance(node.right, ast.Name) and \
                isinstance(node.left, ast.UnaryOp) and \
                isinstance(node.left.op, ast.USub):
            guards.add(node.right.id)
    return guards


def _grid_names(fn: ast.FunctionDef, call: ast.Call) -> set[str]:
    """All block-size names the grid divides by, following one level of
    ``x = ... // b`` indirection."""
    bindings = _floordiv_bindings(fn)
    names = set(_grid_divisors(call))
    kwargs = {kw.arg: kw.value for kw in call.keywords}
    grid = kwargs.get("grid")
    if grid is not None:
        for node in ast.walk(grid):
            if isinstance(node, ast.Name) and node.id in bindings:
                names |= bindings[node.id]
    return names


def _has_f64_conditional(fn: ast.FunctionDef) -> bool:
    """The §Pallas sources accumulator idiom:
    ``jnp.float64 if <...> else jnp.float32`` (either order)."""
    for node in ast.walk(fn):
        if isinstance(node, ast.IfExp):
            names = {getattr(n, "attr", None) for n in ast.walk(node)}
            if {"float64", "float32"} <= names:
                return True
    return False


def _scratch_dtypes(call: ast.Call):
    """dtype expression of each VMEM scratch allocation."""
    kwargs = {kw.arg: kw.value for kw in call.keywords}
    scratch = kwargs.get("scratch_shapes")
    if scratch is None or not isinstance(scratch, (ast.Tuple, ast.List)):
        return
    for s in scratch.elts:
        if isinstance(s, ast.Call) and len(s.args) >= 2:
            yield s.args[1]


def lint_paths(paths, *, repo_root=None) -> Report:
    report = Report()
    repo_root = pathlib.Path(repo_root) if repo_root else None
    for p in paths:
        p = pathlib.Path(p)
        rel = str(p.relative_to(repo_root)) if repo_root and \
            p.is_relative_to(repo_root) else str(p)
        tree = ast.parse(p.read_text(), filename=str(p))
        for node in tree.body:
            if isinstance(node, ast.FunctionDef):
                _lint_launcher(node, rel, report)
                _lint_kernel_body(node, rel, report)
    return report


def _lint_launcher(fn: ast.FunctionDef, rel: str, report: Report) -> None:
    calls = list(_find_calls(fn, "pallas_call"))
    if not calls:
        return
    env = _literal_defaults(fn)

    # --- auto_interpret(None) default contract
    interp_default = env.get("interpret", "absent") \
        if "interpret" in _param_names(fn) else "missing"
    resolves = any(True for _ in _find_calls(fn, "auto_interpret"))
    if interp_default == "missing":
        report.add("auto-interpret-contract", rel, fn.name,
                   "pallas launcher has no `interpret` parameter — CPU "
                   "callers cannot validate it", line=fn.lineno)
    elif interp_default is not None or not resolves:
        report.add("auto-interpret-contract", rel, fn.name,
                   f"`interpret` must default to None and resolve via "
                   f"auto_interpret() (got default={interp_default!r}, "
                   f"auto_interpret called={resolves}) — the contract is "
                   "interpret-on-CPU-only", line=fn.lineno)

    pads = _pad_guards(fn)
    for call in calls:
        # --- block divisibility vs ragged tails
        for name in sorted(_grid_names(fn, call)):
            if name not in pads:
                report.add("block-divisibility", rel, fn.name,
                           f"grid divides by block size `{name}` with no "
                           f"`(-dim) % {name}` ragged-tail pad — "
                           "non-multiple shapes fail or truncate",
                           line=call.lineno)
        # --- per-block VMEM footprint at the literal defaults
        if any(env.get(k) is None for k in ("bm", "bk", "bn")
               if k in _param_names(fn)):
            continue   # full-array defaults: footprint is input-sized
        total = sum(math.prod(dims) * WORST_CASE_ITEMSIZE
                    for _, dims in _block_shapes(call, env))
        if total > VMEM_LIMIT_BYTES:
            report.add("vmem-footprint", rel, fn.name,
                       f"per-block VMEM estimate {total / 2**20:.1f} MiB "
                       f"exceeds the {VMEM_LIMIT_BYTES // 2**20} MiB "
                       "budget at the default block sizes",
                       line=call.lineno)
        # --- accumulator dtype promotion
        for dtype_expr in _scratch_dtypes(call):
            names = {getattr(n, "attr", getattr(n, "id", None))
                     for n in ast.walk(dtype_expr)}
            if "acc_dtype" in names or _has_f64_conditional(fn):
                continue
            report.add("acc-dtype-promotion", rel, fn.name,
                       "VMEM scratch dtype is fixed rather than the "
                       "f64-iff-input-f64 conditional (`acc_dtype`) — "
                       "f64 inputs would silently accumulate at lower "
                       "precision", severity="warn", line=call.lineno)
            break


def _param_names(fn: ast.FunctionDef) -> set[str]:
    args = fn.args
    return {a.arg for a in args.posonlyargs + args.args + args.kwonlyargs}


def _lint_kernel_body(fn: ast.FunctionDef, rel: str,
                      report: Report) -> None:
    params = [a.arg for a in fn.args.args]
    if not params or not any(p.endswith("_ref") for p in params):
        return
    for node in ast.walk(fn):
        if isinstance(node, ast.Call) and \
                _call_name(node) in ("dot", "dot_general", "matmul"):
            if not any(kw.arg == "preferred_element_type"
                       for kw in node.keywords):
                report.add("acc-dtype-promotion", rel, fn.name,
                           f"kernel-body `{_call_name(node)}` without "
                           "preferred_element_type accumulates in the "
                           "input dtype on the MXU", line=node.lineno)
