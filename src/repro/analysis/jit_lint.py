"""AST lint: trace-purity and timer-attribution hazards in JAX code.

Four rules, each a bug class this repo has actually shipped (PRs 2-6
fixed instances of the first three; the timer convention is DESIGN.md's
attribution contract):

* ``unsized-nonzero`` — ``jnp.nonzero(x)`` without ``size=`` has a
  data-dependent output shape: it retraces (or fails) under jit and
  silently varies under vmap. ``seeding.py``'s capped extraction passes
  ``size=m_cap`` for exactly this reason.
* ``traced-host-cast`` — ``.item()`` / ``float()`` / ``int()`` /
  ``bool()`` on a traced value inside a jitted body is a concretization
  error at trace time.
* ``traced-python-branch`` — Python ``if``/``while`` on a traced value
  inside a jitted body (use ``lax.cond``/``jnp.where``; ``is None``
  tests and shape/static-attribute tests are fine and not flagged).
* ``timer-no-sync`` — a ``time.perf_counter()`` section whose timed span
  contains no device sync measures dispatch, not compute, under JAX's
  async execution. Syncs are recognized lexically (``block_until_ready``,
  ``jax.device_get``, ``np.asarray``/``np.array``, builtin
  ``bool``/``int``/``float`` coercions) and *propagated through the call
  graph*: a call to a function that itself syncs (resolvable top-level
  functions, ``from repro.x import name`` imports, and ``self.`` methods)
  satisfies the span — ``cv.py`` timing ``run_plan`` and the scheduler
  timing ``self._step_batched`` are synced by their callees, while a call
  through an unresolvable receiver (a parameter's method) is not assumed
  to sync.

Taint model for the jitted-body rules: non-static parameters are traced
(``static_argnames`` of the ``jax.jit``/``functools.partial(jax.jit)``
decorator are not), taint flows through assignments, and a small
attribute allowlist (``shape``/``ndim``/``dtype``/``size``/``nbytes``/
``itemsize`` plus the kernel-source protocol's static metadata
``streams_rows``/``fused``/``n_rows``) plus ``len()`` un-taints — those
are Python-level values under jit. Nested functions inherit the
enclosing taint (they close over traced values and are typically
``vmap``/``cond`` bodies).
"""
from __future__ import annotations

import ast
import pathlib

from repro.analysis.findings import Report

#: attribute reads that yield Python-level (untraced) values
STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "nbytes", "itemsize",
                "streams_rows", "fused", "n_rows", "at"}

#: calls that force (or imply) a device sync when they appear in a timed
#: span: blocking transfers and host coercions of device values
_SYNC_CALL_NAMES = {"bool", "int", "float"}
_SYNC_ATTR_CALLS = {"block_until_ready", "device_get", "asarray", "array",
                    "item"}


def _call_name(node: ast.Call):
    """(kind, name) of a call target: ("name", f) for ``f(...)``,
    ("attr", m) for ``<expr>.m(...)`` plus the receiver expression."""
    fn = node.func
    if isinstance(fn, ast.Name):
        return "name", fn.id, None
    if isinstance(fn, ast.Attribute):
        return "attr", fn.attr, fn.value
    return "other", None, None


def _is_jit_decorator(dec: ast.expr) -> tuple[bool, set[str]]:
    """Recognize ``@jax.jit``, ``@jit``, and
    ``@functools.partial(jax.jit, static_argnames=(...))`` (also bare
    ``partial``); returns (is_jitted, static_argnames)."""
    if isinstance(dec, ast.Attribute) and dec.attr == "jit":
        return True, set()
    if isinstance(dec, ast.Name) and dec.id == "jit":
        return True, set()
    if isinstance(dec, ast.Call):
        kind, name, _ = _call_name(dec)
        if name == "jit":
            return True, _static_argnames(dec)
        if name == "partial" and dec.args:
            inner = dec.args[0]
            if (isinstance(inner, ast.Attribute) and inner.attr == "jit") \
                    or (isinstance(inner, ast.Name) and inner.id == "jit"):
                return True, _static_argnames(dec)
    return False, set()


def _static_argnames(call: ast.Call) -> set[str]:
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            names = set()
            for elt in ast.walk(kw.value):
                if isinstance(elt, ast.Constant) and \
                        isinstance(elt.value, str):
                    names.add(elt.value)
            return names
    return set()


class _Taint:
    """Per-function taint state: names bound to (potentially) traced
    values."""

    def __init__(self, tainted: set[str]):
        self.names = set(tainted)

    def expr_tainted(self, node: ast.expr) -> bool:
        """True when ``node`` may be a traced value. Attribute reads off
        the static allowlist and ``len()``/shape arithmetic un-taint."""
        if isinstance(node, ast.Name):
            return node.id in self.names
        if isinstance(node, ast.Attribute):
            if node.attr in STATIC_ATTRS:
                return False
            return self.expr_tainted(node.value)
        if isinstance(node, ast.Subscript):
            return self.expr_tainted(node.value)
        if isinstance(node, ast.Call):
            kind, name, recv = _call_name(node)
            if kind == "name" and name == "len":
                return False
            if kind == "name" and name == "getattr" and len(node.args) >= 2:
                a = node.args[1]
                if isinstance(a, ast.Constant) and a.value in STATIC_ATTRS:
                    return False
            if kind == "name" and name in ("int", "float", "bool", "range",
                                           "isinstance"):
                return False
            # any call over tainted operands is tainted (jnp ops), and
            # jnp./lax. constructors are traced values regardless
            return any(self.expr_tainted(a) for a in node.args) or \
                any(self.expr_tainted(kw.value) for kw in node.keywords) or \
                (kind == "attr" and recv is not None
                 and self.expr_tainted(recv))
        if isinstance(node, (ast.BinOp,)):
            return self.expr_tainted(node.left) or \
                self.expr_tainted(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.expr_tainted(node.operand)
        if isinstance(node, ast.Compare):
            if all(isinstance(op, (ast.Is, ast.IsNot))
                   for op in node.ops):
                return False
            return self.expr_tainted(node.left) or \
                any(self.expr_tainted(c) for c in node.comparators)
        if isinstance(node, ast.BoolOp):
            return any(self.expr_tainted(v) for v in node.values)
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(self.expr_tainted(e) for e in node.elts)
        if isinstance(node, ast.IfExp):
            return self.expr_tainted(node.body) or \
                self.expr_tainted(node.orelse)
        if isinstance(node, ast.Starred):
            return self.expr_tainted(node.value)
        return False

    def assign(self, target: ast.expr, tainted: bool) -> None:
        for node in ast.walk(target):
            if isinstance(node, ast.Name):
                if tainted:
                    self.names.add(node.id)
                else:
                    self.names.discard(node.id)


def _function_params(fn: ast.FunctionDef) -> list[str]:
    args = fn.args
    names = [a.arg for a in args.posonlyargs + args.args + args.kwonlyargs]
    if args.vararg:
        names.append(args.vararg.arg)
    if args.kwarg:
        names.append(args.kwarg.arg)
    return names


def _lint_jitted_body(fn: ast.FunctionDef, static: set[str], path, symbol,
                      report: Report) -> None:
    """Taint-based pass over one jitted function (nested defs inherit the
    enclosing taint; their params are traced too — vmap/cond bodies)."""
    taint = _Taint(set(_function_params(fn)) - static)

    def visit_block(stmts):
        for stmt in stmts:
            visit_stmt(stmt)

    def check_calls(node: ast.expr):
        for sub in ast.walk(node):
            if not isinstance(sub, ast.Call):
                continue
            kind, name, recv = _call_name(sub)
            if kind == "attr" and name == "item" and recv is not None \
                    and taint.expr_tainted(recv):
                report.add("traced-host-cast", path, symbol,
                           "`.item()` on a traced value inside a jitted "
                           "body concretizes at trace time",
                           line=sub.lineno)
            if kind == "name" and name in ("float", "int", "bool") and \
                    sub.args and taint.expr_tainted(sub.args[0]):
                report.add("traced-host-cast", path, symbol,
                           f"`{name}()` on a traced value inside a jitted "
                           "body concretizes at trace time",
                           line=sub.lineno)

    def visit_stmt(stmt):
        if isinstance(stmt, ast.FunctionDef):
            inner = set(taint.names) | set(_function_params(stmt))
            saved = taint.names
            taint.names = inner
            visit_block(stmt.body)
            taint.names = saved
            return
        if isinstance(stmt, (ast.If, ast.While)):
            if taint.expr_tainted(stmt.test):
                report.add("traced-python-branch", path, symbol,
                           "Python control flow on a traced value inside "
                           "a jitted body (use lax.cond/jnp.where)",
                           line=stmt.lineno)
            check_calls(stmt.test)
            visit_block(stmt.body)
            visit_block(stmt.orelse)
            return
        if isinstance(stmt, ast.For):
            check_calls(stmt.iter)
            taint.assign(stmt.target, taint.expr_tainted(stmt.iter))
            visit_block(stmt.body)
            visit_block(stmt.orelse)
            return
        if isinstance(stmt, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            value = stmt.value
            if value is not None:
                check_calls(value)
                is_tainted = taint.expr_tainted(value)
                targets = stmt.targets if isinstance(stmt, ast.Assign) \
                    else [stmt.target]
                for t in targets:
                    taint.assign(t, is_tainted or
                                 isinstance(stmt, ast.AugAssign))
            return
        if isinstance(stmt, (ast.Return, ast.Expr)):
            if stmt.value is not None:
                check_calls(stmt.value)
            return
        if isinstance(stmt, ast.With):
            visit_block(stmt.body)
            return
        for node in ast.iter_child_nodes(stmt):
            if isinstance(node, ast.expr):
                check_calls(node)

    visit_block(fn.body)


# --------------------------------------------------------- timer sections

def _contains_sync(node: ast.AST, resolve) -> bool:
    """A lexical sync inside ``node``, or a call to a resolvable function
    known (transitively) to sync. ``resolve(call) -> bool | None``."""
    for sub in ast.walk(node):
        if not isinstance(sub, ast.Call):
            continue
        kind, name, recv = _call_name(sub)
        if kind == "attr" and name in _SYNC_ATTR_CALLS:
            return True
        if kind == "name" and name in _SYNC_CALL_NAMES and sub.args:
            return True
        if resolve is not None and resolve(sub):
            return True
    return False


def _is_perf_counter(node: ast.expr) -> bool:
    if isinstance(node, ast.Call):
        kind, name, _ = _call_name(node)
        return name == "perf_counter"
    return False


def _timer_sections(body: list[ast.stmt]):
    """Yield (var, open_stmt, span_stmts, close_stmt) for every
    ``t = time.perf_counter()`` ... ``... perf_counter() - t ...`` pair
    found in the same statement block; nested blocks are scanned
    recursively."""
    for i, stmt in enumerate(body):
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 and \
                isinstance(stmt.targets[0], ast.Name) and \
                _is_perf_counter(stmt.value):
            var = stmt.targets[0].id
            for j in range(i + 1, len(body)):
                close = body[j]
                if _closes_timer(close, var):
                    yield var, stmt, body[i + 1:j], close
                    break
    for stmt in body:
        for block in _child_blocks(stmt):
            yield from _timer_sections(block)


def _closes_timer(stmt: ast.stmt, var: str) -> bool:
    """A statement that reads ``perf_counter() - var``."""
    for node in ast.walk(stmt):
        if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Sub) \
                and _is_perf_counter(node.left) \
                and any(isinstance(n, ast.Name) and n.id == var
                        for n in ast.walk(node.right)):
            return True
    return False


def _child_blocks(stmt: ast.stmt):
    for field in ("body", "orelse", "finalbody"):
        block = getattr(stmt, field, None)
        if block:
            yield block
    for handler in getattr(stmt, "handlers", ()):
        yield handler.body


# ------------------------------------------------------------- call graph

class _Module:
    def __init__(self, path: pathlib.Path, rel: str):
        self.path = path
        self.rel = rel
        self.tree = ast.parse(path.read_text(), filename=str(path))
        #: {qualname: FunctionDef} — "f" top-level, "Cls.m" methods
        self.functions: dict[str, ast.FunctionDef] = {}
        #: {local name: (module, name)} for ``from repro.x import name``
        self.imports: dict[str, tuple[str, str]] = {}
        for node in self.tree.body:
            if isinstance(node, ast.FunctionDef):
                self.functions[node.name] = node
            elif isinstance(node, ast.ClassDef):
                for item in node.body:
                    if isinstance(item, ast.FunctionDef):
                        self.functions[f"{node.name}.{item.name}"] = item
            elif isinstance(node, ast.ImportFrom) and node.module and \
                    node.module.split(".")[0] == "repro":
                for alias in node.names:
                    self.imports[alias.asname or alias.name] = \
                        (node.module, alias.name)


def _build_sync_map(modules: list[_Module]) -> dict[tuple, bool]:
    """Fixpoint: (module path, qualname) -> "this function syncs", where
    a function syncs if its body contains a lexical sync or a resolvable
    call to a syncing function."""
    by_modname: dict[str, _Module] = {}
    for m in modules:
        # repo-relative path ".../src/repro/x/y.py" -> "repro.x.y"
        parts = pathlib.Path(m.rel).with_suffix("").parts
        if "repro" in parts:
            by_modname[".".join(parts[parts.index("repro"):])] = m

    syncs: dict[tuple, bool] = {(m.rel, q): False
                                for m in modules for q in m.functions}

    def resolver(mod: _Module, cls: str | None):
        def resolve(call: ast.Call):
            kind, name, recv = _call_name(call)
            target = None
            if kind == "name":
                if name in mod.functions:
                    target = (mod.rel, name)
                elif name in mod.imports:
                    src_mod, src_name = mod.imports[name]
                    other = by_modname.get(src_mod)
                    if other and src_name in other.functions:
                        target = (other.rel, src_name)
            elif kind == "attr" and isinstance(recv, ast.Name) and \
                    recv.id == "self" and cls is not None:
                qual = f"{cls}.{name}"
                if qual in mod.functions:
                    target = (mod.rel, qual)
            return bool(target and syncs.get(target))
        return resolve

    changed = True
    while changed:
        changed = False
        for m in modules:
            for qual, fn in m.functions.items():
                if syncs[(m.rel, qual)]:
                    continue
                cls = qual.split(".")[0] if "." in qual else None
                if _contains_sync(fn, resolver(m, cls)):
                    syncs[(m.rel, qual)] = True
                    changed = True
    return syncs


# -------------------------------------------------------------- entry point

def lint_paths(paths, *, repo_root=None) -> Report:
    """Run all four rules over ``paths`` (.py files). The timer rule's
    call-graph propagation resolves across every file in the SAME
    invocation, so lint the package set together."""
    repo_root = pathlib.Path(repo_root) if repo_root else None
    modules = []
    for p in paths:
        p = pathlib.Path(p)
        rel = str(p.relative_to(repo_root)) if repo_root and \
            p.is_relative_to(repo_root) else str(p)
        modules.append(_Module(p, rel))
    syncs = _build_sync_map(modules)
    report = Report()
    for m in modules:
        _lint_module(m, syncs, report)
    return report


def _lint_module(mod: _Module, syncs: dict, report: Report) -> None:
    # rule: unsized-nonzero (anywhere in the module — eager callers break
    # under a later jit/vmap wrap just the same)
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Call):
            kind, name, recv = _call_name(node)
            if kind == "attr" and name == "nonzero" and \
                    isinstance(recv, ast.Name) and \
                    recv.id in ("jnp", "jax") and \
                    not any(kw.arg == "size" for kw in node.keywords):
                report.add("unsized-nonzero", mod.rel,
                           _enclosing(mod, node),
                           "jnp.nonzero without size= has a data-dependent "
                           "output shape (fails/retraces under jit)",
                           line=node.lineno)

    # rules: traced-host-cast / traced-python-branch in jitted bodies
    for qual, fn in mod.functions.items():
        static: set[str] = set()
        jitted = False
        for dec in fn.decorator_list:
            is_jit, names = _is_jit_decorator(dec)
            if is_jit:
                jitted, static = True, names
                break
        if jitted:
            _lint_jitted_body(fn, static, mod.rel, qual, report)

    # rule: timer-no-sync
    def make_resolve(cls):
        def resolve(call: ast.Call):
            kind, name, recv = _call_name(call)
            if kind == "name":
                if name in mod.functions:
                    return syncs.get((mod.rel, name), False)
                if name in mod.imports:
                    return _imported_syncs(syncs, mod.imports[name])
            elif kind == "attr" and isinstance(recv, ast.Name) and \
                    recv.id == "self" and cls is not None:
                return syncs.get((mod.rel, f"{cls}.{name}"), False)
            return False
        return resolve

    for qual, fn in mod.functions.items():
        resolve = make_resolve(qual.split(".")[0] if "." in qual else None)
        for var, open_stmt, span, close in _timer_sections(fn.body):
            if not span:
                continue
            if any(_contains_sync(s, resolve) for s in span):
                continue
            report.add("timer-no-sync", mod.rel, qual,
                       f"perf_counter section `{var}` (line "
                       f"{open_stmt.lineno}) times a span with no "
                       "device sync — attribution measures dispatch, "
                       "not compute (add block_until_ready or a host "
                       "transfer inside the span)",
                       line=open_stmt.lineno, severity="error")


def _imported_syncs(syncs: dict, target: tuple[str, str]) -> bool:
    """Does ``from <module> import <name>`` resolve to a syncing
    function? Matched by qualname + module path suffix."""
    src_mod, src_name = target
    suffix = src_mod.replace(".", "/") + ".py"
    for (rel, qual), ok in syncs.items():
        if qual == src_name and rel.endswith(suffix):
            return ok
    return False


def _enclosing(mod: _Module, node: ast.AST) -> str:
    """Qualname of the function containing ``node`` (by line span), else
    ``<module>``."""
    best, best_span = "<module>", None
    for qual, fn in mod.functions.items():
        end = getattr(fn, "end_lineno", fn.lineno)
        if fn.lineno <= node.lineno <= end:
            span = end - fn.lineno
            if best_span is None or span < best_span:
                best, best_span = qual, span
    return best
