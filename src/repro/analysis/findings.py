"""Shared finding/report structure for every analyzer, plus the baseline.

A ``Finding`` is identified by ``(rule, path, symbol)`` — deliberately NOT
by line number, so a committed baseline survives unrelated edits to the
same file. ``line`` is carried for human navigation only. Baselined
findings may carry a ``justification`` string (the inline "why this is
accepted" record the satellite tasks require); ``Report.new_against``
is the CI gate — it returns only findings whose identity is absent from
the baseline, so the gate fails on NEW findings and never on accepted
ones.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib

SEVERITIES = ("error", "warn")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One analyzer result. ``rule`` is the check's stable name,
    ``path`` a repo-relative file (or ``<plan>`` for plan analysis),
    ``symbol`` the enclosing function/class or plan entity. ``context``
    names the submission a plan finding belongs to (``tenant/plan_id``,
    threaded from the study daemon) — like ``line`` it is carried for
    human navigation only and is NOT part of the identity, so the lint
    baseline stays line-free AND tenant-free."""
    rule: str
    path: str
    symbol: str
    message: str
    severity: str = "error"
    line: int = 0
    context: str = ""

    @property
    def key(self) -> tuple:
        return (self.rule, self.path, self.symbol)

    def render(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        ctx = f" [{self.context}]" if self.context else ""
        return f"[{self.severity}] {self.rule} {loc} ({self.symbol}){ctx}: " \
               f"{self.message}"


class Report:
    """An ordered collection of findings with JSON/text emission and the
    baseline diff the CI gate runs on."""

    def __init__(self, findings=()):
        self.findings: list[Finding] = list(findings)

    def add(self, rule, path, symbol, message, *, severity="error",
            line=0, context="") -> None:
        assert severity in SEVERITIES, severity
        self.findings.append(Finding(rule, str(path), str(symbol), message,
                                     severity=severity, line=int(line),
                                     context=str(context)))

    def extend(self, other: "Report") -> None:
        self.findings.extend(other.findings)

    def __len__(self) -> int:
        return len(self.findings)

    def __iter__(self):
        return iter(self.findings)

    @property
    def errors(self) -> list[Finding]:
        return [f for f in self.findings if f.severity == "error"]

    def new_against(self, baseline: dict | None) -> list[Finding]:
        """Findings not accepted by ``baseline`` (a dict loaded by
        :func:`load_baseline`; None = empty baseline)."""
        accepted = baseline_keys(baseline)
        return [f for f in self.findings if f.key not in accepted]

    def to_json(self) -> dict:
        return {"schema": 1,
                "findings": [dataclasses.asdict(f) for f in self.findings]}

    def render(self) -> str:
        if not self.findings:
            return "no findings"
        return "\n".join(f.render() for f in self.findings)


def baseline_keys(baseline: dict | None) -> set:
    if not baseline:
        return set()
    return {(f["rule"], f["path"], f["symbol"])
            for f in baseline.get("findings", ())}


def load_baseline(path) -> dict | None:
    """Parse a baseline file; None when absent or unreadable (an empty
    baseline — every finding is then new)."""
    try:
        with open(path) as fh:
            data = json.load(fh)
    except (OSError, ValueError):
        return None
    return data if isinstance(data.get("findings"), list) else None


def write_baseline(report: Report, path, *,
                   previous: dict | None = None) -> dict:
    """Write ``report``'s findings as the new baseline, carrying forward
    the ``justification`` strings of entries that persist from
    ``previous`` (identity match) — accepting a finding is an explicit
    edit, not something a refresh silently drops."""
    kept = {}
    for f in (previous or {}).get("findings", ()):
        kept[(f["rule"], f["path"], f["symbol"])] = f.get("justification")
    entries = []
    for f in report.findings:
        entry = {"rule": f.rule, "path": f.path, "symbol": f.symbol,
                 "message": f.message, "severity": f.severity,
                 "justification": kept.get(f.key)
                 or "TODO: justify or fix"}
        entries.append(entry)
    data = {"schema": 1, "findings": entries}
    pathlib.Path(path).write_text(json.dumps(data, indent=2) + "\n")
    return data
