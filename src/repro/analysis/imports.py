"""Intra-package import graph: lint scope and scaffolding inventory.

The seed tree carries non-SVM scaffolding (model zoo, training loop,
serving) that nothing in the SVM reproduction imports. The lint passes
must not hold that code to conventions it predates, and a future PR needs
an explicit list to prune or adopt deliberately (DESIGN.md §Static
analysis records the current inventory). This module derives both from
the only ground truth there is: the import statements themselves,
collected by AST over every module under ``src/repro`` (function-level
imports included — ``svm/svc.py`` imports the CV drivers lazily).
"""
from __future__ import annotations

import ast
import pathlib

#: packages whose modules are the lint roots — the SVM reproduction
#: proper plus the subsystems it consumes through injection rather
#: than imports (checkpoint managers are passed into run_plan/run_grid,
#: the analyzers run the lint itself, the study daemon is an entry point
#: nothing imports), so the import graph alone would misfile them as
#: scaffolding; everything transitively imported from here is "adopted"
#: code
ROOT_PACKAGES = ("repro.svm", "repro.core", "repro.kernels",
                 "repro.checkpoint", "repro.analysis", "repro.service")


def src_root(start=__file__) -> pathlib.Path:
    """The ``src/`` directory this package was imported from."""
    return pathlib.Path(start).resolve().parents[2]


def module_name(path: pathlib.Path, root: pathlib.Path) -> str:
    rel = path.relative_to(root).with_suffix("")
    parts = list(rel.parts)
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def repro_modules(root=None) -> dict[str, pathlib.Path]:
    """{module name: path} for every .py file under ``src/repro``."""
    root = pathlib.Path(root) if root is not None else src_root()
    return {module_name(p, root): p
            for p in sorted((root / "repro").rglob("*.py"))}


def import_graph(root=None) -> dict[str, set[str]]:
    """{module: set of repro modules it imports}. ``from repro.x import
    name`` edges target ``repro.x`` (and ``repro.x.name`` when that is
    itself a module, e.g. ``from repro.svm import cost_model``)."""
    modules = repro_modules(root)
    graph: dict[str, set[str]] = {}
    for mod, path in modules.items():
        tree = ast.parse(path.read_text(), filename=str(path))
        deps: set[str] = set()
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                deps.update(a.name for a in node.names
                            if a.name.split(".")[0] == "repro")
            elif isinstance(node, ast.ImportFrom) and node.module and \
                    node.module.split(".")[0] == "repro" and node.level == 0:
                deps.add(node.module)
                for alias in node.names:
                    sub = f"{node.module}.{alias.name}"
                    if sub in modules:
                        deps.add(sub)
        graph[mod] = {d for d in deps if d in modules}
    return graph


def reachable(graph: dict[str, set[str]], roots) -> set[str]:
    """Transitive closure of ``roots`` (package names include all their
    member modules as roots)."""
    stack = [m for m in graph
             if any(m == r or m.startswith(r + ".") for r in roots)]
    seen = set(stack)
    while stack:
        for dep in graph.get(stack.pop(), ()):
            # importing a module executes every ancestor package's
            # __init__, so those count as reached too; sibling member
            # modules are reached only by their own explicit imports
            parts = dep.split(".")
            for anc in (".".join(parts[:i]) for i in range(1, len(parts) + 1)):
                if anc in graph and anc not in seen:
                    seen.add(anc)
                    stack.append(anc)
    return seen


def scaffolding_inventory(root=None) -> list[str]:
    """Modules under ``src/repro`` that nothing reachable from the SVM
    roots (``repro.svm``/``repro.core``/``repro.kernels``) imports — the
    unadopted seed scaffolding, excluded from the default lint scope."""
    graph = import_graph(root)
    live = reachable(graph, ROOT_PACKAGES)
    return sorted(m for m in graph if m not in live)


def default_scope(root=None) -> list[pathlib.Path]:
    """Files the lint passes run on by default: every module reachable
    from the SVM roots (so an adopted scaffolding module is linted the
    moment something imports it)."""
    modules = repro_modules(root)
    live = reachable(import_graph(root), ROOT_PACKAGES)
    return [modules[m] for m in sorted(live)]
