"""LaneScheduler: per-lane bit-parity with sequential solves under forced
repack boundaries, mixed convergence orders, dependency admission, and
resume-from-mid-batch checkpoints (by original lane id)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cv import _fold_masks, _transition_idx
from repro.data.svm_suite import kfold_chunks, make_dataset
from repro.svm import (DenseKernel, LaneScheduler, init_f, kernel_matrix,
                       smo_solve)
from repro.svm.scheduler import bucket_width

SUITE = ("adult", "heart", "madelon", "mnist", "webdata")


def _setup(name, n=140, k=4):
    ds = make_dataset(name, n_override=n)
    X = jnp.asarray(ds.X)
    y = jnp.asarray(ds.y, jnp.float64)
    K = kernel_matrix(X, X, gamma=ds.gamma)
    chunks = kfold_chunks(n, k, seed=0)
    nn = chunks.size
    return ds, K[:nn][:, :nn], y[:nn], chunks, jnp.asarray(_fold_masks(chunks))


def test_bucket_width_policy():
    assert [bucket_width(w, 4) for w in (1, 2, 3, 4, 5, 9, 16)] == \
        [1, 2, 4, 4, 8, 12, 16]
    assert bucket_width(3, 1) == 3          # quantum 1 = exact widths
    assert bucket_width(7, 8) == 8


@pytest.mark.parametrize("max_width", [0, 1, 3])
@pytest.mark.parametrize("name", SUITE)
def test_scheduler_parity_bitwise_all_suite(name, max_width):
    """Cold folds through the scheduler with tiny chunks (many forced
    repack boundaries) must be bit-identical to sequential solves on every
    suite dataset, for every schedule shape: unbounded vmapped packing
    (max_width=0, straggler tail degrading to the single-lane program),
    pure width-1 round-robin (the CPU cost-model default), and a capped
    width that parks/rotates lanes (max_width=3 over 4 lanes)."""
    ds, K, y, chunks, masks = _setup(name)
    n = y.shape[0]
    sched = LaneScheduler(DenseKernel(K), y, chunk_iters=64, lane_quantum=2,
                          max_width=max_width)
    for h in range(4):
        sched.add(h, masks[h], ds.C, jnp.zeros(n, K.dtype), -y)
    results = sched.run()
    for h in range(4):
        seq = smo_solve(K, y, masks[h], ds.C, jnp.zeros(n), -y)
        np.testing.assert_array_equal(np.asarray(seq.alpha),
                                      np.asarray(results[h].alpha))
        np.testing.assert_array_equal(np.asarray(seq.f),
                                      np.asarray(results[h].f))
        assert int(seq.n_iter) == int(results[h].n_iter)
        assert bool(results[h].converged) == bool(seq.converged)
    occ = sched.occupancy
    assert occ["chunks"] > 1
    if max_width == 0:
        assert occ["peak_width"] >= 4
        # repacking must actually shrink the batch as lanes retire
        assert occ["mean_live_width"] < 4
    else:
        # dispatched width caps at max_width rounded up to its pad bucket
        assert occ["peak_width"] <= bucket_width(max(max_width, 1), 2)


def test_scheduler_mixed_convergence_orders():
    """Heterogeneous lanes (spread C values, one warm-seeded lane) retire
    in scrambled order across many repack boundaries; every lane must still
    replay its sequential iterate sequence bit-exactly."""
    ds, K, y, chunks, masks = _setup("heart")
    n = y.shape[0]
    Cs = [0.1 * ds.C, ds.C, 10.0 * ds.C, 100.0 * ds.C, ds.C]
    warm = smo_solve(K, y, masks[0], ds.C, jnp.zeros(n), -y)
    inits = [(jnp.zeros(n, K.dtype), -y)] * 4 + [(warm.alpha, warm.f)]
    lane_masks = [masks[h % 4] for h in range(4)] + [masks[0]]
    sched = LaneScheduler(DenseKernel(K), y, chunk_iters=32, lane_quantum=2,
                          max_width=0)
    for i, (C, (a0, f0), mask) in enumerate(zip(Cs, inits, lane_masks)):
        sched.add(i, mask, C, a0, f0)
    results = sched.run()
    orders = set()
    for i, (C, (a0, f0), mask) in enumerate(zip(Cs, inits, lane_masks)):
        seq = smo_solve(K, y, mask, C, a0, f0)
        np.testing.assert_array_equal(np.asarray(seq.alpha),
                                      np.asarray(results[i].alpha))
        assert int(seq.n_iter) == int(results[i].n_iter)
        orders.add(int(seq.n_iter))
    assert len(orders) >= 3, "test wants genuinely mixed convergence times"
    # the warm-seeded lane converges immediately and retires on chunk 1
    assert int(results[4].n_iter) == 0


def test_scheduler_admission_matches_cv_chain():
    """A fold chain expressed as lane dependencies (seed transform at
    admission) reproduces run_cv's per-fold trajectories bit-exactly."""
    from repro.core import seeding
    from repro.core.cv import run_cv
    ds = make_dataset("heart", n_override=140)
    rep = run_cv(ds, k=4, method="sir")
    _, K, y, chunks, masks = _setup("heart")
    n = y.shape[0]
    sched = LaneScheduler(DenseKernel(K), y, chunk_iters=64, lane_quantum=2)
    sched.add(0, masks[0], ds.C, jnp.zeros(n, K.dtype), -y)
    for h in range(1, 4):
        S, R, T = _transition_idx(chunks, h - 1, h)

        def seed_fn(prev, C=ds.C, S=S, R=R, T=T):
            a0 = seeding.sir_seed(K, y, C, prev, S, R, T)
            return a0, init_f(K, y, a0)
        sched.add(h, masks[h], ds.C, dep=h - 1, seed_fn=seed_fn)
    results = sched.run()
    assert [int(results[h].n_iter) for h in range(4)] == \
        [f.n_iter for f in rep.folds]
    assert sched.seed_time > 0.0


def test_scheduler_snapshot_resume_bitwise():
    """Rebuild a scheduler from any mid-batch snapshot — retired lanes via
    add_result, live lanes via their (alpha, f, n_iter) keyed by original
    lane id — and finish with bit-identical results."""
    from repro.svm.engine import EngineState, _finalize
    ds, K, y, chunks, masks = _setup("heart")
    n = y.shape[0]
    snaps = []
    sched = LaneScheduler(DenseKernel(K), y, chunk_iters=64, lane_quantum=2,
                          max_width=0,
                          on_snapshot=lambda s: snaps.append(
                              s.snapshot_lanes()))
    for h in range(4):
        sched.add(h, masks[h], ds.C, jnp.zeros(n, K.dtype), -y)
    full = sched.run()
    assert len(snaps) >= 3, "solve should span several chunks"
    mid = len(snaps) // 2
    ids, tree = snaps[mid]
    assert ids == [0, 1, 2, 3]
    # resume under a DIFFERENT schedule shape (width-1 round-robin): the
    # snapshot is keyed by lane id, so packing at crash time is irrelevant
    resumed = LaneScheduler(DenseKernel(K), y, chunk_iters=64,
                            lane_quantum=2, max_width=1)
    for i, h in enumerate(ids):
        if bool(tree["done"][i]):
            state = EngineState(tree["alpha"][i], tree["f"][i],
                                tree["n_iter"][i], jnp.ones((), bool))
            resumed.add_result(h, _finalize(state, y, masks[h], ds.C, 1e-3))
        else:
            resumed.add(h, masks[h], ds.C, tree["alpha"][i], tree["f"][i],
                        n_iter0=int(tree["n_iter"][i]))
    res2 = resumed.run()
    for h in range(4):
        np.testing.assert_array_equal(np.asarray(full[h].alpha),
                                      np.asarray(res2[h].alpha))
        np.testing.assert_array_equal(np.asarray(full[h].f),
                                      np.asarray(res2[h].f))
        assert int(full[h].n_iter) == int(res2[h].n_iter)


def test_run_cv_batched_mid_batch_checkpoint_resume(tmp_path):
    """End-to-end: crash a repacked batched CV mid-flight; the restarted
    run restores every lane by fold id and lands on the identical report."""
    from repro.checkpoint import CheckpointManager
    from repro.core.cv import run_cv_batched
    ds = make_dataset("heart", n_override=120)
    full = run_cv_batched(ds, k=4, chunk_iters=64)

    mgr = CheckpointManager(str(tmp_path / "cv"), max_to_keep=1000)
    run_cv_batched(ds, k=4, chunk_iters=64, checkpoint_manager=mgr)
    steps = mgr.steps_of_class("batch")
    assert len(steps) >= 3
    import shutil
    for s in steps[3:]:                      # 'crash' after the 3rd chunk
        shutil.rmtree(mgr._step_dir(s))
    mgr2 = CheckpointManager(str(tmp_path / "cv"), max_to_keep=1000)
    resumed = run_cv_batched(ds, k=4, chunk_iters=64,
                             checkpoint_manager=mgr2)
    assert [f.n_iter for f in resumed.folds] == \
        [f.n_iter for f in full.folds]
    assert resumed.accuracy == full.accuracy
    assert [f.converged for f in resumed.folds] == \
        [f.converged for f in full.folds]


def test_run_cv_batched_checkpoint_rejects_other_run(tmp_path):
    from repro.checkpoint import CheckpointManager
    from repro.core.cv import run_cv_batched
    ds = make_dataset("heart", n_override=120)
    mgr = CheckpointManager(str(tmp_path / "cv"), max_to_keep=1000)
    run_cv_batched(ds, k=4, chunk_iters=64, checkpoint_manager=mgr)
    mgr2 = CheckpointManager(str(tmp_path / "cv"), max_to_keep=1000)
    with pytest.raises(ValueError, match="cannot resume"):
        run_cv_batched(ds, k=5, chunk_iters=64, checkpoint_manager=mgr2)
    # a different tol is a different run: retired lanes carry fixed points
    # at the snapshot's tolerance, so mixing criteria must be rejected too
    mgr3 = CheckpointManager(str(tmp_path / "cv"), max_to_keep=1000)
    with pytest.raises(ValueError, match="cannot resume"):
        run_cv_batched(ds, k=4, chunk_iters=64, tol=1e-6,
                       checkpoint_manager=mgr3)


def test_scheduler_single_lane_degrades_to_sequential():
    """One lane never pays the batched program: every chunk dispatches the
    single-lane (width 1) path, bit-identical to engine.solve."""
    ds, K, y, chunks, masks = _setup("heart")
    n = y.shape[0]
    sched = LaneScheduler(DenseKernel(K), y, chunk_iters=64)
    sched.add("only", masks[0], ds.C, jnp.zeros(n, K.dtype), -y)
    results = sched.run()
    assert sched.occupancy["peak_width"] == 1
    assert sched.occupancy["programs"] == 1
    seq = smo_solve(K, y, masks[0], ds.C, jnp.zeros(n), -y)
    np.testing.assert_array_equal(np.asarray(seq.alpha),
                                  np.asarray(results["only"].alpha))
    assert int(seq.n_iter) == int(results["only"].n_iter)


def test_scheduler_deadlock_detection():
    ds, K, y, chunks, masks = _setup("heart")
    n = y.shape[0]
    sched = LaneScheduler(DenseKernel(K), y, chunk_iters=64)
    sched.add(0, masks[0], ds.C, jnp.zeros(n, K.dtype), -y)
    sched.add(1, masks[1], ds.C, dep="missing",
              seed_fn=lambda prev: (prev.alpha, prev.f))
    with pytest.raises(RuntimeError, match="never retire"):
        sched.run()


def test_scheduler_rejects_bad_lane_specs():
    ds, K, y, chunks, masks = _setup("heart")
    n = y.shape[0]
    sched = LaneScheduler(DenseKernel(K), y)
    sched.add(0, masks[0], ds.C, jnp.zeros(n, K.dtype), -y)
    with pytest.raises(ValueError, match="duplicate"):
        sched.add(0, masks[0], ds.C, jnp.zeros(n, K.dtype), -y)
    with pytest.raises(ValueError, match="exactly one"):
        sched.add(1, masks[1], ds.C)
    with pytest.raises(ValueError, match="together"):
        sched.add(1, masks[1], ds.C, jnp.zeros(n, K.dtype))
    with pytest.raises(ValueError, match="seed_fn"):
        sched.add(2, masks[2], ds.C, dep=0)


def test_engine_state_lane_helpers():
    """stack/lane/gather/scatter round-trip: the packed-batch vocabulary."""
    from repro.svm.engine import EngineState
    states = [EngineState(jnp.full(3, float(i)), jnp.full(3, -float(i)),
                          jnp.asarray(i, jnp.int64), jnp.asarray(i % 2 == 0))
              for i in range(4)]
    packed = EngineState.stack(states)
    assert packed.alpha.shape == (4, 3)
    for i in range(4):
        np.testing.assert_array_equal(np.asarray(packed.lane(i).alpha),
                                      np.asarray(states[i].alpha))
    sub = packed.gather(jnp.asarray([3, 1]))
    np.testing.assert_array_equal(np.asarray(sub.n_iter), [3, 1])
    back = packed.scatter(jnp.asarray([3, 1]), sub)
    for a, b in zip(back, packed):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    moved = packed.scatter(jnp.asarray([0]), packed.gather(jnp.asarray([2])))
    np.testing.assert_array_equal(np.asarray(moved.alpha[0]),
                                  np.asarray(packed.alpha[2]))


def test_run_cv_and_batched_share_checkpoint_directory(tmp_path):
    """Batch snapshots live above _BATCH_BASE: neither run kind clobbers or
    mis-restores the other's records in a shared directory."""
    from repro.checkpoint import CheckpointManager
    from repro.core.cv import _BATCH_BASE, run_cv, run_cv_batched
    ds = make_dataset("heart", n_override=120)
    mgr = CheckpointManager(str(tmp_path / "cv"), max_to_keep=1000)
    run_cv(ds, k=4, method="sir", checkpoint_manager=mgr, chunk_iters=64)
    cv_steps = set(mgr.all_steps())
    assert all(s < _BATCH_BASE for s in cv_steps)
    run_cv_batched(ds, k=4, chunk_iters=64, checkpoint_manager=mgr)
    # every run_cv record survived the batch run's saves
    assert cv_steps <= set(mgr.all_steps())
    assert all(s >= _BATCH_BASE for s in mgr.steps_of_class("batch"))
    # both kinds resume cleanly from the shared directory
    full_cv = run_cv(ds, k=4, method="sir")
    mgr2 = CheckpointManager(str(tmp_path / "cv"), max_to_keep=1000)
    resumed = run_cv(ds, k=4, method="sir", checkpoint_manager=mgr2,
                     chunk_iters=64)
    assert resumed.total_iterations == full_cv.total_iterations
    full_bat = run_cv_batched(ds, k=4, chunk_iters=64)
    mgr3 = CheckpointManager(str(tmp_path / "cv"), max_to_keep=1000)
    rebat = run_cv_batched(ds, k=4, chunk_iters=64, checkpoint_manager=mgr3)
    assert [f.n_iter for f in rebat.folds] == \
        [f.n_iter for f in full_bat.folds]