"""Study service: multi-tenant daemon over one shared LanePool.

The core invariants: a served plan's results are BIT-identical to an
in-process ``run_plan`` (the pool's schedule-shape parity is what makes
daemon interleaving legal at all); overlapping submissions dedup their
kernel sources (fewer materializations than the sum of solo runs); the
admission gate rejects invalid/infeasible/storm plans with structured
findings before anything materializes; and a killed daemon's studies
resume from their snapshots on restart — under a different width.

Most tests drive :class:`StudyService` directly on the calling thread
(no service thread started — the tests ARE the service thread), which
makes admission order and interleaving deterministic. One end-to-end
test runs the real socket server.
"""
import dataclasses
import json
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cv import _fold_masks, _transition_idx
from repro.core.study import Plan, plan_to_dict, run_plan
from repro.data.svm_suite import kfold_chunks, make_dataset
from repro.service import (PlanRejectedByServer, StudyClient, StudyServer,
                           StudyService)
from repro.svm import DenseKernel, kernel_matrix
from repro.svm.sources import KernelSpec


def _setup(name="heart", n=120, k=4):
    ds = make_dataset(name, n_override=n)
    X = jnp.asarray(ds.X)
    y = jnp.asarray(ds.y, jnp.float64)
    chunks = kfold_chunks(n, k, seed=0)
    nn = chunks.size
    return ds, X[:nn], y[:nn], chunks, jnp.asarray(_fold_masks(chunks))


def _fold_chain_plan(sources, y, masks, chunks, C, *, folds=3, **knobs):
    """Per-source fold chains with tuple lane ids + per-fold evals — the
    grid driver's shape, exercised over arbitrary source dicts."""
    plan = Plan(sources=dict(sources), y=y, chunk_iters=64,
                lane_quantum=2, **knobs)
    n = y.shape[0]
    for key in sources:
        plan.lane((key, 0), source=key, train_mask=masks[0], C=C,
                  alpha0=jnp.zeros(n), f0=-y)
        for h in range(1, folds):
            S, R, T = _transition_idx(chunks, h - 1, h)
            plan.lane((key, h), source=key, train_mask=masks[h], C=C,
                      dep=(key, h - 1), transform="fold",
                      params=dict(method="sir", S_idx=S, R_idx=R, T_idx=T))
        for h in range(folds):
            plan.evaluate((key, h), chunks[h])
    return plan


def _wire(plan) -> dict:
    """Through real JSON, as the socket would carry it."""
    return json.loads(json.dumps(plan_to_dict(plan)))


def _drain(service) -> None:
    """Run the service's scheduling loop inline until every study
    finishes (the calling thread acts as the service thread)."""
    while service._studies:
        service.pool.step()
        service._snapshot_tick()
        service._finish_ready()


def _events_of(emitted, kind):
    return [m for m in emitted if m["type"] == kind]


def _served_results(emitted):
    from repro.core.study import _from_wire, result_from_dict
    return {_from_wire(m["lane"]): result_from_dict(m["result"])
            for m in _events_of(emitted, "result")}


def _assert_bit_identical(solo_results, served):
    assert set(solo_results) == set(served)
    for lid, ref in solo_results.items():
        got = served[lid]
        np.testing.assert_array_equal(np.asarray(ref.alpha),
                                      np.asarray(got.alpha))
        np.testing.assert_array_equal(np.asarray(ref.f),
                                      np.asarray(got.f))
        assert int(ref.n_iter) == int(got.n_iter)
        assert bool(ref.converged) == bool(got.converged)


# ------------------------------------------------- concurrent multi-tenant

def test_two_tenants_dedup_and_bit_parity():
    """Two tenants' overlapping-gamma studies in flight simultaneously:
    each is bit-identical to its solo ``run_plan``, the overlapping
    kernel source is admitted ONCE (dedup hit for the second study), and
    the pool materializes fewer kernels than the two solo runs did
    combined."""
    ds, X, y, chunks, masks = _setup()
    gam = {s: KernelSpec(X=X, gamma=s * ds.gamma, n=y.shape[0])
           for s in (0.5, 1.0, 2.0)}
    plan_a = _fold_chain_plan({0.5: gam[0.5], 1.0: gam[1.0]}, y, masks,
                              chunks, ds.C, max_resident=2)
    plan_b = _fold_chain_plan({1.0: gam[1.0], 2.0: gam[2.0]}, y, masks,
                              chunks, ds.C, max_resident=2)
    solo_a = run_plan(plan_a)
    solo_b = run_plan(plan_b)
    solo_mats = (solo_a.source_stats["materializations"]
                 + solo_b.source_stats["materializations"])
    assert solo_mats >= 4                 # each solo built its own two

    service = StudyService(chunk_iters=64, lane_quantum=2, max_width=0,
                           max_resident=3)
    ev_a, ev_b = [], []
    # both admitted before any chunk runs -> the shared gamma deduped
    service.submit("alice", "study", _wire(plan_a), ev_a.append)
    service.submit("bob", "study", _wire(plan_b), ev_b.append)
    assert len(service._studies) == 2
    assert service.pool.cache.stats["materializations"] == 0  # gate only
    _drain(service)

    (adm_a,) = _events_of(ev_a, "admitted")
    (adm_b,) = _events_of(ev_b, "admitted")
    assert adm_a["dedup_hits"] == 0 and adm_a["sources_admitted"] == 2
    assert adm_b["dedup_hits"] == 1 and adm_b["sources_admitted"] == 1
    _assert_bit_identical(solo_a.results, _served_results(ev_a))
    _assert_bit_identical(solo_b.results, _served_results(ev_b))
    (done_a,) = _events_of(ev_a, "done")
    (done_b,) = _events_of(ev_b, "done")
    assert {tuple(l): tuple(ct) for l, ct in done_a["evals"]} == \
        {k: v for k, v in solo_a.evals.items()}
    assert {tuple(l): tuple(ct) for l, ct in done_b["evals"]} == \
        {k: v for k, v in solo_b.evals.items()}
    # THE dedup claim: 3 distinct kernels served both studies
    assert service.pool.cache.stats["materializations"] == 3 < solo_mats
    # both tenants did real work under fair-share accounting
    assert done_a["tenant_stats"]["served"] > 0
    assert done_b["tenant_stats"]["served"] > 0
    # drained studies freed their lanes and sources
    assert not service.pool.sources and not service.pool._lanes
    assert service._key_refs == {} and service._ident_to_key == {}


def test_fair_share_interleaves_tenants_under_width_cap():
    """Width-1 pool, two single-source studies: the round-robin must not
    starve either tenant — served chunk counts stay balanced."""
    ds, X, y, chunks, masks = _setup()
    K = kernel_matrix(X, X, gamma=ds.gamma)
    plan = _fold_chain_plan({"k": DenseKernel(K)}, y, masks, chunks, ds.C)
    service = StudyService(chunk_iters=64, lane_quantum=2, max_width=1)
    ev_a, ev_b = [], []
    service.submit("alice", "s", _wire(plan), ev_a.append)
    service.submit("bob", "s", _wire(plan), ev_b.append)
    _drain(service)
    stats = service.pool.tenant_stats()
    served = {t: r["served"] for t, r in stats.items()}
    assert served["alice"] > 0 and served["bob"] > 0
    # identical workloads under strict alternation: equal within one chunk
    assert abs(served["alice"] - served["bob"]) <= 1
    _assert_bit_identical(run_plan(plan).results, _served_results(ev_a))


# ------------------------------------------------------------- admission

def test_rejects_invalid_plan_with_findings():
    ds, X, y, chunks, masks = _setup()
    K = kernel_matrix(X, X, gamma=ds.gamma)
    plan = _fold_chain_plan({"k": DenseKernel(K)}, y, masks, chunks, ds.C)
    plan.lane(("k", 0), source="k", train_mask=masks[0], C=ds.C,
              alpha0=jnp.zeros(y.shape[0]), f0=-y)       # duplicate id
    service = StudyService(chunk_iters=64, lane_quantum=2)
    events = []
    service.submit("alice", "dup", _wire(plan), events.append)
    (rej,) = events
    assert rej["type"] == "rejected"
    assert any(f["rule"] == "invalid-plan" for f in rej["findings"])
    assert "duplicate" in rej["error"]
    assert not service._studies and not service.pool.sources


def test_rejects_budget_infeasible_plan():
    """A factory source bigger than the POOL's cache budget (the daemon
    normalizes budgets to its own) is refused statically."""
    ds, X, y, chunks, masks = _setup()
    spec = KernelSpec(X=X, gamma=ds.gamma, n=y.shape[0])
    plan = _fold_chain_plan({"k": spec}, y, masks, chunks, ds.C)
    service = StudyService(chunk_iters=64, lane_quantum=2,
                           cache_bytes=1000)    # K needs n*n*8 >> 1000
    events = []
    service.submit("alice", "big", _wire(plan), events.append)
    (rej,) = events
    assert rej["type"] == "rejected"
    assert any(f["rule"] == "cache-infeasible" for f in rej["findings"])
    assert service.pool.cache.stats["materializations"] == 0


def test_rejects_compile_storm_by_daemon_policy():
    """In-process the storm finding is a warning; the daemon hardens it
    into a rejection (the jit cache is shared across tenants)."""
    ds, X, y, chunks, masks = _setup()
    K = kernel_matrix(X, X, gamma=ds.gamma)
    plan = Plan(sources={"k": DenseKernel(K)}, y=y, chunk_iters=64)
    n = y.shape[0]
    for i in range(9):                    # quantum-1 widths 1..9 > 8
        plan.lane(i, source="k", train_mask=masks[i % 3], C=ds.C,
                  alpha0=jnp.zeros(n), f0=-y)
        plan.evaluate(i, chunks[i % 3])
    service = StudyService(chunk_iters=64, lane_quantum=1, max_width=0)
    events = []
    service.submit("alice", "storm", _wire(plan), events.append)
    (rej,) = events
    assert rej["type"] == "rejected"
    assert "compile-storm" in rej["error"]
    assert any(f["rule"] == "recompile-storm" for f in rej["findings"])


def test_rejects_contract_mismatch_and_duplicate_study():
    ds, X, y, chunks, masks = _setup()
    K = kernel_matrix(X, X, gamma=ds.gamma)
    plan = _fold_chain_plan({"k": DenseKernel(K)}, y, masks, chunks, ds.C)
    service = StudyService(chunk_iters=64, lane_quantum=2)
    events = []
    service.submit("alice", "t", _wire(dataclasses.replace(plan, tol=1e-5)),
                   events.append)
    (rej,) = events
    assert rej["type"] == "rejected" and "tol" in rej["error"]
    # admit for real, then the same (tenant, plan_id) again while in flight
    ok_events, dup_events = [], []
    service.submit("alice", "t", _wire(plan), ok_events.append)
    assert _events_of(ok_events, "admitted")
    service.submit("alice", "t", _wire(plan), dup_events.append)
    (rej2,) = dup_events
    assert rej2["type"] == "rejected" and "in flight" in rej2["error"]
    _drain(service)


def test_findings_carry_study_context():
    """Admission findings name the (tenant, plan) they belong to — the
    wire payload a multi-tenant operator can attribute."""
    ds, X, y, chunks, masks = _setup()
    spec = KernelSpec(X=X, gamma=ds.gamma, n=y.shape[0])
    plan = _fold_chain_plan({"k": spec}, y, masks, chunks, ds.C)
    service = StudyService(chunk_iters=64, lane_quantum=2, cache_bytes=1000)
    events = []
    service.submit("alice", "big", _wire(plan), events.append)
    (rej,) = events
    ctx = [f for f in rej["findings"] if f["rule"] == "cache-infeasible"]
    assert ctx and all(f["context"] == "alice/big" for f in ctx)


# ------------------------------------------------------- kill and resume

def test_kill_daemon_restart_resumes_under_different_width(tmp_path):
    """Snapshot mid-flight, abandon the service (the SIGKILL case: no
    drain), restart with a DIFFERENT width cap, resubmit the same
    (tenant, plan_id): restored lanes enter pre-solved, live lanes resume
    mid-chunk, and every lane lands on the solo run's exact bits."""
    ds, X, y, chunks, masks = _setup()
    gam = {s: DenseKernel(kernel_matrix(X, X, gamma=s * ds.gamma))
           for s in (0.5, 2.0)}
    plan = _fold_chain_plan(gam, y, masks, chunks, ds.C)
    solo = run_plan(plan)
    root = str(tmp_path / "ckpt")

    s1 = StudyService(chunk_iters=64, lane_quantum=2, max_width=0,
                      checkpoint_root=root)
    ev1 = []
    s1.submit("alice", "grid", _wire(plan), ev1.append)
    for _ in range(6):                    # partial progress, then "kill"
        s1.pool.step()
        s1._snapshot_tick()
    assert s1._studies                    # must still be mid-flight
    done_before = len(_events_of(ev1, "result"))

    s2 = StudyService(chunk_iters=64, lane_quantum=2, max_width=1,
                      checkpoint_root=root)
    ev2 = []
    s2.submit("alice", "grid", _wire(plan), ev2.append)
    (adm,) = _events_of(ev2, "admitted")
    assert adm["restored"] == done_before  # retired lanes came back solved
    _drain(s2)
    _assert_bit_identical(solo.results, _served_results(ev2))
    (done,) = _events_of(ev2, "done")
    assert {tuple(l): tuple(ct) for l, ct in done["evals"]} == \
        {k: v for k, v in solo.evals.items()}
    assert set(map(tuple, done["restored"])) == \
        {tuple(l) for l, _ in
         [(m["lane"], m) for m in _events_of(ev1, "result")]}


# ------------------------------------------------------ socket end-to-end

def test_socket_server_end_to_end(tmp_path):
    """The real daemon: AF_UNIX server thread, two StudyClient tenants,
    bit parity, status, rejection over the wire, graceful shutdown."""
    import uuid
    sock = f"/tmp/study-{uuid.uuid4().hex[:8]}.sock"   # AF_UNIX 108-byte cap
    ds, X, y, chunks, masks = _setup()
    K = kernel_matrix(X, X, gamma=ds.gamma)
    plan = _fold_chain_plan({"k": DenseKernel(K)}, y, masks, chunks, ds.C)
    solo = run_plan(plan)

    service = StudyService(chunk_iters=64, lane_quantum=2, max_width=0)
    server = StudyServer(sock, service)
    t = threading.Thread(target=server.serve_forever, daemon=True)
    t.start()
    import os
    import time
    for _ in range(200):
        if os.path.exists(sock):
            break
        time.sleep(0.05)
    try:
        with StudyClient(sock, "alice") as cli:
            assert cli.pool_contract["tol"] == 1e-3
            streamed = []
            served = cli.submit("p", plan,
                                on_result=lambda lid, r: streamed.append(lid))
            _assert_bit_identical(solo.results, served.results)
            assert served.evals == solo.evals
            assert set(streamed) == set(solo.results)
            assert served.tenant_stats["served"] > 0
            with pytest.raises(PlanRejectedByServer, match="tol"):
                cli.submit("q", dataclasses.replace(plan, tol=1e-5))
            status = cli.status()
            assert status["studies"] == []
            assert "alice" in status["tenants"]
            cli.shutdown()
        t.join(timeout=30)
        assert not t.is_alive()
    finally:
        server.stop_accepting()
        if os.path.exists(sock):
            os.unlink(sock)
