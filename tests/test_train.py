"""Training runtime: optimizers, microbatch equivalence, EF compression,
loss decreases on the synthetic bigram task."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.inputs import concrete_batch
from repro.models import init_params, model_params_def
from repro.training import build_train_step, get_optimizer
from repro.training.loss import sharded_xent


def _setup(arch="granite-8b"):
    cfg = get_config(arch, smoke=True)
    params = init_params(model_params_def(cfg), jax.random.PRNGKey(1),
                         jnp.float32)
    return cfg, params


@pytest.mark.parametrize("opt_name", ["adamw", "adafactor", "adam8bit"])
def test_optimizers_step(opt_name):
    cfg, params = _setup()
    opt = get_optimizer(opt_name)
    state = opt.init(params)
    step = build_train_step(cfg, None, opt, lr=1e-3)
    batch = concrete_batch(cfg, 4, 32)
    p2, s2, m = jax.jit(step)(params, state, batch)
    assert bool(jnp.isfinite(m["loss"]))
    delta = max(float(jnp.abs(a - b).max()) for a, b in
                zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert 0 < delta < 1.0


def test_microbatch_equivalence():
    """grad accumulation over 4 microbatches == single big batch (same data,
    same mean gradient) up to fp tolerance."""
    cfg, params = _setup()
    opt = get_optimizer("adamw")
    batch = concrete_batch(cfg, 8, 32)
    outs = {}
    for n_micro in (1, 4):
        step = build_train_step(cfg, None, opt, n_microbatches=n_micro,
                                lr=1e-3)
        p2, _, m = jax.jit(step)(params, opt.init(params), batch)
        outs[n_micro] = (p2, float(m["loss"]))
    # losses are means over the same tokens
    assert outs[1][1] == pytest.approx(outs[4][1], rel=1e-4)
    err = max(float(jnp.abs(a - b).max()) for a, b in
              zip(jax.tree.leaves(outs[1][0]), jax.tree.leaves(outs[4][0])))
    assert err < 5e-3


def test_int8_ef_compression_tracks_uncompressed():
    cfg, params = _setup()
    opt = get_optimizer("adamw")
    batch = concrete_batch(cfg, 8, 32)
    base = build_train_step(cfg, None, opt, n_microbatches=4, lr=1e-3)
    comp = build_train_step(cfg, None, opt, n_microbatches=4, lr=1e-3,
                            compress_grads="int8_ef")
    p1, _, m1 = jax.jit(base)(params, opt.init(params), batch)
    p2, _, m2 = jax.jit(comp)(params, opt.init(params), batch)
    assert m1["loss"] == pytest.approx(m2["loss"], rel=1e-5)
    # compressed update stays close (per-tensor int8 has ~1% granularity)
    num = sum(float(jnp.sum(jnp.square(a - b))) for a, b in
              zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    den = sum(float(jnp.sum(jnp.square(a - c))) for a, c in
              zip(jax.tree.leaves(p1), jax.tree.leaves(params)))
    assert num / max(den, 1e-20) < 0.15


def test_loss_decreases_bigram_task():
    cfg, params = _setup("xlstm-125m")
    opt = get_optimizer("adamw")
    state = opt.init(params)
    step = jax.jit(build_train_step(cfg, None, opt, lr=3e-3))
    from repro.data.tokens import synthetic_token_batch
    losses = []
    for i in range(30):
        b = synthetic_token_batch(cfg.vocab_size, 8, 32, seed=0, step=i)
        b = {k: jnp.asarray(v) for k, v in b.items()}
        params, state, m = step(params, state, b)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.5, losses


def test_sharded_xent_matches_dense():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(2, 5, 11)), jnp.float32)
    targets = jnp.asarray(rng.integers(0, 11, (2, 5)))
    mask = jnp.ones((2, 5), jnp.float32)
    ours = float(sharded_xent(logits, targets, mask))
    p = jax.nn.log_softmax(np.asarray(logits, np.float64), axis=-1)
    ref = -np.mean([p[b, s, targets[b, s]] for b in range(2) for s in range(5)])
    assert ours == pytest.approx(ref, rel=1e-5)
