"""SMO solver correctness: KKT optimality, invariants, warm-start exactness."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.svm_suite import make_dataset
from repro.svm import (dual_objective, init_f, kernel_matrix, smo_solve,
                       bias_from_solution, predict, accuracy)


def _setup(name="heart", n=120, C=None, gamma=None):
    ds = make_dataset(name, n_override=n)
    X = jnp.asarray(ds.X)
    y = jnp.asarray(ds.y, jnp.float64)
    K = kernel_matrix(X, X, gamma=gamma or ds.gamma)
    return ds, K, y


def test_kkt_at_solution():
    ds, K, y = _setup()
    n = y.shape[0]
    mask = jnp.ones(n, bool)
    res = smo_solve(K, y, mask, ds.C, jnp.zeros(n), -y, tol=1e-3)
    assert bool(res.converged)
    # optimality condition (paper Eq. 3): min f over I_up >= max f over I_low - tol
    assert float(res.b_low - res.b_up) <= 1e-3 + 1e-12


def test_constraints_hold():
    ds, K, y = _setup()
    n = y.shape[0]
    res = smo_solve(K, y, jnp.ones(n, bool), ds.C, jnp.zeros(n), -y)
    assert float(jnp.sum(res.alpha * y)) == pytest.approx(0.0, abs=1e-8)
    assert bool(jnp.all((res.alpha >= 0) & (res.alpha <= ds.C)))


def test_f_consistency_maintained():
    """The incremental f must equal its definition after the solve — the
    seeding algorithms rely on this (globally, incl. masked rows)."""
    ds, K, y = _setup()
    n = y.shape[0]
    mask = jnp.ones(n, bool).at[:20].set(False)
    res = smo_solve(K, y, mask, ds.C, jnp.zeros(n), -y)
    f_exact = init_f(K, y, res.alpha)
    assert float(jnp.abs(res.f - f_exact).max()) < 1e-6


def test_warm_start_from_optimum_is_free():
    ds, K, y = _setup()
    n = y.shape[0]
    mask = jnp.ones(n, bool)
    res = smo_solve(K, y, mask, ds.C, jnp.zeros(n), -y)
    warm = smo_solve(K, y, mask, ds.C, res.alpha, res.f)
    assert int(warm.n_iter) == 0


def test_objective_improves_vs_zero():
    ds, K, y = _setup()
    n = y.shape[0]
    res = smo_solve(K, y, jnp.ones(n, bool), ds.C, jnp.zeros(n), -y)
    assert float(dual_objective(K, y, res.alpha)) > 0.0


def test_brute_force_agreement():
    """Compare against a projected-gradient reference on a tiny problem."""
    rng = np.random.default_rng(0)
    n = 24
    X = rng.normal(size=(n, 3))
    y_np = np.sign(X[:, 0] + 0.3 * rng.normal(size=n)).astype(np.float64)
    y_np[y_np == 0] = 1.0
    X = jnp.asarray(X)
    y = jnp.asarray(y_np)
    C, gamma = 5.0, 0.5
    K = kernel_matrix(X, X, gamma=gamma)
    res = smo_solve(K, y, jnp.ones(n, bool), C, jnp.zeros(n), -y, tol=1e-6)
    # projected gradient ascent with equality projection (reference)
    Q = np.asarray(K) * np.outer(y_np, y_np)
    a = np.zeros(n)
    lr = 1.0 / (np.linalg.eigvalsh(Q).max() + 1.0)
    for _ in range(60000):
        g = 1.0 - Q @ a
        a = a + lr * g
        # project to {0<=a<=C, y.a=0} (alternating projection, few rounds)
        for _ in range(8):
            a = np.clip(a, 0, C)
            a = a - y_np * (y_np @ a) / n
        a = np.clip(a, 0, C)
    obj_ref = a.sum() - 0.5 * a @ Q @ a
    obj_smo = float(dual_objective(K, y, res.alpha))
    assert obj_smo >= obj_ref - 1e-3 * max(1.0, abs(obj_ref))


def test_predict_end_to_end():
    # n=500: below ~400 instances the adult-like task (gamma=0.5 over 123
    # dims -> K near identity) generalizes by luck; 500 is robustly learnable
    ds, K, y = _setup("adult", n=500)
    n = y.shape[0]
    mask = jnp.ones(n, bool).at[-50:].set(False)
    res = smo_solve(K, y, mask, ds.C, jnp.zeros(n), -y)
    b = bias_from_solution(res, y, mask, ds.C)
    pred = predict(K[-50:], y, res.alpha, b)
    acc = float(accuracy(pred, y[-50:]))
    assert acc > 0.5  # separable-ish synthetic task: far above chance
