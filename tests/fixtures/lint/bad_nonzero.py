"""Lint fixture: unsized ``jnp.nonzero`` inside a jitted body (shape
depends on values — retrace per input under jit)."""
import jax
import jax.numpy as jnp


@jax.jit
def support_vectors(alpha):
    (idx,) = jnp.nonzero(alpha > 0)
    return idx


@jax.jit
def support_vectors_sized(alpha):
    (idx,) = jnp.nonzero(alpha > 0, size=alpha.shape[0], fill_value=-1)
    return idx
