"""Lint fixture: a Pallas launcher violating every kernel_lint rule —
hard-coded interpret default, grid divisor with no ragged-tail pad,
VMEM-blowing block sizes, fixed-f32 scratch, and a kernel-body dot with
no preferred_element_type."""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _bad_kernel(x_ref, w_ref, o_ref, acc_ref):
    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...])
    o_ref[...] = acc_ref[...]


def bad_matmul(x, w, *, bm=2048, bk=2048, interpret=True):
    M, K = x.shape
    N = w.shape[1]
    return pl.pallas_call(
        _bad_kernel,
        grid=(M // bm, K // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
            pl.BlockSpec((bk, N), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, N), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, N), jnp.float32)],
        interpret=interpret,
    )(x, w)
