"""Lint fixture: a perf_counter section that times async dispatch.

Not collected by pytest (no test_ prefix); scripts/repro_lint.py --paths
runs the linters on it and must exit nonzero, which is what the CI
self-test checks.
"""
import time

import jax.numpy as jnp


def timed_norm(x):
    t0 = time.perf_counter()
    y = jnp.linalg.norm(x)          # dispatch only — nothing blocks
    elapsed = time.perf_counter() - t0
    return y, elapsed


def timed_norm_synced(x):
    import jax
    t0 = time.perf_counter()
    y = jax.block_until_ready(jnp.linalg.norm(x))
    elapsed = time.perf_counter() - t0
    return y, elapsed
