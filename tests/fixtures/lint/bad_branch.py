"""Lint fixture: Python control flow and host casts on traced values in
jitted bodies (ConcretizationTypeError at trace time, or silent retraces)."""
import functools

import jax
import jax.numpy as jnp


@jax.jit
def clip_if_large(x, limit):
    if x.max() > limit:                 # Python branch on a traced value
        return jnp.clip(x, -limit, limit)
    return x


@functools.partial(jax.jit, static_argnames=("scale",))
def host_cast(x, scale):
    return x * float(x.mean()) * scale  # host cast forces a device sync


@jax.jit
def static_branch_ok(x):
    if x.ndim > 1:                      # shape is static under jit — fine
        x = x.reshape(-1)
    return x
