"""Checkpointing: roundtrip, crash consistency, retention, async, CV resume,
and elastic (mesh-changing) restore in a multi-device subprocess."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree


def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones((5,), jnp.int32),
                       "c": jnp.asarray(3.5)}}


def test_roundtrip(tmp_path):
    path = str(tmp_path / "ck")
    save_pytree(path, _tree(), {"step": 7})
    restored, extra = load_pytree(path, target=_tree())
    assert extra["step"] == 7
    for a, b in zip(jax.tree.leaves(_tree()), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_uncommitted_checkpoint_ignored(tmp_path):
    """A writer killed mid-save must never corrupt the latest checkpoint."""
    mgr = CheckpointManager(str(tmp_path), max_to_keep=5)
    mgr.save(1, _tree(), {"ok": True})
    # simulate a partial write: directory without COMMIT marker
    bad = os.path.join(str(tmp_path), "step_0000000002")
    os.makedirs(bad)
    with open(os.path.join(bad, "meta.json"), "w") as fh:
        json.dump({}, fh)
    assert mgr.latest_step() == 1
    step, tree, extra = mgr.restore()
    assert step == 1 and extra["ok"]


def test_retention_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), max_to_keep=2)
    for s in range(5):
        mgr.save(s, _tree())
    assert mgr.all_steps() == [3, 4]


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, _tree(), blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 3


def test_cv_resume_matches_uninterrupted(tmp_path):
    """Kill the CV driver after fold 2; the restarted run must produce the
    same per-fold results (the alpha chain doubles as the restart seed)."""
    from repro.core.cv import run_cv
    from repro.data.svm_suite import make_dataset
    ds = make_dataset("heart", n_override=100)
    full = run_cv(ds, k=5, method="sir")

    mgr = CheckpointManager(str(tmp_path / "cv"))
    # run folds 0-2 then 'crash' (we emulate by a k-limited driver call that
    # checkpoints each fold)
    partial = run_cv(ds, k=5, method="sir", checkpoint_manager=mgr)
    # wipe in-memory state; resume from checkpoint: folds 0-4 cached ->
    # restart sees fold 4 as latest, nothing to do; emulate mid-run crash by
    # removing the last two fold checkpoints
    for s in mgr.all_steps()[-2:]:
        import shutil
        shutil.rmtree(mgr._step_dir(s))
    mgr2 = CheckpointManager(str(tmp_path / "cv"))
    resumed = run_cv(ds, k=5, method="sir", checkpoint_manager=mgr2)
    # resumed run recomputes folds 3-4 only, seeded from checkpointed fold 2
    assert [f.fold for f in resumed.folds] == [3, 4]
    for f_full, f_res in zip(full.folds[3:], resumed.folds):
        assert f_full.acc_correct == f_res.acc_correct
        assert f_full.n_iter == f_res.n_iter


def test_cv_mid_fold_resume(tmp_path):
    """Chunked dispatch checkpoints INSIDE a fold: crash after a few chunks
    of fold 2 and the restarted run resumes that fold's iterate sequence
    (same n_iter account, same accuracy) instead of replaying it."""
    from repro.core.cv import run_cv, _FOLD_STRIDE
    from repro.data.svm_suite import make_dataset
    ds = make_dataset("heart", n_override=100)
    full = run_cv(ds, k=5, method="sir")

    mgr = CheckpointManager(str(tmp_path / "cv"), max_to_keep=1000)
    chunked = run_cv(ds, k=5, method="sir", checkpoint_manager=mgr,
                     chunk_iters=50)
    # chunking must not change results at all
    for f_full, f_ch in zip(full.folds, chunked.folds):
        assert f_full.n_iter == f_ch.n_iter
        assert f_full.acc_correct == f_ch.acc_correct
    # 'crash' mid fold 2: drop everything after its second chunk snapshot
    mids = [s for s in mgr.all_steps() if s % _FOLD_STRIDE != 0
            and s // _FOLD_STRIDE == 2]
    assert len(mids) >= 2, "fold 2 should span several 50-iter chunks"
    import shutil
    for s in mgr.all_steps():
        if s > mids[1]:
            shutil.rmtree(mgr._step_dir(s))
    mgr2 = CheckpointManager(str(tmp_path / "cv"), max_to_keep=1000)
    resumed = run_cv(ds, k=5, method="sir", checkpoint_manager=mgr2,
                     chunk_iters=50)
    assert [f.fold for f in resumed.folds] == [2, 3, 4]
    for f_full, f_res in zip(full.folds[2:], resumed.folds):
        assert f_full.n_iter == f_res.n_iter
        assert f_full.acc_correct == f_res.acc_correct
    # the resumed fold still records its original seed provenance
    assert resumed.folds[0].seed_from == 1


ELASTIC_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import warnings; warnings.filterwarnings("ignore")
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P, AxisType
    from repro.checkpoint import CheckpointManager

    d = os.environ["CKPT_DIR"]
    mgr = CheckpointManager(d)
    mesh = jax.make_mesh((MESHA, MESHB), ("data", "model"),
                         axis_types=(AxisType.Auto,)*2)
    x = jax.device_put(jnp.arange(64.0).reshape(8, 8),
                       NamedSharding(mesh, P("data", "model")))
    tree = {"w": x}
    if os.environ["MODE"] == "save":
        mgr.save(1, tree, {"mesh": [MESHA, MESHB]})
    else:
        step, restored, extra = mgr.restore(target=tree)
        assert extra["mesh"] != [MESHA, MESHB]
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(jnp.arange(64.0).reshape(8, 8)))
        assert restored["w"].sharding.mesh.shape["data"] == MESHA
    print("OK")
""")


@pytest.mark.skipif(not hasattr(jax.sharding, "AxisType"),
                    reason="subprocess harness uses jax.sharding.AxisType / "
                           "make_mesh(axis_types=...); needs jax >= 0.5")
@pytest.mark.parametrize("save_mesh,restore_mesh", [((4, 2), (2, 4)),
                                                    ((8, 1), (2, 4))])
def test_elastic_restore_across_meshes(tmp_path, save_mesh, restore_mesh):
    """Save on one mesh, restore onto a different one (elastic scaling)."""
    env = dict(os.environ, CKPT_DIR=str(tmp_path / "el"),
               PYTHONPATH="src")
    for mode, mesh in (("save", save_mesh), ("restore", restore_mesh)):
        script = ELASTIC_SCRIPT.replace("MESHA", str(mesh[0])) \
                               .replace("MESHB", str(mesh[1]))
        env["MODE"] = mode
        out = subprocess.run([sys.executable, "-c", script], env=env,
                             capture_output=True, text=True, cwd=os.getcwd(),
                             timeout=300)
        assert out.returncode == 0, out.stderr[-2000:]
        assert "OK" in out.stdout
