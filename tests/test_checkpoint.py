"""Checkpointing: roundtrip, crash consistency, retention, async, CV resume,
and elastic (mesh-changing) restore in a multi-device subprocess."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree


def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones((5,), jnp.int32),
                       "c": jnp.asarray(3.5)}}


def test_roundtrip(tmp_path):
    path = str(tmp_path / "ck")
    save_pytree(path, _tree(), {"step": 7})
    restored, extra = load_pytree(path, target=_tree())
    assert extra["step"] == 7
    for a, b in zip(jax.tree.leaves(_tree()), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_uncommitted_checkpoint_ignored(tmp_path):
    """A writer killed mid-save must never corrupt the latest checkpoint."""
    mgr = CheckpointManager(str(tmp_path), max_to_keep=5)
    mgr.save(1, _tree(), {"ok": True})
    # simulate a partial write: directory without COMMIT marker
    bad = os.path.join(str(tmp_path), "step_0000000002")
    os.makedirs(bad)
    with open(os.path.join(bad, "meta.json"), "w") as fh:
        json.dump({}, fh)
    assert mgr.latest_step() == 1
    step, tree, extra = mgr.restore()
    assert step == 1 and extra["ok"]


def test_retention_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), max_to_keep=2)
    for s in range(5):
        mgr.save(s, _tree())
    assert mgr.all_steps() == [3, 4]


def test_retention_classes_gc_independently(tmp_path):
    """max_to_keep applies PER retain_class: a stream of frequent "mid"
    snapshots must not evict the rare "done" records."""
    mgr = CheckpointManager(str(tmp_path), max_to_keep=2)
    for s in range(3):
        mgr.save(s, _tree(), retain_class="done")
    for s in range(10, 16):
        mgr.save(s, _tree(), retain_class="mid")
    assert mgr.all_steps() == [1, 2, 14, 15]
    # a fresh manager (post-crash) learns the classes back from meta.json
    mgr2 = CheckpointManager(str(tmp_path), max_to_keep=2)
    mgr2.save(16, _tree(), retain_class="mid")
    assert mgr2.all_steps() == [1, 2, 15, 16]


def test_cv_mid_snapshots_do_not_evict_done_records(tmp_path):
    """Default retention + chunked dispatch: fold 4's many chunk snapshots
    used to GC away every earlier fold's done record, making resumed
    reports permanently partial in exactly the configuration where
    checkpointing matters most."""
    from repro.core.cv import run_cv, _FOLD_STRIDE
    from repro.data.svm_suite import make_dataset
    ds = make_dataset("heart", n_override=100)
    mgr = CheckpointManager(str(tmp_path / "cv"))   # default max_to_keep=3
    run_cv(ds, k=5, method="sir", checkpoint_manager=mgr, chunk_iters=50)
    done = [s for s in mgr.all_steps() if s % _FOLD_STRIDE == 0]
    assert len(done) == 3   # the newest 3 done records survived the mids


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, _tree(), blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 3


def test_cv_resume_matches_uninterrupted(tmp_path):
    """Kill the CV driver after fold 2; the restarted run must return the
    SAME report as an uninterrupted run — every retained done record is
    restored (not just the latest), so totals/accuracy agree and only folds
    3-4 are recomputed (the alpha chain doubles as the restart seed)."""
    from repro.core.cv import run_cv
    from repro.data.svm_suite import make_dataset
    ds = make_dataset("heart", n_override=100)
    full = run_cv(ds, k=5, method="sir")

    mgr = CheckpointManager(str(tmp_path / "cv"), max_to_keep=100)
    run_cv(ds, k=5, method="sir", checkpoint_manager=mgr)
    # emulate a crash after fold 2 by removing the last two fold checkpoints
    for s in mgr.all_steps()[-2:]:
        import shutil
        shutil.rmtree(mgr._step_dir(s))
    mgr2 = CheckpointManager(str(tmp_path / "cv"), max_to_keep=100)
    resumed = run_cv(ds, k=5, method="sir", checkpoint_manager=mgr2)
    assert [f.fold for f in resumed.folds] == [0, 1, 2, 3, 4]
    assert [f.restored for f in resumed.folds] == [True] * 3 + [False] * 2
    assert not resumed.partial
    for f_full, f_res in zip(full.folds, resumed.folds):
        assert f_full.acc_correct == f_res.acc_correct
        assert f_full.n_iter == f_res.n_iter
        assert f_full.seed_from == f_res.seed_from
        assert f_full.converged == f_res.converged
    # the report-level aggregates no longer silently disagree
    assert resumed.total_iterations == full.total_iterations
    assert resumed.accuracy == full.accuracy


def test_cv_resume_partial_report_flagged(tmp_path):
    """When retention GC dropped the early done records, the resumed report
    cannot cover every fold — it must say so instead of passing off partial
    totals as a full run."""
    from repro.core.cv import run_cv
    from repro.data.svm_suite import make_dataset
    ds = make_dataset("heart", n_override=100)
    mgr = CheckpointManager(str(tmp_path / "cv"))   # default max_to_keep=3
    run_cv(ds, k=5, method="sir", checkpoint_manager=mgr)
    import shutil
    for s in mgr.all_steps()[-2:]:
        shutil.rmtree(mgr._step_dir(s))             # only fold 2 retained
    mgr2 = CheckpointManager(str(tmp_path / "cv"))
    resumed = run_cv(ds, k=5, method="sir", checkpoint_manager=mgr2)
    assert [f.fold for f in resumed.folds] == [2, 3, 4]
    assert resumed.folds[0].restored
    assert resumed.partial
    assert not run_cv(ds, k=5, method="sir").partial


def test_cv_resume_other_method_seeds_but_stays_out_of_report(tmp_path):
    """A done record from a different method is a legitimate seed (the
    fixed point is method-independent) but its n_iter is that method's
    trajectory: it must NOT be republished as this report's per-method
    iteration count (the paper's headline metric)."""
    from repro.core.cv import run_cv
    from repro.data.svm_suite import make_dataset
    ds = make_dataset("heart", n_override=100)
    mgr = CheckpointManager(str(tmp_path / "cv"), max_to_keep=100)
    run_cv(ds, k=5, method="cold", checkpoint_manager=mgr)
    import shutil
    for s in mgr.all_steps()[-2:]:
        shutil.rmtree(mgr._step_dir(s))
    mgr2 = CheckpointManager(str(tmp_path / "cv"), max_to_keep=100)
    resumed = run_cv(ds, k=5, method="sir", checkpoint_manager=mgr2)
    # folds 3-4 are recomputed under sir (fold 3 seeded from cold's fold-2
    # fixed point — that part is sound); cold's folds 0-2 seed the chain
    # but stay out of the sir-labelled report, which says so via partial
    assert [f.fold for f in resumed.folds] == [3, 4]
    assert not any(f.restored for f in resumed.folds)
    assert resumed.folds[0].seed_from == 2
    assert resumed.partial


def test_cv_resume_unchunked_run_with_chunking(tmp_path):
    """Regression: done records use the strided numbering unconditionally,
    so a run checkpointed WITHOUT chunk_iters resumes correctly WITH it.
    (Unchunked runs used to save fold h at step h while the restore path
    assumed (h+1)*_FOLD_STRIDE, leaving mid-snapshot provenance pointing at
    nonexistent steps and silently degrading strict seeding to cold.)"""
    from repro.core.cv import run_cv, _FOLD_STRIDE
    from repro.data.svm_suite import make_dataset
    ds = make_dataset("heart", n_override=100)
    full = run_cv(ds, k=5, method="sir", chunk_iters=50)

    mgr = CheckpointManager(str(tmp_path / "cv"), max_to_keep=100)
    run_cv(ds, k=5, method="sir", checkpoint_manager=mgr)   # unchunked
    assert all(s % _FOLD_STRIDE == 0 for s in mgr.all_steps())
    import shutil
    for s in mgr.all_steps()[-2:]:
        shutil.rmtree(mgr._step_dir(s))
    mgr2 = CheckpointManager(str(tmp_path / "cv"), max_to_keep=100)
    resumed = run_cv(ds, k=5, method="sir", checkpoint_manager=mgr2,
                     chunk_iters=50)
    assert [f.fold for f in resumed.folds] == [0, 1, 2, 3, 4]
    # strict seeding provenance survives the chunking-mode change
    assert resumed.folds[3].seed_from == 2
    assert resumed.folds[4].seed_from == 3
    for f_full, f_res in zip(full.folds, resumed.folds):
        assert f_full.acc_correct == f_res.acc_correct
        assert f_full.n_iter == f_res.n_iter
    assert resumed.total_iterations == full.total_iterations


def test_cv_mid_fold_resume(tmp_path):
    """Chunked dispatch checkpoints INSIDE a fold: crash after a few chunks
    of fold 2 and the restarted run resumes that fold's iterate sequence
    (same n_iter account, same accuracy) instead of replaying it."""
    from repro.core.cv import run_cv, _FOLD_STRIDE
    from repro.data.svm_suite import make_dataset
    ds = make_dataset("heart", n_override=100)
    full = run_cv(ds, k=5, method="sir")

    mgr = CheckpointManager(str(tmp_path / "cv"), max_to_keep=1000)
    chunked = run_cv(ds, k=5, method="sir", checkpoint_manager=mgr,
                     chunk_iters=50)
    # chunking must not change results at all
    for f_full, f_ch in zip(full.folds, chunked.folds):
        assert f_full.n_iter == f_ch.n_iter
        assert f_full.acc_correct == f_ch.acc_correct
    # 'crash' mid fold 2: drop everything after its second chunk snapshot
    mids = [s for s in mgr.all_steps() if s % _FOLD_STRIDE != 0
            and s // _FOLD_STRIDE == 2]
    assert len(mids) >= 2, "fold 2 should span several 50-iter chunks"
    import shutil
    for s in mgr.all_steps():
        if s > mids[1]:
            shutil.rmtree(mgr._step_dir(s))
    mgr2 = CheckpointManager(str(tmp_path / "cv"), max_to_keep=1000)
    resumed = run_cv(ds, k=5, method="sir", checkpoint_manager=mgr2,
                     chunk_iters=50)
    # folds 0-1 come back from their done records; fold 2 resumes mid-flight
    assert [f.fold for f in resumed.folds] == [0, 1, 2, 3, 4]
    assert [f.restored for f in resumed.folds] == [True, True] + [False] * 3
    for f_full, f_res in zip(full.folds, resumed.folds):
        assert f_full.n_iter == f_res.n_iter
        assert f_full.acc_correct == f_res.acc_correct
    # the resumed fold still records its original seed provenance
    assert resumed.folds[2].seed_from == 1
    assert resumed.accuracy == full.accuracy


ELASTIC_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import warnings; warnings.filterwarnings("ignore")
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P, AxisType
    from repro.checkpoint import CheckpointManager

    d = os.environ["CKPT_DIR"]
    mgr = CheckpointManager(d)
    mesh = jax.make_mesh((MESHA, MESHB), ("data", "model"),
                         axis_types=(AxisType.Auto,)*2)
    x = jax.device_put(jnp.arange(64.0).reshape(8, 8),
                       NamedSharding(mesh, P("data", "model")))
    tree = {"w": x}
    if os.environ["MODE"] == "save":
        mgr.save(1, tree, {"mesh": [MESHA, MESHB]})
    else:
        step, restored, extra = mgr.restore(target=tree)
        assert extra["mesh"] != [MESHA, MESHB]
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(jnp.arange(64.0).reshape(8, 8)))
        assert restored["w"].sharding.mesh.shape["data"] == MESHA
    print("OK")
""")


@pytest.mark.skipif(not hasattr(jax.sharding, "AxisType"),
                    reason="subprocess harness uses jax.sharding.AxisType / "
                           "make_mesh(axis_types=...); needs jax >= 0.5")
@pytest.mark.parametrize("save_mesh,restore_mesh", [((4, 2), (2, 4)),
                                                    ((8, 1), (2, 4))])
def test_elastic_restore_across_meshes(tmp_path, save_mesh, restore_mesh):
    """Save on one mesh, restore onto a different one (elastic scaling)."""
    env = dict(os.environ, CKPT_DIR=str(tmp_path / "el"),
               PYTHONPATH="src")
    for mode, mesh in (("save", save_mesh), ("restore", restore_mesh)):
        script = ELASTIC_SCRIPT.replace("MESHA", str(mesh[0])) \
                               .replace("MESHB", str(mesh[1]))
        env["MODE"] = mode
        out = subprocess.run([sys.executable, "-c", script], env=env,
                             capture_output=True, text=True, cwd=os.getcwd(),
                             timeout=300)
        assert out.returncode == 0, out.stderr[-2000:]
        assert "OK" in out.stdout
