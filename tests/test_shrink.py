"""Active-set shrinking (DESIGN.md §Shrinking): full-set optimality
contract across the suite, shrink-off bit-parity, schedule/quantum
determinism, pool parity at every width, mid-shrink kill/resume under a
different schedule shape AND cap bucket, SV-only evaluation, cap-aware
static-analysis calibration, and the cost-model gate."""
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.core.cv import _fold_masks, run_cv
from repro.core.grid import grid_plans, run_grid
from repro.core.study import Plan, StudyCheckpoint, run_plan
from repro.analysis.plan_check import analyze_plan
from repro.data.svm_suite import kfold_chunks, make_dataset
from repro.svm import (DenseKernel, LanePool, PallasRBF, cost_model,
                       kernel_matrix, shrink, smo_solve)
from repro.svm.engine import (chunk_batched_jit, chunk_batched_sources_jit,
                              chunk_jit, optimality, solve)
from repro.svm.smo import dual_objective

SUITE = ("adult", "heart", "madelon", "mnist", "webdata")


def _setup(name, n=120, k=3):
    ds = make_dataset(name, n_override=n)
    X = jnp.asarray(ds.X)
    y = jnp.asarray(ds.y, jnp.float64)
    chunks = kfold_chunks(n, k, seed=0)
    nn = chunks.size
    K = kernel_matrix(X[:nn], X[:nn], gamma=ds.gamma)
    return ds, K, y[:nn], chunks, jnp.asarray(_fold_masks(chunks))


# ------------------------------------------------------ bucketing helpers

def test_cap_helpers():
    assert shrink.bucket_cap(1, 128) == 128
    assert shrink.bucket_cap(129, 128) == 256
    assert shrink.bucket_cap(80, 32) == 96
    # entry gate: no compaction when the bucket would not be < n
    assert shrink.pick_cap(100, 120, 128) is None
    assert shrink.pick_cap(80, 120, 32) == 96
    # declared caps: smallest fitting declared bucket wins
    assert shrink.pick_cap(80, 120, 32, caps=(96,)) == 96
    assert shrink.pick_cap(100, 120, 32, caps=(96,)) is None
    assert shrink.possible_caps(120, 32) == (32, 64, 96)
    assert shrink.possible_caps(120, 32, caps=(96, 64)) == (64, 96)


# ------------------------------------------ full-set optimality contract

@pytest.mark.parametrize("name", SUITE)
def test_solve_shrunk_full_set_contract(name):
    """Shrinking is a schedule transformation: on every suite dataset the
    shrunk solve must land on the same SV set, a dual objective within
    dtype tolerance, a full-set KKT gap <= tol, and an f consistent with
    its alpha — the SMOResult contract is over the FULL set."""
    ds, K, y, chunks, masks = _setup(name)
    n = y.shape[0]
    src = DenseKernel(K)
    ref = solve(src, y, masks[0], ds.C, jnp.zeros(n, K.dtype), -y)
    got = shrink.solve_shrunk(src, y, masks[0], ds.C,
                              jnp.zeros(n, K.dtype), -y,
                              shrink_every=64, shrink_quantum=32)
    assert bool(got.converged)
    sv_ref = np.asarray(ref.alpha) > 0
    sv_got = np.asarray(got.alpha) > 0
    np.testing.assert_array_equal(sv_ref, sv_got)
    obj_ref = float(dual_objective(K, y, ref.alpha))
    obj_got = float(dual_objective(K, y, got.alpha))
    assert abs(obj_ref - obj_got) <= 1e-6 * max(1.0, abs(obj_ref))
    _, _, gap = optimality(got.alpha, got.f, y, masks[0], ds.C)
    assert float(gap) <= 1e-3
    f_re = K @ (got.alpha * y) - y
    np.testing.assert_allclose(np.asarray(f_re), np.asarray(got.f),
                               atol=1e-10)


def test_shrink_off_is_bit_identical():
    """shrink_every=0 must not change a single bit — it dispatches exactly
    the pre-shrinking programs."""
    ds, K, y, chunks, masks = _setup("heart")
    n = y.shape[0]
    src = DenseKernel(K)
    ref = solve(src, y, masks[0], ds.C, jnp.zeros(n, K.dtype), -y)
    got = shrink.solve_shrunk(src, y, masks[0], ds.C,
                              jnp.zeros(n, K.dtype), -y, shrink_every=0)
    np.testing.assert_array_equal(np.asarray(ref.alpha),
                                  np.asarray(got.alpha))
    np.testing.assert_array_equal(np.asarray(ref.f), np.asarray(got.f))
    assert int(ref.n_iter) == int(got.n_iter)


def test_shrunk_iterates_deterministic_across_chunks_and_quantum():
    """The compact iterate sequence is a pure function of the active
    VALUES: chunk granularity (schedule shape) and cap bucketing (pad
    width) must not change a single output bit."""
    ds, K, y, chunks, masks = _setup("heart")
    n = y.shape[0]
    src = DenseKernel(K)
    base = shrink.solve_shrunk(src, y, masks[0], ds.C,
                               jnp.zeros(n, K.dtype), -y,
                               shrink_every=64, shrink_quantum=32,
                               chunk_iters=256)
    for kw in (dict(chunk_iters=97, shrink_quantum=32),
               dict(chunk_iters=256, shrink_quantum=16),
               dict(chunk_iters=97, shrink_quantum=16)):
        got = shrink.solve_shrunk(src, y, masks[0], ds.C,
                                  jnp.zeros(n, K.dtype), -y,
                                  shrink_every=64, **kw)
        np.testing.assert_array_equal(np.asarray(base.alpha),
                                      np.asarray(got.alpha))
        np.testing.assert_array_equal(np.asarray(base.f),
                                      np.asarray(got.f))
        assert int(base.n_iter) == int(got.n_iter)


def test_pallas_source_shrinks():
    """The row-streaming source shrinks through the same machinery: the
    compact gather slices X (active bytes only), reconstruction uses the
    streaming matvec, and the full-set contract holds."""
    ds, K, y, chunks, masks = _setup("heart")
    n = y.shape[0]
    X = jnp.asarray(ds.X)[:n]
    src = PallasRBF(X, ds.gamma)
    ref = solve(src, y, masks[0], ds.C, jnp.zeros(n, src.dtype), -y,
                wss="1")
    got = shrink.solve_shrunk(src, y, masks[0], ds.C,
                              jnp.zeros(n, src.dtype), -y, wss="1",
                              shrink_every=64, shrink_quantum=32)
    assert bool(got.converged)
    np.testing.assert_array_equal(np.asarray(ref.alpha) > 0,
                                  np.asarray(got.alpha) > 0)
    _, _, gap = optimality(got.alpha, got.f, y, masks[0], ds.C)
    assert float(gap) <= 1e-3


# ----------------------------------------------------------- pool parity

@pytest.mark.parametrize("max_width", [1, 2])
def test_pool_shrink_matches_solo_driver(max_width):
    """The pool's (source, cap)-grouped dispatch must be bit-identical to
    the reference solo driver at every width — batching compact lanes via
    chunk_batched_sources_jit is a schedule choice, not a math change."""
    ds, K, y, chunks, masks = _setup("heart")
    n = y.shape[0]
    pool = LanePool({"k": DenseKernel(K)}, y, chunk_iters=256,
                    max_width=max_width, shrink_every=64, shrink_quantum=32)
    for h in range(3):
        pool.add(h, masks[h], ds.C, jnp.zeros(n, K.dtype), -y, source="k")
    results = pool.run()
    for h in range(3):
        solo = shrink.solve_shrunk(DenseKernel(K), y, masks[h], ds.C,
                                   jnp.zeros(n, K.dtype), -y,
                                   shrink_every=64, shrink_quantum=32,
                                   chunk_iters=256)
        np.testing.assert_array_equal(np.asarray(solo.alpha),
                                      np.asarray(results[h].alpha))
        np.testing.assert_array_equal(np.asarray(solo.f),
                                      np.asarray(results[h].f))
        assert int(solo.n_iter) == int(results[h].n_iter)
    occ = pool.occupancy
    assert occ["shrink_lane_chunks"] > 0
    assert 0.0 < occ["mean_active_frac"] <= 1.0


def test_pool_shrink_off_matches_baseline_bitwise():
    """A shrink-capable pool with shrink_every=0 must dispatch exactly the
    historical schedule: same results, same program-tuple shapes."""
    ds, K, y, chunks, masks = _setup("heart")
    n = y.shape[0]
    pool = LanePool({"k": DenseKernel(K)}, y, chunk_iters=256, max_width=1,
                    shrink_every=0)
    for h in range(3):
        pool.add(h, masks[h], ds.C, jnp.zeros(n, K.dtype), -y, source="k")
    results = pool.run()
    for h in range(3):
        seq = smo_solve(K, y, masks[h], ds.C, jnp.zeros(n), -y)
        np.testing.assert_array_equal(np.asarray(seq.alpha),
                                      np.asarray(results[h].alpha))
    assert all(len(p) == 2 for p in pool._programs)   # (key, width) only
    assert "mean_active_frac" not in pool.occupancy


# -------------------------------------------------- seeding -> shrinking

def test_seed_active_mask_cold_start_keeps_everything():
    """A cold start (alpha=0, f=-y) has no bound-locked rows against its
    own (b_up, b_low): the handoff must keep the full set active."""
    ds, K, y, chunks, masks = _setup("heart")
    n = y.shape[0]
    active = shrink.seed_active_mask(jnp.zeros(n), -y, y, masks[0], ds.C)
    np.testing.assert_array_equal(np.asarray(active),
                                  np.asarray(masks[0]))
    # the seeding-layer re-export is the same function
    from repro.core import seeding
    assert seeding.seed_active_mask is shrink.seed_active_mask


def test_seeded_admission_starts_shrunk():
    """A seeded lane whose start point bound-locks rows enters the pool
    already compact (shrink_on_seed), and still lands on the reference
    fixed point's SV set."""
    ds, K, y, chunks, masks = _setup("heart")
    n = y.shape[0]
    ref0 = smo_solve(K, y, masks[0], ds.C, jnp.zeros(n), -y)
    active = shrink.seed_active_mask(ref0.alpha, ref0.f, y, masks[0], ds.C)
    assert int(jnp.sum(active)) < int(jnp.sum(masks[0]))


# ------------------------------------------------- mid-shrink kill/resume

def _shrink_plan(K, y, masks, C, *, max_width=0, shrink_quantum=32):
    plan = Plan(sources={"k": DenseKernel(K)}, y=y, chunk_iters=64,
                lane_quantum=2, max_width=max_width,
                shrink_every=64, shrink_quantum=shrink_quantum)
    n = y.shape[0]
    for h in range(3):
        plan.lane(h, source="k", train_mask=masks[h], C=C,
                  alpha0=jnp.zeros(n), f0=-y)
    return plan


def test_mid_shrink_kill_resume_new_schedule_and_cap(tmp_path):
    """Kill a checkpointed shrink-enabled study while lanes are compact;
    resume under a DIFFERENT schedule shape (width-1 vs unbounded) AND a
    different cap bucket (quantum 16 vs 32 re-buckets the restored active
    mask). The compact iterate sequence depends only on the active VALUES,
    so every lane must land on the bit-identical final (alpha, f)."""
    ds, K, y, chunks, masks = _setup("heart")
    full = run_plan(_shrink_plan(K, y, masks, ds.C))

    mgr = CheckpointManager(str(tmp_path / "shrink"), max_to_keep=1000)
    ck = StudyCheckpoint(manager=mgr, meta={"k": 3, "dataset": "heart"})
    run_plan(_shrink_plan(K, y, masks, ds.C), checkpoint=ck)
    steps = mgr.steps_of_class("study")
    assert len(steps) >= 3
    # crash half-way: the surviving snapshot holds mid-compact lanes — its
    # tree must carry the shrink ledger keys
    keep = steps[: max(1, len(steps) // 2)]
    _, tree, _ = mgr.restore(step=keep[-1])
    for key in ("active", "shrunk", "no_shrink", "unshrinks"):
        assert key in tree, sorted(tree)
    assert np.asarray(tree["shrunk"]).any(), \
        "crash point must catch at least one lane mid-compact"
    for s in steps[len(keep):]:
        shutil.rmtree(mgr._step_dir(s))

    mgr2 = CheckpointManager(str(tmp_path / "shrink"), max_to_keep=1000)
    ck2 = StudyCheckpoint(manager=mgr2, meta={"k": 3, "dataset": "heart"})
    resumed = run_plan(_shrink_plan(K, y, masks, ds.C, max_width=1,
                                    shrink_quantum=16), checkpoint=ck2)
    for h in range(3):
        np.testing.assert_array_equal(np.asarray(full.results[h].alpha),
                                      np.asarray(resumed.results[h].alpha))
        np.testing.assert_array_equal(np.asarray(full.results[h].f),
                                      np.asarray(resumed.results[h].f))
        assert full.stats[h].n_iter == resumed.stats[h].n_iter


def test_shrink_off_snapshots_have_no_ledger(tmp_path):
    """Shrink-off studies must write byte-compatible (pre-shrinking)
    snapshot trees: no ledger keys."""
    ds, K, y, chunks, masks = _setup("heart")
    plan = Plan(sources={"k": DenseKernel(K)}, y=y, chunk_iters=64)
    n = y.shape[0]
    plan.lane(0, source="k", train_mask=masks[0], C=ds.C,
              alpha0=jnp.zeros(n), f0=-y)
    mgr = CheckpointManager(str(tmp_path / "off"), max_to_keep=1000)
    ck = StudyCheckpoint(manager=mgr, meta={"k": 3})
    run_plan(plan, checkpoint=ck)
    _, tree, _ = mgr.restore(step=mgr.steps_of_class("study")[-1])
    assert not {"active", "shrunk", "no_shrink", "unshrinks"} & set(tree)


# --------------------------------------------------- drivers and facades

def test_run_cv_shrink_matches_baseline_accuracy():
    ds = make_dataset("heart", n_override=120)
    base = run_cv(ds, k=3, method="ato")
    got = run_cv(ds, k=3, method="ato", shrink_every=64, shrink_quantum=32)
    accs = lambda r: sorted((f.fold, f.acc_correct) for f in r.folds)
    assert accs(base) == accs(got)
    assert got.occupancy["mean_active_frac"] <= 1.0


def test_run_cv_rejects_shrink_with_midfold_checkpoints(tmp_path):
    ds = make_dataset("heart", n_override=120)
    mgr = CheckpointManager(str(tmp_path / "cv"), max_to_keep=10)
    with pytest.raises(ValueError, match="shrink ledger"):
        run_cv(ds, k=3, shrink_every=64, chunk_iters=64,
               checkpoint_manager=mgr)


def test_svc_shrink_fit_same_svs():
    ds = make_dataset("heart", n_override=100)
    from repro.svm import SVC
    base = SVC(C=ds.C, gamma=ds.gamma).fit(ds.X[:100], ds.y[:100])
    got = SVC(C=ds.C, gamma=ds.gamma, shrink_every=64,
              shrink_quantum=32).fit(ds.X[:100], ds.y[:100])
    np.testing.assert_array_equal(np.asarray(base.result_.alpha) > 0,
                                  np.asarray(got.result_.alpha) > 0)
    assert (base.predict(ds.X[:100]) == got.predict(ds.X[:100])).all()


def test_sv_eval_matches_full_eval():
    """SV-only batched evaluation gathers alpha>0 rows before the matvec;
    correct counts must equal the full-row path on every lane."""
    ds = make_dataset("heart", n_override=120)
    Cs, gammas = [1.0, 2.0, 4.0], [0.05, 0.1, 0.2]
    kw = dict(k=3, method="sir", chunk_iters=512)
    (p_full,) = grid_plans(ds, Cs, gammas, **kw)
    (p_sv,) = grid_plans(ds, Cs, gammas, **kw)
    p_sv.sv_eval = True
    r_full = run_plan(p_full)
    r_sv = run_plan(p_sv)
    assert set(r_full.evals) == set(r_sv.evals)
    for lid in r_full.evals:
        assert int(r_full.evals[lid][0]) == int(r_sv.evals[lid][0]), lid


# ------------------------------------------------ plan_check calibration

def _shrink_grid_kwargs(max_width):
    return dict(k=3, method="sir", chunk_iters=512, max_width=max_width,
                shrink_every=64, shrink_quantum=32, shrink_caps=(96,))


@pytest.mark.parametrize("max_width", [1, 2])
def test_predicted_cap_programs_match_measured(max_width):
    """With declared caps in play, the analyzer's (program, kind, width,
    cap, n, dtype, wss) enumeration must equal the measured jit cache
    misses summed over all three chunk entry points — exactly, at width
    caps 1 and 2."""
    ds = make_dataset("heart", n_override=120)
    Cs, gammas = [1.0, 2.0, 4.0], [0.05, 0.1, 0.2]
    (plan,) = grid_plans(ds, Cs, gammas, **_shrink_grid_kwargs(max_width))
    pa = analyze_plan(plan)
    assert pa.ok, pa.report.render()
    assert {p[3] for p in pa.programs} == {96, 120}
    chunk_jit.clear_cache()
    chunk_batched_jit.clear_cache()
    chunk_batched_sources_jit.clear_cache()
    run_grid(ds, Cs, gammas, **_shrink_grid_kwargs(max_width))
    measured = (chunk_jit._cache_size() + chunk_batched_jit._cache_size()
                + chunk_batched_sources_jit._cache_size())
    assert pa.program_count == measured == 2 * max_width


def test_plan_check_shrink_off_unchanged():
    """Without shrinking the analyzer emits cap == n only — the program
    count (and the recompile-storm math) is exactly the pre-shrink one."""
    ds = make_dataset("heart", n_override=120)
    Cs, gammas = [1.0, 2.0, 4.0], [0.05, 0.1, 0.2]
    (plan,) = grid_plans(ds, Cs, gammas, k=3, method="sir",
                         chunk_iters=512, max_width=2)
    pa = analyze_plan(plan)
    assert pa.program_count == 2
    assert all(p[3] == p[4] for p in pa.programs)
    assert all(src["caps"] == [] for src in pa.per_source.values())


# ------------------------------------------------------- cost-model gate

def test_pick_shrink_fallback_and_measured():
    model = {"entries": {"cpu": {"dense": {"shrink": True},
                                 "pallas_rbf": {"shrink": False}}}}
    # fallback: CPU off (dispatch-bound), accelerators on (bytes-bound)
    assert cost_model.fallback_shrink("cpu") is False
    assert cost_model.fallback_shrink("tpu") is True
    # measured entries override the fallback
    assert cost_model.pick_shrink("cpu", kinds=("dense",), model=model)
    # conservative combine: every kind must agree
    assert not cost_model.pick_shrink("cpu", kinds=("dense", "pallas_rbf"),
                                      model=model)
    # missing backend/kind degrades to the fallback
    assert not cost_model.pick_shrink("cpu", kinds=("dense",), model={})
    assert cost_model.pick_shrink("tpu", kinds=("dense",), model={})


def test_shrink_auto_resolves_like_the_pool(monkeypatch):
    """plan_check resolves shrink_every='auto' through the same
    cost-model verdict as the pool — prediction tracks execution."""
    ds = make_dataset("heart", n_override=120)
    (plan,) = grid_plans(ds, [1.0], [0.1], k=3, method="cold",
                         chunk_iters=512, max_width=1, shrink_every="auto",
                         shrink_quantum=32)
    monkeypatch.setattr(cost_model, "pick_shrink", lambda *a, **k: False)
    pa_off = analyze_plan(plan)
    assert all(p[3] == p[4] for p in pa_off.programs)
    monkeypatch.setattr(cost_model, "pick_shrink", lambda *a, **k: True)
    pa_on = analyze_plan(plan)
    assert any(p[3] < p[4] for p in pa_on.programs)
