"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret mode executes the kernel bodies on CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import (flash_attention, fused_smo_step,
                               rbf_kernel_matrix, smo_f_update)
from repro.kernels.ref import (flash_attention_ref, fused_smo_step_ref,
                               rbf_kernel_matrix_ref, smo_f_update_ref)

RNG = np.random.default_rng(7)


@pytest.mark.parametrize("n,m,d", [(64, 64, 16), (100, 130, 70), (257, 63, 9),
                                   (32, 512, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_rbf_shapes_dtypes(n, m, d, dtype):
    X = jnp.asarray(RNG.normal(size=(n, d)), dtype)
    Z = jnp.asarray(RNG.normal(size=(m, d)), dtype)
    K = rbf_kernel_matrix(X, Z, 0.37, bm=64, bn=64, bk=64)
    Kr = rbf_kernel_matrix_ref(X, Z, 0.37)
    tol = 1e-5 if dtype == jnp.float32 else 1e-10
    np.testing.assert_allclose(np.asarray(K), np.asarray(Kr), atol=tol)


def test_rbf_block_shape_independence():
    # f64: accumulation-order differences across block shapes stay below
    # 1e-12; f32 ordering effects are a separate (dtype-sweep) test
    X = jnp.asarray(RNG.normal(size=(120, 40)), jnp.float64)
    ref = rbf_kernel_matrix_ref(X, X, 0.5)
    for bm, bn, bk in [(32, 32, 16), (64, 128, 32), (128, 64, 64)]:
        K = rbf_kernel_matrix(X, X, 0.5, bm=bm, bn=bn, bk=bk)
        np.testing.assert_allclose(np.asarray(K), np.asarray(ref), atol=1e-12)


@pytest.mark.parametrize("S,D,causal,window", [
    (64, 32, True, None), (100, 32, False, None), (128, 64, True, 24),
    (96, 16, False, 40), (33, 32, True, None),
])
def test_flash_attention_sweep(S, D, causal, window):
    B, H = 2, 3
    q = jnp.asarray(RNG.normal(size=(B, H, S, D)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, H, S, D)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, H, S, D)), jnp.float32)
    o = flash_attention(q, k, v, causal=causal, window=window, bq=32, bk=32)
    r = flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=2e-5)


def test_flash_attention_bf16():
    B, H, S, D = 1, 2, 64, 32
    q = jnp.asarray(RNG.normal(size=(B, H, S, D)), jnp.bfloat16)
    k = jnp.asarray(RNG.normal(size=(B, H, S, D)), jnp.bfloat16)
    v = jnp.asarray(RNG.normal(size=(B, H, S, D)), jnp.bfloat16)
    o = flash_attention(q, k, v, bq=32, bk=32)
    r = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), atol=0.06)


@pytest.mark.parametrize("n", [100, 1000, 8192, 10_000])
def test_smo_f_update(n):
    f = jnp.asarray(RNG.normal(size=(n,)))
    Ki = jnp.asarray(RNG.normal(size=(n,)))
    Kj = jnp.asarray(RNG.normal(size=(n,)))
    out = smo_f_update(f, Ki, Kj, 0.37, block=1024)
    ref = smo_f_update_ref(f, Ki, Kj, 0.37)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-12)


def _step_problem(n, d, dtype):
    X = jnp.asarray(RNG.normal(size=(n, d)), dtype)
    xij = X[jnp.asarray([3, n - 1])]       # a real WSS pair's feature rows
    sq = jnp.sum(X * X, axis=1)
    f = jnp.asarray(RNG.normal(size=(n,)), dtype)
    return f, X, xij, sq, jnp.asarray(0.37, dtype)


@pytest.mark.parametrize("n,d,bm,bk", [
    (257, 9, 64, 64),     # ragged n, d < bk (feature axis fully padded)
    (100, 130, 64, 64),   # ragged on both axes, multi-step k loop
    (120, 40, 32, 16),    # multi-block on both axes
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_fused_smo_step_ragged(n, d, bm, bk, dtype):
    f, X, xij, sq, delta = _step_problem(n, d, dtype)
    out = fused_smo_step(f, X, xij, sq, delta, gamma=0.5, bm=bm, bk=bk)
    ref = fused_smo_step_ref(f, X, xij, sq, delta, 0.5)
    tol = 1e-5 if dtype == jnp.float32 else 1e-12
    assert out.shape == (n,) and out.dtype == dtype
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=tol)


def test_fused_smo_step_full_block_bitwise():
    """Default (full-array) blocks replay the oracle's exact fp ops — the
    bit-parity contract PallasRBF relies on (DESIGN.md §Pallas sources)."""
    f, X, xij, sq, delta = _step_problem(150, 13, jnp.float64)
    out = fused_smo_step(f, X, xij, sq, delta, gamma=0.37)
    ref = fused_smo_step_ref(f, X, xij, sq, delta, 0.37)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_rbf_in_solver_path():
    """The Pallas kernel slots into the SVM pipeline (backend='pallas')."""
    from repro.svm import kernel_matrix
    X = jnp.asarray(RNG.normal(size=(96, 20)), jnp.float64)
    K1 = kernel_matrix(X, X, gamma=0.3, backend="pallas")
    K2 = kernel_matrix(X, X, gamma=0.3, backend="jnp")
    np.testing.assert_allclose(np.asarray(K1), np.asarray(K2), atol=1e-10)
