"""Logical-axis rule resolution: dedup, divisibility, missing axes.
AbstractMesh lets us test the production 16x16 / 2x16x16 resolution logic
without 512 real devices."""
import pytest

try:
    from jax.sharding import AbstractMesh, AxisType, PartitionSpec as P
except ImportError:  # pre-AxisType jax (< 0.5): no abstract-mesh axis types
    pytest.skip("jax.sharding.AxisType/AbstractMesh unavailable on this jax "
                "version; mesh-resolution tests need jax >= 0.5",
                allow_module_level=True)

from repro.sharding import DEFAULT_RULES, logical_to_pspec


@pytest.fixture(scope="module")
def pod():
    return AbstractMesh((16, 16), ("data", "model"),
                        axis_types=(AxisType.Auto,) * 2)


@pytest.fixture(scope="module")
def multipod():
    return AbstractMesh((2, 16, 16), ("pod", "data", "model"),
                        axis_types=(AxisType.Auto,) * 3)


def test_basic_mapping(pod):
    assert logical_to_pspec(("embed", "mlp"), DEFAULT_RULES, pod) \
        == P("data", "model")


def test_missing_mesh_axis_dropped(pod, multipod):
    # "batch" maps to ("pod", "data"): single-pod drops "pod"
    assert logical_to_pspec(("batch", "seq"), DEFAULT_RULES, pod) == P("data", None)
    assert logical_to_pspec(("batch", "seq"), DEFAULT_RULES, multipod) \
        == P(("pod", "data"), None)


def test_duplicate_axis_first_wins(pod):
    assert logical_to_pspec(("mlp", "mlp"), DEFAULT_RULES, pod) == P("model", None)


def test_divisibility_guard(pod):
    # 4 kv-heads cannot shard over the 16-way model axis
    assert logical_to_pspec(("kv_heads",), DEFAULT_RULES, pod, shape=(4,)) == P(None)
    # 64 can
    assert logical_to_pspec(("kv_heads",), DEFAULT_RULES, pod, shape=(64,)) \
        == P("model")


def test_divisibility_guard_partial(multipod):
    # batch=2 shards over pod(2) but not data(16): greedy prefix
    assert logical_to_pspec(("batch",), DEFAULT_RULES, multipod, shape=(2,)) \
        == P("pod")
    # batch=1 (long_500k) stays replicated
    assert logical_to_pspec(("batch",), DEFAULT_RULES, multipod, shape=(1,)) \
        == P(None)


def test_unknown_logical_axis_is_replicated(pod):
    assert logical_to_pspec(("nonexistent_axis",), DEFAULT_RULES, pod) == P(None)


def test_abstract_params_shapes():
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import get_config
    from repro.models import abstract_params, model_params_def
    cfg = get_config("yi-34b")
    abs_tree = abstract_params(model_params_def(cfg), jnp.bfloat16)
    n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(abs_tree))
    assert n > 30e9  # full yi-34b declared without allocating anything
