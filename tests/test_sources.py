"""Kernel-source LRU: compute-on-demand factories, schedule-distance
eviction under a residency budget, bit-parity of budgeted grids, deferred
fused validation, plan validation at entry, and occupancy merging."""
import shutil

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.core.cv import _fold_masks
from repro.core.grid import _merge_occupancy, run_grid
from repro.core.study import Plan, run_plan
from repro.data.svm_suite import kfold_chunks, make_dataset
from repro.svm import (DenseKernel, FusedRBF, KernelSpec, LanePool,
                       SourceCache, kernel_matrix, smo_solve)

SUITE = ("adult", "heart", "madelon", "mnist", "webdata")


def _setup(name, n=100, k=3):
    ds = make_dataset(name, n_override=n)
    X = jnp.asarray(ds.X)
    y = jnp.asarray(ds.y, jnp.float64)
    chunks = kfold_chunks(n, k, seed=0)
    nn = chunks.size
    return ds, X, y[:nn], nn, jnp.asarray(_fold_masks(chunks))


# ------------------------------------------------------------- KernelSpec

def test_kernel_spec_slices_before_kernel_call():
    """The k-fold truncation is applied to X BEFORE the kernel call: the
    materialized matrix is the (n, n) kernel of X[:n], not a slice of the
    full (N, N) matrix (which wastes O(N^2 - n^2) work — the old
    run_grid bug)."""
    ds, X, y, n, _ = _setup("heart", n=100, k=3)   # 100 % 3 != 0 -> n < 100
    assert n < 100
    spec = KernelSpec(X=X, gamma=ds.gamma, n=n)
    src = spec.materialize()
    assert isinstance(src, DenseKernel)
    assert src.K.shape == (n, n)
    np.testing.assert_array_equal(
        np.asarray(src.K),
        np.asarray(kernel_matrix(X[:n], X[:n], gamma=ds.gamma)))
    # the residency half of the protocol answers without materializing
    assert spec.dtype == X.dtype
    assert spec.nbytes == n * n * X.dtype.itemsize
    assert spec.fused is False
    assert src.nbytes == src.K.nbytes == spec.nbytes


def test_source_cache_budget_and_schedule_distance_eviction():
    """max_resident bounds managed residency; the victim is the resident
    source with the FEWEST remaining lanes (schedule distance), never the
    sticky source while another candidate exists; pinned (dense) entries
    never count or evict."""
    ds, X, y, n, _ = _setup("heart")
    specs = {k: KernelSpec(X=X, gamma=g * ds.gamma, n=n)
             for k, g in (("a", 0.5), ("b", 1.0), ("c", 2.0))}
    specs["pin"] = DenseKernel(jnp.eye(n))
    remaining = {"a": 5, "b": 1, "c": 3}
    evicted = []
    cache = SourceCache(specs, max_resident=2,
                        distance=lambda k: remaining[k],
                        sticky=lambda: "a",
                        on_evict=evicted.append)
    assert cache.resident("pin") and not cache.resident("a")
    cache.get("a")
    cache.get("b")
    assert cache.peak_resident == 3          # pin + a + b
    # c forces an eviction: b has the fewest remaining lanes -> victim,
    # even though a is older (schedule distance beats recency); a is also
    # the sticky source and must survive
    cache.get("c")
    assert evicted == ["b"]
    assert cache.resident("a") and cache.resident("c")
    assert not cache.resident("b") and cache.resident("pin")
    # re-materialization is bit-identical (pure function of the spec)
    K_b1 = np.asarray(cache.get("b").K)      # evicts c (distance 3 < a's 5)
    assert evicted == ["b", "c"]
    np.testing.assert_array_equal(K_b1, np.asarray(specs["b"].materialize().K))
    assert cache.materializations == 4 and cache.evictions == 2
    assert cache.stats["peak_resident_bytes"] >= 2 * specs["a"].nbytes


def test_source_cache_byte_budget():
    ds, X, y, n, _ = _setup("heart")
    specs = {g: KernelSpec(X=X, gamma=g * ds.gamma, n=n) for g in (1, 2, 3)}
    one = specs[1].nbytes
    cache = SourceCache(specs, cache_bytes=2 * one + 1)
    cache.get(1), cache.get(2), cache.get(3)
    assert cache.resident_bytes <= 2 * one + 1
    assert cache.peak_resident == 2 and cache.evictions == 1


# --------------------------------------------- pool-level eviction parity

def test_pool_eviction_rematerializes_mid_lane_bitwise():
    """A source's kernel is evicted MID-SOLVE — between a batched group's
    chunks, an external cache reader pulls the OTHER source through a
    1-kernel budget, forcing the serving kernel out (packed states written
    back) and a re-materialization at the next chunk. Every lane still
    lands bit-identical to a solo solve."""
    ds, X, y, n, masks = _setup("heart")
    specs = {"a": KernelSpec(X=X, gamma=0.5 * ds.gamma, n=n),
             "b": KernelSpec(X=X, gamma=2.0 * ds.gamma, n=n)}
    pool = LanePool(specs, y, chunk_iters=64, max_width=0, max_resident=1)
    # two lanes on "a" so its group packs a batch (eviction must write the
    # packed states back), one on "b"
    for h in (0, 1):
        pool.add(("a", h), masks[h], ds.C, jnp.zeros(n, jnp.float64), -y,
                 source="a")
    pool.add(("b", 0), masks[0], ds.C, jnp.zeros(n, jnp.float64), -y,
             source="b")
    pool.on_lane_chunk = lambda lid, state: pool.cache.get(
        "b" if lid[0] == "a" else "a")
    results = pool.run()
    assert pool.cache.peak_resident == 1
    # the reader forced evict -> re-materialize on nearly every chunk
    assert pool.cache.materializations > 3
    assert pool.cache.evictions > 2
    for (g, h) in results:
        K = specs[g].materialize().K
        seq = smo_solve(K, y, masks[h], ds.C, jnp.zeros(n), -y)
        np.testing.assert_array_equal(np.asarray(seq.alpha),
                                      np.asarray(results[(g, h)].alpha))
        np.testing.assert_array_equal(np.asarray(seq.f),
                                      np.asarray(results[(g, h)].f))
        assert int(seq.n_iter) == int(results[(g, h)].n_iter)


def test_pool_unbounded_width_budget_drains_sources():
    """The accelerator default (max_width=0, all live lanes dispatch) must
    NOT thrash a residency budget: per-chunk selection is restricted to
    budget-many managed sources, so each kernel materializes once — the
    count tracks sources, not chunks."""
    ds, X, y, n, masks = _setup("heart")
    specs = {g: KernelSpec(X=X, gamma=g * ds.gamma, n=n)
             for g in (0.5, 1.0, 2.0)}
    pool = LanePool(specs, y, chunk_iters=64, max_width=0, max_resident=1)
    for g in specs:
        for h in range(2):
            pool.add((g, h), masks[h], ds.C, jnp.zeros(n, jnp.float64), -y,
                     source=g)
    results = pool.run()
    assert pool.cache.materializations == len(specs)
    assert pool.cache.peak_resident == 1
    assert all(bool(r.converged) for r in results.values())


def test_pool_capped_selection_prefers_resident_sources():
    """Under a width cap, lanes whose kernel is already resident are
    selected before lanes that would force a materialization: a budgeted
    width-1 pool drains one source, then pays for the next — one
    materialization per source, no thrash."""
    ds, X, y, n, masks = _setup("heart")
    specs = {g: KernelSpec(X=X, gamma=g * ds.gamma, n=n)
             for g in (0.5, 1.0, 2.0)}
    pool = LanePool(specs, y, chunk_iters=64, max_width=1, max_resident=1)
    for g in specs:
        for h in range(2):
            pool.add((g, h), masks[h], ds.C, jnp.zeros(n, jnp.float64), -y,
                     source=g)
    pool.run()
    assert pool.cache.materializations == len(specs)
    assert pool.cache.peak_resident == 1


# ------------------------------------------------------- grid LRU parity

@pytest.mark.parametrize("name", SUITE)
def test_run_grid_lru_budgets_bit_parity(name):
    """run_grid(pool="cross_gamma") under max_resident=1 / 2 / unbounded
    must produce bit-identical cells (iterations AND correct-counts) on
    every suite dataset — eviction/re-materialization schedules are
    unobservable in the results — while peak residency obeys the budget."""
    ds = make_dataset(name, n_override=100)
    kw = dict(Cs=[ds.C, 4 * ds.C], gammas=[0.5 * ds.gamma, 2 * ds.gamma],
              k=3, method="sir", chunk_iters=256)
    full = run_grid(ds, **kw)                       # unbounded: all resident
    assert full.resident["peak_resident"] == 2
    for budget in (1, 2):
        rep = run_grid(ds, max_resident=budget, **kw)
        assert rep.resident["peak_resident"] <= budget
        assert [(c.C, c.gamma, c.iterations, c.acc_correct, c.converged)
                for c in rep.cells] == \
            [(c.C, c.gamma, c.iterations, c.acc_correct, c.converged)
             for c in full.cells]
    assert full.kernel_time > 0


def test_run_grid_lru_kill_resume_cold_cache(tmp_path):
    """A killed budgeted grid resumes with a COLD cache (kernels are not
    checkpointed — specs re-materialize on demand) and lands on the
    identical per-cell report."""
    ds = make_dataset("heart", n_override=100)
    kw = dict(Cs=[ds.C, 4 * ds.C], gammas=[0.5 * ds.gamma, 2 * ds.gamma],
              k=3, method="sir", chunk_iters=64, max_resident=1)
    full = run_grid(ds, **kw)

    mgr = CheckpointManager(str(tmp_path / "grid"), max_to_keep=1000)
    run_grid(ds, checkpoint_manager=mgr, **kw)
    steps = mgr.steps_of_class("study")
    assert len(steps) >= 3
    for s in steps[3:]:
        shutil.rmtree(mgr._step_dir(s))
    mgr2 = CheckpointManager(str(tmp_path / "grid"), max_to_keep=1000)
    resumed = run_grid(ds, checkpoint_manager=mgr2, **kw)
    assert [(c.iterations, c.acc_correct) for c in resumed.cells] == \
        [(c.iterations, c.acc_correct) for c in full.cells]
    # the resumed study re-materialized (kernel_time covers it)
    assert resumed.resident["materializations"] >= 1
    assert resumed.kernel_time > 0


# ------------------------------------------- deferred fused/WSS validation

class _FusedFactory:
    """A factory whose product needs WSS-1 — only discoverable by
    materializing it."""

    def __init__(self, X, gamma):
        self.X, self.gamma = X, gamma

    @property
    def dtype(self):
        return self.X.dtype

    nbytes = 0
    fused = False          # the SPEC doesn't know; the product does

    def materialize(self):
        return FusedRBF(self.X, self.gamma)


def test_fused_source_validation_deferred_to_materialization():
    """A dense fused source still fails at pool construction; a FACTORY
    that produces a fused source passes construction (nothing is computed)
    and fails with the same error at first materialization."""
    ds, X, y, n, masks = _setup("heart")
    with pytest.raises(ValueError, match="requires WSS-1"):
        LanePool({"f": FusedRBF(X[:n], ds.gamma)}, y)
    pool = LanePool({"f": _FusedFactory(X[:n], ds.gamma)}, y)  # no raise
    pool.add(0, masks[0], ds.C, jnp.zeros(n, jnp.float64), -y)
    with pytest.raises(ValueError, match="requires WSS-1"):
        pool.run()
    # and wss="1" accepts the same factory end-to-end
    pool1 = LanePool({"f": _FusedFactory(X[:n], ds.gamma)}, y, wss="1")
    pool1.add(0, masks[0], ds.C, jnp.zeros(n, jnp.float64), -y)
    assert bool(pool1.run()[0].converged)


# ------------------------------------------------- plan validation at entry

def _one_lane_plan(K, y, masks, C):
    plan = Plan(sources={"s": DenseKernel(K)}, y=y)
    plan.lane(0, train_mask=masks[0], C=C,
              alpha0=jnp.zeros(y.shape[0]), f0=-y)
    return plan


def test_run_plan_validates_edges_by_name():
    """A typo'd dep/after edge, an unknown source key, or a cyclic graph
    fails AT ENTRY, naming the offending lane/edge — not hours later as
    LanePool.run's drain-time RuntimeError."""
    ds, X, y, n, masks = _setup("heart")
    K = np.asarray(kernel_matrix(X[:n], X[:n], gamma=ds.gamma))

    plan = _one_lane_plan(K, y, masks, ds.C)
    plan.lane(1, train_mask=masks[1], C=ds.C, dep="typo", transform="fold")
    with pytest.raises(ValueError,
                       match=r"lane 1: dep edge targets undeclared lane "
                             r"'typo'"):
        run_plan(plan)

    plan = _one_lane_plan(K, y, masks, ds.C)
    plan.lane(1, train_mask=masks[1], C=ds.C,
              alpha0=jnp.zeros(n), f0=-y, after=99)
    with pytest.raises(ValueError, match="after edge targets undeclared"):
        run_plan(plan)

    plan = _one_lane_plan(K, y, masks, ds.C)
    plan.lane(1, source="nope", train_mask=masks[1], C=ds.C,
              alpha0=jnp.zeros(n), f0=-y)
    with pytest.raises(ValueError, match="lane 1: unknown source key"):
        run_plan(plan)

    # a cycle is reported as the cycle, not as "every pending lane"
    plan = Plan(sources={"s": DenseKernel(K)}, y=y)
    plan.lane("a", train_mask=masks[0], C=ds.C, dep="b", transform="fold",
              params={})
    plan.lane("b", train_mask=masks[1], C=ds.C, dep="a", transform="fold",
              params={})
    with pytest.raises(ValueError, match="cycle"):
        run_plan(plan)

    plan = _one_lane_plan(K, y, masks, ds.C)
    plan.evaluate(42, np.arange(3))
    with pytest.raises(ValueError, match="EvalSpec targets undeclared"):
        run_plan(plan)


def test_run_plan_rejects_non_dense_pinned_source_at_entry():
    """A PINNED (already-materialized) source missing a required
    capability fails at entry — not after the dependency lane has solved
    for hours. Factories stay deferred (their product is unknowable
    without computing it). Evaluation is no longer such a capability for
    the RBF family: ``rows_at`` (shared since the shrinking
    reconstruction path, DESIGN.md §Shrinking) serves the eval row slab
    without a dense K, and must score identically to the dense path."""
    from repro.svm import DenseKernel, OnDemandRBF
    ds, X, y, n, masks = _setup("heart")
    plan = Plan(sources={"od": OnDemandRBF(X[:n], ds.gamma)}, y=y)
    plan.lane(0, train_mask=masks[0], C=ds.C, alpha0=jnp.zeros(n), f0=-y)
    plan.lane(1, train_mask=masks[1], C=ds.C, dep=0, transform="fold",
              params={})
    with pytest.raises(ValueError, match="transform 'fold' needs a dense"):
        run_plan(plan)

    def eval_plan(source):
        p = Plan(sources={"s": source}, y=y)
        p.lane(0, train_mask=masks[0], C=ds.C, alpha0=jnp.zeros(n), f0=-y)
        p.evaluate(0, np.arange(30))
        return run_plan(p)
    r_od = eval_plan(OnDemandRBF(X[:n], ds.gamma))
    K = kernel_matrix(X[:n], X[:n], gamma=ds.gamma)
    r_dense = eval_plan(DenseKernel(K))
    assert int(r_od.evals[0][0]) == int(r_dense.evals[0][0])


# --------------------------------------------------- occupancy merge fix

def test_merge_occupancy_sums_programs_and_merges_per_source():
    """programs is a distinct-compiled-programs bound: summing across
    pools, not max (the old max undercounted); per_source blocks merge by
    key instead of being dropped."""
    rows = [
        {"chunks": 10, "mean_live_width": 2.0, "mean_packed_width": 1.5,
         "peak_width": 4, "programs": 3,
         "per_source": {"0": {"chunks": 10, "mean_live_width": 2.0,
                              "peak_live_width": 4}}},
        {"chunks": 30, "mean_live_width": 1.0, "mean_packed_width": 1.0,
         "peak_width": 2, "programs": 2,
         "per_source": {"0": {"chunks": 10, "mean_live_width": 1.0,
                              "peak_live_width": 2},
                        "1": {"chunks": 20, "mean_live_width": 3.0,
                              "peak_live_width": 5}}},
    ]
    merged = _merge_occupancy(rows)
    assert merged["programs"] == 5                      # 3 + 2, not max
    assert merged["chunks"] == 40
    assert merged["mean_live_width"] == 1.25            # chunk-weighted
    assert merged["per_source"]["0"] == {
        "chunks": 20, "mean_live_width": 1.5, "peak_live_width": 4}
    assert merged["per_source"]["1"] == {
        "chunks": 20, "mean_live_width": 3.0, "peak_live_width": 5}
    assert _merge_occupancy([]) is None
    assert _merge_occupancy([{"chunks": 0}])["chunks"] == 0
