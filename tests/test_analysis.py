"""repro.analysis: plan analyzer, lint passes, scope derivation, baseline.

The headline test is the calibration contract: the plan analyzer's
predicted distinct-program count must match the MEASURED jit cache misses
of an actual grid run. Width-capped schedules realize every predicted
width deterministically, so caps 1 and 2 assert exact equality (both
pools); the unbounded schedule is a can-produce upper bound — lanes that
converge in lockstep may never visit intermediate widths — so it asserts
measured <= predicted (DESIGN.md §Static analysis).
"""
import json
import pathlib
import types

import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import findings, imports, jit_lint, kernel_lint
from repro.analysis.plan_check import (PlanAnalysis, _max_antichain,
                                       analyze_plan, check_plan)
from repro.core.grid import grid_plans, run_grid
from repro.core.study import Plan, run_plan
from repro.data.svm_suite import make_dataset
from repro.svm.engine import chunk_batched_jit, chunk_jit
from repro.svm.scheduler import possible_widths
from repro.svm.sources import KernelSpec

FIXTURES = pathlib.Path(__file__).parent / "fixtures" / "lint"


# ---------------------------------------------------------------- helpers

def _grid_kwargs(**over):
    kw = dict(k=3, method="sir", chunk_iters=512)
    kw.update(over)
    return kw


def _heart():
    return make_dataset("heart", n_override=120)


def _cs_gammas():
    return [1.0, 2.0, 4.0], [0.05, 0.1, 0.2]


def _small_plan(cache_bytes=0, evaluate=True):
    X = jnp.asarray(np.random.default_rng(0).normal(size=(16, 4)))
    y = jnp.asarray(np.where(np.arange(16) % 2, 1.0, -1.0))
    zeros = jnp.zeros(16)
    plan = Plan(sources={0: KernelSpec(X=X, gamma=0.5, kind="rbf")}, y=y,
                cache_bytes=cache_bytes)
    plan.lane("a", source=0, train_mask=y != 0, C=1.0, alpha0=zeros, f0=-y)
    plan.lane("b", source=0, train_mask=y != 0, C=2.0, alpha0=zeros, f0=-y,
              after="a")
    if evaluate:
        plan.evaluate("a", jnp.arange(4))
        plan.evaluate("b", jnp.arange(4))
    return plan


# ------------------------------------------------- predicted vs measured

def _predicted(pool, max_width):
    plans = grid_plans(_heart(), *_cs_gammas(), pool=pool,
                       max_width=max_width, **_grid_kwargs())
    progs = set()
    for p in plans:
        pa = analyze_plan(p)
        assert pa.ok, pa.report.render()
        progs |= set(map(tuple, pa.programs))
    return len(progs)


def _measured(pool, max_width):
    chunk_jit.clear_cache()
    chunk_batched_jit.clear_cache()
    run_grid(_heart(), *_cs_gammas(), pool=pool, max_width=max_width,
             **_grid_kwargs())
    return chunk_jit._cache_size() + chunk_batched_jit._cache_size()


@pytest.mark.parametrize("pool", ["cross_gamma", "per_gamma"])
@pytest.mark.parametrize("max_width", [1, 2])
def test_predicted_programs_match_measured_compiles(pool, max_width):
    """Width-capped schedules: analyzer prediction == jit cache misses,
    exactly. The jit cache is global, so per_gamma's three pools share
    compiles — same count as the single cross-gamma pool."""
    assert _predicted(pool, max_width) == _measured(pool, max_width) \
        == max_width


def test_unbounded_width_is_an_upper_bound():
    """max_width=0 (uncapped): every predicted width CAN occur, but a
    lockstep schedule may skip intermediate ones — measured never exceeds
    predicted."""
    predicted = _predicted("cross_gamma", 0)
    assert predicted == len(possible_widths(3, 4, 0)) == 3
    assert _measured("cross_gamma", 0) <= predicted


def test_analyzer_enumerates_exact_grid_plans():
    """grid_plans IS run_grid's builder: per-source peaks reflect the
    fold-chain DAG (3 independent cells per gamma, folds chained)."""
    (plan,) = grid_plans(_heart(), *_cs_gammas(), pool="cross_gamma",
                         **_grid_kwargs())
    pa = analyze_plan(plan)
    assert pa.ok
    assert set(pa.per_source) == {0, 1, 2}
    for src in pa.per_source.values():
        assert src["lanes"] == 9          # 3 cells x 3 folds
        assert src["peak_width"] == 3     # fold chains serialize each cell
        assert src["peak_exact"]


# ------------------------------------------------------- plan feasibility

def test_rejects_plan_exceeding_cache_bytes():
    """A factory source larger than the declared budget is statically
    infeasible — check_plan and run_plan(strict) both refuse before any
    kernel materializes."""
    plan = _small_plan(cache_bytes=1000)   # dense 16x16 f64 K = 2048 B
    pa = analyze_plan(plan)
    assert not pa.ok
    assert any(f.rule == "cache-infeasible" for f in pa.report.errors)
    with pytest.raises(ValueError, match="cache-infeasible"):
        check_plan(plan)
    with pytest.raises(ValueError, match="cache-infeasible"):
        run_plan(_small_plan(cache_bytes=1000), analysis="strict")


def test_findings_context_tags_without_changing_identity(tmp_path):
    """``analyze_plan(..., context=...)`` stamps every finding with the
    caller's attribution (the daemon passes ``tenant/plan_id``): rendered
    output names it, but the baseline identity key is untouched — a
    context-tagged finding is still accepted by a context-free baseline,
    and the line-free key semantics survive."""
    plan = _small_plan(cache_bytes=1000)
    plain = analyze_plan(plan)
    tagged = analyze_plan(plan, context="alice/p1")
    assert all(f.context == "alice/p1" for f in tagged.report)
    assert all(f.context == "" for f in plain.report)
    assert all(" [alice/p1]: " in f.render() for f in tagged.report)
    # identity excludes context: the same findings, to a baseline
    assert {f.key for f in tagged.report} == {f.key for f in plain.report}
    base = findings.write_baseline(plain.report, tmp_path / "base.json")
    assert tagged.report.new_against(base) == []
    with pytest.raises(ValueError) as ei:
        check_plan(plan, context="alice/p1")
    assert "alice/p1" in str(ei.value)


def test_admits_plan_within_cache_bytes():
    pa = analyze_plan(_small_plan(cache_bytes=1 << 20))
    assert pa.ok
    assert pa.peak_managed_bytes == 16 * 16 * 8


def test_checkpoint_base_step_audit():
    plan = _small_plan()
    bad = types.SimpleNamespace(base_step=5)
    pa = analyze_plan(plan, checkpoint=bad)
    assert any(f.rule == "checkpoint-key-collision" and "mid-fold"
               in f.message for f in pa.report.errors)
    batch = types.SimpleNamespace(base_step=10 ** 12)
    pa = analyze_plan(plan, checkpoint=batch)
    assert any(f.rule == "checkpoint-key-collision" and "batch"
               in f.message for f in pa.report.errors)
    ok = types.SimpleNamespace(base_step=2 * 10 ** 12)
    assert analyze_plan(plan, checkpoint=ok).ok


def test_dead_lane_is_advisory():
    plan = _small_plan(evaluate=False)
    pa = analyze_plan(plan)
    assert pa.ok                          # warns are not errors
    unobserved = [f for f in pa.report if f.rule == "lane-unobserved"]
    assert [f.symbol for f in unobserved] == ["'b'"]   # 'a' feeds 'b'


def test_invalid_plan_becomes_finding_not_crash():
    plan = _small_plan()
    plan.lane("a", source=0, train_mask=plan.y != 0, C=1.0,
              alpha0=jnp.zeros(16), f0=-plan.y)   # duplicate id
    pa = analyze_plan(plan)
    assert not pa.ok
    assert pa.report.errors[0].rule == "invalid-plan"
    assert "duplicate" in pa.report.errors[0].message


def test_run_plan_attaches_advisory_analysis():
    sr = run_plan(_small_plan())
    assert isinstance(sr.analysis, PlanAnalysis)
    assert sr.analysis.ok and sr.analysis.program_count >= 1
    assert run_plan(_small_plan(), analysis="off").analysis is None
    with pytest.raises(ValueError, match="analysis"):
        run_plan(_small_plan(), analysis="loud")


# ----------------------------------------------------------- antichain

def test_max_antichain_chain_and_independent():
    chain = {i: [i - 1] for i in range(1, 5)}
    chain[0] = []
    assert _max_antichain(list(range(5)), chain) == 1
    assert _max_antichain(list(range(5)), {i: [] for i in range(5)}) == 5


def test_max_antichain_grid_row_dag():
    """3 cells x 3 folds, folds chained within a cell: peak is the cell
    count, and chaining fold 0 across cells (seed_across_C) does not
    change it (the antichain picks one lane per cell at skewed depths)."""
    prereqs = {(c, h): ([(c, h - 1)] if h else []) for c in range(3)
               for h in range(3)}
    nodes = list(prereqs)
    assert _max_antichain(nodes, prereqs) == 3
    for c in range(1, 3):
        prereqs[(c, 0)] = [(c - 1, 0)]
    assert _max_antichain(nodes, prereqs) == 3


def test_possible_widths_buckets_and_caps():
    assert possible_widths(3, 4, 0) == (1, 2, 4)
    assert possible_widths(3, 4, 1) == (1,)
    assert possible_widths(3, 4, 2) == (1, 2)
    assert possible_widths(9, 4, 0) == (1, 2, 4, 8, 12)
    assert possible_widths(1, 4, 0) == (1,)


# ------------------------------------------------------------ lint passes

def _rules(report):
    return {f.rule for f in report}


def test_jit_lint_fixture_nonzero():
    rpt = jit_lint.lint_paths([FIXTURES / "bad_nonzero.py"])
    assert _rules(rpt) == {"unsized-nonzero"}
    assert [f.symbol for f in rpt] == ["support_vectors"]   # sized one OK


def test_jit_lint_fixture_branch_and_cast():
    rpt = jit_lint.lint_paths([FIXTURES / "bad_branch.py"])
    assert _rules(rpt) == {"traced-python-branch", "traced-host-cast"}
    assert "static_branch_ok" not in {f.symbol for f in rpt}


def test_jit_lint_fixture_timer():
    rpt = jit_lint.lint_paths([FIXTURES / "bad_timer.py"])
    assert _rules(rpt) == {"timer-no-sync"}
    assert [f.symbol for f in rpt] == ["timed_norm"]        # synced one OK


def test_kernel_lint_fixture_all_rules():
    rpt = kernel_lint.lint_paths([FIXTURES / "bad_kernel.py"])
    assert _rules(rpt) == {"auto-interpret-contract", "block-divisibility",
                           "vmem-footprint", "acc-dtype-promotion"}


def test_lint_scope_is_clean_against_baseline():
    """The derived scope must carry no findings beyond the committed
    baseline — the same gate CI runs."""
    repo = pathlib.Path(__file__).parents[1]
    scope = imports.default_scope()
    rpt = jit_lint.lint_paths(scope, repo_root=repo)
    rpt.extend(kernel_lint.lint_paths(
        [p for p in scope if "kernels" in p.parts], repo_root=repo))
    baseline = findings.load_baseline(repo / "results"
                                      / "lint_baseline.json")
    assert baseline is not None
    new = rpt.new_against(baseline)
    assert not new, "\n".join(f.render() for f in new)


# ------------------------------------------------------- scope derivation

def test_scaffolding_inventory_excludes_svm_tree():
    scaffolding = imports.scaffolding_inventory()
    assert not any(m.startswith(("repro.svm", "repro.core",
                                 "repro.kernels", "repro.analysis",
                                 "repro.checkpoint"))
                   for m in scaffolding)
    assert "repro.models.transformer" in scaffolding
    assert "repro.training.train_step" in scaffolding
    assert "repro.configs.base" in scaffolding


def test_default_scope_tracks_imports():
    scope = {p.name for p in imports.default_scope()}
    assert {"engine.py", "scheduler.py", "sources.py", "cv.py",
            "grid.py", "study.py", "svm_suite.py"} <= scope
    assert "transformer.py" not in scope
    # sharding is adopted: engine.py imports repro.sharding
    assert "sharding" in {p.parent.name for p in imports.default_scope()}


# ------------------------------------------------------ findings/baseline

def test_baseline_roundtrip_and_gate(tmp_path):
    rpt = findings.Report()
    rpt.add("r1", "a.py", "f", "msg one")
    rpt.add("r2", "b.py", "g", "msg two", severity="warn", line=7)
    path = tmp_path / "base.json"
    findings.write_baseline(rpt, path)
    base = findings.load_baseline(path)
    assert rpt.new_against(base) == []
    rpt.add("r3", "c.py", "h", "fresh")
    new = rpt.new_against(base)
    assert [f.rule for f in new] == ["r3"]
    # identity survives line drift
    moved = findings.Report()
    moved.add("r1", "a.py", "f", "msg one", line=99)
    assert moved.new_against(base) == []


def test_baseline_refresh_keeps_justifications(tmp_path):
    rpt = findings.Report()
    rpt.add("r1", "a.py", "f", "msg")
    path = tmp_path / "base.json"
    data = findings.write_baseline(rpt, path)
    data["findings"][0]["justification"] = "accepted: by design"
    path.write_text(json.dumps(data))
    refreshed = findings.write_baseline(rpt, path,
                                        previous=findings.load_baseline(path))
    assert refreshed["findings"][0]["justification"] == "accepted: by design"
