"""Measured dispatch-width cost model: file loading, fallback semantics,
the conservative combine across source kinds, and the pool's construction
hook (``max_width=None`` reads the model)."""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.svm import DenseKernel, PallasRBF, cost_model
from repro.svm.scheduler import LanePool


def _write_model(path, entries):
    path.write_text(json.dumps({"schema": 1, "entries": entries}))
    return path


def test_load_missing_and_invalid(tmp_path):
    assert cost_model.load(tmp_path / "absent.json") is None
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert cost_model.load(bad) is None
    no_entries = tmp_path / "no_entries.json"
    no_entries.write_text(json.dumps({"schema": 1}))
    assert cost_model.load(no_entries) is None


def test_fallback_is_width1_on_cpu_only():
    assert cost_model.fallback_max_width("cpu") == 1
    assert cost_model.fallback_max_width("tpu") == 0


def test_pick_reads_measured_entry(tmp_path):
    p = _write_model(tmp_path / "m.json", {
        "cpu": {"dense": {"max_width": 1},
                "pallas_rbf": {"max_width": 4}},
        "tpu": {"dense": {"max_width": 0}}})
    assert cost_model.pick_max_width("cpu", kinds=("dense",), path=p) == 1
    assert cost_model.pick_max_width("cpu", kinds=("pallas_rbf",),
                                     path=p) == 4
    # conservative combine: smallest nonzero cap across the pool's kinds
    assert cost_model.pick_max_width("cpu", kinds=("dense", "pallas_rbf"),
                                     path=p) == 1
    assert cost_model.pick_max_width("tpu", kinds=("dense",), path=p) == 0
    # missing kind degrades that kind to the backend fallback
    assert cost_model.pick_max_width("tpu", kinds=("dense", "rope"),
                                     path=p) == 0
    assert cost_model.pick_max_width("cpu", kinds=("rope",), path=p) == 1


def test_pick_unbounded_only_when_all_unbounded(tmp_path):
    p = _write_model(tmp_path / "m.json", {
        "tpu": {"dense": {"max_width": 0}, "pallas_rbf": {"max_width": 8}}})
    assert cost_model.pick_max_width("tpu", kinds=("dense", "pallas_rbf"),
                                     path=p) == 8
    assert cost_model.pick_max_width(
        "tpu", kinds=("dense",),
        model={"entries": {"tpu": {"dense": {"max_width": 0}}}}) == 0


def test_pick_missing_file_falls_back(tmp_path):
    assert cost_model.pick_max_width("cpu", path=tmp_path / "none.json") == 1
    assert cost_model.pick_max_width("gpu", path=tmp_path / "none.json") == 0


def test_source_kind_classifies_streaming():
    X = jnp.asarray(np.random.default_rng(0).normal(size=(32, 5)))
    K = jnp.eye(32)
    assert cost_model.source_kind(DenseKernel(K)) == "dense"
    assert cost_model.source_kind(PallasRBF(X, 0.5)) == "pallas_rbf"
    from repro.svm.sources import KernelSpec
    assert cost_model.source_kind(KernelSpec(X, kind="rbf")) == "dense"
    assert cost_model.source_kind(
        KernelSpec(X, kind="pallas_rbf")) == "pallas_rbf"


def test_pool_reads_model_at_construction(tmp_path, monkeypatch):
    """``max_width=None`` resolves through the measured model for the
    pool's source kinds; an absent file reproduces the historical CPU
    width-1 default."""
    p = _write_model(tmp_path / "m.json",
                     {"cpu": {"dense": {"max_width": 3}}})
    monkeypatch.setenv("REPRO_COST_MODEL", str(p))
    cost_model.clear_cache()
    y = jnp.asarray(np.where(np.arange(16) % 2, 1.0, -1.0))
    K = jnp.eye(16)
    pool = LanePool({"d": DenseKernel(K)}, y)
    assert pool.max_width == 3
    monkeypatch.setenv("REPRO_COST_MODEL", str(tmp_path / "absent.json"))
    cost_model.clear_cache()
    pool = LanePool({"d": DenseKernel(K)}, y)
    assert pool.max_width == 1
    # an explicit cap always wins over the model
    monkeypatch.setenv("REPRO_COST_MODEL", str(p))
    cost_model.clear_cache()
    pool = LanePool({"d": DenseKernel(K)}, y, max_width=7)
    assert pool.max_width == 7
    # leave no stale temp-path entries behind for later tests
    cost_model.clear_cache()


def test_committed_model_has_cpu_width1_verdict():
    """The checked-in artifact must reproduce the historical CPU verdict
    (the scheduler's production default on this container)."""
    model = cost_model.load(cost_model.DEFAULT_PATH)
    assert model is not None, "results/cost_model.json missing or invalid"
    cpu = model["entries"]["cpu"]
    assert cpu["dense"]["max_width"] == 1
    assert cpu["pallas_rbf"]["max_width"] == 1
    assert "1" in cpu["dense"]["us_per_lane_iter"]
