"""Per-arch smoke tests (reduced configs): one forward + one train step +
one decode step on CPU; asserts output shapes and finiteness."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_configs
from repro.launch.inputs import concrete_batch
from repro.models import init_params, model_params_def
from repro.models import transformer as T
from repro.training import build_train_step, get_optimizer

B, S = 2, 32


def _params(cfg):
    return init_params(model_params_def(cfg), jax.random.PRNGKey(0),
                       jnp.float32)


@pytest.mark.parametrize("arch", list_configs())
def test_forward_shapes_finite(arch):
    cfg = get_config(arch, smoke=True)
    params = _params(cfg)
    batch = concrete_batch(cfg, B, S)
    logits, extras = T.forward(params, batch, cfg, mode="train")
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", list_configs())
def test_train_step(arch):
    cfg = get_config(arch, smoke=True)
    params = _params(cfg)
    opt = get_optimizer("adamw")
    opt_state = opt.init(params)
    step = build_train_step(cfg, None, opt, n_microbatches=2, lr=1e-3)
    batch = concrete_batch(cfg, 4, S)
    new_params, _, metrics = jax.jit(step)(params, opt_state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually moved
    moved = any(
        float(jnp.abs(a - b).max()) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)))
    assert moved


@pytest.mark.parametrize("arch", list_configs())
def test_decode_matches_forward(arch):
    """Teacher-forced decode over a short prompt must reproduce the full
    forward logits (cache correctness), for every architecture.

    capacity_factor is raised so the MoE dispatch never drops tokens —
    forward-pass capacity competition is the one *intended* train/decode
    difference (GShard semantics), not a cache bug."""
    cfg = get_config(arch, smoke=True).replace(capacity_factor=8.0)
    params = _params(cfg)
    batch = concrete_batch(cfg, B, S)
    # decode is text-only: patch embeddings exist only in the prefill prompt
    batch.pop("patch_embeds", None)
    logits_full, _ = T.forward(params, batch, cfg, mode="train")

    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = T._encode(params, batch["frames"], cfg, None)
    cache = T.init_cache(cfg, B, S + 4, jnp.float32,
                         enc_len=(enc_out.shape[1] if enc_out is not None else 0))
    errs = []
    steps = 8
    for t in range(steps):
        db = {"tokens": batch["tokens"][:, t:t + 1],
              "step": jnp.asarray(t, jnp.int32)}
        if cfg.rope_kind == "mrope":
            db["positions"] = jnp.full((B, 3, 1), t, jnp.int32)
        if cfg.is_encoder_decoder:
            db["enc_out"] = enc_out
        lg, cache = T.decode_step(params, cache, db, cfg)
        err = float(jnp.abs(lg[:, 0] - logits_full[:, t]).max())
        errs.append(err)
    assert max(errs) < 2e-2, errs


def test_vlm_patches_change_output():
    cfg = get_config("qwen2-vl-2b", smoke=True)
    params = _params(cfg)
    batch = concrete_batch(cfg, B, S)
    l1, _ = T.forward(params, batch, cfg)
    batch2 = dict(batch)
    batch2["patch_embeds"] = batch["patch_embeds"] + 1.0
    l2, _ = T.forward(params, batch2, cfg)
    assert float(jnp.abs(l1 - l2).max()) > 0


def test_moe_routes_to_multiple_experts():
    cfg = get_config("deepseek-v2-236b", smoke=True)
    params = _params(cfg)
    from repro.models.moe import _route
    import numpy as np
    # locate a MoE layer's params via the plan (slice stacked stages)
    moe_params = None
    for (pattern, repeat), sp in zip(T.layer_plan(cfg), params["stages"]):
        for li, spec in enumerate(pattern):
            if spec.mlp == "moe":
                layer = sp[li]
                if repeat > 1:
                    layer = jax.tree.map(lambda p: p[0], layer)
                moe_params = layer["moe"]
                break
        if moe_params is not None:
            break
    x = jnp.asarray(np.random.default_rng(0).normal(size=(64, cfg.d_model)),
                    jnp.float32)
    w, e, _ = _route(moe_params, x, cfg)
    assert len(set(np.asarray(e).ravel().tolist())) > 1
    assert bool(jnp.all(w >= 0))


def test_param_counts_match_published_scale():
    """Full configs must land near the published parameter counts."""
    from repro.models.transformer import count_params, active_params
    expected = {  # (total, tolerance fraction)
        "deepseek-v2-236b": (236e9, 0.12),
        "deepseek-v3-671b": (671e9, 0.12),
        "yi-34b": (34e9, 0.12),
        "gemma-7b": (8.5e9, 0.25),     # incl. 786M embed table
        "granite-8b": (8e9, 0.15),
        "jamba-v0.1-52b": (52e9, 0.15),
        "xlstm-125m": (125e6, 0.30),
        "qwen2-vl-2b": (1.5e9, 0.45),  # backbone only (no ViT)
        "gemma3-4b": (4e9, 0.35),
    }
    for arch, (target, tol) in expected.items():
        cfg = get_config(arch)
        total = count_params(cfg)
        assert abs(total - target) / target < tol, (arch, total, target)
        assert active_params(cfg) <= total


def test_layer_plans():
    plans = {a: T.layer_plan(get_config(a)) for a in list_configs()}
    # deepseek v3: 3 dense layers then 58 MoE
    p = plans["deepseek-v3-671b"]
    assert p[0][1] == 3 and p[1][1] == 58
    # gemma3: 5 repeats of the 6-layer 5:1 pattern + 4-layer local tail
    p = plans["gemma3-4b"]
    assert p[0][1] == 5 and len(p[0][0]) == 6
    # jamba: 4 repeats of the period-8 block, exactly one attn per block
    p = plans["jamba-v0.1-52b"]
    assert p[0][1] == 4 and len(p[0][0]) == 8
    assert sum(1 for s in p[0][0] if s.mixer == "attn") == 1
    assert sum(1 for s in p[0][0] if s.mlp == "moe") == 4
    # xlstm: alternating mlstm/slstm
    p = plans["xlstm-125m"]
    assert {s.mixer for s in p[0][0]} == {"mlstm", "slstm"}
