"""XLA attention variants agree with the reference einsum path."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import sdpa, sdpa_local_chunked, sdpa_q_chunked

RNG = np.random.default_rng(3)


def _qkv(B=2, S=96, H=4, KV=2, D=16):
    q = jnp.asarray(RNG.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, S, KV, D)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, S, KV, D)), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("q_chunk", [16, 32, 48])
def test_q_chunked_matches_sdpa(causal, q_chunk):
    q, k, v = _qkv()
    ref = sdpa(q, k, v, causal=causal)
    out = sdpa_q_chunked(q, k, v, causal=causal, q_chunk=q_chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_q_chunked_with_window():
    q, k, v = _qkv()
    ref = sdpa(q, k, v, causal=True, window=24)
    out = sdpa_q_chunked(q, k, v, causal=True, window=24, q_chunk=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_local_chunked_matches_masked_sdpa():
    W = 16
    q, k, v = _qkv(S=80)
    ref = sdpa(q, k, v, causal=True, window=W)
    out = sdpa_local_chunked(q, k, v, window=W)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_gqa_grouping():
    """GQA (kv<h) must equal MHA with kv heads explicitly repeated."""
    q, k, v = _qkv(H=8, KV=2)
    ref = sdpa(q, jnp.repeat(k, 4, axis=2), jnp.repeat(v, 4, axis=2),
               causal=True)
    out = sdpa(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)
