"""End-to-end behaviour tests for the paper's system: the full alpha-seeded
k-fold CV protocol reproduces the paper's claims on the synthetic suite."""
import pytest

from repro.core.cv import run_cv
from repro.data.svm_suite import make_dataset


@pytest.fixture(scope="module")
def reports():
    ds = make_dataset("madelon", n_override=500)
    return {m: run_cv(ds, k=10, method=m) for m in ("cold", "sir", "mir")}


def test_claim1_same_accuracy(reports):
    """Paper Table 1 (last cols): seeded CV returns the same accuracy.

    madelon-like is chance-level (the paper's own Madelon scores 50.0%):
    its dual optimum is degenerate and |decision|<tol margins flip freely,
    so equality is asserted up to the observed degenerate-flip band (~3%).
    The margin-aware exact check is test_seeding.test_identical_results_claim."""
    cold = reports["cold"].accuracy
    for m in ("sir", "mir"):
        assert reports[m].accuracy == pytest.approx(cold, abs=0.03)


def test_claim2_fewer_iterations(reports):
    """Paper Table 1 (iteration cols): warm-started CV needs fewer total
    SMO iterations than cold start."""
    cold = reports["cold"].total_iterations
    assert reports["sir"].total_iterations < cold
    assert reports["mir"].total_iterations < cold


def test_claim3_all_folds_converge(reports):
    for rep in reports.values():
        assert all(f.converged for f in rep.folds)


def test_claim4_seed_chain_structure(reports):
    """Fold h seeds from fold h-1 (paper protocol); fold 0 is cold."""
    sir = reports["sir"]
    assert sir.folds[0].seed_from == -1
    assert [f.seed_from for f in sir.folds[1:]] == list(range(9))


def test_solve_time_reduced(reports):
    """The seeded folds' SMO ('the rest') time is below cold start's."""
    assert reports["sir"].total_solve_time < reports["cold"].total_solve_time
