"""Static schedule simulator: trace-validated abstract interpretation.

The load-bearing invariant is EVENT-FOR-EVENT trace equality: the
simulator's replay of a plan under an exact iteration oracle must match
the instrumented live pool on every suite dataset, across budgeted
grids, shrink-enabled lanes, and a two-tenant service run — the
scheduler's decisions all route through pure functions both sides
share, so any drift is a bug, not an approximation. On top of that:
bounding oracles must bracket the exact schedule, the time-resolved
``cache-infeasible-time`` finding must catch the plan the
worst-single-source rule admits, the daemon's per-plan tenant budgets
must reject over-budget plans with structured findings that round-trip
the wire, and the extracted pure functions must hold their contracts on
randomized inputs (hypothesis when available, seeded random otherwise).
"""
import dataclasses
import json
import random
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import plan_check, plan_sim
from repro.core.cv import _fold_masks, _transition_idx
from repro.core.study import Plan, plan_to_dict, run_plan
from repro.data.svm_suite import DATASETS, kfold_chunks, make_dataset
from repro.service import (PlanRejectedByServer, StudyClient, StudyServer,
                           StudyService)
from repro.svm import DenseKernel, kernel_matrix
from repro.svm.scheduler import (budget_sources, bucket_width, order_capped,
                                 possible_widths, select_capped)
from repro.svm.sources import KernelSpec, pick_victim

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False


def _setup(name, n=48, k=3):
    ds = make_dataset(name, n_override=n)
    X = jnp.asarray(ds.X)
    y = jnp.asarray(ds.y, jnp.float64)
    chunks = kfold_chunks(n, k, seed=0)
    nn = chunks.size
    return ds, X[:nn], y[:nn], chunks, jnp.asarray(_fold_masks(chunks))


def _fold_chain_plan(sources, y, masks, chunks, C, *, folds=3, **knobs):
    plan = Plan(sources=dict(sources), y=y, chunk_iters=64,
                lane_quantum=2, **knobs)
    n = y.shape[0]
    for key in sources:
        plan.lane((key, 0), source=key, train_mask=masks[0], C=C,
                  alpha0=jnp.zeros(n), f0=-y)
        for h in range(1, folds):
            S, R, T = _transition_idx(chunks, h - 1, h)
            plan.lane((key, h), source=key, train_mask=masks[h], C=C,
                      dep=(key, h - 1), transform="fold",
                      params=dict(method="sir", S_idx=S, R_idx=R, T_idx=T))
        for h in range(folds):
            plan.evaluate((key, h), chunks[h])
    return plan


def _assert_trace_equal(sim_events, live_events):
    if sim_events == live_events:
        return
    for i, (a, b) in enumerate(zip(sim_events, live_events)):
        assert a == b, f"first divergence at event {i}: sim {a!r} != " \
                       f"live {b!r}"
    raise AssertionError(f"trace length mismatch: sim {len(sim_events)} "
                         f"!= live {len(live_events)}")


# ------------------------------------------------ suite-wide trace parity

@pytest.mark.parametrize("name", DATASETS)
def test_trace_parity_budgeted_grid(name):
    """Budgeted 3-source grid with dep chains and checkpoints: the
    simulated trace equals the instrumented live trace event-for-event,
    on every suite dataset."""
    ds, X, y, chunks, masks = _setup(name)
    n = int(y.shape[0])
    sources = {s: KernelSpec(X=X, gamma=s * ds.gamma, n=n)
               for s in (0.5, 1.0, 2.0)}
    plan = _fold_chain_plan(sources, y, masks, chunks, ds.C,
                            cache_bytes=2 * n * n * 8, max_width=4)
    events, pool = plan_sim.dry_run(plan, snapshot_every=3)
    oracle = plan_sim.oracle_from_trace(events)
    sa = plan_sim.simulate_plan(plan, oracle=oracle, snapshot_every=3)
    _assert_trace_equal(sa.events, events)
    assert sa.chunks == pool.chunk_count
    assert sa.checkpoints == sum(1 for e in events if e[0] == "checkpoint")
    assert sa.peak_resident_bytes == max(
        e[2] for e in events if e[0] == "resident")
    assert sa.materializations == sum(
        1 for e in events if e[0] == "materialize")


@pytest.mark.parametrize("name", DATASETS)
def test_trace_parity_shrink_enabled(name):
    """Shrink-enabled lanes: the recorded per-dispatch cap sequences
    replay exactly (shrink lifecycle is data-dependent, so the oracle
    carries them)."""
    ds, X, y, chunks, masks = _setup(name)
    n = int(y.shape[0])
    sources = {s: KernelSpec(X=X, gamma=s * ds.gamma, n=n)
               for s in (1.0, 2.0)}
    plan = _fold_chain_plan(sources, y, masks, chunks, ds.C,
                            shrink_every=128, max_width=4)
    events, pool = plan_sim.dry_run(plan, snapshot_every=5)
    oracle = plan_sim.oracle_from_trace(events, shrink=True)
    sa = plan_sim.simulate_plan(plan, oracle=oracle, snapshot_every=5)
    _assert_trace_equal(sa.events, events)


@pytest.mark.parametrize("name", DATASETS)
def test_trace_parity_two_tenant_service(name):
    """The daemon's shape: two tenants' namespaced plans (with a dedup'd
    shared source) interleaved in one pool — ``simulate_plans`` replays
    the merged schedule, tenant round-robin and shares events included."""
    ds, X, y, chunks, masks = _setup(name)
    n = int(y.shape[0])
    gam = {s: KernelSpec(X=X, gamma=s * ds.gamma, n=n)
           for s in (0.5, 1.0, 2.0)}
    plan_a = _fold_chain_plan({0.5: gam[0.5], 1.0: gam[1.0]}, y, masks,
                              chunks, ds.C, max_resident=3)
    plan_b = _fold_chain_plan({1.0: gam[1.0], 2.0: gam[2.0]}, y, masks,
                              chunks, ds.C, max_resident=3)
    service = StudyService(chunk_iters=64, lane_quantum=2, max_width=4,
                           max_resident=3)
    events = []
    service.pool.on_trace = events.append
    service.submit("alice", "a", json.loads(json.dumps(
        plan_to_dict(plan_a))), lambda m: None)
    service.submit("bob", "b", json.loads(json.dumps(
        plan_to_dict(plan_b))), lambda m: None)
    entries = [(st.tenant, st.plan) for st in service._studies.values()]
    while service.pool.step():
        pass
    oracle = plan_sim.oracle_from_trace(events)
    sa = plan_sim.simulate_plans(entries, oracle=oracle)
    _assert_trace_equal(sa.events, events)
    assert set(sa.tenant_lane_chunks) == {"'alice'", "'bob'"}
    assert any(e[0] == "shares" for e in events)


def test_bound_oracles_bracket_exact():
    """min/max bounding oracles bracket the exact schedule's chunk count
    and resident peak."""
    ds, X, y, chunks, masks = _setup("heart")
    n = int(y.shape[0])
    sources = {s: KernelSpec(X=X, gamma=s * ds.gamma, n=n)
               for s in (0.5, 2.0)}
    plan = _fold_chain_plan(sources, y, masks, chunks, ds.C,
                            cache_bytes=2 * n * n * 8)
    events, _ = plan_sim.dry_run(plan)
    exact = plan_sim.simulate_plan(
        plan, oracle=plan_sim.oracle_from_trace(events))
    lo = plan_sim.simulate_plan(plan, oracle=plan_sim.BoundOracle("min"))
    hi = plan_sim.simulate_plan(
        plan, oracle=plan_sim.BoundOracle(
            "max", horizon=max(exact.n_iters.values()) + plan.chunk_iters))
    assert lo.chunks <= exact.chunks <= hi.chunks
    assert lo.peak_resident_bytes <= exact.peak_resident_bytes \
        <= hi.peak_resident_bytes
    assert lo.lane_chunks <= exact.lane_chunks <= hi.lane_chunks


def test_exact_oracle_missing_lane_raises():
    with pytest.raises(KeyError, match="no n_iter"):
        plan_sim.ExactOracle({}).target("lane", 100)
    with pytest.raises(ValueError, match="horizon"):
        plan_sim.BoundOracle("max")
    with pytest.raises(ValueError, match="unknown bound"):
        plan_sim.BoundOracle("median")


# ------------------------------------- time-resolved admission findings

def _pinned_plus_two_managed():
    """The crafted case the shape-only gate admits: a pinned dense
    kernel plus two managed specs, budgeted so the worst single managed
    source fits on top of the pinned bytes but the schedule co-holds
    both managed kernels."""
    ds, X, y, chunks, masks = _setup("heart", n=60)
    n = int(y.shape[0])
    dense = DenseKernel(kernel_matrix(X, X, gamma=ds.gamma))
    spec1 = KernelSpec(X=X, gamma=0.5 * ds.gamma, n=n)
    spec2 = KernelSpec(X=X, gamma=2.0 * ds.gamma, n=n)
    pinned_b = int(dense.K.size * dense.K.dtype.itemsize)
    managed_b = n * n * np.dtype(spec1.dtype).itemsize
    budget = pinned_b + managed_b + managed_b // 4
    plan = Plan(sources={"pin": dense, "g1": spec1, "g2": spec2}, y=y,
                chunk_iters=64, lane_quantum=2, cache_bytes=budget)
    for key in ("pin", "g1", "g2"):
        plan.lane((key, 0), source=key, train_mask=masks[0], C=ds.C,
                  alpha0=jnp.zeros(n), f0=-y)
        if key != "pin":            # raw-K sources cannot back an eval
            plan.evaluate((key, 0), chunks[0])
    return plan, budget


def test_time_resolved_infeasibility_caught():
    """The acceptance case: worst single source fits (the shape gate
    admits), but the time-resolved peak exceeds cache_bytes — strict
    mode rejects with ``cache-infeasible-time``."""
    plan, budget = _pinned_plus_two_managed()
    pa0 = plan_check.analyze_plan(plan, simulate="off")
    assert not pa0.report.errors        # the old gate admits it
    with pytest.raises(plan_check.PlanRejected) as exc:
        plan_check.check_plan(plan)
    rules = {f.rule for f in exc.value.analysis.report.errors}
    assert "cache-infeasible-time" in rules
    assert exc.value.analysis.sim["min"]["peak_resident_bytes"] > budget


def test_daemon_rejects_time_infeasible_with_structured_analysis():
    plan, budget = _pinned_plus_two_managed()
    service = StudyService(chunk_iters=64, lane_quantum=2,
                           cache_bytes=budget)
    emitted = []
    service.submit("alice", "bad", json.loads(json.dumps(
        plan_to_dict(plan))), emitted.append)
    [msg] = emitted
    assert msg["type"] == "rejected"
    assert "cache-infeasible-time" in {f["rule"] for f in msg["findings"]}
    assert msg["analysis"]["sim"]["min"]["peak_resident_bytes"] > budget
    assert not service._studies           # nothing entered the pool


def test_sim_summaries_attached_on_admission():
    """An admissible plan's analysis carries min/max schedule summaries
    (the daemon's admitted path runs the simulator too)."""
    ds, X, y, chunks, masks = _setup("heart")
    n = int(y.shape[0])
    plan = _fold_chain_plan(
        {1.0: KernelSpec(X=X, gamma=ds.gamma, n=n)}, y, masks, chunks,
        ds.C, cache_bytes=2 * n * n * 8)
    pa = plan_check.check_plan(plan)
    assert set(pa.sim) == {"min", "max"}
    assert pa.sim["min"]["lane_chunks"] <= pa.sim["max"]["lane_chunks"]
    assert pa.to_json()["sim"]["max"]["oracle"] == "bound:max"


# -------------------------------------------------- per-tenant budgets

def _single_lane_plan(**knobs):
    ds, X, y, chunks, masks = _setup("heart", n=60)
    n = int(y.shape[0])
    plan = Plan(sources={"g": KernelSpec(X=X, gamma=ds.gamma, n=n)}, y=y,
                chunk_iters=64, lane_quantum=2, **knobs)
    plan.lane(("g", 0), source="g", train_mask=masks[0], C=ds.C,
              alpha0=jnp.zeros(n), f0=-y)
    plan.evaluate(("g", 0), chunks[0])
    return plan


def test_tenant_chunk_budget_rejects_and_admits():
    plan = _single_lane_plan()
    wire = json.loads(json.dumps(plan_to_dict(plan)))
    tight = StudyService(chunk_iters=64, lane_quantum=2,
                         plan_chunk_budget=2)
    assert tight.pool_contract()["plan_chunk_budget"] == 2
    emitted = []
    tight.submit("bob", "big", wire, emitted.append)
    [msg] = emitted
    assert msg["type"] == "rejected"
    assert "tenant-budget" in {f["rule"] for f in msg["findings"]}

    roomy = StudyService(chunk_iters=64, lane_quantum=2,
                         plan_chunk_budget=10_000,
                         plan_bytes_budget=10 ** 9)
    emitted = []
    roomy.submit("bob", "ok", wire, emitted.append)
    assert emitted[0]["type"] == "admitted"


def test_tenant_bytes_budget_rejects():
    plan = _single_lane_plan()
    service = StudyService(chunk_iters=64, lane_quantum=2,
                           plan_bytes_budget=100)   # < one kernel
    emitted = []
    service.submit("bob", "fat", json.loads(json.dumps(
        plan_to_dict(plan))), emitted.append)
    [msg] = emitted
    assert msg["type"] == "rejected"
    bad = [f for f in msg["findings"] if f["rule"] == "tenant-budget"]
    assert bad and bad[0]["symbol"] == "resident_bytes"


def test_rejection_round_trips_the_wire():
    """Satellite: the full structured analysis crosses the real socket —
    ``PlanRejectedByServer.analysis`` carries findings AND sim bounds."""
    import os
    import time
    import uuid
    sock = f"/tmp/plan-sim-{uuid.uuid4().hex[:8]}.sock"
    plan, budget = _pinned_plus_two_managed()
    service = StudyService(chunk_iters=64, lane_quantum=2,
                           cache_bytes=budget)
    server = StudyServer(sock, service)
    t = threading.Thread(target=server.serve_forever, daemon=True)
    t.start()
    for _ in range(200):
        if os.path.exists(sock):
            break
        time.sleep(0.05)
    try:
        with StudyClient(sock, "alice") as cli:
            assert cli.pool_contract["plan_chunk_budget"] == 0
            with pytest.raises(PlanRejectedByServer) as exc:
                cli.submit("bad", plan)
            err = exc.value
            assert {f["rule"] for f in err.findings} >= \
                {"cache-infeasible-time"}
            assert err.analysis["sim"]["min"]["peak_resident_bytes"] \
                > budget
            assert err.analysis["findings"] == err.findings
            cli.shutdown()
        t.join(timeout=30)
    finally:
        server.stop_accepting()
        if os.path.exists(sock):
            os.unlink(sock)


# ------------------------------------------- pure-function properties

def _check_width_properties(peak, quantum, max_width):
    # max_width caps the SELECTION (k), not the bucketed width — a group
    # can never exceed the cap, so that's the live k range
    widths = possible_widths(peak, quantum, max_width)
    cap = min(peak, max_width) if max_width else peak
    for k in range(1, cap + 1):
        w = bucket_width(k, quantum)
        assert w in widths, (k, peak, quantum, max_width, widths)


def _check_packing_properties(rng):
    class L:
        def __init__(self, i, source, tenant, served):
            self.i, self.source, self.tenant, self.served = \
                i, source, tenant, served

        def __repr__(self):
            return f"L{self.i}"

    n_src = rng.randint(1, 4)
    srcs = [f"s{j}" for j in range(n_src)]
    tenants = [None] if rng.random() < 0.4 else \
        [f"t{j}" for j in range(rng.randint(1, 3))]
    lanes = [L(i, rng.choice(srcs), rng.choice(tenants),
               rng.randint(0, 5)) for i in range(rng.randint(1, 12))]
    resident_set = {s for s in srcs if rng.random() < 0.5}
    sticky = rng.choice(srcs + [None])
    max_width = rng.randint(1, 8)
    tenant_served = {t: rng.randint(0, 20) for t in tenants}
    kw = dict(sticky=sticky, resident=lambda s: s in resident_set,
              served=lambda ln: ln.served, source=lambda ln: ln.source)
    sel = select_capped(lanes, max_width=max_width,
                        tenant=lambda ln: ln.tenant,
                        tenant_served=tenant_served, **kw)
    assert len(sel) == min(max_width, len(lanes))
    assert len(set(map(id, sel))) == len(sel)
    assert all(ln in lanes for ln in sel)
    order = order_capped(lanes, **kw)
    assert sorted(map(id, order)) == sorted(map(id, lanes))
    if len(set(ln.tenant for ln in lanes)) <= 1:
        assert sel == order[:max_width]   # single-tenant = plain priority
    # sticky-source lanes sort ahead of the rest
    if sticky is not None:
        head = [ln.source == sticky for ln in order]
        assert head == sorted(head, reverse=True)

    # budget_sources: pinned pass through, managed prefix honors fits
    nbytes = {s: rng.randint(1, 100) for s in srcs}
    pinned_set = {s for s in srcs if rng.random() < 0.3}
    budget = rng.randint(50, 250)
    out = budget_sources(
        [ln.source for ln in lanes], budgeted=True,
        pinned=lambda s: s in pinned_set,
        resident=lambda s: s in resident_set, sticky=sticky,
        nbytes=nbytes.__getitem__,
        fits=lambda c, b: b <= budget)
    used = {ln.source for ln in lanes}
    assert out <= used
    assert used & pinned_set <= out       # pinned never budgeted out
    taken = [s for s in out if s not in pinned_set]
    if len(used) > 1:
        assert sum(nbytes[s] for s in taken) <= budget or len(taken) == 1

    # pick_victim: a member; never the sticky key when another exists
    if srcs:
        keys = list(srcs)
        victim = pick_victim(
            keys, sticky=sticky,
            distance=lambda k: rng.randint(0, 3))
        assert victim in keys
        if sticky in keys and len(keys) > 1:
            assert victim != sticky


if HAVE_HYPOTHESIS:                                   # pragma: no cover
    @settings(max_examples=100, deadline=None)
    @given(st.integers(1, 64), st.integers(1, 16),
           st.integers(0, 32))
    def test_width_bucketing_properties(peak, quantum, max_width):
        _check_width_properties(peak, quantum, max_width)

    @settings(max_examples=100, deadline=None)
    @given(st.integers(0, 10_000))
    def test_packing_pure_function_properties(seed):
        _check_packing_properties(random.Random(seed))
else:
    def test_width_bucketing_properties():
        rng = random.Random(0)
        for _ in range(300):
            _check_width_properties(rng.randint(1, 64), rng.randint(1, 16),
                                    rng.randint(0, 32))

    def test_packing_pure_function_properties():
        for seed in range(300):
            _check_packing_properties(random.Random(seed))


def test_randomized_lane_graphs_trace_parity():
    """Randomized graphs/budgets/widths: the live pool and the simulator
    agree event-for-event — the pure functions ARE the scheduler."""
    ds, X, y, chunks, masks = _setup("heart", n=36, k=3)
    n = int(y.shape[0])
    for seed in range(4):
        rng = random.Random(seed)
        n_src = rng.randint(1, 3)
        sources = {f"s{j}": KernelSpec(X=X, gamma=(0.5 + j) * ds.gamma,
                                       n=n) for j in range(n_src)}
        knobs = dict(
            chunk_iters=rng.choice([32, 64]),
            lane_quantum=rng.choice([1, 2, 4]),
            max_width=rng.choice([None, 2, 3]),
            max_resident=rng.choice([0, 2]),
            cache_bytes=rng.choice([0, 2 * n * n * 8]))
        plan = Plan(sources=sources, y=y, **knobs)
        prev = {}
        for key in sources:
            for h in range(rng.randint(1, 3)):
                lid = (key, h)
                if h == 0 or rng.random() < 0.5:
                    # fresh or ``after``-held start
                    after = prev.get(rng.choice(list(sources))) \
                        if h > 0 else None
                    plan.lane(lid, source=key, train_mask=masks[h],
                              C=ds.C, alpha0=jnp.zeros(n), f0=-y,
                              after=after)
                else:
                    S, R, T = _transition_idx(chunks, h - 1, h)
                    plan.lane(lid, source=key, train_mask=masks[h],
                              C=ds.C, dep=(key, h - 1), transform="fold",
                              params=dict(method="sir", S_idx=S, R_idx=R,
                                          T_idx=T))
                plan.evaluate(lid, chunks[h])
                prev[key] = lid
        snap = rng.choice([0, 3])
        events, _ = plan_sim.dry_run(plan, snapshot_every=snap)
        oracle = plan_sim.oracle_from_trace(events)
        sa = plan_sim.simulate_plan(plan, oracle=oracle,
                                    snapshot_every=snap)
        _assert_trace_equal(sa.events, events)
