"""shard_map expert-parallel MoE == scatter MoE (8-device subprocess)."""
import os
import subprocess
import sys
import textwrap

import jax
import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import warnings; warnings.filterwarnings("ignore")
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import AxisType
    from repro.configs import get_config
    from repro.models import init_params
    from repro.models import moe as M

    cfg = get_config("deepseek-v3-671b", smoke=True).replace(
        capacity_factor=8.0, n_experts=8)
    params = init_params(M.experts_def(cfg), jax.random.PRNGKey(0), jnp.float32)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(8, 16, cfg.d_model)), jnp.float32) * 0.3
    mesh = jax.make_mesh((4, 2), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
    with jax.sharding.set_mesh(mesh):
        y1, _ = jax.jit(lambda p, x: M._moe_scatter(p, x, cfg))(params, x)
        cfg2 = cfg.replace(moe_impl="shard_map")
        y2, _ = jax.jit(lambda p, x: M.moe_apply(p, x, cfg2))(params, x)
        # grads must flow through the shard_map path
        g = jax.jit(jax.grad(lambda p: jnp.sum(M.moe_apply(p, x, cfg2)[0]**2)
                             ))(params)
    err = float(jnp.abs(y1 - y2).max())
    assert err < 1e-5, err
    gsum = sum(float(jnp.abs(l).sum()) for l in jax.tree.leaves(g))
    assert gsum > 0
    print("OK", err)
""")


@pytest.mark.skipif(
    not (hasattr(jax.sharding, "AxisType") and hasattr(jax.sharding, "set_mesh")),
    reason="subprocess harness uses jax.sharding.AxisType / set_mesh; "
           "needs jax >= 0.5")
def test_shard_map_moe_matches_scatter():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, cwd=os.getcwd(),
                         timeout=580)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
