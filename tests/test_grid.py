"""Hyper-parameter grid driver: cell parity with run_cv, kernel reuse,
C-adjacent seeding, and cold-row batching."""
import dataclasses

import pytest

from repro.core.cv import run_cv
from repro.core.grid import run_grid
from repro.data.svm_suite import make_dataset

CS = [1.0, 8.0]
GAMMAS = [0.1, 0.3]


@pytest.fixture(scope="module")
def ds():
    return make_dataset("heart", n_override=120)


def test_grid_covers_all_cells(ds):
    rep = run_grid(ds, Cs=CS, gammas=GAMMAS, k=4, method="sir")
    assert len(rep.cells) == len(CS) * len(GAMMAS)
    assert {(c.C, c.gamma) for c in rep.cells} == \
        {(C, g) for C in CS for g in GAMMAS}
    assert all(c.converged for c in rep.cells)
    best = rep.best()
    assert best.accuracy == max(c.accuracy for c in rep.cells)


@pytest.mark.parametrize("method", ["sir", "cold"])
def test_grid_cell_matches_run_cv(ds, method):
    """Each grid cell must reproduce the standalone CV run on that cell's
    hyper-parameters exactly (same engine, same seeds, same schedule)."""
    rep = run_grid(ds, Cs=CS, gammas=GAMMAS, k=4, method=method)
    cell = [c for c in rep.cells if c.C == 8.0 and c.gamma == 0.3][0]
    ds_cell = dataclasses.replace(ds, C=8.0, gamma=0.3)
    cv = run_cv(ds_cell, k=4, method=method)
    assert cell.accuracy == pytest.approx(cv.accuracy, abs=1e-12)
    assert cell.iterations == cv.total_iterations


def test_seed_across_C_same_accuracy(ds):
    """C-chained fold 0 changes the starting point, not the fixed point."""
    plain = run_grid(ds, Cs=[0.5, 2.0, 8.0], gammas=[0.2], k=4, method="sir")
    chained = run_grid(ds, Cs=[0.5, 2.0, 8.0], gammas=[0.2], k=4,
                       method="sir", seed_across_C=True)
    for p, c in zip(plain.cells, chained.cells):
        assert (p.C, p.gamma) == (c.C, c.gamma)
        assert c.accuracy == pytest.approx(p.accuracy, abs=0.05)
        assert c.converged


def test_grid_ato_batched_row(ds):
    """method="ato": each cell's fold transitions run the jittable ATO ramp
    (seeding.ato_seed) as scheduler admission transforms, so cells advance
    independently. Cells must match the standalone ATO CV run on accuracy
    and converge; iteration counts are comparable (same per-lane m_cap as
    run_cv, so usually identical, but not contractually bit-equal)."""
    rep = run_grid(ds, Cs=CS, gammas=[0.3], k=4, method="ato")
    assert len(rep.cells) == len(CS)
    assert all(c.converged for c in rep.cells)
    for C in CS:
        cell = [c for c in rep.cells if c.C == C][0]
        cv = run_cv(dataclasses.replace(ds, C=C, gamma=0.3), k=4,
                    method="ato")
        assert cell.accuracy == pytest.approx(cv.accuracy, abs=0.05)
        assert cell.iterations <= 2 * cv.total_iterations + 500
    # ATO transitions compose with the C-chained fold 0 (seed_across_C)
    rep2 = run_grid(ds, Cs=CS, gammas=[0.3], k=4, method="ato",
                    seed_across_C=True)
    assert all(c.converged for c in rep2.cells)


def test_grid_reports_times(ds):
    rep = run_grid(ds, Cs=CS, gammas=GAMMAS, k=3, method="sir")
    assert rep.kernel_time > 0 and rep.solve_time > 0
    rows = rep.rows()
    assert len(rows) == 4 and all("accuracy" in r for r in rows)
