"""Study API / multi-source lane pool: per-lane bit-parity with the
single-source sequential path across schedule shapes and mixed gamma
sources, mid-study kill/resume under a different schedule, plan-built
LOO/grid parity, the seed-transform registry, and the SVC facade."""
import shutil

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.core import seeding
from repro.core.cv import _fold_masks, _transition_idx, run_loo
from repro.core.study import Plan, StudyCheckpoint, run_plan
from repro.data.svm_suite import kfold_chunks, make_dataset
from repro.svm import (DenseKernel, LanePool, init_f, kernel_matrix,
                       smo_solve)

SUITE = ("adult", "heart", "madelon", "mnist", "webdata")
GAMMA_SCALES = (0.5, 2.0)   # two sources per dataset: gamma/2 and 2*gamma


def _setup(name, n=120, k=4):
    ds = make_dataset(name, n_override=n)
    X = jnp.asarray(ds.X)
    y = jnp.asarray(ds.y, jnp.float64)
    chunks = kfold_chunks(n, k, seed=0)
    nn = chunks.size
    Ks = [kernel_matrix(X, X, gamma=s * ds.gamma)[:nn][:, :nn]
          for s in GAMMA_SCALES]
    return ds, Ks, y[:nn], chunks, jnp.asarray(_fold_masks(chunks))


@pytest.mark.parametrize("max_width", [0, 1, 3])
@pytest.mark.parametrize("name", SUITE)
def test_pool_multi_source_parity_bitwise(name, max_width):
    """Lanes spread over two gamma sources, driven through one pool with
    tiny chunks (many forced repack boundaries), must be bit-identical to
    sequential single-source solves on every suite dataset, for every
    schedule shape: unbounded packing, pure width-1 round-robin (the CPU
    cost-model default), and a capped width that parks/rotates lanes
    across sources."""
    ds, (K0, K1), y, chunks, masks = _setup(name)
    n = y.shape[0]
    pool = LanePool({"g0": DenseKernel(K0), "g1": DenseKernel(K1)}, y,
                    chunk_iters=64, lane_quantum=2, max_width=max_width)
    for h in range(3):
        for key in ("g0", "g1"):
            pool.add((key, h), masks[h], ds.C, jnp.zeros(n, K0.dtype), -y,
                     source=key)
    results = pool.run()
    for key, K in (("g0", K0), ("g1", K1)):
        for h in range(3):
            seq = smo_solve(K, y, masks[h], ds.C, jnp.zeros(n), -y)
            got = results[(key, h)]
            np.testing.assert_array_equal(np.asarray(seq.alpha),
                                          np.asarray(got.alpha))
            np.testing.assert_array_equal(np.asarray(seq.f),
                                          np.asarray(got.f))
            assert int(seq.n_iter) == int(got.n_iter)
            assert bool(seq.converged) == bool(got.converged)
    occ = pool.occupancy
    assert set(occ["per_source"]) == {"g0", "g1"}
    if max_width:
        assert occ["peak_width"] <= 2 * max_width  # <= cap per chunk, summed
    else:
        assert occ["peak_width"] >= 4


def test_pool_cross_source_dependency():
    """A lane in one source seeded from a lane in ANOTHER source (admission
    crosses kernel sources) reproduces the eagerly-seeded solve exactly."""
    ds, (K0, K1), y, chunks, masks = _setup("heart")
    n = y.shape[0]
    pool = LanePool({"g0": DenseKernel(K0), "g1": DenseKernel(K1)}, y,
                    chunk_iters=64, max_width=0)
    pool.add("a", masks[0], ds.C, jnp.zeros(n, K0.dtype), -y, source="g0")

    def seed_fn(prev):
        a0 = seeding.scale_seed_C(prev.alpha, y, ds.C, 2 * ds.C, masks[0])
        return a0, init_f(K1, y, a0)
    pool.add("b", masks[0], 2 * ds.C, source="g1", dep="a", seed_fn=seed_fn)
    results = pool.run()

    ref_a = smo_solve(K0, y, masks[0], ds.C, jnp.zeros(n), -y)
    a0 = seeding.scale_seed_C(ref_a.alpha, y, ds.C, 2 * ds.C, masks[0])
    ref_b = smo_solve(K1, y, masks[0], 2 * ds.C, a0, init_f(K1, y, a0))
    np.testing.assert_array_equal(np.asarray(ref_b.alpha),
                                  np.asarray(results["b"].alpha))
    assert int(ref_b.n_iter) == int(results["b"].n_iter)


def test_pool_after_ordering_edge():
    """An ``after`` edge holds an explicitly-started lane until the target
    retires, without touching its start point."""
    ds, (K0, _), y, chunks, masks = _setup("heart")
    n = y.shape[0]
    pool = LanePool({"g0": DenseKernel(K0)}, y, chunk_iters=64)
    order = []
    pool.on_result = lambda lid, res: order.append(lid)
    pool.add("first", masks[0], ds.C, jnp.zeros(n, K0.dtype), -y)
    pool.add("second", masks[1], ds.C, jnp.zeros(n, K0.dtype), -y,
             after="first")
    results = pool.run()
    assert order == ["first", "second"]
    seq = smo_solve(K0, y, masks[1], ds.C, jnp.zeros(n), -y)
    np.testing.assert_array_equal(np.asarray(seq.alpha),
                                  np.asarray(results["second"].alpha))


def _grid_style_plan(Ks, y, masks, chunks, C, max_width=0):
    """A small two-source plan with fold-chain dependencies and tuple lane
    ids — the shape the grid driver builds."""
    plan = Plan(sources={0: DenseKernel(Ks[0]), 1: DenseKernel(Ks[1])}, y=y,
                chunk_iters=64, lane_quantum=2, max_width=max_width)
    n = y.shape[0]
    for gi in (0, 1):
        plan.lane((gi, 0), source=gi, train_mask=masks[0], C=C,
                  alpha0=jnp.zeros(n), f0=-y)
        for h in (1, 2):
            S, R, T = _transition_idx(chunks, h - 1, h)
            plan.lane((gi, h), source=gi, train_mask=masks[h], C=C,
                      dep=(gi, h - 1), transform="fold",
                      params=dict(method="sir", S_idx=S, R_idx=R, T_idx=T))
        for h in range(3):
            plan.evaluate((gi, h), chunks[h])
    return plan


def test_run_plan_kill_resume_different_schedule(tmp_path):
    """Kill a checkpointed study mid-flight; resume under a DIFFERENT
    schedule shape (width-1 round-robin vs unbounded) and with tuple lane
    ids (the JSON round-trip case). Every lane must land on the identical
    result, and the restored-done lanes must be flagged."""
    ds, Ks, y, chunks, masks = _setup("heart")
    full = run_plan(_grid_style_plan(Ks, y, masks, chunks, ds.C))

    mgr = CheckpointManager(str(tmp_path / "study"), max_to_keep=1000)
    ck = StudyCheckpoint(manager=mgr, meta={"k": 3, "dataset": "heart"})
    run_plan(_grid_style_plan(Ks, y, masks, chunks, ds.C), checkpoint=ck)
    steps = mgr.steps_of_class("study")
    assert len(steps) >= 6
    # 'crash' two-thirds in: by then the fold-chain heads have retired, so
    # the surviving snapshot holds BOTH done lanes (restored as results)
    # and live mid-flight lanes (resumed mid-sequence)
    for s in steps[2 * len(steps) // 3:]:
        shutil.rmtree(mgr._step_dir(s))

    mgr2 = CheckpointManager(str(tmp_path / "study"), max_to_keep=1000)
    ck2 = StudyCheckpoint(manager=mgr2, meta={"k": 3, "dataset": "heart"})
    resumed = run_plan(_grid_style_plan(Ks, y, masks, chunks, ds.C,
                                        max_width=1), checkpoint=ck2)
    for lid, res in full.results.items():
        np.testing.assert_array_equal(np.asarray(res.alpha),
                                      np.asarray(resumed.results[lid].alpha))
        assert full.stats[lid].n_iter == resumed.stats[lid].n_iter
        assert full.evals[lid] == resumed.evals[lid]
    assert any(st.restored for st in resumed.stats.values())

    # a different plan identity must be rejected, not silently resumed
    mgr3 = CheckpointManager(str(tmp_path / "study"), max_to_keep=1000)
    ck3 = StudyCheckpoint(manager=mgr3, meta={"k": 4, "dataset": "heart"})
    with pytest.raises(ValueError, match="cannot resume"):
        run_plan(_grid_style_plan(Ks, y, masks, chunks, ds.C),
                 checkpoint=ck3)


def test_run_plan_streams_results():
    """on_result fires once per solved lane, at retirement, with the final
    result object — long studies consume lanes as they land."""
    ds, Ks, y, chunks, masks = _setup("heart")
    seen = {}
    sres = run_plan(_grid_style_plan(Ks, y, masks, chunks, ds.C),
                    on_result=lambda lid, res: seen.setdefault(lid, res))
    assert set(seen) == set(sres.results)
    for lid, res in seen.items():
        assert res is sres.results[lid]


def test_plan_wire_roundtrip_zoo():
    """``plan_to_dict`` -> real JSON -> ``plan_from_dict`` over this
    file's plan shapes: the round-tripped grid plan must EXECUTE
    bit-identically, every zoo member must be a serialization fixed
    point, and hostile wire images die at parse time with named errors
    (the daemon's first line of defense — before the analyzer runs)."""
    import copy
    import json

    from repro.core.study import plan_from_dict, plan_to_dict
    from repro.svm.sources import KernelSpec

    ds, Ks, y, chunks, masks = _setup("heart")
    n = y.shape[0]
    X = jnp.asarray(ds.X)[:n]
    zoo = [_grid_style_plan(Ks, y, masks, chunks, ds.C),
           _grid_style_plan(Ks, y, masks, chunks, ds.C, max_width=1)]
    spec_plan = Plan(sources={"s": KernelSpec(X=X, gamma=ds.gamma, n=n)},
                     y=y, chunk_iters=64, max_resident=1, cache_bytes=1 << 30)
    spec_plan.lane("a", train_mask=masks[0], C=ds.C,
                   alpha0=jnp.zeros(n), f0=-y)
    spec_plan.lane("b", train_mask=masks[0], C=2 * ds.C, dep="a",
                   transform="scale_C",
                   params=dict(C_old=ds.C, train_mask=masks[0]),
                   after="a")
    spec_plan.evaluate("a", chunks[0])
    zoo.append(spec_plan)
    pallas_plan = Plan(sources={0: KernelSpec(X=X, gamma=ds.gamma, n=n)},
                       y=y, wss="1", source_backend="pallas_rbf")
    pallas_plan.lane(0, train_mask=masks[0], C=ds.C,
                     alpha0=jnp.zeros(n), f0=-y)
    zoo.append(pallas_plan)

    for plan in zoo:
        d = json.loads(json.dumps(plan_to_dict(plan)))
        back = plan_from_dict(d)
        # fixed point: re-serializing the parsed plan is byte-stable
        assert json.loads(json.dumps(plan_to_dict(back))) == d

    solo = run_plan(zoo[0])
    wired = run_plan(plan_from_dict(
        json.loads(json.dumps(plan_to_dict(zoo[0])))))
    assert set(solo.results) == set(wired.results)
    for lid, res in solo.results.items():
        np.testing.assert_array_equal(np.asarray(res.alpha),
                                      np.asarray(wired.results[lid].alpha))
        np.testing.assert_array_equal(np.asarray(res.f),
                                      np.asarray(wired.results[lid].f))
        assert int(res.n_iter) == int(wired.results[lid].n_iter)
    assert solo.evals == wired.evals

    # parse-time hardening: hostile images name their defect
    good = plan_to_dict(zoo[0])
    bad = copy.deepcopy(good)
    bad["lanes"][1]["transform"] = "exfiltrate"
    with pytest.raises(ValueError, match="unknown transform 'exfiltrate'"):
        plan_from_dict(bad)
    good_spec = plan_to_dict(spec_plan)
    bad = copy.deepcopy(good_spec)
    bad["sources"][0][1]["kind"] = "poly"
    with pytest.raises(ValueError, match="unknown source kind 'poly'"):
        plan_from_dict(bad)
    bad = copy.deepcopy(good)
    bad["lanes"][0]["C"] = float("inf")
    with pytest.raises(ValueError, match="non-finite"):
        plan_from_dict(bad)
    bad = copy.deepcopy(good)
    bad["tol"] = float("nan")
    with pytest.raises(ValueError, match="non-finite"):
        plan_from_dict(bad)
    bad = copy.deepcopy(good)
    del bad["__plan__"]
    with pytest.raises(ValueError, match="not a wire plan"):
        plan_from_dict(bad)


def test_transform_registry_matches_seeders():
    """The named transforms reproduce their underlying seeders exactly."""
    ds, (K, _), y, chunks, masks = _setup("heart")
    prev = smo_solve(K, y, masks[0], ds.C, jnp.zeros(y.shape[0]), -y)
    S, R, T = _transition_idx(chunks, 0, 1)
    for method in ("sir", "mir", "ato"):
        direct = seeding.SEEDERS[method](K, y, ds.C, prev, S, R, T)
        named = seeding.TRANSFORMS["fold"](K, y, ds.C, prev, method=method,
                                           S_idx=S, R_idx=R, T_idx=T)
        np.testing.assert_array_equal(np.asarray(direct), np.asarray(named))
    sc = seeding.TRANSFORMS["scale_C"](K, y, 2 * ds.C, prev, C_old=ds.C,
                                       train_mask=masks[0])
    np.testing.assert_array_equal(
        np.asarray(sc),
        np.asarray(seeding.scale_seed_C(prev.alpha, y, ds.C, 2 * ds.C,
                                        masks[0])))
    assert {"fold", "scale_C", "loo_avg", "loo_top"} <= set(seeding.TRANSFORMS)


def test_run_plan_rejects_bad_specs():
    ds, (K, _), y, chunks, masks = _setup("heart")
    n = y.shape[0]
    plan = Plan(sources={"s": DenseKernel(K)}, y=y)
    plan.lane(0, train_mask=masks[0], C=ds.C, alpha0=jnp.zeros(n), f0=-y)
    plan.lane(0, train_mask=masks[1], C=ds.C, alpha0=jnp.zeros(n), f0=-y)
    with pytest.raises(ValueError, match="duplicate"):
        run_plan(plan)
    plan2 = Plan(sources={"s": DenseKernel(K)}, y=y)
    plan2.lane(0, train_mask=masks[0], C=ds.C, alpha0=jnp.zeros(n), f0=-y)
    plan2.lane(1, train_mask=masks[1], C=ds.C, dep=0, transform="nope")
    with pytest.raises(ValueError, match="unknown transform"):
        run_plan(plan2)


def test_validate_plan_cycle_names_the_cycle():
    """A dep/after cycle is reported AS the cycle — every offending lane
    by name, not a drain-time 'pending lanes' dump."""
    ds, (K, _), y, chunks, masks = _setup("heart")
    n = y.shape[0]
    plan = Plan(sources={"s": DenseKernel(K)}, y=y)
    plan.lane("a", train_mask=masks[0], C=ds.C, alpha0=jnp.zeros(n), f0=-y,
              after="b")
    plan.lane("b", train_mask=masks[1], C=ds.C, alpha0=jnp.zeros(n), f0=-y,
              after="a")
    with pytest.raises(ValueError, match=r"cycle.*'.' -> '.' -> '.'"):
        run_plan(plan)


def test_validate_plan_dense_k_names_lane_and_source():
    """A seed transform on a K-less source fails at entry, naming both
    the lane and the source key it resolved to."""
    from repro.svm import PallasRBF
    ds, _, y, chunks, masks = _setup("heart")
    n = y.shape[0]
    X = jnp.asarray(ds.X)[:n]
    plan = Plan(sources={"rbf": PallasRBF(X, ds.gamma)}, y=y, wss="1")
    plan.lane("w0", train_mask=masks[0], C=ds.C, alpha0=jnp.zeros(n), f0=-y)
    plan.lane("w1", train_mask=masks[1], C=ds.C, dep="w0", transform="fold",
              params=dict(method="sir",
                          S_idx=jnp.arange(4), R_idx=jnp.arange(4),
                          T_idx=jnp.arange(4)))
    with pytest.raises(ValueError, match=r"'w1'.*'fold'.*'rbf' has no K"):
        run_plan(plan)


def test_bad_source_backend_fails_at_entry():
    """A typo'd ``source_backend`` is rejected before any source could
    materialize — on the Plan (via run_plan) and at run_grid's entry."""
    from repro.core.grid import run_grid
    from repro.svm.sources import KernelSpec

    class ExplodingSpec(KernelSpec):
        def materialize(self):
            raise AssertionError("materialized during entry validation")

    ds, _, y, chunks, masks = _setup("heart")
    n = y.shape[0]
    spec = ExplodingSpec(X=jnp.asarray(ds.X), gamma=ds.gamma, n=n)
    plan = Plan(sources={0: spec}, y=y, source_backend="pallas_rbt")
    plan.lane(0, source=0, train_mask=masks[0], C=ds.C,
              alpha0=jnp.zeros(n), f0=-y)
    with pytest.raises(ValueError, match="unknown source_backend"):
        run_plan(plan)
    with pytest.raises(ValueError, match="unknown source_backend"):
        run_grid(ds, [ds.C], [ds.gamma], k=3, source_backend="dence")


# ----------------------------------------------------------------- run_loo

def _loo_reference(ds, method, rounds, tol=1e-3, max_iter=2_000_000):
    """The pre-Study sequential LOO loop, kept inline as the parity oracle
    for the plan-built ``run_loo``."""
    X = jnp.asarray(ds.X)
    y = jnp.asarray(ds.y, jnp.float64)
    n = ds.n
    K = kernel_matrix(X, X, kind="rbf", gamma=ds.gamma)
    full = smo_solve(K, y, jnp.ones(n, bool), ds.C, jnp.zeros(n, K.dtype),
                     -y, tol=tol, max_iter=max_iter)
    from repro.svm import bias_from_solution, predict
    total_iters, correct = 0, 0
    prev, prev_t = full, None
    for t in range(rounds):
        t_j = jnp.asarray(t)
        mask = jnp.ones(n, bool).at[t_j].set(False)
        if method == "cold":
            alpha0, f0 = jnp.zeros(n, K.dtype), -y
        elif method in ("avg", "top"):
            fn = (seeding.avg_seed_loo if method == "avg"
                  else seeding.top_seed_loo)
            alpha0 = fn(K, y, ds.C, full.alpha, t_j)
            f0 = init_f(K, y, alpha0)
        else:
            if prev_t is None:
                alpha0 = seeding.avg_seed_loo(K, y, ds.C, full.alpha, t_j)
            else:
                S = jnp.asarray(np.delete(np.arange(n), [prev_t, t]))
                alpha0 = seeding.SEEDERS[method](
                    K, y, ds.C, prev, S, jnp.asarray([t]),
                    jnp.asarray([prev_t]))
            f0 = init_f(K, y, alpha0)
        res = smo_solve(K, y, mask, ds.C, alpha0, f0, tol=tol,
                        max_iter=max_iter)
        total_iters += int(res.n_iter)
        b = bias_from_solution(res, y, mask, ds.C)
        pred = predict(K[t_j][None, :], y, res.alpha, b)
        correct += int(pred[0] == y[t_j])
        prev, prev_t = res, t
    return {"base_iterations": int(full.n_iter), "iterations": total_iters,
            "accuracy": round(correct / rounds, 4)}


@pytest.mark.parametrize("method", ["sir", "avg", "cold"])
def test_run_loo_plan_matches_sequential_reference(method):
    """The plan-built LOO (chain deps for SIR, fan-out for AVG, independent
    lanes for cold) reproduces the sequential protocol's iteration counts
    and accuracy exactly."""
    ds = make_dataset("heart", n_override=80)
    got = run_loo(ds, method=method, rounds=6)
    ref = _loo_reference(ds, method, rounds=6)
    assert got["base_iterations"] == ref["base_iterations"]
    assert got["iterations"] == ref["iterations"]
    assert got["accuracy"] == ref["accuracy"]


def test_run_loo_kill_resume(tmp_path):
    """run_loo through the plan builder gets mid-study checkpoint/resume:
    kill after a few chunks, resume, and the report is identical."""
    ds = make_dataset("heart", n_override=80)
    full = run_loo(ds, method="sir", rounds=5, chunk_iters=64)

    mgr = CheckpointManager(str(tmp_path / "loo"), max_to_keep=1000)
    run_loo(ds, method="sir", rounds=5, chunk_iters=64,
            checkpoint_manager=mgr)
    steps = mgr.steps_of_class("study")
    assert len(steps) >= 3
    for s in steps[3:]:
        shutil.rmtree(mgr._step_dir(s))
    mgr2 = CheckpointManager(str(tmp_path / "loo"), max_to_keep=1000)
    resumed = run_loo(ds, method="sir", rounds=5, chunk_iters=64,
                      checkpoint_manager=mgr2)
    for key in ("base_iterations", "iterations", "accuracy", "rounds"):
        assert resumed[key] == full[key]
    # a different protocol is a different study: reject, don't mix
    mgr3 = CheckpointManager(str(tmp_path / "loo"), max_to_keep=1000)
    with pytest.raises(ValueError, match="cannot resume"):
        run_loo(ds, method="mir", rounds=5, chunk_iters=64,
                checkpoint_manager=mgr3)


# ---------------------------------------------------------------- run_grid

@pytest.mark.parametrize("name", SUITE)
def test_run_grid_pooled_matches_per_row(name):
    """The cross-gamma pooled grid must be bit-identical (per-cell
    iteration counts AND accuracies) to the per-row scheduler baseline on
    every suite dataset."""
    from repro.core.grid import run_grid
    ds = make_dataset(name, n_override=100)
    kw = dict(Cs=[ds.C, 4 * ds.C], gammas=[0.5 * ds.gamma, 2 * ds.gamma],
              k=3, method="sir", chunk_iters=256)
    pooled = run_grid(ds, pool="cross_gamma", **kw)
    rows = run_grid(ds, pool="per_gamma", **kw)
    assert [(c.C, c.gamma, c.iterations, c.acc_correct, c.converged)
            for c in pooled.cells] == \
        [(c.C, c.gamma, c.iterations, c.acc_correct, c.converged)
         for c in rows.cells]
    assert set(pooled.occupancy["per_source"]) == {"0", "1"}


def test_run_grid_kill_resume(tmp_path):
    """A killed cross-gamma grid resumes as one study and lands on the
    identical per-cell report."""
    from repro.core.grid import run_grid
    ds = make_dataset("heart", n_override=100)
    kw = dict(Cs=[ds.C, 4 * ds.C], gammas=[0.5 * ds.gamma, 2 * ds.gamma],
              k=3, method="sir", chunk_iters=64)
    full = run_grid(ds, **kw)

    mgr = CheckpointManager(str(tmp_path / "grid"), max_to_keep=1000)
    run_grid(ds, checkpoint_manager=mgr, **kw)
    steps = mgr.steps_of_class("study")
    assert len(steps) >= 3
    for s in steps[3:]:
        shutil.rmtree(mgr._step_dir(s))
    mgr2 = CheckpointManager(str(tmp_path / "grid"), max_to_keep=1000)
    resumed = run_grid(ds, checkpoint_manager=mgr2, **kw)
    assert [(c.iterations, c.acc_correct) for c in resumed.cells] == \
        [(c.iterations, c.acc_correct) for c in full.cells]


# --------------------------------------------------------------------- SVC

def test_svc_fit_predict_separable():
    ds = make_dataset("webdata", n_override=140)   # near-separable regime
    from repro.svm import SVC
    svc = SVC(C=ds.C, gamma=ds.gamma).fit(ds.X, ds.y)
    assert svc.converged_
    assert svc.score(ds.X, ds.y) > 0.95
    pred = svc.predict(ds.X[:7])
    assert set(np.unique(pred)) <= set(svc.classes_)


def test_svc_label_mapping():
    """Arbitrary binary labels round-trip through the ±1 encoding."""
    ds = make_dataset("heart", n_override=80)
    from repro.svm import SVC
    y01 = np.where(ds.y > 0, "pos", "neg")
    svc = SVC(C=ds.C, gamma=ds.gamma).fit(ds.X, y01)
    assert set(np.unique(svc.predict(ds.X))) <= {"pos", "neg"}


def test_svc_cross_validate_matches_run_cv():
    """SVC.cross_validate is the run_cv plan builder on the estimator's
    hyper-parameters — identical per-fold trajectories."""
    from repro.core.cv import run_cv
    from repro.svm import SVC
    ds = make_dataset("heart", n_override=100)
    rep = SVC(C=ds.C, gamma=ds.gamma).cross_validate(ds.X, ds.y, k=4,
                                                     method="sir")
    ref = run_cv(make_dataset("heart", n_override=100), k=4, method="sir")
    assert [f.n_iter for f in rep.folds] == [f.n_iter for f in ref.folds]
    assert rep.accuracy == ref.accuracy
