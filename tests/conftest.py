import warnings

warnings.filterwarnings("ignore")

import repro.svm  # noqa: F401,E402  (enables x64 deterministically for all tests)
