"""Paper-claim validation + property tests for the seeding algorithms."""
import functools

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    # property tests degrade to explicit skips; everything else still runs
    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        def deco(fn):
            @functools.wraps(fn)
            @pytest.mark.skip(reason="hypothesis not installed: property "
                                     "test skipped (pip install hypothesis)")
            def stub():
                pass
            return stub
        return deco

    def settings(*_a, **_k):
        return lambda fn: fn

    class st:  # noqa: N801 - stand-in for hypothesis.strategies
        integers = staticmethod(lambda *a, **k: None)
        floats = staticmethod(lambda *a, **k: None)

from repro.core import seeding
from repro.core.cv import run_cv, _transition_idx
from repro.data.svm_suite import make_dataset, kfold_chunks
from repro.svm import init_f, kernel_matrix, smo_solve

C_TEST = 4.0


def _fold_setup(name="madelon", n=400, k=5):
    ds = make_dataset(name, n_override=n)
    X = jnp.asarray(ds.X)
    y = jnp.asarray(ds.y, jnp.float64)
    K = kernel_matrix(X, X, gamma=ds.gamma)
    chunks = kfold_chunks(n, k, seed=0)
    nn = chunks.size
    K, y = K[:nn][:, :nn], y[:nn]
    mask0 = jnp.ones(nn, bool).at[jnp.asarray(chunks[0])].set(False)
    res0 = smo_solve(K, y, mask0, ds.C, jnp.zeros(nn), -y)
    S, R, T = _transition_idx(chunks, 0, 1)
    return ds, K, y, chunks, res0, (S, R, T)


@pytest.mark.parametrize("method", ["mir", "sir", "ato"])
def test_seed_satisfies_constraints(method):
    ds, K, y, chunks, res0, (S, R, T) = _fold_setup()
    alpha0 = seeding.SEEDERS[method](K, y, ds.C, res0, S, R, T)
    eps = 1e-8 * max(ds.C, 1.0)
    assert bool(jnp.all((alpha0 >= -eps) & (alpha0 <= ds.C + eps)))
    # equality over the NEW training set; removed chunk must be zeroed
    assert float(jnp.abs(jnp.sum(alpha0 * y))) < 1e-6 * max(ds.C, 1.0)
    assert float(jnp.abs(alpha0[R]).max()) == 0.0


@pytest.mark.parametrize("method", ["mir", "sir", "ato"])
def test_identical_results_claim(method):
    """Paper Table 1: seeding changes the starting point, not the result.
    Predictions may only differ where the decision value is within solver
    tolerance of zero (degenerate margins)."""
    ds, K, y, chunks, res0, (S, R, T) = _fold_setup()
    nn = chunks.size
    mask1 = jnp.ones(nn, bool).at[jnp.asarray(chunks[1])].set(False)
    cold = smo_solve(K, y, mask1, ds.C, jnp.zeros(nn), -y)
    alpha0 = seeding.SEEDERS[method](K, y, ds.C, res0, S, R, T)
    warm = smo_solve(K, y, mask1, ds.C, alpha0, init_f(K, y, alpha0))
    from repro.svm import bias_from_solution, decision_function
    bc = bias_from_solution(cold, y, mask1, ds.C)
    bw = bias_from_solution(warm, y, mask1, ds.C)
    t_idx = jnp.asarray(chunks[1])
    dc = decision_function(K[t_idx], y, cold.alpha, bc)
    dw = decision_function(K[t_idx], y, warm.alpha, bw)
    differs = (dc >= 0) != (dw >= 0)
    near_zero = (jnp.abs(dc) < 2e-3) | (jnp.abs(dw) < 2e-3)
    assert bool(jnp.all(~differs | near_zero))


def test_seeding_reduces_iterations():
    """Paper Tables 1/3: warm-started folds need fewer SMO iterations.

    Uses the adult-like set (mixed bounded/free SVs): on the chance-level
    degenerate sets a SINGLE fold transition's count is seed-order sensitive
    (±20%, see EXPERIMENTS.md §Paper-validation caveat) — full-CV totals for
    those are covered by tests/test_system.py::test_claim2_fewer_iterations."""
    ds, K, y, chunks, res0, (S, R, T) = _fold_setup("adult", n=600, k=6)
    nn = chunks.size
    mask1 = jnp.ones(nn, bool).at[jnp.asarray(chunks[1])].set(False)
    cold = smo_solve(K, y, mask1, ds.C, jnp.zeros(nn), -y)
    alpha0 = seeding.sir_seed(K, y, ds.C, res0, S, R, T)
    warm = smo_solve(K, y, mask1, ds.C, alpha0, init_f(K, y, alpha0))
    assert int(warm.n_iter) < int(cold.n_iter)


def test_full_cv_accuracy_identical():
    ds = make_dataset("madelon", n_override=300)
    rep_cold = run_cv(ds, k=5, method="cold")
    for method in ("sir", "mir"):
        rep = run_cv(ds, k=5, method=method)
        assert rep.accuracy == pytest.approx(rep_cold.accuracy, abs=0.02)


def test_straggler_policy_best_available():
    ds = make_dataset("heart", n_override=150)
    rep = run_cv(ds, k=5, method="sir", straggler_policy="best_available",
                 unavailable_folds=frozenset({1}))
    # fold 2 cannot seed from fold 1 (simulated straggler) -> seeds from 0
    assert rep.folds[2].seed_from == 0
    rep_cold = run_cv(ds, k=5, method="cold")
    assert rep.accuracy == pytest.approx(rep_cold.accuracy, abs=0.02)


# ------------------------------------------------------------- LOO seeds ---

@pytest.mark.parametrize("fn", [seeding.avg_seed_loo, seeding.top_seed_loo])
def test_loo_seed_constraints(fn):
    ds = make_dataset("heart", n_override=100)
    X = jnp.asarray(ds.X)
    y = jnp.asarray(ds.y, jnp.float64)
    K = kernel_matrix(X, X, gamma=ds.gamma)
    n = 100
    full = smo_solve(K, y, jnp.ones(n, bool), ds.C, jnp.zeros(n), -y)
    for t in [0, 13, 99]:
        a0 = fn(K, y, ds.C, full.alpha, jnp.asarray(t))
        assert float(a0[t]) == 0.0
        assert float(jnp.abs(jnp.sum(a0 * y))) < 1e-6 * ds.C
        assert bool(jnp.all((a0 >= 0) & (a0 <= ds.C)))


def test_kfold_chunks_indices_stay_in_sliced_range():
    """k not dividing n: chunk indices must index the TRUNCATED arrays.
    (The old permutation(n)[:k*m] kept indices >= k*m; jax's clamping
    scatter then silently corrupted that fold's train mask.)"""
    from repro.data.svm_suite import kfold_chunks
    for n, k in [(100, 3), (101, 10), (270, 7)]:
        chunks = kfold_chunks(n, k, seed=0)
        assert chunks.shape == (k, n // k)
        assert int(chunks.max()) < chunks.size
        assert len(np.unique(chunks)) == chunks.size
    # full-CV drive through the non-divisible path (used to crash / corrupt)
    ds = make_dataset("heart", n_override=100)
    rep = run_cv(ds, k=3, method="sir")
    assert all(f.converged for f in rep.folds)


# ---------------------------------------- constraint-repair edge cases -----
# the corners seeding.py documents: label-skewed folds where -s_S is outside
# T's box-feasible range (stage 2 spills into S), and the empty-free-set
# bias fallback.

def test_water_fill_clamps_infeasible_target():
    y = jnp.asarray([1.0, 1.0, -1.0])
    C = 2.0
    lo = jnp.where(y > 0, 0.0, -C)
    hi = jnp.where(y > 0, C, 0.0)
    beta = jnp.asarray([0.5, 1.0, -0.5])
    # target above sum(hi)=4: every coordinate pins to hi
    out = seeding.water_fill(beta, lo, hi, jnp.asarray(100.0))
    np.testing.assert_allclose(np.asarray(out), np.asarray(hi), atol=1e-9)
    # target below sum(lo)=-2: every coordinate pins to lo
    out = seeding.water_fill(beta, lo, hi, jnp.asarray(-100.0))
    np.testing.assert_allclose(np.asarray(out), np.asarray(lo), atol=1e-9)


def test_repair_equality_label_skewed_spills_into_S():
    """All-one-label T chunk: -s_S is infeasible for T's box, so stage 2
    must rebalance S itself (the documented corner case)."""
    C = 2.0
    # S: six +1 instances carrying beta=1.5 each (s_S = 9); T: three +1
    # instances — T's box sum range is [0, 6], so the target -9 is infeasible
    y = jnp.asarray([1.0] * 6 + [1.0] * 3 + [-1.0])
    alpha0 = jnp.asarray([1.5] * 6 + [0.0] * 3 + [0.7])
    S_idx = jnp.arange(6)
    T_idx = jnp.arange(6, 9)
    out = seeding.repair_equality(alpha0, y, C, S_idx, T_idx)
    train = jnp.concatenate([S_idx, T_idx])
    assert float(jnp.abs(jnp.sum((y * out)[train]))) < 1e-9
    assert bool(jnp.all((out[train] >= -1e-12) & (out[train] <= C + 1e-12)))
    # the R instance (index 9) is untouched by repair
    assert float(out[9]) == pytest.approx(0.7)


def test_repair_equality_feasible_is_noop_on_S():
    """When T can absorb -s_S, S must not be disturbed (paper: touch T
    first, spill into S only in the infeasible corner)."""
    C = 4.0
    y = jnp.asarray([1.0, -1.0, 1.0, -1.0, 1.0, -1.0])
    alpha0 = jnp.asarray([1.0, 2.0, 0.0, 0.0, 0.5, 0.2])
    S_idx = jnp.asarray([0, 1])
    T_idx = jnp.asarray([2, 3])
    out = seeding.repair_equality(alpha0, y, C, S_idx, T_idx)
    np.testing.assert_allclose(np.asarray(out[S_idx]),
                               np.asarray(alpha0[S_idx]), atol=1e-9)
    assert float(jnp.sum((y * out)[jnp.asarray([0, 1, 2, 3])])) == \
        pytest.approx(0.0, abs=1e-9)


def test_bias_fallback_empty_free_set():
    """With every alpha at a bound the free-set mean is undefined; _bias
    must fall back to the midpoint of (b_up, b_low)."""
    from repro.svm.smo import SMOResult
    n = 6
    y = jnp.asarray([1.0, -1.0] * 3)
    C = 1.0
    alpha = jnp.asarray([1.0, 1.0, 0.0, 0.0, 1.0, 1.0])  # all at 0 or C
    prev = SMOResult(alpha=alpha, f=jnp.linspace(-1, 1, n),
                     n_iter=jnp.asarray(0), converged=jnp.asarray(True),
                     b_up=jnp.asarray(-0.25), b_low=jnp.asarray(0.75))
    mask = jnp.ones(n, bool)
    b = seeding._bias(prev, y, mask, C)
    assert float(b) == pytest.approx(0.5 * (-0.25 + 0.75))
    # and the seeders still produce feasible alpha0 from such a solution
    S_idx = jnp.asarray([0, 1])
    R_idx = jnp.asarray([2, 3])
    T_idx = jnp.asarray([4, 5])
    K = jnp.eye(n)
    a0 = seeding.mir_seed(K, y, C, prev, S_idx, R_idx, T_idx)
    train = jnp.concatenate([S_idx, T_idx])
    assert float(jnp.abs(jnp.sum((y * a0)[train]))) < 1e-9
    assert bool(jnp.all((a0 >= -1e-12) & (a0 <= C + 1e-12)))
    assert float(jnp.abs(a0[R_idx]).max()) == 0.0


def test_scale_seed_C_constraints():
    """C-grid transition seed: box at the NEW C, exact equality, zero off
    the training mask."""
    ds, K, y, chunks, res0, (S, R, T) = _fold_setup("heart", n=200, k=5)
    nn = chunks.size
    mask0 = jnp.ones(nn, bool).at[jnp.asarray(chunks[0])].set(False)
    for C_new in (ds.C / 8.0, ds.C * 8.0):
        a0 = seeding.scale_seed_C(res0.alpha, y, ds.C, C_new, mask0)
        assert bool(jnp.all((a0 >= -1e-12) & (a0 <= C_new + 1e-12)))
        assert float(jnp.abs(jnp.sum(a0 * y))) < 1e-6 * max(C_new, 1.0)
        assert float(jnp.abs(jnp.where(mask0, 0.0, a0)).max()) == 0.0


# ----------------------------------------------------- jittable ATO -------
# ato_seed is a fixed-shape lax.while_loop (bordered KKT solve over a padded
# working set); ato_seed_ref is the eager paper-faithful loop it replaced.
# The parity contract: feasible seed, alpha0 close up to the repair
# tolerance, and — the real claim — the seeded solve reaching the same fixed
# point with comparable iteration counts.

ATO_SUITE_N = {"adult": 400, "heart": 270, "madelon": 400, "mnist": 400,
               "webdata": 400}


@pytest.mark.parametrize("name", sorted(ATO_SUITE_N))
def test_ato_jit_parity_suite(name):
    ds, K, y, chunks, res0, (S, R, T) = _fold_setup(name, n=ATO_SUITE_N[name],
                                                    k=5)
    a_ref = seeding.ato_seed_ref(K, y, ds.C, res0, S, R, T)
    a_jit = seeding.ato_seed(K, y, ds.C, res0, S, R, T)
    eps = 1e-8 * max(ds.C, 1.0)
    assert bool(jnp.all((a_jit >= -eps) & (a_jit <= ds.C + eps)))
    assert float(jnp.abs(jnp.sum(a_jit * y))) < 1e-6 * max(ds.C, 1.0)
    assert float(jnp.abs(a_jit[R]).max()) == 0.0
    # bordered KKT vs pinv least squares: same ramp, slightly different
    # Phi per step (heart's full 30-step ramp accumulates the most)
    assert float(jnp.max(jnp.abs(a_jit - a_ref))) < 0.2 * ds.C
    nn = chunks.size
    mask1 = jnp.ones(nn, bool).at[jnp.asarray(chunks[1])].set(False)
    warm_ref = smo_solve(K, y, mask1, ds.C, a_ref, init_f(K, y, a_ref))
    warm_jit = smo_solve(K, y, mask1, ds.C, a_jit, init_f(K, y, a_jit))
    assert bool(warm_jit.converged)
    from repro.svm import dual_objective
    assert float(dual_objective(K, y, warm_jit.alpha)) == pytest.approx(
        float(dual_objective(K, y, warm_ref.alpha)), rel=1e-3, abs=1e-6)
    # comparable warm-start quality (not bit-identical trajectories)
    assert int(warm_jit.n_iter) <= 1.5 * int(warm_ref.n_iter) + 300


def test_ato_jit_empty_free_set():
    """All-bounded prev solution: the masked solve must degrade to the pure
    T/R ramp (Phi = 0), matching the reference's M-empty branch exactly."""
    from repro.svm.smo import SMOResult
    n = 8
    y = jnp.asarray([1.0, -1.0] * 4)
    C = 1.0
    alpha = jnp.asarray([1.0, 1.0, 0.0, 0.0, 1.0, 1.0, 0.0, 0.0])
    K = jnp.eye(n) + 0.05
    f = init_f(K, y, alpha)
    prev = SMOResult(alpha=alpha, f=f, n_iter=jnp.asarray(0),
                     converged=jnp.asarray(True), b_up=jnp.asarray(-0.25),
                     b_low=jnp.asarray(0.75))
    S_idx = jnp.asarray([0, 1, 2, 3])
    R_idx = jnp.asarray([4, 5])
    T_idx = jnp.asarray([6, 7])
    a_ref = seeding.ato_seed_ref(K, y, C, prev, S_idx, R_idx, T_idx)
    a_jit = seeding.ato_seed(K, y, C, prev, S_idx, R_idx, T_idx)
    np.testing.assert_allclose(np.asarray(a_jit), np.asarray(a_ref),
                               atol=1e-9)
    train = jnp.concatenate([S_idx, T_idx])
    assert float(jnp.abs(jnp.sum((y * a_jit)[train]))) < 1e-9
    assert float(jnp.abs(a_jit[R_idx]).max()) == 0.0


def test_ato_jit_drained_R_exits_early():
    """alpha_R already zero: R_active is empty from step 0; the loop still
    ramps T and terminates via the eta=1 exit, like the reference."""
    from repro.svm.smo import SMOResult
    ds, K, y, chunks, res0, (S, R, T) = _fold_setup("heart", n=150, k=5)
    alpha = res0.alpha.at[R].set(0.0)
    prev = SMOResult(alpha=alpha, f=init_f(K, y, alpha), n_iter=res0.n_iter,
                     converged=res0.converged, b_up=res0.b_up,
                     b_low=res0.b_low)
    a_ref = seeding.ato_seed_ref(K, y, ds.C, prev, S, R, T)
    a_jit = seeding.ato_seed(K, y, ds.C, prev, S, R, T)
    eps = 1e-8 * max(ds.C, 1.0)
    assert bool(jnp.all((a_jit >= -eps) & (a_jit <= ds.C + eps)))
    assert float(jnp.abs(jnp.sum(a_jit * y))) < 1e-6 * max(ds.C, 1.0)
    assert float(jnp.abs(a_jit[R]).max()) == 0.0
    assert float(jnp.max(jnp.abs(a_jit - a_ref))) < 0.2 * ds.C


def test_ato_seed_batch_matches_solo():
    """The vmapped batch entry (the grid's C-row path) reproduces the solo
    seeder lane for lane."""
    import jax
    ds, K, y, chunks, res0, (S, R, T) = _fold_setup("heart", n=150, k=5)
    prev2 = jax.tree.map(lambda a: jnp.stack([a, a]), res0)
    a2 = seeding.ato_seed_batch(K, y, jnp.asarray([ds.C, ds.C]), prev2,
                                S, R, T)
    a1 = seeding.ato_seed(K, y, ds.C, res0, S, R, T)
    assert a2.shape == (2,) + a1.shape
    np.testing.assert_array_equal(np.asarray(a2[0]), np.asarray(a2[1]))
    np.testing.assert_allclose(np.asarray(a2[0]), np.asarray(a1), atol=1e-9)


# ------------------------------------------------------ property tests -----

@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.floats(0.5, 100.0))
def test_water_fill_property(seed, C):
    """water_fill returns values in the box whose sum hits any feasible
    target (the paper's AdjustAlpha invariant)."""
    rng = np.random.default_rng(seed)
    m = int(rng.integers(3, 40))
    y = jnp.asarray(np.where(rng.random(m) < 0.5, 1.0, -1.0))
    beta = jnp.asarray(rng.uniform(-C, C, m)) * (y > 0) \
        + jnp.asarray(rng.uniform(-C, 0, m)) * (y < 0)
    lo = jnp.where(y > 0, 0.0, -C)
    hi = jnp.where(y > 0, C, 0.0)
    target = float(rng.uniform(float(jnp.sum(lo)), float(jnp.sum(hi))))
    out = seeding.water_fill(jnp.clip(beta, lo, hi), lo, hi,
                             jnp.asarray(target))
    assert bool(jnp.all((out >= lo - 1e-9) & (out <= hi + 1e-9)))
    assert float(jnp.sum(out)) == pytest.approx(target, abs=1e-6 * max(C, 1))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_smo_invariants_random_problems(seed):
    """Random tiny SVMs: the solver always returns a feasible, converged
    dual within the iteration budget."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(10, 60))
    d = int(rng.integers(2, 8))
    X = jnp.asarray(rng.normal(size=(n, d)))
    y = jnp.asarray(np.where(rng.random(n) < 0.5, 1.0, -1.0))
    if float(jnp.abs(y).sum()) == float(jnp.abs(y.sum())):
        return  # single-class sample: SVM undefined
    K = kernel_matrix(X, X, gamma=0.5)
    res = smo_solve(K, y, jnp.ones(n, bool), C_TEST, jnp.zeros(n), -y,
                    max_iter=200_000)
    assert bool(res.converged)
    assert float(jnp.abs(jnp.sum(res.alpha * y))) < 1e-8
    assert bool(jnp.all((res.alpha >= 0) & (res.alpha <= C_TEST)))
