"""Paper-claim validation + property tests for the seeding algorithms."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import seeding
from repro.core.cv import run_cv, _transition_idx
from repro.data.svm_suite import make_dataset, kfold_chunks
from repro.svm import init_f, kernel_matrix, smo_solve

C_TEST = 4.0


def _fold_setup(name="madelon", n=400, k=5):
    ds = make_dataset(name, n_override=n)
    X = jnp.asarray(ds.X)
    y = jnp.asarray(ds.y, jnp.float64)
    K = kernel_matrix(X, X, gamma=ds.gamma)
    chunks = kfold_chunks(n, k, seed=0)
    nn = chunks.size
    K, y = K[:nn][:, :nn], y[:nn]
    mask0 = jnp.ones(nn, bool).at[jnp.asarray(chunks[0])].set(False)
    res0 = smo_solve(K, y, mask0, ds.C, jnp.zeros(nn), -y)
    S, R, T = _transition_idx(chunks, 0, 1)
    return ds, K, y, chunks, res0, (S, R, T)


@pytest.mark.parametrize("method", ["mir", "sir", "ato"])
def test_seed_satisfies_constraints(method):
    ds, K, y, chunks, res0, (S, R, T) = _fold_setup()
    alpha0 = seeding.SEEDERS[method](K, y, ds.C, res0, S, R, T)
    eps = 1e-8 * max(ds.C, 1.0)
    assert bool(jnp.all((alpha0 >= -eps) & (alpha0 <= ds.C + eps)))
    # equality over the NEW training set; removed chunk must be zeroed
    assert float(jnp.abs(jnp.sum(alpha0 * y))) < 1e-6 * max(ds.C, 1.0)
    assert float(jnp.abs(alpha0[R]).max()) == 0.0


@pytest.mark.parametrize("method", ["mir", "sir", "ato"])
def test_identical_results_claim(method):
    """Paper Table 1: seeding changes the starting point, not the result.
    Predictions may only differ where the decision value is within solver
    tolerance of zero (degenerate margins)."""
    ds, K, y, chunks, res0, (S, R, T) = _fold_setup()
    nn = chunks.size
    mask1 = jnp.ones(nn, bool).at[jnp.asarray(chunks[1])].set(False)
    cold = smo_solve(K, y, mask1, ds.C, jnp.zeros(nn), -y)
    alpha0 = seeding.SEEDERS[method](K, y, ds.C, res0, S, R, T)
    warm = smo_solve(K, y, mask1, ds.C, alpha0, init_f(K, y, alpha0))
    from repro.svm import bias_from_solution, decision_function
    bc = bias_from_solution(cold, y, mask1, ds.C)
    bw = bias_from_solution(warm, y, mask1, ds.C)
    t_idx = jnp.asarray(chunks[1])
    dc = decision_function(K[t_idx], y, cold.alpha, bc)
    dw = decision_function(K[t_idx], y, warm.alpha, bw)
    differs = (dc >= 0) != (dw >= 0)
    near_zero = (jnp.abs(dc) < 2e-3) | (jnp.abs(dw) < 2e-3)
    assert bool(jnp.all(~differs | near_zero))


def test_seeding_reduces_iterations():
    """Paper Tables 1/3: warm-started folds need fewer SMO iterations.

    Uses the adult-like set (mixed bounded/free SVs): on the chance-level
    degenerate sets a SINGLE fold transition's count is seed-order sensitive
    (±20%, see EXPERIMENTS.md §Paper-validation caveat) — full-CV totals for
    those are covered by tests/test_system.py::test_claim2_fewer_iterations."""
    ds, K, y, chunks, res0, (S, R, T) = _fold_setup("adult", n=600, k=6)
    nn = chunks.size
    mask1 = jnp.ones(nn, bool).at[jnp.asarray(chunks[1])].set(False)
    cold = smo_solve(K, y, mask1, ds.C, jnp.zeros(nn), -y)
    alpha0 = seeding.sir_seed(K, y, ds.C, res0, S, R, T)
    warm = smo_solve(K, y, mask1, ds.C, alpha0, init_f(K, y, alpha0))
    assert int(warm.n_iter) < int(cold.n_iter)


def test_full_cv_accuracy_identical():
    ds = make_dataset("madelon", n_override=300)
    rep_cold = run_cv(ds, k=5, method="cold")
    for method in ("sir", "mir"):
        rep = run_cv(ds, k=5, method=method)
        assert rep.accuracy == pytest.approx(rep_cold.accuracy, abs=0.02)


def test_straggler_policy_best_available():
    ds = make_dataset("heart", n_override=150)
    rep = run_cv(ds, k=5, method="sir", straggler_policy="best_available",
                 unavailable_folds=frozenset({1}))
    # fold 2 cannot seed from fold 1 (simulated straggler) -> seeds from 0
    assert rep.folds[2].seed_from == 0
    rep_cold = run_cv(ds, k=5, method="cold")
    assert rep.accuracy == pytest.approx(rep_cold.accuracy, abs=0.02)


# ------------------------------------------------------------- LOO seeds ---

@pytest.mark.parametrize("fn", [seeding.avg_seed_loo, seeding.top_seed_loo])
def test_loo_seed_constraints(fn):
    ds = make_dataset("heart", n_override=100)
    X = jnp.asarray(ds.X)
    y = jnp.asarray(ds.y, jnp.float64)
    K = kernel_matrix(X, X, gamma=ds.gamma)
    n = 100
    full = smo_solve(K, y, jnp.ones(n, bool), ds.C, jnp.zeros(n), -y)
    for t in [0, 13, 99]:
        a0 = fn(K, y, ds.C, full.alpha, jnp.asarray(t))
        assert float(a0[t]) == 0.0
        assert float(jnp.abs(jnp.sum(a0 * y))) < 1e-6 * ds.C
        assert bool(jnp.all((a0 >= 0) & (a0 <= ds.C)))


# ------------------------------------------------------ property tests -----

@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.floats(0.5, 100.0))
def test_water_fill_property(seed, C):
    """water_fill returns values in the box whose sum hits any feasible
    target (the paper's AdjustAlpha invariant)."""
    rng = np.random.default_rng(seed)
    m = int(rng.integers(3, 40))
    y = jnp.asarray(np.where(rng.random(m) < 0.5, 1.0, -1.0))
    beta = jnp.asarray(rng.uniform(-C, C, m)) * (y > 0) \
        + jnp.asarray(rng.uniform(-C, 0, m)) * (y < 0)
    lo = jnp.where(y > 0, 0.0, -C)
    hi = jnp.where(y > 0, C, 0.0)
    target = float(rng.uniform(float(jnp.sum(lo)), float(jnp.sum(hi))))
    out = seeding.water_fill(jnp.clip(beta, lo, hi), lo, hi,
                             jnp.asarray(target))
    assert bool(jnp.all((out >= lo - 1e-9) & (out <= hi + 1e-9)))
    assert float(jnp.sum(out)) == pytest.approx(target, abs=1e-6 * max(C, 1))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_smo_invariants_random_problems(seed):
    """Random tiny SVMs: the solver always returns a feasible, converged
    dual within the iteration budget."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(10, 60))
    d = int(rng.integers(2, 8))
    X = jnp.asarray(rng.normal(size=(n, d)))
    y = jnp.asarray(np.where(rng.random(n) < 0.5, 1.0, -1.0))
    if float(jnp.abs(y).sum()) == float(jnp.abs(y.sum())):
        return  # single-class sample: SVM undefined
    K = kernel_matrix(X, X, gamma=0.5)
    res = smo_solve(K, y, jnp.ones(n, bool), C_TEST, jnp.zeros(n), -y,
                    max_iter=200_000)
    assert bool(res.converged)
    assert float(jnp.abs(jnp.sum(res.alpha * y))) < 1e-8
    assert bool(jnp.all((res.alpha >= 0) & (res.alpha <= C_TEST)))
