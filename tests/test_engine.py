"""Unified SMO engine: kernel-source agreement, chunked-dispatch exactness,
batched fold execution, and wrapper parity (smo_solve / smo_iterations)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.svm_suite import make_dataset, kfold_chunks
from repro.svm import (DenseKernel, FusedRBF, OnDemandRBF, init_f,
                       kernel_matrix, smo_solve, smo_solve_batched)
from repro.svm.distributed import smo_iterations
from repro.svm.engine import EngineState, smo_chunk


def _setup(name="heart", n=150):
    ds = make_dataset(name, n_override=n)
    X = jnp.asarray(ds.X)
    y = jnp.asarray(ds.y, jnp.float64)
    K = kernel_matrix(X, X, gamma=ds.gamma)
    return ds, X, K, y


# ------------------------------------------------- kernel-source parity ---

def test_sources_agree_on_rows():
    """Every provider must hand the engine the same kernel row."""
    ds, X, K, y = _setup()
    dense = DenseKernel(K)
    gather = OnDemandRBF(X, ds.gamma)
    onehot = OnDemandRBF(X, ds.gamma, impl="onehot")
    fused = FusedRBF(X, ds.gamma)
    for i, j in [(0, 7), (31, 149), (80, 80)]:
        rows = [np.asarray(dense.row(i)), np.asarray(gather.row(i)),
                np.asarray(onehot.row(i)), np.asarray(fused.rows2(i, j)[0])]
        for r in rows[1:]:
            np.testing.assert_allclose(r, rows[0], atol=1e-12)
        np.testing.assert_allclose(np.asarray(fused.rows2(i, j)[1]),
                                   np.asarray(dense.row(j)), atol=1e-12)


def test_ondemand_gather_vs_onehot_bitwise():
    """The two scalar-read/update idioms must replay the exact same fp ops."""
    ds, X, K, y = _setup(n=120)
    n = y.shape[0]
    sq = jnp.sum(X * X, axis=1)
    mask = jnp.ones(n, bool).at[:20].set(False)
    outs = {}
    for impl in ("gather", "onehot"):
        outs[impl] = smo_iterations(X, y, mask, jnp.zeros(n), -y, sq, ds.C,
                                    gamma=ds.gamma, n_iters=200, impl=impl)
    for a, b in zip(outs["gather"], outs["onehot"]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fused_wss1_converges_same_fixed_point():
    """WSS-1/fused takes more iterations but must reach the same dual."""
    ds, X, K, y = _setup(n=120)
    n = y.shape[0]
    sq = jnp.sum(X * X, axis=1)
    mask = jnp.ones(n, bool)
    a, f, it, gap = smo_iterations(X, y, mask, jnp.zeros(n), -y, sq, ds.C,
                                   gamma=ds.gamma, n_iters=200_000,
                                   impl="onehot_fused")
    assert float(gap) <= 1e-3
    ref = smo_solve(kernel_matrix(X, X, gamma=ds.gamma), y, mask, ds.C,
                    jnp.zeros(n), -y)
    from repro.svm import dual_objective
    K_full = kernel_matrix(X, X, gamma=ds.gamma)
    assert float(dual_objective(K_full, y, a)) == pytest.approx(
        float(dual_objective(K_full, y, ref.alpha)), rel=1e-3)


def test_fused_requires_wss1():
    ds, X, K, y = _setup(n=64)
    src = FusedRBF(X, ds.gamma)
    state = EngineState(jnp.zeros(64), -y, jnp.zeros((), jnp.int32),
                        jnp.zeros((), bool))
    with pytest.raises(ValueError, match="WSS-1"):
        smo_chunk(src, y, jnp.ones(64, bool), ds.C, state, n_iters=10,
                  wss="2")


# ------------------------------------------------------ chunked dispatch ---

@pytest.mark.parametrize("chunk_iters", [64, 500])
def test_chunked_equals_monolithic_bitwise(chunk_iters):
    ds, X, K, y = _setup()
    n = y.shape[0]
    mask = jnp.ones(n, bool).at[:25].set(False)
    mono = smo_solve(K, y, mask, ds.C, jnp.zeros(n), -y)
    chun = smo_solve(K, y, mask, ds.C, jnp.zeros(n), -y,
                     chunk_iters=chunk_iters)
    for a, b in zip(mono, chun):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_chunk_snapshot_resumes_to_same_fixed_point():
    """Restart from any mid-solve snapshot (the checkpoint unit) and land on
    the identical iterate sequence — alpha, f AND the n_iter account."""
    ds, X, K, y = _setup()
    n = y.shape[0]
    mask = jnp.ones(n, bool)
    snaps = []
    full = smo_solve(K, y, mask, ds.C, jnp.zeros(n), -y, chunk_iters=100,
                     on_chunk=snaps.append)
    assert len(snaps) >= 2, "test needs a solve spanning several chunks"
    state = snaps[1]
    resumed = smo_solve(K, y, mask, ds.C, state.alpha, state.f,
                        chunk_iters=100, n_iter0=int(state.n_iter))
    np.testing.assert_array_equal(np.asarray(full.alpha),
                                  np.asarray(resumed.alpha))
    np.testing.assert_array_equal(np.asarray(full.f), np.asarray(resumed.f))
    assert int(full.n_iter) == int(resumed.n_iter)


def test_smo_iterations_is_resumable_chunk():
    """Two 150-iteration dispatches == one 300-iteration dispatch: the chunk
    is the scheduler's retry unit, with (alpha, f) as the only state."""
    ds, X, K, y = _setup(n=120)
    n = y.shape[0]
    sq = jnp.sum(X * X, axis=1)
    mask = jnp.ones(n, bool)
    a1, f1, it1, _ = smo_iterations(X, y, mask, jnp.zeros(n), -y, sq, ds.C,
                                    gamma=ds.gamma, n_iters=150)
    a2, f2, it2, _ = smo_iterations(X, y, mask, a1, f1, sq, ds.C,
                                    gamma=ds.gamma, n_iters=150)
    a3, f3, it3, _ = smo_iterations(X, y, mask, jnp.zeros(n), -y, sq, ds.C,
                                    gamma=ds.gamma, n_iters=300)
    np.testing.assert_array_equal(np.asarray(a2), np.asarray(a3))
    np.testing.assert_array_equal(np.asarray(f2), np.asarray(f3))
    assert int(it1) + int(it2) == int(it3)


def test_converged_input_passes_through():
    ds, X, K, y = _setup(n=100)
    n = y.shape[0]
    mask = jnp.ones(n, bool)
    res = smo_solve(K, y, mask, ds.C, jnp.zeros(n), -y)
    again = smo_solve(K, y, mask, ds.C, res.alpha, res.f, chunk_iters=32)
    assert int(again.n_iter) == 0
    np.testing.assert_array_equal(np.asarray(res.alpha),
                                  np.asarray(again.alpha))
    # the sharded wrapper likewise reports 0 iterations for a converged state
    sq = jnp.sum(X * X, axis=1)
    a, f, it, gap = smo_iterations(X, y, mask, res.alpha, res.f, sq, ds.C,
                                   gamma=ds.gamma, n_iters=50)
    assert int(it) == 0 and float(gap) <= 1e-3


def test_max_iter_cap_respected_across_chunks():
    ds, X, K, y = _setup()
    n = y.shape[0]
    mask = jnp.ones(n, bool)
    capped = smo_solve(K, y, mask, ds.C, jnp.zeros(n), -y, max_iter=130,
                       chunk_iters=50)
    mono = smo_solve(K, y, mask, ds.C, jnp.zeros(n), -y, max_iter=130)
    assert int(capped.n_iter) == 130 == int(mono.n_iter)
    assert not bool(capped.converged)
    np.testing.assert_array_equal(np.asarray(capped.alpha),
                                  np.asarray(mono.alpha))


# ------------------------------------------------- batched fold execution ---

def test_batched_folds_match_sequential_bitwise():
    ds, X, K, y = _setup("adult", n=400)
    k = 5
    chunks = kfold_chunks(400, k, seed=0)
    n = chunks.size
    K2, y2 = K[:n][:, :n], y[:n]
    masks = np.ones((k, n), bool)
    for h in range(k):
        masks[h, chunks[h]] = False
    masks = jnp.asarray(masks)
    bat = smo_solve_batched(K2, y2, masks, ds.C, jnp.zeros((k, n)),
                            jnp.tile(-y2, (k, 1)))
    for h in range(k):
        seq = smo_solve(K2, y2, masks[h], ds.C, jnp.zeros(n), -y2)
        np.testing.assert_array_equal(np.asarray(seq.alpha),
                                      np.asarray(bat.alpha[h]))
        np.testing.assert_array_equal(np.asarray(seq.f),
                                      np.asarray(bat.f[h]))
        assert int(seq.n_iter) == int(bat.n_iter[h])
        assert bool(bat.converged[h])


def test_batched_per_lane_C():
    """Per-lane C values (the hyper-parameter grid axis) solve correctly."""
    ds, X, K, y = _setup(n=120)
    n = y.shape[0]
    mask = jnp.ones(n, bool).at[:20].set(False)
    Cs = jnp.asarray([0.5, 4.0, 32.0])
    bat = smo_solve_batched(K, y, jnp.tile(mask[None], (3, 1)), Cs,
                            jnp.zeros((3, n)), jnp.tile(-y, (3, 1)))
    for lane, C in enumerate([0.5, 4.0, 32.0]):
        seq = smo_solve(K, y, mask, C, jnp.zeros(n), -y)
        np.testing.assert_array_equal(np.asarray(seq.alpha),
                                      np.asarray(bat.alpha[lane]))
        assert float(jnp.max(bat.alpha[lane])) <= C + 1e-12


def test_batched_warm_seeds():
    """Warm-started lanes (alpha-seeded folds) drop iterations in batch mode
    exactly as they do sequentially."""
    from repro.core import seeding
    from repro.core.cv import _transition_idx
    ds, X, K, y = _setup("adult", n=400)
    k = 5
    chunks = kfold_chunks(400, k, seed=0)
    n = chunks.size
    K2, y2 = K[:n][:, :n], y[:n]
    m0 = jnp.ones(n, bool).at[jnp.asarray(chunks[0])].set(False)
    m1 = jnp.ones(n, bool).at[jnp.asarray(chunks[1])].set(False)
    r0 = smo_solve(K2, y2, m0, ds.C, jnp.zeros(n), -y2)
    S, R, T = _transition_idx(chunks, 0, 1)
    a1 = seeding.sir_seed(K2, y2, ds.C, r0, S, R, T)
    f1 = init_f(K2, y2, a1)
    masks = jnp.stack([m1, m1])
    alpha0s = jnp.stack([jnp.zeros(n), a1])
    f0s = jnp.stack([-y2, f1])
    bat = smo_solve_batched(K2, y2, masks, ds.C, alpha0s, f0s)
    assert int(bat.n_iter[1]) < int(bat.n_iter[0])
    cold = smo_solve(K2, y2, m1, ds.C, jnp.zeros(n), -y2)
    warm = smo_solve(K2, y2, m1, ds.C, a1, f1)
    assert int(bat.n_iter[0]) == int(cold.n_iter)
    assert int(bat.n_iter[1]) == int(warm.n_iter)


# ------------------------------------------------------- NaN guards -------

def test_arg_reduces_nan_guard():
    """Regression: a NaN used to make ``v == min(v)`` all-False, so the
    reduce returned v.shape[0] — out of range — and jax's clamped gather
    silently aliased it to the last row."""
    from repro.svm.engine import _argmin, _argmax
    v = jnp.asarray([3.0, jnp.nan, 1.0, jnp.nan])
    assert int(_argmin(v)) == int(jnp.argmin(v)) == 1
    assert int(_argmax(v)) == int(jnp.argmax(v)) == 1
    clean = jnp.asarray([3.0, 1.0, 1.0, 7.0])
    assert int(_argmin(clean)) == int(jnp.argmin(clean)) == 1
    assert int(_argmax(clean)) == int(jnp.argmax(clean)) == 3
    # degenerate inputs stay in range
    assert int(_argmin(jnp.asarray([jnp.nan, jnp.nan]))) == 0
    assert int(_argmax(jnp.asarray([jnp.nan, jnp.nan]))) == 0
    assert int(_argmin(jnp.full(3, jnp.inf))) == 0
    assert int(_argmax(jnp.full(3, -jnp.inf))) == 0


def test_solver_halts_on_nan_state():
    """A NaN in f on an active row must stop the solve immediately with
    converged=False instead of spinning on a bogus pair until max_iter."""
    ds, X, K, y = _setup(n=64)
    n = y.shape[0]
    f0 = (-y).at[3].set(jnp.nan)
    res = smo_solve(K, y, jnp.ones(n, bool), ds.C, jnp.zeros(n), f0,
                    max_iter=50_000)
    assert not bool(res.converged)
    assert int(res.n_iter) == 0   # halted before any update was applied


@pytest.mark.parametrize("schedule,label", [
    ("batched", "cold_batched"), ("repacked", "cold_batched_repacked")])
def test_run_cv_batched_matches_cold_cv(schedule, label):
    from repro.core.cv import run_cv, run_cv_batched
    ds = make_dataset("heart", n_override=120)
    cold = run_cv(ds, k=4, method="cold")
    bat = run_cv_batched(ds, k=4, schedule=schedule)
    assert bat.method == label
    assert bat.accuracy == pytest.approx(cold.accuracy, abs=1e-12)
    assert [f.n_iter for f in bat.folds] == [f.n_iter for f in cold.folds]
    assert all(f.converged for f in bat.folds)
    if schedule == "repacked":
        assert bat.occupancy["chunks"] >= 1
        assert bat.occupancy["peak_width"] >= 1


def test_solve_batched_n_iter0s_resume_bitwise():
    """A capped batched run resumed with per-lane ``n_iter0s`` replays the
    uninterrupted iterate sequence — alpha, f AND the n_iter account —
    mirroring the single-lane ``solve(..., n_iter0=...)`` path."""
    ds, X, K, y = _setup(n=120)
    n = y.shape[0]
    masks = jnp.stack([jnp.ones(n, bool).at[:20].set(False),
                       jnp.ones(n, bool).at[20:40].set(False)])
    Cs = jnp.asarray([ds.C, 4.0 * ds.C])
    a0 = jnp.zeros((2, n))
    f0 = jnp.tile(-y, (2, 1))
    full = smo_solve_batched(K, y, masks, Cs, a0, f0)
    part = smo_solve_batched(K, y, masks, Cs, a0, f0, max_iter=150)
    np.testing.assert_array_equal(np.asarray(part.n_iter), [150, 150])
    resumed = smo_solve_batched(K, y, masks, Cs, part.alpha, part.f,
                                n_iter0s=part.n_iter)
    for a, b in zip(full, resumed):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the cap counts TOTAL updates incl. the preload: resuming a 150-iter
    # state under max_iter=150 must apply zero further updates
    recapped = smo_solve_batched(K, y, masks, Cs, part.alpha, part.f,
                                 n_iter0s=part.n_iter, max_iter=150)
    np.testing.assert_array_equal(np.asarray(recapped.alpha),
                                  np.asarray(part.alpha))
    np.testing.assert_array_equal(np.asarray(recapped.n_iter), [150, 150])
