"""Unified SMO engine: kernel-source agreement, chunked-dispatch exactness,
batched fold execution, and wrapper parity (smo_solve / smo_iterations)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.svm_suite import make_dataset, kfold_chunks
from repro.svm import (DenseKernel, FusedRBF, OnDemandRBF, PallasRBF, init_f,
                       kernel_matrix, smo_solve, smo_solve_batched)
from repro.svm.distributed import smo_iterations
from repro.svm.engine import EngineState, smo_chunk, solve, solve_batched


def _setup(name="heart", n=150):
    ds = make_dataset(name, n_override=n)
    X = jnp.asarray(ds.X)
    y = jnp.asarray(ds.y, jnp.float64)
    K = kernel_matrix(X, X, gamma=ds.gamma)
    return ds, X, K, y


# ------------------------------------------------- kernel-source parity ---

def test_sources_agree_on_rows():
    """Every provider must hand the engine the same kernel row."""
    ds, X, K, y = _setup()
    dense = DenseKernel(K)
    gather = OnDemandRBF(X, ds.gamma)
    onehot = OnDemandRBF(X, ds.gamma, impl="onehot")
    fused = FusedRBF(X, ds.gamma)
    for i, j in [(0, 7), (31, 149), (80, 80)]:
        rows = [np.asarray(dense.row(i)), np.asarray(gather.row(i)),
                np.asarray(onehot.row(i)), np.asarray(fused.rows2(i, j)[0])]
        for r in rows[1:]:
            np.testing.assert_allclose(r, rows[0], atol=1e-12)
        np.testing.assert_allclose(np.asarray(fused.rows2(i, j)[1]),
                                   np.asarray(dense.row(j)), atol=1e-12)


def test_ondemand_gather_vs_onehot_bitwise():
    """The two scalar-read/update idioms must replay the exact same fp ops."""
    ds, X, K, y = _setup(n=120)
    n = y.shape[0]
    sq = jnp.sum(X * X, axis=1)
    mask = jnp.ones(n, bool).at[:20].set(False)
    outs = {}
    for impl in ("gather", "onehot"):
        outs[impl] = smo_iterations(X, y, mask, jnp.zeros(n), -y, sq, ds.C,
                                    gamma=ds.gamma, n_iters=200, impl=impl)
    for a, b in zip(outs["gather"], outs["onehot"]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fused_wss1_converges_same_fixed_point():
    """WSS-1/fused takes more iterations but must reach the same dual."""
    ds, X, K, y = _setup(n=120)
    n = y.shape[0]
    sq = jnp.sum(X * X, axis=1)
    mask = jnp.ones(n, bool)
    a, f, it, gap = smo_iterations(X, y, mask, jnp.zeros(n), -y, sq, ds.C,
                                   gamma=ds.gamma, n_iters=200_000,
                                   impl="onehot_fused")
    assert float(gap) <= 1e-3
    ref = smo_solve(kernel_matrix(X, X, gamma=ds.gamma), y, mask, ds.C,
                    jnp.zeros(n), -y)
    from repro.svm import dual_objective
    K_full = kernel_matrix(X, X, gamma=ds.gamma)
    assert float(dual_objective(K_full, y, a)) == pytest.approx(
        float(dual_objective(K_full, y, ref.alpha)), rel=1e-3)


def test_fused_requires_wss1():
    ds, X, K, y = _setup(n=64)
    src = FusedRBF(X, ds.gamma)
    state = EngineState(jnp.zeros(64), -y, jnp.zeros((), jnp.int32),
                        jnp.zeros((), bool))
    with pytest.raises(ValueError, match="WSS-1"):
        smo_chunk(src, y, jnp.ones(64, bool), ds.C, state, n_iters=10,
                  wss="2")


# ------------------------------------------------------ chunked dispatch ---

@pytest.mark.parametrize("chunk_iters", [64, 500])
def test_chunked_equals_monolithic_bitwise(chunk_iters):
    ds, X, K, y = _setup()
    n = y.shape[0]
    mask = jnp.ones(n, bool).at[:25].set(False)
    mono = smo_solve(K, y, mask, ds.C, jnp.zeros(n), -y)
    chun = smo_solve(K, y, mask, ds.C, jnp.zeros(n), -y,
                     chunk_iters=chunk_iters)
    for a, b in zip(mono, chun):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_chunk_snapshot_resumes_to_same_fixed_point():
    """Restart from any mid-solve snapshot (the checkpoint unit) and land on
    the identical iterate sequence — alpha, f AND the n_iter account."""
    ds, X, K, y = _setup()
    n = y.shape[0]
    mask = jnp.ones(n, bool)
    snaps = []
    full = smo_solve(K, y, mask, ds.C, jnp.zeros(n), -y, chunk_iters=100,
                     on_chunk=snaps.append)
    assert len(snaps) >= 2, "test needs a solve spanning several chunks"
    state = snaps[1]
    resumed = smo_solve(K, y, mask, ds.C, state.alpha, state.f,
                        chunk_iters=100, n_iter0=int(state.n_iter))
    np.testing.assert_array_equal(np.asarray(full.alpha),
                                  np.asarray(resumed.alpha))
    np.testing.assert_array_equal(np.asarray(full.f), np.asarray(resumed.f))
    assert int(full.n_iter) == int(resumed.n_iter)


def test_smo_iterations_is_resumable_chunk():
    """Two 150-iteration dispatches == one 300-iteration dispatch: the chunk
    is the scheduler's retry unit, with (alpha, f) as the only state."""
    ds, X, K, y = _setup(n=120)
    n = y.shape[0]
    sq = jnp.sum(X * X, axis=1)
    mask = jnp.ones(n, bool)
    a1, f1, it1, _ = smo_iterations(X, y, mask, jnp.zeros(n), -y, sq, ds.C,
                                    gamma=ds.gamma, n_iters=150)
    a2, f2, it2, _ = smo_iterations(X, y, mask, a1, f1, sq, ds.C,
                                    gamma=ds.gamma, n_iters=150)
    a3, f3, it3, _ = smo_iterations(X, y, mask, jnp.zeros(n), -y, sq, ds.C,
                                    gamma=ds.gamma, n_iters=300)
    np.testing.assert_array_equal(np.asarray(a2), np.asarray(a3))
    np.testing.assert_array_equal(np.asarray(f2), np.asarray(f3))
    assert int(it1) + int(it2) == int(it3)


def test_converged_input_passes_through():
    ds, X, K, y = _setup(n=100)
    n = y.shape[0]
    mask = jnp.ones(n, bool)
    res = smo_solve(K, y, mask, ds.C, jnp.zeros(n), -y)
    again = smo_solve(K, y, mask, ds.C, res.alpha, res.f, chunk_iters=32)
    assert int(again.n_iter) == 0
    np.testing.assert_array_equal(np.asarray(res.alpha),
                                  np.asarray(again.alpha))
    # the sharded wrapper likewise reports 0 iterations for a converged state
    sq = jnp.sum(X * X, axis=1)
    a, f, it, gap = smo_iterations(X, y, mask, res.alpha, res.f, sq, ds.C,
                                   gamma=ds.gamma, n_iters=50)
    assert int(it) == 0 and float(gap) <= 1e-3


def test_max_iter_cap_respected_across_chunks():
    ds, X, K, y = _setup()
    n = y.shape[0]
    mask = jnp.ones(n, bool)
    capped = smo_solve(K, y, mask, ds.C, jnp.zeros(n), -y, max_iter=130,
                       chunk_iters=50)
    mono = smo_solve(K, y, mask, ds.C, jnp.zeros(n), -y, max_iter=130)
    assert int(capped.n_iter) == 130 == int(mono.n_iter)
    assert not bool(capped.converged)
    np.testing.assert_array_equal(np.asarray(capped.alpha),
                                  np.asarray(mono.alpha))


# ------------------------------------------------- batched fold execution ---

def test_batched_folds_match_sequential_bitwise():
    ds, X, K, y = _setup("adult", n=400)
    k = 5
    chunks = kfold_chunks(400, k, seed=0)
    n = chunks.size
    K2, y2 = K[:n][:, :n], y[:n]
    masks = np.ones((k, n), bool)
    for h in range(k):
        masks[h, chunks[h]] = False
    masks = jnp.asarray(masks)
    bat = smo_solve_batched(K2, y2, masks, ds.C, jnp.zeros((k, n)),
                            jnp.tile(-y2, (k, 1)))
    for h in range(k):
        seq = smo_solve(K2, y2, masks[h], ds.C, jnp.zeros(n), -y2)
        np.testing.assert_array_equal(np.asarray(seq.alpha),
                                      np.asarray(bat.alpha[h]))
        np.testing.assert_array_equal(np.asarray(seq.f),
                                      np.asarray(bat.f[h]))
        assert int(seq.n_iter) == int(bat.n_iter[h])
        assert bool(bat.converged[h])


def test_batched_per_lane_C():
    """Per-lane C values (the hyper-parameter grid axis) solve correctly."""
    ds, X, K, y = _setup(n=120)
    n = y.shape[0]
    mask = jnp.ones(n, bool).at[:20].set(False)
    Cs = jnp.asarray([0.5, 4.0, 32.0])
    bat = smo_solve_batched(K, y, jnp.tile(mask[None], (3, 1)), Cs,
                            jnp.zeros((3, n)), jnp.tile(-y, (3, 1)))
    for lane, C in enumerate([0.5, 4.0, 32.0]):
        seq = smo_solve(K, y, mask, C, jnp.zeros(n), -y)
        np.testing.assert_array_equal(np.asarray(seq.alpha),
                                      np.asarray(bat.alpha[lane]))
        assert float(jnp.max(bat.alpha[lane])) <= C + 1e-12


def test_batched_warm_seeds():
    """Warm-started lanes (alpha-seeded folds) drop iterations in batch mode
    exactly as they do sequentially."""
    from repro.core import seeding
    from repro.core.cv import _transition_idx
    ds, X, K, y = _setup("adult", n=400)
    k = 5
    chunks = kfold_chunks(400, k, seed=0)
    n = chunks.size
    K2, y2 = K[:n][:, :n], y[:n]
    m0 = jnp.ones(n, bool).at[jnp.asarray(chunks[0])].set(False)
    m1 = jnp.ones(n, bool).at[jnp.asarray(chunks[1])].set(False)
    r0 = smo_solve(K2, y2, m0, ds.C, jnp.zeros(n), -y2)
    S, R, T = _transition_idx(chunks, 0, 1)
    a1 = seeding.sir_seed(K2, y2, ds.C, r0, S, R, T)
    f1 = init_f(K2, y2, a1)
    masks = jnp.stack([m1, m1])
    alpha0s = jnp.stack([jnp.zeros(n), a1])
    f0s = jnp.stack([-y2, f1])
    bat = smo_solve_batched(K2, y2, masks, ds.C, alpha0s, f0s)
    assert int(bat.n_iter[1]) < int(bat.n_iter[0])
    cold = smo_solve(K2, y2, m1, ds.C, jnp.zeros(n), -y2)
    warm = smo_solve(K2, y2, m1, ds.C, a1, f1)
    assert int(bat.n_iter[0]) == int(cold.n_iter)
    assert int(bat.n_iter[1]) == int(warm.n_iter)


# ------------------------------------------- pallas row-streaming source ---

#: five-dataset acceptance sweep; (n_override, max_iter) keeps the parity
#: check fast — heart runs to full convergence, the rest are capped replays
#: of the identical iterate prefix
_SUITE = [("adult", 200, 2000), ("heart", 150, 5_000_000),
          ("madelon", 120, 2000), ("mnist", 150, 2000),
          ("webdata", 200, 2000)]


@pytest.mark.parametrize("name,n,max_iter", _SUITE)
def test_pallas_source_matches_fused_bitwise(name, n, max_iter):
    """PallasRBF (interpret mode) must replay FusedRBF's exact fp ops:
    alpha, f and the iteration count are bit-identical on every suite
    dataset — the streaming source changes memory traffic, not math."""
    ds = make_dataset(name, n_override=n)
    X = jnp.asarray(ds.X)
    y = jnp.asarray(ds.y, jnp.float64)
    m = y.shape[0]
    mask = jnp.ones(m, bool).at[: m // 5].set(False)
    args = (y, mask, ds.C, jnp.zeros(m), -y)
    fr = solve(FusedRBF(X, ds.gamma), *args, wss="1", max_iter=max_iter)
    pr = solve(PallasRBF(X, ds.gamma), *args, wss="1", max_iter=max_iter)
    np.testing.assert_array_equal(np.asarray(fr.alpha), np.asarray(pr.alpha))
    np.testing.assert_array_equal(np.asarray(fr.f), np.asarray(pr.f))
    assert int(fr.n_iter) == int(pr.n_iter)
    assert bool(fr.converged) == bool(pr.converged)


def test_pallas_source_batched_bitwise():
    """The parity holds under vmap (the pool's batched dispatch path)."""
    ds = make_dataset("heart", n_override=120)
    X = jnp.asarray(ds.X)
    y = jnp.asarray(ds.y, jnp.float64)
    n = y.shape[0]
    masks = jnp.stack([jnp.ones(n, bool).at[:20].set(False),
                       jnp.ones(n, bool).at[20:40].set(False),
                       jnp.ones(n, bool)])
    Cs = jnp.asarray([ds.C, 4.0 * ds.C, ds.C])
    a0 = jnp.zeros((3, n))
    f0 = jnp.tile(-y, (3, 1))
    fb = solve_batched(FusedRBF(X, ds.gamma), y, masks, Cs, a0, f0, wss="1")
    pb = solve_batched(PallasRBF(X, ds.gamma), y, masks, Cs, a0, f0, wss="1")
    for a, b in zip(fb, pb):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("max_width", [1, 2])
def test_pallas_source_under_pool_bitwise(max_width):
    """Same fixed points through the lane pool's repacked dispatch, at the
    production (measured, width-1) cap and the bucket-exact batched width.
    One pool per source: parity is per-schedule (solo chunk_jit and the
    vmapped program are not mutually bitwise), and as long as both sources
    see the same dispatch trajectory their iterates stay bit-identical
    chunk by chunk. Wider batches drift at the last ulp — see
    test_pallas_wide_batch_tolerance and DESIGN.md §Pallas sources."""
    from repro.svm.scheduler import LanePool
    ds = make_dataset("heart", n_override=120)
    X = jnp.asarray(ds.X)
    y = jnp.asarray(ds.y, jnp.float64)
    n = y.shape[0]
    masks = [jnp.ones(n, bool).at[h * 20:(h + 1) * 20].set(False)
             for h in range(3)]

    def run(source):
        pool = LanePool({"src": source}, y, wss="1", max_width=max_width,
                        chunk_iters=512)
        for h in range(3):
            pool.add(h, masks[h], ds.C, jnp.zeros(n), -y, source="src")
        return pool.run()

    fres = run(FusedRBF(X, ds.gamma))
    pres = run(PallasRBF(X, ds.gamma))
    for h in range(3):
        fr, pr = fres[h], pres[h]
        np.testing.assert_array_equal(np.asarray(fr.alpha),
                                      np.asarray(pr.alpha))
        np.testing.assert_array_equal(np.asarray(fr.f), np.asarray(pr.f))
        assert int(fr.n_iter) == int(pr.n_iter)


def test_pallas_wide_batch_tolerance():
    """At batch widths >= 4 XLA picks different batched-dot reduction
    strategies for the two programs, so cross-source parity relaxes from
    bitwise to last-ulp agreement (~1e-13 on f64 alphas). The measured CPU
    cost model never dispatches those widths; this pins the failure mode
    so a future regression shows up as a tolerance break, not a mystery."""
    ds = make_dataset("heart", n_override=120)
    X = jnp.asarray(ds.X)
    y = jnp.asarray(ds.y, jnp.float64)
    n = y.shape[0]
    masks = jnp.stack([jnp.ones(n, bool).at[h * 15:(h + 1) * 15].set(False)
                       for h in range(5)])
    a0 = jnp.zeros((5, n))
    f0 = jnp.tile(-y, (5, 1))
    fb = solve_batched(FusedRBF(X, ds.gamma), y, masks, ds.C, a0, f0,
                       wss="1")
    pb = solve_batched(PallasRBF(X, ds.gamma), y, masks, ds.C, a0, f0,
                       wss="1")
    assert bool(jnp.all(fb.converged)) and bool(jnp.all(pb.converged))
    np.testing.assert_allclose(np.asarray(fb.alpha), np.asarray(pb.alpha),
                               atol=1e-10)


def test_pallas_nbytes_is_data_not_matrix():
    """The cache budget must account X's bytes, not n² kernel bytes."""
    from repro.svm.sources import KernelSpec
    ds = make_dataset("heart", n_override=150)
    X = jnp.asarray(ds.X)
    src = PallasRBF(X, ds.gamma)
    assert src.nbytes == X.nbytes
    spec = KernelSpec(X, gamma=ds.gamma, kind="pallas_rbf", n=100)
    assert spec.nbytes == 100 * X.shape[1] * X.dtype.itemsize
    assert spec.fused and spec.streams_rows
    mat = spec.materialize()
    assert isinstance(mat, PallasRBF) and mat.nbytes == spec.nbytes


def test_dense_fupdate_pallas_bitwise():
    """DenseKernel's opt-in pallas f-update replays the plain-jnp ops."""
    ds, X, K, y = _setup(n=120)
    n = y.shape[0]
    mask = jnp.ones(n, bool).at[:20].set(False)
    base = solve(DenseKernel(K), y, mask, ds.C, jnp.zeros(n), -y)
    pal = solve(DenseKernel(K, fupdate="pallas"), y, mask, ds.C,
                jnp.zeros(n), -y)
    np.testing.assert_array_equal(np.asarray(base.alpha),
                                  np.asarray(pal.alpha))
    np.testing.assert_array_equal(np.asarray(base.f), np.asarray(pal.f))
    assert int(base.n_iter) == int(pal.n_iter)


def test_run_cv_batched_pallas_backend():
    from repro.core.cv import run_cv, run_cv_batched
    ds = make_dataset("heart", n_override=120)
    rep = run_cv_batched(ds, k=4, source_backend="pallas_rbf")
    assert rep.method == "cold_pallas"
    assert all(f.converged for f in rep.folds)
    # same fixed points as the dense drivers up to tolerance: held-out
    # accuracy is identical, objectives agree to solver tolerance
    cold = run_cv(ds, k=4, method="cold")
    assert rep.accuracy == pytest.approx(cold.accuracy, abs=1e-12)
    for fp, fd in zip(rep.folds, cold.folds):
        assert fp.objective == pytest.approx(fd.objective, rel=1e-5)
    with pytest.raises(ValueError, match="repacked"):
        run_cv_batched(ds, k=4, source_backend="pallas_rbf",
                       schedule="batched")


def test_grid_pallas_resident_is_n2_independent():
    """A budgeted grid over pallas sources: peak resident kernel bytes are
    X bytes per gamma — independent of n² — and accuracy matches the dense
    cold grid."""
    from repro.core.grid import run_grid
    ds = make_dataset("heart", n_override=120)
    kw = dict(Cs=(0.5, 2.0), gammas=(0.5, 1.0), k=3, method="cold",
              max_resident=1)
    pal = run_grid(ds, source_backend="pallas_rbf", **kw)
    dense = run_grid(ds, **kw)
    n = pal.n
    x_bytes = n * ds.X.shape[1] * 8
    assert pal.resident["peak_resident_bytes"] <= x_bytes
    assert pal.resident["peak_resident_bytes"] < n * n * 8
    assert dense.resident["peak_resident_bytes"] >= n * n * 8
    for cp, cd in zip(pal.cells, dense.cells):
        assert (cp.C, cp.gamma) == (cd.C, cd.gamma)
        assert cp.accuracy == pytest.approx(cd.accuracy, abs=1e-12)
    with pytest.raises(ValueError, match="cold"):
        run_grid(ds, Cs=(0.5,), gammas=(0.5,), k=3, method="sir",
                 source_backend="pallas_rbf")


# ------------------------------------------------------- NaN guards -------

def test_arg_reduces_nan_guard():
    """Regression: a NaN used to make ``v == min(v)`` all-False, so the
    reduce returned v.shape[0] — out of range — and jax's clamped gather
    silently aliased it to the last row."""
    from repro.svm.engine import _argmin, _argmax
    v = jnp.asarray([3.0, jnp.nan, 1.0, jnp.nan])
    assert int(_argmin(v)) == int(jnp.argmin(v)) == 1
    assert int(_argmax(v)) == int(jnp.argmax(v)) == 1
    clean = jnp.asarray([3.0, 1.0, 1.0, 7.0])
    assert int(_argmin(clean)) == int(jnp.argmin(clean)) == 1
    assert int(_argmax(clean)) == int(jnp.argmax(clean)) == 3
    # degenerate inputs stay in range
    assert int(_argmin(jnp.asarray([jnp.nan, jnp.nan]))) == 0
    assert int(_argmax(jnp.asarray([jnp.nan, jnp.nan]))) == 0
    assert int(_argmin(jnp.full(3, jnp.inf))) == 0
    assert int(_argmax(jnp.full(3, -jnp.inf))) == 0


def test_solver_halts_on_nan_state():
    """A NaN in f on an active row must stop the solve immediately with
    converged=False instead of spinning on a bogus pair until max_iter."""
    ds, X, K, y = _setup(n=64)
    n = y.shape[0]
    f0 = (-y).at[3].set(jnp.nan)
    res = smo_solve(K, y, jnp.ones(n, bool), ds.C, jnp.zeros(n), f0,
                    max_iter=50_000)
    assert not bool(res.converged)
    assert int(res.n_iter) == 0   # halted before any update was applied


@pytest.mark.parametrize("schedule,label", [
    ("batched", "cold_batched"), ("repacked", "cold_batched_repacked")])
def test_run_cv_batched_matches_cold_cv(schedule, label):
    from repro.core.cv import run_cv, run_cv_batched
    ds = make_dataset("heart", n_override=120)
    cold = run_cv(ds, k=4, method="cold")
    bat = run_cv_batched(ds, k=4, schedule=schedule)
    assert bat.method == label
    assert bat.accuracy == pytest.approx(cold.accuracy, abs=1e-12)
    assert [f.n_iter for f in bat.folds] == [f.n_iter for f in cold.folds]
    assert all(f.converged for f in bat.folds)
    if schedule == "repacked":
        assert bat.occupancy["chunks"] >= 1
        assert bat.occupancy["peak_width"] >= 1


def test_solve_batched_n_iter0s_resume_bitwise():
    """A capped batched run resumed with per-lane ``n_iter0s`` replays the
    uninterrupted iterate sequence — alpha, f AND the n_iter account —
    mirroring the single-lane ``solve(..., n_iter0=...)`` path."""
    ds, X, K, y = _setup(n=120)
    n = y.shape[0]
    masks = jnp.stack([jnp.ones(n, bool).at[:20].set(False),
                       jnp.ones(n, bool).at[20:40].set(False)])
    Cs = jnp.asarray([ds.C, 4.0 * ds.C])
    a0 = jnp.zeros((2, n))
    f0 = jnp.tile(-y, (2, 1))
    full = smo_solve_batched(K, y, masks, Cs, a0, f0)
    part = smo_solve_batched(K, y, masks, Cs, a0, f0, max_iter=150)
    np.testing.assert_array_equal(np.asarray(part.n_iter), [150, 150])
    resumed = smo_solve_batched(K, y, masks, Cs, part.alpha, part.f,
                                n_iter0s=part.n_iter)
    for a, b in zip(full, resumed):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the cap counts TOTAL updates incl. the preload: resuming a 150-iter
    # state under max_iter=150 must apply zero further updates
    recapped = smo_solve_batched(K, y, masks, Cs, part.alpha, part.f,
                                 n_iter0s=part.n_iter, max_iter=150)
    np.testing.assert_array_equal(np.asarray(recapped.alpha),
                                  np.asarray(part.alpha))
    np.testing.assert_array_equal(np.asarray(recapped.n_iter), [150, 150])
