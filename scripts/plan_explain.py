#!/usr/bin/env python
"""Explain what a Study ``Plan`` will make the machine do — statically.

Loads a wire-serialized plan (``--plan file.json``, a ``plan_to_dict``
image) or builds one from the synthetic suite via ``grid_plans``
(``--dataset/--gammas/--Cs``), then runs the static schedule simulator
(``repro.analysis.plan_sim``) and pretty-prints the analyzer findings,
projected peak resident bytes, dispatch/chunk totals, and (with
``--trace``) the event trace itself. ``--exact`` additionally runs the
instrumented live pool and asserts the simulated trace matches it
event-for-event.

Against a live daemon, ``--connect <socket>`` performs the ``hello``
handshake, normalizes the plan to the daemon's pool contract (exactly
as admission would), and predicts the admission verdict — including the
daemon's per-plan tenant budgets — without submitting anything.

    PYTHONPATH=src python scripts/plan_explain.py --dataset heart \\
        --gammas 0.5,1,2 --folds 4 --cache-bytes 500000 --trace 40
"""
import argparse
import dataclasses
import json
import sys


def build_plan(args):
    if args.plan:
        from repro.core.study import plan_from_dict
        with open(args.plan) as fh:
            return plan_from_dict(json.load(fh))
    from repro.core.grid import grid_plans
    from repro.data.svm_suite import make_dataset
    ds = make_dataset(args.dataset, n_override=args.n)
    gammas = [float(g) * ds.gamma for g in args.gammas.split(",")]
    Cs = [float(c) for c in args.Cs.split(",")] if args.Cs else [ds.C]
    plans = grid_plans(
        ds, Cs, gammas, k=args.folds, chunk_iters=args.chunk_iters,
        lane_quantum=args.lane_quantum, max_width=args.max_width,
        max_resident=args.max_resident, cache_bytes=args.cache_bytes,
        shrink_every=args.shrink_every)
    return plans[0]


def normalize_to_daemon(plan, socket_path):
    """The ``hello`` handshake + the daemon's own knob normalization, so
    the prediction is about the schedule the daemon would actually run."""
    from repro.service import protocol
    sock = protocol.connect(socket_path)
    try:
        rfile, wfile = sock.makefile("rb"), sock.makefile("wb")
        protocol.send_msg(wfile, {"op": "hello", "tenant": "plan-explain"})
        reply = protocol.recv_msg(rfile)
    finally:
        sock.close()
    if not reply or reply.get("type") != "hello":
        raise RuntimeError(f"bad handshake reply: {reply!r}")
    c = reply["pool"]
    plan = dataclasses.replace(
        plan, chunk_iters=c["chunk_iters"], lane_quantum=c["lane_quantum"],
        max_width=c["max_width"], max_resident=c["max_resident"],
        cache_bytes=c["cache_bytes"])
    return plan, c


def show_summary(tag, s) -> None:
    print(f"  [{tag}] chunks={s['chunks']} lane_chunks={s['lane_chunks']} "
          f"peak_resident={s['peak_resident_bytes']}B "
          f"materializations={s['materializations']} "
          f"evictions={s['evictions']} checkpoints={s['checkpoints']} "
          f"est_dispatch={s['est_dispatch_s']}s"
          + (" TRUNCATED" if s["truncated"] else ""))
    for row in s["dispatches"]:
        *bucket, count = row
        print(f"      {count:6d} x {tuple(bucket)}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    src = ap.add_argument_group("plan source")
    src.add_argument("--plan", default=None,
                     help="wire plan JSON (plan_to_dict image)")
    src.add_argument("--dataset", default="heart",
                     help="suite dataset for grid_plans mode")
    src.add_argument("--n", type=int, default=None,
                     help="dataset size override")
    src.add_argument("--gammas", default="0.5,1.0,2.0",
                     help="gamma multipliers (x dataset gamma)")
    src.add_argument("--Cs", default=None,
                     help="C values (default: the dataset's)")
    src.add_argument("--folds", type=int, default=4)
    sched = ap.add_argument_group("schedule knobs (grid_plans mode)")
    sched.add_argument("--chunk-iters", type=int, default=4096)
    sched.add_argument("--lane-quantum", type=int, default=4)
    sched.add_argument("--max-width", type=int, default=None)
    sched.add_argument("--max-resident", type=int, default=0)
    sched.add_argument("--cache-bytes", type=int, default=0)
    sched.add_argument("--shrink-every", type=int, default=0)
    ap.add_argument("--connect", default=None, metavar="SOCKET",
                    help="predict admission against this live daemon "
                    "(hello handshake only; nothing is submitted)")
    ap.add_argument("--horizon", type=int, default=None,
                    help="max-bound oracle horizon in iterations "
                    "(default: plan_check's)")
    ap.add_argument("--exact", action="store_true",
                    help="also run the instrumented live pool and assert "
                    "trace parity (solves the plan!)")
    ap.add_argument("--trace", type=int, default=0, metavar="N",
                    help="print the first N trace events (max-bound sim, "
                    "or the exact sim with --exact)")
    args = ap.parse_args(argv)

    from repro.analysis import plan_check, plan_sim

    plan = build_plan(args)
    contract = None
    if args.connect:
        plan, contract = normalize_to_daemon(plan, args.connect)
        print(f"daemon contract: {contract}")

    pa = plan_check.analyze_plan(plan, simulate="bounds",
                                 sim_horizon=args.horizon)
    print(f"plan: {len(plan.lanes)} lanes over {len(plan.sources)} "
          f"sources; {pa.program_count} distinct jit programs "
          f"(max_width={pa.max_width})")
    print(f"budget: cache_bytes={plan.cache_bytes} "
          f"max_resident={plan.max_resident} pinned={pa.pinned_bytes}B "
          f"largest_managed={pa.peak_managed_bytes}B")
    if pa.sim:
        print("schedule simulation:")
        show_summary("min", pa.sim["min"])
        show_summary("max", pa.sim["max"])
    for f in pa.report:
        print(f"  {f.render()}")

    verdict = "admit" if pa.ok else "REJECT"
    if contract is not None and pa.ok and pa.sim:
        hi = pa.sim["max"]
        if contract.get("plan_chunk_budget") and \
                hi["lane_chunks"] > contract["plan_chunk_budget"]:
            verdict = "REJECT (tenant-budget: lane_chunks " \
                f"{hi['lane_chunks']} > {contract['plan_chunk_budget']})"
        if contract.get("plan_bytes_budget") and \
                hi["peak_resident_bytes"] > contract["plan_bytes_budget"]:
            verdict = "REJECT (tenant-budget: resident bytes " \
                f"{hi['peak_resident_bytes']} > " \
                f"{contract['plan_bytes_budget']})"
    print(f"predicted admission: {verdict}")

    trace_events = None
    if args.exact:
        print("running instrumented live pool for the exact oracle ...")
        events, pool = plan_sim.dry_run(plan)
        oracle = plan_sim.oracle_from_trace(
            events, shrink=bool(pool.shrink_every))
        sa = plan_sim.simulate_plan(plan, oracle=oracle)
        same = sa.events == events
        print(f"exact replay: {len(sa.events)} simulated vs "
              f"{len(events)} live events — "
              f"{'IDENTICAL' if same else 'MISMATCH'}")
        show_summary("exact", sa.summary_json())
        trace_events = sa.events
        if not same:
            return 1
    elif args.trace:
        horizon = args.horizon or plan_check.SIM_HORIZON_CHUNKS \
            * int(plan.chunk_iters)
        sa = plan_sim.simulate_plan(
            plan, oracle=plan_sim.BoundOracle("max", horizon=horizon))
        trace_events = sa.events
    if args.trace and trace_events:
        print(plan_sim.render_events(trace_events, limit=args.trace))
    return 0 if verdict == "admit" else 2


if __name__ == "__main__":
    sys.exit(main())
