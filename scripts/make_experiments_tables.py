"""Regenerate the data-driven tables of EXPERIMENTS.md from results/.

Prints markdown to stdout; EXPERIMENTS.md embeds the output between
generated-table markers. Usage:
    PYTHONPATH=src python scripts/make_experiments_tables.py
"""
import glob
import json
import os

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")
BENCH = os.path.join(os.path.dirname(__file__), "..", "results", "bench")

ARCH_ORDER = ["deepseek-v2-236b", "deepseek-v3-671b", "yi-34b", "gemma3-4b",
              "granite-8b", "gemma-7b", "jamba-v0.1-52b",
              "seamless-m4t-large-v2", "xlstm-125m", "qwen2-vl-2b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def recs():
    out = {}
    for p in glob.glob(os.path.join(DRYRUN, "*.json")):
        with open(p) as fh:
            r = json.load(fh)
        out[r["cell"]] = r
    return out


def fmt_e(x):
    return f"{x:.2e}" if isinstance(x, (int, float)) else "-"


def dryrun_table(r):
    print("\n### Dry-run matrix (compile status, both meshes)\n")
    print("| arch | shape | 16x16 | 2x16x16 | HBM/dev (GB) | compile (s) |")
    print("|---|---|---|---|---|---|")
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r1 = r.get(f"{a}__{s}__pod16x16", {})
            r2 = r.get(f"{a}__{s}__pod2x16x16", {})
            s1, s2 = r1.get("status", "?"), r2.get("status", "?")
            if s1 == "skipped":
                print(f"| {a} | {s} | skip | skip | - | - |")
                continue
            hbm = r1.get("hbm_gb_per_device", "-")
            cs = r1.get("compile_s", "-")
            print(f"| {a} | {s} | {s1} | {s2} | {hbm} | {cs} |")


def roofline_table(r):
    print("\n### Roofline baseline (single-pod 16x16 = 256 chips)\n")
    print("| arch | shape | compute (s) | memory (s) | collective (s) |"
          " dominant | roofline frac | useful FLOP ratio |")
    print("|---|---|---|---|---|---|---|---|")
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            rec = r.get(f"{a}__{s}__pod16x16")
            if not rec or rec.get("status") != "ok":
                continue
            rf = rec["roofline"]
            ur = rec.get("useful_flops_ratio")
            print(f"| {a} | {s} | {fmt_e(rf['compute_s'])} | "
                  f"{fmt_e(rf['memory_s'])} | {fmt_e(rf['collective_s'])} | "
                  f"{rf['dominant']} | "
                  f"{rf['roofline_fraction']:.3f} | "
                  f"{ur:.3f} |" if ur else "")
    svm = [v for k, v in r.items() if k.startswith("svm-smo")]
    for rec in sorted(svm, key=lambda x: x["cell"]):
        rf = rec["roofline"]
        print(f"| svm-smo (n=4M,d=512) | {rec['cell'].split('__')[-1]} | "
              f"{fmt_e(rf['compute_s'])} | {fmt_e(rf['memory_s'])} | "
              f"{fmt_e(rf['collective_s'])} | {rf['dominant']} | "
              f"{rf['roofline_fraction']:.3f} | - |")


def bench_tables():
    for name in sorted(glob.glob(os.path.join(BENCH, "*.json"))):
        with open(name) as fh:
            rows = json.load(fh)
        if not rows:
            continue
        print(f"\n### bench: {os.path.basename(name)[:-5]}\n")
        cols = list(rows[0])
        print("| " + " | ".join(cols) + " |")
        print("|" + "---|" * len(cols))
        for row in rows:
            print("| " + " | ".join(str(row[c]) for c in cols) + " |")


if __name__ == "__main__":
    r = recs()
    dryrun_table(r)
    roofline_table(r)
    bench_tables()
