#!/usr/bin/env python
"""Run the study daemon: one shared LanePool serving plans over a local
socket until SIGTERM/SIGINT or a client ``shutdown`` op (both drain
gracefully: in-flight studies flush checkpoint snapshots and resume on
the next start).

    PYTHONPATH=src python scripts/study_serve.py --socket /tmp/study.sock \\
        --checkpoint-root /tmp/study-ckpt --max-width 4

The flags fix the pool's result-affecting contract (tol, wss, shrink
settings) — submitted plans must match it — and the schedule shape
(width, chunk size, budgets), which served plans inherit.
"""
import argparse
import signal
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--socket", required=True,
                    help="AF_UNIX socket path to listen on")
    ap.add_argument("--checkpoint-root", default=None,
                    help="root directory for per-(tenant, plan) study "
                    "snapshots (omit to disable resume)")
    ap.add_argument("--tol", type=float, default=1e-3)
    ap.add_argument("--wss", default="2", choices=("1", "2"))
    ap.add_argument("--chunk-iters", type=int, default=4096)
    ap.add_argument("--lane-quantum", type=int, default=4)
    ap.add_argument("--max-width", type=int, default=None,
                    help="width cap (default: measured cost model)")
    ap.add_argument("--max-resident", type=int, default=0,
                    help="kernel-source residency budget, count (0=off)")
    ap.add_argument("--cache-bytes", type=int, default=0,
                    help="kernel-source residency budget, bytes (0=off)")
    ap.add_argument("--shrink-every", type=int, default=0)
    ap.add_argument("--shrink-quantum", type=int, default=128)
    ap.add_argument("--snapshot-every", type=int, default=1,
                    help="study snapshot period in pool chunks")
    ap.add_argument("--plan-chunk-budget", type=int, default=0,
                    help="per-plan admission budget: max-bound simulated "
                    "lane-chunks (0=unbounded)")
    ap.add_argument("--plan-bytes-budget", type=int, default=0,
                    help="per-plan admission budget: max-bound simulated "
                    "peak resident bytes (0=unbounded)")
    args = ap.parse_args(argv)

    from repro.service import StudyServer, StudyService

    service = StudyService(
        tol=args.tol, wss=args.wss, chunk_iters=args.chunk_iters,
        lane_quantum=args.lane_quantum, max_width=args.max_width,
        max_resident=args.max_resident, cache_bytes=args.cache_bytes,
        shrink_every=args.shrink_every, shrink_quantum=args.shrink_quantum,
        checkpoint_root=args.checkpoint_root,
        snapshot_every=args.snapshot_every,
        plan_chunk_budget=args.plan_chunk_budget,
        plan_bytes_budget=args.plan_bytes_budget)
    server = StudyServer(args.socket, service)

    def _drain(signum, frame):
        print(f"signal {signum}: draining", file=sys.stderr)
        server.stop_accepting()

    signal.signal(signal.SIGTERM, _drain)
    signal.signal(signal.SIGINT, _drain)
    print(f"study daemon listening on {args.socket} "
          f"(width={service.pool.max_width}, tol={service.pool.tol}, "
          f"wss={service.pool.wss})", file=sys.stderr)
    server.serve_forever()
    print("study daemon drained", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
