#!/usr/bin/env python
"""Measure the lane scheduler's dispatch-width cost model.

The pool's ``max_width`` verdict ("how many lanes may share one vmapped
chunk program before per-lane cost degrades") used to be hard-coded in
``svm/scheduler.py``. This harness measures it with the pool itself: for
each source kind (dense matrix vs row-streaming pallas) it runs a
``LanePool`` of heterogeneous lanes (spread C values, distinct fold-like
masks — so convergence is staggered, exactly the workload the scheduler
repacks for) at each forced ``max_width`` and divides wall-clock by the
total *useful* iterations ``sum_h n_iter_h``. That metric charges the
batched program for its real overheads — frozen mid-chunk lanes, padded
widths, batched gathers — not just raw vmap throughput.

Verdict per (backend, kind):

    max_width = 1     when width-1 is within SLACK (10%) of the best
                      width — the sequential program is preferred at
                      marginal differences (per-lane retirement
                      granularity, O(n) packed state, and the spread at
                      these chunk durations is near timing noise)
    best width        when a bounded width wins by more than SLACK
    0 (unbounded)     when the largest measured width is the winner

The verdict lands in ``results/cost_model.json`` (see
``svm/cost_model.py`` for the schema), which ``LanePool`` loads at
construction. CI runs ``--quick`` and asserts the file parses with a CPU
entry; on this container the full run reproduces the historical width-1
CPU verdict for both kinds.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import platform
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.svm import cost_model
from repro.svm.engine import DenseKernel, PallasRBF
from repro.svm.kernels import kernel_matrix
from repro.svm.scheduler import LanePool

#: width-1 keeps the cap unless a batched width beats it by this factor
SLACK = 1.10

#: staggered-convergence lane spread (grid-like C heterogeneity)
C_SPREAD = (0.25, 0.5, 1.0, 2.0, 4.0, 1.0, 0.5, 2.0)


def _problem(n: int, d: int, gamma: float, n_lanes: int):
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(size=(n, d)))
    y = jnp.asarray(np.where(rng.random(n) < 0.5, -1.0, 1.0))
    masks = [jnp.asarray(np.random.default_rng(10 + h).random(n) < 0.85)
             for h in range(n_lanes)]
    Cs = [C_SPREAD[h % len(C_SPREAD)] for h in range(n_lanes)]
    sources = {"dense": DenseKernel(kernel_matrix(X, X, gamma=gamma)),
               "pallas_rbf": PallasRBF(X, gamma)}
    return sources, y, masks, Cs


def measure_kind(kind: str, source, y, masks, Cs, *, widths, chunk_iters,
                 reps: int) -> dict:
    """us per useful lane-iteration at each forced ``max_width``."""
    n = y.shape[0]
    wss = "1" if getattr(source, "fused", False) else "2"

    def run(width: int):
        pool = LanePool({kind: source}, y, wss=wss, max_width=width,
                        chunk_iters=chunk_iters)
        for h, (mask, C) in enumerate(zip(masks, Cs)):
            pool.add(h, mask, C, jnp.zeros(n, source.dtype), -y,
                     source=kind)
        t0 = time.perf_counter()
        results = pool.run()
        dt = time.perf_counter() - t0
        return dt, sum(int(r.n_iter) for r in results.values())

    run(1)                                  # warm (compile both programs)
    run(max(widths))
    cost = {}
    for w in widths:
        best = np.inf
        for _ in range(reps):
            dt, iters = run(w)
            best = min(best, dt / max(iters, 1))
        cost[str(w)] = best * 1e6
        print(f"  {kind:>10s} width {w:>2d}: "
              f"{cost[str(w)]:8.2f} us/useful-lane-iter", flush=True)
    best_w = min(widths, key=lambda w: cost[str(w)])
    if cost["1"] <= SLACK * cost[str(best_w)]:
        max_width = 1
    elif best_w == max(widths):
        max_width = 0                       # more is better: unbounded
    else:
        max_width = best_w
    return {"max_width": max_width, "us_per_lane_iter": cost}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--n", type=int, default=1000,
                    help="instances per synthetic lane problem")
    ap.add_argument("--d", type=int, default=40)
    ap.add_argument("--chunk-iters", type=int, default=2048,
                    help="pool dispatch granularity (production default)")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--widths", type=int, nargs="+", default=[1, 2, 4, 8])
    ap.add_argument("--out", default=None,
                    help="output path (default: the loader's path, "
                         "results/cost_model.json or $REPRO_COST_MODEL)")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke sizing (small n, widths 1/2, 1 rep)")
    args = ap.parse_args(argv)
    if args.quick:
        args.n, args.chunk_iters, args.reps = 200, 256, 1
        args.widths = [1, 2]
    if 1 not in args.widths:
        ap.error("widths must include 1 (the sequential baseline)")

    backend = jax.default_backend()
    print(f"backend={backend} n={args.n} d={args.d} "
          f"chunk_iters={args.chunk_iters} widths={args.widths}", flush=True)
    sources, y, masks, Cs = _problem(args.n, args.d, gamma=0.5,
                                     n_lanes=max(args.widths))
    entries = {kind: measure_kind(kind, src, y, masks, Cs,
                                  widths=args.widths,
                                  chunk_iters=args.chunk_iters,
                                  reps=args.reps)
               for kind, src in sources.items()}

    out_path = pathlib.Path(args.out) if args.out else cost_model.model_path()
    try:
        model = json.loads(out_path.read_text())
        assert isinstance(model.get("entries"), dict)
    except (OSError, ValueError, AssertionError):
        model = {"entries": {}}
    model["schema"] = 1
    model.setdefault("meta", {})[backend] = {
        "n": args.n, "d": args.d, "chunk_iters": args.chunk_iters,
        "widths": args.widths, "n_lanes": len(masks),
        "quick": bool(args.quick), "slack": SLACK,
        "platform": platform.platform(), "jax": jax.__version__,
    }
    model["entries"][backend] = entries

    out_path.parent.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(model, indent=2, sort_keys=True) + "\n")
    for kind, e in entries.items():
        print(f"{backend}/{kind}: max_width={e['max_width']}")
    print(f"wrote {out_path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
