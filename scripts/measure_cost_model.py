#!/usr/bin/env python
"""Measure the lane scheduler's dispatch-width cost model.

The pool's ``max_width`` verdict ("how many lanes may share one vmapped
chunk program before per-lane cost degrades") used to be hard-coded in
``svm/scheduler.py``. This harness measures it with the pool itself: for
each source kind (dense matrix vs row-streaming pallas) it runs a
``LanePool`` of heterogeneous lanes (spread C values, distinct fold-like
masks — so convergence is staggered, exactly the workload the scheduler
repacks for) at each forced ``max_width`` and divides wall-clock by the
total *useful* iterations ``sum_h n_iter_h``. That metric charges the
batched program for its real overheads — frozen mid-chunk lanes, padded
widths, batched gathers — not just raw vmap throughput.

Verdict per (backend, kind):

    max_width = 1     when width-1 is within SLACK (10%) of the best
                      width — the sequential program is preferred at
                      marginal differences (per-lane retirement
                      granularity, O(n) packed state, and the spread at
                      these chunk durations is near timing noise)
    best width        when a bounded width wins by more than SLACK
    0 (unbounded)     when the largest measured width is the winner

The verdict lands in ``results/cost_model.json`` (see
``svm/cost_model.py`` for the schema), which ``LanePool`` loads at
construction. CI runs ``--quick`` and asserts the file parses with a CPU
entry; on this container the full run reproduces the historical width-1
CPU verdict for both kinds.

The harness also sweeps per-cap throughput for the shrink verdict
(``shrink_every="auto"``): the width-1 chunk program is timed at several
problem sizes — the shapes a shrunk lane's compact dispatches run at —
and shrinking is worth its recompiles + re-gathers on this backend only
when per-iteration cost actually falls with operand size
(``us_per_iter_by_n``). Dispatch-bound backends (CPU interpret mode)
measure flat and get ``shrink: false``; ``--shrink-only`` re-runs just
this sweep and merges it into an existing file.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import platform
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.svm import cost_model
from repro.svm.engine import DenseKernel, PallasRBF
from repro.svm.kernels import kernel_matrix
from repro.svm.scheduler import LanePool

#: width-1 keeps the cap unless a batched width beats it by this factor
SLACK = 1.10

#: shrink pays off only when the smallest swept size is at least this much
#: cheaper per iteration than the full size. The margin is deliberately
#: wide: the sweep measures the NECESSARY condition (the chunk program
#: gets cheaper at compact shapes) at a quarter of the problem, but a real
#: workload's active set rarely shrinks that far and every shrink run also
#: pays costs the sweep cannot charge — per-cap recompiles, re-gather
#: chunks, boundary-bounded dispatches. Requiring a 2x per-iteration win
#: at quarter size keeps dispatch-bound backends (dense CPU measures
#: ~1.5x and then LOSES end-to-end on the ato_shrink bench row) gated
#: off while bytes-bound streams (pallas X-streaming scales ~linearly
#: with the cap) still qualify.
SHRINK_SLACK = 2.0

#: staggered-convergence lane spread (grid-like C heterogeneity)
C_SPREAD = (0.25, 0.5, 1.0, 2.0, 4.0, 1.0, 0.5, 2.0)


def _problem(n: int, d: int, gamma: float, n_lanes: int):
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(size=(n, d)))
    y = jnp.asarray(np.where(rng.random(n) < 0.5, -1.0, 1.0))
    masks = [jnp.asarray(np.random.default_rng(10 + h).random(n) < 0.85)
             for h in range(n_lanes)]
    Cs = [C_SPREAD[h % len(C_SPREAD)] for h in range(n_lanes)]
    sources = {"dense": DenseKernel(kernel_matrix(X, X, gamma=gamma)),
               "pallas_rbf": PallasRBF(X, gamma)}
    return sources, y, masks, Cs


def measure_kind(kind: str, source, y, masks, Cs, *, widths, chunk_iters,
                 reps: int) -> dict:
    """us per useful lane-iteration at each forced ``max_width``."""
    n = y.shape[0]
    wss = "1" if getattr(source, "fused", False) else "2"

    def run(width: int):
        pool = LanePool({kind: source}, y, wss=wss, max_width=width,
                        chunk_iters=chunk_iters)
        for h, (mask, C) in enumerate(zip(masks, Cs)):
            pool.add(h, mask, C, jnp.zeros(n, source.dtype), -y,
                     source=kind)
        t0 = time.perf_counter()
        results = pool.run()
        dt = time.perf_counter() - t0
        return dt, sum(int(r.n_iter) for r in results.values())

    run(1)                                  # warm (compile both programs)
    run(max(widths))
    cost = {}
    for w in widths:
        best = np.inf
        for _ in range(reps):
            dt, iters = run(w)
            best = min(best, dt / max(iters, 1))
        cost[str(w)] = best * 1e6
        print(f"  {kind:>10s} width {w:>2d}: "
              f"{cost[str(w)]:8.2f} us/useful-lane-iter", flush=True)
    best_w = min(widths, key=lambda w: cost[str(w)])
    if cost["1"] <= SLACK * cost[str(best_w)]:
        max_width = 1
    elif best_w == max(widths):
        max_width = 0                       # more is better: unbounded
    else:
        max_width = best_w
    return {"max_width": max_width, "us_per_lane_iter": cost}


def measure_shrink(kind: str, *, ns, d, gamma, chunk_iters, reps,
                   n_lanes: int = 2) -> dict:
    """Per-cap throughput sweep: us per useful iteration of the width-1
    chunk program at each problem size in ``ns`` — the static shapes a
    shrunk lane's compact dispatches run at. Shrink verdict = operand-byte
    sensitivity: True iff the smallest size beats the full size by more
    than ``SHRINK_SLACK`` per iteration."""
    cost = {}
    for m in sorted(ns):
        sources, y, masks, Cs = _problem(m, d, gamma, n_lanes)
        source = sources[kind]
        wss = "1" if getattr(source, "fused", False) else "2"
        best = np.inf
        for rep in range(reps + 1):         # rep 0 doubles as compile warmup
            pool = LanePool({kind: source}, y, wss=wss, max_width=1,
                            chunk_iters=chunk_iters)
            for h, (mask, C) in enumerate(zip(masks, Cs)):
                pool.add(h, mask, C, jnp.zeros(m, source.dtype), -y,
                         source=kind)
            t0 = time.perf_counter()
            results = pool.run()
            dt = time.perf_counter() - t0
            iters = sum(int(r.n_iter) for r in results.values())
            if rep > 0:
                best = min(best, dt / max(iters, 1))
        cost[str(m)] = best * 1e6
        print(f"  {kind:>10s} n {m:>5d}: {cost[str(m)]:8.2f} us/iter",
              flush=True)
    full, small = cost[str(max(ns))], cost[str(min(ns))]
    return {"shrink": bool(small * SHRINK_SLACK <= full),
            "us_per_iter_by_n": cost}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--n", type=int, default=1000,
                    help="instances per synthetic lane problem")
    ap.add_argument("--d", type=int, default=40)
    ap.add_argument("--chunk-iters", type=int, default=2048,
                    help="pool dispatch granularity (production default)")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--widths", type=int, nargs="+", default=[1, 2, 4, 8])
    ap.add_argument("--out", default=None,
                    help="output path (default: the loader's path, "
                         "results/cost_model.json or $REPRO_COST_MODEL)")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke sizing (small n, widths 1/2, 1 rep)")
    ap.add_argument("--shrink-only", action="store_true",
                    help="skip the width sweep; merge only the per-cap "
                         "shrink sweep into the existing file")
    args = ap.parse_args(argv)
    if args.quick:
        args.n, args.chunk_iters, args.reps = 200, 256, 1
        args.widths = [1, 2]
    if 1 not in args.widths:
        ap.error("widths must include 1 (the sequential baseline)")

    backend = jax.default_backend()
    print(f"backend={backend} n={args.n} d={args.d} "
          f"chunk_iters={args.chunk_iters} widths={args.widths}", flush=True)
    out_path = pathlib.Path(args.out) if args.out else cost_model.model_path()
    try:
        model = json.loads(out_path.read_text())
        assert isinstance(model.get("entries"), dict)
    except (OSError, ValueError, AssertionError):
        model = {"entries": {}}
    model["schema"] = 1
    entries = model["entries"].setdefault(backend, {})

    if not args.shrink_only:
        sources, y, masks, Cs = _problem(args.n, args.d, gamma=0.5,
                                         n_lanes=max(args.widths))
        for kind, src in sources.items():
            entries.setdefault(kind, {}).update(
                measure_kind(kind, src, y, masks, Cs, widths=args.widths,
                             chunk_iters=args.chunk_iters, reps=args.reps))
        model.setdefault("meta", {})[backend] = {
            "n": args.n, "d": args.d, "chunk_iters": args.chunk_iters,
            "widths": args.widths, "n_lanes": len(masks),
            "quick": bool(args.quick), "slack": SLACK,
            "platform": platform.platform(), "jax": jax.__version__,
        }

    # per-cap sweep (the shrink verdict): quarter / half / full size,
    # mirroring the capacities a shrink_quantum-bucketed lane visits
    shrink_ns = sorted({max(64, args.n // 4), max(64, args.n // 2), args.n})
    for kind in ("dense", "pallas_rbf"):
        entries.setdefault(kind, {}).update(
            measure_shrink(kind, ns=shrink_ns, d=args.d, gamma=0.5,
                           chunk_iters=args.chunk_iters, reps=args.reps))
    model.setdefault("meta", {}).setdefault(backend, {})["shrink_ns"] = \
        shrink_ns
    model["meta"][backend]["shrink_slack"] = SHRINK_SLACK

    out_path.parent.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(model, indent=2, sort_keys=True) + "\n")
    for kind, e in entries.items():
        print(f"{backend}/{kind}: max_width={e.get('max_width')} "
              f"shrink={e.get('shrink')}")
    print(f"wrote {out_path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
