#!/usr/bin/env python
"""CI smoke for the study service (DESIGN.md §Study service).

Boots the real daemon — AF_UNIX socket, service thread, the works — and
drives it through the contracts CI cares about:

* **parity** — two tenants submit overlapping-gamma fold-chain plans
  concurrently; every lane must come back BIT-identical to an in-process
  ``run_plan`` of the same plan (the LanePool's schedule-shape parity is
  what licenses daemon interleaving);
* **dedup** — the shared gamma is admitted once: the two studies'
  admission accounting must show exactly one dedup hit, and the pool
  must materialize only the distinct kernels (fewer than the two solo
  runs combined);
* **admission** — a budget-infeasible plan is rejected over the wire
  with the ``check_plan`` findings attached, before anything
  materializes;
* **drain** — ``shutdown`` stops the daemon cleanly.

Exit code 0 on success; any assertion failure fails the CI step.
"""
from __future__ import annotations

import dataclasses
import sys
import threading
import uuid

import jax.numpy as jnp
import numpy as np

from repro.core.cv import _fold_masks, _transition_idx
from repro.core.study import Plan, run_plan
from repro.data.svm_suite import kfold_chunks, make_dataset
from repro.service import (PlanRejectedByServer, StudyClient, StudyServer,
                           StudyService)
from repro.svm.sources import KernelSpec


def _plan(specs, y, masks, chunks, C):
    plan = Plan(sources=dict(specs), y=y, chunk_iters=64, lane_quantum=2)
    n = y.shape[0]
    for key in specs:
        plan.lane((key, 0), source=key, train_mask=masks[0], C=C,
                  alpha0=jnp.zeros(n), f0=-y)
        for h in (1, 2):
            S, R, T = _transition_idx(chunks, h - 1, h)
            plan.lane((key, h), source=key, train_mask=masks[h], C=C,
                      dep=(key, h - 1), transform="fold",
                      params=dict(method="sir", S_idx=S, R_idx=R, T_idx=T))
        for h in range(3):
            plan.evaluate((key, h), chunks[h])
    return plan


def main() -> int:
    ds = make_dataset("heart", n_override=120)
    X = jnp.asarray(ds.X)
    y = jnp.asarray(ds.y, jnp.float64)
    chunks = kfold_chunks(120, 4, seed=0)
    nn = chunks.size
    X, y = X[:nn], y[:nn]
    masks = jnp.asarray(_fold_masks(chunks))
    gam = {s: KernelSpec(X=X, gamma=s * ds.gamma, n=nn)
           for s in (0.5, 1.0, 2.0)}
    plan_a = _plan({0.5: gam[0.5], 1.0: gam[1.0]}, y, masks, chunks, ds.C)
    plan_b = _plan({1.0: gam[1.0], 2.0: gam[2.0]}, y, masks, chunks, ds.C)
    solo_a, solo_b = run_plan(plan_a), run_plan(plan_b)
    solo_mats = (solo_a.source_stats["materializations"]
                 + solo_b.source_stats["materializations"])

    sock = f"/tmp/study-ci-{uuid.uuid4().hex[:8]}.sock"
    service = StudyService(chunk_iters=64, lane_quantum=2, max_width=0)
    server = StudyServer(sock, service)
    t = threading.Thread(target=server.serve_forever, daemon=True)
    t.start()
    import os
    import time
    for _ in range(200):
        if os.path.exists(sock):
            break
        time.sleep(0.05)
    else:
        raise AssertionError("daemon socket never appeared")

    served = {}
    gate = threading.Barrier(2)

    def tenant(name, plan):
        with StudyClient(sock, name) as cli:
            gate.wait()                  # submit as close together as we can
            served[name] = cli.submit("grid", plan)

    ta = threading.Thread(target=tenant, args=("alice", plan_a))
    tb = threading.Thread(target=tenant, args=("bob", plan_b))
    ta.start(), tb.start()
    ta.join(300), tb.join(300)
    assert set(served) == {"alice", "bob"}, served.keys()

    for name, solo in (("alice", solo_a), ("bob", solo_b)):
        got = served[name]
        assert set(got.results) == set(solo.results)
        for lid, ref in solo.results.items():
            np.testing.assert_array_equal(np.asarray(ref.alpha),
                                          np.asarray(got.results[lid].alpha))
            assert int(ref.n_iter) == int(got.results[lid].n_iter)
        assert got.evals == solo.evals, (name, got.evals, solo.evals)

    hits = served["alice"].dedup_hits + served["bob"].dedup_hits
    admitted = (served["alice"].sources_admitted
                + served["bob"].sources_admitted)
    mats = max(s.source_stats["materializations"] for s in served.values())
    assert hits == 1, f"expected exactly one cross-tenant dedup hit: {hits}"
    assert admitted == 3, f"expected 3 distinct sources admitted: {admitted}"
    assert mats <= 3 < solo_mats, (mats, solo_mats)

    with StudyClient(sock, "mallory") as cli:
        try:
            cli.submit("q", dataclasses.replace(plan_a, tol=1e-6))
        except PlanRejectedByServer as e:
            assert "tol" in str(e), e
        else:
            raise AssertionError("contract-violating plan was admitted")
        cli.shutdown()
    t.join(60)
    assert not t.is_alive(), "daemon did not drain"

    print(f"service smoke OK: 2 tenants bit-identical to solo runs, "
          f"{hits} dedup hit, {mats} materializations vs {solo_mats} solo, "
          f"rejection + drain clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
