"""Combine two reduced-depth hillclimb records into a full-depth estimate.

For a model = (fixed outside) + (L_moe identical MoE layers), per-step cost
is affine in L_moe: cost(L) = outside + L*per_layer. Two depths (4 and 8
MoE layers here) identify both terms exactly; extrapolation to the real 58
is then exact for flops/bytes/collectives (layer bodies are identical).

Validation: the same extrapolation applied to the SCATTER variant is
compared against the existing full-depth scatter analysis record.

    PYTHONPATH=src python scripts/hc_combine.py
"""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from repro.launch.roofline import roofline_terms  # noqa: E402

DRY = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load(cell):
    with open(os.path.join(DRY, cell + ".json")) as fh:
        return json.load(fh)


def extrapolate(rec_a, rec_b, l_a, l_b, l_full):
    out = {}
    for key in ("flops_per_device", "bytes_per_device",
                "collective_bytes_per_device"):
        a, b = rec_a[key], rec_b[key]
        per_layer = (b - a) / (l_b - l_a)
        outside = a - l_a * per_layer
        out[key] = outside + l_full * per_layer
    out["roofline"] = roofline_terms(out["flops_per_device"],
                                     out["bytes_per_device"],
                                     out["collective_bytes_per_device"])
    return out


def main():
    base = "deepseek-v3-671b__train_4k__pod16x16"
    full_scatter = load(base)
    sc = extrapolate(load(base + "__hc1_sc_d7"), load(base + "__hc1_sc_d11"),
                     4, 8, 58)
    sm = extrapolate(load(base + "__hc1_sm_d7"), load(base + "__hc1_sm_d11"),
                     4, 8, 58)
    print("== extrapolation validation (scatter d7/d11 -> 58 vs full record)")
    for k in ("flops_per_device", "bytes_per_device",
              "collective_bytes_per_device"):
        f = full_scatter[k]
        e = sc[k]
        print(f"  {k}: full={f:.3e} extrap={e:.3e} "
              f"rel_err={(abs(e - f) / max(f, 1)):.3f}")
    print("\n== HC-1 result: scatter (paper-era GShard-style) vs shard_map EP")
    for name, r in (("scatter", sc), ("shard_map", sm)):
        rf = r["roofline"]
        print(f"  {name:10s} compute={rf['compute_s']:.2f}s "
              f"memory={rf['memory_s']:.2f}s "
              f"collective={rf['collective_s']:.2f}s "
              f"dominant={rf['dominant']} "
              f"roofline_frac={rf['roofline_fraction']:.4f}")
    imp = (sc["roofline"]["bound_step_s"] / sm["roofline"]["bound_step_s"])
    print(f"\n  bound-step speedup: {imp:.1f}x")
    with open(os.path.join(DRY, base + "__hc1_combined.json"), "w") as fh:
        json.dump({"cell": base + "__hc1_combined", "status": "ok",
                   "scatter_extrapolated": sc, "shard_map_extrapolated": sm,
                   "validation_full_scatter": {
                       k: full_scatter[k] for k in
                       ("flops_per_device", "bytes_per_device",
                        "collective_bytes_per_device")}}, fh, indent=1)


if __name__ == "__main__":
    main()
