import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""SVM-side multi-pod dry-run: one distributed-SMO chunk (100 iterations,
n=4M instances, d=512 features) lowered + compiled on both production
meshes. Writes results/dryrun/svm-smo__*.json."""  # noqa: E402
import json
import time
import warnings

warnings.filterwarnings("ignore")

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.roofline import collective_bytes_from_hlo, roofline_terms  # noqa: E402
from repro.sharding import logical_to_pspec  # noqa: E402
from repro.svm.distributed import RULES, smo_iterations  # noqa: E402

N, D = 4_194_304, 512


def run(multi_pod: bool, impl: str = "gather"):
    mesh = make_production_mesh(multi_pod=multi_pod)
    name = "pod2x16x16" if multi_pod else "pod16x16"
    if impl != "gather":
        name += f"__{impl}"
    with jax.sharding.set_mesh(mesh):
        def sds(shape, dtype, axes):
            return jax.ShapeDtypeStruct(
                shape, dtype, sharding=NamedSharding(
                    mesh, logical_to_pspec(axes, RULES, mesh, shape=shape)))
        X = sds((N, D), jnp.float32, ("inst", "feat"))
        y = sds((N,), jnp.float32, ("inst",))
        mask = sds((N,), jnp.bool_, ("inst",))
        alpha = sds((N,), jnp.float32, ("inst",))
        f = sds((N,), jnp.float32, ("inst",))
        sq = sds((N,), jnp.float32, ("inst",))
        t0 = time.perf_counter()
        lowered = jax.jit(smo_iterations,
                          static_argnames=("n_iters", "gamma", "impl")).lower(
            X, y, mask, alpha, f, sq, 1.0, gamma=0.5, n_iters=100, impl=impl)
        compiled = lowered.compile()
        dt = time.perf_counter() - t0
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        coll = collective_bytes_from_hlo(compiled.as_text())
        # while-body counted once by cost analysis -> scale by the 100-iter
        # chunk explicitly (single loop, known trip count)
        iters = 100
        flops = float(cost.get("flops", 0.0)) * iters
        byts = float(cost.get("bytes accessed", 0.0)) * iters
        cbytes = coll["total_bytes"] * iters
        rec = {
            "cell": f"svm-smo__n4M_d512__{name}", "status": "ok",
            "n_devices": mesh.size, "compile_s": round(dt, 1),
            "flops_per_device": flops, "bytes_per_device": byts,
            "collective_bytes_per_device": cbytes,
            "collectives": coll["by_kind"],
            "memory": {k: getattr(mem, k, None) for k in
                       ("argument_size_in_bytes", "temp_size_in_bytes",
                        "output_size_in_bytes")},
            "roofline": roofline_terms(flops, byts, cbytes),
            "note": "per 100-iteration SMO chunk (the checkpoint/dispatch unit)",
        }
    out = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun",
                       rec["cell"] + ".json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as fh:
        json.dump(rec, fh, indent=1)
    print(rec["cell"], "compile", rec["compile_s"], "s; dominant:",
          rec["roofline"]["dominant"])


if __name__ == "__main__":
    import sys
    impl = sys.argv[1] if len(sys.argv) > 1 else "gather"
    run(False, impl)
    run(True, impl)
