import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Run one dry-run cell with config overrides + tag (the hillclimb driver).

    python scripts/hillclimb_cell.py <arch> <shape> <tag> key=val [key=val...]
"""  # noqa: E402
import sys
import warnings

warnings.filterwarnings("ignore")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import json  # noqa: E402

from repro.launch.dryrun import RESULTS_DIR, run_cell  # noqa: E402


def parse(v):
    for cast in (int, float):
        try:
            return cast(v)
        except ValueError:
            pass
    return v


def main():
    arch, shape, tag = sys.argv[1:4]
    overrides = dict(kv.split("=", 1) for kv in sys.argv[4:])
    overrides = {k: parse(v) for k, v in overrides.items()}
    from repro.launch.dryrun import RULES_PRESETS
    rules = RULES_PRESETS[overrides.pop("rules", "default")]
    rec = run_cell(arch, shape, False, os.path.abspath(RESULTS_DIR),
                   rules=rules, overrides=overrides or None, tag=tag)
    if rec["status"] == "ok":
        print(json.dumps({k: rec[k] for k in
                          ("cell", "compile_s", "analysis_compile_s",
                           "hbm_gb_per_device", "collective_bytes_per_device",
                           "flops_per_device", "bytes_per_device",
                           "useful_flops_ratio", "roofline")}, indent=1))
    else:
        print(rec["status"], rec.get("error", ""), rec.get("trace", "")[-800:])


if __name__ == "__main__":
    main()
