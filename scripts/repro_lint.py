"""Run every static analyzer in ``repro.analysis`` and gate on NEW findings.

Scope is derived, not listed: ``imports.default_scope()`` — every module
reachable from the SVM roots (repro.svm / repro.core / repro.kernels).
Unadopted seed scaffolding is excluded until something imports it.

Passes:

* ``jit_lint``      — trace-purity over the whole scope
* ``kernel_lint``   — Pallas launch configs, scope files under kernels/
* plan smoke        — a small grid-shaped plan through ``analyze_plan``
                      (catches analyzer/study API drift on every run)

The committed baseline (``results/lint_baseline.json``) holds accepted
findings with justifications; ``--check`` exits nonzero only on findings
NOT in the baseline, so CI fails on regressions, never on accepted debt.

    PYTHONPATH=src python scripts/repro_lint.py --check
    PYTHONPATH=src python scripts/repro_lint.py --write-baseline
    PYTHONPATH=src python scripts/repro_lint.py --paths src/repro/svm/cv.py
"""
import argparse
import json
import os
import pathlib
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.analysis import findings, imports, jit_lint, kernel_lint  # noqa: E402

REPO = pathlib.Path(__file__).resolve().parents[1]
DEFAULT_BASELINE = REPO / "results" / "lint_baseline.json"


def plan_smoke(report: findings.Report) -> None:
    """Analyze a small grid-shaped plan (2 sources x 2 chained lanes).
    Any finding — or an exception — is a lint failure: the plan is
    well-formed by construction, so noise here means the analyzer or the
    study API drifted."""
    import jax.numpy as jnp
    import numpy as np

    from repro.analysis.plan_check import analyze_plan
    from repro.core.study import Plan
    from repro.svm.sources import KernelSpec

    X = jnp.asarray(np.random.default_rng(0).normal(size=(16, 4)))
    y = jnp.asarray(np.where(np.arange(16) % 2, 1.0, -1.0))
    zeros = jnp.zeros(16)
    plan = Plan(sources={g: KernelSpec(X=X, gamma=0.5 * (g + 1), kind="rbf")
                         for g in range(2)}, y=y)
    for g in range(2):
        plan.lane((g, 0), source=g, train_mask=y != 0, C=1.0,
                  alpha0=zeros, f0=-y)
        plan.lane((g, 1), source=g, train_mask=y != 0, C=1.0,
                  alpha0=zeros, f0=-y, after=(g, 0))
        plan.evaluate((g, 0), jnp.arange(4))
        plan.evaluate((g, 1), jnp.arange(4))
    try:
        pa = analyze_plan(plan)
    except Exception as e:  # noqa: BLE001 — smoke must never crash the lint
        report.add("plan-smoke", "<plan:smoke>", "analyze_plan",
                   f"analyzer raised on a well-formed plan: {e!r}")
        return
    report.extend(pa.report)
    if pa.program_count < 1:
        report.add("plan-smoke", "<plan:smoke>", "analyze_plan",
                   "no programs enumerated for a plan with solved lanes")


def run(paths=None) -> findings.Report:
    scope = [pathlib.Path(p) for p in paths] if paths \
        else imports.default_scope()
    report = findings.Report()
    report.extend(jit_lint.lint_paths(scope, repo_root=REPO))
    # derived scope: launch configs live under kernels/; explicit --paths
    # runs every pass on every listed file (fixtures live elsewhere)
    kernel_scope = scope if paths else \
        [p for p in scope if "kernels" in pathlib.Path(p).parts]
    report.extend(kernel_lint.lint_paths(kernel_scope, repo_root=REPO))
    if not paths:
        plan_smoke(report)
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--check", action="store_true",
                    help="exit 1 when findings not in the baseline exist")
    ap.add_argument("--json", metavar="PATH",
                    help="write the full findings report as JSON")
    ap.add_argument("--baseline", default=str(DEFAULT_BASELINE),
                    help="baseline file (default results/lint_baseline.json)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="accept the current findings as the new baseline "
                         "(carries forward existing justifications)")
    ap.add_argument("--paths", nargs="*",
                    help="lint exactly these files instead of the derived "
                         "scope (skips the plan smoke)")
    args = ap.parse_args(argv)

    report = run(args.paths)
    baseline = findings.load_baseline(args.baseline)

    if args.json:
        payload = report.to_json()
        payload["scaffolding"] = imports.scaffolding_inventory()
        pathlib.Path(args.json).write_text(
            json.dumps(payload, indent=2) + "\n")
    if args.write_baseline:
        findings.write_baseline(report, args.baseline, previous=baseline)
        print(f"baseline written: {args.baseline} "
              f"({len(report)} findings)")
        return 0

    new = report.new_against(baseline)
    accepted = len(report) - len(new)
    print(report.render())
    print(f"-- {len(report)} findings "
          f"({accepted} baselined, {len(new)} new)")
    if args.check and new:
        print("NEW findings (fix, or --write-baseline with justification):")
        for f in new:
            print("  " + f.render())
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
