"""Fill dsv2/jamba train_4k analysis terms by depth extrapolation (their
full-depth unrolled analysis graphs compile too slowly on 1 CPU core; the
method is validated to <=4% error on dsv3 — scripts/hc_combine.py)."""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from repro.launch.roofline import roofline_terms  # noqa: E402

DRY = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")

CASES = [
    # (base cell, d_a tag, d_b tag, layers_a, layers_b, layers_full)
    ("deepseek-v2-236b__train_4k__pod16x16", "base_d5", "base_d9", 4, 8, 59),
    ("jamba-v0.1-52b__train_4k__pod16x16", "base_d8", "base_d16", 8, 16, 32),
]


def load(c):
    with open(os.path.join(DRY, c + ".json")) as fh:
        return json.load(fh)


for base, ta, tb, la, lb, lf in CASES:
    rec = load(base)
    a, b = load(base + "__" + ta), load(base + "__" + tb)
    for key in ("flops_per_device", "bytes_per_device",
                "collective_bytes_per_device"):
        per = (b[key] - a[key]) / (lb - la)
        rec[key] = a[key] + (lf - la) * per
    rec["flops_global"] = rec["flops_per_device"] * rec["n_devices"]
    rec["roofline"] = roofline_terms(rec["flops_per_device"],
                                     rec["bytes_per_device"],
                                     rec["collective_bytes_per_device"])
    rec["useful_flops_ratio"] = rec["model_flops"] / rec["flops_global"]
    rec["analysis_method"] = (f"depth-extrapolated from {ta}/{tb} "
                              "(validated <=4% err on dsv3, hc_combine.py)")
    with open(os.path.join(DRY, base + ".json"), "w") as fh:
        json.dump(rec, fh, indent=1)
    rf = rec["roofline"]
    print(f"{base}: dom={rf['dominant']} compute={rf['compute_s']:.2f}s "
          f"mem={rf['memory_s']:.2f}s coll={rf['collective_s']:.2f}s "
          f"frac={rf['roofline_fraction']:.4f}")
