#!/usr/bin/env python
"""CI smoke for active-set shrinking (DESIGN.md §Shrinking).

Runs one budgeted 3x3 grid on the truncated heart dataset with shrinking
enabled — declared cap buckets, so the static analyzer's program
enumeration is exact — and asserts the two contracts CI cares about:

* **prediction** — ``analysis.plan_check`` must predict the enlarged
  ``(single|batched, kind, width, cap, n, dtype, wss)`` program set
  exactly: predicted count == measured jit cache misses summed over the
  three chunk entry points (``chunk_jit``, ``chunk_batched_jit``,
  ``chunk_batched_sources_jit``) with caps in play;
* **optimality** — shrinking is a schedule transformation, not a solver
  change: every lane's support-vector set and held-out correct count must
  be identical to the shrink-off run of the same plan.

Exit code 0 on success; any assertion failure fails the CI step.
"""
from __future__ import annotations

import sys

import jax
import jax.numpy as jnp

from repro.analysis.plan_check import analyze_plan
from repro.core.grid import grid_plans
from repro.core.study import run_plan
from repro.data.svm_suite import make_dataset
from repro.svm import engine


def main() -> int:
    ds = make_dataset("heart", n_override=120)
    # max_resident=2 keeps the kernel LRU budget in play; width-1 keeps
    # the program set small enough to eyeball in the CI log. Cold starts
    # make the SV-set identity assertion exact: seeded chains at this size
    # converge within ~70 iterations, close enough to the tolerance floor
    # that a marginal SV (alpha ~ tol*C) can flip between the two equally
    # converged iterate sequences — a documented property of tol-bounded
    # SMO, not of shrinking (DESIGN.md §Shrinking).
    kw = dict(k=3, method="cold", chunk_iters=512, max_width=1,
              max_resident=2)
    Cs, gammas = [1.0, 2.0, 4.0], [0.05, 0.1, 0.2]
    # n=120, k=3 -> 80 train rows per fold: cap 96 both fits every train
    # set and is < n, so every lane that shrinks lands in one declared
    # bucket and the enumeration is exact (not just CAN-PRODUCE)
    shrink = dict(shrink_every=64, shrink_quantum=32, shrink_caps=(96,))

    (plan_off,) = grid_plans(ds, Cs, gammas, **kw)
    (plan_on,) = grid_plans(ds, Cs, gammas, **kw, **shrink)

    pa = analyze_plan(plan_on, backend=jax.default_backend())
    jax.clear_caches()
    res_on = run_plan(plan_on)
    measured = (engine.chunk_jit._cache_size()
                + engine.chunk_batched_jit._cache_size()
                + engine.chunk_batched_sources_jit._cache_size())
    assert pa.program_count == measured, (
        f"plan_check predicted {pa.program_count} programs "
        f"{pa.programs}, measured {measured} jit cache entries")

    res_off = run_plan(plan_off)
    for lid in res_off.results:
        sv_on = res_on.results[lid].alpha > 0
        sv_off = res_off.results[lid].alpha > 0
        assert bool(jnp.all(sv_on == sv_off)), \
            f"SV set diverged under shrinking on lane {lid}"
        on, off = int(res_on.evals[lid][0]), int(res_off.evals[lid][0])
        assert on == off, \
            f"held-out correct count diverged on lane {lid}: {on} != {off}"

    print(f"shrink smoke OK: predicted == measured == {measured} programs "
          f"({sorted(pa.programs)}); SV sets and fold accuracies identical "
          f"across {len(res_off.results)} lanes")
    return 0


if __name__ == "__main__":
    sys.exit(main())
