#!/usr/bin/env python
"""CI smoke for the static schedule simulator (DESIGN.md §Schedule
simulator).

Two scenarios, both gated on EVENT-FOR-EVENT trace equality between the
simulator's replay (exact iteration oracle) and the instrumented live
pool — the contract that keeps admission-time predictions honest:

* **budgeted 3x3 grid** — ``grid_plans`` cross-gamma pool on the
  truncated heart dataset under a 2-kernel byte budget, checkpoints on:
  eviction churn, pack/writeback lifecycle, checkpoint events;
* **two-tenant service** — two overlapping studies admitted through
  ``StudyService`` (namespaced lanes, dedup'd sources, tenant
  round-robin): ``simulate_plans`` must replay the merged multi-tenant
  schedule, shares events included.

On a mismatch the full trace diff is written to
``plan_sim_trace_diff.txt`` (uploaded as a CI artifact) and the step
fails. Exit code 0 on success.
"""
from __future__ import annotations

import json
import sys

import jax.numpy as jnp

DIFF_PATH = "plan_sim_trace_diff.txt"


def _diff(tag: str, sim_events: list, live_events: list) -> bool:
    from repro.analysis.plan_sim import render_events
    if sim_events == live_events:
        print(f"{tag}: {len(live_events)} events, simulated == live")
        return True
    divergence = next(
        (i for i, (a, b) in enumerate(zip(sim_events, live_events))
         if a != b), min(len(sim_events), len(live_events)))
    with open(DIFF_PATH, "a") as fh:
        fh.write(f"=== {tag}: first divergence at event {divergence} "
                 f"(sim {len(sim_events)} / live {len(live_events)} "
                 "events)\n")
        fh.write("--- simulated\n")
        fh.write(render_events(sim_events) + "\n")
        fh.write("--- live\n")
        fh.write(render_events(live_events) + "\n")
    print(f"{tag}: TRACE MISMATCH at event {divergence} "
          f"(sim {sim_events[divergence:divergence + 1]!r} vs live "
          f"{live_events[divergence:divergence + 1]!r}); "
          f"diff written to {DIFF_PATH}")
    return False


def budgeted_grid() -> bool:
    from repro.analysis import plan_sim
    from repro.core.grid import grid_plans
    from repro.data.svm_suite import make_dataset

    ds = make_dataset("heart", n_override=120)
    n = 120
    (plan,) = grid_plans(
        ds, Cs=[ds.C, 2 * ds.C, 4 * ds.C],
        gammas=[0.5 * ds.gamma, ds.gamma, 2 * ds.gamma], k=3,
        method="sir", chunk_iters=64, lane_quantum=2, max_width=4,
        cache_bytes=2 * n * n * 8)
    events, pool = plan_sim.dry_run(plan, snapshot_every=4)
    oracle = plan_sim.oracle_from_trace(events)
    sa = plan_sim.simulate_plan(plan, oracle=oracle, snapshot_every=4)
    ok = _diff("budgeted-grid", sa.events, events)
    if ok:
        assert sa.chunks == pool.chunk_count
        assert sa.evictions > 0, "budget never churned — weak scenario"
        print(f"  chunks={sa.chunks} peak_resident="
              f"{sa.peak_resident_bytes}B materializations="
              f"{sa.materializations} evictions={sa.evictions} "
              f"checkpoints={sa.checkpoints}")
    return ok


def two_tenant_service() -> bool:
    from repro.analysis import plan_sim
    from repro.core.cv import _fold_masks, _transition_idx
    from repro.core.study import Plan, plan_to_dict
    from repro.data.svm_suite import kfold_chunks, make_dataset
    from repro.service import StudyService
    from repro.svm.sources import KernelSpec

    ds = make_dataset("heart", n_override=120)
    X = jnp.asarray(ds.X)
    y = jnp.asarray(ds.y, jnp.float64)
    chunks = kfold_chunks(120, 4, seed=0)
    nn = chunks.size
    X, y = X[:nn], y[:nn]
    masks = jnp.asarray(_fold_masks(chunks))
    gam = {s: KernelSpec(X=X, gamma=s * ds.gamma, n=int(y.shape[0]))
           for s in (0.5, 1.0, 2.0)}

    def fold_chain(sources):
        plan = Plan(sources=dict(sources), y=y, chunk_iters=64,
                    lane_quantum=2, max_resident=3)
        n = y.shape[0]
        for key in sources:
            plan.lane((key, 0), source=key, train_mask=masks[0], C=ds.C,
                      alpha0=jnp.zeros(n), f0=-y)
            for h in range(1, 3):
                S, R, T = _transition_idx(chunks, h - 1, h)
                plan.lane((key, h), source=key, train_mask=masks[h],
                          C=ds.C, dep=(key, h - 1), transform="fold",
                          params=dict(method="sir", S_idx=S, R_idx=R,
                                      T_idx=T))
            for h in range(3):
                plan.evaluate((key, h), chunks[h])
        return plan

    plan_a = fold_chain({0.5: gam[0.5], 1.0: gam[1.0]})
    plan_b = fold_chain({1.0: gam[1.0], 2.0: gam[2.0]})
    service = StudyService(chunk_iters=64, lane_quantum=2, max_width=4,
                           max_resident=3)
    events: list = []
    service.pool.on_trace = events.append
    for tenant, pid, plan in (("alice", "a", plan_a), ("bob", "b", plan_b)):
        emitted: list = []
        service.submit(tenant, pid, json.loads(json.dumps(
            plan_to_dict(plan))), emitted.append)
        assert emitted[0]["type"] == "admitted", emitted[0]
    entries = [(st.tenant, st.plan) for st in service._studies.values()]
    while service.pool.step():
        pass
    oracle = plan_sim.oracle_from_trace(events)
    sa = plan_sim.simulate_plans(entries, oracle=oracle)
    ok = _diff("two-tenant-service", sa.events, events)
    if ok:
        assert set(sa.tenant_lane_chunks) == {"'alice'", "'bob'"}, \
            sa.tenant_lane_chunks
        assert any(e[0] == "shares" for e in events), \
            "no shares events — tenant tagging broke"
        print(f"  chunks={sa.chunks} tenant_lane_chunks="
              f"{sa.tenant_lane_chunks} materializations="
              f"{sa.materializations}")
    return ok


def main() -> int:
    ok = budgeted_grid()
    ok = two_tenant_service() and ok
    if not ok:
        return 1
    print("plan-sim smoke OK: simulated schedule == live schedule")
    return 0


if __name__ == "__main__":
    sys.exit(main())
